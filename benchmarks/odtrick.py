"""§4.2.3 O(d) projection trick: bit-identical hashes vs the naive O(Md)
construction, swept over the lattice resolution M.

Honest finding (recorded in EXPERIMENTS.md): the trick's win is a FLOP count
independent of M (2d adds/hash vs 2Md mult-adds). On GEMM-optimized backends
the naive path is a dense matmul, so wall-clock crossover sits near M ~ 100
on CPU; at production lattice resolutions (M >= 256) the trick wins outright,
and on TPU the one-hot MXU kernel (repro/kernels/alsh_project) inherits the
matmul efficiency while reading only the prefix tables.
derived = speedup per M (trick vs naive) + bit-identity check.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.core import hash_families as hf
from repro.core import transforms


def _bench_for_M(M: int, d: int = 64, H: int = 256, n: int = 512):
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (H, 2 * d, M))
    tables = hf.PrefixTables(
        folded=jax.vmap(hf._prefix_tables_from_rows)(a),
        offsets=jnp.zeros((H,)),
    )
    levels = jax.random.randint(jax.random.fold_in(key, 1), (n, d), 0, M + 1)
    a_flat = a.reshape(H, 2 * d * M)

    @jax.jit
    def naive(levels):
        P = transforms.transform_P(levels, M)  # (n, 2Md)
        return P @ a_flat.T

    @jax.jit
    def trick(levels):
        return hf.project_data(levels, tables, impl="gather")

    err = float(jnp.max(jnp.abs(naive(levels) - trick(levels))))
    assert err < 5e-2 * np.sqrt(M), err  # identical up to f32 summation order
    return time_fn(naive, levels), time_fn(trick, levels), err


def run():
    out = []
    for M in (16, 64, 256):
        us_naive, us_trick, err = _bench_for_M(M)
        out.append(row(
            f"odtrick_M{M}", us_trick,
            f"speedup={us_naive/us_trick:.2f}x,naive_us={us_naive:.0f},"
            f"flop_ratio={M}x,max_err={err:.1e}",
        ))
    return out
