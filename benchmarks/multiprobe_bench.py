"""Beyond-paper: multiprobe ALSH — recall per table budget.

Both arms go through one ``Index.query`` facade; only the QuerySpec differs
(single-probe at L tables vs multiprobe at L/4 tables, 8 probes) — the
memory-for-probes trade (≈4x less index memory at matched recall)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.api import BoundedSpace, Index, IndexConfig, QuerySpec
from repro.distance import brute_force_nn, recall_at_k


def run():
    n, d, M, b, k = 20_000, 16, 16, 32, 10
    key = jax.random.PRNGKey(3)
    space = BoundedSpace(0.0, 1.0, float(M))
    data = jax.random.uniform(jax.random.fold_in(key, 0), (n, d))
    q = jax.random.uniform(jax.random.fold_in(key, 1), (b, d))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (b, d))) + 0.2
    _, bf_ids = brute_force_nn(data, q, w, k=k)

    L_full, L_small = 16, 4
    cfg_full = IndexConfig(d=d, M=M, K=10, L=L_full, family="theta",
                           max_candidates=128, space=space)
    cfg_small = IndexConfig(d=d, M=M, K=10, L=L_small, family="theta",
                            max_candidates=128, space=space)
    idx_full = Index.build(jax.random.fold_in(key, 3), data, cfg_full)
    idx_small = Index.build(jax.random.fold_in(key, 3), data, cfg_small)

    single = QuerySpec(k=k)
    multi = QuerySpec(k=k, mode="multiprobe", n_probes=8)

    r_full = recall_at_k(idx_full.query(q, w, single).ids, bf_ids, k)
    us_full = time_fn(lambda: idx_full.query(q, w, single), iters=3) / b
    r_multi = recall_at_k(idx_small.query(q, w, multi).ids, bf_ids, k)
    us_multi = time_fn(lambda: idx_small.query(q, w, multi), iters=3) / b
    return [
        row(f"multiprobe_single_L{L_full}", us_full, f"recall@10={r_full:.2f},mem=1.0x"),
        row(f"multiprobe_8probe_L{L_small}", us_multi,
            f"recall@10={r_multi:.2f},mem={L_small/L_full:.2f}x"),
    ]
