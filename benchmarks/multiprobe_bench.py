"""Beyond-paper: multiprobe ALSH — recall per table budget.

derived shows recall@10 for: single-probe at L tables, multiprobe at L/4
tables (8 probes) — the memory-for-probes trade (≈4x less index memory at
matched recall)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.core import BoundedSpace, IndexConfig, build_index, query_index
from repro.core.multiprobe import query_multiprobe
from repro.distance import brute_force_nn


def _recall(res, bf_ids, b, k):
    return float(np.mean([
        len(set(np.asarray(res.ids[i])) & set(np.asarray(bf_ids[i]))) / k
        for i in range(b)
    ]))


def run():
    n, d, M, b, k = 20_000, 16, 16, 32, 10
    key = jax.random.PRNGKey(3)
    space = BoundedSpace(0.0, 1.0, float(M))
    data = jax.random.uniform(jax.random.fold_in(key, 0), (n, d))
    q = jax.random.uniform(jax.random.fold_in(key, 1), (b, d))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (b, d))) + 0.2
    _, bf_ids = brute_force_nn(data, q, w, k=k)

    L_full, L_small = 16, 4
    cfg_full = IndexConfig(d=d, M=M, K=10, L=L_full, family="theta",
                           max_candidates=128, space=space)
    cfg_small = IndexConfig(d=d, M=M, K=10, L=L_small, family="theta",
                            max_candidates=128, space=space)
    idx_full = build_index(jax.random.fold_in(key, 3), data, cfg_full)
    idx_small = build_index(jax.random.fold_in(key, 3), data, cfg_small)

    r_full = _recall(query_index(idx_full, q, w, cfg_full, k=k), bf_ids, b, k)
    us_full = time_fn(lambda: query_index(idx_full, q, w, cfg_full, k=k), iters=3) / b
    r_multi = _recall(query_multiprobe(idx_small, q, w, cfg_small, k=k, n_probes=8),
                      bf_ids, b, k)
    us_multi = time_fn(
        lambda: query_multiprobe(idx_small, q, w, cfg_small, k=k, n_probes=8), iters=3
    ) / b
    return [
        row(f"multiprobe_single_L{L_full}", us_full, f"recall@10={r_full:.2f},mem=1.0x"),
        row(f"multiprobe_8probe_L{L_small}", us_multi,
            f"recall@10={r_multi:.2f},mem={L_small/L_full:.2f}x"),
    ]
