"""Static-analysis gate benchmarks (benchmarks/run.py snapshots the rows
into BENCH_analysis.json).

The gate runs on every PR, so its own cost is a perf surface: the rows
time the lint pass over all of ``src/repro``, the compile-key fold of the
full raw lattice, and the per-path ``make_jaxpr`` trace + liveness scan.
The derived columns carry the report numbers the gate enforces — raw
points vs folded compile keys, the worst path's peak live MiB, lint
finding count — so the perf trajectory doubles as a budget trajectory:
a PR that widens the lattice or fattens a path moves these cells before
it moves production.

``time_fn``'s block_until_ready is a no-op here (everything host-side);
the medians are honest wall times.
"""

from __future__ import annotations

import time
from pathlib import Path

import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.analysis import audit, budgets, lint_paths

SRC_ROOT = Path(__file__).resolve().parents[1] / "src"


def run():
    rows = []

    # layer 1: the lint pass over the whole tree
    findings = lint_paths([SRC_ROOT / "repro"], root=SRC_ROOT)
    lint_us = time_fn(lambda: lint_paths([SRC_ROOT / "repro"], root=SRC_ROOT))
    rows.append(row("analysis/lint_src_repro", lint_us, f"{len(findings)}findings"))

    # layer 2 setup: the four audit index builds (the gate's fixed cost)
    t0 = time.perf_counter()
    indexes = audit.build_audit_indexes()
    build_us = (time.perf_counter() - t0) * 1e6
    g = budgets.AUDIT_GEOMETRY
    rows.append(
        row("analysis/audit_index_builds", build_us,
            f"{len(indexes)}builds@n{g['n']}")
    )

    q = jnp.zeros((g["b"], g["d"]), jnp.float32)
    w = jnp.ones((g["b"], g["d"]), jnp.float32)
    points = audit.enumerate_points()

    def fold():
        return {
            audit.compile_key(p, indexes[(p.family, p.storage)], q, w)
            for p in points
        }

    keys = fold()
    fold_us = time_fn(fold)
    rows.append(
        row("analysis/compile_key_fold", fold_us,
            f"{len(points)}raw->{len(keys)}keys(budget{budgets.RETRACE_BUDGET})")
    )

    # per-path trace + liveness scan, across one representative per key
    seen = set()
    reps = []
    for p in points:
        k = audit.compile_key(p, indexes[(p.family, p.storage)], q, w)
        if k not in seen:
            seen.add(k)
            reps.append(p)
    t0 = time.perf_counter()
    worst = ("", 0)
    for p in reps:
        closed = audit.trace_point(p, indexes[(p.family, p.storage)], q, w)
        peak = audit.peak_live_bytes(closed.jaxpr)
        if peak > worst[1]:
            worst = (p.name, peak)
    total = time.perf_counter() - t0
    rows.append(
        row("analysis/trace_and_scan_per_path", total / len(reps) * 1e6,
            f"worst={worst[0]}@{worst[1] / 2**20:.1f}MiB"
            f"(envelope{budgets.MEMORY_ENVELOPE_BYTES / 2**20:.0f}MiB)")
    )
    return rows
