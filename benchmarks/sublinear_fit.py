"""Empirical sublinearity: candidate work per query vs n, fitted exponent.

The paper's claim is O(n^rho d log n) query time. On CPU wall-time is noisy,
so the primary metric is the candidate fraction examined (the n-dependent
work term); derived = fitted exponent rho_hat of candidates ~ n^rho_hat,
which must be < 1 for the same (K, L).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.core import BoundedSpace, IndexConfig, build_index, query_index


def run():
    d, M, b = 16, 16, 32
    cfg = IndexConfig(d=d, M=M, K=12, L=16, family="theta",
                      max_candidates=256, space=BoundedSpace(0.0, 1.0, float(M)))
    key = jax.random.PRNGKey(0)
    ns = [2_000, 8_000, 32_000]
    cands = []
    us_q = 0.0
    for i, n in enumerate(ns):
        data = jax.random.uniform(jax.random.fold_in(key, i), (n, d))
        idx = build_index(jax.random.fold_in(key, 10 + i), data, cfg)
        q = jax.random.uniform(jax.random.fold_in(key, 20 + i), (b, d))
        w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 30 + i), (b, d))) + 0.2
        res = query_index(idx, q, w, cfg, k=10)
        cands.append(float(jnp.mean(res.n_candidates)))
        if n == ns[-1]:
            us_q = time_fn(lambda: query_index(idx, q, w, cfg, k=10), iters=3) / b

    # least-squares fit of log(cands) = rho_hat * log(n) + c
    lx = np.log(ns)
    ly = np.log(np.maximum(cands, 1.0))
    rho_hat = float(np.polyfit(lx, ly, 1)[0])
    return [
        row("sublinear_candidates_fit", us_q,
            f"rho_hat={rho_hat:.3f}<1,cands={[round(c) for c in cands]},ns={ns}"),
    ]
