"""Recall vs approximation budget: both ALSH families at matched candidate
budgets against the exact scan, all through the ``repro.api`` facade
(the exact reference is the same Index with QuerySpec(mode="exact")).
derived = recall@10 per configuration."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.api import BoundedSpace, Index, IndexConfig, QuerySpec
from repro.distance import recall_at_k


def run():
    n, d, M, b, k = 20_000, 16, 16, 32, 10
    key = jax.random.PRNGKey(0)
    space = BoundedSpace(0.0, 1.0, float(M))
    data = jax.random.uniform(jax.random.fold_in(key, 0), (n, d))
    q = jax.random.uniform(jax.random.fold_in(key, 1), (b, d))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (b, d))) + 0.2

    spec = QuerySpec(k=k)
    exact = QuerySpec(k=k, mode="exact")

    out = []
    bf_ids = None
    for family, K, L, W in (("theta", 10, 16, 4.0), ("theta", 12, 32, 4.0),
                            ("l2", 8, 32, 24.0)):
        cfg = IndexConfig(d=d, M=M, K=K, L=L, family=family, W=W,
                          max_candidates=256, space=space)
        index = Index.build(jax.random.fold_in(key, 3), data, cfg)
        if bf_ids is None:
            bf_ids = index.query(q, w, exact).ids
        res = index.query(q, w, spec)
        recall = recall_at_k(res.ids, bf_ids, k)
        us = time_fn(lambda: index.query(q, w, spec), iters=3) / b
        frac = float(jnp.mean(res.n_candidates)) / n
        out.append(row(f"recall_{family}_K{K}_L{L}", us,
                       f"recall@{k}={recall:.2f},cand_frac={frac:.3f}"))
        last_index = index
    # exact-scan reference line
    us_bf = time_fn(lambda: last_index.query(q, w, exact), iters=3) / b
    out.append(row("recall_exact_scan", us_bf, "recall@10=1.00,cand_frac=1.0"))
    return out
