"""Recall vs approximation budget: both ALSH families at matched candidate
budgets against the exact scan. derived = recall@10 per configuration."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.core import BoundedSpace, IndexConfig, build_index, query_index
from repro.distance import brute_force_nn


def run():
    n, d, M, b, k = 20_000, 16, 16, 32, 10
    key = jax.random.PRNGKey(0)
    space = BoundedSpace(0.0, 1.0, float(M))
    data = jax.random.uniform(jax.random.fold_in(key, 0), (n, d))
    q = jax.random.uniform(jax.random.fold_in(key, 1), (b, d))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (b, d))) + 0.2
    _, bf_ids = brute_force_nn(data, q, w, k=k)

    out = []
    for family, K, L, W in (("theta", 10, 16, 4.0), ("theta", 12, 32, 4.0),
                            ("l2", 8, 32, 24.0)):
        cfg = IndexConfig(d=d, M=M, K=K, L=L, family=family, W=W,
                          max_candidates=256, space=space)
        idx = build_index(jax.random.fold_in(key, 3), data, cfg)
        res = query_index(idx, q, w, cfg, k=k)
        recall = np.mean([
            len(set(np.asarray(res.ids[i])) & set(np.asarray(bf_ids[i]))) / k
            for i in range(b)
        ])
        us = time_fn(lambda: query_index(idx, q, w, cfg, k=k), iters=3) / b
        frac = float(jnp.mean(res.n_candidates)) / n
        out.append(row(f"recall_{family}_K{K}_L{L}", us,
                       f"recall@{k}={recall:.2f},cand_frac={frac:.3f}"))
    # exact-scan reference line
    us_bf = time_fn(lambda: brute_force_nn(data, q, w, k=k), iters=3) / b
    out.append(row("recall_exact_scan", us_bf, "recall@10=1.00,cand_frac=1.0"))
    return out
