"""Offline autotuner: is the Pareto-table prior worth shipping?

Three claims, measured per hash family on one data profile:

  1. SPEEDUP — ``Index.build(quality=...)`` with a ``Planner(table=...)``
     prior (single confirmation probe) vs the table-less calibrated path
     (full ladder). The tentpole bar is >=5x on the end-to-end build
     (``build_speedup`` in the speedup rows; the plan-resolution-only
     ratio is reported alongside as ``plan_speedup`` — at toy n the
     calibrated ladder is cheap enough that plan_speedup understates the
     win, so the bar rides the quantity users feel: build wall-clock).
  2. ADHERENCE — held-out recall@k minus the stated target for BOTH paths;
     recall targets are floors, so the bar is not falling more than 2 pt
     BELOW target (``adherence_ok``); the discrete frontier means the
     prior may overshoot, which costs latency, never quality (prior rows
     stamp provenance=prior when the confirmation probe accepted the
     frontier plan).
  3. FALLBACK — on a profile OUTSIDE every scanned bucket, planning with
     the table resolves a bit-identical PlannedSpec to planning with no
     table at all (the prior must be invisible when it doesn't apply).

The scan itself runs first (grid: family x K x L x probes at the bench
profile) against a resumable trial store under ``results/tuner_bench/`` —
rerunning the bench reuses completed trials, which doubles as a standing
resume test. The prior path is measured BEFORE the calibrated path so any
shared jit-cache warmth biases AGAINST the speedup claim, not for it.

Toy-size via TUNER_BENCH_N (CI smoke uses 2000).
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.api import Index, QualitySpec, QuerySpec
from repro.api.planner import Planner, default_calibration_weights
from repro.distance import recall_at_k
from repro.tuner import DataProfile, ScanSpace, build_table, run_scan

STORE_DIR = "results/tuner_bench"


def _bench_space(n: int, d: int) -> ScanSpace:
    """The scanned grid: small but wide enough that both families place
    >= goal entries on the frontier at the bench profile — theta reaches
    it through cheap multiprobe (L=16, 8 probes), l2 (no multiprobe)
    through the wider candidate window (2048), which is exactly the kind
    of family-asymmetric plan the theory inversion never proposes."""
    return ScanSpace(
        profiles=(DataProfile(n=n, d=d),),
        K=(10, 14, 20),
        L=(16, 32, 64),
        n_probes=(1, 8),
        window=(1024, 2048),
        k=10,
        queries=64,
    )


def _measure(key, data, q, w, quality, family, planner):
    """One quality-first build + held-out recall measurement.

    Timed at steady state: an untimed warmup build first pays the one-time
    jit compiles (identical key -> identical plan -> identical shapes), so
    the timed build is what a fleet pays per additional build of this
    profile — otherwise whichever path runs first eats the shared compile
    bill and the ratio measures call order, not work."""
    warm = Index.build(key, data, quality, family=family, planner=planner)
    jax.block_until_ready(warm.state.sorted_keys)
    t0 = time.time()
    index = Index.build(key, data, quality, family=family, planner=planner)
    jax.block_until_ready(index.state.sorted_keys)
    build_s = time.time() - t0
    plan = index.plan(quality, planner=planner)
    res = index.query(q, w, quality)
    ref = index.query(q, w, QuerySpec(k=quality.k, mode="exact"))
    recall = float(recall_at_k(res.ids, ref.ids, quality.k))
    return {
        "index": index,
        "plan": plan,
        "build_s": build_s,
        "plan_s": index.plan_times[quality],
        "recall": recall,
    }


def _fallback_row():
    """Out-of-bucket profile: table-backed planning must be bit-identical
    to table-less planning (tiny d=8 index; every bucket is d=16)."""
    key = jax.random.PRNGKey(7)
    data = jax.random.uniform(jax.random.fold_in(key, 0), (2000, 8))
    quality = QualitySpec(k=10, recall_target=0.85)
    space = ScanSpace(
        profiles=(DataProfile(n=64, d=4),), K=(4,), L=(4,),
        n_probes=(1,), window=(32,), k=2, queries=8,
    )
    records = run_scan(space, os.path.join(STORE_DIR, "fallback_trials.jsonl"))
    table = build_table(records, space)
    t0 = time.time()
    # plans must match bit-for-bit, so both sides use the same key/data
    with_table = Index.build(
        jax.random.fold_in(key, 1), data, quality, family="theta",
        planner=Planner(table=table),
    )
    without = Index.build(
        jax.random.fold_in(key, 1), data, quality, family="theta",
        planner=Planner(),
    )
    p_t, p_b = with_table.plan(quality), without.plan(quality)
    identical = p_t == p_b and with_table.config == without.config
    return row(
        "tuner_fallback_bitident",
        (time.time() - t0) * 1e6,
        f"identical={identical},provenance={p_t.provenance},"
        f"buckets_scanned={len(table.buckets)}",
    )


def run():
    n = int(os.environ.get("TUNER_BENCH_N", 20_000))
    d, b = 16, 64
    key = jax.random.PRNGKey(0)
    data = jax.random.uniform(jax.random.fold_in(key, 0), (n, d))
    q = jax.random.uniform(jax.random.fold_in(key, 1), (b, d))
    w = default_calibration_weights(jax.random.fold_in(key, 2), (b, d))
    # A demanding target is where the offline prior earns its keep: the
    # calibrated path's ladder cost scales with the theory-planned L
    # (~90-130 tables at 0.95), while the prior's cost is one confirmation
    # probe of a scanned frontier entry. Both paths get the same spec, so
    # the comparison stays fair.
    quality = QualitySpec(k=10, recall_target=0.95, fail_prob=0.05)

    space = _bench_space(n, d)
    store = os.path.join(STORE_DIR, f"trials_n{n}.jsonl")
    t0 = time.time()
    records = run_scan(space, store, log=None)
    scan_s = time.time() - t0
    table = build_table(records, space)
    out = [row(
        "tuner_scan",
        scan_s * 1e6,
        f"trials={len(records)},buckets={len(table.buckets)},"
        f"space={space.space_id},resumable_store={store}",
    )]

    for family in ("theta", "l2"):
        # prior FIRST: shared jit warmth then favors the calibrated side
        prior = _measure(
            jax.random.fold_in(key, 3), data, q, w, quality, family,
            Planner(table=table),
        )
        calib = _measure(
            jax.random.fold_in(key, 3), data, q, w, quality, family,
            Planner(),
        )
        for label, m in (("prior", prior), ("calib", calib)):
            cfg = m["index"].config
            out.append(row(
                f"tuner_{label}_{family}",
                m["build_s"] * 1e6,
                f"recall@10={m['recall']:.3f},"
                f"adherence={m['recall'] - quality.recall_target:+.3f},"
                f"adherence_ok={m['recall'] >= quality.recall_target - 0.02},"
                f"provenance={m['plan'].provenance},K={cfg.K},L={cfg.L},"
                f"C={cfg.max_candidates},mode={m['plan'].mode},"
                f"plan_s={m['plan_s']:.2f},build_s={m['build_s']:.1f}",
            ))
        build_speedup = calib["build_s"] / max(prior["build_s"], 1e-9)
        out.append(row(
            f"tuner_speedup_{family}",
            prior["plan_s"] * 1e6,
            f"build_speedup={build_speedup:.1f}x,"
            f"plan_speedup={calib['plan_s'] / max(prior['plan_s'], 1e-9):.1f}x,"
            f"bar=build_speedup>=5x,bar_met={build_speedup >= 5.0},"
            f"prior_used={prior['plan'].provenance == 'prior'}",
        ))

    out.append(_fallback_row())
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
