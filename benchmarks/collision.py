"""Validate Eq 25 / Eq 27: empirical collision probabilities of the actual
hash implementations vs the paper's closed forms, across the distance range.

derived value = max |empirical - analytic| over the sweep (should be ~1e-2
with 8192 Monte-Carlo hash draws).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core import hash_families as hf
from repro.core import theory
from repro.distance import wl1_distance


def _sweep(family: str, H: int = 8192, n_pairs: int = 24):
    d, M, W = 8, 16, 12.0
    params = hf.LSHParams(d=d, M=M, n_hashes=H, family=family, W=W)
    key = jax.random.PRNGKey(0)
    tables = hf.make_prefix_tables(key, params)
    errs = []
    for i in range(n_pairs):
        k = jax.random.fold_in(key, i + 1)
        k1, k2, k3 = jax.random.split(k, 3)
        o = jax.random.randint(k1, (1, d), 0, M + 1)
        q = jax.random.randint(k2, (1, d), 0, M + 1)
        w = jax.random.normal(k3, (1, d))
        f = hf.hash_data(o, tables, params)
        g = hf.hash_query(q, w, tables, params)
        emp = float(jnp.mean((f == g).astype(jnp.float32)))
        r = wl1_distance(o.astype(float), q.astype(float), w)[0]
        if family == "theta":
            ana = float(theory.collision_prob_theta(r, M, d, w[0]))
        else:
            ana = float(theory.collision_prob_l2(r, M, d, w[0], W))
        errs.append(abs(emp - ana))
    return max(errs)


def run():
    out = []
    for family in ("theta", "l2"):
        us = time_fn(lambda: _sweep(family, H=2048, n_pairs=4), iters=1, warmup=0)
        err = _sweep(family)
        out.append(row(f"collision_eq{'27' if family == 'theta' else '25'}_{family}",
                       us, f"max_abs_err={err:.4f}"))
        assert err < 0.05, (family, err)
    return out
