"""Analytic per-cell FLOP/byte model — the roofline's compute & memory terms.

Why analytic: XLA's ``cost_analysis()`` counts a ``lax.scan`` body ONCE (not
× trip count; verified in tests/test_dryrun_parse.py), so for scanned-layer
models its flops/bytes are misleading. The collective term stays MEASURED
(loop-aware HLO parse in launch/dryrun.py); compute/memory come from this
model, which counts exactly what our implementation executes:

  * attention: full S×S rectangle for "attn"/"global" (our flash path does
    not skip the causal upper triangle — a documented 2x waste, see §Perf),
    (W+blk)×S for local/chunked windows, S_ctx per token for decode;
  * MoE: capacity-dispatched expert FFNs (capacity_factor overhead included)
    + always-on shared expert + router;
  * mamba2 SSD: conv + intra-chunk (Q-square) + state path + projections;
  * train = fwd × (1 + 2 + 1 remat-refwd) = 4× fwd flops (remat on);
  * bytes: parameter traffic (fwd/refwd/bwd reads, grad+param+moment
    writes/reads at their dtypes) + activation carries + logits + KV/state
    traffic — a first-order HBM model, per device.
"""

from __future__ import annotations


from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig

BYTES = {"float32": 4, "bfloat16": 2, "float16": 2}


def _layer_kinds(cfg: ModelConfig):
    return list(cfg.scan_unit) * cfg.resolved_units + list(cfg.tail)


def _attn_ctx(kind: str, cfg: ModelConfig, S: int, mode: str) -> float:
    """Average context length attended per query token (as executed)."""
    base = kind.removesuffix("_moe")
    if mode == "decode":
        if base == "local":
            return min(cfg.window, S)
        if base == "chunked":
            return min(cfg.chunk_size, S)
        return S
    blk_q = cfg.attn_blk_q
    if base == "local":
        return min(cfg.window + blk_q, S)
    if base == "chunked":
        if S <= cfg.chunk_size:  # degenerates to causal (triangular skip)
            return min((S + 2 * cfg.attn_blk_kv) / 2, S)
        return min(cfg.chunk_size + blk_q, S)
    if cfg.causal:
        # triangular block skip (lax.cond in attention.py): executed context
        # per token averages (S + 2*blk_kv)/2
        return min((S + 2 * cfg.attn_blk_kv) / 2, S)
    return S  # bidirectional encoder: full rectangle


def flops_forward(cfg: ModelConfig, S: int, B: int, mode: str) -> float:
    """Global forward FLOPs for S tokens x B sequences (mode: train/prefill)
    or B single tokens against S context (mode: decode)."""
    T = B if mode == "decode" else B * S
    dm, hd = cfg.d_model, cfg.head_dim
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    total = 0.0
    for kind in _layer_kinds(cfg):
        base = kind.removesuffix("_moe")
        if base == "mamba2":
            s = cfg.ssm
            d_inner = s.expand * dm
            nh = d_inner // s.head_dim
            conv_dim = d_inner + 2 * s.n_groups * s.d_state
            d_in_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + nh
            total += 2 * T * dm * d_in_proj  # in_proj
            total += 2 * T * conv_dim * s.d_conv  # depthwise conv
            Q = 1 if mode == "decode" else min(s.chunk, S)
            # SSD: CB scores + intra apply + state build + state apply
            total += 2 * T * Q * s.n_groups * s.d_state  # C·B^T
            total += 2 * T * Q * nh * s.head_dim  # (CB⊙L)·dx
            total += 2 * 2 * T * nh * s.head_dim * s.d_state  # states in+out
            total += 2 * T * d_inner * dm  # out_proj
            continue
        # attention block
        ctx = _attn_ctx(kind, cfg, S, mode)
        total += 2 * T * dm * (H + 2 * Hkv) * hd  # qkv proj
        total += 2 * 2 * T * ctx * H * hd  # scores + pv
        total += 2 * T * H * hd * dm  # out proj
        # ffn
        if kind.endswith("_moe") and cfg.moe is not None:
            m = cfg.moe
            nmat = 3  # gated
            total += 2 * T * dm * m.n_experts  # router
            total += 2 * T * dm * m.d_ff_expert * nmat * m.capacity_factor  # routed
            total += 2 * T * dm * (m.d_ff_expert * m.n_shared) * nmat  # shared
        elif cfg.moe is not None:  # dense layer of a MoE arch
            total += 2 * T * dm * cfg.moe.d_ff_dense * 3
        elif cfg.d_ff:
            nmat = 3 if cfg.activation in ("swiglu", "geglu") else 2
            total += 2 * T * dm * cfg.d_ff * nmat
    # embed/unembed
    total += 2 * T * dm * cfg.vocab_size  # logits
    return total


def param_count(cfg: ModelConfig) -> tuple[float, float]:
    """(total, active) parameters — analytic, matches init_params to ~1%."""
    dm, hd = cfg.d_model, cfg.head_dim
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    total = active = 0.0

    def add(n, act=None):
        nonlocal total, active
        total += n
        active += n if act is None else act

    for kind in _layer_kinds(cfg):
        base = kind.removesuffix("_moe")
        if base == "mamba2":
            s = cfg.ssm
            d_inner = s.expand * dm
            nh = d_inner // s.head_dim
            conv_dim = d_inner + 2 * s.n_groups * s.d_state
            add(dm * (2 * d_inner + 2 * s.n_groups * s.d_state + nh))
            add(conv_dim * (s.d_conv + 1) + 3 * nh + d_inner)
            add(d_inner * dm)
            continue
        if base == "shared_attn":
            continue  # counted once below
        add(dm * (H + 2 * Hkv) * hd + H * hd * dm)
        if kind.endswith("_moe") and cfg.moe is not None:
            m = cfg.moe
            e = 3 * dm * m.d_ff_expert
            add(dm * m.n_experts)  # router
            add(m.n_experts * e, act=m.top_k * e)
            add(3 * dm * m.d_ff_expert * m.n_shared)
        elif cfg.moe is not None:
            add(3 * dm * cfg.moe.d_ff_dense)
        elif cfg.d_ff:
            nmat = 3 if cfg.activation in ("swiglu", "geglu") else 2
            add(nmat * dm * cfg.d_ff)
    kinds = _layer_kinds(cfg)
    if "shared_attn" in kinds:
        add(dm * (H + 2 * Hkv) * hd + H * hd * dm)
        nmat = 3 if cfg.activation in ("swiglu", "geglu") else 2
        add(nmat * dm * cfg.d_ff)
    add(cfg.vocab_size * dm)  # embed
    if not cfg.tie_embeddings and cfg.frontend != "audio":
        add(cfg.vocab_size * dm)  # lm_head
    if cfg.frontend == "audio":
        add(cfg.frontend_dim * dm + dm * cfg.vocab_size)
    return total, active


def cell_model(cfg: ModelConfig, tcfg: TrainConfig, shape: ShapeConfig,
               n_devices: int, mesh_model: int = 16) -> dict:
    """Per-device analytic flops + HBM bytes for one cell."""
    S, B = shape.seq_len, shape.global_batch
    mode = shape.kind
    total_p, active_p = param_count(cfg)
    pb = BYTES[cfg.param_dtype]
    ob = BYTES[tcfg.optimizer_dtype]
    cb = BYTES[cfg.compute_dtype]
    # how many ways weights are split for HBM-read purposes
    if mode in ("prefill", "decode") and cfg.serve_param_layout == "replicated":
        w_shards = 1 if cfg.dp_over_model else mesh_model  # TP-only (or none)
    else:
        w_shards = n_devices

    fwd = flops_forward(cfg, S, B, "prefill" if mode == "train" else mode)
    if mode == "train":
        flops = fwd * (4.0 if cfg.remat else 3.0)  # fwd + bwd(2x) (+ refwd)
    else:
        flops = fwd
    flops_dev = flops / n_devices

    # ---- HBM bytes (per device, first order) -------------------------------
    T = B if mode == "decode" else B * S
    n_layers = cfg.n_layers
    if mode == "train":
        passes = 3 if cfg.remat else 2  # weight reads: fwd, refwd, bwd
        wbytes = total_p * (passes * pb + 2 * pb + 4 * ob + 2 * ob) / n_devices
        # activations: carry in/out per layer (3 passes) + logits f32 2x
        abytes = (T * cfg.d_model * cb * 6 * n_layers) / n_devices
        abytes += (T * cfg.vocab_size * 4 * 2) / n_devices
    elif mode == "prefill":
        wbytes = total_p * pb / w_shards
        abytes = (T * cfg.d_model * cb * 4 * n_layers) / n_devices
        abytes += (B * cfg.vocab_size * 4) / n_devices  # last-pos logits only
        # KV cache writes
        kv = sum(
            min(_attn_ctx(k, cfg, S, "decode"), S) for k in _layer_kinds(cfg)
            if k.removesuffix("_moe") not in ("mamba2",)
        )
        abytes += B * kv * 2 * cfg.n_kv_heads * cfg.head_dim * cb / n_devices
    else:  # decode
        wbytes = total_p * pb / w_shards  # every weight read once per token
        kv = sum(
            min(_attn_ctx(k, cfg, S, "decode"), S) for k in _layer_kinds(cfg)
            if k.removesuffix("_moe") not in ("mamba2",)
        )
        abytes = B * kv * 2 * cfg.n_kv_heads * cfg.head_dim * cb / n_devices
        if cfg.ssm is not None:
            d_inner = cfg.ssm.expand * cfg.d_model
            nh = d_inner // cfg.ssm.head_dim
            n_mamba = sum(1 for k in _layer_kinds(cfg) if k == "mamba2")
            abytes += (B * nh * cfg.ssm.head_dim * cfg.ssm.d_state * 4 * 2 *
                       n_mamba) / n_devices
        abytes += B * cfg.vocab_size * 4 / n_devices

    model_flops = (6.0 if mode == "train" else 2.0) * active_p * T / n_devices
    return {
        "flops_dev": flops_dev,
        "bytes_dev": wbytes + abytes,
        "weight_bytes_dev": wbytes,
        "act_bytes_dev": abytes,
        "model_flops_dev": model_flops,
        "total_params": total_p,
        "active_params": active_p,
    }
