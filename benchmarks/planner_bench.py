"""Planner adherence: does stating ``recall_target`` actually deliver it?

For both hash families, build quality-first (``Index.build(key, data,
QualitySpec)``) and resolve the execution plan (``index.plan``), then
measure recall@k on HELD-OUT queries (not the calibration sample) against
the exact scan. derived = target vs measured recall (adherence = measured -
target; the acceptance bar is adherence >= -0.02) plus the planning cost
split into the build-time theory inversion and the query-time calibration
pass.

Toy-size via PLANNER_BENCH_N (CI smoke uses 4000).
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.api import Index, QualitySpec, QuerySpec
from repro.api.planner import default_calibration_weights
from repro.distance import recall_at_k


def run():
    n = int(os.environ.get("PLANNER_BENCH_N", 20_000))
    d, b = 16, 64
    key = jax.random.PRNGKey(0)
    data = jax.random.uniform(jax.random.fold_in(key, 0), (n, d))
    # held-out workload: fresh query points, the planner's reference weight
    # distribution (adherence is meaningful only when calibration and
    # serving see the same weight profile)
    q = jax.random.uniform(jax.random.fold_in(key, 1), (b, d))
    w = default_calibration_weights(jax.random.fold_in(key, 2), (b, d))

    out = []
    for family in ("theta", "l2"):
        for target in (0.85, 0.95):
            quality = QualitySpec(k=10, recall_target=target)

            # quality-first build = theory inversion + build + calibration
            # (+ escalation rebuilds when calibration misses the target);
            # the resolved plan is memoized, so index.plan() after this is
            # a dict hit
            t0 = time.time()
            index = Index.build(
                jax.random.fold_in(key, 3), data, quality, family=family
            )
            jax.block_until_ready(index.state.sorted_keys)
            t_build = time.time() - t0
            plan = index.plan(quality)

            res = index.query(q, w, quality)
            ref = index.query(q, w, QuerySpec(k=10, mode="exact"))
            recall = recall_at_k(res.ids, ref.ids, 10)
            cfg = index.config
            out.append(row(
                f"planner_{family}_target{target}",
                t_build * 1e6,
                f"recall@10={recall:.3f},adherence={recall - target:+.3f},"
                f"K={cfg.K},L={cfg.L},C={cfg.max_candidates},mode={plan.mode},"
                f"probes={plan.n_probes},cand_frac="
                f"{float(jnp.mean(res.n_candidates)) / n:.3f},"
                f"calib_recall={plan.predicted_recall:.3f},"
                f"plan_build_s={t_build:.1f}",
            ))
    return out
