"""Planner adherence: does stating ``recall_target`` actually deliver it?

For both hash families, build quality-first ONCE at the tightest target
(``Index.build(key, data, QualitySpec)``) and resolve every looser target
by RE-PLANNING on that same built index (``index.plan``) — one build per
family instead of one per row, which is both 2x cheaper and the honest
fleet shape (a deployed index serves many quality tiers). Each row then
measures recall@k on HELD-OUT queries (not the calibration sample) against
the exact scan. derived = target vs measured recall (adherence = measured -
target; the acceptance bar is adherence >= -0.02), the plan's provenance,
and the per-row planning cost (``index.plan_times``; the build row also
reports the full quality-first build wall time).

``--fast`` (or PLANNER_BENCH_FAST=1) first runs a tiny offline tuner scan
over the bench profile and hands the resulting Pareto table to the Planner,
so every row exercises the PRIOR path (single confirmation probe instead of
the calibration ladder; rows stamp provenance="prior"). The default mode is
the table-less calibrated path, unchanged.

Toy-size via PLANNER_BENCH_N (CI smoke uses 4000).
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.api import Index, QualitySpec, QuerySpec
from repro.api.planner import Planner, default_calibration_weights
from repro.distance import recall_at_k

TARGETS = (0.95, 0.85)  # tightest first: it sizes the one build per family


def _fast_planner(n: int, d: int, tmp_dir: str) -> Planner:
    """A Planner backed by a tiny scan of the bench profile (--fast mode)."""
    from repro.tuner import DataProfile, ScanSpace, build_table, run_scan

    space = ScanSpace(
        profiles=(DataProfile(n=n, d=d),),
        K=(10,), L=(32, 64), n_probes=(1, 8), window=(256,),
        k=10, queries=64,
    )
    records = run_scan(space, os.path.join(tmp_dir, "trials.jsonl"))
    return Planner(table=build_table(records, space))


def run(fast: bool | None = None):
    if fast is None:
        fast = os.environ.get("PLANNER_BENCH_FAST", "0") not in ("", "0")
    n = int(os.environ.get("PLANNER_BENCH_N", 20_000))
    d, b = 16, 64
    key = jax.random.PRNGKey(0)
    data = jax.random.uniform(jax.random.fold_in(key, 0), (n, d))
    # held-out workload: fresh query points, the planner's reference weight
    # distribution (adherence is meaningful only when calibration and
    # serving see the same weight profile)
    q = jax.random.uniform(jax.random.fold_in(key, 1), (b, d))
    w = default_calibration_weights(jax.random.fold_in(key, 2), (b, d))

    planner = Planner()
    if fast:
        import tempfile

        with tempfile.TemporaryDirectory() as tmp:
            planner = _fast_planner(n, d, tmp)

    out = []
    for family in ("theta", "l2"):
        index = None
        for target in TARGETS:
            quality = QualitySpec(k=10, recall_target=target)
            if index is None:
                # quality-first build = geometry derivation + build + plan
                # resolution (+ escalation rebuilds on a calibration miss)
                t0 = time.time()
                index = Index.build(
                    jax.random.fold_in(key, 3), data, quality,
                    family=family, planner=planner,
                )
                jax.block_until_ready(index.state.sorted_keys)
                t_build = time.time() - t0
            else:
                t_build = None  # re-plan row: same index, new target
            plan = index.plan(quality, planner=planner)

            res = index.query(q, w, quality)
            ref = index.query(q, w, QuerySpec(k=10, mode="exact"))
            recall = recall_at_k(res.ids, ref.ids, 10)
            cfg = index.config
            plan_s = index.plan_times.get(quality, float("nan"))
            out.append(row(
                f"planner_{family}_target{target}",
                (t_build if t_build is not None else plan_s) * 1e6,
                f"recall@10={recall:.3f},adherence={recall - target:+.3f},"
                f"K={cfg.K},L={cfg.L},C={cfg.max_candidates},mode={plan.mode},"
                f"probes={plan.n_probes},cand_frac="
                f"{float(jnp.mean(res.n_candidates)) / n:.3f},"
                f"calib_recall={plan.predicted_recall:.3f},"
                f"provenance={plan.provenance},plan_s={plan_s:.1f}"
                + (f",build_s={t_build:.1f}" if t_build is not None else ""),
            ))
    return out


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description="planner adherence benchmark")
    ap.add_argument("--fast", action="store_true",
                    help="scan a tiny tuner grid first and plan off the prior")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in run(fast=args.fast):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
