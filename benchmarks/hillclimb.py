import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf hillclimb driver: lower+compile ONE cell with config overrides and
report the roofline terms + memory + collective breakdown.

    PYTHONPATH=src python -m benchmarks.hillclimb --arch llama4-maverick-400b-a17b \
        --shape train_4k --set embed_table_spec=dm_data logits_dtype=bfloat16 \
        --tag mav_embed_fix

Each run appends a JSON line to results/hillclimb.jsonl — the §Perf iteration
log is assembled from these records.
"""

import argparse
import dataclasses
import json
import time


def parse_override(s: str):
    k, v = s.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return k, v == "True"
    return k, v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2"])
    ap.add_argument("--set", nargs="*", default=[], help="model cfg overrides k=v")
    ap.add_argument("--tset", nargs="*", default=[], help="train cfg overrides k=v")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--out", default="results/hillclimb.jsonl")
    args = ap.parse_args()

    from benchmarks.analytic import cell_model
    from benchmarks.roofline import HBM_BW, ICI_BW, PEAK_FLOPS
    from repro.configs import SHAPES, get_bundle
    from repro.launch.compile import lower_cell
    from repro.launch.dryrun import parse_collectives
    from repro.launch.mesh import make_production_mesh

    bundle = get_bundle(args.arch)
    m_over = dict(parse_override(s) for s in args.set)
    t_over = dict(parse_override(s) for s in args.tset)
    mcfg = dataclasses.replace(bundle.model, **m_over)
    tcfg = dataclasses.replace(bundle.train, **t_over)
    bundle = dataclasses.replace(bundle, model=mcfg, train=tcfg)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=(args.mesh == "pod2"))

    t0 = time.time()
    lowered = lower_cell(bundle, shape, mesh)
    compiled = lowered.compile()
    compile_s = time.time() - t0

    ma = compiled.memory_analysis()
    coll = parse_collectives(compiled.as_text())
    model = cell_model(mcfg, tcfg, shape, int(mesh.devices.size))
    t_comp = model["flops_dev"] / PEAK_FLOPS
    t_mem = model["bytes_dev"] / HBM_BW
    t_coll = coll["total_bytes"] / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    frac = (model["model_flops_dev"] / PEAK_FLOPS) / max(terms.values())

    rec = {
        "tag": args.tag, "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
        "overrides": {**m_over, **{f"train.{k}": v for k, v in t_over.items()}},
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dom, "roofline_fraction": frac,
        "temp_gib": ma.temp_size_in_bytes / 2**30,
        "collective_per_type": coll["per_type_bytes"],
        "collective_counts": coll["counts"],
        "compile_s": round(compile_s, 1),
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "a") as f:
        f.write(json.dumps(rec) + "\n")
    print(json.dumps(rec, indent=2))


if __name__ == "__main__":
    main()
