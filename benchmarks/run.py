"""Benchmark harness: one module per paper claim (the paper is a theory
paper — no experimental tables — so benchmarks validate its equations and
complexity claims; see DESIGN.md §1 "Validation targets").

    PYTHONPATH=src python -m benchmarks.run [--only collision,...]

Prints ``name,us_per_call,derived`` CSV. The roofline rows summarize the
compiled dry-run artifacts if present (run repro.launch.dryrun first).

The kernel rows are additionally snapshotted to ``BENCH_kernels.json``,
the mutable-lifecycle rows to ``BENCH_updates.json``, the planner
adherence rows to ``BENCH_planner.json``, and the serving-broker rows
(trace latency/throughput, degradation recall, chaos coverage) to
``BENCH_serving.json`` (cwd) — one record per row plus
backend/device metadata — so successive PRs leave a machine-readable perf
trajectory.
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback

MODULES = [
    "collision",  # Eq 25/27 Monte-Carlo validation
    "rho_tables",  # Thm 4/5 rho < 1 tables
    "odtrick",  # §4.2.3 O(d) trick equivalence + speedup
    "sublinear_fit",  # empirical n^rho_hat scaling
    "recall",  # recall@10 vs exact scan
    "multiprobe_bench",  # beyond-paper: probes-for-tables trade
    "planner_bench",  # declarative planning: recall-target adherence + cost
    "kernels_bench",  # kernel microbenchmarks
    "update_bench",  # mutable lifecycle: insert/query-vs-fill/compact
    "serving_bench",  # broker: traces, degradation recall, chaos coverage
    "roofline",  # dry-run roofline summaries (if results exist)
]


def _write_kernels_json(rows, path: str = "BENCH_kernels.json") -> None:
    import jax

    payload = {
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "rows": [
            {"name": name, "us_per_call": round(us, 2), "derived": str(derived)}
            for name, us, derived in rows
        ],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"# wrote {path} ({len(rows)} rows)", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated module list")
    args = ap.parse_args()
    mods = args.only.split(",") if args.only else MODULES

    print("name,us_per_call,derived")
    failed = []
    for name in mods:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            rows = mod.run()
            for row_name, us, derived in rows:
                print(f"{row_name},{us:.1f},{derived}")
            sys.stdout.flush()
            if name == "kernels_bench":
                _write_kernels_json(rows)
            if name == "update_bench":
                _write_kernels_json(rows, path="BENCH_updates.json")
            if name == "planner_bench":
                _write_kernels_json(rows, path="BENCH_planner.json")
            if name == "serving_bench":
                _write_kernels_json(rows, path="BENCH_serving.json")
        except Exception as e:
            failed.append(name)
            print(f"{name},NaN,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmark modules failed: {failed}")


if __name__ == "__main__":
    main()
