"""Benchmark harness: one module per paper claim (the paper is a theory
paper — no experimental tables — so benchmarks validate its equations and
complexity claims; see DESIGN.md §1 "Validation targets").

    PYTHONPATH=src python -m benchmarks.run [--only collision,...]

Prints ``name,us_per_call,derived`` CSV. The roofline rows summarize the
compiled dry-run artifacts if present (run repro.launch.dryrun first).
"""

from __future__ import annotations

import argparse
import sys
import traceback

MODULES = [
    "collision",  # Eq 25/27 Monte-Carlo validation
    "rho_tables",  # Thm 4/5 rho < 1 tables
    "odtrick",  # §4.2.3 O(d) trick equivalence + speedup
    "sublinear_fit",  # empirical n^rho_hat scaling
    "recall",  # recall@10 vs exact scan
    "multiprobe_bench",  # beyond-paper: probes-for-tables trade
    "kernels_bench",  # kernel microbenchmarks
    "roofline",  # dry-run roofline summaries (if results exist)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated module list")
    args = ap.parse_args()
    mods = args.only.split(",") if args.only else MODULES

    print("name,us_per_call,derived")
    failed = []
    for name in mods:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.1f},{derived}")
            sys.stdout.flush()
        except Exception as e:
            failed.append(name)
            print(f"{name},NaN,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmark modules failed: {failed}")


if __name__ == "__main__":
    main()
