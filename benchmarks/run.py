"""Benchmark harness: one module per paper claim (the paper is a theory
paper — no experimental tables — so benchmarks validate its equations and
complexity claims; see DESIGN.md §1 "Validation targets").

    PYTHONPATH=src python -m benchmarks.run [--only collision,...] [--skip roofline,...]

Prints ``name,us_per_call,derived`` CSV. The roofline rows summarize the
compiled dry-run artifacts if present (run repro.launch.dryrun first).

The kernel rows are additionally snapshotted to ``BENCH_kernels.json``,
the mutable-lifecycle rows to ``BENCH_updates.json``, the planner
adherence rows to ``BENCH_planner.json``, the serving-broker rows
(trace latency/throughput, degradation recall, chaos coverage) to
``BENCH_serving.json``, and the autotuner rows (prior-vs-calibrated
plan speedup + adherence) to ``BENCH_tuner.json``, and the adaptive-probing
rows (tables probed + streamed-vs-monolithic speedup) to
``BENCH_earlyexit.json`` (cwd) — one record per row plus
backend/device metadata — so successive PRs leave a machine-readable perf
trajectory.
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback

MODULES = [
    "collision",  # Eq 25/27 Monte-Carlo validation
    "rho_tables",  # Thm 4/5 rho < 1 tables
    "odtrick",  # §4.2.3 O(d) trick equivalence + speedup
    "sublinear_fit",  # empirical n^rho_hat scaling
    "recall",  # recall@10 vs exact scan
    "multiprobe_bench",  # beyond-paper: probes-for-tables trade
    "planner_bench",  # declarative planning: recall-target adherence + cost
    "kernels_bench",  # kernel microbenchmarks
    "update_bench",  # mutable lifecycle: insert/query-vs-fill/compact
    "serving_bench",  # broker: traces, degradation recall, chaos coverage
    "tuner_bench",  # offline autotuner: prior-vs-calibrated speedup + adherence
    "quant_bench",  # quantized tier: memory ratio, latency, recall delta
    "earlyexit_bench",  # adaptive probing: tables probed + speedup vs full L
    "analysis_bench",  # static-analysis gate: lint/trace cost + budget numbers
    "roofline",  # dry-run roofline summaries (if results exist)
]

# convenience aliases accepted by --only/--skip
ALIASES = {"quant": "quant_bench", "analysis": "analysis_bench",
           "earlyexit": "earlyexit_bench"}

# benchmark modules whose rows also snapshot to a machine-readable artifact
SNAPSHOTS = {
    "kernels_bench": "BENCH_kernels.json",
    "update_bench": "BENCH_updates.json",
    "planner_bench": "BENCH_planner.json",
    "serving_bench": "BENCH_serving.json",
    "tuner_bench": "BENCH_tuner.json",
    "quant_bench": "BENCH_quant.json",
    "earlyexit_bench": "BENCH_earlyexit.json",
    "analysis_bench": "BENCH_analysis.json",
}


def select_modules(only: str | None, skip: str | None) -> list:
    """Apply ``--only`` then ``--skip``; unknown names fail fast (a typo'd
    filter silently running the full suite costs minutes)."""
    mods = only.split(",") if only else list(MODULES)
    skipped = skip.split(",") if skip else []
    mods = [ALIASES.get(m, m) for m in mods]
    skipped = [ALIASES.get(m, m) for m in skipped]
    unknown = [m for m in [*mods, *skipped] if m not in MODULES]
    if unknown:
        raise SystemExit(
            f"unknown benchmark module(s) {unknown}; known: {', '.join(MODULES)}"
        )
    return [m for m in mods if m not in skipped]


def _write_kernels_json(rows, path: str = "BENCH_kernels.json") -> None:
    import jax

    payload = {
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "rows": [
            {"name": name, "us_per_call": round(us, 2), "derived": str(derived)}
            for name, us, derived in rows
        ],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"# wrote {path} ({len(rows)} rows)", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated module list")
    ap.add_argument("--skip", default=None,
                    help="comma-separated modules to exclude from the run")
    args = ap.parse_args()
    mods = select_modules(args.only, args.skip)

    print("name,us_per_call,derived")
    failed = []
    for name in mods:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            rows = mod.run()
            for row_name, us, derived in rows:
                print(f"{row_name},{us:.1f},{derived}")
            sys.stdout.flush()
            if name in SNAPSHOTS:
                _write_kernels_json(rows, path=SNAPSHOTS[name])
        except Exception as e:
            failed.append(name)
            print(f"{name},NaN,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmark modules failed: {failed}")


if __name__ == "__main__":
    main()
