"""Mutable-lifecycle benchmarks: insert throughput, query latency vs delta
fill, compact cost vs full rebuild (benchmarks/run.py snapshots the rows
into BENCH_updates.json).

What the numbers validate:

  * insert is O(H·d·m) hash + scatter — orders of magnitude cheaper than
    the O(H·d·n + L·n log n) rebuild a build-once index needs per batch;
  * two-segment query latency grows mildly with delta fill (the chunked
    delta match adds O(L·cap) key compares + its candidates to the fused
    tail) — the price of mutability between compactions;
  * compact() re-sorts WITHOUT re-hashing, so it undercuts a full
    Index.build of the same rows;
  * the engine's fused two-segment tail (in-place per-segment gather +
    chunked delta match) meets or beats the superseded concat-table tail
    (per-batch (n_main+cap, d) concatenation + dense (b, L, P, cap) key
    match) across delta capacities 1k/4k/16k — the ``engine/`` rows.

Sizes default small enough for the CI smoke (``--only update_bench``); the
shapes, not the absolute times, are the regression signal.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.api import BoundedSpace, Index, IndexConfig, QuerySpec, UpdateSpec

# UPDATE_BENCH_N scales the database down for CI smoke runs (the lifecycle
# path is exercised end-to-end either way; absolute times only mean
# something at the default size)
N = int(os.environ.get("UPDATE_BENCH_N", 30_000))
D = 16
M = 32
CAP = min(4096, max(64, N // 8))
B = 64
K_NN = 10


def _cfg() -> IndexConfig:
    return IndexConfig(
        d=D, M=M, K=10, L=32, family="theta", max_candidates=256,
        space=BoundedSpace(0.0, 1.0, float(M)),
    )


def _legacy_two_segment_query(ix, q, w, k: int):
    """The superseded pre-engine two-segment tail, inlined as the
    benchmark comparator: dense (b, L, P, cap) delta key match + per-batch
    (n_main + cap, d) concat-table gather (what query_index_segmented ran
    before the engine refactor)."""
    from repro.core import transforms
    from repro.core.index import (
        _dedupe_candidates,
        _keys_for,
        _mask_dead,
        _probe_one_table,
        delta_live_mask,
    )
    from repro.kernels import ops

    state, cfg = ix.state, ix.config
    n_main = state.n
    cap = ix.delta.capacity
    n_tot = n_main + cap
    qlevels = transforms.discretize(q, cfg.space)
    keys = _keys_for(qlevels, w, state.tables, cfg, state.mixers)  # (b, L)
    probe = jax.vmap(
        jax.vmap(_probe_one_table, in_axes=(0, 0, 0, None)),
        in_axes=(None, None, 0, None),
    )
    cand = probe(state.sorted_keys, state.perm, keys, cfg.max_candidates)
    cand = _mask_dead(cand.reshape(q.shape[0], -1), ix.tombstones, n_main, n_tot)
    live = delta_live_mask(ix.delta, ix.tombstones, n_main)
    pk = keys[:, :, None]
    match = jnp.any(pk[:, :, :, None] == ix.delta.keys[None, :, None, :], axis=(1, 2))
    slot_ids = n_main + jnp.arange(cap, dtype=jnp.int32)
    dcand = jnp.where(match & live[None, :], slot_ids[None, :], n_tot).astype(jnp.int32)
    cand = jnp.concatenate([cand, dcand], axis=1)
    cand, _ = _dedupe_candidates(cand, n_tot)
    table = jnp.concatenate([state.data, ix.delta.data.astype(state.data.dtype)])
    return ops.gather_rerank_topk(table, cand, q, w, k)


def run():
    key = jax.random.PRNGKey(0)
    data = jax.random.uniform(jax.random.fold_in(key, 0), (N, D))
    q = jax.random.uniform(jax.random.fold_in(key, 1), (B, D))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (B, D))) + 0.2
    cfg = _cfg()
    update = UpdateSpec(delta_capacity=CAP)

    rows = []

    # --- build cost (the thing updates amortize away) -----------------------
    t0 = time.perf_counter()
    index = Index.build(jax.random.fold_in(key, 3), data, cfg, update=update)
    jax.block_until_ready(index.state.sorted_keys)
    t_build_us = (time.perf_counter() - t0) * 1e6
    rows.append(row("update/build_once", t_build_us, f"n={N}"))

    # --- insert throughput (steady-state, jit-cached) -----------------------
    jinsert = jax.jit(lambda ix, r: ix.insert(r))
    for m in (64, 512):
        batch = jax.random.uniform(jax.random.fold_in(key, 10 + m), (m, D))
        us = time_fn(lambda ix=index, b=batch: jinsert(ix, b)[1])
        rows.append(
            row(f"update/insert_m{m}", us,
                f"{m / (us / 1e6):,.0f} rows/s vs rebuild {t_build_us/1e6:.2f}s")
        )

    # --- query latency vs delta fill ---------------------------------------
    jquery = jax.jit(lambda ix, qq, ww: ix.query(qq, ww, QuerySpec(k=K_NN)).dists)
    fills = (0, CAP // 4, CAP)
    base_us = None
    for fill in fills:
        ix = index
        if fill:
            extra = jax.random.uniform(jax.random.fold_in(key, 20), (fill, D))
            ix, _ = jinsert(index, extra)
        us = time_fn(lambda ix=ix: jquery(ix, q, w))
        if base_us is None:
            base_us = us
        rows.append(
            row(f"update/query_fill{fill}", us,
                f"{us / base_us:.2f}x empty-delta latency (b={B})")
        )

    # --- delete + tombstoned-query (mask overhead) --------------------------
    jdelete = jax.jit(lambda ix, ids: ix.delete(ids))
    dead = jnp.arange(0, N, 7, dtype=jnp.int32)  # ~14% churn
    us = time_fn(lambda: jdelete(index, dead).tombstones)
    rows.append(row("update/delete_14pct", us, f"{dead.shape[0]} tombstones"))
    ix_dead = jdelete(index, dead)
    us = time_fn(lambda: jquery(ix_dead, q, w))
    rows.append(row("update/query_tombstoned", us, f"{us / base_us:.2f}x clean"))

    # --- engine: two-segment fused tail vs old concat tail, cap sweep -------
    # full delta at each capacity; fused = the production engine path
    # (in-place per-segment gather, chunked key match), legacy = the
    # superseded dense-match + concat-table tail it replaced. cap=16384 was
    # previously outside the dense match's comfort zone (DESIGN.md §7, now
    # consumed).
    jlegacy = jax.jit(lambda ix, qq, ww: _legacy_two_segment_query(ix, qq, ww, K_NN)[0])
    for cap in (1024, 4096, 16384):
        ix_cap = Index.build(
            jax.random.fold_in(key, 40), data, cfg,
            update=UpdateSpec(delta_capacity=cap),
        )
        fill_rows = jax.random.uniform(jax.random.fold_in(key, 41), (cap, D))
        ix_cap, _ = ix_cap.insert(fill_rows)
        us_fused = time_fn(lambda ix=ix_cap: jquery(ix, q, w))
        us_legacy = time_fn(lambda ix=ix_cap: jlegacy(ix, q, w))
        rows.append(
            row(f"engine/two_segment_fused_cap{cap}", us_fused,
                f"legacy_concat_us={us_legacy:.1f};"
                f"speedup={us_legacy / us_fused:.2f}x (b={B}, full delta)")
        )

    # --- compact vs rebuild -------------------------------------------------
    extra = jax.random.uniform(jax.random.fold_in(key, 30), (CAP, D))
    ix_full, _ = jinsert(index, extra)
    ix_full = jdelete(ix_full, dead)

    def compact():
        return ix_full.compact().state.sorted_keys

    t0 = time.perf_counter()
    jax.block_until_ready(compact())
    t_compact_us = (time.perf_counter() - t0) * 1e6
    survivors = ix_full.n_live
    rows.append(
        row("update/compact", t_compact_us,
            f"{survivors} survivors, {t_compact_us / t_build_us:.2f}x build "
            "(resort without rehash)")
    )
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
