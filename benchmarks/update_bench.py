"""Mutable-lifecycle benchmarks: insert throughput, query latency vs delta
fill, compact cost vs full rebuild (benchmarks/run.py snapshots the rows
into BENCH_updates.json).

What the numbers validate:

  * insert is O(H·d·m) hash + scatter — orders of magnitude cheaper than
    the O(H·d·n + L·n log n) rebuild a build-once index needs per batch;
  * two-segment query latency grows mildly with delta fill (the dense
    delta match adds O(L·cap) key compares + its candidates to the fused
    tail) — the price of mutability between compactions;
  * compact() re-sorts WITHOUT re-hashing, so it undercuts a full
    Index.build of the same rows.

Sizes default small enough for the CI smoke (``--only update_bench``); the
shapes, not the absolute times, are the regression signal.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.api import BoundedSpace, Index, IndexConfig, QuerySpec, UpdateSpec

# UPDATE_BENCH_N scales the database down for CI smoke runs (the lifecycle
# path is exercised end-to-end either way; absolute times only mean
# something at the default size)
N = int(os.environ.get("UPDATE_BENCH_N", 30_000))
D = 16
M = 32
CAP = min(4096, max(64, N // 8))
B = 64
K_NN = 10


def _cfg() -> IndexConfig:
    return IndexConfig(
        d=D, M=M, K=10, L=32, family="theta", max_candidates=256,
        space=BoundedSpace(0.0, 1.0, float(M)),
    )


def run():
    key = jax.random.PRNGKey(0)
    data = jax.random.uniform(jax.random.fold_in(key, 0), (N, D))
    q = jax.random.uniform(jax.random.fold_in(key, 1), (B, D))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (B, D))) + 0.2
    cfg = _cfg()
    update = UpdateSpec(delta_capacity=CAP)

    rows = []

    # --- build cost (the thing updates amortize away) -----------------------
    t0 = time.perf_counter()
    index = Index.build(jax.random.fold_in(key, 3), data, cfg, update=update)
    jax.block_until_ready(index.state.sorted_keys)
    t_build_us = (time.perf_counter() - t0) * 1e6
    rows.append(row("update/build_once", t_build_us, f"n={N}"))

    # --- insert throughput (steady-state, jit-cached) -----------------------
    jinsert = jax.jit(lambda ix, r: ix.insert(r))
    for m in (64, 512):
        batch = jax.random.uniform(jax.random.fold_in(key, 10 + m), (m, D))
        us = time_fn(lambda ix=index, b=batch: jinsert(ix, b)[1])
        rows.append(
            row(f"update/insert_m{m}", us,
                f"{m / (us / 1e6):,.0f} rows/s vs rebuild {t_build_us/1e6:.2f}s")
        )

    # --- query latency vs delta fill ---------------------------------------
    jquery = jax.jit(lambda ix, qq, ww: ix.query(qq, ww, QuerySpec(k=K_NN)).dists)
    fills = (0, CAP // 4, CAP)
    base_us = None
    for fill in fills:
        ix = index
        if fill:
            extra = jax.random.uniform(jax.random.fold_in(key, 20), (fill, D))
            ix, _ = jinsert(index, extra)
        us = time_fn(lambda ix=ix: jquery(ix, q, w))
        if base_us is None:
            base_us = us
        rows.append(
            row(f"update/query_fill{fill}", us,
                f"{us / base_us:.2f}x empty-delta latency (b={B})")
        )

    # --- delete + tombstoned-query (mask overhead) --------------------------
    jdelete = jax.jit(lambda ix, ids: ix.delete(ids))
    dead = jnp.arange(0, N, 7, dtype=jnp.int32)  # ~14% churn
    us = time_fn(lambda: jdelete(index, dead).tombstones)
    rows.append(row("update/delete_14pct", us, f"{dead.shape[0]} tombstones"))
    ix_dead = jdelete(index, dead)
    us = time_fn(lambda: jquery(ix_dead, q, w))
    rows.append(row("update/query_tombstoned", us, f"{us / base_us:.2f}x clean"))

    # --- compact vs rebuild -------------------------------------------------
    extra = jax.random.uniform(jax.random.fold_in(key, 30), (CAP, D))
    ix_full, _ = jinsert(index, extra)
    ix_full = jdelete(ix_full, dead)

    def compact():
        return ix_full.compact().state.sorted_keys

    t0 = time.perf_counter()
    jax.block_until_ready(compact())
    t_compact_us = (time.perf_counter() - t0) * 1e6
    survivors = ix_full.n_live
    rows.append(
        row("update/compact", t_compact_us,
            f"{survivors} survivors, {t_compact_us / t_build_us:.2f}x build "
            "(resort without rehash)")
    )
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
