"""Adaptive-probing benchmarks: tables probed + end-to-end speedup vs the
full-L monolithic tail (benchmarks/run.py snapshots the rows into
BENCH_earlyexit.json).

What the numbers validate:

  * the planner provisions L for the WORST query (Eq 24/26), but on a
    clustered workload the streamed tail's confidence stop (Eq 25/27 at
    the observed running radius, slack 0.1 ≈ recall target 0.9) retires
    most queries after a fraction of the windows — mean tables probed
    should sit at or under 0.5·L on the deep plans;
  * stopping early must not spend the recall the plan promised: measured
    recall@10 of the streamed run stays within 2 points of the full-L
    run at every plan depth;
  * fewer windows is real wall-clock, not accounting: end-to-end speedup
    vs the monolithic tail grows with plan depth (the L=88 worst-case
    plan is the acceptance bar at >= 1.3x).

The workload is the favourable-but-honest case for adaptive probing:
near-duplicate clusters (20k rows by default) with queries on the
cluster centres, where the true neighbours land in the query's own
rank-0 buckets and deep plans are pure insurance. This is also the
regime where the Eq 27 estimate is CALIBRATED: at radii well inside one
lattice cell the discretized levels match and collision is near-certain,
exactly as the formula says. At multi-cell radii the formula reads
optimistic against this implementation (the same gap the planner's
empirical calibration pass exists to absorb — see planner_bench), so a
diffuse workload would stop early against an overestimate; the slack
knob, not this bench, is the lever there. Uniform noise queries would
instead exercise the exhausted path (bit-identical to off — covered by
tests/test_earlyexit.py).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.api import BoundedSpace, Index, IndexConfig, QuerySpec
from repro.distance import recall_at_k

N = int(os.environ.get("EARLYEXIT_BENCH_N", 20_000))
D = 16
M = 32
B = 64
K_NN = 10
CLUSTER = 10  # rows per cluster (= K_NN: each query's true top-10)
SIGMA = 1e-5  # cluster radius << lattice step: the Eq 27-calibrated regime
PLAN_LS = (16, 44, 88)  # planner ladder depths: shallow -> worst-case
EXIT_GROUP = 4
EXIT_SLACK = 0.1  # miss budget ~ (1 - recall_target) at target 0.9


def _cfg(L: int) -> IndexConfig:
    # K=10 keeps buckets (~n/2^K rows) inside the 256-candidate window so
    # recall measures collisions, not window truncation
    return IndexConfig(
        d=D, M=M, K=10, L=L, family="theta", max_candidates=256,
        space=BoundedSpace(0.0, 1.0, float(M)),
    )


def _workload(key):
    """Clustered rows + near-centre queries: every query's top-10 is its
    own cluster, reachable from the rank-0 buckets."""
    n_clusters = N // CLUSTER
    centers = jax.random.uniform(
        jax.random.fold_in(key, 1), (n_clusters, D), minval=0.1, maxval=0.9
    )
    jitter = SIGMA * jax.random.normal(
        jax.random.fold_in(key, 2), (n_clusters, CLUSTER, D)
    )
    data = (centers[:, None, :] + jitter).reshape(-1, D)
    qidx = jax.random.choice(
        jax.random.fold_in(key, 3), n_clusters, (B,), replace=False
    )
    q = centers[qidx] + SIGMA * jax.random.normal(
        jax.random.fold_in(key, 4), (B, D)
    )
    # mild per-query weight skew: the asymmetric embedding's angle at r=0
    # grows with weight spread (Eq 26's cos = Σw / sqrt(d·Σw²)), and the
    # stop bound inherits that optimism — heavy skew belongs to the
    # planner-calibration story, not this latency bench
    w = 1.0 + 0.1 * jnp.abs(jax.random.normal(jax.random.fold_in(key, 5), (B, D)))
    return data, q, w


def run():
    key = jax.random.PRNGKey(0)
    data, q, w = _workload(key)
    rows = []

    for L in PLAN_LS:
        index = Index.build(jax.random.fold_in(key, 10 + L), data, _cfg(L))
        oracle = index.query(q, w, QuerySpec(k=K_NN, mode="exact"))
        off = QuerySpec(k=K_NN)
        on = QuerySpec(k=K_NN, early_exit=True, exit_group=EXIT_GROUP,
                       exit_slack=EXIT_SLACK)

        res_off = index.query(q, w, off)
        rec_off = float(recall_at_k(res_off.ids, oracle.ids, K_NN))
        us_off = time_fn(lambda: index.query(q, w, off)) / B
        rows.append(row(
            f"earlyexit/L{L}/off", us_off,
            f"recall={rec_off:.3f};tables={L}",
        ))

        res_on = index.query(q, w, on)
        rec_on = float(recall_at_k(res_on.ids, oracle.ids, K_NN))
        probed = np.asarray(res_on.tables_probed)
        us_on = time_fn(lambda: index.query(q, w, on)) / B
        rows.append(row(
            f"earlyexit/L{L}/on", us_on,
            f"recall={rec_on:.3f};mean_tables={probed.mean():.2f};"
            f"p99_tables={np.percentile(probed, 99):.1f};"
            f"tables_frac={probed.mean() / L:.3f};"
            f"speedup={us_off / us_on:.2f}",
        ))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
