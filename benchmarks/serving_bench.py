"""Serving-tier benchmarks: broker latency/throughput under arrival traces,
degraded-vs-full recall along the ladder, chaos coverage + recovery
(benchmarks/run.py snapshots the rows into BENCH_serving.json).

What the numbers validate:

  * dynamic batching + the power-of-two bucket ladder serve ragged Poisson
    and bursty arrivals through ONE warm jit cache (the broker asserts no
    retrace after every run) — p50/p99/throughput/shed-rate rows per trace;
  * the degradation ladder's rungs trade calibrated recall for candidate
    volume — the rung recall rows measure each rung against the exact
    oracle on the bench queries, which is the recall a degraded response's
    label promises;
  * under a mid-stream shard kill the broker keeps answering from
    survivors with labeled ``coverage == (S-1)/S``, walks the capped
    exponential backoff, recovers the shard from its persisted manifest,
    and post-recovery answers are bit-identical to pre-failure ones.

Arrival rates are derived from the measured full-bucket service time, so
the load factors (not the absolute req/s) are the regression signal:
poisson runs at ~0.6x capacity (healthy), bursty bursts at ~2.4x
(overload — the degradation/shedding drill). SERVING_BENCH_N scales the
database for CI smoke runs.
"""

from __future__ import annotations

import os
import tempfile

import jax
import numpy as np

from benchmarks.common import row, time_fn
from repro.api import Index, QualitySpec, QuerySpec
from repro.distance import recall_at_k
from repro.serving import (
    Broker,
    BrokerConfig,
    ChaosPlan,
    ShardSet,
    SLOConfig,
    bursty_trace,
    poisson_trace,
    requests_from_trace,
)

N = int(os.environ.get("SERVING_BENCH_N", 20_000))
N_REQ = int(os.environ.get("SERVING_BENCH_REQUESTS", 600))
D = 16
K_NN = 10
MAX_BATCH = 32
SHARDS = 4


def _queries(key, b: int = 256):
    q = np.asarray(jax.random.uniform(jax.random.fold_in(key, 1), (b, D)))
    w = np.abs(np.asarray(
        jax.random.normal(jax.random.fold_in(key, 2), (b, D))
    )) + 0.1
    return q.astype(np.float32), w.astype(np.float32)


def run():
    key = jax.random.PRNGKey(0)
    data = jax.random.uniform(jax.random.fold_in(key, 0), (N, D))
    quality = QualitySpec(k=K_NN, recall_target=0.9)
    index = Index.build(jax.random.fold_in(key, 3), data, quality)
    ladder = index.plan_ladder(quality)
    q, w = _queries(key)
    rows = []

    # --- degraded-vs-full recall: what each rung's label promises ----------
    exact = index.query(q, w, QuerySpec(k=K_NN, mode="exact"))
    for r, spec in enumerate(ladder):
        res = index.query(q, w, spec)
        rec = float(recall_at_k(res.ids, exact.ids, K_NN))
        rows.append(
            row(f"serving/rung{r}_recall_pct", 100.0 * rec,
                f"measured vs exact; label predicts "
                f"{float(spec.predicted_recall):.3f} "
                f"(mode={spec.mode}, probes={spec.n_probes})")
        )

    # --- capacity probe: full-bucket service time sets the arrival rates ---
    spec0 = ladder[0]
    qb, wb = q[:MAX_BATCH], w[:MAX_BATCH]
    t_batch_us = time_fn(lambda: index.query(qb, wb, spec0).dists)
    cap_rps = MAX_BATCH / (t_batch_us / 1e6)
    rows.append(
        row("serving/full_bucket_query", t_batch_us,
            f"b={MAX_BATCH}; engine capacity ~{cap_rps:,.0f} req/s")
    )
    slo = SLOConfig(p99_ms=max(5.0, 4.0 * t_batch_us / 1e3))

    traces = {
        "poisson": poisson_trace(0.6 * cap_rps, N_REQ, seed=1),
        "bursty": bursty_trace(0.3 * cap_rps, 2.4 * cap_rps, N_REQ, seed=2,
                               period_s=max(0.05, 50 * t_batch_us / 1e6)),
    }
    for kind, trace in traces.items():
        broker = Broker(index, quality, slo,
                        BrokerConfig(max_batch=MAX_BATCH, max_queue=4 * MAX_BATCH))
        responses, stats = broker.run(requests_from_trace(trace, q, w))
        broker.assert_no_retrace()
        extra = (f"SLO_p99_ms={slo.p99_ms:.1f};rungs={stats.rung_counts};"
                 f"degraded_frac={stats.degraded_frac:.3f}")
        rows.append(row(f"serving/{kind}_p50", stats.p50_ms * 1e3,
                        f"p50 latency ({kind} arrivals, no retrace)"))
        rows.append(row(f"serving/{kind}_p99", stats.p99_ms * 1e3, extra))
        rows.append(row(f"serving/{kind}_throughput",
                        1e6 / max(stats.throughput_rps, 1e-9),
                        f"{stats.throughput_rps:,.0f} req/s served"))
        rows.append(row(f"serving/{kind}_shed_rate_pct", 100.0 * stats.shed_rate,
                        f"{stats.shed} of {len(responses)} shed"))

    # --- chaos: mid-stream shard kill under the poisson trace ---------------
    with tempfile.TemporaryDirectory(prefix="repro_serving_bench_") as root:
        ss = ShardSet.build(index, SHARDS, root)
        pre = ss.query(q, w, spec0)
        kill_at = float(traces["poisson"][N_REQ // 4])
        ss.chaos = ChaosPlan(
            kill_shard=1, kill_at_s=kill_at, recovery_failures=2,
            backoff_base_s=2 * t_batch_us / 1e6, backoff_cap_s=0.5,
        )
        broker = Broker(index, quality, slo,
                        BrokerConfig(max_batch=MAX_BATCH, max_queue=4 * MAX_BATCH),
                        shardset=ss)
        responses, stats = broker.run(
            requests_from_trace(traces["poisson"], q, w)
        )
        broker.assert_no_retrace()
        served = [r for r in responses if r.status != "shed"]
        expect = (SHARDS - 1) / SHARDS
        n_degraded_cov = sum(
            1 for r in served if abs(r.coverage - expect) < 1e-9
        )
        events = [e["event"] for e in ss.recovery_log]
        post = ss.query(q, w, spec0)
        identical = (np.array_equal(pre.ids, post.ids)
                     and np.array_equal(pre.dists, post.dists))
        rows.append(
            row("serving/chaos_p99", stats.p99_ms * 1e3,
                f"1 of {SHARDS} shards killed mid-stream; "
                f"mean_coverage={stats.mean_coverage:.3f}")
        )
        rows.append(
            row("serving/chaos_survivor_answers", float(n_degraded_cov),
                f"responses labeled coverage={expect} while shard down; "
                f"events={events}")
        )
        rows.append(
            row("serving/chaos_recovery", float(events.count("recover_failed")),
                f"injected failures before recovery; recovered="
                f"{'recovered' in events}; post-recovery bit-identical="
                f"{identical}")
        )
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
