"""Theorem 4/5 complexity tables: rho = log P1 / log P2 over (R1/R2, w-scale,
family) grids. Sublinearity requires rho < 1 everywhere; derived = max rho.

(The paper proves rho < 1 for any R1 < R2; this table quantifies HOW sublinear
each regime is — the theta family wins broadly, the l2 family is competitive
only when weights sit near 1 — see DESIGN.md.)
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core import theory


def _grid(family: str):
    d, M, W = 16, 32, 16.0
    rows = []
    for wscale in (0.25, 1.0, 4.0):
        w = jnp.full((d,), wscale)
        rmax = float(M * jnp.sum(jnp.abs(w)))
        for f1, f2 in ((0.01, 0.1), (0.05, 0.25), (0.1, 0.5)):
            r = float(theory.rho(jnp.asarray(f1 * rmax), jnp.asarray(f2 * rmax),
                                 M, d, w, family=family, W=W))
            rows.append((wscale, f1, f2, r))
    return rows


def run():
    out = []
    for family in ("theta", "l2"):
        us = time_fn(lambda: _grid(family), iters=2, warmup=1)
        rows = _grid(family)
        worst = max(r for *_a, r in rows)
        best = min(r for *_a, r in rows)
        out.append(row(f"rho_table_{family}", us,
                       f"rho_range=[{best:.3f},{worst:.3f}]<1"))
        assert worst < 1.0, (family, rows)
    return out
