"""Kernel microbenchmarks: CPU production path (jnp oracle) timings + Pallas
interpret-mode validation cost. On TPU the ops.py dispatcher switches to the
compiled Pallas kernels; the dry-run roofline covers their cost model."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.kernels import ops, ref


def run():
    key = jax.random.PRNGKey(0)
    n, d, H, M = 4096, 64, 256, 32
    levels = jax.random.randint(key, (n, d), 0, M + 1)
    folded = jax.random.normal(jax.random.fold_in(key, 1), (H, d, M + 1))
    weights = jax.random.normal(jax.random.fold_in(key, 2), (n, d))

    proj = jax.jit(lambda l, f: ops.alsh_project(l, f))
    proj_w = jax.jit(lambda l, f, w: ops.alsh_project(l, f, w))
    out = [
        row("kernel_alsh_project_data", time_fn(proj, levels, folded),
            f"n={n},d={d},H={H},M={M}"),
        row("kernel_alsh_project_query", time_fn(proj_w, levels, folded, weights),
            "weighted"),
    ]

    nd, b, dd = 65536, 64, 128
    data = jax.random.normal(jax.random.fold_in(key, 3), (nd, dd))
    q = jax.random.normal(jax.random.fold_in(key, 4), (b, dd))
    w = jax.random.normal(jax.random.fold_in(key, 5), (b, dd))
    scan = jax.jit(lambda: ops.wl1_scan(data, q, w))
    out.append(row("kernel_wl1_scan", time_fn(scan),
                   f"n={nd},b={b},d={dd} ({nd*b*dd*3/1e9:.1f} GOP)"))

    pts = jax.random.normal(jax.random.fold_in(key, 6), (b, 512, dd))
    rer = jax.jit(lambda: ops.wl1_rerank(pts, q, w))
    out.append(row("kernel_wl1_rerank", time_fn(rer), f"b={b},C=512,d={dd}"))
    return out
