"""Kernel microbenchmarks: CPU production path (jnp oracle) timings + Pallas
interpret-mode validation cost, plus the fused-vs-unfused probe-tail rows
that track the PR-over-PR perf trajectory (benchmarks/run.py snapshots them
into BENCH_kernels.json). On TPU the ops.py dispatcher switches to the
compiled Pallas kernels; the dry-run roofline covers their cost model.

Fused-tail methodology: the "3-step path" is the seed's candidate tail as
separately dispatched kernel stages — gather the (b, P, d) candidate tensor,
``wl1_rerank`` it, ``lax.top_k`` the result — each materializing its output
(exactly how this file benchmarks every other kernel). The fused row is one
``ops.gather_rerank_topk`` call on the same deduped candidate ids. Candidate
ids come from REAL probes of a built index (planted near-neighbour queries,
the paper's R1-NNS regime) so the padding/duplicate structure the fused
kernel exploits is the production one.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.kernels import ops, ref


def _probe_candidates(key, data, queries, weights, L: int, C: int, M: int):
    """Real probe → dedupe ids for a (L, C) budget over the given table."""
    from repro.api import BoundedSpace, Index, IndexConfig
    from repro.core import transforms
    from repro.core.index import _dedupe_candidates, _keys_for, _probe_one_table

    n, d = data.shape
    b = queries.shape[0]
    cfg = IndexConfig(
        d=d, M=M, K=14, L=L, family="theta", max_candidates=C,
        space=BoundedSpace(0.0, 1.0, float(M)),
    )
    idx = Index.build(key, data, cfg).state  # engine pytree for kernel-level rows
    qlevels = transforms.discretize(queries, cfg.space)
    qkeys = _keys_for(qlevels, weights, idx.tables, cfg, idx.mixers)
    probe = jax.vmap(
        jax.vmap(_probe_one_table, in_axes=(0, 0, 0, None)), in_axes=(None, None, 0, None)
    )
    cand = probe(idx.sorted_keys, idx.perm, qkeys, C).reshape(b, L * C)
    ids, n_cand = jax.jit(_dedupe_candidates, static_argnums=1)(cand, n)
    return ids, float(jnp.mean(n_cand))


def _fused_tail_rows(key):
    """Fused gather+rerank+topk vs the unfused 3-step path, b=64 d=128."""
    n, b, d, k, M = 65536, 64, 128, 10, 16
    data = jax.random.uniform(jax.random.fold_in(key, 0), (n, d))
    base = jax.random.randint(jax.random.fold_in(key, 1), (b,), 0, n)
    q = jnp.clip(
        data[base] + 0.01 * jax.random.normal(jax.random.fold_in(key, 2), (b, d)), 0, 1
    )
    w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 3), (b, d))) + 0.1

    gather = jax.jit(lambda data, ids: data[jnp.minimum(ids, n - 1)])
    rerank = jax.jit(ops.wl1_rerank)

    @jax.jit
    def topk_step(dists, ids):
        dists = jnp.where(ids < n, dists, jnp.inf)
        neg, sel = jax.lax.top_k(-dists, k)
        outd = -neg
        return outd, jnp.where(
            jnp.isfinite(outd), jnp.take_along_axis(ids, sel, axis=1), -1
        )

    def unfused(data, ids, q, w):
        # three separate dispatches, each materializing its output; ordering
        # is enforced by data dependence (no artificial host syncs) and
        # time_fn blocks on the final result.
        pts = gather(data, ids)
        dists = rerank(pts, q, w)
        return topk_step(dists, ids)

    # the seed's compiled behavior: same 3 steps inside ONE jit region
    # (what query_index actually traced pre-fusion) — reported alongside so
    # the trajectory records both comparators.
    seed_jit = jax.jit(functools.partial(ref.gather_rerank_topk, k=k))

    fused = jax.jit(functools.partial(ops.gather_rerank_topk, k=k))

    out = []
    for P in (512, 1024, 2048, 4096):
        ids, uniq = _probe_candidates(
            jax.random.fold_in(key, 100 + P), data, q, w, L=8, C=P // 8, M=M
        )
        t_un = time_fn(unfused, data, ids, q, w)
        t_jit = time_fn(seed_jit, data, ids, q, w)
        t_f = time_fn(fused, data, ids, q, w)
        out.append(
            row(
                f"kernel_fused_tail_P{P}",
                t_f,
                f"b={b},d={d},k={k},uniq={uniq:.0f};unfused_us={t_un:.1f};"
                f"seedjit_us={t_jit:.1f};speedup={t_un / t_f:.2f}x;"
                f"speedup_vs_seedjit={t_jit / t_f:.2f}x",
            )
        )
    return out


def _segmented_tail_rows(key):
    """Engine two-segment tail: fused per-segment gather (``delta=``) vs
    the superseded concat-table path (materialize [main; delta], single
    gather) — same deduped candidate ids addressing both segments."""
    from repro.core.index import _dedupe_candidates

    n, cap, b, d, k = 65536, 4096, 64, 128, 10
    main = jax.random.uniform(jax.random.fold_in(key, 0), (n, d))
    delta = jax.random.uniform(jax.random.fold_in(key, 1), (cap, d))
    q = jax.random.uniform(jax.random.fold_in(key, 2), (b, d))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 3), (b, d))) + 0.1
    n_tot = n + cap

    fused = jax.jit(
        lambda m, dl, ids, q, w: ops.gather_rerank_topk(m, ids, q, w, k, delta=dl)
    )
    concat = jax.jit(
        lambda m, dl, ids, q, w: ops.gather_rerank_topk(
            jnp.concatenate([m, dl]), ids, q, w, k
        )
    )
    out = []
    for P in (1024, 4096):
        # ~1/8 of candidates land in the delta segment, ~20% sentinels —
        # the id mix a full delta produces after dedupe
        km = jax.random.fold_in(key, 100 + P)
        ids_m = jax.random.randint(jax.random.fold_in(km, 0), (b, (P * 7) // 8), 0, n)
        ids_d = jax.random.randint(
            jax.random.fold_in(km, 1), (b, P - (P * 7) // 8), n, n_tot + n_tot // 4
        )
        ids, _ = jax.jit(_dedupe_candidates, static_argnums=1)(
            jnp.concatenate([ids_m, ids_d], axis=1).astype(jnp.int32), n_tot
        )
        t_f = time_fn(fused, main, delta, ids, q, w)
        t_c = time_fn(concat, main, delta, ids, q, w)
        out.append(
            row(
                f"kernel_fused_tail_two_segment_P{P}",
                t_f,
                f"b={b},d={d},k={k},cap={cap};concat_us={t_c:.1f};"
                f"speedup={t_c / t_f:.2f}x",
            )
        )
    return out


def _scan_topk_rows(key):
    """Streaming top-k scan vs materializing scan + top_k baseline."""
    n, b, d, k = 65536, 64, 128, 10
    data = jax.random.normal(jax.random.fold_in(key, 0), (n, d))
    q = jax.random.normal(jax.random.fold_in(key, 1), (b, d))
    w = jax.random.normal(jax.random.fold_in(key, 2), (b, d))

    scan = jax.jit(ops.wl1_scan)

    @jax.jit
    def topk_step(dists):
        neg, ids = jax.lax.top_k(-dists, k)
        return -neg, ids

    def unfused(data, q, w):
        dists = jax.block_until_ready(scan(data, q, w))
        return topk_step(dists)

    fused = jax.jit(functools.partial(ops.wl1_scan_topk, k=k))
    t_un = time_fn(unfused, data, q, w)
    t_f = time_fn(fused, data, q, w)
    return [
        row(
            "kernel_wl1_scan_topk",
            t_f,
            f"n={n},b={b},d={d},k={k};unfused_us={t_un:.1f};"
            f"speedup={t_un / t_f:.2f}x",
        )
    ]


def run():
    key = jax.random.PRNGKey(0)
    n, d, H, M = 4096, 64, 256, 32
    levels = jax.random.randint(key, (n, d), 0, M + 1)
    folded = jax.random.normal(jax.random.fold_in(key, 1), (H, d, M + 1))
    weights = jax.random.normal(jax.random.fold_in(key, 2), (n, d))

    proj = jax.jit(lambda l, f: ops.alsh_project(l, f))
    proj_w = jax.jit(lambda l, f, w: ops.alsh_project(l, f, w))
    out = [
        row("kernel_alsh_project_data", time_fn(proj, levels, folded),
            f"n={n},d={d},H={H},M={M}"),
        row("kernel_alsh_project_query", time_fn(proj_w, levels, folded, weights),
            "weighted"),
    ]

    nd, b, dd = 65536, 64, 128
    data = jax.random.normal(jax.random.fold_in(key, 3), (nd, dd))
    q = jax.random.normal(jax.random.fold_in(key, 4), (b, dd))
    w = jax.random.normal(jax.random.fold_in(key, 5), (b, dd))
    scan = jax.jit(ops.wl1_scan)
    out.append(row("kernel_wl1_scan", time_fn(scan, data, q, w),
                   f"n={nd},b={b},d={dd} ({nd*b*dd*3/1e9:.1f} GOP)"))

    pts = jax.random.normal(jax.random.fold_in(key, 6), (b, 512, dd))
    rer = jax.jit(ops.wl1_rerank)
    out.append(row("kernel_wl1_rerank", time_fn(rer, pts, q, w), f"b={b},C=512,d={dd}"))

    out.extend(_scan_topk_rows(jax.random.fold_in(key, 7)))
    out.extend(_fused_tail_rows(jax.random.fold_in(key, 8)))
    out.extend(_segmented_tail_rows(jax.random.fold_in(key, 9)))
    return out
