"""Roofline analysis from the compiled dry-run artifacts (deliverable g).

For each (arch × shape) cell on the single-pod 16x16 mesh:

  compute term    = analytic executed FLOPs per device / peak FLOPs    [s]
  memory term     = analytic HBM bytes per device / HBM bw            [s]
  collective term = measured per-device link traffic / ICI link bw    [s]

Compute/memory are ANALYTIC (benchmarks/analytic.py) because XLA's
cost_analysis() counts lax.scan bodies once, not × trip count (verified in
tests/test_dryrun_parse.py) — its raw numbers are kept in the JSON artifacts
as reference. The collective term is MEASURED from the compiled HLO with the
loop-aware parser in launch/dryrun.py; we conservatively charge a single ICI
link (~50 GB/s).

MODEL_FLOPS (per device) = 6·N_active·D_tokens / chips (train) or 2·N_active·D
(prefill/decode), N_active at top-1 routed share. useful_ratio =
MODEL_FLOPS/executed_FLOPs exposes remat/attention-rectangle/capacity waste
(remat alone ⇒ 0.75 for train). roofline_fraction = MODEL_FLOPS-time /
dominant-term-time: the score we hillclimb in §Perf.

Hardware: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def analyze(results_dir: str = "results/dryrun", mesh: str = "pod1",
            optimized: bool = False):
    import dataclasses

    from benchmarks.analytic import cell_model
    from repro.configs import SHAPES, get_bundle
    from repro.launch.dryrun import optimized_overrides

    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, f"*__{mesh}.json"))):
        rec = json.load(open(path))
        if rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "status": "skipped", "reason": rec["reason"]})
            continue
        if rec.get("status") != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "status": rec.get("status", "?"),
                         "reason": rec.get("error", "")[:200]})
            continue
        arch = rec["arch"]
        bundle = get_bundle(arch)
        mcfg = bundle.model
        if optimized:
            over = optimized_overrides(arch, SHAPES[rec["shape"]].kind)
            if over:
                mcfg = dataclasses.replace(mcfg, **over)
        model = cell_model(mcfg, bundle.train, SHAPES[rec["shape"]],
                           rec["n_devices"])

        t_comp = model["flops_dev"] / PEAK_FLOPS
        t_mem = model["bytes_dev"] / HBM_BW
        t_coll = rec["collectives"]["total_bytes"] / ICI_BW
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dom = max(terms, key=terms.get)
        mflops = model["model_flops_dev"]
        bound = max(terms.values())
        rows.append({
            "arch": arch, "shape": rec["shape"], "status": "ok",
            "kind": rec["kind"],
            "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
            "dominant": dom,
            "model_flops_per_dev": mflops,
            "executed_flops_per_dev": model["flops_dev"],
            "hlo_flops_raw": rec["flops"],
            "useful_ratio": mflops / model["flops_dev"],
            "roofline_fraction": (mflops / PEAK_FLOPS) / bound if bound else 0.0,
            "temp_gib": rec["temp_size_in_bytes"] / 2**30,
            "args_gib": rec["argument_size_in_bytes"] / 2**30,
            "total_params": model["total_params"],
            "active_params": model["active_params"],
            "collective_counts": rec["collectives"]["counts"],
            "collective_bytes": rec["collectives"]["per_type_bytes"],
        })
    return rows


def to_markdown(rows) -> str:
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | dominant | "
        "useful FLOP ratio | roofline frac | temp GiB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("status") != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | {r['status']}: "
                f"{r.get('reason','')[:60]} | — | — | — |"
            )
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | **{r['dominant']}** | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} | "
            f"{r['temp_gib']:.1f} |"
        )
    return "\n".join(lines)


def run():
    """Benchmark-harness entry: summary rows per cell."""
    rows = analyze()
    out = []
    for r in rows:
        if r.get("status") != "ok":
            continue
        out.append((
            f"roofline_{r['arch']}_{r['shape']}", 0.0,
            f"dom={r['dominant']},frac={r['roofline_fraction']:.2f},"
            f"useful={r['useful_ratio']:.2f}",
        ))
    return out


if __name__ == "__main__":
    rows = analyze()
    print(to_markdown(rows))
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.json", "w") as f:
        json.dump(rows, f, indent=2, default=str)
