"""Quantized table tier benchmarks: memory ratio, latency, recall delta per
codec and screening factor (benchmarks/run.py snapshots the rows into
BENCH_quant.json).

What the numbers validate:

  * the int8 table is ≥3x smaller than f32 (bf16 exactly 2x) — the tier's
    reason to exist; candidate generation hashes RAW rows before encoding,
    so compression costs recall ONLY through rerank precision;
  * recall delta vs the f32 build stays within a point at the calibrated
    screening factors (α ∈ {0, 2, 4}) — the proxy screen keeps k·α
    survivors for the exact decoded rerank, so the final ranking is f32
    arithmetic over quantized rows either way;
  * screened query latency vs the unscreened quantized query and vs the
    f32 baseline — the screen reads 1–2 bytes/value instead of 4, then
    reranks a fraction of the candidate set.

Sizes default small enough for the CI smoke (``--only quant``); the memory
ratios and recall deltas, not the absolute times, are the regression signal.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.api import BoundedSpace, Index, IndexConfig, QuerySpec
from repro.distance import recall_at_k

N = int(os.environ.get("QUANT_BENCH_N", 20_000))
D = 16
M = 32
B = 64
K_NN = 10
ALPHAS = (2.0, 4.0)


def _cfg(storage: str) -> IndexConfig:
    return IndexConfig(
        d=D, M=M, K=10, L=32, family="theta", max_candidates=256,
        space=BoundedSpace(0.0, 1.0, float(M)), storage=storage,
    )


def run():
    key = jax.random.PRNGKey(0)
    data = jax.random.uniform(jax.random.fold_in(key, 1), (N, D))
    q = jax.random.uniform(jax.random.fold_in(key, 2), (B, D))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 3), (B, D))) + 0.2

    bkey = jax.random.fold_in(key, 4)
    rows = []

    f32_ix = Index.build(bkey, data, _cfg("f32"))
    oracle = f32_ix.query(q, w, QuerySpec(k=K_NN, mode="exact"))
    spec = QuerySpec(k=K_NN)
    base_us = time_fn(lambda: f32_ix.query(q, w, spec)) / B
    base_res = f32_ix.query(q, w, spec)
    base_rec = recall_at_k(base_res.ids, oracle.ids, K_NN)
    rows.append(row("quant/f32/query", base_us,
                    f"recall@{K_NN}={base_rec:.3f} "
                    f"table_mb={f32_ix.table_bytes / 2**20:.2f}"))

    for storage in ("bf16", "int8"):
        ix = Index.build(bkey, data, _cfg(storage))
        ratio = f32_ix.table_bytes / ix.table_bytes
        res = ix.query(q, w, spec)
        us = time_fn(lambda ix=ix: ix.query(q, w, spec)) / B
        rec = recall_at_k(res.ids, oracle.ids, K_NN)
        rows.append(row(
            f"quant/{storage}/query", us,
            f"recall@{K_NN}={rec:.3f} delta={rec - base_rec:+.3f} "
            f"mem_ratio={ratio:.2f}x"))
        for alpha in ALPHAS:
            sspec = QuerySpec(k=K_NN, screen_alpha=alpha)
            sres = ix.query(q, w, sspec)
            sus = time_fn(lambda ix=ix, sspec=sspec: ix.query(q, w, sspec)) / B
            srec = recall_at_k(sres.ids, oracle.ids, K_NN)
            rep = ix.explain(q[:8], w[:8], sspec)
            import numpy as np
            rows.append(row(
                f"quant/{storage}/screen_a{alpha:g}", sus,
                f"recall@{K_NN}={srec:.3f} delta={srec - base_rec:+.3f} "
                f"reranked~{float(np.mean(rep.rows_reranked)):.0f}/"
                f"{float(np.mean(rep.rows_screened)):.0f}"))
    return rows
