"""End-to-end ALSH index behaviour: recall, guarantee, sublinearity signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BoundedSpace, IndexConfig, build_index, query_index, plan_index
from repro.distance import brute_force_nn, wl1_distance


def _dataset(key, n, d):
    return jax.random.uniform(key, (n, d))


def test_recall_at_10_theta(rng):
    """With a generous (K, L) budget, theta-ALSH recall@10 over positive weights is high."""
    n, d, M = 4000, 16, 16
    space = BoundedSpace(0.0, 1.0, float(M))
    data = _dataset(jax.random.fold_in(rng, 0), n, d)
    cfg = IndexConfig(
        d=d, M=M, K=10, L=32, family="theta", max_candidates=128, space=space
    )
    idx = build_index(jax.random.fold_in(rng, 1), data, cfg)
    b = 16
    q = jax.random.uniform(jax.random.fold_in(rng, 2), (b, d))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(rng, 3), (b, d))) + 0.2
    res = query_index(idx, q, w, cfg, k=10)
    _, bf_ids = brute_force_nn(data, q, w, k=10)
    recall = np.mean(
        [len(set(np.asarray(res.ids[i])) & set(np.asarray(bf_ids[i]))) / 10 for i in range(b)]
    )
    assert recall >= 0.5, f"theta recall@10 = {recall}"
    # the whole point: examined candidates << n
    assert float(jnp.mean(res.n_candidates)) < 0.6 * n


def test_r1_r2_nns_guarantee_l2(rng):
    """Definition 3 behaviour for (d_w^l1, l2)-ALSH: a planted R1-near neighbour
    is recovered with candidate fraction ≈ 0 (the sublinear regime).

    NOTE the l2 variant's contrast is compressed by the residual transformed
    distance M·Σ(1-w_i)² at r=0, so it shines when weights are near 1 and the
    neighbour is genuinely near — exactly the (R1, R2)-NNS promise, not
    arbitrary recall@k. (theta variant covers the broad-recall case above.)
    """
    n, d, M = 4000, 16, 16
    space = BoundedSpace(0.0, 1.0, float(M))
    data = _dataset(jax.random.fold_in(rng, 0), n, d)
    b = 32
    base_ids = jnp.arange(b) * 17
    q = jnp.clip(
        data[base_ids] + 0.003 * jax.random.normal(jax.random.fold_in(rng, 2), (b, d)), 0, 1
    )
    w = 1.0 + 0.02 * jax.random.normal(jax.random.fold_in(rng, 3), (b, d))
    cfg = IndexConfig(
        d=d, M=M, K=8, L=16, family="l2", W=8.0, max_candidates=128, space=space
    )
    idx = build_index(jax.random.fold_in(rng, 1), data, cfg)
    res = query_index(idx, q, w, cfg, k=1)
    hit = np.mean(np.asarray(res.ids[:, 0]) == np.asarray(base_ids))
    assert hit >= 0.85, f"planted-NN hit rate = {hit}"
    assert float(jnp.mean(res.n_candidates)) < 0.05 * n


def test_returned_distances_are_exact(rng):
    """Whatever ids come back, their reported distances are exact d_w^l1."""
    n, d, M = 500, 8, 8
    space = BoundedSpace(0.0, 1.0, float(M))
    data = _dataset(jax.random.fold_in(rng, 10), n, d)
    cfg = IndexConfig(d=d, M=M, K=6, L=8, max_candidates=64, space=space)
    idx = build_index(jax.random.fold_in(rng, 11), data, cfg)
    q = jax.random.uniform(jax.random.fold_in(rng, 12), (4, d))
    w = jax.random.normal(jax.random.fold_in(rng, 13), (4, d))
    res = query_index(idx, q, w, cfg, k=3)
    for i in range(4):
        for j in range(3):
            pid = int(res.ids[i, j])
            if pid < 0:
                continue
            want = float(wl1_distance(data[pid], q[i], w[i]))
            np.testing.assert_allclose(float(res.dists[i, j]), want, rtol=1e-4, atol=1e-4)


def test_self_query_finds_self(rng):
    """A query equal to a data point with positive weights must find it (dist 0)."""
    n, d, M = 1000, 12, 16
    space = BoundedSpace(0.0, 1.0, float(M))
    data = _dataset(jax.random.fold_in(rng, 20), n, d)
    cfg = IndexConfig(d=d, M=M, K=8, L=16, max_candidates=64, space=space)
    idx = build_index(jax.random.fold_in(rng, 21), data, cfg)
    q = data[:8]
    w = jnp.ones((8, d))
    res = query_index(idx, q, w, cfg, k=1)
    # identical point ⇒ identical lattice point ⇒ identical data-hash in every
    # table when w > 0 keeps signs (theta family, w=1 ⇒ f == g exactly)
    assert np.all(np.asarray(res.dists[:, 0]) <= 1e-5)


def test_candidates_scale_sublinearly(rng):
    """n_candidates grows visibly slower than n (the sublinearity signal)."""
    d, M = 12, 16
    space = BoundedSpace(0.0, 1.0, float(M))
    cfg = IndexConfig(d=d, M=M, K=12, L=16, max_candidates=128, space=space)
    fracs = []
    for i, n in enumerate((1000, 8000)):
        data = _dataset(jax.random.fold_in(rng, 30 + i), n, d)
        idx = build_index(jax.random.fold_in(rng, 40 + i), data, cfg)
        q = jax.random.uniform(jax.random.fold_in(rng, 50 + i), (8, d))
        w = jnp.abs(jax.random.normal(jax.random.fold_in(rng, 60 + i), (8, d))) + 0.2
        res = query_index(idx, q, w, cfg, k=1)
        fracs.append(float(jnp.mean(res.n_candidates)) / n)
    assert fracs[1] < fracs[0], f"candidate fraction should shrink with n: {fracs}"


def test_negative_weights_supported(rng):
    """Each w_i may be negative (paper abstract): pipeline runs and matches oracle."""
    n, d, M = 800, 10, 8
    space = BoundedSpace(0.0, 1.0, float(M))
    data = _dataset(jax.random.fold_in(rng, 70), n, d)
    cfg = IndexConfig(d=d, M=M, K=6, L=24, max_candidates=128, space=space)
    idx = build_index(jax.random.fold_in(rng, 71), data, cfg)
    q = jax.random.uniform(jax.random.fold_in(rng, 72), (4, d))
    w = jax.random.normal(jax.random.fold_in(rng, 73), (4, d))  # mixed signs
    res = query_index(idx, q, w, cfg, k=5)
    assert res.ids.shape == (4, 5)
    finite = np.isfinite(np.asarray(res.dists))
    assert finite.any()
