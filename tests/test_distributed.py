"""Multi-device CPU tests (8 fake host devices via subprocess — the main
pytest process must keep seeing 1 device).

Covers: shard_map distributed ALSH query + hierarchical top-k merge matching
the global brute force, and train-step sharding on a real (2,2,2) mesh.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


def test_sharded_alsh_matches_global_bruteforce():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import IndexConfig, BoundedSpace
        from repro.core.distributed import sharded_query
        from repro.distance import brute_force_nn

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        n, d, M, k = 4096, 12, 16, 10
        key = jax.random.PRNGKey(0)
        data = jax.random.uniform(key, (n, d))
        cfg = IndexConfig(d=d, M=M, K=10, L=24, family="theta",
                          max_candidates=128, space=BoundedSpace(0., 1., float(M)))
        q = jax.random.uniform(jax.random.fold_in(key, 1), (8, d))
        w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (8, d))) + 0.2
        res = sharded_query(jax.random.fold_in(key, 3), data, q, w, cfg, mesh, k=k)
        bf_d, bf_i = brute_force_nn(data, q, w, k=k)
        recall = np.mean([len(set(np.asarray(res.ids[i])) & set(np.asarray(bf_i[i]))) / k
                          for i in range(8)])
        # distances of returned ids must be exact
        for i in range(8):
            for j in range(k):
                pid = int(res.ids[i, j])
                if pid < 0: continue
                want = float(jnp.sum(w[i] * jnp.abs(data[pid] - q[i])))
                got = float(res.dists[i, j])
                assert abs(got - want) < 1e-3, (got, want)
        print("RECALL", recall)
        assert recall >= 0.5, recall

        # hierarchical merge == flat merge (same answer, fewer cross-pod bytes)
        res_flat = sharded_query(jax.random.fold_in(key, 3), data, q, w, cfg, mesh,
                                 k=k, merge_hierarchical=False)
        np.testing.assert_allclose(np.sort(np.asarray(res.dists), -1),
                                   np.sort(np.asarray(res_flat.dists), -1), atol=1e-4)
        print("OK")
    """)
    assert "OK" in out


def test_facade_shard_prebuilt_matches_oneshot():
    """Index.shard builds shard-local indexes ONCE; its query() must be
    bit-identical to the one-shot sharded_query path (same key/cfg) and its
    exact mode must reproduce the global brute force."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.api import Index, IndexConfig, QuerySpec, BoundedSpace
        from repro.core.distributed import sharded_query
        from repro.distance import brute_force_nn
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        n, d, M, k = 2048, 12, 16, 5
        key = jax.random.PRNGKey(0)
        data = jax.random.uniform(key, (n, d))
        cfg = IndexConfig(d=d, M=M, K=10, L=16, family="theta",
                          max_candidates=128, space=BoundedSpace(0., 1., float(M)))
        q = jax.random.uniform(jax.random.fold_in(key, 1), (8, d))
        w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (8, d))) + 0.2
        bkey = jax.random.fold_in(key, 3)

        sharded = Index.build(bkey, data, cfg).shard(mesh)
        res = sharded.query(q, w, QuerySpec(k=k))

        ds = jax.device_put(data, NamedSharding(mesh, P(tuple(mesh.axis_names), None)))
        ref = sharded_query(bkey, ds, q, w, cfg, mesh, k=k)
        np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))
        np.testing.assert_array_equal(np.asarray(res.dists), np.asarray(ref.dists))

        rex = sharded.query(q, w, QuerySpec(k=k, mode="exact"))
        bf_d, _ = brute_force_nn(data, q, w, k=k)
        np.testing.assert_allclose(np.asarray(rex.dists), np.asarray(bf_d), atol=1e-4)
        print("OK")
    """)
    assert "OK" in out


def test_mutable_lifecycle_save_load_shard_parity():
    """The full lifecycle × persistence × distribution matrix: a mutated
    index (non-empty delta + tombstones) round-trips through save/load,
    re-shards from the persisted build_key (which must reproduce the DELTA
    hashes too), serves bit-identical queries sharded vs single-host with
    the same global ids, and keeps serving inserts/deletes sharded."""
    out = _run("""
        import tempfile
        import jax, jax.numpy as jnp, numpy as np
        from repro.api import Index, IndexConfig, QuerySpec, UpdateSpec, BoundedSpace

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        n, d, k = 512, 8, 7
        key = jax.random.PRNGKey(0)
        data = jax.random.uniform(jax.random.fold_in(key, 0), (n, d))
        extra = jax.random.uniform(jax.random.fold_in(key, 1), (37, d))
        q = jax.random.uniform(jax.random.fold_in(key, 2), (5, d))
        w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 3), (5, d))) + 0.2
        cfg = IndexConfig(d=d, M=8, K=6, L=10, family="theta",
                          max_candidates=n + 64, space=BoundedSpace(0., 1., 8.))

        local = Index.build(jax.random.fold_in(key, 9), data, cfg,
                            update=UpdateSpec(delta_capacity=64))
        local, ids = local.insert(extra)
        local = local.delete(jnp.asarray([3, 77, int(ids[4])], jnp.int32))

        with tempfile.TemporaryDirectory() as td:
            local.save(td)
            restored = Index.load(td)
        sharded = restored.shard(mesh)  # replays the delta through the
                                        # re-derived tables (same build_key)
        r_l = local.query(q, w, QuerySpec(k=k))
        r_s = sharded.query(q, w, QuerySpec(k=k))
        np.testing.assert_array_equal(np.asarray(r_l.ids), np.asarray(r_s.ids))
        np.testing.assert_array_equal(np.asarray(r_l.dists), np.asarray(r_s.dists))
        np.testing.assert_array_equal(np.asarray(r_l.n_candidates),
                                      np.asarray(r_s.n_candidates))

        # lifecycle continues sharded, in lockstep with single-host
        local2, ids_l = local.insert(extra[:11])
        sharded2, ids_s = sharded.insert(extra[:11])
        np.testing.assert_array_equal(np.asarray(ids_l), np.asarray(ids_s))
        dels = jnp.asarray([int(ids_l[0]), 42], jnp.int32)
        local2, sharded2 = local2.delete(dels), sharded2.delete(dels)
        for mode in ("probe", "multiprobe", "exact"):
            a = local2.query(q, w, QuerySpec(k=k, mode=mode))
            b = sharded2.query(q, w, QuerySpec(k=k, mode=mode))
            np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
            np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))
        assert not np.isin(np.asarray(dels), np.asarray(b.ids)).any()

        # sharded compact == single-host compact, bit for bit
        ca, cb = local2.compact(), sharded2.compact()
        for la, lb in zip(jax.tree_util.tree_leaves(ca.state),
                          jax.tree_util.tree_leaves(cb.state)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        print("OK")
    """)
    assert "OK" in out


def test_train_step_on_small_production_mesh():
    out = _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_bundle, reduced_model
        from repro.launch import specs
        from repro.models.sharding import use_mesh, sanitize_spec_tree
        from repro.runtime.train_step import (init_train_state, make_train_step,
                                              train_state_specs, batch_pytree_specs)

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        bundle = get_bundle("qwen3-8b")
        mcfg = dataclasses.replace(reduced_model(bundle.model), n_units=2, n_layers=2,
                                   n_heads=4, n_kv_heads=2, d_model=64)
        tcfg = bundle.train
        with use_mesh(mesh):
            state = init_train_state(jax.random.PRNGKey(0), mcfg, tcfg)
            batch = specs.train_batch(mcfg, 8, 32, concrete=True)
            sspec = sanitize_spec_tree(train_state_specs(mcfg, tcfg), state, mesh)
            bspec = sanitize_spec_tree(batch_pytree_specs(batch), batch, mesh)
            to_sh = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                           is_leaf=lambda s: isinstance(s, P))
            state = jax.device_put(state, to_sh(sspec))
            batch = jax.device_put(batch, to_sh(bspec))
            step = jax.jit(make_train_step(mcfg, tcfg),
                           in_shardings=(to_sh(sspec), to_sh(bspec)),
                           out_shardings=(to_sh(sspec), None))
            new_state, metrics = step(state, batch)
            loss1 = float(metrics["loss"])
            assert np.isfinite(loss1)

            # distributed result == single-device result
        state1 = init_train_state(jax.random.PRNGKey(0), mcfg, tcfg)
        batch1 = specs.train_batch(mcfg, 8, 32, concrete=True)
        step1 = jax.jit(make_train_step(mcfg, tcfg))
        _, metrics1 = step1(state1, batch1)
        loss_single = float(metrics1["loss"])
        print("LOSSES", loss1, loss_single)
        assert abs(loss1 - loss_single) < 5e-3, (loss1, loss_single)
        print("OK")
    """)
    assert "OK" in out


def test_decode_step_on_small_mesh():
    out = _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import models
        from repro.configs import get_bundle, reduced_model
        from repro.models.sharding import use_mesh, sanitize_spec_tree
        from repro.runtime.serve_step import make_decode_step

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        mcfg = reduced_model(get_bundle("gemma3-1b").model)
        with use_mesh(mesh):
            params = models.init_params(jax.random.PRNGKey(0), mcfg)
            caches = models.init_caches(8, 64, mcfg)
            pspec = sanitize_spec_tree(models.param_specs(mcfg), params, mesh)
            cspec = sanitize_spec_tree(models.cache_specs(mcfg), caches, mesh)
            to_sh = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                           is_leaf=lambda s: isinstance(s, P))
            params_d = jax.device_put(params, to_sh(pspec))
            caches_d = jax.device_put(caches, to_sh(cspec))
            batch = {"token": jnp.zeros((8,), jnp.int32),
                     "pos": jnp.zeros((8,), jnp.int32)}
            step = jax.jit(make_decode_step(mcfg),
                           in_shardings=(to_sh(pspec), None, to_sh(cspec)),
                           out_shardings=(None, None, to_sh(cspec)))
            logits, tok, new_caches = step(params_d, batch, caches_d)
            assert np.all(np.isfinite(np.asarray(logits)))

        # matches single-device decode
        step1 = jax.jit(make_decode_step(mcfg))
        logits1, _, _ = step1(params, batch, caches)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(logits1),
                                   rtol=2e-3, atol=2e-3)
        print("OK")
    """)
    assert "OK" in out


def test_moe_ep_shardmap_matches_gspmd():
    out = _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_bundle, reduced_model
        from repro.models import moe
        from repro.models.sharding import use_mesh, set_policy

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        bundle = get_bundle("llama4-scout-17b-16e")
        mcfg = reduced_model(bundle.model)  # 4 experts, capacity >= T
        key = jax.random.PRNGKey(0)
        params = moe.init_moe(key, mcfg, mcfg.moe, jnp.float32)
        x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, mcfg.d_model))

        ref = moe.moe_ffn_gspmd(params, x, mcfg, mcfg.moe)  # no mesh: plain
        with use_mesh(mesh):
            mcfg_ep = dataclasses.replace(mcfg, moe_impl="ep_shardmap")
            got = moe.moe_ffn_ep_shardmap(params, x, mcfg_ep, mcfg.moe)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

        # grads flow through the shard_map path
        with use_mesh(mesh):
            g = jax.grad(lambda p: jnp.sum(
                moe.moe_ffn_ep_shardmap(p, x, mcfg_ep, mcfg.moe) ** 2))(params)
        for leaf in jax.tree.leaves(g):
            assert np.all(np.isfinite(np.asarray(leaf)))
        print("OK")
    """)
    assert "OK" in out


def test_moe_a2a_shardmap_matches_gspmd():
    out = _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_bundle, reduced_model
        from repro.models import moe
        from repro.models.sharding import use_mesh, set_policy

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        bundle = get_bundle("llama4-scout-17b-16e")
        mcfg = reduced_model(bundle.model)  # 4 experts, capacity >= T (no drops)
        key = jax.random.PRNGKey(0)
        params = moe.init_moe(key, mcfg, mcfg.moe, jnp.float32)
        x = jax.random.normal(jax.random.fold_in(key, 1), (8, 16, mcfg.d_model))

        ref = moe.moe_ffn_gspmd(params, x, mcfg, mcfg.moe)
        mcfg_a2a = dataclasses.replace(mcfg, moe_impl="a2a_shardmap",
                                       dp_over_model=True)
        try:
            set_policy(dp_over_model=True)
            with use_mesh(mesh):
                got = moe.moe_ffn_a2a_shardmap(params, x, mcfg_a2a, mcfg.moe)
                g = jax.grad(lambda p: jnp.sum(
                    moe.moe_ffn_a2a_shardmap(p, x, mcfg_a2a, mcfg.moe) ** 2))(params)
        finally:
            set_policy()
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
        for leaf in jax.tree.leaves(g):
            assert np.all(np.isfinite(np.asarray(leaf)))
        print("OK")
    """)
    assert "OK" in out
