"""Mutable index lifecycle: segmented insert / delete / compact.

The load-bearing invariant (and the reason the delta segment hashes with
the persisted build tables): after ANY interleaving of insert/delete, query
results are bit-identical to a fresh ``Index.build`` (same build_key) over
the surviving rows once ids are mapped through ``live_ids()``; deleted ids
never appear; ``compact()`` preserves all of it while emptying the delta.

Bit-parity needs candidate windows that never truncate (``max_candidates``
>= total rows here): under truncation the mutated and fresh indexes keep
different — equally valid — C-subsets of an oversized bucket.
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    BoundedSpace,
    Index,
    IndexConfig,
    QuerySpec,
    UpdateSpec,
)

N = 400
D = 8
CAP = 64


def _cfg(family="theta", **kw):
    kw.setdefault("max_candidates", N + CAP)  # no window truncation (parity)
    kw.setdefault("space", BoundedSpace(0.0, 1.0, 8.0))
    kw.setdefault("W", 8.0)
    return IndexConfig(d=D, M=8, K=6, L=10, family=family, **kw)


def _problem(rng, salt=0, m=37, b=5):
    data = jax.random.uniform(jax.random.fold_in(rng, salt), (N, D))
    extra = jax.random.uniform(jax.random.fold_in(rng, salt + 1), (m, D))
    q = jax.random.uniform(jax.random.fold_in(rng, salt + 2), (b, D))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(rng, salt + 3), (b, D))) + 0.2
    return data, extra, q, w


def _mutable(rng, data, family="theta", cap=CAP, salt=9):
    return Index.build(
        jax.random.fold_in(rng, salt),
        data,
        _cfg(family=family),
        update=UpdateSpec(delta_capacity=cap),
    )


def _assert_parity(index, all_rows, q, w, spec, bkey, cfg):
    """Mutated-index query == fresh build over survivors (ids mapped)."""
    live = index.live_ids()
    fresh = Index.build(bkey, jnp.asarray(all_rows)[live], cfg)
    got = index.query(q, w, spec)
    want = fresh.query(q, w, spec)
    mapped = np.where(np.asarray(want.ids) >= 0, live[np.asarray(want.ids)], -1)
    np.testing.assert_array_equal(np.asarray(got.ids), mapped)
    np.testing.assert_array_equal(np.asarray(got.dists), np.asarray(want.dists))
    np.testing.assert_array_equal(
        np.asarray(got.n_candidates), np.asarray(want.n_candidates)
    )
    return fresh


# ---------------------------------------------------------------------------
# insert
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["theta", "l2"])
def test_insert_assigns_stable_ids_and_queries_find_rows(rng, family):
    data, extra, q, w = _problem(rng)
    index = _mutable(rng, data, family=family)
    index, ids = index.insert(extra)
    np.testing.assert_array_equal(np.asarray(ids), N + np.arange(extra.shape[0]))
    assert index.delta_fill == extra.shape[0]
    # an inserted row queried exactly comes back as its own nearest neighbour
    res = index.query(extra[:4], jnp.ones((4, D)), QuerySpec(k=1))
    np.testing.assert_array_equal(np.asarray(res.ids[:, 0]), np.asarray(ids[:4]))
    np.testing.assert_allclose(np.asarray(res.dists[:, 0]), 0.0, atol=1e-6)


def test_insert_overflow_returns_minus_one(rng):
    data, extra, _, _ = _problem(rng, m=CAP + 10)
    index = _mutable(rng, data)
    index, ids = index.insert(extra)
    ids = np.asarray(ids)
    np.testing.assert_array_equal(ids[:CAP], N + np.arange(CAP))
    np.testing.assert_array_equal(ids[CAP:], -1)
    assert index.delta_fill == CAP


def test_immutable_index_rejects_mutation(rng):
    data, extra, _, _ = _problem(rng)
    index = Index.build(jax.random.fold_in(rng, 9), data, _cfg())
    for op, call in [
        ("insert", lambda: index.insert(extra)),
        ("delete", lambda: index.delete(jnp.asarray([0]))),
        ("compact", lambda: index.compact()),
    ]:
        with pytest.raises(ValueError, match="delta_capacity"):
            call()


# ---------------------------------------------------------------------------
# the parity invariant: mutated == fresh build over survivors
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["theta", "l2"])
@pytest.mark.parametrize("mode", ["probe", "multiprobe", "exact"])
def test_interleaved_lifecycle_parity(rng, family, mode):
    if family == "l2" and mode == "multiprobe":
        pytest.skip("l2 family does not support multiprobe")
    data, extra, q, w = _problem(rng)
    bkey = jax.random.fold_in(rng, 9)
    index = _mutable(rng, data, family=family)
    # interleave: insert half, delete some of both segments, insert the rest
    index, ids1 = index.insert(extra[:20])
    index = index.delete(jnp.asarray([0, 5, int(ids1[3])], jnp.int32))
    index, ids2 = index.insert(extra[20:])
    index = index.delete(jnp.asarray([17, int(ids2[2])], jnp.int32))

    spec = QuerySpec(k=7, mode=mode)
    all_rows = jnp.concatenate([data, extra])
    fresh = _assert_parity(index, all_rows, q, w, spec, bkey, _cfg(family=family))

    # deleted ids never appear
    res = index.query(q, w, spec)
    dead = {0, 5, 17, int(ids1[3]), int(ids2[2])}
    assert not dead & set(np.asarray(res.ids).ravel().tolist())

    # compact() preserves the invariant while emptying the delta — and its
    # state is bit-identical to the fresh build (same key, same sort)
    compacted = index.compact()
    assert compacted.delta_fill == 0
    for a, b in zip(
        jax.tree_util.tree_leaves(compacted.state),
        jax.tree_util.tree_leaves(fresh.state),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    got = compacted.query(q, w, spec)
    want = fresh.query(q, w, spec)
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(want.ids))
    np.testing.assert_array_equal(np.asarray(got.dists), np.asarray(want.dists))


def test_delete_of_unassigned_id_is_ignored(rng):
    """Deleting an id no insert has handed out must be a no-op — NOT a
    pre-tombstone on the slot a future insert will occupy."""
    data, extra, _, _ = _problem(rng)
    index = _mutable(rng, data)
    index = index.delete(jnp.asarray([N + 3, N + CAP + 5, -7], jnp.int32))
    assert index.n_live == N
    index, ids = index.insert(extra[:5])
    res = index.query(extra[:5], jnp.ones((5, D)), QuerySpec(k=1))
    np.testing.assert_array_equal(np.asarray(res.ids[:, 0]), np.asarray(ids))


def test_delete_then_reinsert_distinct_ids(rng):
    """Deleting delta rows does not free their slots (append-only): new
    inserts get fresh ids and the tombstoned rows stay gone."""
    data, extra, q, _ = _problem(rng)
    index = _mutable(rng, data)
    index, ids1 = index.insert(extra[:10])
    index = index.delete(ids1)
    index, ids2 = index.insert(extra[10:20])
    assert int(ids2[0]) == N + 10  # slots not reused
    res = index.query(extra[:10], jnp.ones((10, D)), QuerySpec(k=1))
    assert not set(np.asarray(ids1).tolist()) & set(np.asarray(res.ids).ravel().tolist())


# ---------------------------------------------------------------------------
# jit stability: one compiled program across the index's life
# ---------------------------------------------------------------------------


def test_lifecycle_ops_jit_without_retrace(rng):
    data, extra, q, w = _problem(rng)
    index = _mutable(rng, data)
    jq = jax.jit(lambda ix, q, w: ix.query(q, w, QuerySpec(k=5)))
    jins = jax.jit(lambda ix, rows: ix.insert(rows))
    jdel = jax.jit(lambda ix, ids: ix.delete(ids))
    for i in range(4):
        index, _ = jins(index, extra[i * 8 : (i + 1) * 8])
        index = jdel(index, jnp.asarray([i * 3], jnp.int32))
        jq(index, q, w)
    from repro.analysis import cache_size

    assert cache_size(jq) == 1
    assert cache_size(jins) == 1
    assert cache_size(jdel) == 1


def test_index_with_delta_crosses_jit_boundary(rng):
    data, extra, q, w = _problem(rng)
    index, _ = _mutable(rng, data).insert(extra)
    leaves, treedef = jax.tree_util.tree_flatten(index)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.config == index.config and rebuilt.update == index.update
    want = index.query(q, w, QuerySpec(k=3)).dists
    got = jax.jit(lambda ix: ix.query(q, w, QuerySpec(k=3)).dists)(index)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# lifecycle × persistence
# ---------------------------------------------------------------------------


def test_build_insert_save_load_query_parity(rng, tmp_path):
    data, extra, q, w = _problem(rng)
    index = _mutable(rng, data)
    index, ids = index.insert(extra)
    index = index.delete(jnp.asarray([1, int(ids[4])], jnp.int32))
    want = index.query(q, w, QuerySpec(k=7))

    index.save(tmp_path)  # pathlib.Path accepted
    back = Index.load(tmp_path)
    assert back.update == index.update
    assert back.delta_fill == index.delta_fill
    got = back.query(q, w, QuerySpec(k=7))
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(want.ids))
    np.testing.assert_array_equal(np.asarray(got.dists), np.asarray(want.dists))

    # the lifecycle RESUMES: next insert continues the id sequence
    back, ids2 = back.insert(extra[:3])
    np.testing.assert_array_equal(
        np.asarray(ids2), N + extra.shape[0] + np.arange(3)
    )


def test_manifest_records_segments_and_guards_fill(rng, tmp_path):
    data, extra, _, _ = _problem(rng)
    index, _ = _mutable(rng, data).insert(extra)
    index.save(tmp_path)
    meta = json.loads((tmp_path / "index.json").read_text())
    seg = {s["kind"]: s for s in meta["segments"]}
    assert seg["main"]["rows"] == N
    assert seg["delta"]["capacity"] == CAP
    assert seg["delta"]["fill"] == extra.shape[0]
    # a torn overwrite that changes the fill level must be rejected
    meta["segments"] = [
        s if s["kind"] != "delta" else {**s, "fill": 0} for s in meta["segments"]
    ]
    (tmp_path / "index.json").write_text(json.dumps(meta))
    with pytest.raises(ValueError, match="manifest disagrees"):
        Index.load(tmp_path)


def test_immutable_roundtrip_stays_immutable(rng, tmp_path):
    data, _, q, w = _problem(rng)
    index = Index.build(jax.random.fold_in(rng, 9), data, _cfg())
    index.save(str(tmp_path))
    back = Index.load(str(tmp_path))
    assert not back.mutable
    with pytest.raises(ValueError, match="delta_capacity"):
        back.insert(data[:2])


# ---------------------------------------------------------------------------
# query argument validation (satellite: actionable errors, not trace noise)
# ---------------------------------------------------------------------------


def test_query_validates_trailing_dims_and_batch(rng):
    data, _, q, w = _problem(rng)
    index = Index.build(jax.random.fold_in(rng, 9), data, _cfg())
    with pytest.raises(ValueError, match="queries"):
        index.query(q[:, :-1], w, QuerySpec(k=3))
    with pytest.raises(ValueError, match="weights"):
        index.query(q, w[:, :-1], QuerySpec(k=3))
    with pytest.raises(ValueError, match="batch dims disagree"):
        index.query(q, w[:-1], QuerySpec(k=3))
    with pytest.raises(ValueError, match="queries"):
        index.query(q[0], w[0], QuerySpec(k=3))  # 1-D, not (b, d)
    with pytest.raises(ValueError, match="queries"):
        index.query(q[None], w[None], QuerySpec(k=3))  # 3-D, not (b, d)
    with pytest.raises(ValueError, match="rows"):
        _mutable(rng, data).insert(data[:, :-1])


def test_updatespec_validation():
    with pytest.raises(ValueError, match="delta_capacity"):
        UpdateSpec(delta_capacity=-1)
    with pytest.raises(ValueError, match="compact_threshold"):
        UpdateSpec(delta_capacity=8, compact_threshold=0.0)
    assert not UpdateSpec().mutable
    assert UpdateSpec(delta_capacity=8).mutable


# ---------------------------------------------------------------------------
# invalid-id sentinel unification (satellite regression)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mutable", [False, True])
@pytest.mark.parametrize("mode", ["probe", "multiprobe", "exact"])
def test_invalid_slots_are_minus_one_and_inf(rng, mutable, mode):
    """ids == -1 ⇔ dists == +inf, in every mode, mutable or not — the
    internal candidate sentinel (n) must never escape a QueryResult."""
    data = jax.random.uniform(jax.random.fold_in(rng, 0), (5, D)) * 0.1
    cfg = _cfg(max_candidates=16)
    if mutable:
        index = Index.build(
            jax.random.fold_in(rng, 9), data, cfg, update=UpdateSpec(delta_capacity=8)
        )
        index = index.delete(jnp.asarray([2], jnp.int32))
    else:
        index = Index.build(jax.random.fold_in(rng, 9), data, cfg)
    q = jnp.ones((2, D)) * 0.95  # far corner: few/no probe candidates
    w = jnp.ones((2, D))
    res = index.query(q, w, QuerySpec(k=9, mode=mode))  # k > live rows
    ids = np.asarray(res.ids)
    dists = np.asarray(res.dists)
    assert ((ids == -1) == ~np.isfinite(dists)).all()
    assert ids.max() < 5 + 8 and ids.min() >= -1  # never the sentinel n


def test_all_query_paths_agree_on_overflowed_distances(rng):
    """A distance that overflows float32 to +inf reports 'not found'
    (ids == -1) identically on the streaming-scan and gather-rerank paths."""
    data = jax.random.uniform(jax.random.fold_in(rng, 1), (8, D))
    q = jnp.zeros((1, D))
    w = jnp.full((1, D), 3e38)  # w·|x-q| overflows f32
    from repro.kernels import ops

    d1, i1 = ops.wl1_scan_topk(data, q, w, 3, force="chunked")
    d2, i2 = ops.wl1_scan_topk(data, q, w, 3, force="ref")
    cand = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32)[None, :], (1, 8))
    d3, i3 = ops.gather_rerank_topk(data, cand, q, w, 3, force="auto")
    for d_, i_ in [(d1, i1), (d2, i2), (d3, i3)]:
        np.testing.assert_array_equal(np.asarray(i_), -1)
        assert not np.isfinite(np.asarray(d_)).any()


# ---------------------------------------------------------------------------
# streaming datastore (runtime.retrieval rides the same lifecycle)
# ---------------------------------------------------------------------------


def test_retrieval_datastore_extends_and_retires(rng):
    from repro.configs import RetrievalConfig
    from repro.runtime import retrieval as rt

    rcfg = RetrievalConfig(
        datastore_size=256, d_key=8, K=6, L=8, topk=4, delta_capacity=32
    )
    state = rt.build_datastore(jax.random.fold_in(rng, 0), 16, 50, rcfg)
    assert state.index.mutable
    assert state.values.shape == (256 + 32,)

    hidden = jax.random.normal(jax.random.fold_in(rng, 1), (5, 16))
    toks = jnp.arange(5, dtype=jnp.int32) + 40
    state2, ids = rt.extend_datastore(state, hidden, toks)
    np.testing.assert_array_equal(np.asarray(ids), 256 + np.arange(5))
    np.testing.assert_array_equal(
        np.asarray(state2.values[256:261]), np.asarray(toks)
    )
    # an ingested record is retrievable at its own key...
    res = state2.index.query(
        rt.reduce_key(hidden, state2), jnp.ones((5, 8)), rt.QuerySpec(k=1)
    )
    np.testing.assert_array_equal(np.asarray(res.ids[:, 0]), np.asarray(ids))
    # ...and gone after retire
    state3 = rt.retire_datastore(state2, ids)
    res = state3.index.query(
        rt.reduce_key(hidden, state3), jnp.ones((5, 8)), rt.QuerySpec(k=1)
    )
    assert not set(np.asarray(ids).tolist()) & set(
        np.asarray(res.ids).ravel().tolist()
    )


# ---------------------------------------------------------------------------
# misc surface
# ---------------------------------------------------------------------------


def test_needs_compact_and_live_counts(rng):
    data, extra, _, _ = _problem(rng, m=CAP)
    index = _mutable(rng, data)
    assert not index.needs_compact and index.n_live == N
    index, ids = index.insert(extra[: int(CAP * 0.8)])
    assert index.needs_compact  # default threshold 0.75
    index = index.delete(ids[:5])
    assert index.n_live == N + int(CAP * 0.8) - 5
    compacted = index.compact()
    assert compacted.n == index.n_live and not compacted.needs_compact


def test_shard_requires_divisible_capacity(rng):
    data, _, _, _ = _problem(rng)
    index = _mutable(rng, data, cap=7)

    class FakeMesh:
        class devices:
            size = 4

    with pytest.raises(ValueError, match="multiple of the mesh"):
        index.shard(FakeMesh())
