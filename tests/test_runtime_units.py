"""Unit tests: optimizer math, data pipeline determinism, sharding sanitizer."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_bundle
from repro.configs.base import TrainConfig
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.models.sharding import sanitize_spec
from repro.optim import (
    adamw_update,
    clip_by_global_norm,
    compress_grads,
    decompress_accumulate,
    init_opt_state,
    lr_schedule,
)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_decreases_quadratic():
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=100,
                       weight_decay=0.0, grad_clip=1e9)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_opt_state(params, tcfg)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
        params, state, _ = adamw_update(params, grads, state, tcfg)
    assert float(jnp.linalg.norm(params["w"])) < 0.3


def test_weight_decay_shrinks_params():
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=0, weight_decay=0.5,
                       grad_clip=1e9)
    params = {"w": jnp.asarray([1.0])}
    state = init_opt_state(params, tcfg)
    zero_grads = {"w": jnp.zeros(1)}
    new_params, *_ = adamw_update(params, zero_grads, state, tcfg)
    assert float(new_params["w"][0]) < 1.0


def test_clip_by_global_norm():
    grads = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
    clipped, gn = clip_by_global_norm(grads, 1.0)
    np.testing.assert_allclose(float(gn), 5.0, rtol=1e-5)
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5
    )


def test_lr_schedule_shape():
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(jnp.asarray(s), tcfg)) for s in range(0, 101, 10)]
    assert lrs[0] < lrs[1]  # warmup rises
    assert lrs[-1] < lrs[2]  # cosine decays
    assert all(l >= 0 for l in lrs)


def test_int8_ef_compression_error_feedback_converges():
    """With error feedback, quantization error doesn't accumulate: the sum of
    decompressed grads over steps tracks the true sum."""
    g = jnp.asarray([0.001, -0.003, 0.5])
    ef = jnp.zeros(3)
    acc = jnp.zeros(3)
    for step in range(50):
        comp, ef = compress_grads(g, "int8_ef", ef)
        acc = decompress_accumulate(acc, comp, "int8_ef")
    # EF keeps the residual bounded (error does NOT grow with steps): the
    # accumulated sum tracks the true sum within one quantum per element.
    np.testing.assert_allclose(np.asarray(acc), np.asarray(g) * 50, rtol=0.05)
    assert float(jnp.max(jnp.abs(ef))) < 0.5 / 127.0 + 1e-6  # one quantum


def test_bf16_compression_halves_bytes():
    g = {"w": jnp.ones((128,), jnp.float32)}
    comp, _ = compress_grads(g, "bf16", None)
    assert comp["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_stream_deterministic_and_step_pure():
    mcfg = get_bundle("qwen3-8b").model
    dcfg = DataConfig(seq_len=64, global_batch=4, seed=9)
    s1 = SyntheticStream(dcfg, mcfg)
    s2 = SyntheticStream(dcfg, mcfg)
    for step in (0, 5, 1000):
        np.testing.assert_array_equal(s1.batch(step)["tokens"], s2.batch(step)["tokens"])
    assert not np.array_equal(s1.batch(0)["tokens"], s1.batch(1)["tokens"])


def test_stream_shards_disjoint_rng():
    mcfg = get_bundle("qwen3-8b").model
    a = SyntheticStream(DataConfig(seq_len=64, global_batch=8, n_shards=2, shard_id=0), mcfg)
    b = SyntheticStream(DataConfig(seq_len=64, global_batch=8, n_shards=2, shard_id=1), mcfg)
    assert a.local_batch == 4
    assert not np.array_equal(a.batch(3)["tokens"], b.batch(3)["tokens"])


def test_stream_modalities():
    audio = get_bundle("hubert-xlarge").model
    vlm = get_bundle("qwen2-vl-2b").model
    sa = SyntheticStream(DataConfig(seq_len=32, global_batch=2), audio).batch(0)
    assert sa["frames"].shape == (2, 32, audio.frontend_dim)
    assert sa["targets"].max() < audio.vocab_size
    sv = SyntheticStream(DataConfig(seq_len=32, global_batch=2), vlm).batch(0)
    nv = min(vlm.n_vision_tokens, 16)
    assert sv["tokens"].shape == (2, 32 - nv)
    assert sv["positions"].shape == (3, 2, 32)


# ---------------------------------------------------------------------------
# sharding sanitizer
# ---------------------------------------------------------------------------


def test_sanitize_spec_divisibility():
    mesh = jax.make_mesh((1, 1), ("data", "model"))  # single device: sizes 1
    s = sanitize_spec(P("data", "model"), (8, 8), mesh)
    assert s == P("data", "model")  # size-1 axes always divide


def test_sanitize_spec_drops_nondivisible():
    import subprocess, sys, os, textwrap
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.models.sharding import sanitize_spec
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        # dim 8 % 4 == 0 keeps "model"; dim 3 % 2 != 0 drops "data"
        assert sanitize_spec(P("data", "model"), (3, 8), mesh) == P(None, "model")
        # tuple degrades greedily: ("pod","data") -> prefix that divides
        mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
        assert sanitize_spec(P(("pod", "data")), (2,), mesh2) == P(("pod",))
        assert sanitize_spec(P(("pod", "data")), (8,), mesh2) == P(("pod", "data"))
        # unknown axis names dropped
        assert sanitize_spec(P("nope"), (8,), mesh2) == P(None)
        print("OK")
    """)], capture_output=True, text=True, env=env, timeout=120)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
