"""§4.2.3 O(d) trick: prefix-sum projections ≡ naive 2Md inner products."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hash_families as hf
from repro.core import transforms

settings = hypothesis.settings(max_examples=25, deadline=None)


def _naive_projection(levels, w, a_rows):
    """a^T P(o) / a^T Q_w(q) via the explicit 2Md construction (paper's naive path).

    a_rows: (2d, M) row view; flat layout must match transform_P/Q:
    (cos-block d rows of M ; sin-block d rows of M).
    """
    d2, M = a_rows.shape
    a_flat = a_rows.reshape(-1)
    if w is None:
        vec = transforms.transform_P(levels, M)
    else:
        vec = transforms.transform_Q(levels, w, M)
    return jnp.dot(a_flat, vec)


@settings
@hypothesis.given(d=st.integers(1, 12), M=st.integers(1, 10), seed=st.integers(0, 2**31 - 1))
def test_prefix_trick_matches_naive_data(d, M, seed):
    rng = np.random.RandomState(seed)
    a_rows = jnp.asarray(rng.randn(2 * d, M), jnp.float32)
    folded = hf._prefix_tables_from_rows(a_rows)
    levels = jnp.asarray(rng.randint(0, M + 1, size=(3, d)), jnp.int32)
    got = hf._project_gather(levels, folded[None], None)[:, 0]
    want = jax.vmap(lambda lv: _naive_projection(lv, None, a_rows))(levels)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@settings
@hypothesis.given(d=st.integers(1, 12), M=st.integers(1, 10), seed=st.integers(0, 2**31 - 1))
def test_prefix_trick_matches_naive_query(d, M, seed):
    rng = np.random.RandomState(seed)
    a_rows = jnp.asarray(rng.randn(2 * d, M), jnp.float32)
    folded = hf._prefix_tables_from_rows(a_rows)
    levels = jnp.asarray(rng.randint(0, M + 1, size=(3, d)), jnp.int32)
    w = jnp.asarray(rng.randn(3, d), jnp.float32)
    got = hf._project_gather(levels, folded[None], w)[:, 0]
    want = jax.vmap(lambda lv, wv: _naive_projection(lv, wv, a_rows))(levels, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_onehot_impl_matches_gather_impl(rng):
    d, M, H, n = 33, 17, 21, 50
    params = hf.LSHParams(d=d, M=M, n_hashes=H, family="l2", W=3.0)
    tables = hf.make_prefix_tables(rng, params)
    k1, k2 = jax.random.split(rng)
    levels = jax.random.randint(k1, (n, d), 0, M + 1)
    w = jax.random.normal(k2, (n, d))
    for weights in (None, w):
        a = hf._project_gather(levels, tables.folded, weights)
        b = hf._project_onehot(levels, tables.folded, weights)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_hash_codes_deterministic_and_asymmetric(rng):
    """f(x) and g(x) agree iff weights are all-ones (the ALSH asymmetry)."""
    d, M = 8, 6
    params = hf.LSHParams(d=d, M=M, n_hashes=64, family="theta")
    tables = hf.make_prefix_tables(rng, params)
    levels = jax.random.randint(jax.random.fold_in(rng, 1), (4, d), 0, M + 1)
    ones = jnp.ones((4, d))
    f = hf.hash_data(levels, tables, params, impl="gather")
    g1 = hf.hash_query(levels, ones, tables, params, impl="gather")
    np.testing.assert_array_equal(np.asarray(f), np.asarray(g1))  # w=1 ⇒ symmetric
    w = 2.5 * ones
    g2 = hf.hash_query(levels, w, tables, params, impl="gather")
    # positive scaling preserves signs ⇒ same theta hashes (sanity of Eq 5)
    np.testing.assert_array_equal(np.asarray(f), np.asarray(g2))
    wneg = -ones
    g3 = hf.hash_query(levels, wneg, tables, params, impl="gather")
    assert np.any(np.asarray(f) != np.asarray(g3))  # negation flips signs


def test_l2_hash_bucket_width(rng):
    params = hf.LSHParams(d=4, M=5, n_hashes=8, family="l2", W=2.0)
    tables = hf.make_prefix_tables(rng, params)
    proj = jnp.linspace(-10, 10, 8 * 5).reshape(5, 8)
    codes = hf.l2_hash(proj, tables, params.W)
    recon_low = codes * params.W - tables.offsets[None, :]
    assert np.all(np.asarray(proj) >= np.asarray(recon_low) - 1e-5)
    assert np.all(np.asarray(proj) < np.asarray(recon_low) + params.W + 1e-4)
