"""Shared test fixtures.

NOTE: XLA_FLAGS / device-count overrides are deliberately NOT set here — smoke
tests and benchmarks must see the real single-device CPU. Only
``repro/launch/dryrun.py`` (a separate process) forces 512 host devices.
Multi-device CPU tests (shard_map / pipeline) spawn subprocesses instead.
"""

import os

import jax
import pytest

# Determinism for hypothesis + jax.random interplay.
os.environ.setdefault("JAX_PLATFORMS", "")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(20260714)
