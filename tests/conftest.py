"""Shared test fixtures.

NOTE: XLA_FLAGS / device-count overrides are deliberately NOT set here — smoke
tests and benchmarks must see the real single-device CPU. Only
``repro/launch/dryrun.py`` (a separate process) forces 512 host devices.
Multi-device CPU tests (shard_map / pipeline) spawn subprocesses instead.

``hypothesis`` is optional: several modules import it at top level for
property-based sweeps, but offline environments can't install it. When the
real package is missing we register a minimal stub in ``sys.modules`` BEFORE
test modules are collected — strategy constructors become inert placeholders
and ``@given`` turns the test into a skip — so the suite still collects and
every non-property test runs.
"""

import os
import sys

import jax
import pytest

# Determinism for hypothesis + jax.random interplay.
os.environ.setdefault("JAX_PLATFORMS", "")


def _install_hypothesis_stub() -> None:
    import types

    def _strategy(*args, **kwargs):
        return None  # inert placeholder — never drawn (given() skips first)

    def given(*_args, **_kwargs):
        def deco(fn):
            def wrapper():  # no params: given-supplied args must not look like fixtures
                pytest.skip("hypothesis not installed — property test skipped")

            # NOT functools.wraps: __wrapped__ would re-expose the original
            # signature and pytest would hunt fixtures for the given-params.
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    def _permissive(_name):
        return _strategy

    root = types.ModuleType("hypothesis")
    root.given = given
    root.settings = settings
    root.assume = lambda *a, **k: True
    root.__getattr__ = _permissive

    st = types.ModuleType("hypothesis.strategies")
    st.__getattr__ = _permissive
    extra = types.ModuleType("hypothesis.extra")
    extra.__getattr__ = _permissive
    hnp = types.ModuleType("hypothesis.extra.numpy")
    hnp.__getattr__ = _permissive

    root.strategies = st
    root.extra = extra
    extra.numpy = hnp
    sys.modules["hypothesis"] = root
    sys.modules["hypothesis.strategies"] = st
    sys.modules["hypothesis.extra"] = extra
    sys.modules["hypothesis.extra.numpy"] = hnp


try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _install_hypothesis_stub()


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(20260714)
