"""Fault tolerance: checkpoint/restart determinism, failure injection,
async checkpointing, straggler telemetry."""

import dataclasses
import os

import jax
import numpy as np
import pytest

from repro import ckpt
from repro.configs import get_bundle, reduced_model
from repro.data.pipeline import DataConfig
from repro.runtime.fault import (
    SimulatedFailure,
    StragglerMonitor,
    run_with_restarts,
    train_loop,
)


@pytest.fixture()
def tiny_bundle():
    bundle = get_bundle("gemma3-1b")
    mcfg = dataclasses.replace(reduced_model(bundle.model), n_units=1, n_layers=8,
                               tail=("local", "local"))
    tcfg = dataclasses.replace(bundle.train, total_steps=20, warmup_steps=2)
    return dataclasses.replace(bundle, model=mcfg, train=tcfg)


DCFG = DataConfig(seq_len=32, global_batch=2)


def _leaves(state):
    return [np.asarray(x) for x in jax.tree.leaves(state)]


def test_restart_reproduces_uninterrupted_run(tiny_bundle, tmp_path):
    """Kill at step 7 (after ckpt at 5), restart → bitwise-identical to a
    clean 10-step run."""
    clean = train_loop(tiny_bundle, DCFG, 10, str(tmp_path / "clean"), ckpt_every=5)
    faulty = run_with_restarts(
        tiny_bundle, DCFG, 10, str(tmp_path / "faulty"), failures=(7,), ckpt_every=5
    )
    for a, b in zip(_leaves(clean), _leaves(faulty)):
        np.testing.assert_array_equal(a, b)


def test_failure_without_commit_replays_steps(tiny_bundle, tmp_path):
    """A failure before any post-step commit resumes from step 0 and still
    converges to the same state (pure-function-of-step data)."""
    d = str(tmp_path / "c")
    with pytest.raises(SimulatedFailure):
        train_loop(tiny_bundle, DCFG, 10, d, ckpt_every=100, fail_at=3)
    assert ckpt.latest_step(d) == 0  # only the step-0 bootstrap commit
    resumed = train_loop(tiny_bundle, DCFG, 6, d, ckpt_every=100)
    clean = train_loop(tiny_bundle, DCFG, 6, str(tmp_path / "clean"), ckpt_every=100)
    for a, b in zip(_leaves(resumed), _leaves(clean)):
        np.testing.assert_array_equal(a, b)


def test_async_checkpointer_equivalent(tiny_bundle, tmp_path):
    sync = train_loop(tiny_bundle, DCFG, 6, str(tmp_path / "s"), ckpt_every=2)
    asyn = train_loop(
        tiny_bundle, DCFG, 6, str(tmp_path / "a"), ckpt_every=2, async_ckpt=True
    )
    for a, b in zip(_leaves(sync), _leaves(asyn)):
        np.testing.assert_array_equal(a, b)
    assert ckpt.latest_step(str(tmp_path / "a")) == 6


def test_checkpoint_roundtrip_dtypes(tmp_path):
    tree = {
        "a": jax.numpy.arange(6, dtype=jax.numpy.int32).reshape(2, 3),
        "b": {"c": jax.numpy.ones((4,), jax.numpy.bfloat16) * 1.5},
        "scalar": jax.numpy.asarray(7, jax.numpy.int32),
    }
    ckpt.save_checkpoint(str(tmp_path), 3, tree)
    assert ckpt.latest_step(str(tmp_path)) == 3
    back = ckpt.restore_checkpoint(str(tmp_path), 3, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_uncommitted_checkpoint_ignored(tmp_path):
    d = str(tmp_path)
    tree = {"x": jax.numpy.zeros((2,))}
    ckpt.save_checkpoint(d, 5, tree)
    # simulate crash mid-write at step 10: dir exists, no COMMIT
    os.makedirs(os.path.join(d, "step_000000010"))
    assert ckpt.latest_step(d) == 5


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(alpha=0.5, k_sigma=3.0)
    for s in range(8):
        assert not mon.observe(s, 0.10 + 0.001 * (s % 2))
    assert mon.observe(8, 1.0)  # 10x step time → flagged
    assert mon.flagged and mon.flagged[0][0] == 8


def test_grad_compression_modes_run(tiny_bundle, tmp_path):
    """bf16 and int8+EF compression paths train without NaNs."""
    for mode in ("bf16", "int8_ef"):
        tcfg = dataclasses.replace(
            tiny_bundle.train, grad_compression=mode, microbatch=2
        )
        b = dataclasses.replace(tiny_bundle, train=tcfg)
        state = train_loop(b, DCFG, 3, str(tmp_path / mode), ckpt_every=100)
        for leaf in jax.tree.leaves(state.params):
            assert np.all(np.isfinite(np.asarray(leaf, np.float32))), mode


# --- async checkpoint lifecycle (context manager: flush on exception) -------


def test_async_checkpointer_flushes_in_flight_save_on_failure(tmp_path, monkeypatch):
    """A SimulatedFailure raised while an async save is in flight must NOT
    lose that save: AsyncCheckpointer.__exit__ joins the writer thread, so
    the commit is deterministically visible to the restarting process.
    (The pre-context-manager train_loop leaked the thread here — whether
    the restart saw the last commit was a race.)"""
    import time as _time

    from repro.ckpt import checkpoint as ckpt_mod

    real_save = ckpt_mod.save_checkpoint
    monkeypatch.setattr(
        ckpt_mod,
        "save_checkpoint",
        lambda *a, **k: (_time.sleep(0.3), real_save(*a, **k))[1],
    )
    d = str(tmp_path)
    with pytest.raises(SimulatedFailure):
        with ckpt.AsyncCheckpointer(d) as saver:
            saver.save(7, {"x": jax.numpy.arange(3)})
            # the slow writer is still running when the "node" dies
            raise SimulatedFailure("die with a save in flight")
    assert ckpt.latest_step(d) == 7  # flushed, not raced


def test_async_checkpointer_exit_clean_path_raises_save_errors(tmp_path):
    """On a clean exit a failed async save must propagate (nothing else
    will surface it); while unwinding another exception it must not mask
    the primary error."""
    import pytest as _pytest

    bad = os.path.join(str(tmp_path), "file")  # parent is a FILE: save fails
    with open(bad, "w") as f:
        f.write("x")
    with _pytest.raises(OSError):
        with ckpt.AsyncCheckpointer(os.path.join(bad, "sub")) as saver:
            saver.save(1, {"x": jax.numpy.zeros((1,))})
    # unwinding path: the primary error wins over the save error
    with _pytest.raises(SimulatedFailure):
        with ckpt.AsyncCheckpointer(os.path.join(bad, "sub")) as saver:
            saver.save(1, {"x": jax.numpy.zeros((1,))})
            raise SimulatedFailure("primary")


def test_train_loop_failure_with_async_ckpt_commits_in_flight_save(
    tiny_bundle, tmp_path, monkeypatch
):
    """End-to-end: train_loop with async_ckpt dies right after handing the
    step-5 save to the writer thread; the restart must resume FROM step 5
    and reproduce the clean run bitwise."""
    import time as _time

    from repro.ckpt import checkpoint as ckpt_mod

    real_save = ckpt_mod.save_checkpoint
    monkeypatch.setattr(
        ckpt_mod,
        "save_checkpoint",
        lambda *a, **k: (_time.sleep(0.2), real_save(*a, **k))[1],
    )
    d = str(tmp_path / "faulty")
    with pytest.raises(SimulatedFailure):
        train_loop(tiny_bundle, DCFG, 10, d, ckpt_every=5, fail_at=6,
                   async_ckpt=True)
    assert ckpt.latest_step(d) == 5  # the in-flight save was flushed

    faulty = run_with_restarts(tiny_bundle, DCFG, 10, d, failures=(),
                               ckpt_every=5, async_ckpt=True)
    clean = train_loop(tiny_bundle, DCFG, 10, str(tmp_path / "clean"),
                       ckpt_every=5)
    for a, b in zip(_leaves(faulty), _leaves(clean)):
        np.testing.assert_array_equal(a, b)


def test_ewma_quantile_tracks_sustained_shift():
    """The serving-tier consumer: with k_sigma=inf every sample folds in,
    so a sustained latency shift moves the p99 estimate (the training
    straggler rule would have frozen it as an outlier)."""
    import math

    mon = StragglerMonitor(alpha=0.2, k_sigma=math.inf)
    for s in range(30):
        mon.observe(s, 10.0)
    calm = mon.ewma_quantile()
    assert calm == pytest.approx(10.0, abs=1.0)
    for s in range(30, 60):
        mon.observe(s, 100.0)  # overload: 10x latencies
    assert mon.ewma_quantile() > 50.0  # the estimate followed the shift
    assert mon.ewma_quantile(0.0) == pytest.approx(mon.mean)
