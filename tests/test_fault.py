"""Fault tolerance: checkpoint/restart determinism, failure injection,
async checkpointing, straggler telemetry."""

import dataclasses
import os

import jax
import numpy as np
import pytest

from repro import ckpt
from repro.configs import get_bundle, reduced_model
from repro.data.pipeline import DataConfig
from repro.runtime.fault import (
    SimulatedFailure,
    StragglerMonitor,
    run_with_restarts,
    train_loop,
)


@pytest.fixture()
def tiny_bundle():
    bundle = get_bundle("gemma3-1b")
    mcfg = dataclasses.replace(reduced_model(bundle.model), n_units=1, n_layers=8,
                               tail=("local", "local"))
    tcfg = dataclasses.replace(bundle.train, total_steps=20, warmup_steps=2)
    return dataclasses.replace(bundle, model=mcfg, train=tcfg)


DCFG = DataConfig(seq_len=32, global_batch=2)


def _leaves(state):
    return [np.asarray(x) for x in jax.tree.leaves(state)]


def test_restart_reproduces_uninterrupted_run(tiny_bundle, tmp_path):
    """Kill at step 7 (after ckpt at 5), restart → bitwise-identical to a
    clean 10-step run."""
    clean = train_loop(tiny_bundle, DCFG, 10, str(tmp_path / "clean"), ckpt_every=5)
    faulty = run_with_restarts(
        tiny_bundle, DCFG, 10, str(tmp_path / "faulty"), failures=(7,), ckpt_every=5
    )
    for a, b in zip(_leaves(clean), _leaves(faulty)):
        np.testing.assert_array_equal(a, b)


def test_failure_without_commit_replays_steps(tiny_bundle, tmp_path):
    """A failure before any post-step commit resumes from step 0 and still
    converges to the same state (pure-function-of-step data)."""
    d = str(tmp_path / "c")
    with pytest.raises(SimulatedFailure):
        train_loop(tiny_bundle, DCFG, 10, d, ckpt_every=100, fail_at=3)
    assert ckpt.latest_step(d) == 0  # only the step-0 bootstrap commit
    resumed = train_loop(tiny_bundle, DCFG, 6, d, ckpt_every=100)
    clean = train_loop(tiny_bundle, DCFG, 6, str(tmp_path / "clean"), ckpt_every=100)
    for a, b in zip(_leaves(resumed), _leaves(clean)):
        np.testing.assert_array_equal(a, b)


def test_async_checkpointer_equivalent(tiny_bundle, tmp_path):
    sync = train_loop(tiny_bundle, DCFG, 6, str(tmp_path / "s"), ckpt_every=2)
    asyn = train_loop(
        tiny_bundle, DCFG, 6, str(tmp_path / "a"), ckpt_every=2, async_ckpt=True
    )
    for a, b in zip(_leaves(sync), _leaves(asyn)):
        np.testing.assert_array_equal(a, b)
    assert ckpt.latest_step(str(tmp_path / "a")) == 6


def test_checkpoint_roundtrip_dtypes(tmp_path):
    tree = {
        "a": jax.numpy.arange(6, dtype=jax.numpy.int32).reshape(2, 3),
        "b": {"c": jax.numpy.ones((4,), jax.numpy.bfloat16) * 1.5},
        "scalar": jax.numpy.asarray(7, jax.numpy.int32),
    }
    ckpt.save_checkpoint(str(tmp_path), 3, tree)
    assert ckpt.latest_step(str(tmp_path)) == 3
    back = ckpt.restore_checkpoint(str(tmp_path), 3, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_uncommitted_checkpoint_ignored(tmp_path):
    d = str(tmp_path)
    tree = {"x": jax.numpy.zeros((2,))}
    ckpt.save_checkpoint(d, 5, tree)
    # simulate crash mid-write at step 10: dir exists, no COMMIT
    os.makedirs(os.path.join(d, "step_000000010"))
    assert ckpt.latest_step(d) == 5


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(alpha=0.5, k_sigma=3.0)
    for s in range(8):
        assert not mon.observe(s, 0.10 + 0.001 * (s % 2))
    assert mon.observe(8, 1.0)  # 10x step time → flagged
    assert mon.flagged and mon.flagged[0][0] == 8


def test_grad_compression_modes_run(tiny_bundle, tmp_path):
    """bf16 and int8+EF compression paths train without NaNs."""
    for mode in ("bf16", "int8_ef"):
        tcfg = dataclasses.replace(
            tiny_bundle.train, grad_compression=mode, microbatch=2
        )
        b = dataclasses.replace(tiny_bundle, train=tcfg)
        state = train_loop(b, DCFG, 3, str(tmp_path / mode), ckpt_every=100)
        for leaf in jax.tree.leaves(state.params):
            assert np.all(np.isfinite(np.asarray(leaf, np.float32))), mode
