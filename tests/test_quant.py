"""Quantized table tier: codecs, proxy screening, and exact rerank.

The contract under test, layer by layer:

  * codecs — round-trip within dtype precision, int8 tables ≥3x smaller
    than f32, f32 encode is a true passthrough (same array object).
  * kernels — every schedule (ref / chunked / auto / pallas-interpret)
    agrees on quantized payloads, and the block-coalesced pallas kernel is
    BIT-identical to the per-row kernel on f32 (the default path must not
    move by a single ulp).
  * engine — candidate generation hashes RAW rows before encoding, so the
    candidate sets are codec-invariant; with ``storage="f32"`` the whole
    engine is bit-identical to an unquantized build, screening knob or not.
  * quality — int8 + calibrated screening stays within a point of f32
    recall while the table is ≥3x smaller.
  * planner — quantized ladders grow screening rungs; f32 ladders do not
    (plan bit-parity with yesterday); the empirical-prior path runs
    unchanged on a quantized index.
  * persistence — the v5 manifest round-trips codec + scales; pre-v5
    directories load as f32.
"""

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import quant
from repro.api import (
    BoundedSpace,
    Index,
    IndexConfig,
    Planner,
    QualitySpec,
    QuerySpec,
    UpdateSpec,
)
from repro.distance import recall_at_k
from repro.kernels import ops
from repro.kernels.gather_rerank import (
    gather_rerank_topk_pallas,
    gather_rerank_topk_pallas_blocked,
)

N = 400
D = 8


def _cfg(family="theta", storage="f32", **kw):
    kw.setdefault("max_candidates", 64)
    kw.setdefault("space", BoundedSpace(0.0, 1.0, 8.0))
    kw.setdefault("W", 8.0)
    return IndexConfig(d=D, M=8, K=6, L=8, family=family, storage=storage, **kw)


def _problem(rng, n=N, d=D, b=4, salt=0):
    data = jax.random.uniform(jax.random.fold_in(rng, salt), (n, d))
    q = jax.random.uniform(jax.random.fold_in(rng, salt + 1), (b, d))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(rng, salt + 2), (b, d))) + 0.2
    return data, q, w


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------


def test_codec_roundtrip_and_ratio(rng):
    data = jax.random.uniform(jax.random.fold_in(rng, 0), (64, D))

    f32 = quant.get_codec("f32")
    payload, scales = f32.encode(data)
    assert payload is data and scales is None  # true passthrough

    bf16 = quant.get_codec("bf16")
    payload, scales = bf16.encode(data)
    assert payload.dtype == jnp.bfloat16 and scales is None
    dec = quant.decode_table(payload, scales)
    assert dec.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(dec), np.asarray(data),
                               rtol=0, atol=1.0 / 128)
    assert data.nbytes / payload.nbytes == 2.0

    int8 = quant.get_codec("int8")
    payload, scales = int8.encode(data)
    assert payload.dtype == jnp.int8 and scales.shape == (D,)
    dec = quant.decode_table(payload, scales)
    # symmetric per-dimension: error bounded by half a quantization step
    step = np.asarray(scales)
    err = np.abs(np.asarray(dec) - np.asarray(data))
    assert (err <= step[None, :] * 0.5 + 1e-7).all()
    assert data.nbytes / payload.nbytes >= 3.0  # acceptance: ≥3x smaller

    with pytest.raises(ValueError, match="storage"):
        quant.get_codec("int4")


def test_int8_encode_saturates_out_of_fit_rows(rng):
    """Delta inserts re-use the sealed segment's scales; rows outside the
    fitted range must clamp to ±127, never wrap."""
    data = jax.random.uniform(jax.random.fold_in(rng, 1), (32, D))
    codec = quant.get_codec("int8")
    _, scales = codec.encode(data)
    wild = data * 10.0
    enc = codec.encode_rows(wild, scales)
    assert int(np.abs(np.asarray(enc)).max()) <= 127


def test_screen_keep_semantics():
    assert quant.screen_keep(10, 0.0, 1000) == 0  # screening off
    assert quant.screen_keep(10, 2.0, 1000) == 20
    assert quant.screen_keep(10, 1.0, 1000) == 10
    assert quant.screen_keep(10, 2.5, 1000) == 25
    # keep >= slots: screening cannot drop anything — disabled
    assert quant.screen_keep(10, 4.0, 30) == 0


def test_proxy_query_factorization(rng):
    """int8 proxy: w'·|code − q'| == w·|decode(code) − s·round(q/s)| — the
    screen never decodes, yet ranks by a faithful quantized-lattice wl1."""
    data, q, w = _problem(rng, n=64, salt=3)
    codec = quant.get_codec("int8")
    payload, scales = codec.encode(data)
    qp, wp = quant.proxy_query(q, w, payload.dtype, scales)
    proxy = np.sum(np.asarray(wp)[:, None, :]
                   * np.abs(np.asarray(payload, dtype=np.float32)[None, :, :]
                            - np.asarray(qp)[:, None, :]), axis=-1)
    dec = np.asarray(quant.decode_table(payload, scales))
    qq = np.asarray(scales) * np.clip(
        np.round(np.asarray(q) / np.asarray(scales)), -127, 127)
    direct = np.sum(np.asarray(w)[:, None, :]
                    * np.abs(dec[None, :, :] - qq[:, None, :]), axis=-1)
    np.testing.assert_allclose(proxy, direct, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# kernels: schedule parity on quantized payloads; f32 blocked bit-identity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("storage", ["bf16", "int8"])
def test_schedule_parity_quantized(rng, storage):
    data, q, w = _problem(rng, salt=10)
    codec = quant.get_codec(storage)
    payload, scales = codec.encode(data)
    ids = jax.random.randint(jax.random.fold_in(rng, 11), (4, 48), 0, N + 8)
    ids = jnp.where(ids >= N, N, ids).astype(jnp.int32)  # some sentinels
    ref_d, ref_i = ops.gather_rerank_topk(payload, ids, q, w, 5,
                                          force="ref", scales=scales)
    for force in ("chunked", "auto", "interpret"):
        d_, i_ = ops.gather_rerank_topk(payload, ids, q, w, 5,
                                        force=force, scales=scales)
        np.testing.assert_array_equal(np.asarray(i_), np.asarray(ref_i),
                                      err_msg=f"ids diverge under {force}")
        np.testing.assert_allclose(np.asarray(d_), np.asarray(ref_d),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("storage", ["bf16", "int8"])
def test_schedule_parity_quantized_segmented(rng, storage):
    data, q, w = _problem(rng, salt=12)
    codec = quant.get_codec(storage)
    payload, scales = codec.encode(data)
    delta_rows = jax.random.uniform(jax.random.fold_in(rng, 13), (32, D))
    delta = codec.encode_rows(delta_rows, scales)
    ids = jax.random.randint(jax.random.fold_in(rng, 14), (4, 48), 0, N + 32)
    ids = ids.astype(jnp.int32)
    ref_d, ref_i = ops.gather_rerank_topk(payload, ids, q, w, 5,
                                          force="ref", delta=delta,
                                          scales=scales)
    for force in ("chunked", "auto", "interpret"):
        d_, i_ = ops.gather_rerank_topk(payload, ids, q, w, 5,
                                        force=force, delta=delta,
                                        scales=scales)
        np.testing.assert_array_equal(np.asarray(i_), np.asarray(ref_i),
                                      err_msg=f"ids diverge under {force}")
        np.testing.assert_allclose(np.asarray(d_), np.asarray(ref_d),
                                   rtol=1e-5, atol=1e-5)


def test_blocked_kernel_bit_identical_on_f32(rng):
    """The coalesced-DMA kernel and the per-row kernel must agree BIT for
    bit on f32 — same insertion order, same ties, same sentinels."""
    data, q, w = _problem(rng, salt=20)
    ids = jax.random.randint(jax.random.fold_in(rng, 21), (4, 50), 0, N + 16)
    ids = jnp.where(ids >= N, N, ids).astype(jnp.int32)
    per_row = gather_rerank_topk_pallas(data, ids, q, w, 7, interpret=True)
    blocked = gather_rerank_topk_pallas_blocked(data, ids, q, w, 7,
                                                interpret=True)
    np.testing.assert_array_equal(np.asarray(per_row[1]),
                                  np.asarray(blocked[1]))
    np.testing.assert_array_equal(np.asarray(per_row[0]),
                                  np.asarray(blocked[0]))


# ---------------------------------------------------------------------------
# engine: f32 bit-identity + codec-invariant candidates + screened recall
# ---------------------------------------------------------------------------


def test_f32_storage_is_bit_identical_and_ignores_alpha(rng):
    """storage='f32' (the default) must not change a single bit — and a
    screen_alpha on an f32 index normalizes to the unscreened program."""
    data, q, w = _problem(rng, salt=30)
    bkey = jax.random.fold_in(rng, 31)
    base = Index.build(bkey, data, _cfg())
    for spec in (QuerySpec(k=5), QuerySpec(k=5, mode="multiprobe", n_probes=4),
                 QuerySpec(k=5, mode="exact")):
        r0 = base.query(q, w, spec)
        r1 = base.query(q, w, dataclasses.replace(spec, screen_alpha=2.0))
        np.testing.assert_array_equal(np.asarray(r0.ids), np.asarray(r1.ids))
        np.testing.assert_array_equal(np.asarray(r0.dists), np.asarray(r1.dists))


@pytest.mark.parametrize("family", ["theta", "l2"])
@pytest.mark.parametrize("storage", ["bf16", "int8"])
def test_candidates_are_codec_invariant(rng, family, storage):
    """Hashing runs on RAW rows before encoding, so probe/multiprobe see
    IDENTICAL candidate sets on every codec — fresh and with a delta."""
    data, q, w = _problem(rng, salt=40)
    bkey = jax.random.fold_in(rng, 41)
    f32_ix = Index.build(bkey, data, _cfg(family=family),
                         update=UpdateSpec(delta_capacity=32))
    q_ix = Index.build(bkey, data, _cfg(family=family, storage=storage),
                       update=UpdateSpec(delta_capacity=32))
    rows = jax.random.uniform(jax.random.fold_in(rng, 42), (16, D))
    f32_ix, _ = f32_ix.insert(rows)
    q_ix, _ = q_ix.insert(rows)
    specs = [QuerySpec(k=5)]
    if family == "theta":  # l2 has no multiprobe
        specs.append(QuerySpec(k=5, mode="multiprobe", n_probes=4))
    for spec in specs:
        r_f32 = f32_ix.query(q, w, spec)
        r_q = q_ix.query(q, w, spec)
        np.testing.assert_array_equal(np.asarray(r_f32.n_candidates),
                                      np.asarray(r_q.n_candidates))


@pytest.mark.parametrize("family", ["theta", "l2"])
@pytest.mark.parametrize("storage", ["bf16", "int8"])
@pytest.mark.parametrize("mode", ["probe", "multiprobe", "exact"])
def test_quantized_engine_matrix(rng, family, storage, mode):
    """Full matrix: quantized rerank ranks by the DECODED rows; against the
    f32 build the returned ids must still agree almost everywhere (the
    codecs perturb distances by <1% of the wl1 scale here)."""
    if mode == "multiprobe" and family == "l2":
        pytest.skip("l2 has no multiprobe")
    data, q, w = _problem(rng, salt=50)
    bkey = jax.random.fold_in(rng, 51)
    spec = QuerySpec(k=5, mode=mode,
                     n_probes=4 if mode == "multiprobe" else 8)
    f32_ix = Index.build(bkey, data, _cfg(family=family),
                         update=UpdateSpec(delta_capacity=32))
    q_ix = Index.build(bkey, data, _cfg(family=family, storage=storage),
                       update=UpdateSpec(delta_capacity=32))
    rows = jax.random.uniform(jax.random.fold_in(rng, 52), (16, D))
    f32_ix, _ = f32_ix.insert(rows)
    q_ix, _ = q_ix.insert(rows)
    r_f32 = f32_ix.query(q, w, spec)
    r_q = q_ix.query(q, w, spec)
    assert r_q.ids.shape == r_f32.ids.shape
    # sentinel structure must match exactly (candidate sets are identical);
    # compare the non-sentinel id SETS — codecs may reorder near-ties
    np.testing.assert_array_equal(np.asarray(r_q.ids) < 0,
                                  np.asarray(r_f32.ids) < 0)
    num = den = 0
    for ra, rb in zip(np.asarray(r_q.ids), np.asarray(r_f32.ids)):
        sa = {int(x) for x in ra if x >= 0}
        sb = {int(x) for x in rb if x >= 0}
        num += len(sa & sb)
        den += len(sb)
    overlap = num / max(den, 1)
    assert overlap >= 0.9, f"{storage}/{family}/{mode}: id overlap {overlap}"


@pytest.mark.parametrize("storage", ["bf16", "int8"])
def test_screened_query_recall(rng, storage):
    """Proxy screen + exact rerank on survivors: recall vs the f32 exact
    oracle within a point of the unscreened quantized query."""
    data, q, w = _problem(rng, b=8, salt=60)
    bkey = jax.random.fold_in(rng, 61)
    oracle = Index.build(bkey, data, _cfg()).query(q, w, QuerySpec(k=5, mode="exact"))
    q_ix = Index.build(bkey, data, _cfg(storage=storage))
    plain = q_ix.query(q, w, QuerySpec(k=5))
    screened = q_ix.query(q, w, QuerySpec(k=5, screen_alpha=4.0))
    rec_plain = recall_at_k(plain.ids, oracle.ids, 5)
    rec_screened = recall_at_k(screened.ids, oracle.ids, 5)
    assert rec_screened >= rec_plain - 0.01
    # candidate accounting is identical — screening happens after dedupe
    np.testing.assert_array_equal(np.asarray(plain.n_candidates),
                                  np.asarray(screened.n_candidates))


def test_explain_storage_accounting(rng):
    data, q, w = _problem(rng, salt=70)
    ix = Index.build(jax.random.fold_in(rng, 71), data, _cfg(storage="int8"))
    spec = QuerySpec(k=5, screen_alpha=2.0)
    rep = ix.explain(q, w, spec)
    assert rep.storage == "int8"
    assert rep.table_bytes == ix.table_bytes
    assert ix.table_bytes < N * D * 4  # compressed: payload + scales < f32
    n_cand = np.asarray(rep.rows_screened)
    assert (n_cand >= np.asarray(rep.rows_reranked)).all()
    assert (np.asarray(rep.rows_reranked) <= 10).all()  # keep = k*alpha
    assert (np.asarray(rep.bytes_gathered)
            == (n_cand + np.asarray(rep.rows_reranked)) * D).all()
    d = rep.to_dict()
    for key in ("storage", "mean_rows_screened", "mean_rows_reranked",
                "mean_bytes_gathered", "table_bytes"):
        assert key in d
    # f32 reports zero screens and full-width gathers
    f32_rep = Index.build(jax.random.fold_in(rng, 72), data, _cfg()).explain(
        q, w, QuerySpec(k=5))
    assert f32_rep.storage == "f32"
    assert (np.asarray(f32_rep.rows_screened) == 0).all()


def test_compact_reencodes_quantized_delta(rng):
    data, q, w = _problem(rng, salt=80)
    ix = Index.build(jax.random.fold_in(rng, 81), data, _cfg(storage="int8"),
                     update=UpdateSpec(delta_capacity=64))
    rows = jax.random.uniform(jax.random.fold_in(rng, 82), (48, D))
    ix, _ = ix.insert(rows)
    ix = ix.delete(jnp.arange(8, dtype=jnp.int32))
    compacted = ix.compact()
    assert compacted.n == N + 48 - 8
    assert compacted.state.data.dtype == jnp.int8
    assert compacted.state.scales is not None
    # compact renumbers ids and REFITS the scales on the merged segment, so
    # compare exact scans (same survivor rows, sorted distances) within the
    # re-quantization error budget (≤ d·max(w)·step/2)
    exact = QuerySpec(k=5, mode="exact")
    r1 = ix.query(q, w, exact)
    r2 = compacted.query(q, w, exact)
    np.testing.assert_allclose(np.asarray(r1.dists), np.asarray(r2.dists),
                               rtol=0, atol=0.1)


def test_shard_gate_names_storage(rng):
    data, _, _ = _problem(rng, salt=90)
    ix = Index.build(jax.random.fold_in(rng, 91), data, _cfg(storage="int8"))
    with pytest.raises(ValueError, match="storage"):
        ix.shard(None)


def test_bad_storage_and_alpha_are_named_errors():
    with pytest.raises(ValueError, match="storage"):
        _cfg(storage="fp8")
    with pytest.raises(ValueError, match="screen_alpha"):
        QuerySpec(k=5, screen_alpha=0.5)


# ---------------------------------------------------------------------------
# planner: alpha rungs on quantized ladders only; prior path unchanged
# ---------------------------------------------------------------------------

QUALITY = QualitySpec(k=3, recall_target=0.6, calibration_queries=8)


def test_planner_alpha_rungs_only_when_quantized(rng):
    data, _, _ = _problem(rng, salt=100)
    f32_ix = Index.build(jax.random.fold_in(rng, 101), data, _cfg())
    ladder = f32_ix.plan_ladder(QUALITY)
    assert all(r.screen_alpha == 0.0 for r in ladder)  # plan bit-parity

    q_ix = Index.build(jax.random.fold_in(rng, 101), data, _cfg(storage="int8"))
    q_plan = Planner().plan_query(q_ix, QUALITY)
    assert q_plan.provenance == "calibrated"
    # a quantized plan resolves and executes end to end
    _, q, w = _problem(rng, salt=100)
    res = q_ix.query(q, w, q_plan)
    assert res.ids.shape == (4, 3)


def test_planner_quantized_ladder_has_alpha_candidates(rng):
    data, _, _ = _problem(rng, salt=110)
    ix = Index.build(jax.random.fold_in(rng, 111), data, _cfg(storage="int8"))
    ladder = Planner()._plan_ladder(ix.config, k=3)
    alphas = {r.screen_alpha for r in ladder}
    assert 0.0 in alphas and alphas & set(Planner._SCREEN_ALPHAS)


def test_prior_planner_runs_on_quantized_index(rng):
    """Planner(table=...) — the empirical-prior path — must resolve a plan
    on a quantized index exactly as it does today (falls back to
    calibration when the profile is out of bucket; no codec crash)."""
    from repro.tuner import DataProfile, ScanSpace, build_table, run_scan
    from repro.tuner.space import AUTO_WIDTH

    space = ScanSpace(
        profiles=(DataProfile(n=N, d=D),), families=("theta",),
        K=(6,), L=(8,), W=(AUTO_WIDTH,), n_probes=(1,), window=(64,),
        k=3, queries=8,
    )
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        records = run_scan(space, os.path.join(td, "trials.jsonl"))
        table = build_table(records, space)
    data, q, w = _problem(rng, salt=120)
    ix = Index.build(jax.random.fold_in(rng, 121), data, _cfg(storage="int8"))
    plan = Planner(table=table).plan_query(ix, QUALITY)
    assert plan.provenance in ("prior", "calibrated")
    res = ix.query(q, w, plan)
    assert res.ids.shape == (4, 3)


# ---------------------------------------------------------------------------
# persistence: v5 round-trip + pre-v5 compatibility
# ---------------------------------------------------------------------------


def test_v5_roundtrip_int8_with_delta(rng, tmp_path):
    data, q, w = _problem(rng, salt=130)
    ix = Index.build(jax.random.fold_in(rng, 131), data, _cfg(storage="int8"),
                     update=UpdateSpec(delta_capacity=32))
    rows = jax.random.uniform(jax.random.fold_in(rng, 132), (16, D))
    ix, _ = ix.insert(rows)
    d = str(tmp_path / "int8")
    ix.save(d)
    meta = json.load(open(os.path.join(d, "index.json")))
    assert meta["version"] == 5
    assert meta["codec"]["storage"] == "int8"
    assert meta["config"]["storage"] == "int8"
    loaded = Index.load(d)
    assert loaded.config.storage == "int8"
    assert loaded.state.data.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(loaded.state.scales),
                                  np.asarray(ix.state.scales))
    r1 = ix.query(q, w, QuerySpec(k=5, screen_alpha=2.0))
    r2 = loaded.query(q, w, QuerySpec(k=5, screen_alpha=2.0))
    np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
    np.testing.assert_array_equal(np.asarray(r1.dists), np.asarray(r2.dists))


def test_pre_v5_directory_loads_as_f32(rng, tmp_path):
    """A directory written before the codec tier (no 'storage' key, no
    codec meta, version 4) must load exactly as an f32 index."""
    data, q, w = _problem(rng, salt=140)
    ix = Index.build(jax.random.fold_in(rng, 141), data, _cfg())
    d = str(tmp_path / "prev5")
    ix.save(d)
    meta_path = os.path.join(d, "index.json")
    meta = json.load(open(meta_path))
    meta["version"] = 4
    meta["config"].pop("storage", None)
    meta.pop("codec", None)
    with open(meta_path, "w") as fh:
        json.dump(meta, fh)
    loaded = Index.load(d)
    assert loaded.config.storage == "f32"
    r1 = ix.query(q, w, QuerySpec(k=5))
    r2 = loaded.query(q, w, QuerySpec(k=5))
    np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
    np.testing.assert_array_equal(np.asarray(r1.dists), np.asarray(r2.dists))


# ---------------------------------------------------------------------------
# serving: quantized index behind the ShardSet + serve drill entry
# ---------------------------------------------------------------------------


def test_shardset_builds_from_quantized_index(rng, tmp_path):
    from repro.serving import ShardSet

    data, q, w = _problem(rng, salt=150)
    ix = Index.build(jax.random.fold_in(rng, 151), data, _cfg(storage="int8"))
    ss = ShardSet.build(ix, 2, str(tmp_path / "shards"))
    assert ss.n_shards == 2
    for shard in ss.shards:
        assert shard.config.storage == "int8"
        assert shard.state.data.dtype == jnp.int8
