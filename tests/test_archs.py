"""Per-architecture smoke tests: reduced config, one train/prefill/decode step
on CPU, asserting output shapes and no NaNs. Exercises the exact layer-pattern
code paths of the full configs (MoE dispatch, SSD scan, shared blocks,
M-RoPE, frontends)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_bundle, list_archs, reduced_model
from repro.launch import specs

ARCHS = list_archs()
B, S = 2, 32


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(arch_id):
        if arch_id not in cache:
            cfg = reduced_model(get_bundle(arch_id).model)
            params = models.init_params(jax.random.PRNGKey(0), cfg)
            cache[arch_id] = (cfg, params)
        return cache[arch_id]

    return get


@pytest.mark.parametrize("arch_id", ARCHS)
def test_train_step_smoke(arch_state, arch_id):
    cfg, params = arch_state(arch_id)
    batch = specs.train_batch(cfg, B, S, concrete=True)
    loss = models.forward_train(params, batch, cfg)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch_id} loss = {loss}"
    # loss should be near log(vocab) at random init
    assert 0.0 < float(loss) < 3 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch_id", ARCHS)
def test_train_grads_finite(arch_state, arch_id):
    cfg, params = arch_state(arch_id)
    batch = specs.train_batch(cfg, B, S, concrete=True)
    grads = jax.grad(lambda p: models.forward_train(p, batch, cfg))(params)
    flat = jax.tree.leaves(grads)
    assert flat, arch_id
    for g in flat:
        assert np.all(np.isfinite(np.asarray(g, np.float32))), arch_id


@pytest.mark.parametrize("arch_id", ARCHS)
def test_prefill_smoke(arch_state, arch_id):
    cfg, params = arch_state(arch_id)
    batch = specs.prefill_batch(cfg, B, S, concrete=True)
    logits, caches = models.forward_prefill(params, batch, cfg)
    if cfg.encoder_only:
        assert logits.shape == (B, S, cfg.vocab_size)
        assert caches is None
    else:
        assert logits.shape == (B, cfg.vocab_size)
        assert caches is not None
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch_id


@pytest.mark.parametrize("arch_id", [a for a in ARCHS if a != "hubert-xlarge"])
def test_decode_smoke(arch_state, arch_id):
    cfg, params = arch_state(arch_id)
    caches = models.init_caches(B, S, cfg)
    batch = specs.decode_batch(cfg, B, 0, concrete=True)
    logits, next_tok, new_caches = models.forward_decode(params, batch, caches, cfg)
    assert logits.shape == (B, cfg.vocab_size)
    assert next_tok.shape == (B,)
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch_id
    # cache trees keep their structure
    assert jax.tree.structure(new_caches) == jax.tree.structure(caches)


@pytest.mark.parametrize("arch_id", [a for a in ARCHS if a != "hubert-xlarge"])
def test_prefill_decode_consistency(arch_state, arch_id):
    """Decoding after prefill must match a one-longer prefill's last logits."""
    cfg, params = arch_state(arch_id)
    if cfg.frontend == "vision":
        pytest.skip("vlm decode uses text-RoPE equivalence; covered by smoke")
    key = jax.random.PRNGKey(42)
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)

    logits_full, _ = models.forward_prefill(
        params, {"tokens": tokens}, cfg
    )  # last position of S+1 tokens

    _, caches = models.forward_prefill(
        params, {"tokens": tokens[:, :S]}, cfg, cache_len=S + 8
    )
    step = {"token": tokens[:, S], "pos": jnp.full((B,), S, jnp.int32)}
    logits_step, _, _ = models.forward_decode(params, step, caches, cfg)

    np.testing.assert_allclose(
        np.asarray(logits_step), np.asarray(logits_full), rtol=2e-2, atol=2e-2
    )
