"""ALSH index persistence: the built index (a pytree) round-trips through the
production checkpoint machinery — build once, serve from restore."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import ckpt
from repro.core import BoundedSpace, IndexConfig, build_index, query_index


def test_index_checkpoint_roundtrip(rng, tmp_path):
    n, d, M = 2000, 12, 16
    cfg = IndexConfig(d=d, M=M, K=8, L=8, family="theta", max_candidates=64,
                      space=BoundedSpace(0.0, 1.0, float(M)))
    data = jax.random.uniform(jax.random.fold_in(rng, 0), (n, d))
    idx = build_index(jax.random.fold_in(rng, 1), data, cfg)

    ckpt.save_checkpoint(str(tmp_path), 0, idx)
    idx2 = ckpt.restore_checkpoint(str(tmp_path), 0, idx)

    q = jax.random.uniform(jax.random.fold_in(rng, 2), (4, d))
    w = jnp.ones((4, d))
    r1 = query_index(idx, q, w, cfg, k=5)
    r2 = query_index(idx2, q, w, cfg, k=5)
    np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
    np.testing.assert_allclose(np.asarray(r1.dists), np.asarray(r2.dists), rtol=1e-6)
