"""ALSH index persistence: the built index (a pytree) round-trips through the
production checkpoint machinery — build once, serve from restore."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import ckpt
from repro.core import BoundedSpace, IndexConfig, build_index, query_index


def test_index_checkpoint_roundtrip(rng, tmp_path):
    n, d, M = 2000, 12, 16
    cfg = IndexConfig(d=d, M=M, K=8, L=8, family="theta", max_candidates=64,
                      space=BoundedSpace(0.0, 1.0, float(M)))
    data = jax.random.uniform(jax.random.fold_in(rng, 0), (n, d))
    idx = build_index(jax.random.fold_in(rng, 1), data, cfg)

    ckpt.save_checkpoint(str(tmp_path), 0, idx)
    idx2 = ckpt.restore_checkpoint(str(tmp_path), 0, idx)

    q = jax.random.uniform(jax.random.fold_in(rng, 2), (4, d))
    w = jnp.ones((4, d))
    r1 = query_index(idx, q, w, cfg, k=5)
    r2 = query_index(idx2, q, w, cfg, k=5)
    np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
    np.testing.assert_allclose(np.asarray(r1.dists), np.asarray(r2.dists), rtol=1e-6)


# --- torn-persistence fuzz: a damaged directory must raise a NAMED error ----
#
# Every scenario below simulates a realistic storage fault on a COMMITTED
# index directory (truncation, bit-flips, partial deletion). The contract:
# ``Index.load`` either restores the exact index or raises an error that
# names the problem — it never hands back garbage arrays. The scenarios
# exercise the ckpt decode/CRC path (CorruptCheckpointError), the COMMIT
# protocol (FileNotFoundError), and persist._check_consistent (ValueError).


def _saved_index(rng, tmp_path, name="idx"):
    from repro.api import Index, IndexConfig, UpdateSpec

    cfg = IndexConfig(d=8, M=16, K=6, L=4, family="theta", max_candidates=32,
                      space=BoundedSpace(0.0, 1.0, 16.0))
    data = jax.random.uniform(jax.random.fold_in(rng, 0), (256, 8))
    index = Index.build(jax.random.fold_in(rng, 1), data, cfg,
                        update=UpdateSpec(delta_capacity=32))
    d = str(tmp_path / name)
    index.save(d)
    return index, d


def _payload_files(d):
    import glob
    import os

    files = sorted(glob.glob(os.path.join(d, "step_*", "shard_*")))
    assert files, f"no committed payload under {d}"
    return files


def test_truncated_payload_raises_named_error(rng, tmp_path):
    import pytest

    from repro.api import Index

    _, d = _saved_index(rng, tmp_path)
    f = _payload_files(d)[0]
    blob = open(f, "rb").read()
    with open(f, "wb") as fh:
        fh.write(blob[: len(blob) // 2])
    with pytest.raises(ckpt.CorruptCheckpointError, match="corrupt"):
        Index.load(d)


def test_bitflipped_payload_raises_named_error(rng, tmp_path):
    """Flip single bytes at several offsets — every corruption must be
    caught by the decompress/unpack guard or the per-leaf CRC, never loaded
    silently (ValueError from a shape mismatch is also acceptable: still a
    named refusal, not garbage)."""
    import pytest

    from repro.api import Index

    _, d0 = _saved_index(rng, tmp_path)
    blob = open(_payload_files(d0)[0], "rb").read()
    for i, frac in enumerate((0.1, 0.5, 0.9)):
        _, d = _saved_index(rng, tmp_path, name=f"flip{i}")
        f = _payload_files(d)[0]
        pos = int(len(blob) * frac)
        mut = bytearray(blob)
        mut[pos] ^= 0xFF
        with open(f, "wb") as fh:
            fh.write(bytes(mut))
        with pytest.raises((ckpt.CorruptCheckpointError, ValueError)):
            Index.load(d)


def test_missing_shard_with_commit_raises(rng, tmp_path):
    import os

    import pytest

    from repro.api import Index

    _, d = _saved_index(rng, tmp_path)
    os.remove(_payload_files(d)[0])  # COMMIT survives, payload does not
    with pytest.raises(FileNotFoundError, match="shard"):
        Index.load(d)


def test_missing_commit_is_an_aborted_save(rng, tmp_path):
    import glob
    import os

    import pytest

    from repro.api import Index

    _, d = _saved_index(rng, tmp_path)
    for c in glob.glob(os.path.join(d, "step_*", "COMMIT")):
        os.remove(c)  # uncommitted step == crash mid-save
    with pytest.raises(FileNotFoundError, match="committed"):
        Index.load(d)


def test_missing_meta_raises(rng, tmp_path):
    import os

    import pytest

    from repro.api import Index

    _, d = _saved_index(rng, tmp_path)
    os.remove(os.path.join(d, "index.json"))
    with pytest.raises(FileNotFoundError, match="index directory"):
        Index.load(d)


def test_meta_payload_mismatch_raises(rng, tmp_path):
    """Overwrite the meta with a DIFFERENT geometry (a torn overwrite of an
    existing directory): _check_consistent must reject the pairing."""
    import json
    import os

    import pytest

    from repro.api import Index

    _, d = _saved_index(rng, tmp_path)
    meta_path = os.path.join(d, "index.json")
    meta = json.load(open(meta_path))
    meta["config"]["L"] = meta["config"]["L"] * 2
    with open(meta_path, "w") as fh:
        json.dump(meta, fh)
    with pytest.raises(ValueError, match="does not describe the stored arrays"):
        Index.load(d)


def test_intact_directory_still_loads_after_fuzz_suite(rng, tmp_path):
    """Control: an undamaged directory restores bit-identically."""
    import numpy as np

    from repro.api import Index, QuerySpec

    index, d = _saved_index(rng, tmp_path)
    loaded = Index.load(d)
    q = jax.random.uniform(jax.random.fold_in(rng, 5), (4, 8))
    w = jnp.ones((4, 8))
    r1 = index.query(q, w, QuerySpec(k=5))
    r2 = loaded.query(q, w, QuerySpec(k=5))
    np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
    np.testing.assert_array_equal(np.asarray(r1.dists), np.asarray(r2.dists))


# --- v5 (quantized storage) fuzz: codec + scales are covered too ------------
#
# The v5 manifest adds a codec entry and (for scaled codecs) a decode-scale
# leaf to the payload. The same contract extends: damage to the int8 block,
# the scales, or the codec bookkeeping must raise a NAMED error.


def _saved_int8_index(rng, tmp_path, name="q_idx"):
    from repro.api import Index, IndexConfig, UpdateSpec

    cfg = IndexConfig(d=8, M=16, K=6, L=4, family="theta", max_candidates=32,
                      space=BoundedSpace(0.0, 1.0, 16.0), storage="int8")
    data = jax.random.uniform(jax.random.fold_in(rng, 0), (256, 8))
    index = Index.build(jax.random.fold_in(rng, 1), data, cfg,
                        update=UpdateSpec(delta_capacity=32))
    d = str(tmp_path / name)
    index.save(d)
    return index, d


def test_bitflipped_int8_payload_raises_named_error(rng, tmp_path):
    """A flipped byte inside the committed int8 block (or its scales — one
    CRC-guarded blob) must be caught, never decoded into a skewed table."""
    import pytest

    from repro.api import Index

    _, d = _saved_int8_index(rng, tmp_path)
    f = _payload_files(d)[0]
    blob = bytearray(open(f, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    with open(f, "wb") as fh:
        fh.write(bytes(blob))
    with pytest.raises((ckpt.CorruptCheckpointError, ValueError)):
        Index.load(d)


def test_codec_manifest_mismatch_raises_named_error(rng, tmp_path):
    """config.storage edited to f32 over an int8 payload — a torn overwrite
    shape; _check_consistent must name the codec mix."""
    import json
    import os

    import pytest

    from repro.api import Index

    _, d = _saved_int8_index(rng, tmp_path)
    meta_path = os.path.join(d, "index.json")
    meta = json.load(open(meta_path))
    meta["config"]["storage"] = "f32"
    with open(meta_path, "w") as fh:
        json.dump(meta, fh)
    with pytest.raises(ValueError, match="mixes codecs"):
        Index.load(d)


def test_meta_internal_codec_mismatch_raises_named_error(rng, tmp_path):
    """The manifest's codec entry contradicting its own config is an
    internally inconsistent file, refused by name."""
    import json
    import os

    import pytest

    from repro.api import Index

    _, d = _saved_int8_index(rng, tmp_path)
    meta_path = os.path.join(d, "index.json")
    meta = json.load(open(meta_path))
    meta["codec"]["storage"] = "bf16"
    with open(meta_path, "w") as fh:
        json.dump(meta, fh)
    with pytest.raises(ValueError, match="internally inconsistent"):
        Index.load(d)


def test_truncated_scales_raise_named_error(rng, tmp_path):
    """A scale vector that lost dimensions (torn partial write) is refused
    by _check_consistent even if the blob itself decodes."""
    import dataclasses
    import json
    import os

    import pytest

    from repro.api import persist

    index, d = _saved_int8_index(rng, tmp_path)
    meta = json.load(open(os.path.join(d, "index.json")))
    torn = dataclasses.replace(index.state, scales=index.state.scales[:3])
    with pytest.raises(ValueError, match="missing or truncated"):
        persist._check_consistent(torn, index.delta, index.tombstones,
                                  index.config, index.update, meta,
                                  os.path.join(d, "index.json"))


def test_intact_int8_directory_roundtrips_bit_identically(rng, tmp_path):
    """Control for the v5 scenarios: the undamaged quantized directory
    restores codec, scales, and query results exactly."""
    import numpy as np

    from repro.api import Index, QuerySpec

    index, d = _saved_int8_index(rng, tmp_path)
    loaded = Index.load(d)
    assert loaded.config.storage == "int8"
    assert loaded.state.data.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(loaded.state.scales),
                                  np.asarray(index.state.scales))
    q = jax.random.uniform(jax.random.fold_in(rng, 5), (4, 8))
    w = jnp.ones((4, 8))
    r1 = index.query(q, w, QuerySpec(k=5, screen_alpha=2.0))
    r2 = loaded.query(q, w, QuerySpec(k=5, screen_alpha=2.0))
    np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
    np.testing.assert_array_equal(np.asarray(r1.dists), np.asarray(r2.dists))
