"""Offline autotuner: scan space, crash-safe store, Pareto table, prior.

Contracts under test (ISSUE 7 acceptance):
  * Pareto edge cases: dominance ties, single-point frontiers, duplicate
    non-dominated trials collapsing deterministically
  * scan resume-from-partial completes the grid with no duplicate/missing
    trials and a BIT-IDENTICAL frontier artifact
  * worker-process fan-out measures the same deterministic metrics as the
    inline path
  * prior-vs-calibrated parity on an in-bucket profile (provenance="prior",
    adherence within the bar, plan is a first-class bit-identical spec)
  * with no table or an out-of-bucket profile, planning is bit-identical
    to the table-less calibrated path
  * tuning provenance rides the v4 persistence manifest (v3 still loads)
"""

import dataclasses
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    BoundedSpace,
    Index,
    IndexConfig,
    Planner,
    QualitySpec,
    QuerySpec,
)
from repro.tuner import (
    DataProfile,
    ScanSpace,
    TrialStore,
    TrialSpec,
    TuningTable,
    build_table,
    grid,
    log_range,
    pareto_front,
    run_scan,
    run_trial,
    scan_is_complete,
    seeded_choice,
)
from repro.tuner.pareto import dominates
from repro.tuner.space import AUTO_WIDTH

# one small space shared by the scan/table/prior tests: 6 trials at n=400
SPACE = ScanSpace(
    profiles=(DataProfile(n=400, d=6),),
    families=("theta", "l2"),
    K=(3, 4),
    L=(8,),
    W=(AUTO_WIDTH,),
    n_probes=(1, 2),
    window=(64,),
    k=3,
    queries=8,
)
QUALITY = QualitySpec(k=3, recall_target=0.6, calibration_queries=8)


def _rec(trial_id, recall, cost, mem=100, **kw):
    return {
        "trial_id": trial_id, "status": "ok", "recall": recall, "cost": cost,
        "mem_bytes": mem, **kw,
    }


@pytest.fixture(scope="module")
def scanned(tmp_path_factory):
    """One full single-shot scan + its table (the reference artifact)."""
    store = tmp_path_factory.mktemp("tuner") / "trials.jsonl"
    records = run_scan(SPACE, store)
    return store, records, build_table(records, SPACE)


# ---------------------------------------------------------------------------
# space: axis helpers + enumeration rules
# ---------------------------------------------------------------------------


def test_axis_helpers():
    assert grid(3, 1, 3, 2) == (3, 1, 2)
    assert log_range(4, 64, 3) == (4, 16, 64)
    assert log_range(8, 8, 1) == (8,)
    with pytest.raises(ValueError, match="log_range"):
        log_range(0, 8, 2)
    picked = seeded_choice(range(100), 5, seed=3)
    assert picked == seeded_choice(range(100), 5, seed=3)  # deterministic
    assert len(picked) == 5 and set(picked) <= set(range(100))
    assert picked != seeded_choice(range(100), 5, seed=4)
    assert seeded_choice((1, 2), 9) == (1, 2)  # num covers the axis


def test_profile_and_space_validation():
    with pytest.raises(ValueError, match="source"):
        DataProfile(n=10, d=2, source="mystery")
    with pytest.raises(ValueError, match="skew"):
        DataProfile(n=10, d=2, skew=0.0)
    with pytest.raises(ValueError, match="profiles"):
        ScanSpace(profiles=())
    with pytest.raises(ValueError, match="unknown hash family"):
        ScanSpace(profiles=(DataProfile(n=10, d=2),), families=("nope",))


def test_trial_enumeration_collapses_duplicates():
    # theta ignores W: two W values must not double the theta grid
    s = dataclasses.replace(SPACE, families=("theta",), W=(2.0, 8.0))
    trials = s.trials()
    assert len(trials) == 4  # 2 K x 1 L x 2 probes
    assert all(t.W == 4.0 for t in trials)
    # l2 has no probing: n_probes collapses to 1
    s = dataclasses.replace(SPACE, families=("l2",))
    trials = s.trials()
    assert len(trials) == 2 and all(t.n_probes == 1 for t in trials)
    # theta's K cap (31) drops oversized K; window < k drops the point
    s = dataclasses.replace(SPACE, families=("theta",), K=(3, 40), window=(2, 64))
    assert all(t.K == 3 and t.window == 64 for t in s.trials())


def test_trial_ids_content_addressed():
    t = SPACE.trials()[0]
    again = TrialSpec.from_dict(t.to_dict())
    assert again == t and again.trial_id == t.trial_id
    assert t.seed == again.seed
    other = dataclasses.replace(t, L=t.L + 1)
    assert other.trial_id != t.trial_id
    # space round-trips (and its id with it)
    assert ScanSpace.from_dict(SPACE.to_dict()).space_id == SPACE.space_id


# ---------------------------------------------------------------------------
# pareto: dominance edge cases
# ---------------------------------------------------------------------------


def test_dominates_edge_cases():
    a = _rec("a", recall=0.9, cost=10)
    b = _rec("b", recall=0.8, cost=20)
    tie = _rec("t", recall=0.9, cost=10)
    assert dominates(a, b) and not dominates(b, a)
    assert not dominates(a, tie) and not dominates(tie, a)  # full tie: neither
    assert not dominates(a, a)  # irreflexive


def test_pareto_single_point_frontier():
    only = _rec("x", recall=0.5, cost=99)
    assert pareto_front([only]) == [only]
    assert pareto_front([]) == []


def test_pareto_duplicate_nondominated_collapse():
    """Exact objective duplicates collapse to the smallest trial_id — the
    frontier cannot depend on store insertion order."""
    r1 = _rec("bbbb", recall=0.9, cost=10)
    r2 = _rec("aaaa", recall=0.9, cost=10)
    for order in ([r1, r2], [r2, r1]):
        front = pareto_front(order)
        assert [r["trial_id"] for r in front] == ["aaaa"]


def test_pareto_partial_ties_both_survive():
    a = _rec("a", recall=0.9, cost=10, mem=100)
    b = _rec("b", recall=0.9, cost=20, mem=50)  # worse cost, better memory
    c = _rec("c", recall=0.8, cost=25, mem=60)  # dominated by b
    bad = _rec("d", recall=1.0, cost=0, mem=0, status="skipped")
    front = pareto_front([a, b, c, bad])
    assert [r["trial_id"] for r in front] == ["a", "b"]


# ---------------------------------------------------------------------------
# scan: store crash-safety + resume bit-identity
# ---------------------------------------------------------------------------


def test_store_tolerates_torn_trailing_line(tmp_path, scanned):
    src, records, _ = scanned
    store = TrialStore(tmp_path / "torn.jsonl")
    store.write_header(SPACE)
    store.append(records[0])
    with open(store.path, "a") as f:
        f.write('{"trial_id": "abc", "trunc')  # mid-write crash artifact
    loaded = store.load(SPACE)
    assert set(loaded) == {records[0]["trial_id"]}


def test_store_rejects_interior_corruption_and_alien_space(tmp_path, scanned):
    _, records, _ = scanned
    store = TrialStore(tmp_path / "corrupt.jsonl")
    store.write_header(SPACE)
    with open(store.path, "a") as f:
        f.write("not json\n")
    store.append(records[0])
    with pytest.raises(ValueError, match="corrupt"):
        store.load(SPACE)

    other = TrialStore(tmp_path / "alien.jsonl")
    other.write_header(dataclasses.replace(SPACE, base_seed=9))
    with pytest.raises(ValueError, match="fresh store"):
        other.load(SPACE)
    # alien trial ids behind a matching header fail in run_scan
    bad = TrialStore(tmp_path / "alien_ids.jsonl")
    bad.write_header(SPACE)
    bad.append({"trial_id": "f" * 16, "status": "ok"})
    with pytest.raises(ValueError, match="not in this scan space"):
        run_scan(SPACE, bad.path)


def test_resume_completes_grid_bit_identically(tmp_path, scanned):
    """Kill-and-resume drill: a partial store (budget-stopped, then torn)
    resumes to the full grid with no duplicate/missing trials and a
    byte-identical tuning table."""
    _, _, reference = scanned
    store = tmp_path / "partial.jsonl"
    first = run_scan(SPACE, store, max_trials=2)
    assert len(first) == 2 and not scan_is_complete(SPACE, store)
    with open(store, "a") as f:
        f.write('{"torn')  # the crash artifact resume must shrug off

    records = run_scan(SPACE, store)
    assert scan_is_complete(SPACE, store)
    want_ids = [t.trial_id for t in SPACE.trials()]
    assert [r["trial_id"] for r in records] == want_ids
    # store file holds each trial exactly once (no duplicate work recorded)
    # and the resume truncated the torn line instead of burying it
    with open(store) as f:
        lines = [ln for ln in f.read().splitlines() if ln.strip()]
    stored = [json.loads(ln)["trial_id"] for ln in lines[1:]]
    assert sorted(stored) == sorted(want_ids)

    resumed_table = build_table(records, SPACE)
    assert json.dumps(resumed_table.to_dict(), sort_keys=True) == json.dumps(
        reference.to_dict(), sort_keys=True
    )


def test_rerun_trial_is_deterministic(scanned):
    _, records, _ = scanned
    again = run_trial(records[0]["trial"])
    for key in ("recall", "cand_frac", "cost", "mem_bytes", "W"):
        assert again[key] == records[0][key], key


def test_worker_pool_matches_inline(tmp_path):
    """Spawned workers (fresh jax runtimes) must reproduce the inline
    metrics — the store is content-addressed, not process-addressed."""
    tiny = ScanSpace(
        profiles=(DataProfile(n=64, d=4),), families=("theta",),
        K=(3, 4), L=(4,), n_probes=(1,), window=(16,), k=2, queries=4,
    )
    inline = run_scan(tiny, tmp_path / "inline.jsonl")
    pooled = run_scan(tiny, tmp_path / "pooled.jsonl", workers=2)
    for a, b in zip(inline, pooled):
        for key in ("trial_id", "recall", "cand_frac", "cost", "mem_bytes"):
            assert a[key] == b[key], key


# ---------------------------------------------------------------------------
# table: artifact + lookup
# ---------------------------------------------------------------------------


def test_table_roundtrip_and_version_gate(tmp_path, scanned):
    _, _, table = scanned
    path = table.save(tmp_path / "tuning_table.json")
    loaded = TuningTable.load(path)
    assert loaded.to_dict() == table.to_dict()
    assert loaded.provenance()["space_id"] == SPACE.space_id

    doc = loaded.to_dict()
    doc["version"] = 99
    (tmp_path / "bad.json").write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="version"):
        TuningTable.load(tmp_path / "bad.json")
    doc["format"] = "something.else"
    (tmp_path / "worse.json").write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="not a tuning table"):
        TuningTable.load(tmp_path / "worse.json")


def test_nearest_bucket_tolerances(scanned):
    _, _, table = scanned
    assert table.nearest_bucket("theta", 400, 6) is not None
    assert table.nearest_bucket("theta", 700, 6) is not None  # within 2x rows
    assert table.nearest_bucket("theta", 4000, 6) is None  # log2 gap > 1
    assert table.nearest_bucket("theta", 400, 7) is None  # d must match
    assert table.nearest_bucket("theta", 400, 6, skew=2.0) is None
    assert table.nearest_bucket(None, 400, 6) is not None  # family=auto

    bucket = table.nearest_bucket("theta", 400, 6)
    assert TuningTable.best_entry(bucket, recall_target=2.0) is None
    best = TuningTable.best_entry(bucket, recall_target=0.0)
    assert best == min(bucket["entries"], key=lambda e: (e["cost"], e["trial_id"]))


# ---------------------------------------------------------------------------
# planner integration: prior vs calibrated
# ---------------------------------------------------------------------------


def _workload(rng, n=400, d=6, b=4, salt=200):
    data = jax.random.uniform(jax.random.fold_in(rng, salt), (n, d))
    q = jax.random.uniform(jax.random.fold_in(rng, salt + 1), (b, d))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(rng, salt + 2), (b, d))) + 0.2
    return data, q, w


def test_prior_plan_parity_in_bucket(scanned, rng):
    _, _, table = scanned
    data, q, w = _workload(rng)
    key = jax.random.fold_in(rng, 210)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        prior_ix = Index.build(key, data, QUALITY, planner=Planner(table=table))
        calib_ix = Index.build(key, data, QUALITY)
    p_plan, c_plan = prior_ix.plans[QUALITY], calib_ix.plans[QUALITY]
    assert p_plan.provenance == "prior"
    assert c_plan.provenance == "calibrated"
    assert prior_ix.tuning == table.provenance()
    assert calib_ix.tuning is None
    # parity: both paths meet the stated target within the adherence bar on
    # their own calibration evidence
    bar = QUALITY.recall_target - 0.02
    assert p_plan.predicted_recall >= bar
    assert c_plan.predicted_recall >= bar
    # a prior plan is a first-class spec: quality-spec and resolved-plan
    # queries are bit-identical
    via_quality = prior_ix.query(q, w, QUALITY)
    via_plan = prior_ix.query(q, w, p_plan)
    np.testing.assert_array_equal(np.asarray(via_quality.ids), np.asarray(via_plan.ids))
    np.testing.assert_array_equal(np.asarray(via_quality.dists), np.asarray(via_plan.dists))


def test_explain_stamps_provenance_and_plan_time(scanned, rng):
    _, _, table = scanned
    data, q, w = _workload(rng, salt=230)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        index = Index.build(
            jax.random.fold_in(rng, 231), data, QUALITY,
            planner=Planner(table=table),
        )
    report = index.explain(q, w, QUALITY)
    assert report.provenance == "prior"
    assert report.plan_build_s is not None and report.plan_build_s > 0.0
    assert report.to_dict()["provenance"] == "prior"
    # mechanism specs carry no planning metadata
    raw = index.explain(q, w, QuerySpec(k=3))
    assert raw.provenance is None and raw.plan_build_s is None


def test_out_of_bucket_falls_back_bit_identically(scanned, rng):
    """With the profile outside every bucket (d mismatch) the table-backed
    planner must resolve the SAME plan a table-less planner does."""
    _, _, table = scanned
    data, _, _ = _workload(rng, d=5, salt=240)
    cfg = IndexConfig(
        d=5, M=8, K=4, L=8, family="theta", max_candidates=64,
        space=BoundedSpace(0.0, 1.0, 8.0),
    )
    index = Index.build(jax.random.fold_in(rng, 241), data, cfg)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with_table = Planner(table=table).plan_query(index, QUALITY)
        bare = Planner().plan_query(index, QUALITY)
    assert with_table == bare
    assert with_table.provenance == "calibrated"
    # build-time geometry derivation falls back identically too
    cfg_a = Planner(table=table).plan_config(data, QUALITY)
    cfg_b = Planner().plan_config(data, QUALITY)
    assert cfg_a == cfg_b


def test_no_table_is_the_default_path(rng):
    """Planner() with no table is exactly yesterday's planner (guards the
    bit-identical-fallback acceptance criterion at the API level)."""
    data, _, _ = _workload(rng, salt=250)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        a = Index.build(jax.random.fold_in(rng, 251), data, QUALITY)
        b = Index.build(jax.random.fold_in(rng, 251), data, QUALITY,
                        planner=Planner(table=None))
    assert a.plans[QUALITY] == b.plans[QUALITY]
    assert a.config == b.config


# ---------------------------------------------------------------------------
# persistence: tuning provenance in the v4 manifest
# ---------------------------------------------------------------------------


def test_tuning_provenance_survives_save_load(scanned, rng, tmp_path):
    _, _, table = scanned
    data, q, w = _workload(rng, salt=260)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        index = Index.build(
            jax.random.fold_in(rng, 261), data, QUALITY,
            planner=Planner(table=table),
        )
    assert index.plans[QUALITY].provenance == "prior"
    index.save(str(tmp_path))

    meta = json.loads((tmp_path / "index.json").read_text())
    assert meta["version"] == 5
    assert meta["tuning"] == table.provenance()

    restored = Index.load(str(tmp_path))
    assert restored.tuning == table.provenance()
    assert restored.plans[QUALITY] == index.plans[QUALITY]  # provenance too
    want = index.query(q, w, QUALITY)
    got = restored.query(q, w, QUALITY)
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(want.ids))


def test_v3_directories_load_without_tuning(scanned, rng, tmp_path):
    _, _, table = scanned
    data, _, _ = _workload(rng, salt=270)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        index = Index.build(
            jax.random.fold_in(rng, 271), data, QUALITY,
            planner=Planner(table=table),
        )
    index.save(str(tmp_path))
    meta_path = tmp_path / "index.json"
    meta = json.loads(meta_path.read_text())
    meta["version"] = 3
    del meta["tuning"]
    meta_path.write_text(json.dumps(meta))
    restored = Index.load(str(tmp_path))
    assert restored.tuning is None
    assert restored.plans == index.plans
