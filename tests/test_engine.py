"""Candidate-stream execution engine: parity, kernels, and contracts.

The load-bearing claim of the engine refactor is that ONE pipeline
(key enumeration → candidate sources → merge/dedupe/mask → fused
gather/rerank/top-k) reproduces every pre-refactor query path BIT FOR BIT.
``_legacy_query`` below reimplements the superseded pipeline verbatim —
per-mode probe front-ends, the dense (b, L, P, cap) delta key match, the
per-batch (n_main + cap, d) concatenated row table, the single-table fused
tail — and the suite asserts the engine matches it exactly across
probe/multiprobe/exact × fresh/segmented/tombstoned × both hash families,
plus the sharded service against its single-host twin.

Also pinned here: the two-segment gather kernels against the concatenated
table on every backend schedule, the chunked delta match against the dense
formulation, the sentinel contract (ids == -1 ⇔ dists == +inf), and the
no-retrace-across-fill-levels jit guarantee carried over from
tests/test_lifecycle.py.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.api import (
    BoundedSpace,
    Index,
    IndexConfig,
    QuerySpec,
    UpdateSpec,
)
from repro.core import transforms
from repro.core.index import (
    DeltaSegment,
    QueryResult,
    _dedupe_candidates,
    _delta_candidates,
    _keys_for,
    _mask_dead,
    _probe_one_table,
    delta_live_mask,
)
from repro.core.multiprobe import multiprobe_keys_for
from repro.kernels import ops

N = 400
D = 8
CAP = 64


def _cfg(family="theta", **kw):
    kw.setdefault("max_candidates", N + CAP)  # no window truncation (parity)
    kw.setdefault("space", BoundedSpace(0.0, 1.0, 8.0))
    kw.setdefault("W", 8.0)
    return IndexConfig(d=D, M=8, K=6, L=10, family=family, **kw)


def _problem(rng, salt=0, m=37, b=5):
    data = jax.random.uniform(jax.random.fold_in(rng, salt), (N, D))
    extra = jax.random.uniform(jax.random.fold_in(rng, salt + 1), (m, D))
    q = jax.random.uniform(jax.random.fold_in(rng, salt + 2), (b, D))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(rng, salt + 3), (b, D))) + 0.2
    return data, extra, q, w


def _index_for(rng, data, extra, family, lifecycle):
    """fresh (immutable) | delta (inserts only) | churn (inserts + deletes
    in both segments)."""
    bkey = jax.random.fold_in(rng, 9)
    if lifecycle == "fresh":
        return Index.build(bkey, data, _cfg(family=family))
    index = Index.build(
        bkey, data, _cfg(family=family), update=UpdateSpec(delta_capacity=CAP)
    )
    index, ids = index.insert(extra)
    if lifecycle == "churn":
        index = index.delete(jnp.asarray([0, 5, 17, int(ids[3]), int(ids[11])], jnp.int32))
    return index


def _legacy_query(index: Index, queries, weights, spec: QuerySpec) -> QueryResult:
    """The PRE-REFACTOR pipeline, reimplemented verbatim: this is what
    query_index / query_multiprobe / query_*_segmented / the facade
    computed before the engine existed. The engine must match bit for bit."""
    state, cfg = index.state, index.config
    n_main = state.n
    b = queries.shape[0]
    if index.mutable:
        cap = index.delta.capacity
        n_tot = n_main + cap
        table = jnp.concatenate(
            [state.data, index.delta.data.astype(state.data.dtype)], axis=0
        )
        tombstones = index.tombstones
    else:
        cap, n_tot, table, tombstones = 0, n_main, state.data, None

    if spec.mode == "exact":
        if not index.mutable:
            dists, ids = ops.wl1_scan_topk(state.data, queries, weights, spec.k)
            return QueryResult(dists, ids, jnp.full(b, n_main, jnp.int32))
        live = ~tombstones[:n_main]
        if cap:
            live = jnp.concatenate(
                [live, delta_live_mask(index.delta, tombstones, n_main)]
            )
        ids_row = jnp.where(live, jnp.arange(n_tot, dtype=jnp.int32), n_tot)
        cand = jnp.broadcast_to(jnp.sort(ids_row)[None, :], (b, n_tot))
        dists, ids = ops.gather_rerank_topk(table, cand, queries, weights, spec.k)
        n_candidates = jnp.broadcast_to(jnp.sum(live).astype(jnp.int32), (b,))
        return QueryResult(dists, ids, n_candidates)

    if spec.mode == "multiprobe":
        keys = multiprobe_keys_for(
            state, queries, weights, cfg, spec.n_probes, spec.max_flips
        )  # (b, L, P)
    else:
        qlevels = transforms.discretize(queries, cfg.space)
        keys = _keys_for(qlevels, weights, state.tables, cfg, state.mixers)[:, :, None]

    probe = jax.vmap(
        jax.vmap(
            jax.vmap(_probe_one_table, in_axes=(None, None, 0, None)),
            in_axes=(0, 0, 0, None),
        ),
        in_axes=(None, None, 0, None),
    )
    cand = probe(state.sorted_keys, state.perm, keys, cfg.max_candidates)
    cand = cand.reshape(b, -1)
    if index.mutable:
        cand = _mask_dead(cand, tombstones, n_main, n_tot)
        if cap:
            live = delta_live_mask(index.delta, tombstones, n_main)
            # the DENSE (b, L, P, cap) key match the chunked engine replaced
            match = jnp.any(
                keys[:, :, :, None] == index.delta.keys[None, :, None, :], axis=(1, 2)
            )
            slot_ids = n_main + jnp.arange(cap, dtype=jnp.int32)
            dcand = jnp.where(match & live[None, :], slot_ids[None, :], n_tot).astype(
                jnp.int32
            )
            cand = jnp.concatenate([cand, dcand], axis=1)
    cand, n_candidates = _dedupe_candidates(cand, n_tot)
    dists, ids = ops.gather_rerank_topk(table, cand, queries, weights, spec.k)
    return QueryResult(dists, ids, n_candidates)


def _assert_bit_identical(got: QueryResult, want: QueryResult):
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(want.ids))
    np.testing.assert_array_equal(np.asarray(got.dists), np.asarray(want.dists))
    np.testing.assert_array_equal(
        np.asarray(got.n_candidates), np.asarray(want.n_candidates)
    )


# ---------------------------------------------------------------------------
# engine == pre-refactor pipeline, the full matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["theta", "l2"])
@pytest.mark.parametrize("mode", ["probe", "multiprobe", "exact"])
@pytest.mark.parametrize("lifecycle", ["fresh", "delta", "churn"])
def test_engine_matches_legacy_pipeline(rng, family, mode, lifecycle):
    if family == "l2" and mode == "multiprobe":
        pytest.skip("l2 family does not support multiprobe")
    data, extra, q, w = _problem(rng)
    index = _index_for(rng, data, extra, family, lifecycle)
    spec = QuerySpec(k=7, mode=mode)
    _assert_bit_identical(
        index.query(q, w, spec), _legacy_query(index, q, w, spec)
    )


@pytest.mark.parametrize("family", ["theta", "l2"])
def test_legacy_entry_points_are_engine_backed(rng, family):
    """The five core entry points are thin wrappers: their results must be
    bit-identical to the facade (same compiled engine underneath)."""
    from repro.core.index import (
        query_exact_segmented,
        query_index,
        query_index_segmented,
    )
    from repro.core.multiprobe import query_multiprobe, query_multiprobe_segmented

    data, extra, q, w = _problem(rng)
    cfg = _cfg(family=family)
    imm = _index_for(rng, data, extra, family, "fresh")
    mut = _index_for(rng, data, extra, family, "churn")
    k = 7
    _assert_bit_identical(
        query_index(imm.state, q, w, cfg, k=k),
        imm.query(q, w, QuerySpec(k=k)),
    )
    _assert_bit_identical(
        query_index_segmented(mut.state, mut.delta, mut.tombstones, q, w, cfg, k=k),
        mut.query(q, w, QuerySpec(k=k)),
    )
    _assert_bit_identical(
        query_exact_segmented(mut.state, mut.delta, mut.tombstones, q, w, k=k),
        mut.query(q, w, QuerySpec(k=k, mode="exact")),
    )
    if family == "theta":
        _assert_bit_identical(
            query_multiprobe(imm.state, q, w, cfg, k=k),
            imm.query(q, w, QuerySpec(k=k, mode="multiprobe")),
        )
        _assert_bit_identical(
            query_multiprobe_segmented(
                mut.state, mut.delta, mut.tombstones, q, w, cfg, k=k
            ),
            mut.query(q, w, QuerySpec(k=k, mode="multiprobe")),
        )


def test_core_deprecation_shims_still_warn(rng):
    """Satellite contract: the repro.core package-level shims now reach the
    engine-backed facade paths but must keep their DeprecationWarning."""
    import repro.core as core

    data, _, q, w = _problem(rng)
    cfg = _cfg()
    with pytest.warns(DeprecationWarning, match="repro.api.Index.build"):
        state = core.build_index(jax.random.fold_in(rng, 9), data, cfg)
    with pytest.warns(DeprecationWarning, match="repro.api.Index.query"):
        res = core.query_index(state, q, w, cfg, k=3)
    assert res.ids.shape == (5, 3)
    with pytest.warns(DeprecationWarning, match="multiprobe"):
        core.query_multiprobe(state, q, w, cfg, k=3)


# ---------------------------------------------------------------------------
# chunked delta key match == dense formulation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cap", [1, 64, 130, 1500])
@pytest.mark.parametrize("P", [1, 4])
def test_delta_chunked_match_equals_dense(rng, cap, P):
    """The fori_loop-chunked key match (any block size, capacity not a
    block multiple) reproduces the dense (b, L, P, cap) comparison."""
    L, b, n_main = 6, 7, 100
    kk = jax.random.fold_in(rng, cap * 10 + P)
    # draw keys from a small alphabet so real collisions occur
    dkeys = jax.random.randint(jax.random.fold_in(kk, 0), (L, cap), 0, 13, dtype=jnp.int32)
    pk = jax.random.randint(jax.random.fold_in(kk, 1), (b, L, P), 0, 13, dtype=jnp.int32)
    live = jax.random.bernoulli(jax.random.fold_in(kk, 2), 0.8, (cap,))
    delta = DeltaSegment(
        data=jnp.zeros((cap, D)),
        levels=jnp.zeros((cap, D), jnp.int32),
        keys=dkeys,
        fill=jnp.asarray(cap, jnp.int32),
    )
    sentinel = n_main + cap
    dense_match = jnp.any(pk[:, :, :, None] == dkeys[None, :, None, :], axis=(1, 2))
    slot_ids = n_main + jnp.arange(cap, dtype=jnp.int32)
    want = jnp.where(dense_match & live[None, :], slot_ids[None, :], sentinel)
    for block in (32, 1024):
        got = _delta_candidates(pk, delta, live, n_main, sentinel, block=block)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert np.asarray(dense_match).any(), "degenerate test: no collisions"


# ---------------------------------------------------------------------------
# two-segment fused gather == concatenated-table gather, every schedule
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("force", ["auto", "chunked", "ref", "interpret"])
@pytest.mark.parametrize("shape", [(100, 40, 64, 3), (600, 300, 777, 10)])
def test_segmented_gather_matches_concat_table(rng, force, shape):
    """ops.gather_rerank_topk(main, ids, ..., delta=delta) must be
    bit-identical to the single-table call over concat([main, delta]) on
    every backend schedule (incl. the Pallas kernel in interpret mode) —
    ids mixing both segments, duplicates-as-sentinels, and k > #valid."""
    n_main, cap, P, k = shape
    d, b = 16, 4
    kk = jax.random.fold_in(rng, n_main)
    main = jax.random.uniform(jax.random.fold_in(kk, 0), (n_main, d))
    delta = jax.random.uniform(jax.random.fold_in(kk, 1), (cap, d))
    q = jax.random.uniform(jax.random.fold_in(kk, 2), (b, d))
    w = jax.random.normal(jax.random.fold_in(kk, 3), (b, d))  # negative weights too
    n_tot = n_main + cap
    ids = jax.random.randint(
        jax.random.fold_in(kk, 4), (b, P), 0, n_tot + n_tot // 3, dtype=jnp.int32
    )  # ~25% sentinels
    ids, _ = _dedupe_candidates(ids, n_tot)  # production contract: deduped input
    got = ops.gather_rerank_topk(main, ids, q, w, k, force=force, delta=delta)
    want = ops.gather_rerank_topk(
        jnp.concatenate([main, delta]), ids, q, w, k, force=force
    )
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))


def test_segmented_gather_all_invalid_rows(rng):
    """A query whose every candidate is a sentinel returns (+inf, -1) on
    the segmented path exactly like the single-table path."""
    main = jax.random.uniform(jax.random.fold_in(rng, 0), (20, D))
    delta = jax.random.uniform(jax.random.fold_in(rng, 1), (8, D))
    q = jnp.zeros((2, D))
    w = jnp.ones((2, D))
    ids = jnp.full((2, 16), 28, jnp.int32)  # all == n_tot sentinel
    for force in ("auto", "chunked", "ref", "interpret"):
        dists, got_ids = ops.gather_rerank_topk(main, ids, q, w, 5, force=force, delta=delta)
        np.testing.assert_array_equal(np.asarray(got_ids), -1)
        assert not np.isfinite(np.asarray(dists)).any()


# ---------------------------------------------------------------------------
# big-delta capacity: the chunked match unblocks cap >> 4096
# ---------------------------------------------------------------------------


def test_large_delta_capacity_queries(rng):
    """A delta_capacity=16384 index (4x the old dense-match comfort zone)
    builds, inserts, and queries; inserted rows are retrievable and the
    two-segment result matches the exact oracle at non-truncating budgets."""
    cap = 16384
    data, extra, q, w = _problem(rng, m=64)
    index = Index.build(
        jax.random.fold_in(rng, 9),
        data,
        _cfg(),
        update=UpdateSpec(delta_capacity=cap),
    )
    index, ids = index.insert(extra)
    res = index.query(extra[:4], jnp.ones((4, D)), QuerySpec(k=1))
    np.testing.assert_array_equal(np.asarray(res.ids[:, 0]), np.asarray(ids[:4]))
    for mode in ("probe", "exact"):
        spec = QuerySpec(k=5, mode=mode)
        _assert_bit_identical(
            index.query(q, w, spec), _legacy_query(index, q, w, spec)
        )


# ---------------------------------------------------------------------------
# contracts carried from test_lifecycle: sentinels + no retrace
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mutable", [False, True])
@pytest.mark.parametrize("mode", ["probe", "multiprobe", "exact"])
def test_engine_sentinels_minus_one_iff_inf(rng, mutable, mode):
    data = jax.random.uniform(jax.random.fold_in(rng, 0), (5, D)) * 0.1
    cfg = _cfg(max_candidates=16)
    if mutable:
        index = Index.build(
            jax.random.fold_in(rng, 9), data, cfg, update=UpdateSpec(delta_capacity=8)
        )
        index = index.delete(jnp.asarray([2], jnp.int32))
    else:
        index = Index.build(jax.random.fold_in(rng, 9), data, cfg)
    q = jnp.ones((2, D)) * 0.95
    w = jnp.ones((2, D))
    res = index.query(q, w, QuerySpec(k=9, mode=mode))
    ids = np.asarray(res.ids)
    dists = np.asarray(res.dists)
    assert ((ids == -1) == ~np.isfinite(dists)).all()
    assert ids.max() < 5 + 8 and ids.min() >= -1  # internal sentinels never escape


def test_mode_irrelevant_static_args_share_compiled_program(rng):
    """Static args a mode does not read (n_probes/max_flips in probe mode,
    cfg in exact mode) are normalized before the compile-key lookup — the
    facade and the legacy shims hit ONE executable per traced program."""
    from repro.analysis import RetraceGuard
    from repro.core.index import query_exact_segmented

    data, extra, q, w = _problem(rng)
    imm = _index_for(rng, data, extra, "theta", "fresh")
    r1 = imm.query(q, w, QuerySpec(k=3))  # spec default n_probes=8/max_flips=3
    with RetraceGuard() as guard:
        r2 = imm.query(q, w, QuerySpec(k=3, n_probes=4, max_flips=1))
        guard.assert_no_retrace(context="probe-mode n_probes variant")
    _assert_bit_identical(r1, r2)

    mut = _index_for(rng, data, extra, "theta", "delta")
    mut.query(q, w, QuerySpec(k=3, mode="exact"))  # facade passes real cfg
    with RetraceGuard() as guard:
        query_exact_segmented(mut.state, mut.delta, mut.tombstones, q, w, k=3)  # cfg=None
        guard.assert_no_retrace(context="legacy exact shim vs facade")


def test_engine_no_retrace_across_fill_levels(rng):
    """One compiled program per (geometry, spec) across the index's whole
    mutable life — probe AND multiprobe."""
    data, extra, q, w = _problem(rng)
    index = Index.build(
        jax.random.fold_in(rng, 9),
        data,
        _cfg(),
        update=UpdateSpec(delta_capacity=CAP),
    )
    jq = jax.jit(lambda ix, q, w: ix.query(q, w, QuerySpec(k=5)))
    jmp = jax.jit(lambda ix, q, w: ix.query(q, w, QuerySpec(k=5, mode="multiprobe")))
    jins = jax.jit(lambda ix, rows: ix.insert(rows))
    jdel = jax.jit(lambda ix, ids: ix.delete(ids))
    for i in range(4):
        index, _ = jins(index, extra[i * 8 : (i + 1) * 8])
        index = jdel(index, jnp.asarray([i * 3], jnp.int32))
        jq(index, q, w)
        jmp(index, q, w)
    from repro.analysis import cache_size

    assert cache_size(jq) == 1
    assert cache_size(jmp) == 1
    assert cache_size(jins) == 1
    assert cache_size(jdel) == 1


# ---------------------------------------------------------------------------
# engine internals: source/block contract
# ---------------------------------------------------------------------------


def test_sources_emit_fixed_shape_blocks(rng):
    """Block contract: static (b, P_src) shapes, sentinel >= n_valid for
    empty slots, global ids across sources."""
    data, extra, q, w = _problem(rng)
    index = _index_for(rng, data, extra, "theta", "churn")
    cfg = index.config
    keys = engine.probe_keys(index.state, q, w, cfg)
    assert keys.shape == (5, cfg.L, 1)
    srcs = engine.sources_for(index.state, index.delta, index.tombstones, cfg, keys)
    assert len(srcs) == 2  # sorted-table + delta-match
    n_tot = index.state.n + index.delta.capacity
    table_block = srcs[0].emit(q, w)
    delta_block = srcs[1].emit(q, w)
    assert table_block.shape == (5, cfg.L * 1 * cfg.max_candidates)
    assert delta_block.shape == (5, CAP)
    # live delta ids are global (>= n_main), sentinels >= n_tot
    db = np.asarray(delta_block)
    assert ((db >= index.state.n) | (db >= n_tot)).all()
    # a multiprobe enumeration feeds the SAME sources
    mkeys = engine.probe_keys(
        index.state, q, w, cfg, mode="multiprobe", n_probes=4, max_flips=2
    )
    assert mkeys.shape[:2] == (5, cfg.L) and mkeys.shape[2] <= 4
    srcs_mp = engine.sources_for(index.state, index.delta, index.tombstones, cfg, mkeys)
    assert srcs_mp[0].emit(q, w).shape == (5, cfg.L * mkeys.shape[2] * cfg.max_candidates)


# ---------------------------------------------------------------------------
# sharded facade: validation parity (satellite) + engine parity
# ---------------------------------------------------------------------------


def test_sharded_query_validates_like_single_host(rng):
    """ShardedIndex.query runs the same _validate_query_args checks as
    Index.query — malformed inputs raise the named ValueError, not a
    shard_map trace error."""
    data, _, q, w = _problem(rng)
    mesh = jax.make_mesh((1,), ("data",))
    sharded = Index.build(jax.random.fold_in(rng, 9), data, _cfg()).shard(mesh)
    with pytest.raises(ValueError, match="queries"):
        sharded.query(q[:, :-1], w, QuerySpec(k=3))
    with pytest.raises(ValueError, match="weights"):
        sharded.query(q, w[:, :-1], QuerySpec(k=3))
    with pytest.raises(ValueError, match="batch dims disagree"):
        sharded.query(q, w[:-1], QuerySpec(k=3))
    with pytest.raises(ValueError, match="queries"):
        sharded.query(q[0], w[0], QuerySpec(k=3))


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_sharded_engine_matches_single_host():
    """Per-shard engine dispatch + hierarchical merge == single-host engine,
    bit for bit, for both families across probe/multiprobe/exact on a
    mutable (delta + tombstones) index (8 fake CPU devices, subprocess)."""
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.api import Index, IndexConfig, QuerySpec, UpdateSpec, BoundedSpace

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        n, d, k = 512, 8, 7
        key = jax.random.PRNGKey(0)
        data = jax.random.uniform(jax.random.fold_in(key, 0), (n, d))
        extra = jax.random.uniform(jax.random.fold_in(key, 1), (37, d))
        q = jax.random.uniform(jax.random.fold_in(key, 2), (5, d))
        w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 3), (5, d))) + 0.2
        for family in ("theta", "l2"):
            cfg = IndexConfig(d=d, M=8, K=6, L=10, family=family, W=8.0,
                              max_candidates=n + 64, space=BoundedSpace(0., 1., 8.))
            local = Index.build(jax.random.fold_in(key, 9), data, cfg,
                                update=UpdateSpec(delta_capacity=64))
            local, ids = local.insert(extra)
            local = local.delete(jnp.asarray([3, 77, int(ids[4])], jnp.int32))
            sharded = local.shard(mesh)
            modes = ("probe", "exact") + (("multiprobe",) if family == "theta" else ())
            for mode in modes:
                a = local.query(q, w, QuerySpec(k=k, mode=mode))
                b = sharded.query(q, w, QuerySpec(k=k, mode=mode))
                np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
                np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))
                np.testing.assert_array_equal(np.asarray(a.n_candidates),
                                              np.asarray(b.n_candidates))
        print("OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    assert "OK" in out.stdout
