"""Fused streaming-top-k kernels vs the materializing ref oracles.

Covers (interpret=True Pallas bodies + chunked jnp production paths):
  * shape/padding sweeps — non-multiple n, d, C; C > n; k > candidates;
  * all-invalid candidate rows;
  * dedupe correctness with candidate ids duplicated across tables;
  * the fused query_index tail vs a hand-built unfused gather → rerank →
    top-k reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BoundedSpace, IndexConfig, build_index, query_index
from repro.core.index import _dedupe_candidates
from repro.kernels import ops, ref
from repro.kernels.gather_rerank import (
    gather_rerank_topk_chunked,
    gather_rerank_topk_pallas,
)
from repro.kernels.wl1_topk import wl1_scan_topk_chunked, wl1_scan_topk_pallas

# (n, b, d, k): block-exact, off-by-one, sub-block, k > n
SCAN_TOPK_SHAPES = [
    (1, 1, 1, 1),
    (33, 3, 7, 5),
    (128, 8, 256, 128),  # exact blocks, k = lane width
    (129, 9, 257, 10),  # off-by-one everywhere
    (300, 5, 16, 3),
    (4, 2, 2, 8),  # k > n ⇒ (+inf, -1) tail
]


@pytest.mark.parametrize("n,b,d,k", SCAN_TOPK_SHAPES)
@pytest.mark.parametrize("impl", ["interpret", "chunked"])
def test_scan_topk_matches_ref(n, b, d, k, impl):
    key = jax.random.PRNGKey(n * 31 + b * 7 + d + k)
    k1, k2, k3 = jax.random.split(key, 3)
    data = jax.random.normal(k1, (n, d))
    q = jax.random.normal(k2, (b, d))
    w = jax.random.normal(k3, (b, d))
    want_d, want_i = ref.wl1_scan_topk(data, q, w, k)
    if impl == "interpret":
        got_d, got_i = wl1_scan_topk_pallas(data, q, w, k, interpret=True)
    else:
        got_d, got_i = wl1_scan_topk_chunked(data, q, w, k, chunk=64)
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d), rtol=1e-5, atol=1e-5)
    assert np.array_equal(np.asarray(got_i), np.asarray(want_i))


# (n, b, P, d, k): P is the candidate-slot count (L·C in the index)
GATHER_SHAPES = [
    (50, 3, 17, 7, 5),
    (200, 2, 64, 128, 10),  # d exactly one chunk
    (8, 2, 40, 5, 3),  # C > n: more slots than database rows
    (10, 2, 16, 300, 4),  # d spans multiple chunks with padding
    (5, 1, 1, 1, 1),
]


@pytest.mark.parametrize("n,b,P,d,k", GATHER_SHAPES)
@pytest.mark.parametrize("impl", ["interpret", "chunked"])
def test_gather_rerank_topk_matches_ref(n, b, P, d, k, impl):
    key = jax.random.PRNGKey(n + P * 13 + d + k)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    data = jax.random.normal(k1, (n, d))
    q = jax.random.normal(k2, (b, d))
    w = jax.random.normal(k3, (b, d))
    raw = jax.random.randint(k4, (b, P), 0, n + max(2, n // 3))
    ids = jnp.minimum(raw, n).astype(jnp.int32)  # >= n ⇒ invalid sentinel
    want_d, want_i = ref.gather_rerank_topk(data, ids, q, w, k)
    if impl == "interpret":
        got_d, got_i = gather_rerank_topk_pallas(data, ids, q, w, k, interpret=True)
    else:
        got_d, got_i = gather_rerank_topk_chunked(data, ids, q, w, k, chunk=16)
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d), rtol=1e-5, atol=1e-5)
    assert np.array_equal(np.asarray(got_i), np.asarray(want_i))


@pytest.mark.parametrize("impl", ["interpret", "chunked", "ref"])
def test_gather_rerank_all_invalid(impl):
    """A query whose every candidate slot is padding returns (+inf, -1)."""
    key = jax.random.PRNGKey(0)
    n, b, P, d, k = 12, 3, 9, 6, 4
    data = jax.random.normal(key, (n, d))
    q = jax.random.normal(jax.random.fold_in(key, 1), (b, d))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (b, d)))
    ids = jnp.full((b, P), n, jnp.int32)
    got_d, got_i = ops.gather_rerank_topk(data, ids, q, w, k, force=impl)
    assert np.all(np.isinf(np.asarray(got_d)))
    assert np.all(np.asarray(got_i) == -1)


@pytest.mark.parametrize("impl", ["interpret", "chunked"])
def test_gather_rerank_duplicate_ids_after_dedupe(impl):
    """Ids duplicated across tables: dedupe marks repeats invalid, and the
    fused top-k must not return the same id twice."""
    key = jax.random.PRNGKey(7)
    n, b, d, k = 30, 2, 8, 6
    data = jax.random.normal(key, (n, d))
    q = jax.random.normal(jax.random.fold_in(key, 1), (b, d))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (b, d))) + 0.1
    # every id appears in "both tables" (two copies), plus window padding
    half = jax.random.randint(jax.random.fold_in(key, 3), (b, 10), 0, n)
    cand = jnp.concatenate([half, half, jnp.full((b, 4), n + 3)], axis=1)
    deduped, n_cand = _dedupe_candidates(cand.astype(jnp.int32), n)
    # counts only unique real ids
    for i in range(b):
        assert int(n_cand[i]) == len(set(np.asarray(half[i]).tolist()))
    got_d, got_i = ops.gather_rerank_topk(data, deduped, q, w, k, force=impl)
    want_d, want_i = ref.gather_rerank_topk(data, deduped, q, w, k)
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d), rtol=1e-5, atol=1e-5)
    assert np.array_equal(np.asarray(got_i), np.asarray(want_i))
    for i in range(b):
        real = [int(x) for x in np.asarray(got_i[i]) if x >= 0]
        assert len(real) == len(set(real)), f"duplicate id returned: {real}"


def test_query_index_matches_unfused_reference(rng):
    """End-to-end: the fused query tail returns exactly what the old 3-step
    (gather → wl1_rerank → lax.top_k) path returned."""
    n, d, M, k = 600, 10, 8, 5
    space = BoundedSpace(0.0, 1.0, float(M))
    data = jax.random.uniform(jax.random.fold_in(rng, 80), (n, d))
    cfg = IndexConfig(d=d, M=M, K=6, L=12, max_candidates=32, space=space)
    idx = build_index(jax.random.fold_in(rng, 81), data, cfg)
    q = jax.random.uniform(jax.random.fold_in(rng, 82), (6, d))
    w = jax.random.normal(jax.random.fold_in(rng, 83), (6, d))  # mixed signs
    res = query_index(idx, q, w, cfg, k=k)

    # unfused reference tail over the same probe set
    from repro.core import transforms
    from repro.core.index import _keys_for, _probe_one_table

    qlevels = transforms.discretize(q, cfg.space)
    qkeys = _keys_for(qlevels, w, idx.tables, cfg, idx.mixers)
    probe = jax.vmap(
        jax.vmap(_probe_one_table, in_axes=(0, 0, 0, None)), in_axes=(None, None, 0, None)
    )
    cand = probe(idx.sorted_keys, idx.perm, qkeys, cfg.max_candidates)
    cand, _ = _dedupe_candidates(cand.reshape(6, -1), n)
    valid = cand < n
    pts = data[jnp.minimum(cand, n - 1)]
    dists = jnp.where(valid, ref.wl1_rerank(pts, q, w), jnp.inf)
    neg, sel = jax.lax.top_k(-dists, k)
    want_d = -neg
    want_i = jnp.where(
        jnp.isfinite(want_d), jnp.take_along_axis(cand, sel, axis=1), -1
    )
    np.testing.assert_allclose(
        np.asarray(res.dists), np.asarray(want_d), rtol=1e-5, atol=1e-5
    )
    assert np.array_equal(np.asarray(res.ids), np.asarray(want_i))


@pytest.mark.parametrize("impl", ["interpret", "chunked"])
def test_scan_topk_positive_weights_ascending(impl, rng):
    """Sanity: ascending order, non-negative dists under positive weights."""
    data = jax.random.normal(rng, (70, 9))
    q = jax.random.normal(jax.random.fold_in(rng, 1), (4, 9))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(rng, 2), (4, 9)))
    d, i = ops.wl1_scan_topk(data, q, w, 10, force=impl)
    d = np.asarray(d)
    assert np.all(np.diff(d, axis=1) >= -1e-6)
    assert np.all(d >= -1e-6)
    assert np.all(np.asarray(i) >= 0)
