"""The repro.api facade: one Index.query, four behaviors — and bit-parity
with the legacy (ALSHIndex, IndexConfig) shims it replaces."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    BoundedSpace,
    Index,
    IndexConfig,
    QuerySpec,
    get_family,
)
from repro.core import build_index, query_index
from repro.core.multiprobe import query_multiprobe
from repro.distance import brute_force_nn, wl1_distance


def _cfg(d=10, M=8, K=6, L=12, family="theta", **kw):
    kw.setdefault("max_candidates", 64)
    kw.setdefault("space", BoundedSpace(0.0, 1.0, float(M)))
    return IndexConfig(d=d, M=M, K=K, L=L, family=family, **kw)


def _problem(rng, n=800, d=10, b=4, salt=0):
    data = jax.random.uniform(jax.random.fold_in(rng, salt), (n, d))
    q = jax.random.uniform(jax.random.fold_in(rng, salt + 1), (b, d))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(rng, salt + 2), (b, d))) + 0.2
    return data, q, w


# ---------------------------------------------------------------------------
# parity: facade vs legacy shims (fixed seed, bit-identical)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["theta", "l2"])
def test_probe_bit_identical_to_legacy(rng, family):
    data, q, w = _problem(rng, salt=0)
    cfg = _cfg(family=family, W=8.0)
    bkey = jax.random.fold_in(rng, 9)
    index = Index.build(bkey, data, cfg)
    legacy = build_index(bkey, data, cfg)

    res = index.query(q, w, QuerySpec(k=5))
    ref = query_index(legacy, q, w, cfg, k=5)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))
    np.testing.assert_array_equal(np.asarray(res.dists), np.asarray(ref.dists))
    np.testing.assert_array_equal(
        np.asarray(res.n_candidates), np.asarray(ref.n_candidates)
    )


def test_multiprobe_bit_identical_to_legacy(rng):
    data, q, w = _problem(rng, salt=10)
    cfg = _cfg(family="theta")
    bkey = jax.random.fold_in(rng, 19)
    index = Index.build(bkey, data, cfg)
    legacy = build_index(bkey, data, cfg)

    res = index.query(q, w, QuerySpec(k=5, mode="multiprobe", n_probes=4, max_flips=2))
    ref = query_multiprobe(legacy, q, w, cfg, k=5, n_probes=4, max_flips=2)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))
    np.testing.assert_array_equal(np.asarray(res.dists), np.asarray(ref.dists))


def test_exact_mode_matches_brute_force(rng):
    data, q, w = _problem(rng, salt=20)
    index = Index.build(jax.random.fold_in(rng, 29), data, _cfg())
    res = index.query(q, w, QuerySpec(k=7, mode="exact"))
    bf_d, bf_i = brute_force_nn(data, q, w, k=7)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(bf_i))
    np.testing.assert_allclose(np.asarray(res.dists), np.asarray(bf_d), rtol=1e-6)
    # exact mode scans everything — the sublinearity metric reports n
    np.testing.assert_array_equal(np.asarray(res.n_candidates), index.n)


# ---------------------------------------------------------------------------
# negative query weights (the paper's w may be negative), both families
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["theta", "l2"])
def test_negative_weights_through_facade(rng, family):
    data, q, _ = _problem(rng, salt=30)
    w = jax.random.normal(jax.random.fold_in(rng, 33), q.shape)  # mixed signs
    assert bool(jnp.any(w < 0))
    cfg = _cfg(family=family, W=8.0, L=24, max_candidates=128)
    bkey = jax.random.fold_in(rng, 39)
    index = Index.build(bkey, data, cfg)

    res = index.query(q, w, QuerySpec(k=5))
    assert res.ids.shape == (q.shape[0], 5)
    assert np.isfinite(np.asarray(res.dists)).any()
    # returned distances are exact d_w^l1 (negative contributions included)
    for i in range(q.shape[0]):
        for j in range(5):
            pid = int(res.ids[i, j])
            if pid < 0:
                continue
            want = float(wl1_distance(data[pid], q[i], w[i]))
            np.testing.assert_allclose(
                float(res.dists[i, j]), want, rtol=1e-4, atol=1e-4
            )
    # parity with the legacy shim under the same seed
    ref = query_index(build_index(bkey, data, cfg), q, w, cfg, k=5)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ref.ids))


# ---------------------------------------------------------------------------
# self-describing persistence
# ---------------------------------------------------------------------------


def test_load_restores_from_directory_alone(rng, tmp_path):
    data, q, w = _problem(rng, salt=40)
    cfg = _cfg(family="l2", W=8.0)
    index = Index.build(jax.random.fold_in(rng, 49), data, cfg)
    want = index.query(q, w, QuerySpec(k=5))

    index.save(str(tmp_path))
    restored = Index.load(str(tmp_path))  # no config, no template tree

    assert restored.config == cfg
    assert restored.n == index.n
    got = restored.query(q, w, QuerySpec(k=5))
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(want.ids))
    np.testing.assert_array_equal(np.asarray(got.dists), np.asarray(want.dists))


def test_load_rejects_non_index_directory(tmp_path):
    with pytest.raises(FileNotFoundError, match="index.json"):
        Index.load(str(tmp_path))


def test_load_rejects_future_format_version(rng, tmp_path):
    import json

    data, _, _ = _problem(rng, salt=45)
    Index.build(jax.random.fold_in(rng, 44), data, _cfg()).save(str(tmp_path))
    meta_path = tmp_path / "index.json"
    meta = json.loads(meta_path.read_text())
    meta["version"] = 99
    meta_path.write_text(json.dumps(meta))
    with pytest.raises(ValueError, match="version"):
        Index.load(str(tmp_path))


def test_load_rejects_torn_overwrite(rng, tmp_path):
    """Meta from one geometry + arrays from another (a torn re-save of the
    same directory) must be rejected, not silently mis-loaded."""
    import json

    data, _, _ = _problem(rng, salt=46)
    Index.build(jax.random.fold_in(rng, 47), data, _cfg(L=12)).save(str(tmp_path))
    meta_path = tmp_path / "index.json"
    meta = json.loads(meta_path.read_text())
    meta["config"]["L"] = 6  # pretend the overwrite's meta landed, arrays didn't
    meta_path.write_text(json.dumps(meta))
    with pytest.raises(ValueError, match="does not describe the stored arrays"):
        Index.load(str(tmp_path))


# ---------------------------------------------------------------------------
# config / spec validation (actionable errors at construction)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("field", ["d", "M", "K", "L", "max_candidates"])
def test_config_rejects_nonpositive_geometry(field):
    good = dict(d=8, M=8, K=6, L=4, max_candidates=32,
                space=BoundedSpace(0.0, 1.0, 8.0))
    for bad in (0, -3):
        with pytest.raises(ValueError, match=rf"IndexConfig\.{field}"):
            IndexConfig(**{**good, field: bad})


def test_config_rejects_unknown_family():
    with pytest.raises(ValueError, match="unknown hash family"):
        _cfg(family="cosine")


def test_config_rejects_theta_overwide_keys():
    with pytest.raises(ValueError, match="K <= 31"):
        _cfg(K=32, family="theta")


def test_config_rejects_l2_bad_width():
    with pytest.raises(ValueError, match=r"IndexConfig\.W"):
        _cfg(family="l2", W=0.0)


def test_config_rejects_space_overflowing_lattice():
    with pytest.raises(ValueError, match="space"):
        _cfg(M=8, space=BoundedSpace(0.0, 1.0, 32.0))


def test_config_normalizes_family_objects():
    cfg = _cfg(family=get_family("theta"))
    assert cfg.family == "theta"
    assert cfg.family_obj is get_family("theta")


def test_queryspec_validation():
    with pytest.raises(ValueError, match="mode"):
        QuerySpec(mode="fuzzy")
    with pytest.raises(ValueError, match=r"QuerySpec\.k"):
        QuerySpec(k=0)
    with pytest.raises(ValueError, match="n_probes"):
        QuerySpec(mode="multiprobe", n_probes=0)
    with pytest.raises(ValueError, match=r"QuerySpec\.impl"):
        QuerySpec(impl="onehott")
    with pytest.raises(ValueError, match="only applies to mode='probe'"):
        QuerySpec(mode="exact", impl="onehot")
    QuerySpec(mode="probe", impl="onehot")  # valid combination


def test_multiprobe_rejects_l2_family(rng):
    data, q, w = _problem(rng, salt=50)
    index = Index.build(jax.random.fold_in(rng, 59), data, _cfg(family="l2", W=8.0))
    with pytest.raises(ValueError, match="multiprobe"):
        index.query(q, w, QuerySpec(k=3, mode="multiprobe"))


# ---------------------------------------------------------------------------
# the Index is a pytree: config rides in the treedef across jit
# ---------------------------------------------------------------------------


def test_index_crosses_jit_boundary(rng):
    data, q, w = _problem(rng, salt=60)
    index = Index.build(jax.random.fold_in(rng, 69), data, _cfg())

    @jax.jit
    def f(ix, q, w):
        return ix.query(q, w, QuerySpec(k=3)).dists

    got = f(index, q, w)
    want = index.query(q, w, QuerySpec(k=3)).dists
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)

    leaves, treedef = jax.tree_util.tree_flatten(index)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.config == index.config


def test_config_replace_revalidates():
    cfg = _cfg(family="theta")
    with pytest.raises(ValueError, match="K <= 31"):
        dataclasses.replace(cfg, K=40)
