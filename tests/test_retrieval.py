"""ALSH retrieval attachment (kNN-LM-style decode augmentation)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs import RetrievalConfig, get_bundle, reduced_model
from repro.runtime import retrieval as rt
from repro.runtime.serve_step import make_decode_step


RCFG = RetrievalConfig(datastore_size=2048, d_key=16, M=16, K=6, L=8,
                       max_candidates=32, topk=4, interp_lambda=0.3)


def test_datastore_build_and_probe(rng):
    state = rt.build_datastore(rng, d_model=64, vocab=512, rcfg=RCFG)
    hidden = jax.random.normal(jax.random.fold_in(rng, 1), (4, 64))
    logp = rt.retrieve_logits(hidden, state, RCFG, vocab=512)
    assert logp.shape == (4, 512)
    # a log-prob distribution (up to the +eps floor)
    p = np.exp(np.asarray(logp))
    np.testing.assert_allclose(p.sum(-1), 1.0, atol=1e-3)


def test_quality_first_datastore(rng):
    """rcfg.recall_target plans the lookup eagerly (precision-weight
    calibration) and the memoized plan drives jit'd retrieval."""
    import dataclasses

    rcfg = dataclasses.replace(RCFG, recall_target=0.7)
    state = rt.build_datastore(rng, d_model=64, vocab=512, rcfg=rcfg)
    spec = rt.query_spec(rcfg)
    assert spec in state.index.plans  # resolved at build, not at decode
    hidden = jax.random.normal(jax.random.fold_in(rng, 7), (4, 64))
    logp = jax.jit(
        lambda h, s: rt.retrieve_logits(h, s, rcfg, vocab=512)
    )(hidden, state)  # memo must survive the jit crossing
    assert logp.shape == (4, 512)
    # and the planned path is bit-identical to executing the plan directly
    want = rt.retrieve_logits(hidden, state, rcfg, vocab=512)
    np.testing.assert_array_equal(np.asarray(logp), np.asarray(want))


def test_interpolation_is_valid_distribution(rng):
    state = rt.build_datastore(rng, d_model=64, vocab=512, rcfg=RCFG)
    hidden = jax.random.normal(jax.random.fold_in(rng, 2), (2, 64))
    lm_logits = jax.random.normal(jax.random.fold_in(rng, 3), (2, 512))
    knn = rt.retrieve_logits(hidden, state, RCFG, vocab=512)
    mixed = rt.interpolate(lm_logits, knn, RCFG.interp_lambda)
    p = np.exp(np.asarray(mixed))
    np.testing.assert_allclose(p.sum(-1), 1.0, atol=1e-3)


def test_decode_step_with_retrieval(rng):
    cfg = reduced_model(get_bundle("gemma3-1b").model)
    params = models.init_params(rng, cfg)
    state = rt.build_datastore(
        jax.random.fold_in(rng, 1), cfg.d_model, cfg.vocab_size, RCFG
    )
    caches = models.init_caches(2, 32, cfg)
    step = make_decode_step(cfg, RCFG)
    batch = {"token": jnp.zeros((2,), jnp.int32), "pos": jnp.zeros((2,), jnp.int32)}
    logits, tok, new_caches = step(params, batch, caches, state)
    assert logits.shape == (2, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))
    # retrieval must actually change the distribution vs the plain decode
    plain = make_decode_step(cfg, None)
    plogits, _, _ = plain(params, batch, caches)
    lm_logp = jax.nn.log_softmax(plogits, axis=-1)
    assert not np.allclose(np.asarray(lm_logp), np.asarray(logits), atol=1e-4)


def test_per_query_weights_change_retrieval(rng):
    """The paper's headline property end-to-end: the SAME hidden state with a
    different query-time weight vector retrieves differently."""
    state = rt.build_datastore(rng, d_model=32, vocab=128, rcfg=RCFG)
    hidden = jax.random.normal(jax.random.fold_in(rng, 5), (1, 32))
    w1 = jnp.ones((1, RCFG.d_key))
    w2 = jnp.concatenate(
        [10 * jnp.ones((1, RCFG.d_key // 2)), 0.01 * jnp.ones((1, RCFG.d_key // 2))],
        axis=1,
    )
    l1 = rt.retrieve_logits(hidden, state, RCFG, 128, weights=w1)
    l2 = rt.retrieve_logits(hidden, state, RCFG, 128, weights=w2)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))
