"""Eq 25/27 collision probabilities: Monte-Carlo vs closed form; rho < 1 (Thm 4/5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hash_families as hf
from repro.core import theory
from repro.distance import wl1_distance


@pytest.mark.parametrize("family", ["theta", "l2"])
def test_collision_probability_montecarlo(rng, family):
    """Empirical Pr[f(o)=g(q)] over 4096 hash draws matches Eq 25/27 within 3 sigma."""
    d, M, H, W = 6, 8, 4096, 8.0
    params = hf.LSHParams(d=d, M=M, n_hashes=H, family=family, W=W)
    tables = hf.make_prefix_tables(rng, params)
    k1, k2, k3 = jax.random.split(jax.random.fold_in(rng, 7), 3)
    o = jax.random.randint(k1, (1, d), 0, M + 1)
    q = jax.random.randint(k2, (1, d), 0, M + 1)
    w = jax.random.normal(k3, (1, d))
    f = hf.hash_data(o, tables, params, impl="gather")
    g = hf.hash_query(q, w, tables, params, impl="gather")
    emp = float(jnp.mean((f == g).astype(jnp.float32)))
    r = wl1_distance(o.astype(jnp.float32), q.astype(jnp.float32), w)[0]
    if family == "theta":
        ana = float(theory.collision_prob_theta(r, M, d, w[0]))
    else:
        ana = float(theory.collision_prob_l2(r, M, d, w[0], W))
    sigma = np.sqrt(max(ana * (1 - ana), 1e-6) / H)
    assert abs(emp - ana) < 4 * sigma + 0.01, (emp, ana, sigma)


@pytest.mark.parametrize("family", ["theta", "l2"])
def test_collision_prob_monotone_decreasing(family):
    d, M, W = 10, 16, 4.0
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (d,))) + 0.1
    rmax = float(M * jnp.sum(w))
    rs = jnp.linspace(0.0, rmax, 64)
    if family == "theta":
        ps = theory.collision_prob_theta(rs, M, d, w)
    else:
        ps = theory.collision_prob_l2(rs, M, d, w, W)
    diffs = np.diff(np.asarray(ps))
    assert np.all(diffs <= 1e-6), "collision prob must decrease with distance"
    assert np.all((np.asarray(ps) >= -1e-6) & (np.asarray(ps) <= 1 + 1e-6))


@pytest.mark.parametrize("family", ["theta", "l2"])
def test_rho_below_one(family):
    """Thm 4/5: rho = log P1 / log P2 < 1 for any R1 < R2."""
    d, M = 12, 32
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (d,))) + 0.1
    rmax = float(M * jnp.sum(w))
    for f1, f2 in [(0.01, 0.1), (0.05, 0.3), (0.2, 0.6)]:
        r = float(
            theory.rho(
                jnp.asarray(f1 * rmax), jnp.asarray(f2 * rmax), M, d, w, family=family, W=16.0
            )
        )
        assert 0.0 < r < 1.0, (family, f1, f2, r)


def test_plan_index_reasonable():
    plan = theory.plan_index(n=100_000, R1=0.3, R2=2.0, M=32, d=16)
    assert 1 <= plan.K <= 32 and 1 <= plan.L <= 256
    assert 0 < plan.rho < 1
    assert theory.success_probability(plan) > 0.5


def test_p_l2_closed_form_bounds_and_width_monotonicity():
    """Eq 4 direct: p in (0, 1), decreasing in r, increasing in W."""
    rs = jnp.linspace(0.1, 50.0, 64)
    for W in (1.0, 4.0, 16.0):
        ps = np.asarray(theory.p_l2(rs, W))
        assert np.all((ps > 0) & (ps < 1))
        assert np.all(np.diff(ps) <= 1e-7), "p_l2 must decrease with r"
    p_by_W = [float(theory.p_l2(jnp.asarray(5.0), W)) for W in (1.0, 2.0, 4.0, 8.0)]
    assert np.all(np.diff(p_by_W) > 0), "p_l2 must increase with W at fixed r"


def test_p_theta_closed_form():
    """Eq 6 direct: linear in the angle, 1 at 0, 0 at pi."""
    np.testing.assert_allclose(float(theory.p_theta(jnp.asarray(0.0))), 1.0)
    np.testing.assert_allclose(float(theory.p_theta(jnp.asarray(jnp.pi))), 0.0, atol=1e-7)
    np.testing.assert_allclose(float(theory.p_theta(jnp.asarray(jnp.pi / 2))), 0.5)


@pytest.mark.parametrize("family", ["theta", "l2"])
def test_eq24_eq26_inverse_round_trip(family):
    """wl1 -> transformed distance -> wl1 is the identity (Eq 24/26 inverted)."""
    d, M = 9, 16
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (d,))) + 0.2
    rs = jnp.linspace(0.5, 0.5 * M * float(jnp.sum(w)), 32)
    if family == "l2":
        back = theory.wl1_from_l2_distance(
            theory.l2_distance_from_wl1(rs, M, d, w), M, d, w
        )
    else:
        back = theory.wl1_from_angular_distance(
            theory.angular_distance_from_wl1(rs, M, d, w), M, d, w
        )
    np.testing.assert_allclose(np.asarray(back), np.asarray(rs), rtol=1e-4, atol=1e-2)


def test_invert_p_l2_round_trip():
    for W in (2.0, 8.0):
        for p in (0.2, 0.5, 0.9):
            r = theory.invert_p_l2(p, W)
            np.testing.assert_allclose(float(theory.p_l2(jnp.asarray(r), W)), p, rtol=1e-5)
    with pytest.raises(ValueError, match="invert_p_l2"):
        theory.invert_p_l2(1.5, 4.0)


def test_solve_K():
    assert theory.solve_K(0.5, 1024) == 10
    assert theory.solve_K(0.5, 10**9, max_K=12) == 12  # clamped
    assert theory.solve_K(0.999, 10) >= 1
    with pytest.raises(ValueError, match="solve_K"):
        theory.solve_K(1.0, 100)


def test_solve_tables_meets_failure_bound():
    """L returned by solve_tables achieves miss prob <= fail_prob (pre-clamp)."""
    P1, P2, n = 0.8, 0.5, 100_000
    for delta in (0.3, 0.1, 0.01):
        K, L = theory.solve_tables(P1, P2, n, fail_prob=delta, max_L=100_000)
        assert (1.0 - P1**K) ** L <= delta + 1e-12
    # stricter target -> no fewer tables
    _, L_loose = theory.solve_tables(P1, P2, n, fail_prob=0.3, max_L=100_000)
    _, L_tight = theory.solve_tables(P1, P2, n, fail_prob=0.01, max_L=100_000)
    assert L_tight >= L_loose
    with pytest.raises(ValueError, match="fail_prob"):
        theory.solve_tables(P1, P2, n, fail_prob=0.0)
    with pytest.raises(ValueError, match="P2 < P1"):
        theory.solve_tables(0.5, 0.8, n)


def test_solve_bucket_width_minimizes_rho():
    """The solved W beats nearby widths on rho = log p(s1)/log p(s2)."""
    s1, s2 = 6.0, 18.0

    def rho_at(W):
        return float(
            jnp.log(theory.p_l2(jnp.asarray(s1), W))
            / jnp.log(theory.p_l2(jnp.asarray(s2), W))
        )

    W = theory.solve_bucket_width(s1, s2)
    assert rho_at(W) < 1.0
    for factor in (0.25, 0.5, 2.0, 4.0):
        assert rho_at(W) <= rho_at(W * factor) + 1e-3, (W, factor)
    with pytest.raises(ValueError, match="solve_bucket_width"):
        theory.solve_bucket_width(5.0, 5.0)


def test_operating_radii():
    R1, R2 = theory.operating_radii([1.0, 2.0, 3.0, 4.0, 5.0], approx_c=2.0)
    np.testing.assert_allclose(R1, 3.0)
    np.testing.assert_allclose(R2, 6.0)
    # degenerate sample falls back to the geometric diameter when given
    R1, R2 = theory.operating_radii([0.0, 0.0], approx_c=2.0, r_max=40.0)
    assert 0 < R1 and R2 == 2 * R1 and R2 <= 40.0
    with pytest.raises(ValueError, match="approx_c"):
        theory.operating_radii([1.0], approx_c=1.0)
    with pytest.raises(ValueError, match="non-positive"):
        theory.operating_radii([0.0], approx_c=2.0)


def test_eq24_consistency(rng):
    """Eq 24: ||P(o)-Q_w(q)||_2 closed form == explicit vector computation."""
    from repro.core import transforms

    d, M = 7, 9
    k1, k2, k3 = jax.random.split(rng, 3)
    o = jax.random.randint(k1, (d,), 0, M + 1)
    q = jax.random.randint(k2, (d,), 0, M + 1)
    w = jax.random.normal(k3, (d,))
    P = transforms.transform_P(o, M)
    Q = transforms.transform_Q(q, w, M)
    explicit = float(jnp.linalg.norm(P - Q))
    r = wl1_distance(o.astype(jnp.float32), q.astype(jnp.float32), w)
    closed = float(theory.l2_distance_from_wl1(r, M, d, w))
    np.testing.assert_allclose(explicit, closed, rtol=1e-4)


def test_eq26_consistency(rng):
    from repro.core import transforms

    d, M = 7, 9
    k1, k2, k3 = jax.random.split(jax.random.fold_in(rng, 3), 3)
    o = jax.random.randint(k1, (d,), 0, M + 1)
    q = jax.random.randint(k2, (d,), 0, M + 1)
    w = jax.random.normal(k3, (d,)) + 0.01
    P = transforms.transform_P(o, M)
    Q = transforms.transform_Q(q, w, M)
    cosang = float(jnp.dot(P, Q) / (jnp.linalg.norm(P) * jnp.linalg.norm(Q)))
    explicit = float(np.arccos(np.clip(cosang, -1, 1)))
    r = wl1_distance(o.astype(jnp.float32), q.astype(jnp.float32), w)
    closed = float(theory.angular_distance_from_wl1(r, M, d, w))
    np.testing.assert_allclose(explicit, closed, rtol=1e-3, atol=1e-4)
