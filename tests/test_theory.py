"""Eq 25/27 collision probabilities: Monte-Carlo vs closed form; rho < 1 (Thm 4/5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hash_families as hf
from repro.core import theory
from repro.distance import wl1_distance


@pytest.mark.parametrize("family", ["theta", "l2"])
def test_collision_probability_montecarlo(rng, family):
    """Empirical Pr[f(o)=g(q)] over 4096 hash draws matches Eq 25/27 within 3 sigma."""
    d, M, H, W = 6, 8, 4096, 8.0
    params = hf.LSHParams(d=d, M=M, n_hashes=H, family=family, W=W)
    tables = hf.make_prefix_tables(rng, params)
    k1, k2, k3 = jax.random.split(jax.random.fold_in(rng, 7), 3)
    o = jax.random.randint(k1, (1, d), 0, M + 1)
    q = jax.random.randint(k2, (1, d), 0, M + 1)
    w = jax.random.normal(k3, (1, d))
    f = hf.hash_data(o, tables, params, impl="gather")
    g = hf.hash_query(q, w, tables, params, impl="gather")
    emp = float(jnp.mean((f == g).astype(jnp.float32)))
    r = wl1_distance(o.astype(jnp.float32), q.astype(jnp.float32), w)[0]
    if family == "theta":
        ana = float(theory.collision_prob_theta(r, M, d, w[0]))
    else:
        ana = float(theory.collision_prob_l2(r, M, d, w[0], W))
    sigma = np.sqrt(max(ana * (1 - ana), 1e-6) / H)
    assert abs(emp - ana) < 4 * sigma + 0.01, (emp, ana, sigma)


@pytest.mark.parametrize("family", ["theta", "l2"])
def test_collision_prob_monotone_decreasing(family):
    d, M, W = 10, 16, 4.0
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (d,))) + 0.1
    rmax = float(M * jnp.sum(w))
    rs = jnp.linspace(0.0, rmax, 64)
    if family == "theta":
        ps = theory.collision_prob_theta(rs, M, d, w)
    else:
        ps = theory.collision_prob_l2(rs, M, d, w, W)
    diffs = np.diff(np.asarray(ps))
    assert np.all(diffs <= 1e-6), "collision prob must decrease with distance"
    assert np.all((np.asarray(ps) >= -1e-6) & (np.asarray(ps) <= 1 + 1e-6))


@pytest.mark.parametrize("family", ["theta", "l2"])
def test_rho_below_one(family):
    """Thm 4/5: rho = log P1 / log P2 < 1 for any R1 < R2."""
    d, M = 12, 32
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (d,))) + 0.1
    rmax = float(M * jnp.sum(w))
    for f1, f2 in [(0.01, 0.1), (0.05, 0.3), (0.2, 0.6)]:
        r = float(
            theory.rho(
                jnp.asarray(f1 * rmax), jnp.asarray(f2 * rmax), M, d, w, family=family, W=16.0
            )
        )
        assert 0.0 < r < 1.0, (family, f1, f2, r)


def test_plan_index_reasonable():
    plan = theory.plan_index(n=100_000, R1=0.3, R2=2.0, M=32, d=16)
    assert 1 <= plan.K <= 32 and 1 <= plan.L <= 256
    assert 0 < plan.rho < 1
    assert theory.success_probability(plan) > 0.5


def test_eq24_consistency(rng):
    """Eq 24: ||P(o)-Q_w(q)||_2 closed form == explicit vector computation."""
    from repro.core import transforms

    d, M = 7, 9
    k1, k2, k3 = jax.random.split(rng, 3)
    o = jax.random.randint(k1, (d,), 0, M + 1)
    q = jax.random.randint(k2, (d,), 0, M + 1)
    w = jax.random.normal(k3, (d,))
    P = transforms.transform_P(o, M)
    Q = transforms.transform_Q(q, w, M)
    explicit = float(jnp.linalg.norm(P - Q))
    r = wl1_distance(o.astype(jnp.float32), q.astype(jnp.float32), w)
    closed = float(theory.l2_distance_from_wl1(r, M, d, w))
    np.testing.assert_allclose(explicit, closed, rtol=1e-4)


def test_eq26_consistency(rng):
    from repro.core import transforms

    d, M = 7, 9
    k1, k2, k3 = jax.random.split(jax.random.fold_in(rng, 3), 3)
    o = jax.random.randint(k1, (d,), 0, M + 1)
    q = jax.random.randint(k2, (d,), 0, M + 1)
    w = jax.random.normal(k3, (d,)) + 0.01
    P = transforms.transform_P(o, M)
    Q = transforms.transform_Q(q, w, M)
    cosang = float(jnp.dot(P, Q) / (jnp.linalg.norm(P) * jnp.linalg.norm(Q)))
    explicit = float(np.arccos(np.clip(cosang, -1, 1)))
    r = wl1_distance(o.astype(jnp.float32), q.astype(jnp.float32), w)
    closed = float(theory.angular_distance_from_wl1(r, M, d, w))
    np.testing.assert_allclose(explicit, closed, rtol=1e-3, atol=1e-4)
