"""Pipeline parallelism: pipelined forward/backward == sequential reference
(subprocess with 4 fake CPU devices)."""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


def test_pipeline_forward_and_grads_match_sequential():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.runtime.pipeline import pipeline_apply, pipeline_loss

        mesh = jax.make_mesh((4,), ("pod",))
        P_stages, n_micro, mb, dim = 4, 8, 2, 16
        key = jax.random.PRNGKey(0)
        Ws = jax.random.normal(key, (P_stages, dim, dim)) / dim**0.5
        bs = jax.random.normal(jax.random.fold_in(key, 1), (P_stages, dim)) * 0.1
        params = {"W": Ws, "b": bs}
        x = jax.random.normal(jax.random.fold_in(key, 2), (n_micro, mb, dim))
        tgt = jax.random.normal(jax.random.fold_in(key, 3), (n_micro, mb, dim))

        def stage_fn(p, h):
            return jnp.tanh(h @ p["W"] + p["b"])

        def loss_fn(y, t):
            return jnp.mean((y - t) ** 2)

        # sequential reference
        def seq_forward(params, x):
            h = x
            for s in range(P_stages):
                h = stage_fn(jax.tree.map(lambda q: q[s], params), h)
            return h
        y_ref = jax.vmap(lambda xm: seq_forward(params, xm))(x)
        y_pipe = pipeline_apply(stage_fn, params, x, mesh, "pod")
        np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-5)

        # gradients through the pipeline == sequential gradients
        def seq_loss(params):
            y = jax.vmap(lambda xm: seq_forward(params, xm))(x)
            return jnp.mean(jax.vmap(loss_fn)(y, tgt))
        def pipe_loss(params):
            return pipeline_loss(stage_fn, loss_fn, params, x, tgt, mesh, "pod")
        g_ref = jax.grad(seq_loss)(params)
        g_pipe = jax.grad(pipe_loss)(params)
        for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_pipe)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)
        print("OK")
    """)
    assert "OK" in out
