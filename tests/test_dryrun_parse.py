"""Loop-aware HLO collective parsing: totals must scale with scan trip count."""

import re

import pytest

from repro.launch.dryrun import parse_collectives, _split_computations, _trip_count

FAKE_HLO = """
HloModule test

%cond.1 (arg: (s32[], f32[8])) -> pred[] {
  %iv = s32[] get-tuple-element(%arg), index=0
  %bound = s32[] constant(12)
  ROOT %lt = pred[] compare(%iv, %bound), direction=LT
}

%body.1 (arg: (s32[], f32[8])) -> (s32[], f32[8]) {
  %x = f32[8]{0} get-tuple-element(%arg), index=1
  %ag = f32[128]{0} all-gather(%x), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[8]{0} all-reduce(%x), replica_groups=[16,16]<=[256], to_apply=%add
  ROOT %t = (s32[], f32[8]) tuple(%iv2, %x)
}

ENTRY %main (p0: f32[8]) -> f32[8] {
  %w = (s32[], f32[8]) while(%init), condition=%cond.1, body=%body.1
  %ag2 = f32[64]{0} all-gather(%p0), replica_groups=[1,256]<=[256], dimensions={0}
  ROOT %out = f32[8] get-tuple-element(%w), index=1
}
"""


def test_split_and_tripcount():
    comps = _split_computations(FAKE_HLO)
    assert "cond.1" in comps and "body.1" in comps and "main" in comps
    assert _trip_count(comps["cond.1"]) == 12


def test_loop_scaled_collectives():
    res = parse_collectives(FAKE_HLO)
    # body: all-gather 128*4 = 512 B * 12 trips; all-reduce 8*4*2 = 64 B * 12
    # entry: all-gather 64*4 = 256 B
    assert res["per_type_bytes"]["all-gather"] == 512 * 12 + 256
    assert res["per_type_bytes"]["all-reduce"] == 64 * 12
    assert res["counts"]["all-gather"] == 13
    assert res["total_bytes"] == 512 * 12 + 256 + 64 * 12


def test_real_module_scales_with_layers():
    """Compile tiny 1-unit vs 4-unit models: parsed collective bytes must
    scale ~4x (each unit all-gathers its FSDP-sharded weights)."""
    import subprocess
    import sys
    import os
    import textwrap

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent("""
        import dataclasses, jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_bundle, reduced_model
        from repro.launch import specs
        from repro.launch.dryrun import parse_collectives
        from repro.models.sharding import use_mesh, sanitize_spec_tree
        from repro.runtime.train_step import (init_train_state, make_train_step,
                                              train_state_specs, batch_pytree_specs)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        vals = {}
        for n_units in (1, 4):
            bundle = get_bundle("qwen3-8b")
            mcfg = dataclasses.replace(reduced_model(bundle.model), n_units=n_units,
                                       n_layers=n_units)
            tcfg = bundle.train
            with use_mesh(mesh):
                state = jax.eval_shape(lambda: init_train_state(
                    jax.random.PRNGKey(0), mcfg, tcfg))
                batch = specs.train_batch(mcfg, 8, 64)
                sspec = sanitize_spec_tree(train_state_specs(mcfg, tcfg), state, mesh)
                bspec = sanitize_spec_tree(batch_pytree_specs(batch), batch, mesh)
                to_sh = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                               is_leaf=lambda s: isinstance(s, P))
                comp = jax.jit(make_train_step(mcfg, tcfg),
                               in_shardings=(to_sh(sspec), to_sh(bspec)),
                               out_shardings=(to_sh(sspec), None)).lower(
                                   state, batch).compile()
            vals[n_units] = parse_collectives(comp.as_text())["total_bytes"]
        ratio = vals[4] / max(vals[1], 1.0)
        print("RATIO", ratio, vals)
        assert 2.0 < ratio < 8.0, (ratio, vals)
        print("OK")
    """)], capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-2500:]}"
    assert "OK" in out.stdout
