"""Declarative planning: QualitySpec -> Planner -> PlannedSpec.

Contracts under test (ISSUE 4 acceptance):
  * query(q, w, QualitySpec) is BIT-IDENTICAL to query(q, w, resolved plan)
  * planning is deterministic given (index, sample seed)
  * plans survive save/load (v3 manifest) and shard()
  * spec validation (QualitySpec fields, PlannedSpec fields, the
    n_probes-reachability gap, legacy shim deprecation)
  * explain() returns per-query diagnostics without changing the answer
"""

import dataclasses
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    BoundedSpace,
    Index,
    IndexConfig,
    PlannedSpec,
    Planner,
    QualitySpec,
    QuerySpec,
)
from repro.distance import recall_at_k

QUALITY = QualitySpec(k=5, recall_target=0.8, calibration_queries=16)


@pytest.fixture(scope="module")
def rng_module():
    return jax.random.PRNGKey(20260714)


def _cfg(d=8, M=8, K=6, L=12, family="theta", **kw):
    kw.setdefault("max_candidates", 64)
    kw.setdefault("space", BoundedSpace(0.0, 1.0, float(M)))
    return IndexConfig(d=d, M=M, K=K, L=L, family=family, **kw)


def _problem(rng, n=600, d=8, b=4, salt=0):
    data = jax.random.uniform(jax.random.fold_in(rng, salt), (n, d))
    q = jax.random.uniform(jax.random.fold_in(rng, salt + 1), (b, d))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(rng, salt + 2), (b, d))) + 0.2
    return data, q, w


@pytest.fixture(scope="module")
def planned_index(rng_module):
    """One quality-built index shared by the read-only planning tests."""
    data, _, _ = _problem(rng_module, salt=0)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # tiny n: best-effort plans are fine
        return Index.build(jax.random.fold_in(rng_module, 9), data, QUALITY)


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------


def test_qualityspec_validation():
    with pytest.raises(ValueError, match=r"QualitySpec\.k"):
        QualitySpec(k=0)
    with pytest.raises(ValueError, match="recall_target"):
        QualitySpec(recall_target=0.0)
    with pytest.raises(ValueError, match="approx_c"):
        QualitySpec(approx_c=1.0)
    with pytest.raises(ValueError, match="fail_prob"):
        QualitySpec(fail_prob=1.0)
    with pytest.raises(ValueError, match="latency_budget_ms"):
        QualitySpec(latency_budget_ms=0.0)
    with pytest.raises(ValueError, match="calibration_queries"):
        QualitySpec(calibration_queries=0)
    assert QualitySpec() == QualitySpec()  # frozen + hashable value object
    assert hash(QualitySpec()) == hash(QualitySpec())


def test_plannedspec_validation_and_conversion():
    with pytest.raises(ValueError, match=r"PlannedSpec\.mode"):
        PlannedSpec(k=5, mode="exact")
    with pytest.raises(ValueError, match=r"PlannedSpec\.n_probes"):
        PlannedSpec(k=5, mode="multiprobe", n_probes=0)
    with pytest.raises(ValueError, match=r"PlannedSpec\.max_flips"):
        PlannedSpec(k=5, mode="multiprobe", max_flips=-1)

    plan = PlannedSpec(k=5, mode="multiprobe", n_probes=4, max_flips=2,
                       max_candidates=32)
    qs = plan.to_query_spec()
    assert qs == QuerySpec(k=5, mode="multiprobe", n_probes=4, max_flips=2)
    cfg = _cfg(max_candidates=64)
    assert plan.effective_config(cfg).max_candidates == 32
    assert PlannedSpec(k=5, mode="probe", max_candidates=64).effective_config(cfg) is cfg
    with pytest.raises(ValueError, match="exceeds the built"):
        PlannedSpec(k=5, mode="probe", max_candidates=128).effective_config(cfg)


def test_query_rejects_unreachable_n_probes(rng):
    """Satellite: n_probes beyond the (K, max_flips) enumeration must be
    rejected, not silently probe duplicate buckets."""
    data, q, w = _problem(rng, salt=10)
    index = Index.build(jax.random.fold_in(rng, 19), data, _cfg(K=4))
    # reachable with K=4, max_flips=1: 1 + 4 = 5 keys
    index.query(q, w, QuerySpec(k=3, mode="multiprobe", n_probes=5, max_flips=1))
    with pytest.raises(ValueError, match="distinct probe keys reachable"):
        index.query(q, w, QuerySpec(k=3, mode="multiprobe", n_probes=6, max_flips=1))


def test_query_rejects_unknown_spec_type(rng):
    data, q, w = _problem(rng, salt=15)
    index = Index.build(jax.random.fold_in(rng, 18), data, _cfg())
    with pytest.raises(TypeError, match="spec must be"):
        index.query(q, w, {"k": 3})


def test_legacy_shims_warn():
    """Satellite: the package-level legacy shims deprecate toward the facade
    (the defining modules stay warning-free — the facade runs through them)."""
    from repro import core

    key = jax.random.PRNGKey(0)
    data = jax.random.uniform(key, (64, 8))
    cfg = _cfg(L=4)
    with pytest.warns(DeprecationWarning, match="repro.api.Index"):
        legacy = core.build_index(key, data, cfg)
    q = jax.random.uniform(jax.random.fold_in(key, 1), (2, 8))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (2, 8))) + 0.1
    with pytest.warns(DeprecationWarning, match="repro.api.Index"):
        core.query_index(legacy, q, w, cfg, k=2)
    with pytest.warns(DeprecationWarning, match="multiprobe"):
        core.query_multiprobe(legacy, q, w, cfg, k=2, n_probes=2)
    # the facade executes the same engine without tripping the shims
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        Index.build(key, data, cfg).query(q, w, QuerySpec(k=2))


# ---------------------------------------------------------------------------
# the tentpole contracts
# ---------------------------------------------------------------------------


def test_quality_query_bit_identical_to_planned(planned_index, rng_module):
    _, q, w = _problem(rng_module, salt=0)
    res_q = planned_index.query(q, w, QUALITY)
    plan = planned_index.plan(QUALITY)  # memo hit — resolved during build
    res_p = planned_index.query(q, w, plan)
    np.testing.assert_array_equal(np.asarray(res_q.ids), np.asarray(res_p.ids))
    np.testing.assert_array_equal(np.asarray(res_q.dists), np.asarray(res_p.dists))
    np.testing.assert_array_equal(
        np.asarray(res_q.n_candidates), np.asarray(res_p.n_candidates)
    )
    # and the planned spec is an honest mechanism spec: replaying it through
    # the knob path (QuerySpec + effective window) is also bit-identical
    knob = planned_index.query(
        q, w,
        dataclasses.replace(
            plan, predicted_recall=float("nan"),
            predicted_success=float("nan"), expected_candidates=float("nan"),
        ),
    )
    np.testing.assert_array_equal(np.asarray(res_q.ids), np.asarray(knob.ids))


def test_planning_is_deterministic(planned_index, rng_module):
    data, _, _ = _problem(rng_module, salt=0)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        rebuilt = Index.build(jax.random.fold_in(rng_module, 9), data, QUALITY)
    assert rebuilt.config == planned_index.config
    assert rebuilt.plan(QUALITY) == planned_index.plan(QUALITY)
    # a different sample seed may give a different plan object, but planning
    # stays a pure function of (index, seed)
    seeded = dataclasses.replace(QUALITY, seed=7)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        assert rebuilt.plan(seeded) == planned_index.plan(seeded)


def test_plan_is_memoized(planned_index):
    p1 = planned_index.plan(QUALITY)
    assert planned_index.plans[QUALITY] is p1
    assert planned_index.plan(QUALITY) is p1  # no second calibration


def test_planned_fields_are_calibrated(planned_index):
    plan = planned_index.plan(QUALITY)
    assert plan.mode in ("probe", "multiprobe")
    assert 0.0 <= plan.predicted_recall <= 1.0
    assert 0.0 <= plan.predicted_success <= 1.0
    assert plan.expected_candidates > 0
    assert plan.max_candidates <= planned_index.config.max_candidates


def test_latency_budget_prefers_cheaper_plans(rng):
    """A tight candidate budget must never pick a MORE expensive plan than
    the unconstrained resolution."""
    data, _, _ = _problem(rng, n=800, salt=20)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        index = Index.build(jax.random.fold_in(rng, 29), data, QUALITY)
        free = index.plan(QUALITY)
        tight = index.plan(
            dataclasses.replace(QUALITY, latency_budget_ms=0.001)
        )
    assert tight.expected_candidates <= free.expected_candidates + 1e-6


def test_plan_memo_survives_jit_crossing(planned_index, rng_module):
    _, q, w = _problem(rng_module, salt=0)

    @jax.jit
    def serve(ix, q, w):
        return ix.query(q, w, QUALITY).dists  # must resolve from the memo

    got = serve(planned_index, q, w)
    want = planned_index.query(q, w, QUALITY).dists
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_unplanned_quality_under_jit_raises(rng):
    data, q, w = _problem(rng, salt=30)
    index = Index.build(jax.random.fold_in(rng, 39), data, _cfg())

    @jax.jit
    def serve(ix, q, w):
        return ix.query(q, w, QUALITY).dists

    with pytest.raises(ValueError, match="cannot calibrate under jit"):
        serve(index, q, w)


# ---------------------------------------------------------------------------
# persistence (v3) and sharding
# ---------------------------------------------------------------------------


def test_plans_survive_save_load(planned_index, rng_module, tmp_path):
    _, q, w = _problem(rng_module, salt=0)
    want = planned_index.query(q, w, QUALITY)
    planned_index.save(str(tmp_path))

    meta = json.loads((tmp_path / "index.json").read_text())
    assert meta["version"] == 5
    assert len(meta["plans"]) == len(planned_index.plans)

    restored = Index.load(str(tmp_path))
    assert restored.plans == planned_index.plans  # exact float round trip
    got = restored.query(q, w, QUALITY)  # memo hit, no re-calibration
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(want.ids))
    np.testing.assert_array_equal(np.asarray(got.dists), np.asarray(want.dists))


def test_v2_directories_still_load(rng, tmp_path):
    """A pre-plan directory (v2 layout) must restore with an empty memo."""
    data, q, w = _problem(rng, salt=40)
    index = Index.build(jax.random.fold_in(rng, 49), data, _cfg())
    index.save(str(tmp_path))
    meta_path = tmp_path / "index.json"
    meta = json.loads(meta_path.read_text())
    meta["version"] = 2
    del meta["plans"]
    meta_path.write_text(json.dumps(meta))
    restored = Index.load(str(tmp_path))
    assert restored.plans == {}
    got = restored.query(q, w, QuerySpec(k=3))
    want = index.query(q, w, QuerySpec(k=3))
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(want.ids))


def test_plans_survive_shard(planned_index, rng_module):
    _, q, w = _problem(rng_module, salt=0)
    mesh = jax.make_mesh((1,), ("data",))
    sharded = planned_index.shard(mesh)
    assert sharded.plans == planned_index.plans
    res_q = sharded.query(q, w, QUALITY)
    res_p = sharded.query(q, w, planned_index.plan(QUALITY))
    np.testing.assert_array_equal(np.asarray(res_q.ids), np.asarray(res_p.ids))


def test_sharded_rejects_unplanned_quality(rng):
    data, q, w = _problem(rng, salt=50)
    index = Index.build(jax.random.fold_in(rng, 59), data, _cfg())
    sharded = index.shard(jax.make_mesh((1,), ("data",)))
    with pytest.raises(ValueError, match="BEFORE index.shard"):
        sharded.query(q, w, QUALITY)


def test_sharded_rejects_unreachable_n_probes(rng):
    """The sharded facade applies the same probe-reach gate as Index.query."""
    data, q, w = _problem(rng, salt=55)
    index = Index.build(jax.random.fold_in(rng, 58), data, _cfg(K=4))
    sharded = index.shard(jax.make_mesh((1,), ("data",)))
    with pytest.raises(ValueError, match="distinct probe keys reachable"):
        sharded.query(q, w, QuerySpec(k=3, mode="multiprobe", n_probes=6, max_flips=1))


# ---------------------------------------------------------------------------
# explain / QueryReport
# ---------------------------------------------------------------------------


def test_explain_matches_query_and_reports(planned_index, rng_module):
    _, q, w = _problem(rng_module, salt=0)
    b = q.shape[0]
    report = planned_index.explain(q, w, QUALITY)
    res = planned_index.query(q, w, QUALITY)
    np.testing.assert_array_equal(
        np.asarray(report.result.ids), np.asarray(res.ids)
    )
    assert report.quality == QUALITY
    assert report.spec == planned_index.plan(QUALITY)
    for field in ("predicted_success", "n_candidates", "truncated_tables", "n_invalid"):
        assert getattr(report, field).shape == (b,)
    assert np.all((report.predicted_success >= 0) & (report.predicted_success <= 1))
    assert np.all(report.n_invalid >= 0)
    d = report.to_dict()
    json.dumps(d)  # loggable
    assert d["quality"]["recall_target"] == QUALITY.recall_target


def test_explain_mechanism_spec_and_exact(rng):
    data, q, w = _problem(rng, salt=60)
    index = Index.build(jax.random.fold_in(rng, 69), data, _cfg())
    rep = index.explain(q, w, QuerySpec(k=3, mode="exact"))
    assert rep.quality is None
    np.testing.assert_array_equal(rep.truncated_tables, 0)
    np.testing.assert_array_equal(rep.n_candidates, index.n)
    rep_mp = index.explain(q, w, QuerySpec(k=3, mode="multiprobe", n_probes=4))
    assert rep_mp.spec == QuerySpec(k=3, mode="multiprobe", n_probes=4)


# ---------------------------------------------------------------------------
# build-time planning (plan_config)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["theta", "l2"])
def test_plan_config_families(rng, family):
    data, _, _ = _problem(rng, n=500, salt=70)
    cfg = Planner().plan_config(data, QUALITY, family=family)
    assert cfg.family == family
    assert cfg.d == data.shape[1]
    assert 1 <= cfg.K and 1 <= cfg.L
    if family == "l2":
        assert cfg.W > 0
    # the derived geometry must pass its own validation round trip
    assert dataclasses.replace(cfg) == cfg


def test_plan_config_auto_picks_lower_rho(rng):
    data, _, _ = _problem(rng, n=500, salt=80)
    planner = Planner()
    cfg = planner.plan_config(data, QUALITY, family="auto")
    assert cfg.family in ("theta", "l2")


def test_quality_build_meets_target_or_warns(rng):
    """The escalation loop either reaches the calibrated target or leaves
    the best-effort warning trail."""
    data, q, w = _problem(rng, n=800, b=16, salt=90)
    quality = QualitySpec(k=5, recall_target=0.85, calibration_queries=24)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        index = Index.build(jax.random.fold_in(rng, 99), data, quality)
    plan = index.plan(quality)
    warned = any("recall_target" in str(x.message) for x in rec)
    assert plan.predicted_recall >= quality.recall_target - 1e-9 or warned
    # held-out sanity: the planned path beats a deliberately starved spec
    res = index.query(q, w, quality)
    ref = index.query(q, w, QuerySpec(k=5, mode="exact"))
    assert recall_at_k(res.ids, ref.ids, 5) >= 0.5
