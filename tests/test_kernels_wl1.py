"""Pallas wl1 scan/re-rank kernels vs ref oracle (interpret=True sweeps)."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.wl1_distance import wl1_rerank_pallas, wl1_scan_pallas

SCAN_SHAPES = [
    (1, 1, 1),
    (33, 3, 7),
    (128, 8, 256),  # exact blocks
    (129, 9, 257),  # off-by-one
    (512, 16, 300),
]


@pytest.mark.parametrize("n,b,d", SCAN_SHAPES)
def test_scan_matches_ref(n, b, d):
    key = jax.random.PRNGKey(n + b + d)
    k1, k2, k3 = jax.random.split(key, 3)
    data = jax.random.normal(k1, (n, d))
    q = jax.random.normal(k2, (b, d))
    w = jax.random.normal(k3, (b, d))
    got = wl1_scan_pallas(data, q, w, interpret=True)
    want = ref.wl1_scan(data, q, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


RERANK_SHAPES = [(1, 1, 1), (3, 10, 7), (8, 128, 256), (5, 129, 300)]


@pytest.mark.parametrize("b,C,d", RERANK_SHAPES)
def test_rerank_matches_ref(b, C, d):
    key = jax.random.PRNGKey(b * 7 + C + d)
    k1, k2, k3 = jax.random.split(key, 3)
    pts = jax.random.normal(k1, (b, C, d))
    q = jax.random.normal(k2, (b, d))
    w = jax.random.normal(k3, (b, d))
    got = wl1_rerank_pallas(pts, q, w, interpret=True)
    want = ref.wl1_rerank(pts, q, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(
    n=st.integers(1, 64),
    b=st.integers(1, 10),
    d=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_scan_property(n, b, d, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    data = jax.random.normal(k1, (n, d))
    q = jax.random.normal(k2, (b, d))
    w = jax.random.normal(k3, (b, d))
    got = wl1_scan_pallas(data, q, w, interpret=True)
    want = ref.wl1_scan(data, q, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_scan_triangle_like_properties(rng):
    """wl1(o, o) = 0; positive weights ⇒ non-negative distances (oracle + kernel)."""
    d = 24
    data = jax.random.normal(rng, (16, d))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(rng, 1), (16, d)))
    got = wl1_scan_pallas(data, data, w, interpret=True)
    diag = jnp.diagonal(got)
    np.testing.assert_allclose(np.asarray(diag), 0.0, atol=1e-5)
    assert np.all(np.asarray(got) >= -1e-5)
