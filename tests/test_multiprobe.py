"""Multiprobe ALSH (beyond-paper): same recall from fewer tables."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BoundedSpace, IndexConfig, build_index, query_index
from repro.core.multiprobe import query_multiprobe
from repro.distance import brute_force_nn


def _recall(res, bf_ids, b, k):
    return np.mean([
        len(set(np.asarray(res.ids[i])) & set(np.asarray(bf_ids[i]))) / k
        for i in range(b)
    ])


def test_multiprobe_beats_single_probe_at_equal_tables(rng):
    n, d, M, b, k = 4000, 16, 16, 16, 10
    space = BoundedSpace(0.0, 1.0, float(M))
    data = jax.random.uniform(jax.random.fold_in(rng, 0), (n, d))
    q = jax.random.uniform(jax.random.fold_in(rng, 1), (b, d))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(rng, 2), (b, d))) + 0.2
    _, bf_ids = brute_force_nn(data, q, w, k=k)

    cfg_small = IndexConfig(d=d, M=M, K=10, L=4, family="theta",
                            max_candidates=128, space=space)
    idx = build_index(jax.random.fold_in(rng, 3), data, cfg_small)

    r1 = _recall(query_index(idx, q, w, cfg_small, k=k), bf_ids, b, k)
    r8 = _recall(query_multiprobe(idx, q, w, cfg_small, k=k, n_probes=8), bf_ids, b, k)
    assert r8 > r1 + 0.1, (r1, r8)


def test_multiprobe_matches_bigger_index(rng):
    """L=4 with 8 probes ≈ L=16 single-probe recall (4x less index memory)."""
    n, d, M, b, k = 4000, 16, 16, 16, 10
    space = BoundedSpace(0.0, 1.0, float(M))
    data = jax.random.uniform(jax.random.fold_in(rng, 10), (n, d))
    q = jax.random.uniform(jax.random.fold_in(rng, 11), (b, d))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(rng, 12), (b, d))) + 0.2
    _, bf_ids = brute_force_nn(data, q, w, k=k)

    cfg_small = IndexConfig(d=d, M=M, K=10, L=4, family="theta",
                            max_candidates=128, space=space)
    cfg_big = IndexConfig(d=d, M=M, K=10, L=16, family="theta",
                          max_candidates=128, space=space)
    idx_small = build_index(jax.random.fold_in(rng, 13), data, cfg_small)
    idx_big = build_index(jax.random.fold_in(rng, 13), data, cfg_big)

    r_multi = _recall(query_multiprobe(idx_small, q, w, cfg_small, k=k, n_probes=8),
                      bf_ids, b, k)
    r_big = _recall(query_index(idx_big, q, w, cfg_big, k=k), bf_ids, b, k)
    assert r_multi >= r_big - 0.15, (r_multi, r_big)


def test_probe_zero_equals_single_probe(rng):
    """n_probes=1 (no flips) must reproduce the paper's single-probe path."""
    n, d, M = 1000, 8, 8
    space = BoundedSpace(0.0, 1.0, float(M))
    data = jax.random.uniform(jax.random.fold_in(rng, 20), (n, d))
    q = jax.random.uniform(jax.random.fold_in(rng, 21), (4, d))
    w = jnp.ones((4, d))
    cfg = IndexConfig(d=d, M=M, K=8, L=8, family="theta",
                      max_candidates=64, space=space)
    idx = build_index(jax.random.fold_in(rng, 22), data, cfg)
    r_single = query_index(idx, q, w, cfg, k=3)
    r_multi = query_multiprobe(idx, q, w, cfg, k=3, n_probes=1)
    np.testing.assert_array_equal(np.asarray(r_single.ids), np.asarray(r_multi.ids))
