"""Elastic scaling: a training job checkpointed on an 8-device mesh resumes
on a 4-device mesh (node loss) and on 1 device, bit-identically.

This works because (a) checkpoints are stored device-layout-free, (b) the
data pipeline is a pure function of (seed, step, shard), and (c) shardings
are re-derived from specs at restore time — the mesh is a runtime property,
not part of the training state.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


CODE = """
import dataclasses, jax, numpy as np
from repro.configs import get_bundle, reduced_model
from repro.data.pipeline import DataConfig
from repro.runtime.fault import train_loop

bundle = get_bundle("gemma3-1b")
mcfg = dataclasses.replace(reduced_model(bundle.model), n_units=1, n_layers=8,
                           tail=("local", "local"))
bundle = dataclasses.replace(bundle, model=mcfg)
dcfg = DataConfig(seq_len=32, global_batch=4)
state = train_loop(bundle, dcfg, {steps}, {ckpt_dir!r}, ckpt_every=4)
leaves = jax.tree.leaves(state)
print("FINGERPRINT", float(sum(np.abs(np.asarray(l, np.float64)).sum() for l in leaves)))
"""


@pytest.mark.xfail(
    run=False,
    strict=False,
    reason=(
        "pre-seed failure: the assertion demands BIT-identical float64 "
        "fingerprints across 8-, 4-, and 1-device meshes, but data-parallel "
        "gradient psum reassociates float additions differently per device "
        "count, so the fingerprints drift by ~1 ulp per step. Checkpoint "
        "layout-freedom and resume correctness are covered by "
        "tests/test_fault.py; making cross-mesh reductions bit-deterministic "
        "would require a fixed-order (tree-sequential) all-reduce, which XLA "
        "does not expose. run=False: the 3 subprocess training runs cost "
        "minutes and the outcome is known."
    ),
)
def test_resume_across_device_counts(tmp_path):
    d = str(tmp_path / "ck")
    # phase 1: 8 "nodes" train to step 4 (commit at 4)
    _run(CODE.format(steps=4, ckpt_dir=d), devices=8)
    # phase 2: cluster shrinks to 4 nodes; resume 4 -> 8
    out_small = _run(CODE.format(steps=8, ckpt_dir=d), devices=4)
    # reference: uninterrupted single-device run to 8
    d2 = str(tmp_path / "ref")
    out_ref = _run(CODE.format(steps=8, ckpt_dir=d2), devices=1)
    fp_small = out_small.strip().splitlines()[-1]
    fp_ref = out_ref.strip().splitlines()[-1]
    assert fp_small == fp_ref, (fp_small, fp_ref)
