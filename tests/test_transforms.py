"""Property tests for the paper's §4.1 transforms (Eq 13, 19-24, Obs 1/2)."""

import hypothesis
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import transforms
from repro.distance import wl1_distance

settings = hypothesis.settings(max_examples=40, deadline=None)


def _levels(draw, d, M, seed):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randint(0, M + 1, size=(d,)), jnp.int32)


@settings
@hypothesis.given(
    d=st.integers(1, 24),
    M=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_eq21_identity(d, M, seed):
    """d_w^l1(o, q) == M*sum(w) - <P(o), Q_w(q)> exactly (Eq 21)."""
    rng = np.random.RandomState(seed)
    o = jnp.asarray(rng.randint(0, M + 1, size=(d,)), jnp.int32)
    q = jnp.asarray(rng.randint(0, M + 1, size=(d,)), jnp.int32)
    w = jnp.asarray(rng.randn(d), jnp.float32)
    direct = wl1_distance(o.astype(jnp.float32), q.astype(jnp.float32), w)
    via = transforms.wl1_via_mips(o, q, w, M)
    np.testing.assert_allclose(direct, via, rtol=1e-4, atol=1e-4)


@settings
@hypothesis.given(d=st.integers(1, 24), M=st.integers(1, 12), seed=st.integers(0, 2**31 - 1))
def test_eq22_eq23_norms(d, M, seed):
    """||P(o)||^2 = Md (data-independent) and ||Q_w(q)||^2 = M sum(w^2) (Eq 22/23)."""
    rng = np.random.RandomState(seed)
    o = jnp.asarray(rng.randint(0, M + 1, size=(d,)), jnp.int32)
    q = jnp.asarray(rng.randint(0, M + 1, size=(d,)), jnp.int32)
    w = jnp.asarray(rng.randn(d), jnp.float32)
    P = transforms.transform_P(o, M)
    Q = transforms.transform_Q(q, w, M)
    np.testing.assert_allclose(float(jnp.sum(P * P)), M * d, rtol=1e-5)
    np.testing.assert_allclose(
        float(jnp.sum(Q * Q)), float(M * jnp.sum(w * w)), rtol=1e-4
    )


@settings
@hypothesis.given(d=st.integers(1, 16), M=st.integers(1, 10), seed=st.integers(0, 2**31 - 1))
def test_unary_code_is_binary_and_monotone(d, M, seed):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randint(0, M + 1, size=(d,)), jnp.int32)
    v = transforms.unary_code(x, M)
    assert v.shape == (d, M)
    assert set(np.unique(np.asarray(v))).issubset({0.0, 1.0})
    # exactly x_i ones, prefix-packed
    np.testing.assert_array_equal(np.asarray(jnp.sum(v, axis=-1), np.int32), np.asarray(x))
    sorted_desc = np.sort(np.asarray(v), axis=-1)[:, ::-1]
    np.testing.assert_array_equal(np.asarray(v), sorted_desc)


@settings
@hypothesis.given(
    d=st.integers(1, 8),
    t=st.floats(0.5, 64.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_observation1_slack(d, t, seed):
    """|d_w^l1(u_t(o), u_t(q)) - t*d_w^l1(o, q)| <= sum|w| (Obs 1 inner inequality)."""
    rng = np.random.RandomState(seed)
    space = transforms.BoundedSpace(0.0, 1.0, t)
    o = jnp.asarray(rng.rand(d), jnp.float32)
    q = jnp.asarray(rng.rand(d), jnp.float32)
    w = jnp.asarray(rng.randn(d), jnp.float32)
    lo = transforms.discretize(o, space).astype(jnp.float32)
    lq = transforms.discretize(q, space).astype(jnp.float32)
    lattice = float(wl1_distance(lo, lq, w))
    scaled = float(t * wl1_distance(o, q, w))
    slack = float(jnp.sum(jnp.abs(w))) + 1e-4
    assert abs(lattice - scaled) <= slack


def test_discretize_range_and_clip():
    space = transforms.BoundedSpace(-2.0, 3.0, 10.0)
    M = space.M
    assert M == 50
    x = jnp.asarray([-2.0, 3.0, 0.0, 2.99999])
    lv = transforms.discretize(x, space)
    assert int(lv.min()) >= 0 and int(lv.max()) <= M


def test_observation2_cos_sin_identity():
    """w|o-q| = w - (cos,sin)(o) . w*(cos,sin)(q) for all bit pairs (Obs 2)."""
    for o in (0, 1):
        for q in (0, 1):
            for w in (-1.7, 0.0, 2.3):
                lhs = w * abs(o - q)
                co, so = np.cos(np.pi / 2 * o), np.sin(np.pi / 2 * o)
                cq, sq = np.cos(np.pi / 2 * q), np.sin(np.pi / 2 * q)
                rhs = w - (co * w * cq + so * w * sq)
                np.testing.assert_allclose(lhs, rhs, atol=1e-12)
