"""Adaptive probing (engine early exit): parity, stopping, and contracts.

The streamed tail (:mod:`repro.engine.stream`) replays the monolithic
pipeline's windows a trace-static group at a time and stops per query at
the geometric / Eq 25-27 confidence bound. Its correctness contract is
pinned here:

  * at ``exit_slack=0`` on duplicate-free data the streamed result is
    BIT-IDENTICAL (ids, dists, n_candidates) to ``early_exit=False`` —
    which the test_engine suite already pins to the PR 5 legacy oracle —
    across both families × sealed/segmented/quantized views and
    group sizes that do and do not divide the window count;
  * the streamed program never retraces across delta fill levels,
    tombstone churn, or batch content, and dead knobs (exit_group /
    exit_slack while ``early_exit=False``) do not mint compile keys;
  * adversarial stopping: all queries stopping in the FIRST group
    (duplicate rows at distance 0 → geometric) and NO query stopping
    (slack 0, distinct rows → exhausted) both return correct results with
    correctly stamped ``tables_probed`` / ``stop_reason``;
  * the multiprobe rank contract ``probe_keys(..., with_ranks=True)``
    exposes: P-axis position is the per-query probe quality rank, and the
    keys are bit-identical to the rank-free call.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.api import (
    BoundedSpace,
    Index,
    IndexConfig,
    QuerySpec,
    UpdateSpec,
)
from repro.engine.stream import (
    STOP_CONFIDENCE,
    STOP_EXHAUSTED,
    STOP_GEOMETRIC,
    window_order,
)

N = 400
D = 8
CAP = 64


def _cfg(family="theta", **kw):
    kw.setdefault("max_candidates", N + CAP)  # no window truncation (parity)
    kw.setdefault("space", BoundedSpace(0.0, 1.0, 8.0))
    kw.setdefault("W", 8.0)
    kw.setdefault("K", 6)
    kw.setdefault("L", 10)
    return IndexConfig(d=D, M=8, family=family, **kw)


def _problem(rng, salt=0, m=37, b=5):
    data = jax.random.uniform(jax.random.fold_in(rng, salt), (N, D))
    extra = jax.random.uniform(jax.random.fold_in(rng, salt + 1), (m, D))
    q = jax.random.uniform(jax.random.fold_in(rng, salt + 2), (b, D))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(rng, salt + 3), (b, D))) + 0.2
    return data, extra, q, w


def _index_for(rng, data, extra, family, view):
    bkey = jax.random.fold_in(rng, 9)
    if view == "sealed":
        return Index.build(bkey, data, _cfg(family=family))
    if view == "quantized":
        return Index.build(bkey, data, _cfg(family=family, storage="int8"))
    index = Index.build(
        bkey, data, _cfg(family=family), update=UpdateSpec(delta_capacity=CAP)
    )
    index, ids = index.insert(extra)
    return index.delete(
        jnp.asarray([0, 5, 17, int(ids[3]), int(ids[11])], jnp.int32)
    )


def _assert_bit_identical(got, want):
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(want.ids))
    np.testing.assert_array_equal(np.asarray(got.dists), np.asarray(want.dists))
    np.testing.assert_array_equal(
        np.asarray(got.n_candidates), np.asarray(want.n_candidates)
    )


# ---------------------------------------------------------------------------
# slack-0 bit-identity: streamed == monolithic, the full matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["theta", "l2"])
@pytest.mark.parametrize("mode", ["probe", "multiprobe"])
@pytest.mark.parametrize("view", ["sealed", "segmented", "quantized"])
def test_slack_zero_streamed_matches_monolithic(rng, family, mode, view):
    if family == "l2" and mode == "multiprobe":
        pytest.skip("l2 family does not support multiprobe")
    data, extra, q, w = _problem(rng)
    index = _index_for(rng, data, extra, family, view)
    off = QuerySpec(k=7, mode=mode)
    on = QuerySpec(k=7, mode=mode, early_exit=True, exit_group=4,
                   exit_slack=0.0)
    res_on = index.query(q, w, on)
    _assert_bit_identical(res_on, index.query(q, w, off))
    # full pass: every query exhausts every window, stamped as such
    P = 1 if mode == "probe" else int(
        engine.probe_keys(index.state, q, w, index.config, mode=mode,
                          n_probes=on.n_probes, max_flips=on.max_flips).shape[2]
    )
    np.testing.assert_array_equal(
        np.asarray(res_on.tables_probed), index.config.L * P
    )
    np.testing.assert_array_equal(np.asarray(res_on.stop_reason), STOP_EXHAUSTED)


def test_slack_zero_identical_for_nondividing_group(rng):
    """Group sizes that do NOT divide L·P exercise the padded last group —
    the repeated window must dedupe away without changing the result."""
    data, extra, q, w = _problem(rng)
    index = _index_for(rng, data, extra, "theta", "segmented")
    want = index.query(q, w, QuerySpec(k=7))
    for G in (3, 4, 7):  # L=10: 10 % G != 0 for 3, 4, 7
        got = index.query(
            q, w, QuerySpec(k=7, early_exit=True, exit_group=G, exit_slack=0.0)
        )
        _assert_bit_identical(got, want)


def test_negative_weights_disable_geometric_stop(rng):
    """Negative weights can make distances negative — the zero bound is
    unsound there, so streamed results must still match the monolithic
    tail bit for bit (the rule never fires)."""
    data, extra, q, _ = _problem(rng)
    w = jax.random.normal(jax.random.fold_in(rng, 77), q.shape)  # mixed sign
    index = _index_for(rng, data, extra, "theta", "sealed")
    on = QuerySpec(k=7, early_exit=True, exit_group=4, exit_slack=0.0)
    res = index.query(q, w, on)
    _assert_bit_identical(res, index.query(q, w, QuerySpec(k=7)))
    np.testing.assert_array_equal(np.asarray(res.stop_reason), STOP_EXHAUSTED)


# ---------------------------------------------------------------------------
# adversarial stopping
# ---------------------------------------------------------------------------


def test_all_queries_stop_in_first_group(rng):
    """Every query finds k exact duplicates at distance 0 in its own
    bucket: the geometric bound fires after the FIRST group for all of
    them, and the answers are exactly those duplicates."""
    k, b = 4, 3
    q = jax.random.uniform(jax.random.fold_in(rng, 0), (b, D))
    filler = jax.random.uniform(jax.random.fold_in(rng, 1), (N - b * k, D))
    # k copies of each query, then filler; ids of q[i]'s copies are known
    data = jnp.concatenate([jnp.repeat(q, k, axis=0), filler])
    index = Index.build(jax.random.fold_in(rng, 9), data, _cfg())
    w = jnp.ones((b, D))
    res = index.query(
        q, w, QuerySpec(k=k, early_exit=True, exit_group=4, exit_slack=0.0)
    )
    np.testing.assert_array_equal(np.asarray(res.stop_reason), STOP_GEOMETRIC)
    np.testing.assert_array_equal(np.asarray(res.tables_probed), 4)
    np.testing.assert_array_equal(np.asarray(res.dists), 0.0)
    want_ids = np.arange(b * k).reshape(b, k)  # ascending id among dist ties
    np.testing.assert_array_equal(np.asarray(res.ids), want_ids)


def test_confidence_stop_fires_and_stays_correct(rng):
    """A loose slack stops easy queries early (reason CONFIDENCE, fewer
    windows) while the returned neighbours still match the exact oracle on
    clustered data where rank-0 probes find the true neighbour."""
    # tight cluster around each query: its neighbour is in its own bucket
    q = jax.random.uniform(jax.random.fold_in(rng, 0), (4, D)) * 0.8 + 0.1
    near = q[:, None, :] + 1e-3 * jax.random.normal(
        jax.random.fold_in(rng, 1), (4, 8, D)
    )
    filler = jax.random.uniform(jax.random.fold_in(rng, 2), (N - 32, D))
    data = jnp.concatenate([near.reshape(-1, D), filler])
    index = Index.build(jax.random.fold_in(rng, 9), data, _cfg(L=16))
    w = jnp.ones((4, D))
    res = index.query(
        q, w, QuerySpec(k=3, early_exit=True, exit_group=4, exit_slack=0.4)
    )
    probed = np.asarray(res.tables_probed)
    reasons = np.asarray(res.stop_reason)
    assert (reasons == STOP_CONFIDENCE).any(), (probed, reasons)
    assert probed[reasons == STOP_CONFIDENCE].max() < index.config.L
    # stopped early, still right: top-1 is each query's nearest cluster row
    exact = index.query(q, w, QuerySpec(k=3, mode="exact"))
    np.testing.assert_array_equal(
        np.asarray(res.ids[:, 0]), np.asarray(exact.ids[:, 0])
    )


# ---------------------------------------------------------------------------
# trace contract: one program across fills, batches, and dead knobs
# ---------------------------------------------------------------------------


def test_streamed_no_retrace_across_fills_and_batches(rng):
    """One compiled streamed program per (geometry, spec) across the
    index's whole mutable life AND across query batch contents."""
    from repro.analysis import cache_size

    data, extra, q, w = _problem(rng)
    index = Index.build(
        jax.random.fold_in(rng, 9), data, _cfg(),
        update=UpdateSpec(delta_capacity=CAP),
    )
    spec = QuerySpec(k=5, early_exit=True, exit_group=4, exit_slack=0.1)
    jq = jax.jit(lambda ix, q, w: ix.query(q, w, spec))
    jins = jax.jit(lambda ix, rows: ix.insert(rows))
    jdel = jax.jit(lambda ix, ids: ix.delete(ids))
    for i in range(4):
        index, _ = jins(index, extra[i * 8 : (i + 1) * 8])
        index = jdel(index, jnp.asarray([i * 3], jnp.int32))
        qb = jax.random.uniform(jax.random.fold_in(rng, 100 + i), q.shape)
        jq(index, qb, w)
    assert cache_size(jq) == 1


def test_dead_exit_knobs_share_compiled_program(rng):
    """exit_group / exit_slack are normalized away while early_exit=False,
    and fold-to-off corners (single group, exact mode) reuse the
    monolithic program instead of minting streamed keys."""
    from repro.analysis import RetraceGuard

    data, extra, q, w = _problem(rng)
    index = _index_for(rng, data, extra, "theta", "sealed")
    r1 = index.query(q, w, QuerySpec(k=3))
    with RetraceGuard() as guard:
        r2 = index.query(
            q, w, QuerySpec(k=3, early_exit=False, exit_group=16, exit_slack=0.5)
        )
        guard.assert_no_retrace(context="dead knobs while early_exit=False")
    _assert_bit_identical(r1, r2)
    with RetraceGuard() as guard:
        r3 = index.query(
            q, w,
            # exit_group >= L·P ⇒ one group ⇒ normalized back to monolithic
            QuerySpec(k=3, early_exit=True, exit_group=64, exit_slack=0.1),
        )
        guard.assert_no_retrace(context="single-group early exit folds to off")
    _assert_bit_identical(r1, r3)


def test_early_exit_spec_validation():
    with pytest.raises(ValueError, match="exact"):
        QuerySpec(k=3, mode="exact", early_exit=True)
    with pytest.raises(ValueError, match="exit_group"):
        QuerySpec(k=3, early_exit=True, exit_group=0)
    with pytest.raises(ValueError, match="exit_slack"):
        QuerySpec(k=3, early_exit=True, exit_slack=1.0)


# ---------------------------------------------------------------------------
# window order + multiprobe rank contract
# ---------------------------------------------------------------------------


def test_window_order_is_quality_major_and_padded():
    tbl, ranks, n_windows, n_groups = window_order(L=10, P=3, exit_group=4)
    assert n_windows == 30 and n_groups == 8
    assert tbl.shape == (32,) and ranks.shape == (32,)
    # all rank-0 windows stream before any rank-1 window
    np.testing.assert_array_equal(tbl[:10], np.arange(10))
    np.testing.assert_array_equal(ranks[:10], 0)
    np.testing.assert_array_equal(ranks[10:20], 1)
    # padding repeats the LAST real window
    np.testing.assert_array_equal(tbl[30:], 9)
    np.testing.assert_array_equal(ranks[30:], 2)


def test_probe_keys_rank_contract(rng):
    """with_ranks=True: keys bit-identical to the rank-free call, ranks
    are the P-axis position (the multiprobe family emits most-likely
    first), zeros in probe mode."""
    data, extra, q, w = _problem(rng)
    index = _index_for(rng, data, extra, "theta", "sealed")
    state, cfg = index.state, index.config
    plain = engine.probe_keys(state, q, w, cfg, mode="multiprobe",
                              n_probes=4, max_flips=2)
    keys, ranks = engine.probe_keys(state, q, w, cfg, mode="multiprobe",
                                    n_probes=4, max_flips=2, with_ranks=True)
    np.testing.assert_array_equal(np.asarray(keys), np.asarray(plain))
    assert ranks.shape == keys.shape
    np.testing.assert_array_equal(
        np.asarray(ranks),
        np.broadcast_to(np.arange(keys.shape[2])[None, None, :], keys.shape),
    )
    pkeys, pranks = engine.probe_keys(state, q, w, cfg, with_ranks=True)
    assert pkeys.shape == (q.shape[0], cfg.L, 1)
    np.testing.assert_array_equal(np.asarray(pranks), 0)


# ---------------------------------------------------------------------------
# reporting: the stamps ride QueryReport / explain
# ---------------------------------------------------------------------------


def test_explain_stamps_tables_probed_and_stop_reason(rng):
    data, extra, q, w = _problem(rng)
    index = _index_for(rng, data, extra, "theta", "sealed")
    on = QuerySpec(k=5, early_exit=True, exit_group=4, exit_slack=0.1)
    rep = index.explain(q, w, on)
    assert rep.tables_probed is not None and rep.stop_reason is not None
    assert rep.tables_probed.shape == (q.shape[0],)
    d = rep.to_dict()
    assert d["mean_tables_probed"] == pytest.approx(
        float(np.mean(rep.tables_probed))
    )
    assert sum(d["stop_reasons"].values()) == q.shape[0]
    # monolithic plans stamp None — the report distinguishes "probed all
    # by design" from "streamed and exhausted"
    rep_off = index.explain(q, w, QuerySpec(k=5))
    assert rep_off.tables_probed is None and rep_off.stop_reason is None
    assert rep_off.to_dict()["mean_tables_probed"] is None
