"""Theorem 3 mechanism demo: no *symmetric* LSH can handle query-time weights.

Thm 3 is an impossibility result — not implementable as an algorithm. This
test demonstrates its proof mechanism concretely: a single pair (o, q) is
pushed to distance R1 by one weight vector and R2 by another, while any
weight-oblivious (symmetric) hash family necessarily gives the SAME collision
probability for both — so it cannot be (R1, R2, P1, P2)-sensitive. Our
asymmetric family distinguishes the two cases.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hash_families as hf
from repro.distance import wl1_distance


def test_theorem3_mechanism():
    """Two weight vectors with IDENTICAL norm profiles (sum w, sum w^2) select
    different coordinates of |o - q|, pushing the same pair to distance R1 or
    R2. A symmetric hash gives one collision probability for both (the Thm 3
    contradiction); the asymmetric family separates them.

    (Norm profiles are held fixed because the theta family is scale-invariant
    in w — Eq 27 depends on r only relative to M*sqrt(d * sum w^2).)
    """
    d, M = 4, 8
    o = jnp.asarray([[0, 0, 0, 0]], jnp.int32)
    q = jnp.asarray([[1, 5, 0, 0]], jnp.int32)  # |o - q| = (1, 5, 0, 0)
    R1, R2 = 1.0, 5.0
    w_near = jnp.asarray([[1.0, 0.0, 0.0, 0.0]])  # d_w = 1 = R1
    w_far = jnp.asarray([[0.0, 1.0, 0.0, 0.0]])  # d_w = 5 = R2
    assert float(wl1_distance(o.astype(float), q.astype(float), w_near)[0]) == R1
    assert float(wl1_distance(o.astype(float), q.astype(float), w_far)[0]) == R2

    # Symmetric hashing (hash both sides with f = data hash): collision
    # probability cannot depend on w — identical for both weight vectors.
    params = hf.LSHParams(d=d, M=M, n_hashes=4096, family="theta")
    tables = hf.make_prefix_tables(jax.random.PRNGKey(0), params)
    fo = hf.hash_data(o, tables, params, impl="gather")
    fq = hf.hash_data(q, tables, params, impl="gather")
    p_sym = float(jnp.mean((fo == fq).astype(jnp.float32)))
    # trivially the same number whichever w "applies" — the Thm 3 contradiction.

    # Asymmetric hashing DOES separate the two cases:
    g_near = hf.hash_query(q, w_near, tables, params, impl="gather")
    g_far = hf.hash_query(q, w_far, tables, params, impl="gather")
    p_near = float(jnp.mean((fo == g_near).astype(jnp.float32)))
    p_far = float(jnp.mean((fo == g_far).astype(jnp.float32)))
    assert p_near > p_far + 0.02, (p_near, p_far, p_sym)

    # and the empirical gap matches Eq 27 closed forms
    from repro.core import theory

    ana_near = float(theory.collision_prob_theta(jnp.asarray(R1), M, d, w_near[0]))
    ana_far = float(theory.collision_prob_theta(jnp.asarray(R2), M, d, w_far[0]))
    np.testing.assert_allclose(p_near, ana_near, atol=0.03)
    np.testing.assert_allclose(p_far, ana_far, atol=0.03)
