"""Serving tier: degradation ladder, dynamic batching (no retrace),
admission control, SLO degradation, shard chaos + recovery.

The broker tests run in VIRTUAL time with an injected ``service_time_fn``,
so queueing/degradation/shedding dynamics are deterministic on any
machine — wall-clock only enters through the (asserted-warm) jit cache.
"""

import numpy as np
import pytest

import jax

from repro.api import Index, QualitySpec, QuerySpec
from repro.api.index import validate_query_args
from repro.serving import (
    Broker,
    BrokerConfig,
    ChaosPlan,
    ShardSet,
    SLOConfig,
    bursty_trace,
    poisson_trace,
    requests_from_trace,
)

N, D, K = 512, 8, 5


@pytest.fixture(scope="module")
def built():
    rng = np.random.default_rng(0)
    data = rng.normal(size=(N, D)).astype(np.float32)
    quality = QualitySpec(k=K, recall_target=0.8)
    index = Index.build(jax.random.PRNGKey(0), data, quality)
    return index, quality


@pytest.fixture(scope="module")
def qw():
    rng = np.random.default_rng(1)
    q = rng.normal(size=(64, D)).astype(np.float32)
    w = np.abs(rng.normal(size=(64, D))).astype(np.float32) + 0.1
    return q, w


# --- degradation ladder -----------------------------------------------------


def test_plan_ladder_rung0_is_the_planned_spec(built):
    index, quality = built
    ladder = index.plan_ladder(quality)
    assert ladder[0] == index.plan(quality)
    assert len(ladder) >= 2  # this config must leave degradation headroom


def test_plan_ladder_strictly_cheaper_and_labeled(built):
    index, quality = built
    ladder = index.plan_ladder(quality)
    for spec in ladder:
        # every rung carries the calibrated label a degraded response stamps
        assert 0.0 <= spec.predicted_recall <= 1.0
        assert 0.0 <= spec.predicted_success <= 1.0
        assert spec.expected_candidates >= 0.0
    recalls = [float(s.predicted_recall) for s in ladder]
    assert recalls[0] == max(recalls)


def test_plan_ladder_memoized_and_seeds_plan(built):
    index, quality = built
    ladder = index.plan_ladder(quality)
    assert index.plan_ladder(quality) is ladder  # memo hit
    assert index.plans[quality] == ladder[0]


# --- argument validation (satellite) ---------------------------------------


def test_nonfinite_queries_rejected(built, qw):
    index, _ = built
    q, w = (x.copy() for x in qw)
    q[3, 0] = np.nan
    q[7, 2] = np.inf
    with pytest.raises(ValueError, match=r"queries.*non-finite.*\b3\b.*\b7\b"):
        index.query(q, w, QuerySpec(k=K))


def test_nonfinite_weights_rejected():
    w = np.ones((4, 3), np.float32)
    w[2, 1] = -np.inf
    with pytest.raises(ValueError, match="weights.*non-finite.*2"):
        validate_query_args(3, np.zeros((4, 3), np.float32), w)


def test_finite_args_pass_validation(qw):
    validate_query_args(D, *qw)


# --- arrival traces ---------------------------------------------------------


def test_traces_deterministic_and_ascending():
    a = poisson_trace(100.0, 50, seed=7)
    b = poisson_trace(100.0, 50, seed=7)
    np.testing.assert_array_equal(a, b)
    assert (np.diff(a) > 0).all()
    c = bursty_trace(50.0, 500.0, 50, seed=7)
    assert (np.diff(c) > 0).all()
    with pytest.raises(ValueError, match="rate_hz"):
        poisson_trace(0.0, 5)
    with pytest.raises(ValueError, match="burst_hz"):
        bursty_trace(100.0, 10.0, 5)


# --- dynamic batching: bucket ladder + asserted no-retrace ------------------


def test_bucket_ladder_covers_and_rounds_up(built):
    index, quality = built
    broker = Broker(index, quality, SLOConfig(p99_ms=50.0),
                    BrokerConfig(max_batch=8, warmup=False))
    assert broker.buckets == [1, 2, 4, 8]
    assert broker.bucket_for(1) == 1
    assert broker.bucket_for(3) == 4
    assert broker.bucket_for(8) == 8
    assert broker.bucket_for(99) == 8  # clamped to max_batch


def test_ragged_arrivals_never_retrace(built, qw):
    index, quality = built
    broker = Broker(index, quality, SLOConfig(p99_ms=1e6),
                    BrokerConfig(max_batch=8, max_queue=64))
    # ragged gaps force every bucket size through the engine
    arrivals = np.cumsum(np.resize([1e-4, 1e-4, 1e-4, 0.05, 1e-4, 0.05], 60))
    responses, stats = broker.run(requests_from_trace(arrivals, *qw))
    broker.assert_no_retrace()
    assert stats.served == 60 and stats.shed == 0
    assert all(r.status == "ok" for r in responses)


def test_assert_no_retrace_needs_warmup(built):
    index, quality = built
    broker = Broker(index, quality, SLOConfig(p99_ms=50.0),
                    BrokerConfig(warmup=False))
    with pytest.raises(RuntimeError, match="warmup"):
        broker.assert_no_retrace()


# --- admission control: bounded queue + deadlines ---------------------------


def test_queue_overflow_and_deadline_shed_are_labeled(built, qw):
    index, quality = built
    # service is 10x slower than arrivals: the bounded queue must overflow
    # and the stragglers must blow their deadline — both shed WITH a reason
    slo = SLOConfig(p99_ms=10.0, deadline_ms=25.0, patience=10_000)
    broker = Broker(index, quality, slo,
                    BrokerConfig(max_batch=2, max_queue=4),
                    service_time_fn=lambda bucket, rung, spec: 0.02)
    arrivals = np.arange(40) * 1e-3  # 1000/s vs ~100/s service
    responses, stats = broker.run(requests_from_trace(arrivals, *qw))
    reasons = {r.shed_reason for r in responses if r.status == "shed"}
    assert reasons == {"queue_full", "deadline"}
    assert stats.shed > 0 and stats.shed_rate > 0.0
    assert stats.served + stats.shed == 40
    # the deadline gates DEQUEUE: a served request waited at most the
    # deadline in queue, then accrued one 20ms modeled service round
    for r in responses:
        if r.status != "shed":
            assert r.latency_ms <= slo.effective_deadline_ms + 20.0 + 1e-6


# --- SLO degradation: overload served within SLO, labeled -------------------


def test_overload_degrades_within_slo_and_labels(built, qw):
    index, quality = built
    ladder = index.plan_ladder(quality)
    slo = SLOConfig(p99_ms=30.0, patience=10_000)  # never walk back up

    # rung 0 can't sustain the offered load; deeper rungs can (modeled)
    def svc(bucket, rung, spec):
        return 0.02 if rung == 0 else 0.002

    broker = Broker(index, quality, slo,
                    BrokerConfig(max_batch=4, max_queue=512),
                    service_time_fn=svc)
    arrivals = np.arange(300) * (1 / 400.0)  # 400/s vs 200/s rung-0 capacity
    responses, stats = broker.run(requests_from_trace(arrivals, *qw))
    broker.assert_no_retrace()

    assert stats.shed == 0  # degradation absorbed the overload, not shedding
    assert stats.degrades >= 1 and max(stats.rung_counts) > 0
    # steady state: the EWMA p99 settled back inside the SLO
    assert broker.tracker.p99_ms <= slo.p99_ms
    # the tail of the run is actually served within the SLO
    tail = [r for r in responses if r.status != "shed"][-50:]
    assert max(r.latency_ms for r in tail) <= slo.p99_ms
    # degraded responses are labeled with their rung's calibrated prediction
    for r in responses:
        if r.rung > 0:
            assert r.status == "degraded"
            assert r.predicted_recall == float(ladder[r.rung].predicted_recall)
            assert r.predicted_success == float(ladder[r.rung].predicted_success)


# --- chaos: shard kill, labeled coverage, backoff recovery ------------------


@pytest.fixture(scope="module")
def shardset_env(built, tmp_path_factory):
    index, quality = built
    root = tmp_path_factory.mktemp("shards")
    return index, quality, str(root)


def test_shardset_exact_matches_single_host(built, qw, tmp_path):
    """Shard-exact + host merge == single-host exact: the merge is exact."""
    index, _ = built
    ss = ShardSet.build(index, 4, str(tmp_path))
    spec = QuerySpec(k=K, mode="exact")
    got = ss.query(*qw, spec)
    ref = index.query(*qw, spec)
    np.testing.assert_array_equal(got.ids, np.asarray(ref.ids))
    np.testing.assert_allclose(got.dists, np.asarray(ref.dists), rtol=1e-6)
    assert got.coverage == 1.0


def test_shard_kill_mid_stream_coverage_and_recovery(built, qw, tmp_path):
    index, quality = built
    spec = index.plan(quality)
    ss = ShardSet.build(index, 4, str(tmp_path))
    pre = ss.query(*qw, spec)

    base, cap = 0.01, 0.015
    ss.chaos = ChaosPlan(kill_shard=2, kill_at_s=0.05, recovery_failures=2,
                         backoff_base_s=base, backoff_cap_s=cap)
    broker = Broker(index, quality, SLOConfig(p99_ms=1e6),
                    BrokerConfig(max_batch=4, max_queue=256), shardset=ss,
                    service_time_fn=lambda b, r, s: 0.004)
    arrivals = np.arange(200) * (1 / 500.0)
    responses, stats = broker.run(requests_from_trace(arrivals, *qw))
    broker.assert_no_retrace()

    served = [r for r in responses if r.status != "shed"]
    covs = {round(r.coverage, 6) for r in served}
    # survivors kept answering, labeled with exactly (S-1)/S coverage
    assert covs == {0.75, 1.0}
    for r in served:
        if r.coverage < 1.0:
            assert r.status == "degraded"
            k_ids = r.ids
            assert k_ids is not None and len(k_ids) == K

    # dead shard's rows never appear while it is down
    lo, hi = 2 * (N // 4), 3 * (N // 4)
    for r in served:
        if r.coverage < 1.0:
            in_dead = (r.ids >= lo) & (r.ids < hi)
            assert not in_dead.any()

    events = [e["event"] for e in ss.recovery_log]
    assert events == ["killed", "recover_failed", "recover_failed", "recovered"]
    # capped exponential backoff: base, then min(2*base, cap)
    backoffs = [e["next_backoff_s"] for e in ss.recovery_log
                if e["event"] == "recover_failed"]
    assert backoffs == [base, cap]

    # recovered shard answers bit-identically to the pre-failure set
    assert ss.coverage == 1.0
    post = ss.query(*qw, spec)
    np.testing.assert_array_equal(pre.ids, post.ids)
    np.testing.assert_array_equal(pre.dists, post.dists)
    assert stats.mean_coverage < 1.0  # the outage shows up in the aggregate


def test_shard_row_ranges_validation():
    from repro.core.distributed import merge_topk_host, shard_row_ranges

    assert shard_row_ranges(8, 2) == [(0, 4), (4, 8)]
    with pytest.raises(ValueError, match="equal"):
        shard_row_ranges(10, 4)

    # host merge: sentinels (dead shard) sink; ties broken stably
    d = np.array([[[0.5, np.inf]], [[np.inf, np.inf]], [[0.2, 0.7]]])
    i = np.array([[[3, -1]], [[-1, -1]], [[10, 11]]])
    md, mi = merge_topk_host(d, i, 3)
    np.testing.assert_array_equal(mi[0], [10, 3, 11])
    np.testing.assert_allclose(md[0], [0.2, 0.5, 0.7])
