"""Tests for the trace-contract analyzer (repro.analysis).

Layer 1 (lint): every RPR rule fires on a minimal bad snippet and stays
silent on the clean counterpart; the inline allowlist suppresses findings
only when it carries a reason (RPR000 otherwise).

Layer 2 (audit): the HEAD lattice passes every budget and round-trips
through the golden file; two seeded regressions — the pre-PR5 dense
delta-match materialization and an unfolded static axis — fail with the
named AUD001/AUD002 diagnostics, measured-vs-budget numbers included.

The audit index builds cost ~45 s, so they run ONCE in a module fixture
and every ``run_audit`` call reuses them via monkeypatch.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (
    RetraceError,
    RetraceGuard,
    cache_size,
    engine_cache_size,
    lint_source,
)
from repro.analysis import audit, budgets
from repro.analysis.lint import RULES

ENGINE = "repro/engine/mod.py"  # traced + hot scope
KERNELS = "repro/kernels/mod.py"
OUTSIDE = "repro/serving/mod.py"  # neither traced nor hot


def codes(src, relpath=ENGINE):
    return [f.code for f in lint_source(src, relpath)]


# ---------------------------------------------------------------------------
# lint: one bad + one clean snippet per rule
# ---------------------------------------------------------------------------


def test_rpr001_tracer_branch_fires_and_clean():
    bad = "def f(x):\n    if jnp.sum(x) > 0:\n        return x\n    return -x\n"
    assert codes(bad) == ["RPR001"]
    # static branch: clean
    assert codes("def f(x, flag):\n    if flag:\n        return x\n    return -x\n") == []
    # same traced branch OUTSIDE the traced scopes: clean
    assert codes(bad, OUTSIDE) == []


def test_rpr001_while_ternary_assert():
    assert codes("def f(x):\n    while jnp.any(x):\n        x = x - 1\n") == ["RPR001"]
    assert codes("def f(x):\n    y = 1 if jnp.all(x) else 2\n    return y\n") == ["RPR001"]
    assert codes("def f(x):\n    assert jnp.isfinite(x).all()\n") == ["RPR001"]


def test_rpr002_host_sync_fires_and_clean():
    assert codes("def f(x):\n    return x.item()\n") == ["RPR002"]
    assert codes("def f(x):\n    return np.asarray(x)\n") == ["RPR002"]
    assert codes("def f(x):\n    return float(g(x))\n") == ["RPR002"]
    # off the hot path (serving may sync): clean
    assert codes("def f(x):\n    return x.item()\n", OUTSIDE) == []
    # float over a plain name is not flagged (usually a python scalar)
    assert codes("def f(x):\n    return float(x)\n") == []


def test_rpr003_distance_fill_fires_and_clean():
    assert codes("def f():\n    return jnp.full((2,), 1e9)\n") == ["RPR003"]
    assert codes("def f(x):\n    return x + 1e38\n") == ["RPR003"]
    assert codes("def f():\n    return jnp.full((2,), jnp.inf)\n") == []


def test_rpr004_id_sentinel_fires_and_clean():
    assert codes("def f():\n    return jnp.full((2,), -2)\n") == ["RPR004"]
    assert codes("def f(ids):\n    return ids == -7\n") == ["RPR004"]
    assert codes("def f(ids):\n    return jnp.full((2,), -1), ids == -1\n") == []


def test_rpr005_unhashable_static_default():
    bad = (
        "@functools.partial(jax.jit, static_argnames=('opts',))\n"
        "def f(x, opts=[]):\n    return x\n"
    )
    assert codes(bad) == ["RPR005"]
    ok = (
        "@functools.partial(jax.jit, static_argnames=('opts',))\n"
        "def f(x, opts=()):\n    return x\n"
    )
    assert codes(ok) == []


def test_rpr006_import_time_jnp_fires_and_clean():
    assert codes("X = jnp.arange(4)\n") == ["RPR006"]
    assert codes("def f():\n    return jnp.arange(4)\n") == []
    # static metadata at module scope is fine (quant codec tables do this)
    assert codes("DT = jnp.dtype('int8')\n") == []


def test_rpr007_pallas_confined_to_kernels():
    call = "def f(k):\n    return pl.pallas_call(k, out_shape=None)\n"
    imp = "from jax.experimental import pallas as pl\n"
    assert codes(call) == ["RPR007"]
    assert codes(imp) == ["RPR007"]
    assert codes(call, KERNELS) == []
    assert codes(imp, KERNELS) == []


def test_rpr008_private_jit_poke():
    assert codes("def f(fn):\n    return fn._cache_size()\n") == ["RPR008"]
    assert codes("def f(fn):\n    return fn._cache_size()\n", "repro/analysis/x.py") == []


def test_allowlist_needs_reason_and_suppresses():
    bad = "def f(x):\n    if jnp.sum(x) > 0:  # repro: allow[RPR001]\n        return x\n"
    assert codes(bad) == ["RPR000", "RPR001"]  # reasonless marker suppresses nothing
    ok = "def f(x):\n    if jnp.sum(x) > 0:  # repro: allow[RPR001] host-only helper\n        return x\n"
    assert codes(ok) == []
    # marker on the line above also covers the finding
    above = (
        "def f(x):\n"
        "    # repro: allow[RPR001] host-only helper\n"
        "    if jnp.sum(x) > 0:\n"
        "        return x\n"
    )
    assert codes(above) == []
    # wrong code does not suppress
    wrong = "def f(x):\n    if jnp.sum(x) > 0:  # repro: allow[RPR002] wrong code\n        return x\n"
    assert codes(wrong) == ["RPR001"]


def test_rule_catalog_is_stable():
    assert set(RULES) == {f"RPR00{i}" for i in range(9)}


def test_repo_tree_is_clean():
    """The gate's contract on HEAD: zero unexplained findings in src/repro."""
    from pathlib import Path

    from repro.analysis import lint_paths

    root = Path(audit.__file__).resolve().parents[2]  # .../src
    assert lint_paths([root / "repro"], root=root) == []


# ---------------------------------------------------------------------------
# retrace guard
# ---------------------------------------------------------------------------


def test_retrace_guard_watches_a_jitted_fn():
    calls = jax.jit(lambda x: x * 2)
    guard = RetraceGuard(fn=calls)
    with pytest.raises(RuntimeError):
        guard.assert_no_retrace()  # snapshot first
    calls(jnp.ones((2,)))
    guard.snapshot()
    assert guard.snapshotted and guard.baseline == 1
    calls(jnp.ones((2,)))  # same shape: cached
    guard.assert_no_retrace()
    calls(jnp.ones((3,)))  # new shape: compiles
    with pytest.raises(RetraceError, match="jit cache grew 1 -> 2"):
        guard.assert_no_retrace(context="shape change")
    assert issubclass(RetraceError, AssertionError)


def test_retrace_guard_context_manager():
    fn = jax.jit(lambda x: x + 1)
    fn(jnp.ones((2,)))
    with RetraceGuard(fn=fn):
        fn(jnp.ones((2,)))
    with pytest.raises(RetraceError):
        with RetraceGuard(fn=fn):
            fn(jnp.ones((4,)))
    assert cache_size(fn) == 2
    assert engine_cache_size() >= 0  # shared engine counter resolves


# ---------------------------------------------------------------------------
# audit: peak-bytes / dtype walkers (unit level, no index builds)
# ---------------------------------------------------------------------------


def test_peak_live_bytes_sees_large_intermediate():
    def f(x):
        y = jnp.zeros((512, 512), jnp.float32) + x
        return y.sum()

    closed = jax.make_jaxpr(f)(jnp.float32(0.0))
    peak = audit.peak_live_bytes(closed.jaxpr)
    assert peak >= 512 * 512 * 4


def test_peak_live_bytes_recurses_into_subjaxprs():
    def inner(x):
        return (jnp.zeros((256, 256), jnp.float32) + x).sum()

    def f(x):
        return jax.jit(inner)(x)

    closed = jax.make_jaxpr(f)(jnp.float32(0.0))
    assert audit.peak_live_bytes(closed.jaxpr) >= 256 * 256 * 4


def test_dtype_violations_flag_int8_arithmetic():
    def bad(x):
        return x + x  # int8 add — quantized-domain arithmetic

    closed = jax.make_jaxpr(bad)(jnp.zeros((4,), jnp.int8))
    found = audit.dtype_violations(closed.jaxpr, "unit")
    assert any(f.code == "AUD003" and "int8" in f.message for f in found)

    def ok(x, idx):
        rows = jnp.take(x, idx, axis=0)  # move...
        return rows.astype(jnp.float32) * 2.0  # ...then decode, then compute

    closed = jax.make_jaxpr(ok)(
        jnp.zeros((8, 4), jnp.int8), jnp.zeros((3,), jnp.int32)
    )
    assert audit.dtype_violations(closed.jaxpr, "unit") == []


# ---------------------------------------------------------------------------
# audit: the full lattice (one shared index build)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def audit_indexes():
    return audit.build_audit_indexes()


@pytest.fixture()
def cached_build(monkeypatch, audit_indexes):
    monkeypatch.setattr(audit, "build_audit_indexes", lambda: audit_indexes)


def test_audit_head_passes_and_golden_round_trips(cached_build):
    golden = audit.load_golden()
    assert golden is not None, "golden_budget.json must be checked in"
    report = audit.run_audit(golden=golden, live_probe=True)
    assert report["failures"] == []
    assert report["ok"]
    assert report["compile_keys"]["count"] == budgets.RETRACE_BUDGET
    assert report["compile_keys"]["raw_points"] > report["compile_keys"]["count"]
    assert report["memory"]["max_peak_live_bytes"] <= budgets.MEMORY_ENVELOPE_BYTES
    # round trip: a golden regenerated from this report is the one on disk
    # (same backend only — trace shapes differ across backends)
    if golden["backend"] == report["backend"]:
        assert audit.golden_from_report(report) == golden


def test_seeded_memory_regression_fails_with_named_diagnostic(
    cached_build, monkeypatch
):
    sub = [
        p for p in audit.enumerate_points()
        if p.view == "segmented" and p.family == "theta" and p.storage == "f32"
        and p.mode == "probe"
    ]
    assert sub
    monkeypatch.setattr(audit, "enumerate_points", lambda: sub)
    report = audit.run_audit(inject="memory", live_probe=False)
    assert not report["ok"]
    breaches = [f for f in report["failures"] if f["code"] == "AUD001"]
    assert breaches, report["failures"]
    for f in breaches:
        assert f["path"].startswith("theta/f32/segmented/probe")
        assert f["measured"] > f["budget"] == budgets.MEMORY_ENVELOPE_BYTES
        assert "memory envelope" in f["message"]
    # the dense (b, L·P·C, cap) tensor dwarfs the envelope by design
    assert max(f["measured"] for f in breaches) > 4 * budgets.MEMORY_ENVELOPE_BYTES


def test_seeded_retrace_regression_fails_with_named_diagnostic(
    cached_build, monkeypatch, audit_indexes
):
    sub = [
        p for p in audit.enumerate_points()
        if p.family == "theta" and p.storage == "f32" and p.view == "sealed"
    ]
    q = jnp.zeros((budgets.AUDIT_GEOMETRY["b"], budgets.AUDIT_GEOMETRY["d"]))
    w = jnp.ones_like(q)
    folded = len(
        {
            audit.compile_key(p, audit_indexes[(p.family, p.storage)], q, w)
            for p in sub
        }
    )
    assert folded < len(sub)  # the sublattice carries redundant axes
    monkeypatch.setattr(audit, "enumerate_points", lambda: sub)
    monkeypatch.setattr(budgets, "RETRACE_BUDGET", folded)
    report = audit.run_audit(inject="retrace", live_probe=False)
    assert not report["ok"]
    (breach,) = [f for f in report["failures"] if f["code"] == "AUD002"]
    assert breach["measured"] == len(sub) > breach["budget"] == folded
    assert "normalize_static_args" in breach["message"]
    assert "static variant" in breach["message"]  # names an unfolded axis


def test_audit_rejects_unknown_injection():
    with pytest.raises(ValueError, match="inject"):
        audit.run_audit(inject="bogus")


def test_golden_drift_is_reported(cached_build):
    golden = audit.load_golden()
    if golden["backend"] != jax.default_backend():
        pytest.skip("golden traced on a different backend")
    skewed = {
        "backend": golden["backend"],
        "compile_keys": golden["compile_keys"],
        "paths": {k: v * 2 for k, v in golden["paths"].items()},
    }
    report = audit.run_audit(golden=skewed, live_probe=False)
    drift = [f for f in report["failures"] if f["code"] == "AUD004"]
    assert drift and all("golden" in f["message"] for f in drift)


# ---------------------------------------------------------------------------
# normalization contract (static level, no builds)
# ---------------------------------------------------------------------------


def test_normalize_static_args_folds_redundant_axes():
    from repro.engine.pipeline import normalize_static_args

    cfg = audit._audit_config("theta", "f32")
    f32, i8 = jnp.float32, jnp.int8
    # probe ignores n_probes/max_flips/alpha(f32)
    a = normalize_static_args(cfg, f32, 3, "probe", 8, 3, "auto", 2.0)
    b = normalize_static_args(cfg, f32, 3, "probe", 1, 0, "auto", 0.0)
    assert a == b
    # exact drops cfg, impl, alpha entirely (and the early-exit knobs)
    a = normalize_static_args(cfg, i8, 3, "exact", 8, 3, "gather", 2.0)
    assert a == (None, 3, "exact", 1, 0, "auto", 0.0, False, 0, 0.0)
    # int8 keeps a real alpha; multiprobe folds impl but keeps probes
    a = normalize_static_args(cfg, i8, 3, "multiprobe", 4, 2, "gather", 2.0)
    assert a == (cfg, 3, "multiprobe", 4, 2, "auto", 2.0, False, 0, 0.0)
    # early exit: dead knobs zero while off; an active screen folds it off;
    # a single group folds it off; a live streamed point keeps its knobs
    a = normalize_static_args(cfg, f32, 3, "probe", 1, 0, "auto", 0.0,
                              False, 16, 0.5)
    assert a == b
    a = normalize_static_args(cfg, i8, 3, "probe", 1, 0, "auto", 2.0,
                              True, 4, 0.1)
    assert a[7:] == (False, 0, 0.0)
    a = normalize_static_args(cfg, f32, 3, "probe", 1, 0, "auto", 0.0,
                              True, cfg.L, 0.1)
    assert a == b
    a = normalize_static_args(cfg, f32, 3, "probe", 1, 0, "auto", 0.0,
                              True, 4, 0.1)
    assert a[7:] == (True, 4, 0.1)
