"""Pallas alsh_project kernel vs ref oracle: shape/dtype sweeps (interpret=True)."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.alsh_project import alsh_project_pallas

SHAPES = [
    (1, 1, 1, 1),  # degenerate minimum
    (7, 5, 3, 4),  # everything sub-block
    (128, 64, 128, 32),  # exact block multiples
    (130, 65, 129, 32),  # off-by-one over blocks
    (64, 200, 17, 9),  # d > BD (multi-step reduction)
    (256, 33, 1024, 5),  # many hashes
]


@pytest.mark.parametrize("n,d,H,M", SHAPES)
@pytest.mark.parametrize("weighted", [False, True])
def test_project_matches_ref(n, d, H, M, weighted):
    key = jax.random.PRNGKey(n * 1000 + d * 100 + H + M)
    k1, k2, k3 = jax.random.split(key, 3)
    levels = jax.random.randint(k1, (n, d), 0, M + 1)
    folded = jax.random.normal(k2, (H, d, M + 1), jnp.float32)
    weights = jax.random.normal(k3, (n, d), jnp.float32) if weighted else None
    got = alsh_project_pallas(levels, folded, weights, interpret=True)
    want = ref.alsh_project(levels, folded, weights)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("table_dtype", [jnp.float32, jnp.bfloat16])
def test_project_dtypes(table_dtype):
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    levels = jax.random.randint(k1, (32, 16), 0, 9)
    folded = jax.random.normal(k2, (8, 16, 9), jnp.float32).astype(table_dtype)
    got = alsh_project_pallas(levels, folded, None, interpret=True)
    want = ref.alsh_project(levels, folded.astype(jnp.float32), None)
    tol = 1e-4 if table_dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)
    assert got.dtype == jnp.float32  # accumulation stays f32


@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(
    n=st.integers(1, 40),
    d=st.integers(1, 48),
    H=st.integers(1, 24),
    M=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_project_property_random_shapes(n, d, H, M, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    levels = jax.random.randint(k1, (n, d), 0, M + 1)
    folded = jax.random.normal(k2, (H, d, M + 1), jnp.float32)
    weights = jax.random.normal(k3, (n, d), jnp.float32)
    got = alsh_project_pallas(levels, folded, weights, interpret=True)
    want = ref.alsh_project(levels, folded, weights)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-4)
