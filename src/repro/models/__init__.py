"""Assigned LM architectures as composable JAX modules (no framework deps)."""

from repro.models.model import (
    cache_specs,
    forward_decode,
    forward_prefill,
    forward_train,
    init_caches,
    init_params,
    param_specs,
)

__all__ = [
    "cache_specs",
    "forward_decode",
    "forward_prefill",
    "forward_train",
    "init_caches",
    "init_params",
    "param_specs",
]
