"""Mixture-of-Experts (top-1 routing, llama4-style) with expert parallelism.

Capacity-based sorted dispatch (Switch/MaxText style, static shapes):

  1. route: top-1 expert per token (+ sigmoid gate, llama4 convention)
  2. sort tokens by expert id; position-in-expert via exclusive-cumsum offsets
  3. scatter into a (E, C, dm) buffer, C = capacity_factor * T/E — overflow
     tokens are dropped (their gate contribution is zero; the shared expert
     still sees them, so no token goes dark)
  4. batched expert FFN on (E, C, dm) with E sharded over "model" (EP) — under
     GSPMD this is the canonical all_to_all pair around expert compute
  5. gather back + unsort + gate; add the always-on shared expert

Memory: E*C*dm ≈ capacity_factor * T * dm — same order as activations,
sharded over (model, data). A shared (always-on) expert runs as a plain MLP
in parallel with the routed path (llama4's design).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import layers, mlp
from repro.models.sharding import BATCH, EP, FSDP, get_mesh, maybe_shard, resolve_entry


def init_moe(key, cfg: ModelConfig, mcfg: MoEConfig, dtype) -> dict:
    kr, ke1, ke2, ke3, ks = jax.random.split(key, 5)
    dm, dff, E = cfg.d_model, mcfg.d_ff_expert, mcfg.n_experts
    std_in, std_out = dm**-0.5, dff**-0.5
    p = {
        "router": layers.init_linear(kr, dm, E, dtype, std=0.02),
        "experts": {
            "w_up": layers.truncated_normal_init(ke1, (E, dm, dff), std_in, dtype),
            "w_gate": layers.truncated_normal_init(ke2, (E, dm, dff), std_in, dtype),
            "w_down": layers.truncated_normal_init(ke3, (E, dff, dm), std_out, dtype),
        },
    }
    if mcfg.n_shared:
        p["shared"] = mlp.init_mlp(ks, dm, mcfg.d_ff_expert * mcfg.n_shared, "swiglu", dtype)
    return p


def moe_specs(mcfg: MoEConfig, impl: str = "gspmd") -> dict:
    P = jax.sharding.PartitionSpec
    # Both impls STORE experts 2-D sharded (EP x FSDP): grads/moments stay
    # (E/ep)/(data)-sharded — storing EP-only would leave ~48 GB/device of
    # expert grads on llama4-maverick (measured; see EXPERIMENTS §Perf). The
    # ep_shardmap path all-gathers the weights over FSDP transiently at the
    # shard_map boundary; the gather's transpose reduce-scatters the grads.
    experts = {
        "w_up": P(EP, FSDP, None),
        "w_gate": P(EP, FSDP, None),
        "w_down": P(EP, None, FSDP),
    }
    p = {"router": layers.linear_specs(None, None), "experts": experts}
    if mcfg.n_shared:
        p["shared"] = mlp.mlp_specs("swiglu")
    return p


def _capacity(T: int, E: int, factor: float) -> int:
    c = int(factor * T / E) + 1
    return max(8, min(c, T))


def _dispatch_compute_combine(xf, router_logits, we, E, C, E_offset=0):
    """Shared core: sorted capacity dispatch -> expert FFN -> combine.

    xf (T, dm); router_logits (T, E_total) float32; we holds (E, dm, dff)
    weight stacks for the E LOCAL experts starting at global id E_offset.
    Tokens routed outside [E_offset, E_offset+E) are dropped here (handled by
    other ranks under EP). Returns (T, dm) routed output (gated).
    """
    T, dm = xf.shape
    expert_global = jnp.argmax(router_logits, axis=-1).astype(jnp.int32)  # (T,)
    gate = jax.nn.sigmoid(jnp.max(router_logits, axis=-1))  # (T,)
    local = expert_global - E_offset
    mine = (local >= 0) & (local < E)
    local = jnp.where(mine, local, E)  # foreign tokens -> virtual expert E

    sort_idx = jnp.argsort(local)  # (T,) stable; foreign tokens sort last
    sorted_expert = local[sort_idx]
    counts = jnp.sum(jax.nn.one_hot(local, E + 1, dtype=jnp.int32), axis=0)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos_in_expert = jnp.arange(T, dtype=jnp.int32) - offsets[jnp.minimum(sorted_expert, E)]
    keep = (pos_in_expert < C) & (sorted_expert < E)
    safe_pos = jnp.where(keep, pos_in_expert, C - 1)
    safe_exp = jnp.minimum(sorted_expert, E - 1)

    buf = jnp.zeros((E, C, dm), xf.dtype)
    xs = xf[sort_idx] * keep[:, None].astype(xf.dtype)
    buf = buf.at[safe_exp, safe_pos].add(xs)

    up = jnp.einsum("ecd,edf->ecf", buf, we["w_up"].astype(xf.dtype))
    gt = jnp.einsum("ecd,edf->ecf", buf, we["w_gate"].astype(xf.dtype))
    h = jax.nn.silu(gt) * up
    down = jnp.einsum("ecf,efd->ecd", h, we["w_down"].astype(xf.dtype))  # (E, C, dm)

    gathered = down[safe_exp, safe_pos] * keep[:, None].astype(xf.dtype)
    inv = jnp.argsort(sort_idx)
    return gathered[inv] * gate[:, None].astype(xf.dtype)


def moe_ffn_ep_shardmap(params: dict, x: jax.Array, cfg: ModelConfig,
                        mcfg: MoEConfig) -> jax.Array:
    """Explicit expert parallelism (perf lever, DESIGN.md + EXPERIMENTS §Perf).

    Activations stay replicated across the EP ("model") axis (they are batch-
    sharded over ("pod","data") only — the megatron layout); each EP rank
    dispatches the SAME token set to its local E/ep experts and a single psum
    combines partial outputs. Collectives per MoE layer: ONE all-reduce of
    (T_local, dm) — versus the GSPMD scatter/gather fallback that replicated
    full dispatch buffers (measured 5.3 TiB of all-reduce per step on
    llama4-maverick; see EXPERIMENTS §Perf).
    """
    mesh = get_mesh()
    ep_axis = resolve_entry(EP)
    if mesh is None or ep_axis not in mesh.axis_names:
        return moe_ffn_gspmd(params, x, cfg, mcfg)
    ep = mesh.shape[ep_axis]
    B, S, dm = x.shape
    E = mcfg.n_experts
    assert E % ep == 0, (E, ep)
    E_local = E // ep

    # greedy divisibility degradation (mirror of sharding.sanitize_spec):
    # keep the batch-axis prefix whose product divides B (e.g. global_batch 32
    # on a 16x16 mesh under dp_over_model -> batch over ("data",) only)
    batch_axes = []
    prod = 1
    for a in resolve_entry(BATCH) or ():
        if a in mesh.axis_names and B % (prod * mesh.shape[a]) == 0:
            batch_axes.append(a)
            prod *= mesh.shape[a]
    batch_axes = tuple(batch_axes)
    P = PartitionSpec
    # Two data layouts:
    #  * megatron (ep_axis NOT in batch): x replicated over EP — dispatch the
    #    same token set per rank, psum partial outputs.
    #  * dp_over_model (ep_axis IN batch): x batch-sharded over EP too —
    #    all_gather tokens over EP, dispatch, then psum_scatter the combined
    #    outputs back to each rank's slice (half the bytes of AG+psum).
    gather_tokens = ep_axis in batch_axes
    # tokens visible to one rank's dispatch = batch shard WITHOUT the ep axis
    n_batch_shards = 1
    for a in batch_axes:
        if a != ep_axis:
            n_batch_shards *= mesh.shape[a]
    T = max(B // n_batch_shards, 1) * S
    C = _capacity(T, E, mcfg.capacity_factor)

    def local_fn(router_w, we_up, we_gate, we_down, xl):
        if gather_tokens:
            xl = jax.lax.all_gather(xl, ep_axis, axis=0, tiled=True)  # (Bl*ep, S, dm)
        Bg = xl.shape[0]
        xf = xl.reshape(Bg * S, dm)
        router_logits = (xf @ router_w.astype(xf.dtype)).astype(jnp.float32)
        rank = jax.lax.axis_index(ep_axis)
        we = {"w_up": we_up, "w_gate": we_gate, "w_down": we_down}
        routed = _dispatch_compute_combine(
            xf, router_logits, we, E_local, C, E_offset=rank * E_local
        )
        routed = routed.reshape(Bg, S, dm)
        if gather_tokens:
            return jax.lax.psum_scatter(routed, ep_axis, scatter_dimension=0,
                                        tiled=True)  # (Bl, S, dm)
        return jax.lax.psum(routed, ep_axis)

    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(),  # router replicated
            P(ep_axis), P(ep_axis), P(ep_axis),  # experts over EP (gathered over FSDP)
            P(batch_axes, None, None),
        ),
        out_specs=P(batch_axes, None, None),
        check_rep=False,
    )
    we = params["experts"]
    # transient FSDP gather (storage stays (EP x FSDP)-sharded; see moe_specs)
    w_up = maybe_shard(we["w_up"], EP, None, None)
    w_gate = maybe_shard(we["w_gate"], EP, None, None)
    w_down = maybe_shard(we["w_down"], EP, None, None)
    routed = fn(params["router"]["w"], w_up, w_gate, w_down, x)

    out = routed
    if "shared" in params:
        xf = x.reshape(B * S, dm)
        out = out + mlp.mlp(params["shared"], xf, "swiglu").reshape(B, S, dm)
    return maybe_shard(out, BATCH, None, None)


def _dispatch_by_ids(xf, local_ids, we, E, C):
    """Expert FFN for tokens with PRE-ASSIGNED local expert ids (a2a receive
    side). local_ids (T,) in [0, E) or -1 (invalid/padding). Returns (T, dm)
    outputs (zeros for invalid/dropped)."""
    T, dm = xf.shape
    valid = local_ids >= 0
    local = jnp.where(valid, local_ids, E)
    sort_idx = jnp.argsort(local)
    sorted_expert = local[sort_idx]
    counts = jnp.sum(jax.nn.one_hot(local, E + 1, dtype=jnp.int32), axis=0)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(T, dtype=jnp.int32) - offsets[jnp.minimum(sorted_expert, E)]
    keep = (pos < C) & (sorted_expert < E)
    safe_pos = jnp.where(keep, pos, C - 1)
    safe_exp = jnp.minimum(sorted_expert, E - 1)

    buf = jnp.zeros((E, C, dm), xf.dtype)
    buf = buf.at[safe_exp, safe_pos].add(xf[sort_idx] * keep[:, None].astype(xf.dtype))
    up = jnp.einsum("ecd,edf->ecf", buf, we["w_up"].astype(xf.dtype))
    gt = jnp.einsum("ecd,edf->ecf", buf, we["w_gate"].astype(xf.dtype))
    h = jax.nn.silu(gt) * up
    down = jnp.einsum("ecf,efd->ecd", h, we["w_down"].astype(xf.dtype))
    out_sorted = down[safe_exp, safe_pos] * keep[:, None].astype(xf.dtype)
    return out_sorted[jnp.argsort(sort_idx)]


def moe_ffn_a2a_shardmap(params: dict, x: jax.Array, cfg: ModelConfig,
                         mcfg: MoEConfig) -> jax.Array:
    """TRUE all-to-all expert parallelism (beyond-paper, EXPERIMENTS §Perf).

    Tokens are batch-sharded over the EP axis too (requires dp_over_model);
    each rank routes its tokens, exchanges them with the owning expert ranks
    via all_to_all (per-peer capacity Cp), computes its local experts, and
    all_to_alls the outputs back. Expert weights never move; token traffic is
    2·capacity_factor·T_local·dm per layer — constant in model size, the
    layout that scales past the weight-gather floor of gather-EP.
    """
    mesh = get_mesh()
    ep_axis = resolve_entry(EP)
    if mesh is None or ep_axis not in mesh.axis_names:
        return moe_ffn_gspmd(params, x, cfg, mcfg)
    ep = mesh.shape[ep_axis]
    B, S, dm = x.shape
    E = mcfg.n_experts
    assert E % ep == 0, (E, ep)
    E_local = E // ep

    batch_axes = []
    prod = 1
    for a in resolve_entry(BATCH) or ():
        if a in mesh.axis_names and B % (prod * mesh.shape[a]) == 0:
            batch_axes.append(a)
            prod *= mesh.shape[a]
    batch_axes = tuple(batch_axes)
    if ep_axis not in batch_axes:
        # tokens are replicated over EP: a2a degenerates — use gather-EP path
        return moe_ffn_ep_shardmap(params, x, cfg, mcfg)

    T_l = (B // prod) * S  # tokens per rank
    Cp = max(8, int(mcfg.capacity_factor * T_l / ep) + 1)  # per-peer slots
    C2 = max(8, int(mcfg.capacity_factor * ep * Cp / E_local) + 1)  # per-expert
    P = PartitionSpec

    def local_fn(router_w, we_up, we_gate, we_down, xl):
        Bl = xl.shape[0]
        xf = xl.reshape(Bl * S, dm)
        T = xf.shape[0]
        logits = (xf @ router_w.astype(xf.dtype)).astype(jnp.float32)
        expert_global = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        gate = jax.nn.sigmoid(jnp.max(logits, axis=-1))
        target = expert_global // E_local  # owning rank per token

        # --- pack send buffers: (ep, Cp, dm) + local-expert ids -------------
        sidx = jnp.argsort(target)
        st = target[sidx]
        counts = jnp.sum(jax.nn.one_hot(target, ep, dtype=jnp.int32), axis=0)
        offs = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
        pos = jnp.arange(T, dtype=jnp.int32) - offs[st]
        keep = pos < Cp
        safe_pos = jnp.where(keep, pos, Cp - 1)
        sbuf = jnp.zeros((ep, Cp, dm), xf.dtype)
        sbuf = sbuf.at[st, safe_pos].add(
            xf[sidx] * keep[:, None].astype(xf.dtype)
        )
        smeta = jnp.full((ep, Cp), -1, jnp.int32)
        smeta = smeta.at[st, safe_pos].set(
            jnp.where(keep, expert_global[sidx] % E_local, -1)
        )

        # --- exchange, compute, exchange back --------------------------------
        rbuf = jax.lax.all_to_all(sbuf, ep_axis, 0, 0, tiled=True)
        rmeta = jax.lax.all_to_all(smeta[..., None], ep_axis, 0, 0, tiled=True)[..., 0]
        we = {"w_up": we_up, "w_gate": we_gate, "w_down": we_down}
        y = _dispatch_by_ids(rbuf.reshape(ep * Cp, dm), rmeta.reshape(ep * Cp),
                             we, E_local, C2)
        ybuf = jax.lax.all_to_all(y.reshape(ep, Cp, dm), ep_axis, 0, 0, tiled=True)

        # --- unpack at source -------------------------------------------------
        back_sorted = ybuf[st, safe_pos] * keep[:, None].astype(xf.dtype)
        routed = back_sorted[jnp.argsort(sidx)] * gate[:, None].astype(xf.dtype)
        return routed.reshape(Bl, S, dm)

    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(), P(ep_axis), P(ep_axis), P(ep_axis), P(batch_axes, None, None)),
        out_specs=P(batch_axes, None, None),
        check_rep=False,
    )
    we = params["experts"]
    w_up = maybe_shard(we["w_up"], EP, None, None)
    w_gate = maybe_shard(we["w_gate"], EP, None, None)
    w_down = maybe_shard(we["w_down"], EP, None, None)
    routed = fn(params["router"]["w"], w_up, w_gate, w_down, x)

    out = routed
    if "shared" in params:
        xf = x.reshape(B * S, dm)
        out = out + mlp.mlp(params["shared"], xf, "swiglu").reshape(B, S, dm)
    return maybe_shard(out, BATCH, None, None)


def moe_ffn(params: dict, x: jax.Array, cfg: ModelConfig, mcfg: MoEConfig) -> jax.Array:
    """x (B, S, dm) -> (B, S, dm). Top-1 routed + shared expert (impl lever)."""
    if cfg.moe_impl == "a2a_shardmap":
        return moe_ffn_a2a_shardmap(params, x, cfg, mcfg)
    if cfg.moe_impl == "ep_shardmap":
        return moe_ffn_ep_shardmap(params, x, cfg, mcfg)
    return moe_ffn_gspmd(params, x, cfg, mcfg)


def moe_ffn_gspmd(params: dict, x: jax.Array, cfg: ModelConfig, mcfg: MoEConfig) -> jax.Array:
    """GSPMD-auto dispatch (paper-faithful baseline path)."""
    B, S, dm = x.shape
    E = mcfg.n_experts
    T = B * S
    C = _capacity(T, E, mcfg.capacity_factor)
    xf = x.reshape(T, dm)

    router_logits = layers.linear(params["router"], xf).astype(jnp.float32)  # (T, E)
    expert_idx = jnp.argmax(router_logits, axis=-1).astype(jnp.int32)  # (T,)
    gate = jax.nn.sigmoid(jnp.max(router_logits, axis=-1))  # (T,) llama4 top-1 gate

    # --- sorted capacity dispatch -------------------------------------------
    sort_idx = jnp.argsort(expert_idx)  # (T,) stable
    sorted_expert = expert_idx[sort_idx]
    counts = jnp.sum(jax.nn.one_hot(expert_idx, E, dtype=jnp.int32), axis=0)  # (E,)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos_in_expert = jnp.arange(T, dtype=jnp.int32) - offsets[sorted_expert]  # (T,)
    keep = pos_in_expert < C
    safe_pos = jnp.where(keep, pos_in_expert, C - 1)

    buf = jnp.zeros((E, C, dm), x.dtype)
    xs = xf[sort_idx] * keep[:, None].astype(x.dtype)
    buf = buf.at[sorted_expert, safe_pos].add(xs)  # dropped tokens add 0 to slot C-1
    buf = maybe_shard(buf, EP, None, None)  # experts over model axis (EP)

    # --- expert FFN (batched over local experts) ----------------------------
    we = params["experts"]
    up = jnp.einsum("ecd,edf->ecf", buf, we["w_up"].astype(x.dtype))
    gt = jnp.einsum("ecd,edf->ecf", buf, we["w_gate"].astype(x.dtype))
    h = jax.nn.silu(gt) * up
    down = jnp.einsum("ecf,efd->ecd", h, we["w_down"].astype(x.dtype))  # (E, C, dm)
    down = maybe_shard(down, EP, None, None)

    # --- combine: gather back, unsort, gate ---------------------------------
    gathered = down[sorted_expert, safe_pos]  # (T, dm) in sorted order
    gathered = gathered * keep[:, None].astype(x.dtype)
    inv = jnp.argsort(sort_idx)
    routed = gathered[inv] * gate[:, None].astype(x.dtype)

    out = routed
    if "shared" in params:
        out = out + mlp.mlp(params["shared"], xf, "swiglu")
    out = out.reshape(B, S, dm)
    return maybe_shard(out, BATCH, None, None)


def aux_load_balance_loss(router_logits: jax.Array, E: int) -> jax.Array:
    """Switch-style load-balance auxiliary (exposed for the training loss)."""
    probs = jax.nn.softmax(router_logits, axis=-1)
    expert_idx = jnp.argmax(router_logits, axis=-1)
    frac_tokens = jnp.mean(jax.nn.one_hot(expert_idx, E), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return E * jnp.sum(frac_tokens * frac_probs)
