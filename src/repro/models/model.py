"""Model assembly: heterogeneous layer patterns under scan, three run modes.

Layer patterns: ``cfg.scan_unit`` is a tuple of layer kinds repeated
``n_units`` times (stacked params, jax.lax.scan over units — one traced copy
of the unit body regardless of depth) followed by an explicit ``tail``.
Kinds:

  attn / local / global / chunked / global_nope  — attention block (+ MLP)
     ... with "_moe" suffix → MoE FFN instead of dense MLP
  mamba2        — Mamba2 SSD block (no separate FFN, mamba-stack style)
  shared_attn   — attention + MLP with weights SHARED across occurrences
                  (zamba2); per-occurrence KV caches remain distinct.

Run modes:
  forward_train   — full-sequence forward + next-token (or masked) CE loss
  forward_prefill — full-sequence forward, returns per-layer caches + logits
  forward_decode  — one token against the caches

Params are nested dicts; ``param_specs`` mirrors the exact tree with
PartitionSpecs (FSDP over "data", TP/EP over "model").
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention, layers, mlp, moe, ssm
from repro.models.sharding import BATCH, FSDP, TP, maybe_shard


def _compute_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def _param_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _is_attn(kind: str) -> bool:
    return kind.split("_moe")[0] in ("attn", "local", "global", "chunked", "global_nope")


def _attn_kind(kind: str) -> str:
    return kind.removesuffix("_moe")


def _is_moe(kind: str) -> bool:
    return kind.endswith("_moe")


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_block(key, kind: str, cfg: ModelConfig, dtype) -> dict:
    """Params for one layer of the given kind (shared_attn → empty marker)."""
    if kind == "shared_attn":
        return {}
    if kind == "mamba2":
        k1, k2 = jax.random.split(key)
        return {
            "ln1": layers.init_rmsnorm(cfg.d_model, dtype),
            "mamba": ssm.init_mamba2(k1, cfg, cfg.ssm, dtype),
        }
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": layers.init_rmsnorm(cfg.d_model, dtype),
        "attn": attention.init_attention(k1, cfg, dtype),
        "ln2": layers.init_rmsnorm(cfg.d_model, dtype),
    }
    if _is_moe(kind):
        p["ffn"] = moe.init_moe(k2, cfg, cfg.moe, dtype)
    else:
        d_ff = cfg.moe.d_ff_dense if (cfg.moe is not None) else cfg.d_ff
        p["ffn"] = mlp.init_mlp(k2, cfg.d_model, d_ff, cfg.activation, dtype)
    return p


def _block_specs(kind: str, cfg: ModelConfig) -> dict:
    if kind == "shared_attn":
        return {}
    if kind == "mamba2":
        return {
            "ln1": layers.rmsnorm_specs(),
            "mamba": ssm.mamba2_specs(cfg, cfg.ssm),
        }
    p = {
        "ln1": layers.rmsnorm_specs(),
        "attn": attention.attention_specs(cfg),
        "ln2": layers.rmsnorm_specs(),
    }
    if _is_moe(kind):
        p["ffn"] = moe.moe_specs(cfg.moe, impl=cfg.moe_impl)
    else:
        p["ffn"] = mlp.mlp_specs(cfg.activation)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    cfg.validate()
    dtype = _param_dtype(cfg)
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {}

    # --- embedding / frontend ------------------------------------------------
    if cfg.frontend == "audio":
        params["frontend_proj"] = layers.init_linear(
            keys[0], cfg.frontend_dim, cfg.d_model, dtype
        )
        params["head"] = layers.init_linear(keys[1], cfg.d_model, cfg.vocab_size, dtype)
    else:
        params["embed"] = layers.init_embed(keys[0], cfg.vocab_size, cfg.d_model, dtype)
        if cfg.frontend == "vision":
            params["vision_proj"] = layers.init_linear(
                keys[2], cfg.frontend_dim, cfg.d_model, dtype
            )
        if not cfg.tie_embeddings:
            params["lm_head"] = layers.init_linear(
                keys[3], cfg.d_model, cfg.vocab_size, dtype, std=0.02
            )

    # --- stacked scan units ---------------------------------------------------
    n_units = cfg.resolved_units

    def unit_init(ukey):
        ks = jax.random.split(ukey, len(cfg.scan_unit))
        return {
            f"p{i}": _init_block(ks[i], kind, cfg, dtype)
            for i, kind in enumerate(cfg.scan_unit)
        }

    if n_units:
        unit_keys = jax.random.split(keys[4], n_units)
        params["units"] = jax.vmap(unit_init)(unit_keys)

    if cfg.tail:
        tks = jax.random.split(keys[5], len(cfg.tail))
        params["tail"] = {
            f"p{i}": _init_block(tks[i], kind, cfg, dtype)
            for i, kind in enumerate(cfg.tail)
        }

    if "shared_attn" in cfg.scan_unit or "shared_attn" in cfg.tail:
        params["shared_block"] = _init_block(keys[6], "attn", cfg, dtype)

    params["ln_f"] = layers.init_rmsnorm(cfg.d_model, dtype)
    return params


def param_specs(cfg: ModelConfig) -> dict:
    cfg.validate()
    specs: dict[str, Any] = {}
    if cfg.frontend == "audio":
        specs["frontend_proj"] = layers.linear_specs(None, FSDP)
        specs["head"] = layers.linear_specs(FSDP, TP)
    else:
        if cfg.embed_table_spec == "dm_data":
            # perf lever: vocab replicated, d_model FSDP-sharded — the token
            # gather stays local (no SPMD "replicate-then-reshard" fallback)
            specs["embed"] = {"table": P(None, FSDP)}
        else:
            specs["embed"] = layers.embed_specs()
        if cfg.frontend == "vision":
            specs["vision_proj"] = layers.linear_specs(None, FSDP)
        if not cfg.tie_embeddings:
            specs["lm_head"] = layers.linear_specs(FSDP, TP)

    def unit_specs():
        return {
            f"p{i}": _block_specs(kind, cfg) for i, kind in enumerate(cfg.scan_unit)
        }

    if cfg.resolved_units:
        # stacked along a leading (n_units) axis — prepend None to every spec
        specs["units"] = jax.tree.map(
            lambda s: P(None, *s), unit_specs(),
            is_leaf=lambda s: isinstance(s, P),
        )
    if cfg.tail:
        specs["tail"] = {
            f"p{i}": _block_specs(kind, cfg) for i, kind in enumerate(cfg.tail)
        }
    if "shared_attn" in cfg.scan_unit or "shared_attn" in cfg.tail:
        specs["shared_block"] = _block_specs("attn", cfg)
    specs["ln_f"] = layers.rmsnorm_specs()
    return specs


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


def _apply_block_seq(kind, bparams, shared, x, positions, cfg: ModelConfig):
    """Train-mode (no cache) application of one block."""
    if kind == "shared_attn":
        bparams, kind = shared, "attn"
    if kind == "mamba2":
        h = layers.rmsnorm(bparams["ln1"], x, cfg.norm_eps)
        return x + ssm.mamba2_sequence(bparams["mamba"], h, cfg, cfg.ssm)
    ak = _attn_kind(kind)
    h = layers.rmsnorm(bparams["ln1"], x, cfg.norm_eps)
    x = x + attention.attn_sequence(bparams["attn"], h, positions, cfg, ak)
    h = layers.rmsnorm(bparams["ln2"], x, cfg.norm_eps)
    if _is_moe(kind):
        x = x + moe.moe_ffn(bparams["ffn"], h, cfg, cfg.moe)
    else:
        x = x + mlp.mlp(bparams["ffn"], h, cfg.activation)
    return x


def _apply_block_prefill(kind, bparams, shared, x, positions, cfg, cache_len):
    """Like seq, but also builds this block's decode cache (cache_len slots)."""
    if kind == "shared_attn":
        bparams, kind = shared, "attn"
        eff_kind = "attn"
    else:
        eff_kind = kind
    if kind == "mamba2":
        h = layers.rmsnorm(bparams["ln1"], x, cfg.norm_eps)
        out, cache = ssm.mamba2_sequence(bparams["mamba"], h, cfg, cfg.ssm, return_cache=True)
        return x + out, cache
    ak = _attn_kind(eff_kind)
    h = layers.rmsnorm(bparams["ln1"], x, cfg.norm_eps)
    clen = attention.cache_len_for(ak, cfg, cache_len)
    cache = attention.prefill_kv(bparams["attn"], h, positions, cfg, ak, clen)
    x = x + attention.attn_sequence(bparams["attn"], h, positions, cfg, ak)
    h = layers.rmsnorm(bparams["ln2"], x, cfg.norm_eps)
    if _is_moe(kind):
        x = x + moe.moe_ffn(bparams["ffn"], h, cfg, cfg.moe)
    else:
        x = x + mlp.mlp(bparams["ffn"], h, cfg.activation)
    return x, cache


def _apply_block_decode(kind, bparams, shared, x, pos, cache, cfg):
    if kind == "shared_attn":
        bparams, kind = shared, "attn"
    if kind == "mamba2":
        h = layers.rmsnorm(bparams["ln1"], x, cfg.norm_eps)
        out, new_cache = ssm.mamba2_decode(bparams["mamba"], h, cache, cfg, cfg.ssm)
        return x + out, new_cache
    ak = _attn_kind(kind)
    h = layers.rmsnorm(bparams["ln1"], x, cfg.norm_eps)
    out, new_cache = attention.attn_decode(bparams["attn"], h, pos, cache, cfg, ak)
    x = x + out
    h = layers.rmsnorm(bparams["ln2"], x, cfg.norm_eps)
    if _is_moe(kind):
        x = x + moe.moe_ffn(bparams["ffn"], h, cfg, cfg.moe)
    else:
        x = x + mlp.mlp(bparams["ffn"], h, cfg.activation)
    return x, new_cache


# ---------------------------------------------------------------------------
# Backbone drivers (train / prefill / decode)
# ---------------------------------------------------------------------------


def _backbone_train(params, x, positions, cfg: ModelConfig):
    shared = params.get("shared_block")

    def unit_body(h, unit_p):
        for i, kind in enumerate(cfg.scan_unit):
            h = _apply_block_seq(kind, unit_p[f"p{i}"], shared, h, positions, cfg)
        return h, None

    if cfg.remat:
        policy = (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            if cfg.remat_policy == "dots"
            else jax.checkpoint_policies.nothing_saveable
        )
        unit_body = jax.checkpoint(unit_body, policy=policy)
    if cfg.resolved_units:
        x, _ = jax.lax.scan(unit_body, x, params["units"])
    for i, kind in enumerate(cfg.tail):
        x = _apply_block_seq(kind, params["tail"][f"p{i}"], shared, x, positions, cfg)
    return layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)


def _backbone_prefill(params, x, positions, cfg: ModelConfig, cache_len: int):
    shared = params.get("shared_block")

    def unit_body(h, unit_p):
        caches = {}
        for i, kind in enumerate(cfg.scan_unit):
            h, caches[f"p{i}"] = _apply_block_prefill(
                kind, unit_p[f"p{i}"], shared, h, positions, cfg, cache_len
            )
        return h, caches

    caches: dict[str, Any] = {}
    if cfg.resolved_units:
        x, caches["units"] = jax.lax.scan(unit_body, x, params["units"])
    if cfg.tail:
        caches["tail"] = {}
        for i, kind in enumerate(cfg.tail):
            x, caches["tail"][f"p{i}"] = _apply_block_prefill(
                kind, params["tail"][f"p{i}"], shared, x, positions, cfg, cache_len
            )
    return layers.rmsnorm(params["ln_f"], x, cfg.norm_eps), caches


def _backbone_decode(params, x, pos, caches, cfg: ModelConfig):
    shared = params.get("shared_block")

    def unit_body(h, xs):
        unit_p, unit_c = xs
        new_c = {}
        for i, kind in enumerate(cfg.scan_unit):
            h, new_c[f"p{i}"] = _apply_block_decode(
                kind, unit_p[f"p{i}"], shared, h, pos, unit_c[f"p{i}"], cfg
            )
        return h, new_c

    new_caches: dict[str, Any] = {}
    if cfg.resolved_units:
        x, new_caches["units"] = jax.lax.scan(
            unit_body, x, (params["units"], caches["units"])
        )
    if cfg.tail:
        new_caches["tail"] = {}
        for i, kind in enumerate(cfg.tail):
            x, new_caches["tail"][f"p{i}"] = _apply_block_decode(
                kind, params["tail"][f"p{i}"], shared, x, pos, caches["tail"][f"p{i}"], cfg
            )
    return layers.rmsnorm(params["ln_f"], x, cfg.norm_eps), new_caches


# ---------------------------------------------------------------------------
# Inputs → hidden states
# ---------------------------------------------------------------------------


def _embed_inputs(params, batch: dict, cfg: ModelConfig):
    """Returns (x (B,S,dm), positions) for any modality."""
    cdt = _compute_dtype(cfg)
    if cfg.frontend == "audio":
        x = layers.linear(params["frontend_proj"], batch["frames"].astype(cdt))
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    elif cfg.frontend == "vision":
        tok_emb = layers.embed(params["embed"], batch["tokens"], cdt)
        patches = layers.linear(params["vision_proj"], batch["patches"].astype(cdt))
        x = jnp.concatenate([patches, tok_emb], axis=1)  # vision prefix
        positions = batch["positions"]  # (3, B, S) M-RoPE grids
        B, S = x.shape[:2]
    else:
        x = layers.embed(params["embed"], batch["tokens"], cdt)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, cdt)
    x = maybe_shard(x, BATCH, None, None)
    return x, positions


def _logits(params, x, cfg: ModelConfig):
    ldt = jnp.dtype(cfg.logits_dtype)
    if cfg.frontend == "audio":
        out = layers.linear(params["head"], x).astype(ldt)
    elif cfg.tie_embeddings:
        out = layers.unembed(params["embed"], x).astype(ldt)
    else:
        out = layers.linear(params["lm_head"], x).astype(ldt)
    out = layers.softcap(out, cfg.logit_softcap)
    return maybe_shard(out, BATCH, None, TP)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def _ce_terms(params, x_slice, targets, mask, cfg):
    """(sum nll, sum mask) for one sequence slice — logits live only here."""
    logits = _logits(params, x_slice, cfg)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits, targets[..., None], axis=-1
    )[..., 0].astype(jnp.float32)
    nll = (logz - gold) * mask
    return jnp.sum(nll), jnp.sum(mask)


def forward_train(params, batch: dict, cfg: ModelConfig) -> jax.Array:
    """Mean loss. LM: next-token CE; audio encoder: masked-prediction CE."""
    x, positions = _embed_inputs(params, batch, cfg)
    x = _backbone_train(params, x, positions, cfg)

    if cfg.frontend == "audio":
        targets = batch["targets"]  # (B, S) int32
        mask = batch["mask"].astype(jnp.float32)  # (B, S) — masked positions
    else:
        tokens = batch["tokens"]
        targets = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))  # next-token
        mask = jnp.pad(
            jnp.ones_like(tokens[:, 1:], jnp.float32), ((0, 0), (0, 1))
        )
        if cfg.frontend == "vision":
            nv = x.shape[1] - tokens.shape[1]
            x = x[:, nv:]  # only text positions carry LM loss

    S = x.shape[1]
    if cfg.loss_chunk and S % cfg.loss_chunk == 0 and S > cfg.loss_chunk:
        # perf lever: chunked CE — the (B, c, V) logits tensor is transient
        # per chunk (rematerialized in backward), never (B, S, V).
        # The output matrix is constrained to replicated ONCE here, outside
        # the chunk scan — otherwise its FSDP all-gather re-runs per chunk
        # (measured +1.2s collective on qwen3-8b, see EXPERIMENTS §Perf).
        params = dict(params)
        if cfg.frontend == "audio":
            params["head"] = {"w": maybe_shard(params["head"]["w"], None, None)}
        elif cfg.tie_embeddings:
            params["embed"] = {
                "table": maybe_shard(params["embed"]["table"], None, None)
            }
        else:
            params["lm_head"] = {"w": maybe_shard(params["lm_head"]["w"], None, None)}
        c = cfg.loss_chunk
        nc = S // c
        xs = x.reshape(x.shape[0], nc, c, x.shape[-1]).swapaxes(0, 1)
        ts = targets.reshape(targets.shape[0], nc, c).swapaxes(0, 1)
        ms = mask.reshape(mask.shape[0], nc, c).swapaxes(0, 1)

        def chunk(carry, inp):
            xc, tc, mc = inp
            snll, smask = jax.checkpoint(
                lambda a, b, m: _ce_terms(params, a, b, m, cfg)
            )(xc, tc, mc)
            return (carry[0] + snll, carry[1] + smask), None

        (nll_sum, mask_sum), _ = jax.lax.scan(chunk, (jnp.zeros(()), jnp.zeros(())),
                                              (xs, ts, ms))
    else:
        nll_sum, mask_sum = _ce_terms(params, x, targets, mask, cfg)
    return nll_sum / jnp.maximum(mask_sum, 1.0)


def forward_prefill(params, batch: dict, cfg: ModelConfig, cache_len: int | None = None):
    """Returns (last-position logits (B, V), caches). Encoder-only: (logits, None).

    cache_len: total serving-cache slots (>= seq_len to leave decode room);
    defaults to seq_len (the dry-run "cache of seq_len" convention).
    """
    x, positions = _embed_inputs(params, batch, cfg)
    if cfg.encoder_only:
        x = _backbone_train(params, x, positions, cfg)
        return _logits(params, x, cfg), None
    cache_len = cache_len or x.shape[1]
    x, caches = _backbone_prefill(params, x, positions, cfg, cache_len)
    logits = _logits(params, x[:, -1:, :], cfg)[:, 0]
    return logits, caches


def forward_decode(params, batch: dict, caches, cfg: ModelConfig, return_hidden=False):
    """One decode step. batch: {"token": (B,), "pos": (B,)} (+ mrope positions).

    (For VLM decode, M-RoPE on generated text positions is exactly standard
    RoPE with t=h=w=pos, so the 2D position path is used — no approximation.)
    """
    cdt = _compute_dtype(cfg)
    tok = batch["token"]
    x = layers.embed(params["embed"], tok[:, None], cdt)  # (B, 1, dm)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, cdt)
    pos = batch["pos"]
    x, new_caches = _backbone_decode(params, x, pos, caches, cfg)
    logits = _logits(params, x, cfg)[:, 0]  # (B, V)
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if return_hidden:
        return logits, next_tok, new_caches, x[:, 0]
    return logits, next_tok, new_caches


def init_caches(batch: int, seq_len: int, cfg: ModelConfig) -> dict:
    """Zero caches for decode-from-scratch (dry-run / serving bootstrap)."""
    dtype = _compute_dtype(cfg)

    def cache_for(kind):
        if kind == "mamba2":
            return ssm.init_mamba_cache(batch, cfg, cfg.ssm, dtype)
        ak = _attn_kind(kind if kind != "shared_attn" else "attn")
        clen = attention.cache_len_for(ak, cfg, seq_len)
        return attention.init_kv_cache(batch, clen, cfg, dtype)

    caches: dict[str, Any] = {}
    if cfg.resolved_units:
        unit = {f"p{i}": cache_for(k) for i, k in enumerate(cfg.scan_unit)}
        caches["units"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.resolved_units, *a.shape)), unit
        )
    if cfg.tail:
        caches["tail"] = {f"p{i}": cache_for(k) for i, k in enumerate(cfg.tail)}
    return caches


def cache_specs(cfg: ModelConfig) -> dict:
    """PartitionSpecs for the cache pytree (batch over data, heads over model)."""

    def spec_for(kind, stacked: bool):
        lead = (None,) if stacked else ()
        if kind == "mamba2":
            return ssm.MambaCache(
                conv=P(*lead, BATCH, None, TP),
                state=P(*lead, BATCH, TP, None, None),
            )
        # KV caches shard their SEQUENCE dim over "model" by default: head
        # counts (kv=1 MQA) can't split 16 ways, the sequence always can.
        # GSPMD lowers the seq-sharded decode attention to partial softmax +
        # reduction (flash-decode style). "heads_model" is the alternative
        # lever for GQA archs whose kv count divides the axis.
        if cfg.cache_spec_mode == "heads_model":
            return attention.KVCache(
                k=P(*lead, BATCH, None, TP, None),
                v=P(*lead, BATCH, None, TP, None),
                k_pos=P(*lead, BATCH, None),
            )
        return attention.KVCache(
            k=P(*lead, BATCH, TP, None, None),
            v=P(*lead, BATCH, TP, None, None),
            k_pos=P(*lead, BATCH, TP),
        )

    specs: dict[str, Any] = {}
    if cfg.resolved_units:
        specs["units"] = {
            f"p{i}": spec_for(k, True) for i, k in enumerate(cfg.scan_unit)
        }
    if cfg.tail:
        specs["tail"] = {f"p{i}": spec_for(k, False) for i, k in enumerate(cfg.tail)}
    return specs
