"""Shared neural net layers: norms, rotary embeddings, initializers.

Functional style: ``init_*`` build parameter pytrees (nested dicts),
``*_specs`` build the matching PartitionSpec pytrees, and apply functions are
plain functions of (params, inputs). No framework dependency.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.sharding import FSDP, TP


def truncated_normal_init(key, shape, std, dtype):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def init_rmsnorm(dim: int, dtype) -> dict:
    return {"scale": jnp.zeros((dim,), dtype)}  # gemma-style (1 + scale) form


def rmsnorm_specs() -> dict:
    return {"scale": P(None)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + params["scale"].astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE + M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (B, S, H, D), positions (B, S) int -> rotated x (split-half convention)."""
    freqs = rope_freqs(x.shape[-1], theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions_3d: jax.Array, theta: float, sections: tuple
) -> jax.Array:
    """Qwen2-VL M-RoPE. x (B, S, H, D); positions_3d (3, B, S) (t, h, w) grids.

    The D/2 frequency slots are partitioned into ``sections`` (t, h, w); each
    section rotates by its own position grid. sum(sections) == D/2.
    """
    D = x.shape[-1]
    half = D // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(D, theta)  # (D/2,)
    # build per-slot positions: (B, S, D/2)
    sec_ids = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=half
    )  # static
    pos_sel = jnp.take(positions_3d, sec_ids, axis=0)  # (D/2, B, S)
    pos_sel = jnp.moveaxis(pos_sel, 0, -1).astype(jnp.float32)  # (B, S, D/2)
    angles = pos_sel * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def init_embed(key, vocab: int, d_model: int, dtype) -> dict:
    return {"table": truncated_normal_init(key, (vocab, d_model), 0.02, dtype)}


def embed_specs() -> dict:
    return {"table": P(TP, FSDP)}  # vocab over model axis, d_model over data (FSDP)


def embed(params: dict, tokens: jax.Array, compute_dtype) -> jax.Array:
    return params["table"].astype(compute_dtype)[tokens]


def unembed(params: dict, x: jax.Array) -> jax.Array:
    """logits = x @ table^T (tied); callers may cast/softcap."""
    table = params["table"].astype(x.dtype)
    return jax.lax.dot_general(
        x, table, (((x.ndim - 1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


def init_linear(key, d_in: int, d_out: int, dtype, std: float | None = None) -> dict:
    std = std if std is not None else d_in**-0.5
    return {"w": truncated_normal_init(key, (d_in, d_out), std, dtype)}


def linear_specs(spec_in, spec_out) -> dict:
    return {"w": P(spec_in, spec_out)}


def linear(params: dict, x: jax.Array) -> jax.Array:
    return x @ params["w"].astype(x.dtype)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
