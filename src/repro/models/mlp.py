"""Feed-forward blocks: SwiGLU / GeGLU / GELU, tensor-parallel over d_ff."""

from __future__ import annotations

import jax

from repro.models import layers
from repro.models.sharding import BATCH, FSDP, TP, maybe_shard


def init_mlp(key, d_model: int, d_ff: int, activation: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": layers.init_linear(k1, d_model, d_ff, dtype),
        "w_down": layers.init_linear(k2, d_ff, d_model, dtype, std=d_ff**-0.5),
    }
    if activation in ("swiglu", "geglu"):
        p["w_gate"] = layers.init_linear(k3, d_model, d_ff, dtype)
    return p


def mlp_specs(activation: str) -> dict:
    p = {
        "w_up": layers.linear_specs(FSDP, TP),
        "w_down": layers.linear_specs(TP, FSDP),
    }
    if activation in ("swiglu", "geglu"):
        p["w_gate"] = layers.linear_specs(FSDP, TP)
    return p


def mlp(params: dict, x: jax.Array, activation: str) -> jax.Array:
    up = layers.linear(params["w_up"], x)
    if activation == "swiglu":
        h = jax.nn.silu(layers.linear(params["w_gate"], x)) * up
    elif activation == "geglu":
        h = jax.nn.gelu(layers.linear(params["w_gate"], x), approximate=True) * up
    else:  # gelu
        h = jax.nn.gelu(up, approximate=True)
    h = maybe_shard(h, BATCH, None, TP)
    return layers.linear(params["w_down"], h)
