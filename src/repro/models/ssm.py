"""Mamba2 (SSD — state-space duality) block: chunked train/prefill scan +
constant-memory single-step decode.

Faithful to Dao & Gu (arXiv:2405.21060): per-head scalar decay A, grouped
B/C (n_groups), depthwise causal conv on (x, B, C), softplus dt with bias,
gated RMSNorm before out-projection.

Chunked algorithm (chunk = Q):
  intra:  Y_c = (C_c B_c^T ⊙ L_c) (dt_c ⊙ x_c)        — quadratic within chunk
  states: S_c = Σ_j exp(cum_end - cum_j) dt_j B_j x_j^T — one state per chunk
  inter:  scan over chunks: R_c = exp(Σ dA_c) R_{c-1} + S_c
          Y_c += exp(cum) C_c R_{c-1}

All recurrence math in float32; projections in the model compute dtype.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models import layers
from repro.models.sharding import BATCH, FSDP, TP, maybe_shard


def _dims(cfg: ModelConfig, scfg: SSMConfig):
    d_inner = scfg.expand * cfg.d_model
    nh = d_inner // scfg.head_dim
    conv_dim = d_inner + 2 * scfg.n_groups * scfg.d_state
    return d_inner, nh, conv_dim


def init_mamba2(key, cfg: ModelConfig, scfg: SSMConfig, dtype) -> dict:
    d_inner, nh, conv_dim = _dims(cfg, scfg)
    d_in_proj = 2 * d_inner + 2 * scfg.n_groups * scfg.d_state + nh
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": layers.init_linear(k1, cfg.d_model, d_in_proj, dtype),
        "conv_w": layers.truncated_normal_init(k2, (scfg.d_conv, conv_dim), 0.2, dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)
        ).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nh,), 1e-2, jnp.float32))),
        "norm": layers.init_rmsnorm(d_inner, dtype),
        "out_proj": layers.init_linear(k3, d_inner, cfg.d_model, dtype, std=d_inner**-0.5),
    }


def mamba2_specs(cfg: ModelConfig, scfg: SSMConfig) -> dict:
    P = jax.sharding.PartitionSpec
    return {
        "in_proj": layers.linear_specs(FSDP, TP),
        "conv_w": P(None, TP),
        "conv_b": P(TP),
        "A_log": P(None),
        "D": P(None),
        "dt_bias": P(None),
        "norm": layers.rmsnorm_specs(),
        "out_proj": layers.linear_specs(TP, FSDP),
    }


class MambaCache(NamedTuple):
    conv: jax.Array  # (B, d_conv - 1, conv_dim) last conv inputs
    state: jax.Array  # (B, nh, head_dim, d_state) float32 SSM state


def init_mamba_cache(batch: int, cfg: ModelConfig, scfg: SSMConfig, dtype) -> MambaCache:
    d_inner, nh, conv_dim = _dims(cfg, scfg)
    return MambaCache(
        conv=jnp.zeros((batch, scfg.d_conv - 1, conv_dim), dtype),
        state=jnp.zeros((batch, nh, scfg.head_dim, scfg.d_state), jnp.float32),
    )


def _split_proj(proj, cfg: ModelConfig, scfg: SSMConfig):
    d_inner, nh, _ = _dims(cfg, scfg)
    gs = scfg.n_groups * scfg.d_state
    z, xBC, dt = jnp.split(proj, [d_inner, d_inner + d_inner + 2 * gs], axis=-1)
    return z, xBC, dt  # dt (…, nh)


def _conv_sequence(xBC, params, scfg: SSMConfig, init_conv=None):
    """Depthwise causal conv1d along seq. xBC (B, S, conv_dim)."""
    B, S, Cd = xBC.shape
    K = scfg.d_conv
    if init_conv is None:
        init_conv = jnp.zeros((B, K - 1, Cd), xBC.dtype)
    padded = jnp.concatenate([init_conv, xBC], axis=1)  # (B, S+K-1, Cd)
    w = params["conv_w"].astype(xBC.dtype)  # (K, Cd)
    out = jnp.zeros_like(xBC)
    for i in range(K):  # K is tiny (4): unrolled taps
        out = out + padded[:, i : i + S, :] * w[i][None, None, :]
    out = out + params["conv_b"].astype(xBC.dtype)[None, None, :]
    return jax.nn.silu(out), padded[:, -(K - 1) :, :] if K > 1 else init_conv


def mamba2_sequence(
    params: dict,
    u: jax.Array,
    cfg: ModelConfig,
    scfg: SSMConfig,
    return_cache: bool = False,
):
    """u (B, S, dm) -> (B, S, dm) [, MambaCache]. Chunked SSD scan."""
    B, S, dm = u.shape
    d_inner, nh, conv_dim = _dims(cfg, scfg)
    hd, ds, ng = scfg.head_dim, scfg.d_state, scfg.n_groups
    Q = min(scfg.chunk, S)
    pad = -S % Q
    nc = (S + pad) // Q

    proj = layers.linear(params["in_proj"], u)
    z, xBC, dt = _split_proj(proj, cfg, scfg)
    xBC, conv_tail = _conv_sequence(xBC, params, scfg)
    x, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + ng * ds], axis=-1)

    # float32 recurrence land
    x = x.reshape(B, S, nh, hd).astype(jnp.float32)
    Bm = Bm.reshape(B, S, ng, ds).astype(jnp.float32)
    Cm = Cm.reshape(B, S, ng, ds).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, None, :])  # (B,S,nh)
    A = -jnp.exp(params["A_log"])  # (nh,)
    dA = dt * A[None, None, :]  # (B, S, nh) negative

    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))

    Sp = S + pad
    xc = x.reshape(B, nc, Q, nh, hd)
    Bc = Bm.reshape(B, nc, Q, ng, ds)
    Cc = Cm.reshape(B, nc, Q, ng, ds)
    dtc = dt.reshape(B, nc, Q, nh)
    dAc = dA.reshape(B, nc, Q, nh)
    cum = jnp.cumsum(dAc, axis=2)  # (B, nc, Q, nh) inclusive
    total = cum[:, :, -1, :]  # (B, nc, nh)

    # intra-chunk: heads share group B/C (ng==1 assumed for head broadcast)
    CB = jnp.einsum("bcqgs,bckgs->bcqk", Cc, Bc)  # (B,nc,Q,Q) group-summed
    # L[b,c,h,i,j] = exp(cum_i - cum_j) for i >= j
    Lmat = jnp.exp(
        jnp.clip(cum[:, :, :, None, :] - cum[:, :, None, :, :], -60.0, 0.0)
    )  # (B,nc,Q,Q,nh)
    tri = jnp.tril(jnp.ones((Q, Q), jnp.float32))
    W = CB[..., None] * Lmat * tri[None, None, :, :, None]  # (B,nc,Q,Q,nh)
    dx = dtc[..., None] * xc  # (B,nc,Q,nh,hd)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", W, dx)

    # chunk states: S_c[h,p,s] = sum_j exp(total - cum_j) dx_j[h,p] B_j[s]
    decay_state = jnp.exp(jnp.clip(total[:, :, None, :] - cum, -60.0, None))  # (B,nc,Q,nh)
    Sc = jnp.einsum("bcqh,bcqhp,bcqgs->bchps", decay_state, dx, Bc)  # (B,nc,nh,hd,ds)

    # inter-chunk scan
    def step(R, inp):
        Sc_c, tot_c = inp  # (B,nh,hd,ds), (B,nh)
        R_out = R  # state BEFORE this chunk
        R_new = R * jnp.exp(jnp.clip(tot_c, -60.0, 0.0))[:, :, None, None] + Sc_c
        return R_new, R_out

    R0 = jnp.zeros((B, nh, hd, ds), jnp.float32)
    R_final, R_prevs = jax.lax.scan(
        step,
        R0,
        (jnp.moveaxis(Sc, 1, 0), jnp.moveaxis(total, 1, 0)),
    )
    R_prev = jnp.moveaxis(R_prevs, 0, 1)  # (B,nc,nh,hd,ds) state entering chunk

    decay_in = jnp.exp(jnp.clip(cum, -60.0, 0.0))  # (B,nc,Q,nh)
    y_inter = jnp.einsum("bcqgs,bchps,bcqh->bcqhp", Cc, R_prev, decay_in)

    y = (y_intra + y_inter).reshape(B, Sp, nh, hd)[:, :S]
    y = y + params["D"][None, None, :, None] * x.reshape(B, Sp, nh, hd)[:, :S]
    y = y.reshape(B, S, d_inner)

    # gated RMSNorm + out projection
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = layers.rmsnorm(params["norm"], y, cfg.norm_eps).astype(u.dtype)
    out = layers.linear(params["out_proj"], y)
    out = maybe_shard(out, BATCH, None, None)
    if return_cache:
        return out, MambaCache(conv=conv_tail.astype(u.dtype), state=R_final)
    return out


def mamba2_decode(
    params: dict,
    u: jax.Array,
    cache: MambaCache,
    cfg: ModelConfig,
    scfg: SSMConfig,
):
    """One-token decode. u (B, 1, dm) -> (B, 1, dm), new cache. O(1) in context."""
    B = u.shape[0]
    d_inner, nh, conv_dim = _dims(cfg, scfg)
    hd, ds, ng = scfg.head_dim, scfg.d_state, scfg.n_groups

    proj = layers.linear(params["in_proj"], u)[:, 0]  # (B, d_in_proj)
    z, xBC, dt = _split_proj(proj, cfg, scfg)

    # conv ring buffer
    window = jnp.concatenate([cache.conv, xBC[:, None, :]], axis=1)  # (B, K, conv_dim)
    w = params["conv_w"].astype(xBC.dtype)
    xBC = jnp.einsum("bkc,kc->bc", window, w) + params["conv_b"].astype(xBC.dtype)
    xBC = jax.nn.silu(xBC)
    new_conv = window[:, 1:, :]

    x, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + ng * ds], axis=-1)
    x = x.reshape(B, nh, hd).astype(jnp.float32)
    Bm = Bm.reshape(B, ng, ds).astype(jnp.float32)[:, 0]  # ng == 1
    Cm = Cm.reshape(B, ng, ds).astype(jnp.float32)[:, 0]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, :])  # (B, nh)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A[None, :])  # (B, nh)

    dx = dt[..., None] * x  # (B, nh, hd)
    state = cache.state * decay[:, :, None, None] + jnp.einsum("bhp,bs->bhps", dx, Bm)
    y = jnp.einsum("bhps,bs->bhp", state, Cm) + params["D"][None, :, None] * x
    y = y.reshape(B, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = layers.rmsnorm(params["norm"], y, cfg.norm_eps).astype(u.dtype)
    out = layers.linear(params["out_proj"], y)[:, None, :]
    return out, MambaCache(conv=new_conv, state=state)
