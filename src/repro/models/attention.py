"""Attention: GQA/MQA, qk-norm, RoPE/M-RoPE/NoPE, full/sliding-window/chunked.

Two execution paths:

  * ``attn_sequence`` (train / prefill): blockwise FLASH-style attention in
    pure JAX — outer scan over query blocks, inner scan over KV blocks with an
    online-softmax accumulator, so peak memory is O(blk_q * blk_kv) instead of
    O(S^2). Local ("local", window) and chunked ("chunked", llama4-iRoPE)
    kinds slice a static KV window per query block — linear-in-S FLOPs.
  * ``attn_decode`` (serving): one new token against a ring-buffer KV cache
    with absolute-position tracking (`k_pos`), so full/local/chunked masking
    is uniform: a position-predicate over cached slots.

KV caches are rotated at WRITE time (k stored post-RoPE), the standard
serving layout.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.sharding import BATCH, FSDP, TP, maybe_shard

NEG_INF = -1e30  # repro: allow[RPR003] additive attention-mask logit floor, not a wl1 distance fill (softmax needs finite)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, dtype) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    dm, H, Hkv, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": layers.init_linear(kq, dm, H * D, dtype),
        "wk": layers.init_linear(kk, dm, Hkv * D, dtype),
        "wv": layers.init_linear(kv, dm, Hkv * D, dtype),
        "wo": layers.init_linear(ko, H * D, dm, dtype, std=(H * D) ** -0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.init_rmsnorm(D, dtype)
        p["k_norm"] = layers.init_rmsnorm(D, dtype)
    return p


def attention_specs(cfg: ModelConfig) -> dict:
    p = {
        "wq": layers.linear_specs(FSDP, TP),
        "wk": layers.linear_specs(FSDP, TP),
        "wv": layers.linear_specs(FSDP, TP),
        "wo": layers.linear_specs(TP, FSDP),
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.rmsnorm_specs()
        p["k_norm"] = layers.rmsnorm_specs()
    return p


class KVCache(NamedTuple):
    """Ring-buffer KV cache for one attention layer."""

    k: jax.Array  # (B, C, Hkv, D) — rotated keys
    v: jax.Array  # (B, C, Hkv, D)
    k_pos: jax.Array  # (B, C) int32 absolute positions (-1 = empty)

    @property
    def cache_len(self) -> int:
        return self.k.shape[1]


def init_kv_cache(batch: int, cache_len: int, cfg: ModelConfig, dtype) -> KVCache:
    Hkv, D = cfg.n_kv_heads, cfg.head_dim
    return KVCache(
        k=jnp.zeros((batch, cache_len, Hkv, D), dtype),
        v=jnp.zeros((batch, cache_len, Hkv, D), dtype),
        k_pos=jnp.full((batch, cache_len), -1, jnp.int32),
    )


def cache_len_for(kind: str, cfg: ModelConfig, seq_len: int) -> int:
    if kind == "local":
        return min(cfg.window, seq_len)
    if kind == "chunked":
        return min(cfg.chunk_size, seq_len)
    return seq_len  # full / global / global_nope / shared_attn


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------


def _qkv(params, x, cfg: ModelConfig, positions, kind: str):
    """Project + norm + rotate. x (B, S, dm) -> q (B,S,H,D), k/v (B,S,Hkv,D)."""
    B, S, _ = x.shape
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = layers.linear(params["wq"], x).reshape(B, S, H, D)
    k = layers.linear(params["wk"], x).reshape(B, S, Hkv, D)
    v = layers.linear(params["wv"], x).reshape(B, S, Hkv, D)
    if cfg.qk_norm:
        q = layers.rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = layers.rmsnorm(params["k_norm"], k, cfg.norm_eps)
    if kind != "global_nope":
        theta = cfg.rope_theta
        if kind == "local" and cfg.rope_local_theta is not None:
            theta = cfg.rope_local_theta
        if cfg.pos == "mrope" and positions.ndim == 3:
            q = layers.apply_mrope(q, positions, theta, cfg.mrope_sections)
            k = layers.apply_mrope(k, positions, theta, cfg.mrope_sections)
        else:
            pos2d = positions if positions.ndim == 2 else positions[0]
            q = layers.apply_rope(q, pos2d, theta)
            k = layers.apply_rope(k, pos2d, theta)
    q = maybe_shard(q, BATCH, None, TP, None)
    k = maybe_shard(k, BATCH, None, TP, None)
    v = maybe_shard(v, BATCH, None, TP, None)
    return q, k, v


# ---------------------------------------------------------------------------
# Blockwise (flash-style) sequence attention
# ---------------------------------------------------------------------------


def _block_mask(kind: str, causal: bool, q_pos, k_pos, window: int, chunk: int):
    """(..., q, k) boolean mask from absolute positions."""
    valid = k_pos[..., None, :] >= 0
    if causal:
        valid &= k_pos[..., None, :] <= q_pos[..., :, None]
    if kind == "local":
        valid &= k_pos[..., None, :] > q_pos[..., :, None] - window
    elif kind == "chunked":
        q_chunk = q_pos // chunk
        k_chunk = k_pos // chunk
        valid &= k_chunk[..., None, :] == q_chunk[..., :, None]
    return valid


def _sdpa_blocked(q, k, v, q_pos, k_pos, cfg: ModelConfig, kind: str, blk_q: int,
                  blk_kv: int, tri_ok: bool = False):
    """Online-softmax attention. q (B,Sq,H,D); k/v (B,Sk,Hkv,D); pos int arrays.

    Returns (B, Sq, H, D). Sq % blk_q == 0 and Sk % blk_kv == 0 (wrapper pads).
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    Hkv = k.shape[2]
    G = H // Hkv
    scale = D**-0.5
    nq, nk = Sq // blk_q, Sk // blk_kv

    # (B, nq, blk_q, Hkv, G, D) query blocks
    qb = q.reshape(B, nq, blk_q, Hkv, G, D)
    qpb = q_pos.reshape(B, nq, blk_q) if q_pos.ndim == 2 else q_pos.reshape(nq, blk_q)
    kb = k.reshape(B, nk, blk_kv, Hkv, D)
    vb = v.reshape(B, nk, blk_kv, Hkv, D)
    kpb = k_pos.reshape(B, nk, blk_kv) if k_pos.ndim == 2 else k_pos.reshape(nk, blk_kv)

    # triangular skip: for causal FULL attention, a KV block strictly above
    # the diagonal contributes nothing — lax.cond skips its compute at
    # runtime (differentiable; XLA conditionals truly skip on TPU). Saves
    # ~2x attention FLOPs at long S (the analytic roofline model counts
    # (S + 2*blk)/2 accordingly).
    tri_skip = cfg.causal and (
        kind in ("attn", "global", "global_nope", "shared_attn") or tri_ok
    )

    def q_block(carry, qi):
        q_i = jax.lax.dynamic_index_in_dim(qb, qi, 1, keepdims=False)  # (B,blk_q,Hkv,G,D)
        qp_i = jax.lax.dynamic_index_in_dim(qpb, qi, qpb.ndim - 2, keepdims=False)

        def kv_compute(acc, ki):
            m, l, o = acc
            k_j = jax.lax.dynamic_index_in_dim(kb, ki, 1, keepdims=False)  # (B,blk_kv,Hkv,D)
            v_j = jax.lax.dynamic_index_in_dim(vb, ki, 1, keepdims=False)
            kp_j = jax.lax.dynamic_index_in_dim(kpb, ki, kpb.ndim - 2, keepdims=False)
            # logits (B, Hkv, G, blk_q, blk_kv)
            logits = jnp.einsum(
                "bqhgd,bkhd->bhgqk", q_i, k_j, preferred_element_type=jnp.float32
            )
            logits = logits * scale
            mask = _block_mask(
                kind, cfg.causal, qp_i, kp_j, cfg.window, cfg.chunk_size
            )  # (B, blk_q, blk_kv) or (blk_q, blk_kv)
            if mask.ndim == 2:
                mask = mask[None]
            logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v_j.dtype), v_j,
                            preferred_element_type=jnp.float32)
            o_new = o * corr[..., None] + pv
            return (m_new, l_new, o_new)

        def kv_block(acc, ki):
            if tri_skip:
                on_or_below_diag = ki * blk_kv <= (qi + 1) * blk_q - 1
                return (
                    jax.lax.cond(on_or_below_diag, kv_compute,
                                 lambda a, _ki: a, acc, ki),
                    None,
                )
            return kv_compute(acc, ki), None

        m0 = jnp.full((B, Hkv, G, blk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, blk_q), jnp.float32)
        o0 = jnp.zeros((B, Hkv, G, blk_q, D), jnp.float32)
        (m, l, o), _ = jax.lax.scan(kv_block, (m0, l0, o0), jnp.arange(nk))
        out = o / jnp.maximum(l[..., None], 1e-30)  # (B,Hkv,G,blk_q,D)
        out = jnp.moveaxis(out, 3, 1)  # (B, blk_q, Hkv, G, D)
        return carry, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_block, None, jnp.arange(nq))  # (nq, B, blk_q, Hkv, G, D)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, D)
    return out


def _sdpa_windowed(q, k, v, q_pos, k_pos, cfg: ModelConfig, kind: str, blk_q: int):
    """Local/chunked attention: each query block sees a static KV window.

    Window span W + blk_q where W = window (local) or chunk_size (chunked) —
    linear-in-S FLOPs, the sub-quadratic path used by long-context archs.
    """
    B, Sq, H, D = q.shape
    W = cfg.window if kind == "local" else cfg.chunk_size
    W = min(W, k.shape[1])
    Hkv = k.shape[2]
    G = H // Hkv
    scale = D**-0.5
    nq = Sq // blk_q
    span = W + blk_q

    qb = q.reshape(B, nq, blk_q, Hkv, G, D)
    qpb = q_pos.reshape(B, nq, blk_q) if q_pos.ndim == 2 else q_pos.reshape(nq, blk_q)

    def q_block(carry, qi):
        q_i = jax.lax.dynamic_index_in_dim(qb, qi, 1, keepdims=False)
        qp_i = jax.lax.dynamic_index_in_dim(qpb, qi, qpb.ndim - 2, keepdims=False)
        start = jnp.maximum(qi * blk_q - W, 0)
        start = jnp.minimum(start, k.shape[1] - span)
        k_w = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
        v_w = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
        kp_w = jax.lax.dynamic_slice_in_dim(k_pos, start, span, axis=k_pos.ndim - 1)
        logits = jnp.einsum(
            "bqhgd,bkhd->bhgqk", q_i, k_w, preferred_element_type=jnp.float32
        ) * scale
        mask = _block_mask(kind, cfg.causal, qp_i, kp_w, cfg.window, cfg.chunk_size)
        if mask.ndim == 2:
            mask = mask[None]
        logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
        m = jnp.max(logits, axis=-1, keepdims=True)
        p = jnp.exp(logits - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        out = jnp.einsum("bhgqk,bkhd->bhgqd", (p / jnp.maximum(l, 1e-30)).astype(v_w.dtype),
                         v_w, preferred_element_type=jnp.float32)
        out = jnp.moveaxis(out, 3, 1)  # (B, blk_q, Hkv, G, D)
        return carry, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_block, None, jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, D)
    return out


def attn_sequence(
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    kind: str,
    blk_q: int | None = None,
    blk_kv: int | None = None,
) -> jax.Array:
    """Full-sequence attention (train/prefill). x (B, S, dm) -> (B, S, dm)."""
    blk_q = blk_q or cfg.attn_blk_q
    blk_kv = blk_kv or cfg.attn_blk_kv
    B, S, _ = x.shape
    q, k, v = _qkv(params, x, cfg, positions, kind)
    pos2d = positions if positions.ndim == 2 else positions[0]

    blk_q = min(blk_q, S)
    blk_kv = min(blk_kv, S)
    pad_q = -S % blk_q
    if pad_q:  # pad queries/keys to block multiple; padded k_pos = -1 masks them
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        pos2d = jnp.pad(pos2d, ((0, 0), (0, pad_q)), constant_values=-1)

    if kind in ("local", "chunked") and k.shape[1] > (
        (cfg.window if kind == "local" else cfg.chunk_size) + blk_q
    ):
        out = _sdpa_windowed(q, k, v, pos2d, pos2d, cfg, kind, blk_q)
    else:
        # chunked at S <= chunk_size degenerates to plain causal ⇒ the
        # triangular block skip applies
        tri_ok = kind == "chunked" and S <= cfg.chunk_size
        out = _sdpa_blocked(q, k, v, pos2d, pos2d, cfg, kind, blk_q, blk_kv, tri_ok)
    if pad_q:
        out = out[:, :S]
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    out = layers.linear(params["wo"], out)
    return maybe_shard(out, BATCH, None, None)


def prefill_kv(
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    kind: str,
    cache_len: int,
) -> KVCache:
    """Build the layer's KV cache from a prefilled sequence (last cache_len slots)."""
    B, S, _ = x.shape
    _, k, v = _qkv(params, x, cfg, positions, kind)
    pos2d = positions if positions.ndim == 2 else positions[0]
    if S >= cache_len:
        k = k[:, S - cache_len :]
        v = v[:, S - cache_len :]
        kp = pos2d[:, S - cache_len :]
    else:
        pad = cache_len - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kp = jnp.pad(pos2d, ((0, 0), (0, pad)), constant_values=-1)
    return KVCache(k=k, v=v, k_pos=kp.astype(jnp.int32))


def attn_decode(
    params: dict,
    x: jax.Array,
    pos: jax.Array,
    cache: KVCache,
    cfg: ModelConfig,
    kind: str,
) -> tuple[jax.Array, KVCache]:
    """One-token decode. x (B, 1, dm); pos (B,) absolute position of the new token."""
    B = x.shape[0]
    q, k_new, v_new = _qkv(params, x, cfg, positions=pos[:, None], kind=kind)
    # ring-buffer write
    slot = (pos % cache.cache_len).astype(jnp.int32)  # (B,)
    bidx = jnp.arange(B)
    k = cache.k.at[bidx, slot].set(k_new[:, 0].astype(cache.k.dtype))
    v = cache.v.at[bidx, slot].set(v_new[:, 0].astype(cache.v.dtype))
    k_pos = cache.k_pos.at[bidx, slot].set(pos.astype(jnp.int32))
    new_cache = KVCache(k=k, v=v, k_pos=k_pos)

    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg, k, preferred_element_type=jnp.float32)
    logits = logits * (D**-0.5)
    mask = _block_mask(kind, True, pos[:, None], k_pos, cfg.window, cfg.chunk_size)[:, 0, :]
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, 1, H * D).astype(x.dtype)
    out = layers.linear(params["wo"], out)
    return out, new_cache
