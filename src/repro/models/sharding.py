"""Mesh context + logical sharding rules for the model/runtime stack.

Axes: ("pod", "data", "model") — production meshes (2, 16, 16) and (16, 16)
(the single-pod mesh has no "pod" axis; rules degrade gracefully).

Design: a module-level mesh context (set by launch code). ``maybe_shard``
applies with_sharding_constraint only when a mesh is active, so the exact same
model code runs single-device (tests/examples) and on the production mesh
(dry-run/train). Batch shards over ("pod", "data"); tensor-parallel dims over
"model"; FSDP parameter sharding over "data" on a rule-selected axis.
"""

from __future__ import annotations

import contextlib
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    global _ACTIVE_MESH
    prev, _ACTIVE_MESH = _ACTIVE_MESH, mesh
    try:
        yield
    finally:
        _ACTIVE_MESH = prev


def _filter_spec(spec: Sequence) -> P:
    """Drop axis names that don't exist in the active mesh (e.g. 'pod' on 1-pod)."""
    mesh = _ACTIVE_MESH
    names = set(mesh.axis_names) if mesh is not None else set()

    def keep(entry):
        entry = resolve_entry(entry)
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(e for e in entry if e in names)
            return kept if kept else None
        return entry if entry in names else None

    return P(*(keep(e) for e in spec))


def sharding(*spec) -> Optional[NamedSharding]:
    """NamedSharding for the active mesh (None if no mesh)."""
    if _ACTIVE_MESH is None:
        return None
    return NamedSharding(_ACTIVE_MESH, _filter_spec(spec))


def _axis_size(mesh: Mesh, entry) -> int:
    if isinstance(entry, (tuple, list)):
        n = 1
        for e in entry:
            n *= mesh.shape[e]
        return n
    return mesh.shape[entry]


def _sanitize_entry(mesh: Mesh, entry, dim: int):
    """Keep a spec entry only if it divides the dim; tuples degrade greedily
    (e.g. ("pod","data") on batch 8 with 2x16 mesh -> ("pod",))."""
    entry = resolve_entry(entry)
    if entry is None:
        return None
    if isinstance(entry, (tuple, list)):
        kept = []
        prod = 1
        for e in entry:
            if e in mesh.axis_names and dim % (prod * mesh.shape[e]) == 0:
                kept.append(e)
                prod *= mesh.shape[e]
        return tuple(kept) if kept else None
    if entry not in mesh.axis_names:
        return None
    return entry if dim % mesh.shape[entry] == 0 else None


def sanitize_spec(spec: P, shape: tuple, mesh: Optional[Mesh] = None) -> P:
    """Shape-aware spec cleanup: drop axes that don't exist in the mesh or
    don't divide the corresponding dim (kv=1 heads, batch=1, vocab 504...)."""
    mesh = mesh or _ACTIVE_MESH
    if mesh is None:
        return P()
    entries = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    return P(*(_sanitize_entry(mesh, e, d) for e, d in zip(entries, shape)))


def sanitize_spec_tree(spec_tree, shape_tree, mesh: Optional[Mesh] = None):
    """Walk a (PartitionSpec pytree, shape pytree) pair and sanitize each leaf."""
    return jax.tree.map(
        lambda s, x: sanitize_spec(s, x.shape, mesh),
        spec_tree,
        shape_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


def maybe_shard(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint when a mesh is active; identity otherwise.

    Shape-aware: entries that don't divide the dim are dropped, so the same
    model code serves every (arch x shape x mesh) combination.
    """
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x
    clean = sanitize_spec(P(*spec), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, clean))


# ---------------------------------------------------------------------------
# Canonical logical specs (referenced by model + runtime code)
#
# These are SENTINELS resolved against the active sharding policy at
# constraint/lowering time, so one model codebase supports both parallelism
# layouts:
#   megatron  (default): batch over ("pod","data"); TP over "model"
#   fsdp_only (dp_over_model=True): batch over ("pod","data","model") — the
#             model axis becomes extra data parallelism; TP constraints
#             dissolve (params replicate across "model", still ZeRO over
#             "data"); EP stays on "model" (experts must shard somewhere).
# ---------------------------------------------------------------------------

BATCH = "@batch"
TP = "@tp"
FSDP = "@fsdp"
EP = "@ep"  # expert parallelism — survives fsdp_only mode
SEQ_SP = "@tp"  # sequence parallelism rides the tp axis

_POLICY = {
    "@batch": ("pod", "data"),
    "@tp": "model",
    "@fsdp": "data",
    "@ep": "model",
}


def set_policy(dp_over_model: bool = False, fsdp: bool = True) -> None:
    """Select the parallelism layout (see module docstring).

    fsdp=False replicates parameters over the data axis (the serving layout:
    weights live TP-sharded, no per-step FSDP gathers).
    """
    _POLICY["@batch"] = ("pod", "data", "model") if dp_over_model else ("pod", "data")
    _POLICY["@tp"] = None if dp_over_model else "model"
    _POLICY["@fsdp"] = "data" if fsdp else None


def resolve_entry(entry):
    """Sentinel -> concrete mesh-axis entry under the active policy."""
    if isinstance(entry, str) and entry.startswith("@"):
        return _POLICY[entry]
    if isinstance(entry, (tuple, list)):
        out = []
        for e in entry:
            r = resolve_entry(e)
            if r is None:
                continue
            out.extend(r) if isinstance(r, (tuple, list)) else out.append(r)
        return tuple(out) if out else None
    return entry


def batch_spec(*rest) -> tuple:
    return (BATCH, *rest)
