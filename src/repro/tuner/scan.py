"""Fan-out scan executor with a crash-safe incremental trial store.

One trial = build a small index at a concrete :class:`~repro.tuner.space.
TrialSpec` point and measure, through the REAL ``repro.api`` /
``repro.engine`` query path (never a simulation):

  * ``recall``     — held-out recall@k against the exact oracle
  * ``cand_frac``  — mean unique candidates / n (the sublinearity metric)
  * ``cost``       — the planner's deterministic candidate+slot cost model
                     (the latency axis of the Pareto table; wall-clock-free
                     so resumed and fresh scans agree bit-for-bit)
  * ``mem_bytes``  — bytes of the built index state
  * ``us_per_query`` — measured wall time (advisory only: recorded for
                     humans, EXCLUDED from the frontier so the tuning-table
                     artifact stays bit-reproducible)

Execution fans out across worker PROCESSES (``workers=N`` spawns fresh
interpreters — each gets its own jax runtime, so a crashed or OOM-killed
trial never takes the scan down) and optionally across devices: trials with
``shards > 1`` build through ``Index.shard`` and measure the sharded query
path (skipped with a recorded reason when the host has too few devices).

Crash safety is the JSONL trial store: one fsync'd line per COMPLETED
trial, keyed by the content-addressed ``trial_id``. Resuming a partial run
re-enumerates the space, skips every stored id, tolerates a torn trailing
line (the crash artifact), and rejects a store written for a different
space. Per-trial seeds derive from the trial ids, so the completed grid —
and the Pareto frontier built from it — is bit-identical no matter how many
times the scan died on the way there.
"""

from __future__ import annotations

import json
import os
import time

from repro.tuner.space import (
    AUTO_WIDTH,
    ScanSpace,
    TrialSpec,
    profile_data,
    profile_queries,
    profile_weights,
)

__all__ = ["TrialStore", "run_trial", "run_scan", "resolve_width", "scan_is_complete", "trial_cost"]

# relative cost of a probed (table, probe, slot) vs one reranked candidate —
# mirrors Planner.slot_cost so scan costs and plan costs rank identically
SLOT_COST = 0.02


def trial_cost(
    L: int,
    n_probes: int,
    window: int,
    mean_cand: float,
    mean_tables: float | None = None,
) -> float:
    """The deterministic latency proxy used for Pareto dominance.

    ``mean_tables`` is the measured mean probe windows visited (early-exit
    trials): the slot term then charges only the expected fraction of the
    L·n_probes lattice the streamed tail actually touched — the
    expected-tables-probed cost column dominance runs over. None (or a
    full sweep) charges the whole lattice, exactly the pre-streaming
    model."""
    slots = float(L * n_probes * window)
    if mean_tables is not None:
        slots *= min(1.0, float(mean_tables) / float(L * n_probes))
    return float(mean_cand) + SLOT_COST * slots


def resolve_width(trial: TrialSpec, data, key) -> float:
    """Resolve ``W="auto"`` for an l2 trial: anchor the bucket width at the
    planner's collision-prob goal on the 75th percentile of the transformed
    kth-NN near distance — the same scale-robust rule
    ``Planner._solve_family`` applies, computed on the trial's own data."""
    import jax
    import jax.numpy as jnp

    from repro.api.planner import Planner
    from repro.core import theory, transforms
    from repro.core.transforms import BoundedSpace
    from repro.kernels import ops

    space = BoundedSpace(0.0, 1.0, float(trial.M))
    m = min(trial.queries, trial.profile.n)
    k_rows, k_j, k_w = jax.random.split(key, 3)
    rows = jax.random.choice(k_rows, data.shape[0], (m,), replace=False)
    qs = data[rows] + jax.random.uniform(
        k_j, (m, trial.profile.d), minval=-1 / space.t, maxval=1 / space.t
    )
    ws = profile_weights(k_w, (m, trial.profile.d), trial.profile.skew)
    levels = transforms.discretize(data, space).astype(jnp.float32)
    qlevels = transforms.discretize(qs, space).astype(jnp.float32)
    kk = min(trial.k + 1, data.shape[0])
    nn_d, _ = ops.wl1_scan_topk(levels, qlevels, ws, kk)
    r1 = jnp.maximum(nn_d[:, kk - 1], 1e-6)
    s1 = theory.l2_distance_from_wl1(r1, max(space.M, 1), trial.profile.d, ws)
    c_star = 1.0 / theory.invert_p_l2(Planner._P1_GOAL, 1.0)
    return float(c_star * jnp.quantile(s1, 0.75))


def run_trial(trial_dict: dict, real_data=None) -> dict:
    """Execute one trial; returns the store record (a plain JSON dict).

    Deterministic given the trial content (except the advisory
    ``us_per_query`` wall-clock field). Importable at module top level so
    spawn-based worker pools can pickle it.
    """
    import jax
    import jax.numpy as jnp

    from repro.api import Index, IndexConfig, PlannedSpec, QuerySpec
    from repro.core.transforms import BoundedSpace
    from repro.distance import recall_at_k

    trial = TrialSpec.from_dict(trial_dict)
    rec = {"trial_id": trial.trial_id, "trial": trial.to_dict(), "status": "ok"}
    if trial.shards > 1 and jax.device_count() < trial.shards:
        rec.update(
            status="skipped",
            reason=f"needs {trial.shards} devices, host has {jax.device_count()}",
        )
        return rec

    key = jax.random.PRNGKey(trial.seed)
    data = profile_data(trial.profile, jax.random.fold_in(key, 0), real_data)
    W = trial.W
    if W == AUTO_WIDTH:
        W = (
            resolve_width(trial, data, jax.random.fold_in(key, 1))
            if trial.family == "l2"
            else 4.0
        )
    cfg = IndexConfig(
        d=trial.profile.d, M=trial.M, K=trial.K, L=trial.L,
        family=trial.family, W=float(W), max_candidates=trial.window,
        space=BoundedSpace(0.0, 1.0, float(trial.M)),
    )
    index = Index.build(jax.random.fold_in(key, 2), data, cfg)

    qs = profile_queries(
        trial.profile, jax.random.fold_in(key, 3), trial.queries, real_data
    )
    ws = profile_weights(
        jax.random.fold_in(key, 4), (trial.queries, trial.profile.d),
        trial.profile.skew,
    )
    spec = PlannedSpec(
        k=trial.k, mode="multiprobe" if trial.n_probes > 1 else "probe",
        n_probes=trial.n_probes if trial.n_probes > 1 else 1,
        max_flips=trial.max_flips, max_candidates=trial.window,
        early_exit=trial.early_exit, exit_group=trial.exit_group,
        exit_slack=trial.exit_slack,
    )
    handle = index
    if trial.shards > 1:
        handle = index.shard(jax.make_mesh((trial.shards,), ("data",)))

    res = handle.query(qs, ws, spec)
    exact = handle.query(qs, ws, QuerySpec(k=trial.k, mode="exact"))
    recall = float(recall_at_k(res.ids, exact.ids, trial.k))
    mean_cand = float(jnp.mean(res.n_candidates))
    mean_tables = (
        float(jnp.mean(res.tables_probed))
        if res.tables_probed is not None
        else None
    )

    # advisory wall time: median of 3 warm calls (compile excluded)
    times = []
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(handle.query(qs, ws, spec).ids)
        times.append(time.perf_counter() - t0)
    times.sort()

    rec.update(
        family=trial.family, K=trial.K, L=trial.L, W=float(W),
        n_probes=trial.n_probes, max_flips=trial.max_flips,
        window=trial.window, k=trial.k, shards=trial.shards,
        early_exit=trial.early_exit, exit_group=trial.exit_group,
        exit_slack=trial.exit_slack,
        tables_probed=mean_tables,
        recall=recall,
        cand_frac=mean_cand / trial.profile.n,
        cost=trial_cost(
            trial.L, trial.n_probes, trial.window, mean_cand, mean_tables
        ),
        mem_bytes=int(
            sum(x.nbytes for x in jax.tree_util.tree_leaves(index.state))
        ),
        us_per_query=times[1] / trial.queries * 1e6,
    )
    return rec


def _pool_trial(args) -> dict:
    trial_dict, real = args
    return run_trial(trial_dict, real_data=real)


class TrialStore:
    """Append-only JSONL store of completed trial records.

    Line 0 is a header naming the :class:`ScanSpace` content hash; every
    following line is one completed trial. Writes are flushed + fsync'd per
    record, so a kill between trials loses nothing and a kill mid-write
    leaves at most one torn TRAILING line, which ``load`` tolerates. A torn
    or alien line anywhere else means the store is corrupt (or belongs to a
    different scan) and raises a named error instead of silently merging.
    """

    def __init__(self, path: str | os.PathLike):
        self.path = os.fspath(path)

    def exists(self) -> bool:
        return os.path.exists(self.path)

    def has_data(self) -> bool:
        return self.exists() and os.path.getsize(self.path) > 0

    def repair(self) -> None:
        """Truncate a torn TRAILING line (the mid-write crash artifact).
        Run before resuming appends: left in place, the torn line would sit
        ABOVE the resumed records and read as interior corruption on the
        next load."""
        if not self.exists():
            return
        with open(self.path, "rb") as f:
            raw = f.read()
        lines = raw.split(b"\n")
        while lines and not lines[-1].strip():
            lines.pop()
        if not lines:
            return
        try:
            json.loads(lines[-1])
            return  # intact store, nothing to do
        except json.JSONDecodeError:
            pass
        keep = b"\n".join(lines[:-1])
        with open(self.path, "wb") as f:
            if keep:
                f.write(keep + b"\n")
            f.flush()
            os.fsync(f.fileno())

    def write_header(self, space: ScanSpace) -> None:
        os.makedirs(os.path.dirname(os.path.abspath(self.path)), exist_ok=True)
        with open(self.path, "w") as f:
            f.write(json.dumps(
                {"kind": "space", "space_id": space.space_id}, sort_keys=True
            ) + "\n")
            f.flush()
            os.fsync(f.fileno())

    def load(self, space: ScanSpace | None = None) -> dict:
        """Parse the store into ``{trial_id: record}`` (first write wins —
        duplicate ids cannot disagree, they are content-addressed). Checks
        the header against ``space`` when given."""
        records: dict = {}
        if not self.exists():
            return records
        with open(self.path) as f:
            lines = f.read().splitlines()
        for i, line in enumerate(lines):
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                if i == len(lines) - 1:
                    continue  # torn trailing line from a mid-write crash
                raise ValueError(
                    f"{self.path}:{i + 1} is not valid JSON (and is not the "
                    f"trailing line) — the trial store is corrupt; delete it "
                    f"to rescan from scratch"
                ) from None
            if i == 0:
                if rec.get("kind") != "space":
                    raise ValueError(
                        f"{self.path} has no space header — not a tuner "
                        f"trial store"
                    )
                if space is not None and rec.get("space_id") != space.space_id:
                    raise ValueError(
                        f"{self.path} was written for scan space "
                        f"{rec.get('space_id')!r} but this scan is "
                        f"{space.space_id!r} — point the scan at a fresh "
                        f"store (mixing spaces would corrupt the frontier)"
                    )
                continue
            records.setdefault(rec["trial_id"], rec)
        return records

    def append(self, record: dict) -> None:
        with open(self.path, "a") as f:
            f.write(json.dumps(record, sort_keys=True) + "\n")
            f.flush()
            os.fsync(f.fileno())


def run_scan(
    space: ScanSpace,
    store_path: str | os.PathLike,
    workers: int = 0,
    real_data=None,
    max_trials: int | None = None,
    log=None,
) -> list:
    """Run (or resume) the scan; returns completed records in canonical
    trial order.

    Args:
      space: the declarative grid to cover.
      store_path: JSONL trial store — created with a space header if absent,
        resumed (completed ids skipped) if present.
      workers: 0/1 runs trials inline; N > 1 fans out over N spawned worker
        processes (each with its own jax runtime).
      real_data: (rows, d) array backing ``source="sampled"`` profiles.
      max_trials: stop after this many NEW completions (crash/resume drills
        and budgeted incremental scans); None runs the grid dry.
      log: optional ``print``-like progress callback.
    """
    trials = space.trials()
    store = TrialStore(store_path)
    store.repair()  # drop a torn trailing line before appending below it
    done = store.load(space)
    unknown = set(done) - {t.trial_id for t in trials}
    if unknown:
        raise ValueError(
            f"{store.path} holds {len(unknown)} trial(s) not in this scan "
            f"space (e.g. {sorted(unknown)[:3]}) despite a matching header — "
            f"the store is corrupt; delete it to rescan"
        )
    if not store.has_data():
        store.write_header(space)
    pending = [t for t in trials if t.trial_id not in done]
    if max_trials is not None:
        pending = pending[: max(0, max_trials)]
    if log:
        log(
            f"scan {space.space_id}: {len(trials)} trials total, "
            f"{len(done)} stored, {len(pending)} to run "
            f"(workers={workers})"
        )

    if pending:
        real = None
        if real_data is not None:
            import numpy as np

            real = np.asarray(real_data)
        if workers <= 1:
            for t in pending:
                rec = run_trial(t.to_dict(), real_data=real)
                done[rec["trial_id"]] = rec
                store.append(rec)
                if log:
                    log(f"  trial {rec['trial_id']} {rec['status']}")
        else:
            import multiprocessing as mp

            ctx = mp.get_context("spawn")  # fresh interpreters: jax-safe
            with ctx.Pool(processes=workers) as pool:
                jobs = [(t.to_dict(), real) for t in pending]
                for rec in pool.imap_unordered(_pool_trial, jobs):
                    done[rec["trial_id"]] = rec
                    store.append(rec)
                    if log:
                        log(f"  trial {rec['trial_id']} {rec['status']}")
    return [done[t.trial_id] for t in trials if t.trial_id in done]


def scan_is_complete(space: ScanSpace, store_path: str | os.PathLike) -> bool:
    """True when every trial of ``space`` has a stored record."""
    done = TrialStore(store_path).load(space)
    return all(t.trial_id in done for t in space.trials())
