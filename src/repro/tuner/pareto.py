"""Dominance filtering and the versioned ``tuning_table.json`` artifact.

The scan's trial records are reduced per ``(family, data profile)`` bucket
to the recall/cost/memory Pareto frontier — the set of operating points no
other point beats on every axis at once. The frontier is what the Planner
consults as an EMPIRICAL PRIOR: "for an index that looks like yours, these
are the only parameter settings worth running".

Objectives (fixed, documented in the artifact):

  * ``recall``     maximize — held-out recall@k vs the exact oracle
  * ``cost``       minimize — the planner's deterministic candidate+slot
                   model (the latency axis; wall-clock-free on purpose so
                   the artifact is bit-reproducible across reruns/resumes)
  * ``mem_bytes``  minimize — bytes of built index state

Determinism contract: the frontier is a pure function of the trial
records' deterministic fields. Exact duplicates on the objective vector
collapse to the lexicographically smallest ``trial_id``; the surviving
entries sort by (recall desc, cost asc, trial_id) — so two stores that
cover the same space byte-compare equal frontiers, however many crashed
runs it took to fill them.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os

__all__ = ["dominates", "pareto_front", "TuningTable", "build_table"]

TABLE_FORMAT = "repro.tuner.table"
TABLE_VERSION = 1

# (record key, sense): sense +1 = minimize, -1 = maximize
OBJECTIVES = (("recall", -1), ("cost", 1), ("mem_bytes", 1))

# fields copied from a trial record into a frontier entry — deterministic
# only (us_per_query is deliberately absent; see module docstring).
# early-exit knobs ride along so the planner prior can replay them;
# tables_probed is informational (the cost column already embeds it).
_ENTRY_FIELDS = (
    "trial_id", "family", "K", "L", "W", "n_probes", "max_flips",
    "window", "k", "shards", "recall", "cand_frac", "cost", "mem_bytes",
    "early_exit", "exit_group", "exit_slack", "tables_probed",
)

# defaults for records written before the early-exit axes existed
_ENTRY_DEFAULTS = {
    "early_exit": False, "exit_group": 0, "exit_slack": 0.0,
    "tables_probed": None,
}


def _objective_vector(rec: dict) -> tuple:
    """The record as a minimize-everything tuple."""
    return tuple(sense * float(rec[key]) for key, sense in OBJECTIVES)


def dominates(a: dict, b: dict) -> bool:
    """True when ``a`` is at least as good as ``b`` on every objective and
    strictly better on at least one (ties on every axis dominate nothing)."""
    va, vb = _objective_vector(a), _objective_vector(b)
    return all(x <= y for x, y in zip(va, vb)) and any(
        x < y for x, y in zip(va, vb)
    )


def pareto_front(records: list) -> list:
    """The non-dominated subset of ``records``, canonically ordered.

    Edge-case contract (tested):
      * a single record is its own frontier;
      * records tied on every objective (duplicate non-dominated trials)
        collapse to the one with the smallest ``trial_id``;
      * ties on SOME objectives dominate nothing — both survive.
    """
    # collapse exact objective duplicates first (dominance is irreflexive,
    # so without this both copies would survive and the artifact would
    # depend on store insertion order)
    by_vec: dict = {}
    for rec in records:
        if rec.get("status", "ok") != "ok":
            continue
        vec = _objective_vector(rec)
        best = by_vec.get(vec)
        if best is None or rec["trial_id"] < best["trial_id"]:
            by_vec[vec] = rec
    unique = list(by_vec.values())
    front = [
        r for r in unique if not any(dominates(o, r) for o in unique if o is not r)
    ]
    front.sort(key=lambda r: (-r["recall"], r["cost"], r["trial_id"]))
    return front


def _entry(rec: dict) -> dict:
    return {
        k: rec.get(k, _ENTRY_DEFAULTS[k]) if k in _ENTRY_DEFAULTS else rec[k]
        for k in _ENTRY_FIELDS
    }


@dataclasses.dataclass
class TuningTable:
    """The versioned Pareto-table artifact the Planner consults.

    ``buckets`` is a list of ``{family, profile: {n, d, skew, source},
    entries: [...]}`` dicts — one per (family, data profile) with at least
    one usable trial, entries being the canonical Pareto frontier. ``meta``
    records the provenance: scan space id, trial counts, the artifact
    version. Serialized with sorted keys, so the file is byte-stable.
    """

    buckets: list
    meta: dict

    # -- persistence --------------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "format": TABLE_FORMAT,
            "version": TABLE_VERSION,
            "meta": self.meta,
            "buckets": self.buckets,
        }

    def save(self, path: str | os.PathLike) -> str:
        path = os.fspath(path)
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)
        return path

    @classmethod
    def load(cls, path: str | os.PathLike) -> "TuningTable":
        path = os.fspath(path)
        with open(path) as f:
            d = json.load(f)
        if d.get("format") != TABLE_FORMAT:
            raise ValueError(
                f"{path} has format {d.get('format')!r}, expected "
                f"{TABLE_FORMAT!r} — not a tuning table"
            )
        if d.get("version") != TABLE_VERSION:
            raise ValueError(
                f"{path} is tuning-table version {d.get('version')!r}; this "
                f"build reads version {TABLE_VERSION} — re-run the scan or "
                f"upgrade"
            )
        return cls(buckets=d["buckets"], meta=d.get("meta", {}))

    def provenance(self) -> dict:
        """The compact stamp shipped inside index manifests (see
        ``Index.save``): enough to trace a served plan back to the scan
        that justified it."""
        return {
            "format": TABLE_FORMAT,
            "version": TABLE_VERSION,
            "space_id": self.meta.get("space_id"),
            "n_trials": self.meta.get("n_trials"),
            "k": self.meta.get("k"),
        }

    # -- lookup -------------------------------------------------------------
    # bucket-match tolerances: a profile is "in bucket" within 2x on rows
    # (log2 distance <= 1) and 0.5 on weight skew; d must match exactly
    # (every knob's meaning changes with dimensionality)
    MAX_LOG2_N = 1.0
    MAX_SKEW = 0.5

    def nearest_bucket(
        self, family: str | None, n: int, d: int, skew: float = 1.0
    ) -> dict | None:
        """The closest scanned profile bucket, or None when the query
        profile is out of every bucket's tolerance box (the caller must
        then fall back to full calibration). ``family=None`` searches all
        families (build-time auto selection)."""
        best, best_key = None, None
        for b in self.buckets:
            if family is not None and b["family"] != family:
                continue
            p = b["profile"]
            if p["d"] != d:
                continue
            dn = abs(math.log2(max(n, 1)) - math.log2(max(p["n"], 1)))
            ds = abs(skew - p["skew"])
            if dn > self.MAX_LOG2_N or ds > self.MAX_SKEW:
                continue
            key = (dn + ds, p["n"], p["skew"], b["family"])
            if best_key is None or key < best_key:
                best, best_key = b, key
        return best

    @staticmethod
    def best_entry(bucket: dict, recall_target: float) -> dict | None:
        """Cheapest frontier entry meeting ``recall_target`` (None when the
        whole frontier falls short — the scanned grid never reached that
        recall on this profile)."""
        ok = [e for e in bucket["entries"] if e["recall"] >= recall_target - 1e-9]
        if not ok:
            return None
        return min(ok, key=lambda e: (e["cost"], e["trial_id"]))


def build_table(records: list, space) -> TuningTable:
    """Reduce scan records to the per-(family, profile) frontier table.

    Deterministic given the records' deterministic fields; trials with
    ``status != "ok"`` (e.g. skipped sharded trials) are excluded and
    counted in ``meta``.
    """
    groups: dict = {}
    n_ok = 0
    for rec in records:
        if rec.get("status", "ok") != "ok":
            continue
        n_ok += 1
        p = rec["trial"]["profile"]
        gk = (rec["family"], p["n"], p["d"], p["skew"], p["source"])
        groups.setdefault(gk, []).append(rec)
    buckets = []
    for gk in sorted(groups):
        family, n, d, skew, source = gk
        front = pareto_front(groups[gk])
        if not front:
            continue
        buckets.append({
            "family": family,
            "profile": {"n": n, "d": d, "skew": skew, "source": source},
            "entries": [_entry(r) for r in front],
        })
    return TuningTable(
        buckets=buckets,
        meta={
            "space_id": space.space_id,
            "k": space.k,
            "n_trials": len(records),
            "n_ok": n_ok,
            "objectives": [
                {"key": k, "sense": "max" if s < 0 else "min"}
                for k, s in OBJECTIVES
            ],
        },
    )
