"""Declarative scan-space spec for the offline autotuner.

A :class:`ScanSpace` names the five-knob design space of the paper's ALSH
schemes — ``family × K × L × W × n_probes × window`` — crossed with the
:class:`DataProfile` axes (rows ``n``, dims ``d``, weight skew, data
source). ``ScanSpace.trials()`` enumerates it into concrete
:class:`TrialSpec` points, filtering out invalid corners (theta's K <= 31
bit-packing cap, multiprobe on families that don't support it, probe counts
beyond the (K, max_flips) perturbation reach) and collapsing knobs a family
ignores (theta has no bucket width, l2 has no probing sequence) so the grid
never runs duplicate work.

Every trial is content-addressed: ``TrialSpec.trial_id`` is a stable hash of
the trial's semantic payload (profile + parameters + the space's base seed),
and the trial's PRNG seed is derived from that id — rerunning a trial
anywhere reproduces its dataset, queries, weights, and therefore its
deterministic metrics bit-for-bit. The id is what makes the scan executor's
crash-safe resume possible (see :mod:`repro.tuner.scan`).

Axis helpers: :func:`grid` (explicit values), :func:`log_range` (geometric
sweep), :func:`seeded_choice` (deterministic random subsample of a grid —
the ScanLHA-style "random scan" mode for spaces too big to cross fully).

Data profiles are synthetic-first (seeded ``uniform`` / ``clustered``
generators — the distributions the repo's planner and benchmarks calibrate
against) with a ``sampled`` source that draws rows from a real dataset
passed to the executor, so a deployment can scan its own data without
shipping it into the spec.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json

from repro.core.families import get_family, n_flip_subsets

__all__ = [
    "DataProfile",
    "TrialSpec",
    "ScanSpace",
    "grid",
    "log_range",
    "seeded_choice",
    "profile_data",
    "profile_queries",
    "profile_weights",
]

# l2 trials may ask for the planner-style anchored bucket width instead of a
# fixed float; the executor resolves it per trial (see scan.resolve_width)
AUTO_WIDTH = "auto"


def grid(*values):
    """An explicit axis: the values, deduplicated, in the given order."""
    out = []
    for v in values:
        if v not in out:
            out.append(v)
    return tuple(out)


def log_range(lo: int, hi: int, num: int) -> tuple:
    """``num`` geometrically spaced ints in [lo, hi], deduplicated."""
    if lo <= 0 or hi < lo or num <= 0:
        raise ValueError(f"log_range needs 0 < lo <= hi and num > 0; "
                         f"got lo={lo}, hi={hi}, num={num}")
    if num == 1:
        return (int(lo),)
    ratio = (hi / lo) ** (1.0 / (num - 1))
    vals = [int(round(lo * ratio**i)) for i in range(num)]
    return grid(*vals)


def seeded_choice(values, num: int, seed: int = 0) -> tuple:
    """A deterministic random subsample of an axis (ScanLHA's random-scan
    mode): ``num`` values drawn without replacement, order-stable given
    ``seed``. Returns all of ``values`` when ``num`` covers them."""
    values = grid(*values)
    if num >= len(values):
        return values
    # seeded Fisher-Yates via a tiny splitmix-style LCG — no numpy import,
    # no global RNG state, identical on every host
    state = (seed * 0x9E3779B97F4A7C15 + 0xBF58476D1CE4E5B9) & (2**64 - 1)
    pool = list(values)
    out = []
    for _ in range(num):
        state = (state * 6364136223846793005 + 1442695040888963407) & (2**64 - 1)
        out.append(pool.pop((state >> 33) % len(pool)))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class DataProfile:
    """One point on the data axes of the scan.

    Attributes:
      n: database rows the trial index is built over.
      d: dimensionality.
      skew: weight-distribution shape — trial weights are drawn as
        ``|N(0,1)|**skew + 0.1`` per dim, so ``skew=1.0`` reproduces the
        planner's reference profile
        (:func:`repro.api.planner.default_calibration_weights`), ``skew>1``
        concentrates mass on few dims (heavy-tailed tenant weights) and
        ``skew<1`` flattens it.
      source: "uniform" (iid U[0,1) rows), "clustered" (seeded Gaussian
        mixture in the unit cube — the correlated stand-in), or "sampled"
        (rows drawn from the real dataset handed to the scan executor).
    """

    n: int
    d: int
    skew: float = 1.0
    source: str = "uniform"

    def __post_init__(self):
        if self.source not in ("uniform", "clustered", "sampled"):
            raise ValueError(
                f"DataProfile.source must be 'uniform' | 'clustered' | "
                f"'sampled', got {self.source!r}"
            )
        for field in ("n", "d"):
            v = getattr(self, field)
            if not isinstance(v, int) or v <= 0:
                raise ValueError(
                    f"DataProfile.{field} must be a positive int, got {v!r}"
                )
        if not self.skew > 0:
            raise ValueError(f"DataProfile.skew must be > 0, got {self.skew!r}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "DataProfile":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class TrialSpec:
    """One fully concrete scan point: a data profile plus the five knobs.

    ``W`` is a float, or the string ``"auto"`` for l2 trials that should
    resolve the planner-style anchored width on their own data (the record
    written to the trial store carries the resolved float). ``seed`` is
    DERIVED from ``trial_id`` — never set it by hand.
    """

    profile: DataProfile
    family: str
    K: int
    L: int
    W: object  # float | "auto"
    n_probes: int
    max_flips: int
    window: int  # query-time per-table candidate window (== build C)
    k: int
    queries: int  # held-out queries measured per trial
    M: int = 32
    shards: int = 1
    base_seed: int = 0
    early_exit: bool = False
    exit_group: int = 8
    exit_slack: float = 0.0

    def payload(self) -> dict:
        """The semantic content the trial id hashes (everything that can
        change a deterministic metric)."""
        return {
            "profile": self.profile.to_dict(),
            "family": self.family,
            "K": self.K,
            "L": self.L,
            "W": self.W,
            "n_probes": self.n_probes,
            "max_flips": self.max_flips,
            "window": self.window,
            "k": self.k,
            "queries": self.queries,
            "M": self.M,
            "shards": self.shards,
            "base_seed": self.base_seed,
            "early_exit": self.early_exit,
            "exit_group": self.exit_group,
            "exit_slack": self.exit_slack,
        }

    @property
    def trial_id(self) -> str:
        digest = hashlib.sha1(
            json.dumps(self.payload(), sort_keys=True).encode()
        ).hexdigest()
        return digest[:16]

    @property
    def seed(self) -> int:
        """Per-trial PRNG seed: the first 31 bits of the content hash, so a
        rerun of the same trial (any process, any host) draws identical
        data/queries/weights."""
        return int(self.trial_id[:8], 16) & 0x7FFFFFFF

    def to_dict(self) -> dict:
        return self.payload()

    @classmethod
    def from_dict(cls, d: dict) -> "TrialSpec":
        d = dict(d)
        d["profile"] = DataProfile.from_dict(d["profile"])
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class ScanSpace:
    """The declarative spec the executor enumerates.

    Axes are plain tuples (build them with :func:`grid` /
    :func:`log_range` / :func:`seeded_choice`); the cross product is
    filtered down to valid, non-duplicate trials by :meth:`trials`.
    """

    profiles: tuple
    families: tuple = ("theta", "l2")
    K: tuple = (8, 12, 16)
    L: tuple = (16, 32, 64)
    W: tuple = (AUTO_WIDTH,)
    n_probes: tuple = (1, 4, 16)
    window: tuple = (256,)
    k: int = 10
    queries: int = 64
    M: int = 32
    shards: int = 1
    base_seed: int = 0
    early_exit: tuple = (False,)
    exit_group: tuple = (8,)
    exit_slack: float = 0.1

    def __post_init__(self):
        # normalize axes to tuples so the space hashes/serializes stably
        for f in ("profiles", "families", "K", "L", "W", "n_probes", "window",
                  "early_exit", "exit_group"):
            object.__setattr__(self, f, tuple(getattr(self, f)))
        if not self.profiles:
            raise ValueError("ScanSpace.profiles must name at least one DataProfile")
        for fam in self.families:
            get_family(fam)  # raises on unknown names

    @property
    def space_id(self) -> str:
        """Content hash of the whole space — the trial store records it so a
        resume against the wrong store fails loudly instead of merging two
        unrelated scans."""
        return hashlib.sha1(
            json.dumps(self.to_dict(), sort_keys=True).encode()
        ).hexdigest()[:16]

    def to_dict(self) -> dict:
        return {
            "profiles": [p.to_dict() for p in self.profiles],
            "families": list(self.families),
            "K": list(self.K),
            "L": list(self.L),
            "W": list(self.W),
            "n_probes": list(self.n_probes),
            "window": list(self.window),
            "k": self.k,
            "queries": self.queries,
            "M": self.M,
            "shards": self.shards,
            "base_seed": self.base_seed,
            "early_exit": list(self.early_exit),
            "exit_group": list(self.exit_group),
            "exit_slack": self.exit_slack,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ScanSpace":
        d = dict(d)
        d["profiles"] = tuple(DataProfile.from_dict(p) for p in d["profiles"])
        for f in ("families", "K", "L", "W", "n_probes", "window"):
            d[f] = tuple(d[f])
        for f in ("early_exit", "exit_group"):
            if f in d:
                d[f] = tuple(d[f])
        return cls(**d)

    def trials(self) -> tuple:
        """Enumerate the valid, deduplicated trial grid (stable order).

        Collapsing rules (each avoids measuring the same program twice):
          * theta ignores W — every W value collapses to the config default.
          * l2 has no probing sequence — n_probes collapses to 1.
          * n_probes beyond ``n_flip_subsets(K, max_flips)`` duplicates
            buckets — those points are dropped, matching the facade's
            probe-reach gate.
          * K above a family's cap (theta: 31) is dropped.
          * windows below k, and profiles smaller than the held-out query
            draw, are dropped.
          * early-exit points whose L·n_probes lattice spans fewer than two
            ``exit_group`` groups are dropped (the engine's normalization
            folds them onto the monolithic program — measuring them would
            duplicate the early_exit=False point); when early exit is off
            the group/slack knobs collapse to their defaults for the same
            reason.
        """
        out, seen = [], set()
        for profile in self.profiles:
            for fam in self.families:
                fam_obj = get_family(fam)
                for K in self.K:
                    if fam_obj.max_K is not None and K > fam_obj.max_K:
                        continue
                    max_flips = min(3, K)
                    for L in self.L:
                        for W in self.W:
                            if fam != "l2":
                                W = 4.0  # unused by theta; collapse
                            for p in self.n_probes:
                                if not fam_obj.supports_multiprobe:
                                    p = 1  # collapse: no probing sequence
                                if p > 1 and p > n_flip_subsets(K, max_flips):
                                    continue
                                for C in self.window:
                                    if C < self.k or self.k >= profile.n:
                                        continue
                                    for early in self.early_exit:
                                        for G in self.exit_group:
                                            if not early:
                                                G, slack = 8, 0.0  # collapse
                                            else:
                                                slack = self.exit_slack
                                                if L * p < 2 * G:
                                                    continue  # folds to off
                                            t = TrialSpec(
                                                profile=profile, family=fam,
                                                K=K, L=L, W=W, n_probes=p,
                                                max_flips=(
                                                    max_flips if p > 1 else 0
                                                ),
                                                window=C, k=self.k,
                                                queries=self.queries, M=self.M,
                                                shards=self.shards,
                                                base_seed=self.base_seed,
                                                early_exit=early, exit_group=G,
                                                exit_slack=slack,
                                            )
                                            if t.trial_id not in seen:
                                                seen.add(t.trial_id)
                                                out.append(t)
        return tuple(out)


# ---------------------------------------------------------------------------
# profile data generation (seeded, shared by trials and benchmarks)
# ---------------------------------------------------------------------------


def _mixture(key, m: int, d: int, centers: int = 8):
    """Seeded Gaussian mixture clipped to the unit cube."""
    import jax
    import jax.numpy as jnp

    kc, ka, kn = jax.random.split(key, 3)
    mu = jax.random.uniform(kc, (centers, d), minval=0.15, maxval=0.85)
    assign = jax.random.randint(ka, (m,), 0, centers)
    rows = mu[assign] + 0.06 * jax.random.normal(kn, (m, d))
    return jnp.clip(rows, 0.0, 1.0 - 1e-6)


def profile_data(profile: DataProfile, key, real_data=None):
    """The trial database: (n, d) rows drawn per ``profile.source``."""
    import jax
    import jax.numpy as jnp

    n, d = profile.n, profile.d
    if profile.source == "uniform":
        return jax.random.uniform(key, (n, d))
    if profile.source == "clustered":
        return _mixture(key, n, d)
    if real_data is None:
        raise ValueError(
            "DataProfile.source='sampled' needs a real dataset — pass "
            "real_data=(rows, d) to the scan executor"
        )
    real = jnp.asarray(real_data, jnp.float32)
    if real.ndim != 2 or real.shape[1] != d:
        raise ValueError(
            f"real_data must be (rows, d={d}); got shape {tuple(real.shape)}"
        )
    idx = jax.random.choice(key, real.shape[0], (n,), replace=real.shape[0] < n)
    return real[idx]


def profile_queries(profile: DataProfile, key, b: int, real_data=None):
    """Held-out queries: fresh draws from the profile's distribution (for
    ``sampled``, real rows jittered by one lattice cell so their bucket keys
    decouple from the indexed copies — same rationale as the planner's
    calibration sample)."""
    import jax

    if profile.source == "sampled":
        rows = profile_data(
            dataclasses.replace(profile, n=b), jax.random.fold_in(key, 0),
            real_data,
        )
        jitter = jax.random.uniform(
            jax.random.fold_in(key, 1), rows.shape, minval=-1 / 32, maxval=1 / 32
        )
        return rows + jitter
    return profile_data(dataclasses.replace(profile, n=b), key, real_data)


def profile_weights(key, shape, skew: float = 1.0):
    """Per-query weights ``|N(0,1)|**skew + 0.1`` — skew=1.0 is the
    planner's reference distribution."""
    import jax
    import jax.numpy as jnp

    return jnp.abs(jax.random.normal(key, shape)) ** skew + 0.1
