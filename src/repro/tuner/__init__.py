"""repro.tuner — the offline autotuner (distributed Pareto parameter scan).

Per-build planner calibration costs 13–24 s per plan (BENCH_planner
``plan_build_s``); at fleet scale, where thousands of tenant indexes get
(re)planned, that bill is paid over and over for the SAME answer. This
subsystem moves the search offline:

  1. :mod:`repro.tuner.space` — declare the scan: the five ALSH knobs
     (family × K × L × W × probes × window) crossed with data profiles
     (n / d / weight skew, synthetic or sampled-real rows). Trials are
     content-addressed and seeded from their own ids.
  2. :mod:`repro.tuner.scan` — execute it: worker-process fan-out, each
     trial measuring held-out recall@k, candidate fraction, and query cost
     through the REAL engine path, persisted incrementally to a crash-safe
     JSONL store (resume skips completed ids; reruns are bit-identical).
  3. :mod:`repro.tuner.pareto` — reduce it: per-(family, profile)
     recall/cost/memory Pareto frontiers, serialized as the versioned
     ``tuning_table.json`` artifact.
  4. ``repro.api.planner.Planner(table=...)`` — consume it: planning
     interpolates the nearest-profile frontier entry, confirms it with a
     single probe instead of the full calibration ladder, and stamps the
     resolved plan ``provenance="prior"``; profiles outside every bucket
     fall back to today's calibrated path bit-identically.

CLI: ``python -m repro.launch.tune`` (scan + table in one command, resumable).
"""

from repro.tuner.pareto import TuningTable, build_table, pareto_front
from repro.tuner.scan import TrialStore, run_scan, run_trial, scan_is_complete
from repro.tuner.space import (
    DataProfile,
    ScanSpace,
    TrialSpec,
    grid,
    log_range,
    seeded_choice,
)

__all__ = [
    "DataProfile",
    "ScanSpace",
    "TrialSpec",
    "grid",
    "log_range",
    "seeded_choice",
    "TrialStore",
    "run_scan",
    "run_trial",
    "scan_is_complete",
    "TuningTable",
    "build_table",
    "pareto_front",
]
