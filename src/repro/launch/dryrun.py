import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile EVERY (arch × shape × mesh) cell.

512 placeholder host devices are forced ABOVE (before any jax import — jax
locks the device count on first init). For each runnable cell this driver:

  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. lowers the cell's step (train_step / prefill / decode) with sanitized
     NamedShardings over abstract inputs,
  3. compiles it (SPMD partitioning must succeed = the distribution config
     is coherent),
  4. records memory_analysis(), cost_analysis(), and the per-type collective
     byte totals parsed from the optimized HLO,

into results/dryrun/<arch>__<shape>__<mesh>.json (resumable: existing
results are skipped unless --force).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh pod1
"""

import argparse
import json
import re
import time
import traceback

HW = {  # TPU v5e-class constants used by the roofline pass
    "peak_flops_bf16": 197e12,
    "hbm_bw": 819e9,
    "ici_bw": 50e9,
}

COLLECTIVE_RE = re.compile(
    r"=\s*(?:\()?(?P<type>[a-z0-9]+)\[(?P<dims>[\d,]*)\]"
    r".*?\s(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
WHILE_RE = re.compile(r"\swhile\(.*?condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
CONST_RE = re.compile(r"constant\((\d+)\)")

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _split_computations(hlo_text: str) -> dict:
    """HLO text -> {computation_name: [lines]}."""
    comps = {}
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = COMP_HEADER_RE.match(line) or COMP_HEADER_RE.match(stripped)
        if m and stripped.endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(stripped)
    return comps


def _line_collective_bytes(line: str):
    """(op, traffic_bytes) for a collective line, else None.

    Traffic model (ring algorithms, group size g, result bytes R):
      all-gather ≈ R; all-reduce ≈ 2R; reduce-scatter ≈ R*g (input = g*R);
      all-to-all ≈ R; collective-permute ≈ R.
    """
    m = COLLECTIVE_RE.search(line)
    if not m:
        return None
    dt = m.group("type")
    if dt not in DTYPE_BYTES:
        return None
    nbytes = DTYPE_BYTES[dt]
    for d in [int(x) for x in m.group("dims").split(",") if x]:
        nbytes *= d
    g = 1
    gm = GROUPS_RE.search(line)
    if gm:
        g = int(gm.group(2))
    op = m.group("op")
    factor = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": float(g),
              "all-to-all": 1.0, "collective-permute": 1.0}[op]
    return op, nbytes * factor


def _trip_count(cond_lines: list) -> int:
    """Loop bound heuristic: the max integer constant in the while condition
    (jax.lax.scan lowers to while with `compare(iv, constant(N)), LT`)."""
    best = 1
    for line in cond_lines:
        for c in CONST_RE.findall(line):
            best = max(best, int(c))
    return best


def parse_collectives(hlo_text: str) -> dict:
    """LOOP-AWARE per-device link-traffic estimate per collective type.

    cost_analysis()/flat text both count a scan body once; here each while
    body's collectives are multiplied by its trip count (nested loops
    compose multiplicatively). Validated against n_units scaling in
    tests/test_dryrun_parse.py.
    """
    comps = _split_computations(hlo_text)
    # per-computation local costs + call edges
    local = {name: {} for name in comps}
    edges = {name: [] for name in comps}  # (child, multiplier)
    for name, lines in comps.items():
        for line in lines:
            got = _line_collective_bytes(line)
            if got:
                op, b = got
                local[name][op] = local[name].get(op, 0.0) + b
                local[name][f"n_{op}"] = local[name].get(f"n_{op}", 0) + 1
            wm = WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond, []))
                edges[name].append((body, trips))
            elif " call(" in line or " conditional(" in line:
                for cm in re.finditer(r"(?:to_apply|branch_computations)=\{?%?([\w\.\-]+)", line):
                    edges[name].append((cm.group(1), 1))

    import functools

    @functools.lru_cache(maxsize=None)
    def total(name: str):
        acc = dict(local.get(name, {}))
        for child, mult in edges.get(name, []):
            sub = total(child)
            for k, v in sub.items():
                acc[k] = acc.get(k, 0) + v * mult
        return acc

    # entry computation: the one not called by anyone (fallback: max cost)
    called = {c for es in edges.values() for c, _ in es}
    entries = [n for n in comps if n not in called]
    agg = {}
    for e in entries:
        for k, v in total(e).items():
            agg[k] = agg.get(k, 0) + v
    per_type = {k: v for k, v in agg.items() if not k.startswith("n_")}
    counts = {k[2:]: int(v) for k, v in agg.items() if k.startswith("n_")}
    return {
        "per_type_bytes": per_type,
        "counts": counts,
        "total_bytes": float(sum(per_type.values())),
    }


def optimized_overrides(arch_id: str, shape_kind: str) -> dict:
    """The beyond-paper lever set per (arch, cell kind) — see EXPERIMENTS §Perf.

    train/prefill: pure-FSDP layout (model axis = extra DP) + explicit
    EP shard_map for MoE archs. decode: replicated serving layout for dense
    archs that fit (<~10B); MoE archs keep the 2-D expert sharding (replicated
    experts would need 48 GB/device on maverick).
    """
    moe = arch_id.startswith("llama4")
    if shape_kind in ("train", "prefill"):
        over = {"dp_over_model": True}
        if moe:
            over["moe_impl"] = "a2a_shardmap"
        return over
    if not moe:
        return {"serve_param_layout": "replicated", "param_dtype": "bfloat16"}
    return {}


def run_cell(arch_id: str, shape_name: str, mesh_name: str, out_dir: str, force: bool,
             optimized: bool = False):
    import dataclasses

    import jax

    from repro.configs import SHAPES, get_bundle
    from repro.launch.compile import lower_cell
    from repro.launch.mesh import make_production_mesh

    out_path = os.path.join(out_dir, f"{arch_id}__{shape_name}__{mesh_name}.json")
    if os.path.exists(out_path) and not force:
        print(f"[skip] {out_path} exists")
        return True

    bundle = get_bundle(arch_id)
    if optimized and shape_name in SHAPES:
        over = optimized_overrides(arch_id, SHAPES[shape_name].kind)
        if over:
            bundle = dataclasses.replace(
                bundle, model=dataclasses.replace(bundle.model, **over)
            )
    if shape_name in bundle.shape_skips:
        rec = {
            "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
            "status": "skipped", "reason": bundle.shape_skips[shape_name],
        }
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"[skip-cell] {arch_id} x {shape_name}: {rec['reason']}")
        return True

    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
    rec = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "mesh_shape": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "n_devices": int(mesh.devices.size),
        "kind": shape.kind, "seq_len": shape.seq_len, "global_batch": shape.global_batch,
    }
    t0 = time.time()
    try:
        lowered = lower_cell(bundle, shape, mesh)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            rec[k] = int(getattr(ma, k, 0) or 0)
        ca = compiled.cost_analysis() or {}
        rec["flops"] = float(ca.get("flops", 0.0))
        rec["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
        rec["utilization_ops"] = {
            k: v for k, v in ca.items() if k in ("transcendentals",)
        }
        hlo = compiled.as_text()
        rec["collectives"] = parse_collectives(hlo)
        rec["hlo_lines"] = hlo.count("\n")
        rec["status"] = "ok"
        print(
            f"[ok] {arch_id} x {shape_name} x {mesh_name}: "
            f"flops/dev={rec['flops']:.3e} bytes/dev={rec['bytes_accessed']:.3e} "
            f"coll={rec['collectives']['total_bytes']:.3e}B "
            f"temp={rec['temp_size_in_bytes']/2**30:.2f}GiB "
            f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)"
        )
    except Exception as e:  # record and continue — failures are bugs to fix
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {arch_id} x {shape_name} x {mesh_name}: {rec['error'][:300]}")
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=2)
    return rec.get("status") in ("ok", "skipped")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch id (default: all)")
    ap.add_argument("--shape", default=None, help="single shape (default: all)")
    ap.add_argument("--mesh", default=None, choices=[None, "pod1", "pod2"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the EXPERIMENTS §Perf lever set per cell")
    args = ap.parse_args()
    if args.optimized and args.out == "results/dryrun":
        args.out = "results/dryrun_opt"

    from repro.configs import SHAPES, list_archs

    os.makedirs(args.out, exist_ok=True)
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.mesh] if args.mesh else ["pod1", "pod2"]

    ok = True
    for arch in archs:
        for shape in shapes:
            for mesh in meshes:
                ok &= run_cell(arch, shape, mesh, args.out, args.force,
                               optimized=args.optimized)
    print("DRYRUN", "PASS" if ok else "FAIL")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
