"""AOT lowering of every (arch × shape × mesh) cell — shared by the dry-run,
the roofline pass, and the real launchers.

Everything is abstract: ShapeDtypeStruct inputs, eval_shape-derived state
trees, sanitized NamedShardings. ``.lower()`` proves the program + sharding
is coherent; ``.compile()`` proves SPMD partitioning succeeds and yields
memory/cost analyses. No arrays are ever allocated at production size.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import models
from repro.configs.base import ArchBundle, ShapeConfig
from repro.launch import specs as input_specs
from repro.models.sharding import sanitize_spec_tree, set_policy, use_mesh
from repro.runtime.serve_step import make_decode_step, make_prefill_step
from repro.runtime.train_step import (
    batch_pytree_specs,
    init_train_state,
    make_train_step,
    train_state_specs,
)


def _to_shardings(mesh: Mesh, spec_tree, shape_tree):
    clean = sanitize_spec_tree(spec_tree, shape_tree, mesh)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), clean, is_leaf=lambda s: isinstance(s, P)
    )


def abstract_train_state(bundle: ArchBundle):
    return jax.eval_shape(
        lambda: init_train_state(jax.random.PRNGKey(0), bundle.model, bundle.train)
    )


def abstract_params(bundle: ArchBundle):
    return jax.eval_shape(lambda: models.init_params(jax.random.PRNGKey(0), bundle.model))


def lower_cell(bundle: ArchBundle, shape: ShapeConfig, mesh: Mesh):
    """Lower one cell's step function on the given mesh. Returns jax.stages.Lowered."""
    mcfg, tcfg = bundle.model, bundle.train
    serve_fsdp = not (
        shape.kind in ("prefill", "decode") and mcfg.serve_param_layout == "replicated"
    )
    set_policy(dp_over_model=mcfg.dp_over_model, fsdp=serve_fsdp)
    try:
        return _lower_cell_inner(bundle, shape, mesh)
    finally:
        set_policy()


def _lower_cell_inner(bundle: ArchBundle, shape: ShapeConfig, mesh: Mesh):
    mcfg, tcfg = bundle.model, bundle.train
    with use_mesh(mesh):
        if shape.kind == "train":
            state_shapes = abstract_train_state(bundle)
            batch = input_specs.train_batch(mcfg, shape.global_batch, shape.seq_len)
            state_sh = _to_shardings(mesh, train_state_specs(mcfg, tcfg), state_shapes)
            batch_sh = _to_shardings(mesh, batch_pytree_specs(batch), batch)
            step = make_train_step(mcfg, tcfg)
            jitted = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            )
            return jitted.lower(state_shapes, batch)

        params_shapes = abstract_params(bundle)
        params_sh = _to_shardings(mesh, models.param_specs(mcfg), params_shapes)

        if shape.kind == "prefill":
            batch = input_specs.prefill_batch(mcfg, shape.global_batch, shape.seq_len)
            batch_sh = _to_shardings(mesh, batch_pytree_specs(batch), batch)
            step = make_prefill_step(mcfg)
            jitted = jax.jit(step, in_shardings=(params_sh, batch_sh))
            return jitted.lower(params_shapes, batch)

        # decode: one new token against a cache of shape.seq_len
        batch = input_specs.decode_batch(mcfg, shape.global_batch, shape.seq_len - 1)
        caches_shapes = jax.eval_shape(
            lambda: models.init_caches(shape.global_batch, shape.seq_len, mcfg)
        )
        caches_sh = _to_shardings(mesh, models.cache_specs(mcfg), caches_shapes)
        from repro.models.sharding import BATCH

        batch_sh = _to_shardings(
            mesh, {"token": P(BATCH), "pos": P(BATCH)}, batch
        )
        step = make_decode_step(mcfg)
        jitted = jax.jit(
            step,
            in_shardings=(params_sh, batch_sh, caches_sh),
            out_shardings=(None, None, caches_sh),
            donate_argnums=(2,),
        )
        return jitted.lower(params_shapes, batch, caches_shapes)
