"""Offline autotuning launcher: scan the knob grid, ship the Pareto table.

One command drives the whole :mod:`repro.tuner` pipeline:

  1. assemble a :class:`~repro.tuner.space.ScanSpace` from the CLI axes
     (family × K × L × W × probes × window, crossed with n × d × skew data
     profiles),
  2. run (or RESUME) the scan against the JSONL trial store — completed
     trial ids are skipped, so re-running the same command after a crash,
     preemption, or ``--max-trials`` budget stop picks up exactly where it
     left off,
  3. when the grid is covered, reduce the records to the per-(family,
     profile) Pareto frontier and write the versioned ``tuning_table.json``
     artifact next to the store.

The table is what production planners consume::

    table = TuningTable.load("results/tuning/tuning_table.json")
    index = Index.build(key, data, quality=q, planner=Planner(table=table))

Usage:
  PYTHONPATH=src python -m repro.launch.tune                     # default grid
  PYTHONPATH=src python -m repro.launch.tune --n 4096 16384 --workers 4
  PYTHONPATH=src python -m repro.launch.tune --max-trials 20     # budgeted slice
  (rerun the same command to resume; the store + table live under --out)
"""

from __future__ import annotations

import argparse
import json
import os


def build_space(args) -> "ScanSpace":
    """The CLI axes as a declarative ScanSpace (shared with tests)."""
    from repro.tuner import DataProfile, ScanSpace, grid
    from repro.tuner.space import AUTO_WIDTH

    profiles = tuple(
        DataProfile(n=n, d=args.d, skew=skew, source=args.source)
        for n in args.n
        for skew in args.skew
    )
    W = tuple(AUTO_WIDTH if w == AUTO_WIDTH else float(w) for w in args.W)
    return ScanSpace(
        profiles=profiles,
        families=tuple(args.family),
        K=grid(*args.K),
        L=grid(*args.L),
        W=W,
        n_probes=grid(*args.probes),
        window=grid(*args.window),
        k=args.k,
        queries=args.queries,
        base_seed=args.seed,
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="repro.tuner offline scan -> Pareto tuning table"
    )
    ap.add_argument("--out", default="results/tuning",
                    help="output directory (trial store + tuning_table.json)")
    ap.add_argument("--family", nargs="+", default=["theta", "l2"],
                    help="hash families to scan")
    ap.add_argument("--n", nargs="+", type=int, default=[4096],
                    help="database sizes (one data profile per n x skew)")
    ap.add_argument("--d", type=int, default=16, help="dimensionality")
    ap.add_argument("--skew", nargs="+", type=float, default=[1.0],
                    help="weight-distribution skews (1.0 = planner reference)")
    ap.add_argument("--source", default="uniform",
                    choices=["uniform", "clustered"],
                    help="synthetic data source for every profile")
    ap.add_argument("--K", nargs="+", type=int, default=[8, 12, 16],
                    help="hashes per table")
    ap.add_argument("--L", nargs="+", type=int, default=[16, 32, 64],
                    help="table counts")
    ap.add_argument("--W", nargs="+", default=["auto"],
                    help="l2 bucket widths ('auto' = planner-anchored)")
    ap.add_argument("--probes", nargs="+", type=int, default=[1, 4, 16],
                    help="multiprobe bucket counts (theta only)")
    ap.add_argument("--window", nargs="+", type=int, default=[256],
                    help="per-table candidate windows")
    ap.add_argument("--k", type=int, default=10, help="recall is measured @k")
    ap.add_argument("--queries", type=int, default=64,
                    help="held-out queries per trial")
    ap.add_argument("--seed", type=int, default=0, help="scan base seed")
    ap.add_argument("--workers", type=int, default=0,
                    help="worker processes (0/1 = inline)")
    ap.add_argument("--max-trials", type=int, default=None,
                    help="stop after this many NEW trials (resume later)")
    args = ap.parse_args(argv)

    from repro.tuner import TuningTable, build_table, run_scan, scan_is_complete

    space = build_space(args)
    trials = space.trials()
    store_path = os.path.join(args.out, "trials.jsonl")
    table_path = os.path.join(args.out, "tuning_table.json")
    print(f"scan space {space.space_id}: {len(trials)} trials -> {store_path}")

    records = run_scan(
        space, store_path, workers=args.workers,
        max_trials=args.max_trials, log=print,
    )
    if not scan_is_complete(space, store_path):
        remaining = len(trials) - len(records)
        print(
            f"PARTIAL: {len(records)}/{len(trials)} trials stored "
            f"({remaining} remaining) — rerun the same command to resume; "
            f"no table written"
        )
        return 0

    table = build_table(records, space)
    table.save(table_path)
    loaded = TuningTable.load(table_path)  # round-trip sanity
    n_entries = sum(len(b["entries"]) for b in loaded.buckets)
    print(
        f"tuning table: {len(loaded.buckets)} bucket(s), "
        f"{n_entries} frontier entries -> {table_path}"
    )
    for b in loaded.buckets:
        p = b["profile"]
        best = max(e["recall"] for e in b["entries"])
        print(
            f"  {b['family']:>6} n={p['n']} d={p['d']} skew={p['skew']}: "
            f"{len(b['entries'])} entries, best recall {best:.3f}"
        )
    print(json.dumps(loaded.provenance(), sort_keys=True))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
