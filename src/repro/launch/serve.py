"""Serving launcher — four modes:

  ALSH vector-search service (the paper's workload), served end-to-end
  through the ``repro.api`` Index facade on the shared ``repro.engine``
  pipeline (key enumeration → candidate sources → dedupe →
  gather_rerank_topk kernels; the exactness spot-check is the same facade
  with QuerySpec(mode="exact") — the oracle runs the identical tail it
  validates). Configuration is
  QUALITY-FIRST: state a recall target and the planner resolves the
  execution knobs (and prints its resolution + per-batch diagnostics):
    python -m repro.launch.serve --mode alsh --recall-target 0.9
  The legacy knob path is untouched — give explicit knobs and no planning
  happens (bit-identical to previous releases):
    python -m repro.launch.serve --mode alsh [--n 100000 --d 64 --batches 4]
    python -m repro.launch.serve --mode alsh --multiprobe --probes 8

  Streaming-ingest service — the mutable lifecycle under live traffic:
  every tick interleaves an insert batch and a retire batch with the query
  batches, all on one jit-compiled program (fixed delta capacity ⇒ no
  retrace), compacting when the delta fills past the policy threshold.
  The engine's chunked delta key match keeps per-query memory independent
  of the capacity, so large deltas (16k+, fewer compaction stalls) are a
  plain flag away:
    python -m repro.launch.serve --mode stream --ingest 512 --retire 128 \
        --delta-capacity 16384

  Fault-tolerant broker service — the full serving tier (repro.serving):
  dynamic batching over an arrival trace, SLO admission control with the
  calibrated degradation ladder, and optional shard chaos (mid-stream
  kill, survivors-only answers with labeled coverage, backoff recovery):
    python -m repro.launch.serve --mode broker --recall-target 0.9 \
        --slo-p99-ms 50 --arrival bursty --rate 500 --requests 2000
    python -m repro.launch.serve --mode broker --shards 4 --kill-shard 1 \
        --kill-at 0.5

  LM decode service with optional ALSH retrieval augmentation:
    python -m repro.launch.serve --mode lm --arch gemma3-1b --reduced --retrieval

All run real batched requests on local devices; the production mesh path is
exercised by the dry-run.
"""

from __future__ import annotations

import argparse
import time


def serve_alsh(args):
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.api import Index, QualitySpec, QuerySpec
    from repro.configs.paper_alsh import ALSHServiceConfig
    from repro.distance import recall_at_k

    svc = ALSHServiceConfig(
        n_per_shard=args.n, d=args.d, K=args.K, L=args.L,
        query_batch=args.query_batch, topk=args.topk,
    )
    key = jax.random.PRNGKey(0)
    data = jax.random.uniform(jax.random.fold_in(key, 1), (svc.n_per_shard, svc.d))

    # quality-first: a stated recall target plans BOTH the geometry and the
    # serving policy; explicit knobs (the legacy path) skip planning entirely
    quality = None
    if args.recall_target is not None:
        quality = QualitySpec(k=svc.topk, recall_target=args.recall_target,
                              latency_budget_ms=args.latency_budget_ms)
    build_cfg = quality if quality is not None else svc.index_config
    if args.storage != "f32" and quality is None:
        build_cfg = dataclasses.replace(build_cfg, storage=args.storage)
    t0 = time.time()
    index = Index.build(jax.random.fold_in(key, 2), data, build_cfg)
    jax.block_until_ready(index.state.sorted_keys)
    cfg = index.config
    print(f"[alsh] built index over n={svc.n_per_shard} d={svc.d} "
          f"family={cfg.family} K={cfg.K} L={cfg.L} storage={cfg.storage} "
          f"in {time.time()-t0:.2f}s"
          + (" (planned from QualitySpec)" if quality is not None else ""))

    # serving policy is a spec value, not a code path
    if quality is not None:
        t0 = time.time()
        spec = index.plan(quality)  # calibration pass, memoized
        print(f"[alsh] planned in {time.time()-t0:.2f}s: {spec}")
    elif args.multiprobe:
        spec = QuerySpec(k=svc.topk, mode="multiprobe", n_probes=args.probes)
    else:
        spec = QuerySpec(k=svc.topk)
    if cfg.storage != "f32" and spec.mode != "exact" and spec.screen_alpha == 0.0:
        # quantized tier: screen against compressed rows, exact-rerank the
        # top k*alpha survivors
        spec = dataclasses.replace(spec, screen_alpha=args.screen_alpha)
    if args.early_exit and spec.mode != "exact" and spec.screen_alpha == 0.0:
        # adaptive probing: stream probe windows, stop per query once the
        # running top-k clears the confidence bound (DESIGN §13)
        spec = dataclasses.replace(
            spec, early_exit=True, exit_group=args.exit_group,
            exit_slack=args.exit_slack,
        )
    exact = QuerySpec(k=svc.topk, mode="exact")
    print(f"[alsh] serving policy: {spec}")

    for b in range(args.batches):
        kq = jax.random.fold_in(key, 100 + b)
        q = jax.random.uniform(kq, (svc.query_batch, svc.d))
        w = jnp.abs(jax.random.normal(jax.random.fold_in(kq, 1), (svc.query_batch, svc.d))) + 0.1
        t0 = time.time()
        res = index.query(q, w, spec)
        jax.block_until_ready(res.dists)
        dt = time.time() - t0
        # spot-check recall on the first 16 queries (exact mode = the oracle)
        ref = index.query(q[:16], w[:16], exact)
        rec = recall_at_k(res.ids[:16], ref.ids, svc.topk)
        line = (f"[alsh] batch {b}: {svc.query_batch} queries in {dt*1e3:.1f} ms "
                f"({dt/svc.query_batch*1e6:.1f} us/query) "
                f"cand_frac={float(jnp.mean(res.n_candidates))/svc.n_per_shard:.4f} "
                f"recall@{svc.topk}~{rec:.2f}")
        if quality is not None:
            # per-query diagnostics: predicted success + truncation pressure
            rep = index.explain(q[:16], w[:16], spec)
            line += (f" pred_success~{float(rep.predicted_success.mean()):.2f} "
                     f"truncated={int((rep.truncated_tables > 0).sum())}/16")
        print(line)
        if args.stats:
            # storage-tier accounting: bytes moved by the gather tail
            import numpy as np
            rep = index.explain(q[:16], w[:16], spec)
            print(f"[alsh]   stats: storage={rep.storage} "
                  f"table_bytes={rep.table_bytes} "
                  f"rows_screened~{float(np.mean(rep.rows_screened)):.1f} "
                  f"rows_reranked~{float(np.mean(rep.rows_reranked)):.1f} "
                  f"bytes_gathered~{float(np.mean(rep.bytes_gathered)):.0f}")
            if rep.tables_probed is not None:
                # adaptive-probing accounting: windows visited + stop mix
                d = rep.to_dict()
                n_win = cfg.L * (spec.n_probes if spec.mode == "multiprobe"
                                 else 1)
                print(f"[alsh]   stats: tables_probed~"
                      f"{d['mean_tables_probed']:.1f}/{n_win} "
                      f"stop_reasons={d['stop_reasons']}")


def serve_alsh_stream(args):
    """Mutable-index service: rows arrive and retire while queries flow."""
    import jax
    import jax.numpy as jnp

    from repro.api import Index, QuerySpec, UpdateSpec
    from repro.configs.paper_alsh import ALSHServiceConfig
    from repro.distance import recall_at_k

    svc = ALSHServiceConfig(
        n_per_shard=args.n, d=args.d, K=args.K, L=args.L,
        query_batch=args.query_batch, topk=args.topk,
    )
    key = jax.random.PRNGKey(0)
    data = jax.random.uniform(jax.random.fold_in(key, 1), (svc.n_per_shard, svc.d))
    update = UpdateSpec(delta_capacity=args.delta_capacity,
                        compact_threshold=args.compact_threshold)
    t0 = time.time()
    index = Index.build(jax.random.fold_in(key, 2), data, svc.index_config,
                        update=update)
    jax.block_until_ready(index.state.sorted_keys)
    print(f"[stream] built mutable index n={svc.n_per_shard} d={svc.d} "
          f"delta_capacity={args.delta_capacity} in {time.time()-t0:.2f}s")

    spec = QuerySpec(k=svc.topk)
    exact = QuerySpec(k=svc.topk, mode="exact")
    # one compiled program each for the whole service life (static shapes)
    jquery = jax.jit(lambda ix, q, w: ix.query(q, w, spec))
    jinsert = jax.jit(lambda ix, rows: ix.insert(rows))
    jdelete = jax.jit(lambda ix, ids: ix.delete(ids))

    next_retire = 0  # retire oldest main rows first (FIFO churn)
    for b in range(args.batches):
        kb = jax.random.fold_in(key, 100 + b)
        # ingest: new rows enter the delta segment
        rows = jax.random.uniform(jax.random.fold_in(kb, 0),
                                  (args.ingest, svc.d))
        t0 = time.time()
        index, ids = jinsert(index, rows)
        jax.block_until_ready(ids)
        t_ins = time.time() - t0
        # retire: oldest rows tombstone out
        retire = jnp.arange(next_retire, next_retire + args.retire,
                            dtype=jnp.int32)
        next_retire += args.retire
        index = jdelete(index, retire)
        # serve queries against the live two-segment view
        q = jax.random.uniform(jax.random.fold_in(kb, 1), (svc.query_batch, svc.d))
        w = jnp.abs(jax.random.normal(jax.random.fold_in(kb, 2),
                                      (svc.query_batch, svc.d))) + 0.1
        t0 = time.time()
        res = jquery(index, q, w)
        jax.block_until_ready(res.dists)
        t_q = time.time() - t0
        ref = index.query(q[:16], w[:16], exact)
        rec = recall_at_k(res.ids[:16], ref.ids, svc.topk)
        fill = index.delta_fill
        print(f"[stream] tick {b}: +{args.ingest} rows in {t_ins*1e3:.1f} ms "
              f"({args.ingest/max(t_ins,1e-9):,.0f} rows/s), -{args.retire} retired, "
              f"{svc.query_batch} queries in {t_q*1e3:.1f} ms "
              f"({t_q/svc.query_batch*1e6:.1f} us/query) "
              f"delta={fill}/{args.delta_capacity} recall@{svc.topk}~{rec:.2f}")
        if index.needs_compact:
            t0 = time.time()
            index = index.compact()
            jax.block_until_ready(index.state.sorted_keys)
            # compact renumbers survivors to [0, n_live); everything below
            # next_retire was tombstoned, so the oldest surviving row is 0
            next_retire = 0
            print(f"[stream] compacted to n={index.n} (delta emptied) "
                  f"in {time.time()-t0:.2f}s")


def serve_broker(args):
    """Fault-tolerant broker drill: arrival trace -> batched engine calls
    under an SLO, with optional scripted shard failure."""
    import tempfile

    import jax
    import numpy as np

    from repro.api import Index, QualitySpec
    from repro.serving import (
        Broker,
        BrokerConfig,
        ChaosPlan,
        ShardSet,
        SLOConfig,
        make_trace,
        requests_from_trace,
    )

    key = jax.random.PRNGKey(0)
    data = jax.random.uniform(jax.random.fold_in(key, 1), (args.n, args.d))
    quality = QualitySpec(
        k=args.topk,
        recall_target=args.recall_target if args.recall_target is not None else 0.9,
    )
    t0 = time.time()
    index = Index.build(jax.random.fold_in(key, 2), data, quality)
    ladder = index.plan_ladder(quality)
    print(f"[broker] built+planned n={args.n} d={args.d} in {time.time()-t0:.2f}s; "
          f"ladder has {len(ladder)} rungs "
          f"(recalls {[round(float(r.predicted_recall), 3) for r in ladder]})")

    shardset = None
    tmp = None
    if args.shards > 1:
        tmp = tempfile.TemporaryDirectory(prefix="repro_shards_")
        t0 = time.time()
        shardset = ShardSet.build(index, args.shards, tmp.name)
        print(f"[broker] built {args.shards} shards (persisted for recovery) "
              f"in {time.time()-t0:.2f}s")
        if args.kill_shard is not None:
            shardset.chaos = ChaosPlan(
                kill_shard=args.kill_shard, kill_at_s=args.kill_at
            )
            print(f"[broker] chaos armed: kill shard {args.kill_shard} "
                  f"at t={args.kill_at}s")

    slo = SLOConfig(p99_ms=args.slo_p99_ms)
    broker = Broker(
        index, quality, slo,
        BrokerConfig(max_batch=args.max_batch, max_queue=args.max_queue),
        shardset=shardset,
    )
    kq = jax.random.fold_in(key, 3)
    q = np.asarray(jax.random.uniform(kq, (256, args.d)))
    w = np.abs(np.asarray(jax.random.normal(jax.random.fold_in(kq, 1), (256, args.d)))) + 0.1
    trace = make_trace(args.arrival, args.rate, args.requests, seed=0)
    reqs = requests_from_trace(trace, q, w)
    t0 = time.time()
    responses, stats = broker.run(reqs)
    broker.assert_no_retrace()
    print(f"[broker] {args.arrival} trace: {len(reqs)} requests at ~{args.rate}/s "
          f"served in {time.time()-t0:.2f}s wall")
    print(f"[broker] p50={stats.p50_ms:.2f}ms p99={stats.p99_ms:.2f}ms "
          f"(SLO {slo.p99_ms}ms) throughput={stats.throughput_rps:.0f} req/s")
    print(f"[broker] shed_rate={stats.shed_rate:.3f} "
          f"degraded_frac={stats.degraded_frac:.3f} rungs={stats.rung_counts} "
          f"mean_coverage={stats.mean_coverage:.3f}")
    if shardset is not None and args.kill_shard is not None:
        served = [r for r in responses if r.status != "shed"]
        covs = sorted({round(r.coverage, 6) for r in served})
        expect = (args.shards - 1) / args.shards
        events = [e["event"] for e in shardset.recovery_log]
        print(f"[broker] chaos: coverages seen {covs}; recovery log events {events}")
        assert any(abs(c - expect) < 1e-9 for c in covs), (
            f"expected some survivors-only answers at coverage {expect}, got {covs}"
        )
        assert "killed" in events, "scripted kill never fired"
        assert "recovered" in events, "shard never recovered within the trace"
        assert shardset.coverage == 1.0, "shard set did not return to full coverage"
        print("[broker] chaos assertions passed: labeled degraded coverage + recovery")
    if tmp is not None:
        tmp.cleanup()


def serve_lm(args):
    import jax
    import jax.numpy as jnp

    from repro import models
    from repro.configs import RetrievalConfig, get_bundle, reduced_model
    from repro.runtime import retrieval as rt
    from repro.runtime.serve_step import make_decode_step, make_prefill_step

    bundle = get_bundle(args.arch)
    mcfg = reduced_model(bundle.model) if args.reduced else bundle.model
    rcfg = None
    if args.retrieval:
        rcfg = RetrievalConfig(datastore_size=4096, d_key=16, K=6, L=8, topk=4)

    key = jax.random.PRNGKey(0)
    params = models.init_params(key, mcfg)
    B, S, gen = args.batch, args.prompt_len, args.gen_len
    prompt = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0, mcfg.vocab_size)

    prefill = jax.jit(make_prefill_step(mcfg, cache_len=S + gen))
    decode = jax.jit(make_decode_step(mcfg, rcfg))
    retr_state = None
    if rcfg is not None:
        retr_state = rt.build_datastore(jax.random.fold_in(key, 2), mcfg.d_model,
                                        mcfg.vocab_size, rcfg)

    t0 = time.time()
    logits, caches = prefill(params, {"tokens": prompt})
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(tok)
    print(f"[lm] prefill B={B} S={S} in {time.time()-t0:.2f}s "
          f"(retrieval={'on' if rcfg else 'off'})")

    out = [tok]
    t0 = time.time()
    for i in range(gen):
        batch = {"token": tok, "pos": jnp.full((B,), S + i, jnp.int32)}
        if rcfg is None:
            _, tok, caches = decode(params, batch, caches)
        else:
            _, tok, caches = decode(params, batch, caches, retr_state)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"[lm] generated {gen} tokens x {B} seqs in {dt:.2f}s "
          f"({dt/gen*1e3:.1f} ms/step); sample: {[int(t[0]) for t in out[:8]]}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["alsh", "stream", "broker", "lm"],
                    default="alsh")
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--retrieval", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--n", type=int, default=65536)
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--K", type=int, default=12)
    ap.add_argument("--L", type=int, default=32)
    ap.add_argument("--query-batch", type=int, default=256)
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--batches", type=int, default=3)
    ap.add_argument("--storage", choices=["f32", "bf16", "int8"],
                    default="f32",
                    help="alsh mode: compressed table tier (explicit-knob "
                         "path; quantized rows are screened then exact-"
                         "reranked)")
    ap.add_argument("--screen-alpha", type=float, default=2.0,
                    help="alsh mode: keep k*alpha proxy-screen survivors "
                         "for exact rerank (quantized storage only)")
    ap.add_argument("--stats", action="store_true",
                    help="alsh mode: print storage-tier accounting "
                         "(table_bytes, rows screened/reranked, bytes "
                         "gathered) per batch")
    ap.add_argument("--early-exit", action="store_true",
                    help="alsh mode: adaptive probing — stream probe "
                         "windows in trace-static groups and stop per "
                         "query at the confidence bound (f32 tables only; "
                         "folds off under an active quantized screen)")
    ap.add_argument("--exit-group", type=int, default=8,
                    help="alsh mode: probe windows per streamed group "
                         "(with --early-exit)")
    ap.add_argument("--exit-slack", type=float, default=0.1,
                    help="alsh mode: acceptable miss probability for the "
                         "confidence stop; 0 disables it (geometric-only, "
                         "bit-identical results)")
    ap.add_argument("--multiprobe", action="store_true",
                    help="serve with QuerySpec(mode='multiprobe')")
    ap.add_argument("--probes", type=int, default=8,
                    help="multiprobe buckets per table")
    ap.add_argument("--recall-target", type=float, default=None,
                    help="alsh mode: quality-first serving — plan geometry "
                         "and policy for this recall@topk (overrides "
                         "--K/--L/--multiprobe/--probes)")
    ap.add_argument("--latency-budget-ms", type=float, default=None,
                    help="alsh mode: optional per-query latency budget for "
                         "the planner's cost model (with --recall-target)")
    ap.add_argument("--ingest", type=int, default=512,
                    help="stream mode: rows inserted per tick")
    ap.add_argument("--retire", type=int, default=128,
                    help="stream mode: rows tombstoned per tick")
    ap.add_argument("--delta-capacity", type=int, default=8192,
                    help="stream mode: delta-segment slots before a compact "
                         "(the chunked delta match keeps query memory flat "
                         "in this, so 16k+ capacities are fine)")
    ap.add_argument("--compact-threshold", type=float, default=0.75,
                    help="stream mode: fill fraction that triggers compact")
    ap.add_argument("--slo-p99-ms", type=float, default=50.0,
                    help="broker mode: target p99 latency; breaches walk "
                         "down the degradation ladder")
    ap.add_argument("--arrival", choices=["poisson", "bursty"],
                    default="poisson", help="broker mode: arrival trace shape")
    ap.add_argument("--rate", type=float, default=500.0,
                    help="broker mode: mean arrival rate (req/s)")
    ap.add_argument("--requests", type=int, default=1000,
                    help="broker mode: trace length")
    ap.add_argument("--shards", type=int, default=1,
                    help="broker mode: >1 serves a host-side ShardSet")
    ap.add_argument("--kill-shard", type=int, default=None,
                    help="broker mode: chaos — shard to kill mid-stream "
                         "(needs --shards > 1)")
    ap.add_argument("--kill-at", type=float, default=0.5,
                    help="broker mode: virtual time (s) of the shard kill")
    ap.add_argument("--max-batch", type=int, default=64,
                    help="broker mode: largest dynamic-batch bucket")
    ap.add_argument("--max-queue", type=int, default=256,
                    help="broker mode: admission queue bound (overflow sheds)")
    args = ap.parse_args()
    if args.mode == "alsh":
        serve_alsh(args)
    elif args.mode == "stream":
        serve_alsh_stream(args)
    elif args.mode == "broker":
        serve_broker(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
