"""Input builders: concrete batches (smoke/examples) and ShapeDtypeStruct
stand-ins (dry-run) for every (arch × shape-kind) cell.

The modality frontends are stubs by assignment: [audio] provides precomputed
frame embeddings, [vlm] provides precomputed patch embeddings + M-RoPE grids.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def _mk(shape, dtype, concrete: bool, fill=0):
    if not concrete:
        return jax.ShapeDtypeStruct(shape, dtype)
    if fill == "iota":
        size = int(np.prod(shape))
        return jnp.arange(size, dtype=dtype).reshape(shape) % 97
    return jnp.full(shape, fill, dtype)


def train_batch(cfg: ModelConfig, batch: int, seq: int, concrete: bool = False) -> dict:
    if cfg.frontend == "audio":
        return {
            "frames": _mk((batch, seq, cfg.frontend_dim), jnp.float32, concrete, 0.1),
            "targets": _mk((batch, seq), jnp.int32, concrete, "iota"),
            "mask": _mk((batch, seq), jnp.bool_, concrete, True),
        }
    if cfg.frontend == "vision":
        nv = min(cfg.n_vision_tokens, seq // 2)  # clamp for tiny test seqs
        s_text = seq - nv
        return {
            "tokens": _mk((batch, s_text), jnp.int32, concrete, "iota"),
            "patches": _mk((batch, nv, cfg.frontend_dim), jnp.float32, concrete, 0.1),
            "positions": _mk((3, batch, seq), jnp.int32, concrete, "iota"),
        }
    return {"tokens": _mk((batch, seq), jnp.int32, concrete, "iota")}


def prefill_batch(cfg: ModelConfig, batch: int, seq: int, concrete: bool = False) -> dict:
    b = train_batch(cfg, batch, seq, concrete)
    b.pop("targets", None)
    b.pop("mask", None)
    return b


def decode_batch(cfg: ModelConfig, batch: int, pos_value: int, concrete: bool = False) -> dict:
    return {
        "token": _mk((batch,), jnp.int32, concrete, 1),
        "pos": _mk((batch,), jnp.int32, concrete, pos_value),
    }
