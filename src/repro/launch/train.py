"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs REAL steps on the local device(s) (CPU here; identical code path on a
TPU slice — the mesh just gets bigger via --mesh production). For cluster
bring-up the dry-run (``repro.launch.dryrun``) validates every cell first.

Fault tolerance is on by default: resumes from the newest committed
checkpoint in --ckpt-dir; checkpoints every --ckpt-every steps (async).
"""

from __future__ import annotations

import argparse
import dataclasses
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--reduced", action="store_true", help="tiny smoke config")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--grad-compression", default=None, choices=[None, "bf16", "int8_ef"])
    args = ap.parse_args()

    import jax

    from repro.configs import get_bundle, reduced_model
    from repro.data.pipeline import DataConfig
    from repro.runtime.fault import train_loop

    bundle = get_bundle(args.arch)
    mcfg = reduced_model(bundle.model) if args.reduced else bundle.model
    tcfg = dataclasses.replace(
        bundle.train,
        microbatch=args.microbatch,
        grad_compression=args.grad_compression,
        total_steps=args.steps,
        **({"learning_rate": args.lr} if args.lr else {}),
    )
    bundle = dataclasses.replace(bundle, model=mcfg, train=tcfg)
    dcfg = DataConfig(seq_len=args.seq_len, global_batch=args.global_batch)

    print(f"[train] arch={args.arch} reduced={args.reduced} steps={args.steps} "
          f"devices={jax.device_count()}")
    t0 = time.time()
    losses = []

    def log(step, metrics):
        losses.append(metrics["loss"])
        if step % 10 == 0 or step == 1:
            print(f"  step {step:5d}  loss {metrics['loss']:.4f}  "
                  f"gnorm {metrics['grad_norm']:.3f}  lr {metrics['lr']:.2e}  "
                  f"({(time.time()-t0)/max(step,1):.2f}s/step)")

    train_loop(
        bundle, dcfg, args.steps, args.ckpt_dir,
        ckpt_every=args.ckpt_every, async_ckpt=True, on_metrics=log,
    )
    print(f"[train] done: first-10 mean loss {sum(losses[:10])/max(len(losses[:10]),1):.4f} "
          f"-> last-10 mean {sum(losses[-10:])/max(len(losses[-10:]),1):.4f}")


if __name__ == "__main__":
    main()
