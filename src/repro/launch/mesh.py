"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — critical because the dry-run forces 512 host
devices via XLA_FLAGS before any jax import, while tests/benchmarks must see
the real single device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(n_data: int | None = None, n_model: int = 1):
    """Small mesh over whatever devices exist (CPU tests: 4/8 host devices)."""
    n = len(jax.devices())
    n_data = n_data if n_data is not None else n // n_model
    return jax.make_mesh((n_data, n_model), ("data", "model"))
