"""qwen3-8b [dense]: 36L, 32H GQA kv=8, qk-norm, SwiGLU, vocab 151936.

[hf:Qwen/Qwen3-8B] — head_dim 128, untied lm_head, rope theta 1M.
long_500k skipped: pure full-attention arch.
"""

from repro.configs.base import ArchBundle, ModelConfig, TrainConfig

MODEL = ModelConfig(
    name="qwen3-8b",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151_936,
    scan_unit=("attn",),
    qk_norm=True,
    rope_theta=1_000_000.0,
    activation="swiglu",
    tie_embeddings=False,
    param_dtype="float32",
)

BUNDLE = ArchBundle(
    arch_id="qwen3-8b",
    model=MODEL,
    train=TrainConfig(),
    shape_skips={"long_500k": "pure full-attention arch: 500k cell not run (per spec)"},
)
