"""gemma-2b [dense]: 18L, 8H MQA kv=1, GeGLU, head_dim 256, vocab 256000.

[arXiv:2403.08295; hf:google/gemma-2b] — embeddings scaled by sqrt(d_model),
tied unembedding, full global attention on every layer.

long_500k skipped: pure full attention (per spec, sub-quadratic archs only).
"""

from repro.configs.base import ArchBundle, ModelConfig, TrainConfig

MODEL = ModelConfig(
    name="gemma-2b",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256_000,
    scan_unit=("attn",),
    activation="geglu",
    embed_scale=True,
    tie_embeddings=True,
    param_dtype="float32",
)

BUNDLE = ArchBundle(
    arch_id="gemma-2b",
    model=MODEL,
    train=TrainConfig(),
    shape_skips={"long_500k": "pure full-attention arch: 500k cell not run (per spec)"},
)
