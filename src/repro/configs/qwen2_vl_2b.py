"""qwen2-vl-2b [vlm]: 28L, 12H GQA kv=2, M-RoPE, dynamic-resolution vision.

[arXiv:2409.12191; hf:Qwen/Qwen2-VL-2B] — SwiGLU d_ff 8960, vocab 151936,
tied embeddings. The vision tower is a STUB: ``input_specs`` provides 256
precomputed patch embeddings (frontend_dim 1280, mapped by vision_proj — the
"merger" stand-in) plus the 3D (t, h, w) M-RoPE position grids for the full
sequence. Backbone M-RoPE sections (16, 24, 24) over the 64 half-dims.

long_500k skipped: pure full-attention arch.
"""

from repro.configs.base import ArchBundle, ModelConfig, TrainConfig

MODEL = ModelConfig(
    name="qwen2-vl-2b",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151_936,
    scan_unit=("attn",),
    pos="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    activation="swiglu",
    tie_embeddings=True,
    frontend="vision",
    frontend_dim=1280,
    n_vision_tokens=256,
    param_dtype="float32",
)

BUNDLE = ArchBundle(
    arch_id="qwen2-vl-2b",
    model=MODEL,
    train=TrainConfig(),
    shape_skips={"long_500k": "pure full-attention arch: 500k cell not run (per spec)"},
)
