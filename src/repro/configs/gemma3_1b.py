"""gemma3-1b [dense]: 26L, 4H GQA kv=1, 5:1 local:global, 128k-class context.

[hf:google/gemma-3-1b-pt] — GeGLU, head_dim 256, qk-norm, sliding window 512
on local layers (rope theta 10k), global layers rope theta 1M, embeddings
scaled by sqrt(d_model), tied unembedding, 262144 vocab.

Pattern: (5 local + 1 global) x 4 units + 2 local tail = 26 layers.
long_500k runs: local layers are linear-in-S; the 4 global layers' KV at 500k
is ~4 GB bf16 (kv=1, head_dim 256) — manageable.
"""

from repro.configs.base import ArchBundle, ModelConfig, TrainConfig

MODEL = ModelConfig(
    name="gemma3-1b",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    scan_unit=("local", "local", "local", "local", "local", "global"),
    n_units=4,
    tail=("local", "local"),
    window=512,
    qk_norm=True,
    rope_theta=1_000_000.0,
    rope_local_theta=10_000.0,
    activation="geglu",
    embed_scale=True,
    tie_embeddings=True,
    param_dtype="float32",
)

BUNDLE = ArchBundle(arch_id="gemma3-1b", model=MODEL, train=TrainConfig())
