"""llama4-scout-17b-16e [moe]: 48L, MoE 16 experts top-1 + shared expert.

[hf:meta-llama/Llama-4-Scout-17B-16E] — 40H GQA kv=8, head_dim 128, iRoPE
attention pattern (3 chunked-local : 1 global-NoPE), every layer MoE
(16 routed top-1, d_ff 8192, + 1 always-on shared expert), vocab 202048.

~109B total / ~17B active. long_500k runs: chunked layers are linear-in-S
(iRoPE is llama4's long-context mechanism); global-NoPE KV is decode-linear.
"""

from repro.configs.base import ArchBundle, ModelConfig, MoEConfig, TrainConfig

MODEL = ModelConfig(
    name="llama4-scout-17b-16e",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    scan_unit=("chunked_moe", "chunked_moe", "chunked_moe", "global_nope_moe"),
    n_units=12,
    chunk_size=8192,
    rope_theta=500_000.0,
    activation="swiglu",
    tie_embeddings=False,
    moe=MoEConfig(
        n_experts=16, top_k=1, d_ff_expert=8192, n_shared=1, every=1, d_ff_dense=16384
    ),
    param_dtype="bfloat16",
)

BUNDLE = ArchBundle(arch_id="llama4-scout-17b-16e", model=MODEL, train=TrainConfig())
