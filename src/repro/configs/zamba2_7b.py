"""zamba2-7b [hybrid]: 81L Mamba2 backbone + SHARED full-attention blocks.

[arXiv:2411.15242] — Mamba2 layers (ssm_state 64) with one shared
attention+MLP block woven in every 6th position (weights shared across all
occurrences — zamba2's parameter-reuse trick; per-occurrence KV caches stay
distinct). 32H MHA kv=32, head_dim 112, d_ff 14336, vocab 32000.

Pattern: (5 mamba2 + 1 shared_attn) x 13 + 3 mamba2 tail = 81 layers.
long_500k runs: SSM state carries long context; shared-attn KV is the only
S-dependent cache.
"""

from repro.configs.base import ArchBundle, ModelConfig, SSMConfig, TrainConfig

MODEL = ModelConfig(
    name="zamba2-7b",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32_000,
    scan_unit=("mamba2", "mamba2", "mamba2", "mamba2", "mamba2", "shared_attn"),
    n_units=13,
    tail=("mamba2", "mamba2", "mamba2"),
    activation="swiglu",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, d_conv=4, n_groups=1, chunk=256),
    param_dtype="float32",
)

BUNDLE = ArchBundle(arch_id="zamba2-7b", model=MODEL, train=TrainConfig())
