"""glm4-9b [dense]: 40L, 32H GQA kv=2, SwiGLU, vocab 151552.

[hf:THUDM/glm-4-9b] — head_dim 128, RoPE, untied lm_head.
long_500k skipped: pure full-attention arch.
"""

from repro.configs.base import ArchBundle, ModelConfig, TrainConfig

MODEL = ModelConfig(
    name="glm4-9b",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=151_552,
    scan_unit=("attn",),
    rope_theta=10_000.0,
    activation="swiglu",
    tie_embeddings=False,
    param_dtype="float32",
)

BUNDLE = ArchBundle(
    arch_id="glm4-9b",
    model=MODEL,
    train=TrainConfig(),
    shape_skips={"long_500k": "pure full-attention arch: 500k cell not run (per spec)"},
)
