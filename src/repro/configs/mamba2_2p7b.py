"""mamba2-2.7b [ssm]: 64L attention-free SSD (state-space duality).

[arXiv:2405.21060] — d_model 2560, ssm_state 128, head_dim 64, expand 2
(d_inner 5120, 80 SSM heads), vocab 50280, tied embeddings. No attention,
no positional encoding (the SSM recurrence carries order).

All four shape cells run: decode is a constant-size state update; long_500k
is the arch's home turf.
"""

from repro.configs.base import ArchBundle, ModelConfig, SSMConfig, TrainConfig

MODEL = ModelConfig(
    name="mamba2-2.7b",
    n_layers=64,
    d_model=2560,
    n_heads=1,  # unused (attention-free) — kept for schema completeness
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,  # no FFN in mamba2 blocks
    vocab_size=50_280,
    scan_unit=("mamba2",),
    activation="swiglu",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, d_conv=4, n_groups=1, chunk=256),
    param_dtype="float32",
)

BUNDLE = ArchBundle(arch_id="mamba2-2.7b", model=MODEL, train=TrainConfig())
