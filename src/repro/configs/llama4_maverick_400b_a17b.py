"""llama4-maverick-400b-a17b [moe]: 48L, MoE 128 experts top-1, interleaved.

[hf:meta-llama/Llama-4-Maverick-17B-128E] — 40H GQA kv=8, head_dim 128, iRoPE
(3 chunked : 1 global-NoPE), MoE on every OTHER layer (128 routed top-1 +
shared expert, d_ff 8192); interleaved dense layers use d_ff 16384.
vocab 202048. ~400B total / ~17B active.

Memory posture: bf16 params AND bf16 optimizer moments (TrainConfig) so the
ZeRO-3-sharded train state fits the single-pod 256 x 16 GB mesh
(400e9 * (2+2+2+2) B / 256 ≈ 12.5 GB/chip).
"""

from repro.configs.base import ArchBundle, ModelConfig, MoEConfig, TrainConfig

MODEL = ModelConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    scan_unit=("chunked", "chunked_moe", "chunked", "global_nope_moe"),
    n_units=12,
    chunk_size=8192,
    rope_theta=500_000.0,
    activation="swiglu",
    tie_embeddings=False,
    moe=MoEConfig(
        n_experts=128, top_k=1, d_ff_expert=8192, n_shared=1, every=2, d_ff_dense=16384
    ),
    param_dtype="bfloat16",
)

BUNDLE = ArchBundle(
    arch_id="llama4-maverick-400b-a17b",
    model=MODEL,
    train=TrainConfig(optimizer_dtype="bfloat16"),
)
