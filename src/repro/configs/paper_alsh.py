"""The paper's own workload: a sharded ALSH vector-search service config.

This is the standalone ``--arch paper-alsh`` target for ``launch/serve.py``:
build (d_w^l1, theta)-ALSH indexes over row-sharded data and serve batched
weighted NNS queries at cluster scale.
"""

import dataclasses

from repro.core.index import IndexConfig
from repro.core.transforms import BoundedSpace


@dataclasses.dataclass(frozen=True)
class ALSHServiceConfig:
    n_per_shard: int = 262_144  # database rows per device
    d: int = 128
    M: int = 32
    K: int = 12
    L: int = 32
    family: str = "theta"
    W: float = 8.0
    max_candidates: int = 128
    query_batch: int = 1024  # global query batch per serve step
    topk: int = 10

    @property
    def index_config(self) -> IndexConfig:
        return IndexConfig(
            d=self.d,
            M=self.M,
            K=self.K,
            L=self.L,
            family=self.family,
            W=self.W,
            max_candidates=self.max_candidates,
            space=BoundedSpace(0.0, 1.0, float(self.M)),
        )


SERVICE = ALSHServiceConfig()
