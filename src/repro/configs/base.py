"""Config schema: model architecture, input shapes, training/serving knobs.

Every assigned architecture is a ``ArchBundle`` in its own module under
``repro/configs/`` and is selectable via ``--arch <id>`` in the launchers.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

# Layer kinds understood by the block engine (models/model.py):
#   "attn"        — full (global) attention, RoPE
#   "local"       — sliding-window attention (cfg.window), RoPE (local theta)
#   "global"      — full attention, RoPE (global theta)
#   "chunked"     — chunked local attention (cfg.chunk_size), RoPE  [llama4 iRoPE]
#   "global_nope" — full attention, NO positional encoding         [llama4 iRoPE]
#   "mamba2"      — Mamba2 SSD block
#   "shared_attn" — full attention with weights SHARED across occurrences [zamba2]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int = 1
    d_ff_expert: int = 8192
    n_shared: int = 1  # always-on shared experts (llama4 style)
    every: int = 1  # MoE on layers with (index % every == every - 1); others dense
    d_ff_dense: int = 16384  # d_ff of the interleaved dense layers
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class RetrievalConfig:
    """ALSH retrieval attachment for serving (the paper's technique as a feature)."""

    datastore_size: int = 65536  # records per data-axis shard
    d_key: int = 64  # reduced hidden-state key dim (random projection)
    M: int = 32
    K: int = 8
    L: int = 16
    family: str = "theta"
    max_candidates: int = 64
    topk: int = 8
    interp_lambda: float = 0.25  # logit interpolation weight
    # > 0 makes the datastore index mutable (streaming ingest of new
    # (hidden-state, token) records during serving; see runtime.retrieval)
    delta_capacity: int = 0
    # quality-first retrieval: when set, build_datastore resolves a
    # QualitySpec(k=topk, recall_target=...) through the planner EAGERLY
    # (the memoized plan then drives every decode-step lookup; the explicit
    # K/L/max_candidates above are the legacy path and still the default)
    recall_target: float | None = None


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # layer pattern: n_layers == n_units * len(scan_unit) + len(tail)
    scan_unit: tuple = ("attn",)
    n_units: Optional[int] = None
    tail: tuple = ()
    # attention details
    causal: bool = True
    qk_norm: bool = False
    window: int = 512
    chunk_size: int = 8192
    rope_theta: float = 10_000.0
    rope_local_theta: Optional[float] = None
    pos: str = "rope"  # rope | mrope
    mrope_sections: tuple = (16, 24, 24)
    logit_softcap: Optional[float] = None
    activation: str = "swiglu"  # swiglu | geglu | gelu
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    embed_scale: bool = False  # gemma multiplies embeddings by sqrt(d_model)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # modality frontend stubs ([audio]/[vlm] archs)
    frontend: Optional[str] = None  # None | "audio" | "vision"
    frontend_dim: int = 512
    n_vision_tokens: int = 256
    encoder_only: bool = False
    # numerics / compilation
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    # ---- perf hillclimb levers (defaults = paper-faithful baseline) --------
    embed_table_spec: str = "vocab_model"  # "vocab_model" | "dm_data"
    logits_dtype: str = "float32"  # "float32" | "bfloat16"
    loss_chunk: int = 0  # >0: CE computed in seq chunks (never full (B,S,V))
    attn_blk_q: int = 512
    attn_blk_kv: int = 1024
    cache_spec_mode: str = "seq_model"  # "seq_model" | "heads_model"
    dp_over_model: bool = False  # True: model axis = extra DP (no activation TP)
    remat_policy: str = "nothing"  # "nothing" | "dots" (dots_with_no_batch_dims)
    moe_impl: str = "gspmd"  # "gspmd" | "ep_shardmap" (explicit EP, see moe.py)
    serve_param_layout: str = "fsdp"  # "fsdp" | "replicated" (decode/prefill only)

    @property
    def unit_len(self) -> int:
        return len(self.scan_unit)

    @property
    def resolved_units(self) -> int:
        if self.n_units is not None:
            return self.n_units
        assert (self.n_layers - len(self.tail)) % self.unit_len == 0, self.name
        return (self.n_layers - len(self.tail)) // self.unit_len

    def validate(self) -> None:
        assert self.resolved_units * self.unit_len + len(self.tail) == self.n_layers, (
            f"{self.name}: pattern {self.scan_unit}x{self.resolved_units}+{self.tail} "
            f"!= {self.n_layers} layers"
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell: what to lower and at what size."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    # memory / distribution knobs
    optimizer_dtype: str = "float32"  # moments dtype ("bfloat16" to halve HBM)
    microbatch: int = 1  # gradient-accumulation chunks per step
    grad_compression: Optional[str] = None  # None | "bf16" | "int8_ef"
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class ArchBundle:
    """Everything ``--arch <id>`` selects."""

    arch_id: str
    model: ModelConfig
    train: TrainConfig = TrainConfig()
    retrieval: Optional[RetrievalConfig] = None
    # which shape cells run for this arch (None = skip, with reason)
    shape_skips: dict = dataclasses.field(default_factory=dict)

    def runnable_shapes(self):
        return [s for s in SHAPES.values() if s.name not in self.shape_skips]
