"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""

from __future__ import annotations

import dataclasses

from repro.configs import (
    gemma3_1b,
    gemma_2b,
    glm4_9b,
    hubert_xlarge,
    llama4_maverick_400b_a17b,
    llama4_scout_17b_16e,
    mamba2_2p7b,
    qwen2_vl_2b,
    qwen3_8b,
    zamba2_7b,
)
from repro.configs.base import (
    ArchBundle,
    ModelConfig,
    MoEConfig,
    RetrievalConfig,
    SHAPES,
    ShapeConfig,
    SSMConfig,
    TrainConfig,
)

_BUNDLES = {
    b.arch_id: b
    for b in [
        hubert_xlarge.BUNDLE,
        gemma3_1b.BUNDLE,
        gemma_2b.BUNDLE,
        qwen3_8b.BUNDLE,
        glm4_9b.BUNDLE,
        zamba2_7b.BUNDLE,
        llama4_scout_17b_16e.BUNDLE,
        llama4_maverick_400b_a17b.BUNDLE,
        qwen2_vl_2b.BUNDLE,
        mamba2_2p7b.BUNDLE,
    ]
}


def list_archs() -> list[str]:
    return sorted(_BUNDLES)


def get_bundle(arch_id: str) -> ArchBundle:
    if arch_id not in _BUNDLES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list_archs()}")
    return _BUNDLES[arch_id]


def reduced_model(cfg: ModelConfig) -> ModelConfig:
    """Same family/pattern, tiny dimensions — for CPU smoke tests.

    Keeps the layer-kind structure (scan_unit/tail, MoE/SSM/frontends) so the
    smoke test exercises exactly the code paths of the full config.
    """
    unit = cfg.scan_unit
    tail = cfg.tail
    n_units = 2
    n_layers = n_units * len(unit) + len(tail)
    kv = 1 if cfg.n_kv_heads == 1 else 2
    updates = dict(
        n_layers=n_layers,
        n_units=n_units,
        d_model=64,
        n_heads=4,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        window=32,
        chunk_size=64,
        frontend_dim=32,
        n_vision_tokens=8,
        mrope_sections=(2, 3, 3),  # scaled to head_dim 16 (half = 8)
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
    if cfg.moe is not None:
        # capacity_factor = n_experts ⇒ C >= T: no capacity drops in smoke
        # tests (drops are load-dependent and would make prefill/decode
        # consistency checks nondeterministic; the full configs keep 1.25).
        updates["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, d_ff_expert=64, d_ff_dense=128, capacity_factor=4.0
        )
    if cfg.ssm is not None:
        updates["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16, chunk=16)
    if cfg.name == "mamba2-2.7b":
        updates["n_heads"] = 1
        updates["n_kv_heads"] = 1
    return dataclasses.replace(cfg, **updates)


__all__ = [
    "ArchBundle",
    "ModelConfig",
    "MoEConfig",
    "RetrievalConfig",
    "SHAPES",
    "ShapeConfig",
    "SSMConfig",
    "TrainConfig",
    "get_bundle",
    "list_archs",
    "reduced_model",
]
