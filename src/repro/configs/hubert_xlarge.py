"""hubert-xlarge [audio]: 48L encoder-only, GQA kv=16 (full MHA), vocab 504.

[arXiv:2106.07447] — same backbone as wav2vec2-XL. The conv waveform frontend
is a STUB: ``input_specs`` provides precomputed 512-dim frame embeddings (the
frontend_proj maps them into the 1280-dim residual stream). Training objective
is HuBERT's masked-prediction CE over the 504-unit codebook.

Deviations noted in DESIGN.md: conv positional embedding → RoPE
(bidirectional); encoder-only ⇒ decode_32k / long_500k cells skipped.
"""

from repro.configs.base import ArchBundle, ModelConfig, TrainConfig

MODEL = ModelConfig(
    name="hubert-xlarge",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    scan_unit=("attn",),
    causal=False,
    encoder_only=True,
    activation="gelu",
    frontend="audio",
    frontend_dim=512,
    tie_embeddings=False,
    param_dtype="float32",
)

BUNDLE = ArchBundle(
    arch_id="hubert-xlarge",
    model=MODEL,
    train=TrainConfig(),
    shape_skips={
        "decode_32k": "encoder-only architecture: no autoregressive decode step",
        "long_500k": "encoder-only architecture: no decode; 500k bidirectional encode not a defined cell",
    },
)
