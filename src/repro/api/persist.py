"""Self-describing index persistence for the ``repro.api`` facade.

Layout (one directory per index):

    <dir>/index.json               — format tag + the full IndexConfig
    <dir>/step_000000000/…         — array leaves via the production ckpt
                                     machinery (msgpack + zstd/zlib, atomic
                                     COMMIT protocol; see repro/ckpt)

``index.json`` makes checkpoints restorable from the directory *alone*:
``Index.load(dir)`` rebuilds the config from JSON and the pytree structure
from the config — no template tree, no separately-threaded ``IndexConfig``.
The array payload reuses ``repro.ckpt``'s committed-step protocol, so a
crash mid-save can never be loaded from.
"""

from __future__ import annotations

import json
import os

import jax.numpy as jnp

from repro import ckpt
from repro.core.hash_families import PrefixTables
from repro.core.index import ALSHIndex, IndexConfig
from repro.core.transforms import BoundedSpace

FORMAT = "repro.api.index"
VERSION = 1
_META = "index.json"


def config_to_dict(cfg: IndexConfig) -> dict:
    return {
        "d": cfg.d,
        "M": cfg.M,
        "K": cfg.K,
        "L": cfg.L,
        "family": cfg.family,
        "W": cfg.W,
        "max_candidates": cfg.max_candidates,
        "space": {"lo": cfg.space.lo, "hi": cfg.space.hi, "t": cfg.space.t},
    }


def config_from_dict(d: dict) -> IndexConfig:
    space = d["space"]
    return IndexConfig(
        d=d["d"],
        M=d["M"],
        K=d["K"],
        L=d["L"],
        family=d["family"],
        W=d["W"],
        max_candidates=d["max_candidates"],
        space=BoundedSpace(space["lo"], space["hi"], space["t"]),
    )


def _state_template() -> ALSHIndex:
    """Structure-only ALSHIndex (leaf values/shapes come from the payload)."""
    z = jnp.zeros((), jnp.float32)
    return ALSHIndex(
        tables=PrefixTables(folded=z, offsets=z),
        mixers=z,
        sorted_keys=z,
        perm=z,
        data=z,
        levels=z,
    )


def save_index(directory: str, state: ALSHIndex, build_key, cfg: IndexConfig) -> str:
    """Write a self-describing index directory.

    The array payload commits FIRST (ckpt COMMIT protocol), the meta file is
    atomically replaced LAST: a fresh directory that crashed mid-save has no
    ``index.json`` and is rejected by load. Overwriting an existing
    directory with a different geometry can still tear (old meta + new
    arrays, or vice versa through the ckpt step replacement) —
    ``load_index`` cross-checks the restored array shapes against the config
    to catch that."""
    os.makedirs(directory, exist_ok=True)
    ckpt.save_checkpoint(directory, 0, {"build_key": build_key, "state": state})
    meta = {"format": FORMAT, "version": VERSION, "config": config_to_dict(cfg)}
    tmp = os.path.join(directory, _META + ".tmp")
    with open(tmp, "w") as f:
        json.dump(meta, f, indent=2)
        f.write("\n")
    os.replace(tmp, os.path.join(directory, _META))
    return directory


def load_index(directory: str) -> tuple[ALSHIndex, "jnp.ndarray", IndexConfig]:
    """Restore (state, build_key, config) from a directory alone."""
    meta_path = os.path.join(directory, _META)
    if not os.path.exists(meta_path):
        raise FileNotFoundError(
            f"{directory!r} is not a repro.api index directory (no {_META}); "
            "was it written by Index.save()?"
        )
    with open(meta_path) as f:
        meta = json.load(f)
    if meta.get("format") != FORMAT:
        raise ValueError(
            f"{meta_path} has format {meta.get('format')!r}, expected {FORMAT!r}"
        )
    if meta.get("version") != VERSION:
        raise ValueError(
            f"{meta_path} is format version {meta.get('version')!r}; this build "
            f"reads version {VERSION} — migrate the directory or upgrade"
        )
    cfg = config_from_dict(meta["config"])
    step = ckpt.latest_step(directory)
    if step is None:
        raise FileNotFoundError(
            f"no committed checkpoint step under {directory!r} (aborted save?)"
        )
    # template leaves are placeholders — shapes/dtypes come from the payload
    tree = ckpt.restore_checkpoint(
        directory, step, {"build_key": jnp.zeros((), jnp.uint32), "state": _state_template()}
    )
    state = tree["state"]
    _check_consistent(state, cfg, meta_path)
    return state, tree["build_key"], cfg


def _check_consistent(state: ALSHIndex, cfg: IndexConfig, meta_path: str) -> None:
    """Reject directories whose meta and array payload disagree (e.g. a torn
    overwrite of an existing directory with a different geometry)."""
    n = state.data.shape[0]
    want = {
        "tables.folded": ((cfg.n_hashes, cfg.d, cfg.M + 1), state.tables.folded.shape),
        "tables.offsets": ((cfg.n_hashes,), state.tables.offsets.shape),
        "mixers": ((cfg.L, cfg.K), state.mixers.shape),
        "sorted_keys": ((cfg.L, n), state.sorted_keys.shape),
        "perm": ((cfg.L, n + cfg.max_candidates), state.perm.shape),
        "data": ((n, cfg.d), state.data.shape),
        "levels": ((n, cfg.d), state.levels.shape),
    }
    bad = {k: v for k, v in want.items() if tuple(v[1]) != v[0]}
    if bad:
        detail = "; ".join(f"{k}: stored {v[1]}, config implies {v[0]}" for k, v in bad.items())
        raise ValueError(
            f"{meta_path} does not describe the stored arrays ({detail}) — "
            "the directory was probably partially overwritten; re-save the index"
        )
