"""Self-describing index persistence for the ``repro.api`` facade.

Layout (one directory per index):

    <dir>/index.json               — format tag + IndexConfig + UpdateSpec
                                     + the segment manifest
    <dir>/step_000000000/…         — array leaves via the production ckpt
                                     machinery (msgpack + zstd/zlib, atomic
                                     COMMIT protocol; see repro/ckpt)

``index.json`` makes checkpoints restorable from the directory *alone*:
``Index.load(dir)`` rebuilds the config from JSON and the pytree structure
from the config — no template tree, no separately-threaded ``IndexConfig``.
The array payload reuses ``repro.ckpt``'s committed-step protocol, so a
crash mid-save can never be loaded from.

Format version 2 adds the MUTABLE lifecycle state: the manifest lists every
segment (sealed main rows; delta capacity + fill level) plus the tombstone
count, and the payload carries the delta arrays and tombstone bitmap — a
restored index resumes insert/delete/query exactly where it stopped, and a
re-shard re-derives identical hash tables (delta hashes included) from the
persisted build key. Version-1 directories (immutable, pre-lifecycle) still
load, as immutable indexes.

Format version 3 adds the PLAN memo: every resolved
``QualitySpec -> PlannedSpec`` pair is recorded in the manifest's ``plans``
list (pure JSON — no array payload change), so a restored index answers
QualitySpec queries without re-running the calibration pass, with the
exact same resolved parameters. Version-1/2 directories still load, with an
empty memo.

Format version 4 adds the TUNING provenance stamp: when any memoized plan
was resolved from an offline :mod:`repro.tuner` Pareto table
(``PlannedSpec.provenance == "prior"``), the manifest's ``tuning`` entry
records which table justified it (format/version/space_id/trial counts) —
a shipped index is auditable back to the scan that tuned it. Pure JSON, no
payload change; pre-v4 directories load with ``tuning=None``.

Format version 5 adds QUANTIZED storage: the config carries ``storage``
(the :mod:`repro.quant` row codec), the manifest's ``codec`` entry records
the payload dtype/bytes-per-value, and scaled codecs (int8) persist the
``(d,)`` decode-scale leaf inside the state payload. ``load_index``
cross-checks codec against the restored payload dtype and the scales leaf
shape, so a torn overwrite mixing codecs is a named error, never silently
garbled distances. Pre-v5 directories load as ``storage="f32"``.

All entry points accept ``str`` or ``pathlib.Path`` directories.
"""

from __future__ import annotations

import dataclasses
import json
import os

import jax.numpy as jnp
import numpy as np

from repro import ckpt
from repro.api.spec import PlannedSpec, QualitySpec, UpdateSpec
from repro.core.hash_families import PrefixTables
from repro.core.index import ALSHIndex, DeltaSegment, IndexConfig
from repro.core.transforms import BoundedSpace
from repro.quant import get_codec

FORMAT = "repro.api.index"
VERSION = 5
_READABLE_VERSIONS = (1, 2, 3, 4, 5)
_META = "index.json"


def config_to_dict(cfg: IndexConfig) -> dict:
    return {
        "d": cfg.d,
        "M": cfg.M,
        "K": cfg.K,
        "L": cfg.L,
        "family": cfg.family,
        "W": cfg.W,
        "max_candidates": cfg.max_candidates,
        "space": {"lo": cfg.space.lo, "hi": cfg.space.hi, "t": cfg.space.t},
        "storage": cfg.storage,
    }


def config_from_dict(d: dict) -> IndexConfig:
    space = d["space"]
    return IndexConfig(
        d=d["d"],
        M=d["M"],
        K=d["K"],
        L=d["L"],
        family=d["family"],
        W=d["W"],
        max_candidates=d["max_candidates"],
        space=BoundedSpace(space["lo"], space["hi"], space["t"]),
        storage=d.get("storage", "f32"),  # pre-v5 directories: full precision
    )


def update_to_dict(update: UpdateSpec) -> dict:
    return {
        "delta_capacity": update.delta_capacity,
        "compact_threshold": update.compact_threshold,
    }


def update_from_dict(d: dict) -> UpdateSpec:
    return UpdateSpec(
        delta_capacity=d["delta_capacity"],
        compact_threshold=d.get("compact_threshold", 0.75),
    )


def plans_to_list(plans: dict) -> list:
    """The v3 ``plans`` manifest entry: one {quality, planned} record per
    memoized resolution. Dataclass fields only — floats round-trip exactly
    through JSON, so a reloaded plan compares equal to the original."""
    return [
        {"quality": dataclasses.asdict(q), "planned": dataclasses.asdict(p)}
        for q, p in plans.items()
    ]


def plans_from_list(entries: list) -> dict:
    return {
        QualitySpec(**e["quality"]): PlannedSpec(**e["planned"]) for e in entries
    }


def _state_template(storage: str = "f32") -> ALSHIndex:
    """Structure-only ALSHIndex (leaf values/shapes come from the payload).
    Scaled codecs (int8) add the decode-scale leaf to the tree structure —
    the payload of a scaled save carries it, and the restore template must
    match leaf-for-leaf."""
    z = jnp.zeros((), jnp.float32)
    return ALSHIndex(
        tables=PrefixTables(folded=z, offsets=z),
        mixers=z,
        sorted_keys=z,
        perm=z,
        data=z,
        levels=z,
        scales=z if get_codec(storage).scaled else None,
    )


def _delta_template() -> DeltaSegment:
    z = jnp.zeros((), jnp.float32)
    return DeltaSegment(data=z, levels=z, keys=z, fill=z)


def save_index(
    directory: str | os.PathLike,
    state: ALSHIndex,
    build_key,
    cfg: IndexConfig,
    update: UpdateSpec = UpdateSpec(),
    delta: DeltaSegment | None = None,
    tombstones=None,
    plans: dict | None = None,
    tuning: dict | None = None,
) -> str:
    """Write a self-describing index directory (format version 4).

    The array payload commits FIRST (ckpt COMMIT protocol), the meta file is
    atomically replaced LAST: a fresh directory that crashed mid-save has no
    ``index.json`` and is rejected by load. Overwriting an existing
    directory with a different geometry can still tear (old meta + new
    arrays, or vice versa through the ckpt step replacement) —
    ``load_index`` cross-checks the restored array shapes against the config
    to catch that."""
    directory = os.fspath(directory)
    if delta is None:
        delta = DeltaSegment.empty(cfg, update.delta_capacity, dtype=state.data.dtype)
    if tombstones is None:
        tombstones = jnp.zeros((state.data.shape[0] + delta.capacity,), bool)
    os.makedirs(directory, exist_ok=True)
    ckpt.save_checkpoint(
        directory,
        0,
        {
            "build_key": build_key,
            "state": state,
            "delta": delta,
            "tombstones": tombstones,
        },
    )
    fill = int(delta.fill)
    codec = get_codec(cfg.storage)
    meta = {
        "format": FORMAT,
        "version": VERSION,
        "config": config_to_dict(cfg),
        "update": update_to_dict(update),
        "codec": {
            "storage": codec.name,
            "dtype": str(codec.dtype),
            "bytes_per_value": codec.bytes_per_value,
            "scaled": codec.scaled,
        },
        "segments": [
            {"kind": "main", "rows": int(state.data.shape[0]), "sealed": True},
            {
                "kind": "delta",
                "capacity": int(delta.capacity),
                "fill": fill,
                "sealed": False,
            },
        ],
        "tombstone_count": int(np.asarray(tombstones).sum()),
        "plans": plans_to_list(plans or {}),
        "tuning": tuning,
    }
    tmp = os.path.join(directory, _META + ".tmp")
    with open(tmp, "w") as f:
        json.dump(meta, f, indent=2)
        f.write("\n")
    os.replace(tmp, os.path.join(directory, _META))
    return directory


def load_index(
    directory: str | os.PathLike,
) -> tuple[
    ALSHIndex,
    "jnp.ndarray",
    IndexConfig,
    UpdateSpec,
    DeltaSegment,
    "jnp.ndarray",
    dict,
    dict | None,
]:
    """Restore (state, build_key, config, update, delta, tombstones, plans,
    tuning) from a directory alone. Version-1 directories restore as
    immutable indexes; pre-v3 directories restore with an empty plan memo;
    pre-v4 directories restore with no tuning provenance."""
    directory = os.fspath(directory)
    meta_path = os.path.join(directory, _META)
    if not os.path.exists(meta_path):
        raise FileNotFoundError(
            f"{directory!r} is not a repro.api index directory (no {_META}); "
            "was it written by Index.save()?"
        )
    with open(meta_path) as f:
        meta = json.load(f)
    if meta.get("format") != FORMAT:
        raise ValueError(
            f"{meta_path} has format {meta.get('format')!r}, expected {FORMAT!r}"
        )
    version = meta.get("version")
    if version not in _READABLE_VERSIONS:
        raise ValueError(
            f"{meta_path} is format version {version!r}; this build reads "
            f"versions {_READABLE_VERSIONS} — migrate the directory or upgrade"
        )
    cfg = config_from_dict(meta["config"])
    step = ckpt.latest_step(directory)
    if step is None:
        raise FileNotFoundError(
            f"no committed checkpoint step under {directory!r} (aborted save?)"
        )
    # template leaves are placeholders — shapes/dtypes come from the payload;
    # only the STRUCTURE (incl. the scaled codec's scales leaf) must match
    template = {
        "build_key": jnp.zeros((), jnp.uint32),
        "state": _state_template(cfg.storage),
    }
    if version >= 2:
        template["delta"] = _delta_template()
        template["tombstones"] = jnp.zeros((), bool)
    tree = ckpt.restore_checkpoint(directory, step, template)
    state = tree["state"]
    if version >= 2:
        update = update_from_dict(meta["update"])
        delta = tree["delta"]
        tombstones = tree["tombstones"]
    else:  # pre-lifecycle directory: immutable, no delta, nothing deleted
        update = UpdateSpec()
        delta = DeltaSegment.empty(cfg, 0, dtype=state.data.dtype)
        tombstones = jnp.zeros((state.data.shape[0],), bool)
    _check_consistent(state, delta, tombstones, cfg, update, meta, meta_path)
    plans = plans_from_list(meta.get("plans", [])) if version >= 3 else {}
    tuning = meta.get("tuning") if version >= 4 else None
    return state, tree["build_key"], cfg, update, delta, tombstones, plans, tuning


def _check_consistent(
    state: ALSHIndex,
    delta: DeltaSegment,
    tombstones,
    cfg: IndexConfig,
    update: UpdateSpec,
    meta: dict,
    meta_path: str,
) -> None:
    """Reject directories whose meta and array payload disagree (e.g. a torn
    overwrite of an existing directory with a different geometry or a
    different storage codec)."""
    n = state.data.shape[0]
    cap = delta.capacity
    codec = get_codec(cfg.storage)
    for leaf, dtype in (("data", state.data.dtype), ("delta.data", delta.data.dtype)):
        if jnp.dtype(dtype) != codec.dtype:
            raise ValueError(
                f"{meta_path} declares storage={cfg.storage!r} (payload dtype "
                f"{codec.dtype}) but the stored {leaf} array is {dtype} — the "
                f"directory mixes codecs (torn overwrite or hand-edited "
                f"manifest); re-save the index"
            )
    if codec.scaled:
        if state.scales is None or tuple(state.scales.shape) != (cfg.d,):
            got = None if state.scales is None else tuple(state.scales.shape)
            raise ValueError(
                f"{meta_path} declares the scaled codec {cfg.storage!r} but "
                f"the stored decode scales are {got} (need ({cfg.d},)) — "
                f"the scale leaf is missing or truncated; re-save the index"
            )
    elif state.scales is not None:
        raise ValueError(
            f"{meta_path} declares the unscaled codec {cfg.storage!r} but the "
            f"payload carries a decode-scale leaf — the directory mixes "
            f"codecs; re-save the index"
        )
    mcodec = meta.get("codec")
    if mcodec is not None and mcodec.get("storage") != cfg.storage:
        raise ValueError(
            f"{meta_path} codec entry says {mcodec.get('storage')!r} but the "
            f"config says storage={cfg.storage!r} — the manifest is "
            f"internally inconsistent; re-save the index"
        )
    want = {
        "tables.folded": ((cfg.n_hashes, cfg.d, cfg.M + 1), state.tables.folded.shape),
        "tables.offsets": ((cfg.n_hashes,), state.tables.offsets.shape),
        "mixers": ((cfg.L, cfg.K), state.mixers.shape),
        "sorted_keys": ((cfg.L, n), state.sorted_keys.shape),
        "perm": ((cfg.L, n + cfg.max_candidates), state.perm.shape),
        "data": ((n, cfg.d), state.data.shape),
        "levels": ((n, cfg.d), state.levels.shape),
        "delta.data": ((update.delta_capacity, cfg.d), delta.data.shape),
        "delta.levels": ((update.delta_capacity, cfg.d), delta.levels.shape),
        "delta.keys": ((cfg.L, update.delta_capacity), delta.keys.shape),
        "tombstones": ((n + cap,), tombstones.shape),
    }
    bad = {k: v for k, v in want.items() if tuple(v[1]) != v[0]}
    if bad:
        detail = "; ".join(f"{k}: stored {v[1]}, config implies {v[0]}" for k, v in bad.items())
        raise ValueError(
            f"{meta_path} does not describe the stored arrays ({detail}) — "
            "the directory was probably partially overwritten; re-save the index"
        )
    if meta.get("version", 1) >= 2:
        seg = {s["kind"]: s for s in meta.get("segments", [])}
        fill = int(delta.fill)
        mseg = seg.get("delta", {})
        if (
            mseg.get("capacity") != cap
            or not (0 <= fill <= cap)
            or mseg.get("fill") != fill
        ):
            raise ValueError(
                f"{meta_path} segment manifest disagrees with the stored delta "
                f"(manifest capacity/fill {mseg.get('capacity')}/{mseg.get('fill')}, "
                f"stored {cap}/{fill}) — the directory was probably partially "
                "overwritten; re-save the index"
            )
        if seg.get("main", {}).get("rows") != n:
            raise ValueError(
                f"{meta_path} segment manifest says {seg.get('main', {}).get('rows')} "
                f"main rows but the payload stores {n} — re-save the index"
            )
