"""The ``repro.api`` Index facade — one object, four behaviors.

The engine underneath (``repro.core``) is a pair: an ``ALSHIndex`` pytree of
arrays and an ``IndexConfig`` of static geometry, threaded separately
through every call. This module fuses them into a single config-carrying
:class:`Index` so consumers (serving, retrieval, examples, benchmarks)
never re-wire build/query/persist plumbing by hand:

    index = Index.build(key, data, cfg)
    res   = index.query(q, w, QuerySpec(k=10))                  # single-probe
    res   = index.query(q, w, QuerySpec(k=10, mode="multiprobe"))
    res   = index.query(q, w, QuerySpec(k=10, mode="exact"))    # oracle scan
    index.save(dir);  index = Index.load(dir)                   # dir alone
    sharded = index.shard(mesh); sharded.query(q, w, spec)      # cluster

``Index`` is a registered pytree whose *config rides in the static treedef*:
it crosses jit/vmap/shard_map boundaries like any array bundle, and two
indexes with different geometry can never be confused for one compiled
program. Query execution dispatches on :class:`~repro.api.spec.QuerySpec`
fields to the same jit'd engine entry points the legacy shims call, so
facade results are bit-identical to ``query_index``/``query_multiprobe``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.api.spec import QuerySpec
from repro.core.index import (
    ALSHIndex,
    IndexConfig,
    QueryResult,
    build_index,
    query_index,
)


def _as_key_data(key: jax.Array) -> jax.Array:
    """Normalize typed PRNG keys to raw uint32 key data (persistable)."""
    if jax.dtypes.issubdtype(key.dtype, jax.dtypes.prng_key):
        return jax.random.key_data(key)
    return key


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Index:
    """A built ALSH index that owns its static configuration.

    Attributes:
      state: the array bundle (tables, sorted keys, permutations, data).
      build_key: the PRNG key the tables were drawn from — persisted so a
        restored index can be re-sharded (shard-local rebuilds re-derive
        identical tables from it).
      config: static geometry; lives in the pytree treedef, not the leaves.
    """

    state: ALSHIndex
    build_key: jax.Array
    config: IndexConfig

    # -- pytree protocol (config is static aux data) ------------------------
    def tree_flatten(self):
        return (self.state, self.build_key), self.config

    @classmethod
    def tree_unflatten(cls, config, children):
        state, build_key = children
        return cls(state=state, build_key=build_key, config=config)

    # -- construction -------------------------------------------------------
    @classmethod
    def build(
        cls, key: jax.Array, data: jax.Array, config: IndexConfig, impl: str = "auto"
    ) -> "Index":
        """Hash every point and sort each table — Theorem 1 preprocessing."""
        key = _as_key_data(key)
        return cls(
            state=build_index(key, data, config, impl=impl),
            build_key=key,
            config=config,
        )

    @property
    def n(self) -> int:
        """Indexed database rows."""
        return self.state.n

    @property
    def d(self) -> int:
        return self.config.d

    # -- querying -----------------------------------------------------------
    def query(
        self, queries: jax.Array, weights: jax.Array, spec: QuerySpec = QuerySpec()
    ) -> QueryResult:
        """Batched k-NN under d_w^l1; ``spec`` picks the execution strategy.

        Args:
          queries: (b, d) float query points.
          weights: (b, d) per-query weight vectors (the paper's w — may be
            negative).
          spec: policy — exact | probe | multiprobe; see
            :class:`~repro.api.spec.QuerySpec`.
        """
        if spec.mode == "exact":
            from repro.kernels import ops

            dists, ids = ops.wl1_scan_topk(self.state.data, queries, weights, spec.k)
            n_candidates = jnp.full(queries.shape[0], self.n, jnp.int32)
            return QueryResult(dists=dists, ids=ids, n_candidates=n_candidates)
        if spec.mode == "multiprobe":
            from repro.core.multiprobe import query_multiprobe

            return query_multiprobe(
                self.state,
                queries,
                weights,
                self.config,
                k=spec.k,
                n_probes=spec.n_probes,
                max_flips=spec.max_flips,
            )
        return query_index(
            self.state, queries, weights, self.config, k=spec.k, impl=spec.impl
        )

    # -- persistence (self-describing) --------------------------------------
    def save(self, directory: str) -> str:
        """Write a directory restorable by ``Index.load(directory)`` alone."""
        from repro.api import persist

        return persist.save_index(directory, self.state, self.build_key, self.config)

    @classmethod
    def load(cls, directory: str) -> "Index":
        """Restore an index from a directory — config travels with the data."""
        from repro.api import persist

        state, build_key, cfg = persist.load_index(directory)
        return cls(state=state, build_key=build_key, config=cfg)

    # -- distribution -------------------------------------------------------
    def shard(self, mesh, merge_hierarchical: bool = True) -> "ShardedIndex":
        """Partition the database rows over ``mesh`` for cluster serving.

        Builds each shard's local index ONCE (tables re-derived from the
        persisted ``build_key``, so they match across shards and across
        save/load). Returns a :class:`ShardedIndex` whose ``query()`` runs
        shard-local probes, then a hierarchical top-k merge along the mesh
        axes (innermost first) — no per-query rebuild.
        """
        from repro.core.distributed import build_local_indexes

        index_sharded = build_local_indexes(
            self.build_key, self.state.data, self.config, mesh
        )
        return ShardedIndex(
            index_sharded=index_sharded,
            config=self.config,
            mesh=mesh,
            merge_hierarchical=merge_hierarchical,
        )


@dataclasses.dataclass
class ShardedIndex:
    """Row-sharded view of an :class:`Index` for the distributed service.

    Each device owns a disjoint row range with a complete prebuilt local
    index over it; hash tables are identical across shards, so query
    hashing is computed once and is valid everywhere. ``query()`` returns
    globally-merged results with global row ids.
    """

    index_sharded: ALSHIndex  # leaf layout per core.distributed.local_index_specs
    config: IndexConfig
    mesh: object
    merge_hierarchical: bool = True

    @property
    def n(self) -> int:
        return self.index_sharded.data.shape[0]

    def query(
        self, queries: jax.Array, weights: jax.Array, spec: QuerySpec = QuerySpec()
    ):
        """Same facade contract as ``Index.query`` — hierarchical-merge path."""
        from repro.core.distributed import sharded_index_query

        return sharded_index_query(
            self.index_sharded,
            queries,
            weights,
            self.config,
            self.mesh,
            spec=spec,
            merge_hierarchical=self.merge_hierarchical,
        )
