"""The ``repro.api`` Index facade — one object, full lifecycle.

The engine underneath (``repro.core``) is a pair: an ``ALSHIndex`` pytree of
arrays and an ``IndexConfig`` of static geometry, threaded separately
through every call. This module fuses them into a single config-carrying
:class:`Index` so consumers (serving, retrieval, examples, benchmarks)
never re-wire build/query/persist plumbing by hand:

    index = Index.build(key, data, cfg)
    res   = index.query(q, w, QuerySpec(k=10))                  # single-probe
    res   = index.query(q, w, QuerySpec(k=10, mode="multiprobe"))
    res   = index.query(q, w, QuerySpec(k=10, mode="exact"))    # oracle scan
    index.save(dir);  index = Index.load(dir)                   # dir alone
    sharded = index.shard(mesh); sharded.query(q, w, spec)      # cluster

Indexes built with ``UpdateSpec(delta_capacity=C)`` are MUTABLE — they
survive data churn without the O(H·d·n + L·n log n) rebuild:

    index = Index.build(key, data, cfg, update=UpdateSpec(delta_capacity=4096))
    index, ids = index.insert(new_rows)     # functional; ids are stable
    index = index.delete(ids[:16])          # tombstones, never re-sorts
    res = index.query(q, w, spec)           # two-segment probe, same contract
    if index.needs_compact: index = index.compact()   # the only sort

Memory model: the sealed main segment never changes; inserts land in a
fixed-capacity delta segment hashed with the SAME tables (so one set of
query keys is valid everywhere); deletes flip tombstone bits. Every shape
is static — insert/delete/query reuse one compiled program across the
index's whole life at a given capacity.

``Index`` is a registered pytree whose *config and update policy ride in
the static treedef*: it crosses jit/vmap/shard_map boundaries like any
array bundle, and two indexes with different geometry can never be confused
for one compiled program. Query execution dispatches on
:class:`~repro.api.spec.QuerySpec` fields to the same jit'd engine entry
points the legacy shims call, so facade results are bit-identical to
``query_index``/``query_multiprobe`` (and a mutable index's results are
bit-identical to a fresh build over its surviving rows — see
tests/test_lifecycle.py).
"""

from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.api.spec import PlannedSpec, QualitySpec, QuerySpec, UpdateSpec
from repro.core.families import n_flip_subsets
from repro.core.index import (
    ALSHIndex,
    DeltaSegment,
    IndexConfig,
    QueryResult,
    build_index,
    delta_insert,
    tombstone_ids,
)


def _as_key_data(key: jax.Array) -> jax.Array:
    """Normalize typed PRNG keys to raw uint32 key data (persistable)."""
    if jax.dtypes.issubdtype(key.dtype, jax.dtypes.prng_key):
        return jax.random.key_data(key)
    return key


def validate_query_args(d: int, queries: jax.Array, weights: jax.Array) -> None:
    """Shape/batch/value validation shared by BOTH query facades
    (``Index.query`` and ``ShardedIndex.query``): malformed ``(queries,
    weights)`` raise a ValueError naming the offending argument instead of
    surfacing as a trace error deep inside jit/shard_map, and NON-FINITE
    rows (NaN/Inf) raise a ValueError naming the offending row indices
    instead of silently poisoning every distance in the rerank tail (a NaN
    query compares false against every candidate, so the top-k would return
    sentinel garbage with no hint why). The finiteness scan is skipped for
    tracers — inside jit the caller has already validated the concrete
    arrays at the boundary."""
    for name, arr in (("queries", queries), ("weights", weights)):
        if arr.ndim != 2 or arr.shape[-1] != d:
            raise ValueError(
                f"{name} must be (b, d) with trailing dim config.d={d}; "
                f"got {name}.shape={tuple(arr.shape)}"
            )
    if tuple(queries.shape[:-1]) != tuple(weights.shape[:-1]):
        raise ValueError(
            f"queries and weights batch dims disagree: "
            f"queries.shape={tuple(queries.shape)} vs "
            f"weights.shape={tuple(weights.shape)}"
        )
    for name, arr in (("queries", queries), ("weights", weights)):
        if isinstance(arr, jax.core.Tracer):
            continue
        finite_rows = np.isfinite(np.asarray(arr)).all(axis=1)
        if not finite_rows.all():
            bad = np.nonzero(~finite_rows)[0]
            head = ", ".join(map(str, bad[:8])) + (", …" if bad.size > 8 else "")
            raise ValueError(
                f"{name} contains non-finite values (NaN/Inf) in "
                f"{bad.size} of {finite_rows.size} rows [{head}] — "
                f"non-finite {name} would silently produce NaN distances "
                f"through the rerank tail; filter or clamp them first"
            )


def _check_probe_reach(cfg: IndexConfig, spec: QuerySpec) -> None:
    """Reject multiprobe specs asking for more probes than the (K,
    max_flips) perturbation enumeration can reach — beyond that count every
    extra probe re-probes a duplicate bucket and buys nothing. Applied by
    BOTH the single-host and the sharded query facade."""
    if spec.mode != "multiprobe":
        return
    cap = n_flip_subsets(cfg.K, spec.max_flips)
    if spec.n_probes > cap:
        raise ValueError(
            f"QuerySpec.n_probes={spec.n_probes} exceeds the "
            f"{cap} distinct probe keys reachable with K={cfg.K} "
            f"hash bits and max_flips={spec.max_flips} — extra probes "
            f"would silently hit duplicate buckets; lower n_probes or "
            f"raise max_flips"
        )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Index:
    """A built ALSH index that owns its static configuration and lifecycle.

    Attributes:
      state: the sealed main segment (tables, sorted keys, permutations,
        data) — never mutated after build; only ``compact()`` replaces it.
      build_key: the PRNG key the tables were drawn from — persisted so a
        restored index can be re-sharded (shard-local rebuilds re-derive
        identical tables from it, including the delta-row hashes).
      config: static geometry; lives in the pytree treedef, not the leaves.
      update: static mutability policy (delta capacity); also in the treedef.
      delta: fixed-capacity unsealed segment holding post-build inserts
        (empty, capacity 0, for immutable indexes).
      tombstones: (n_main + capacity,) bool — True marks a deleted row in
        either segment.

    Row ids are stable across mutation: main rows keep their build ids
    ``[0, n_main)``; the i-th inserted row gets id ``n_main + i`` (also
    under sharding). Only ``compact()`` renumbers — ``live_ids()`` gives
    the old-id-per-new-id mapping of the compaction that is about to
    happen (or just happened, from the pre-compact index).
    """

    state: ALSHIndex
    build_key: jax.Array
    config: IndexConfig
    update: UpdateSpec = UpdateSpec()
    delta: DeltaSegment | None = None
    tombstones: jax.Array | None = None
    # memoized QualitySpec -> PlannedSpec resolutions; static metadata (rides
    # the treedef, persists in the v3 manifest, copies through shard())
    plans: dict = dataclasses.field(default_factory=dict, compare=False)
    # memoized QualitySpec -> degradation-ladder resolutions (tuple of
    # PlannedSpec, richest first). Host-side serving metadata only: it does
    # NOT ride the treedef or the manifest — a jit/shard_map crossing or a
    # save/load drops it, and plan_ladder() re-derives it deterministically
    ladders: dict = dataclasses.field(default_factory=dict, compare=False)
    # wall seconds each QualitySpec resolution cost on THIS process (audit
    # metadata for explain/benchmarks). Host-side only: wall clocks must
    # never ride the treedef (they would fracture the jit cache) or the
    # manifest (plans are bit-reproducible, their timings are not)
    plan_times: dict = dataclasses.field(default_factory=dict, compare=False)
    # provenance stamp of the offline tuning table that backed a
    # prior-based plan (repro.tuner TuningTable.provenance()). None until a
    # table-backed planner resolves a plan here; persisted in the v4
    # manifest so shipped indexes carry their tuning lineage
    tuning: dict | None = dataclasses.field(default=None, compare=False)

    def __post_init__(self):
        # Synthesize empty mutation state when constructed without it (the
        # common case for immutable indexes and shard-local facades).
        if self.delta is None:
            self.delta = DeltaSegment.empty(
                self.config, self.update.delta_capacity, dtype=self.state.data.dtype
            )
        if self.tombstones is None:
            self.tombstones = jnp.zeros(
                (self.state.data.shape[0] + self.delta.capacity,), bool
            )

    # -- pytree protocol (config + update policy are static aux data; the
    # plan memo rides along as a hashable tuple so QualitySpec queries keep
    # resolving AFTER a jit/shard_map crossing) ------------------------------
    def tree_flatten(self):
        return (
            (self.state, self.build_key, self.delta, self.tombstones),
            (self.config, self.update, tuple(self.plans.items())),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        state, build_key, delta, tombstones = children
        config, update, plans = aux
        return cls(
            state=state,
            build_key=build_key,
            config=config,
            update=update,
            delta=delta,
            tombstones=tombstones,
            plans=dict(plans),
        )

    # -- construction -------------------------------------------------------
    @classmethod
    def build(
        cls,
        key: jax.Array,
        data: jax.Array,
        config: "IndexConfig | QualitySpec",
        impl: str = "auto",
        update: UpdateSpec = UpdateSpec(),
        family: str = "auto",
        M: int = 32,
        planner=None,
    ) -> "Index":
        """Hash every point and sort each table — Theorem 1 preprocessing.

        ``config`` is either an explicit :class:`IndexConfig` (the classic
        knob path, unchanged) or a :class:`QualitySpec` — then the geometry
        (family, K, L, W, max_candidates, space) is DERIVED from theory
        plus a data sample by :class:`repro.api.planner.Planner`, the
        execution plan is calibrated and memoized immediately, and when
        even the best calibrated plan misses ``recall_target`` the table
        count is escalated (L doubled, bounded by the planner's caps) and
        the build retried — theory proposes, measurement disposes. All of
        it is deterministic given (data, quality.seed);
        ``family``/``M``/``planner`` tune the derivation and are ignored on
        the explicit path. ``update=UpdateSpec(delta_capacity=C)`` reserves
        C delta slots and makes the index mutable (``insert``/``delete``/
        ``compact``).
        """
        key = _as_key_data(key)
        if not isinstance(config, QualitySpec):
            return cls(
                state=build_index(key, data, config, impl=impl),
                build_key=key,
                config=config,
                update=update,
            )

        import time as _time
        import warnings

        from repro.api.planner import Planner

        quality = config
        planner = planner or Planner()
        cfg = planner.plan_config(data, quality, family=family, M=M)
        last_round = 2  # escalation attempts: L x2 each, then accept best
        for attempt in range(last_round + 1):
            index = cls(
                state=build_index(key, data, cfg, impl=impl),
                build_key=key,
                config=cfg,
                update=update,
            )
            at_cap = cfg.L >= planner.max_L
            with warnings.catch_warnings(record=True) as caught:
                warnings.simplefilter("always")
                t0 = _time.perf_counter()
                planned = planner.plan_query(index, quality)
                index._record_plan(
                    quality, planned, planner, _time.perf_counter() - t0
                )
            if planned.predicted_recall >= quality.recall_target - 1e-9 or (
                attempt == last_round or at_cap
            ):
                # this attempt's plan is the one the caller gets — its
                # warnings (budget exceeded, target unreachable) are real
                for w in caught:
                    warnings.warn(w.message, w.category, stacklevel=2)
                return index
            # recall miss with escalation headroom: the rebuild supersedes
            # this attempt's warnings, so drop them
            cfg = dataclasses.replace(cfg, L=min(2 * cfg.L, planner.max_L))
        return index

    @property
    def n(self) -> int:
        """Main-segment (sealed) rows."""
        return self.state.n

    @property
    def d(self) -> int:
        return self.config.d

    @property
    def mutable(self) -> bool:
        return self.update.mutable

    @property
    def capacity(self) -> int:
        """Total addressable rows: main + delta slots."""
        return self.state.n + self.delta.capacity

    @property
    def table_bytes(self) -> int:
        """Resident bytes of the row tables (main payload + delta payload +
        decode scales) — the memory the storage codec is compressing. Hash
        tables/permutations are excluded: they are storage-invariant."""
        total = self.state.data.nbytes + self.delta.data.nbytes
        if self.state.scales is not None:
            total += self.state.scales.nbytes
        return int(total)

    @property
    def delta_fill(self) -> int:
        """Delta slots used (device sync — don't poll inside jit)."""
        return int(self.delta.fill)

    @property
    def n_live(self) -> int:
        """Surviving rows: filled, not tombstoned (device sync)."""
        return int(self.live_ids().size)

    @property
    def needs_compact(self) -> bool:
        """Advisory: delta fill crossed ``update.compact_threshold``."""
        cap = self.delta.capacity
        if cap == 0:
            return False
        return self.delta_fill >= self.update.compact_threshold * cap

    # -- querying -----------------------------------------------------------
    def _validate_query_args(self, queries: jax.Array, weights: jax.Array) -> None:
        validate_query_args(self.config.d, queries, weights)

    def resolve(self, spec) -> tuple[QuerySpec, IndexConfig, "PlannedSpec | None"]:
        """Normalize any spec kind to (mechanism QuerySpec, effective
        config, resolved PlannedSpec-or-None). QualitySpecs go through the
        memoized planner; PlannedSpecs apply their candidate window to the
        config. The same resolution backs ``query`` and ``explain`` — which
        is what makes ``query(q, w, quality)`` bit-identical to
        ``query(q, w, index.plan(quality))``."""
        if isinstance(spec, QualitySpec):
            spec = self.plan(spec)
        if isinstance(spec, PlannedSpec):
            return spec.to_query_spec(), spec.effective_config(self.config), spec
        if not isinstance(spec, QuerySpec):
            raise TypeError(
                f"spec must be a QuerySpec, QualitySpec, or PlannedSpec; "
                f"got {type(spec).__name__}"
            )
        return spec, self.config, None

    def plan(self, quality: QualitySpec, planner=None) -> PlannedSpec:
        """Resolve ``quality`` to a concrete :class:`PlannedSpec`, memoized
        on this index (and on every index derived from it by insert/delete —
        they share the memo; ``compact``/fresh builds re-plan).

        Planning is deterministic given (index, ``quality.seed``): a
        calibration sample is drawn from the build key, the plan ladder is
        executed on it, and the cheapest plan meeting
        ``quality.recall_target`` wins. The resolved plan rides the pytree
        treedef, persists through ``save``/``load`` (v3 manifest), and
        copies into ``shard()``-ed service handles.
        """
        planned = self.plans.get(quality)
        if planned is None:
            import time

            if planner is None:
                from repro.api.planner import Planner

                planner = Planner()
            t0 = time.perf_counter()
            planned = planner.plan_query(self, quality)
            self._record_plan(quality, planned, planner, time.perf_counter() - t0)
        return planned

    def _record_plan(self, quality, planned, planner, elapsed: float) -> None:
        """Memoize a resolution + its audit metadata: wall time (host-side,
        surfaces as ``QueryReport.plan_build_s``) and — for prior-based
        plans — the provenance stamp of the tuning table that shipped it."""
        self.plans[quality] = planned
        self.plan_times[quality] = elapsed
        if planned.provenance == "prior" and getattr(planner, "table", None) is not None:
            self.tuning = planner.table.provenance()

    def plan_ladder(self, quality: QualitySpec, planner=None) -> tuple:
        """Resolve ``quality`` to the full DEGRADATION ladder (memoized):
        a tuple of :class:`PlannedSpec` rungs, rung 0 being exactly what
        ``plan(quality)`` returns (the contract-meeting operating point) and
        every later rung strictly cheaper — fewer probes, then single-probe,
        then shrinking candidate windows. Each rung carries its calibrated
        ``predicted_recall``/``predicted_success``, which is what lets a
        serving broker under SLO pressure step down the ladder and LABEL
        each degraded response with the recall it traded away (see
        :mod:`repro.serving`). One calibration pass scores every rung, and
        the rung-0 resolution seeds the ``plans`` memo, so
        ``plan_ladder`` + ``query(quality)`` costs one calibration total."""
        ladder = self.ladders.get(quality)
        if ladder is None:
            if planner is None:
                from repro.api.planner import Planner

                planner = Planner()
            ladder = planner.plan_ladder(self, quality)
            self.ladders[quality] = ladder
            self.plans.setdefault(quality, ladder[0])
        return ladder

    def query(self, queries: jax.Array, weights: jax.Array, spec=QuerySpec()) -> QueryResult:
        """Batched k-NN under d_w^l1; ``spec`` picks the execution strategy.

        Args:
          queries: (b, d) float query points.
          weights: (b, d) per-query weight vectors (the paper's w — may be
            negative).
          spec: policy — a mechanism :class:`QuerySpec` (exact | probe |
            multiprobe), a resolved :class:`PlannedSpec`, or a declarative
            :class:`QualitySpec` (planned on first use, memoized after).

        Every mode runs the one :mod:`repro.engine` pipeline — a mutable
        index adds the delta key-match source and the tombstone mask to the
        sealed-table window source; an immutable index probes the sealed
        source alone (bit-identical to the legacy shims, which wrap the
        same engine). Invalid result slots are ``ids == -1`` /
        ``dists == +inf`` in every mode.
        """
        self._validate_query_args(queries, weights)
        qspec, cfg, _ = self.resolve(spec)
        _check_probe_reach(cfg, qspec)
        return engine.query(
            self.state,
            self.delta if self.mutable else None,
            self.tombstones if self.mutable else None,
            queries,
            weights,
            cfg,
            k=qspec.k,
            mode=qspec.mode,
            n_probes=qspec.n_probes,
            max_flips=qspec.max_flips,
            impl=qspec.impl,
            screen_alpha=qspec.screen_alpha,
            early_exit=qspec.early_exit,
            exit_group=qspec.exit_group,
            exit_slack=qspec.exit_slack,
        )

    def explain(self, queries: jax.Array, weights: jax.Array, spec=QuerySpec()):
        """Run ``query`` and return a :class:`~repro.api.planner.QueryReport`
        wrapping the result with per-query diagnostics: the resolved
        parameters, the Thm 1 success probability predicted from Eq 25/27
        at each query's own weight vector, candidate counts, and
        truncation/sentinel flags. The answer arrays are bit-identical to a
        plain ``query`` with the same spec — explain only adds the probe
        bookkeeping (an extra pass over the sorted keys, host-side).
        """
        from repro.api.planner import QueryReport
        from repro.core import theory
        from repro.core.index import query_keys_for, table_window_sizes

        self._validate_query_args(queries, weights)
        quality = spec if isinstance(spec, QualitySpec) else None
        qspec, cfg, planned = self.resolve(spec)
        res = self.query(queries, weights, planned if planned is not None else qspec)

        b = queries.shape[0]
        if qspec.mode == "exact":
            truncated = np.zeros((b,), np.int32)
        else:
            if qspec.mode == "multiprobe":
                from repro.core.multiprobe import multiprobe_keys_for

                keys = multiprobe_keys_for(
                    self.state, queries, weights, cfg,
                    qspec.n_probes, qspec.max_flips,
                )  # (b, L, P)
            else:
                keys = query_keys_for(self.state, queries, weights, cfg)  # (b, L)
            wins = table_window_sizes(self.state.sorted_keys, keys)
            over = wins > cfg.max_candidates
            truncated = np.asarray(
                jnp.sum(over.reshape(b, -1), axis=1), dtype=np.int32
            )

        # Thm 1 success bound per query at its OWN w and observed top-1 r
        # (result distances are raw-unit; Eq 25/27 want lattice units — x t)
        top1 = res.dists[:, 0]
        valid1 = jnp.isfinite(top1)
        r1 = jnp.where(valid1, top1, 0.0) * cfg.space.t
        if cfg.family == "l2":
            p1 = theory.collision_prob_l2(r1, cfg.M, cfg.d, weights, cfg.W)
        else:
            p1 = theory.collision_prob_theta(r1, cfg.M, cfg.d, weights)
        p1 = jnp.clip(p1, 1e-12, 1.0 - 1e-12)
        success = jnp.where(valid1, 1.0 - (1.0 - p1**cfg.K) ** cfg.L, 0.0)

        # storage-tier accounting: what the fused tail actually moved.
        # Screening gathers every unique candidate once at the ENCODED row
        # width; the exact rerank then re-gathers only the survivors (all
        # candidates when the screen is statically off).
        from repro import quant

        n_cand = np.asarray(res.n_candidates, dtype=np.int64)
        row_bytes = self.state.data.dtype.itemsize * cfg.d
        screening = (
            qspec.mode != "exact" and self.state.data.dtype != jnp.float32
        )
        if screening:
            p_slots = qspec.n_probes if qspec.mode == "multiprobe" else 1
            n_slots = cfg.L * p_slots * cfg.max_candidates + (
                self.delta.capacity if self.mutable else 0
            )
            keep = quant.screen_keep(qspec.k, qspec.screen_alpha, n_slots)
        else:
            keep = 0
        rows_screened = n_cand if keep else np.zeros_like(n_cand)
        rows_reranked = np.minimum(n_cand, keep) if keep else n_cand
        bytes_gathered = (rows_screened + rows_reranked) * row_bytes

        return QueryReport(
            spec=planned if planned is not None else qspec,
            quality=quality,
            result=res,
            predicted_success=np.asarray(success),
            n_candidates=np.asarray(res.n_candidates),
            truncated_tables=truncated,
            n_invalid=np.asarray(jnp.sum(res.ids < 0, axis=1), dtype=np.int32),
            provenance=planned.provenance if planned is not None else None,
            plan_build_s=(
                self.plan_times.get(quality) if quality is not None else None
            ),
            storage=self.config.storage,
            rows_screened=rows_screened,
            rows_reranked=rows_reranked,
            bytes_gathered=bytes_gathered,
            table_bytes=self.table_bytes,
            tables_probed=(
                np.asarray(res.tables_probed, dtype=np.int32)
                if res.tables_probed is not None else None
            ),
            stop_reason=(
                np.asarray(res.stop_reason, dtype=np.int32)
                if res.stop_reason is not None else None
            ),
        )

    # -- mutation (functional: every method returns a new Index) ------------
    def _require_mutable(self, op: str) -> None:
        if not self.mutable:
            raise ValueError(
                f"Index.{op}() requires a mutable index — build with "
                f"update=UpdateSpec(delta_capacity=...) (this index was built "
                f"with delta_capacity=0)"
            )

    def insert(self, rows: jax.Array) -> tuple["Index", jax.Array]:
        """Append rows to the delta segment.

        Args:
          rows: (m, d) new data points (hashed with the index's own tables).

        Returns:
          (new index, (m,) int32 assigned ids). Ids are stable until the
          next ``compact()``; ``-1`` marks rows that did not fit (delta at
          capacity — compact and retry). jit/vmap-safe, no retrace across
          fill levels.
        """
        self._require_mutable("insert")
        if rows.ndim != 2 or rows.shape[-1] != self.config.d:
            raise ValueError(
                f"insert rows must be (m, d) with trailing dim "
                f"config.d={self.config.d}; got rows.shape={tuple(rows.shape)}"
            )
        delta, ids = delta_insert(self.state, self.delta, rows, self.config)
        return dataclasses.replace(self, delta=delta), ids

    def delete(self, ids: jax.Array) -> "Index":
        """Tombstone rows by id (either segment). Unknown ids — negative or
        not yet assigned by any insert — are ignored; deleted ids never
        appear in query results. Functional and jit-safe; space is
        reclaimed by ``compact()``."""
        self._require_mutable("delete")
        ts = tombstone_ids(
            self.tombstones, jnp.asarray(ids), self.state.n, self.delta.fill
        )
        return dataclasses.replace(self, tombstones=ts)

    def live_ids(self):
        """(n_live,) int64 numpy array: surviving row ids in compaction
        order — ``live_ids()[new_id] == old_id`` after ``compact()``."""
        tomb = np.asarray(self.tombstones)
        n_main = self.state.n
        fill = int(self.delta.fill)
        main_keep = np.nonzero(~tomb[:n_main])[0]
        delta_keep = n_main + np.nonzero(~tomb[n_main : n_main + fill])[0]
        return np.concatenate([main_keep, delta_keep])

    def compact(self) -> "Index":
        """Merge delta + surviving main rows into a fresh sealed segment.

        The ONLY lifecycle operation that sorts. Hashes are NOT recomputed:
        main-row keys are recovered by inverting each table's permutation
        and delta-row keys were computed at insert time — the merge is a
        gather + L argsorts, bit-identical to ``Index.build`` over the
        surviving rows (same ``build_key``). Returns a new index with an
        empty delta and a clear tombstone bitmap; ids are renumbered per
        ``live_ids()``. Host-side (dynamic output shape) — do not call
        under jit.
        """
        self._require_mutable("compact")
        state, cfg = self.state, self.config
        n_main = state.n
        fill = int(self.delta.fill)
        tomb = np.asarray(self.tombstones)
        main_keep = jnp.asarray(np.nonzero(~tomb[:n_main])[0], jnp.int32)
        delta_keep = jnp.asarray(
            np.nonzero(~tomb[n_main : n_main + fill])[0], jnp.int32
        )

        # recover per-table keys of main rows at their original positions by
        # inverting the sort: keys[l, perm[l, i]] = sorted_keys[l, i]
        perm = state.perm[:, :n_main]
        keys_main = jnp.zeros((cfg.L, n_main), jnp.int32)
        keys_main = keys_main.at[
            jnp.arange(cfg.L, dtype=jnp.int32)[:, None], perm
        ].set(state.sorted_keys)

        # survivors are decoded to f32 and RE-ENCODED as a fresh segment —
        # int8 scales are refit to the surviving rows (the delta rows were
        # saturating against the OLD segment's range; the new sealed segment
        # gets its own). f32 storage: decode and encode are both the
        # identity, bit-identical to concatenating the raw arrays.
        from repro import quant
        from repro.core.index import get_codec

        data = jnp.concatenate(
            [
                quant.decode_table(state.data[main_keep], state.scales),
                quant.decode_table(
                    self.delta.data[delta_keep].astype(state.data.dtype),
                    state.scales,
                ),
            ]
        )
        levels = jnp.concatenate(
            [state.levels[main_keep], self.delta.levels[delta_keep]]
        )
        keys_ln = jnp.concatenate(
            [keys_main[:, main_keep], self.delta.keys[:, delta_keep]], axis=1
        )

        # the sort — identical to build_index's tail over the survivor rows
        n_new = data.shape[0]
        perm_new = jnp.argsort(keys_ln, axis=1).astype(jnp.int32)
        sorted_keys = jnp.take_along_axis(keys_ln, perm_new, axis=1)
        pad = jnp.full((cfg.L, cfg.max_candidates), n_new, dtype=jnp.int32)
        perm_new = jnp.concatenate([perm_new, pad], axis=1)
        payload, scales = get_codec(cfg.storage).encode(data)
        new_state = ALSHIndex(
            tables=state.tables,
            mixers=state.mixers,
            sorted_keys=sorted_keys,
            perm=perm_new,
            data=payload,
            levels=levels,
            scales=scales,
        )
        return Index(
            state=new_state,
            build_key=self.build_key,
            config=cfg,
            update=self.update,
        )

    # -- persistence (self-describing) --------------------------------------
    def save(self, directory: str | os.PathLike) -> str:
        """Write a directory restorable by ``Index.load(directory)`` alone.

        The manifest records every segment (main rows, delta capacity/fill,
        tombstone count) plus the resolved query plans, so a restored
        mutable index resumes its lifecycle — and its memoized planning —
        exactly where it stopped."""
        from repro.api import persist

        return persist.save_index(
            directory,
            self.state,
            self.build_key,
            self.config,
            update=self.update,
            delta=self.delta,
            tombstones=self.tombstones,
            plans=self.plans,
            tuning=self.tuning,
        )

    @classmethod
    def load(cls, directory: str | os.PathLike) -> "Index":
        """Restore an index from a directory — config, update policy,
        segment state, and resolved query plans all travel with the data."""
        from repro.api import persist

        state, build_key, cfg, update, delta, tombstones, plans, tuning = (
            persist.load_index(directory)
        )
        return cls(
            state=state,
            build_key=build_key,
            config=cfg,
            update=update,
            delta=delta,
            tombstones=tombstones,
            plans=plans,
            tuning=tuning,
        )

    # -- distribution -------------------------------------------------------
    def shard(self, mesh, merge_hierarchical: bool = True) -> "ShardedIndex":
        """Partition the database rows over ``mesh`` for cluster serving.

        Builds each shard's local index ONCE (tables re-derived from the
        persisted ``build_key``, so they match across shards and across
        save/load). A mutable index replays its delta rows through the
        sharded insert path — the same tables re-hash them to identical
        keys, ids are preserved (``n_main + i`` for the i-th insert), and
        tombstones carry over. Each shard gets its own
        ``update.delta_capacity``-slot delta. Returns a
        :class:`ShardedIndex` with the same query/insert/delete surface.
        """
        from repro.core.distributed import build_local_indexes, make_sharded_delta

        if self.config.storage != "f32":
            raise ValueError(
                f"Index.shard() supports storage='f32' only (this index was "
                f"built with storage={self.config.storage!r}) — the mesh path "
                f"re-discretizes raw rows per shard, and per-shard re-encoding "
                f"would drift the quantization grid away from the single-host "
                f"index it must answer bit-identically to. Use the host-side "
                f"serving shard set (repro.serving.chaos.ShardSet), which "
                f"re-encodes each shard self-consistently, or build with "
                f"storage='f32' before sharding"
            )
        S = mesh.devices.size
        if self.mutable and self.update.delta_capacity % S:
            raise ValueError(
                f"UpdateSpec.delta_capacity={self.update.delta_capacity} must "
                f"be a multiple of the mesh size ({S} devices) — each shard "
                f"owns an equal slice of the delta segment"
            )
        index_sharded = build_local_indexes(
            self.build_key, self.state.data, self.config, mesh
        )
        sharded = ShardedIndex(
            index_sharded=index_sharded,
            config=self.config,
            mesh=mesh,
            merge_hierarchical=merge_hierarchical,
            update=self.update,
            build_key=self.build_key,
            plans=dict(self.plans),
        )
        if self.mutable:
            sharded.delta_sharded, sharded.tombstones_sharded = make_sharded_delta(
                self.config,
                mesh,
                self.update.delta_capacity // S,
                self.state.data.dtype,
                n_local=self.state.n // S,
            )
            fill = self.delta_fill
            if fill:
                sharded, _ = sharded.insert(self.delta.data[:fill])
            gids = np.nonzero(np.asarray(self.tombstones))[0]
            if gids.size:
                sharded = sharded.delete(jnp.asarray(gids, jnp.int32))
        return sharded


@dataclasses.dataclass
class ShardedIndex:
    """Row-sharded view of an :class:`Index` for the distributed service.

    Each device owns a disjoint row range with a complete prebuilt local
    index over it; hash tables are identical across shards, so query
    hashing is computed once and is valid everywhere. ``query()`` returns
    globally-merged results with global row ids.

    Mutable lifecycles shard too: every device owns a private
    ``update.delta_capacity / n_shards``-slot delta slice, inserts are
    routed round-robin by global id (``gid % shards`` picks the owner),
    deletes tombstone on whichever shard owns the id, and the global id
    scheme matches the single-host :class:`Index` exactly (main row i ↔
    gid i; i-th inserted row ↔ gid n_main + i) — so a sharded and a
    single-host index fed the same update stream return the SAME ids.
    """

    index_sharded: ALSHIndex  # leaf layout per core.distributed.local_index_specs
    config: IndexConfig
    mesh: object
    merge_hierarchical: bool = True
    update: UpdateSpec = UpdateSpec()
    build_key: jax.Array | None = None
    delta_sharded: DeltaSegment | None = None  # leaf layout per local_delta_specs
    tombstones_sharded: jax.Array | None = None  # (S·(n_local+cap),) shard-major
    plans: dict = dataclasses.field(default_factory=dict)  # from the source Index

    @property
    def n(self) -> int:
        return self.index_sharded.data.shape[0]

    @property
    def n_shards(self) -> int:
        return self.mesh.devices.size

    @property
    def mutable(self) -> bool:
        return self.update.mutable and self.delta_sharded is not None

    @property
    def _cap_local(self) -> int:
        """Delta slots per shard (delta_capacity is the index-wide total)."""
        return self.update.delta_capacity // self.n_shards

    @property
    def delta_fill(self) -> int:
        """Total delta slots used across shards (device sync)."""
        if self.delta_sharded is None:
            return 0
        return int(jnp.sum(self.delta_sharded.fill))

    @property
    def needs_compact(self) -> bool:
        """Advisory: ANY shard's delta slice crossed the compact threshold
        (that shard starts dropping inserts first — see ``insert``)."""
        if self.delta_sharded is None:
            return False
        fills = np.asarray(self.delta_sharded.fill)
        return bool((fills >= self.update.compact_threshold * self._cap_local).any())

    def query(self, queries: jax.Array, weights: jax.Array, spec=QuerySpec()):
        """Same facade contract as ``Index.query`` — hierarchical-merge path,
        including the same argument validation (malformed ``(queries,
        weights)`` raise the named ValueError, never a shard_map trace
        error). Each shard runs the shared :mod:`repro.engine` pipeline
        over its slice; the hierarchical top-k merge composes the results.

        QualitySpecs resolve against the plan memo the source ``Index``
        carried into ``shard()`` (calibration needs the single-host view, so
        an UNPLANNED QualitySpec is rejected here with the fix spelled out).
        """
        from repro.core.distributed import sharded_index_query

        cfg = self.config
        validate_query_args(cfg.d, queries, weights)
        if isinstance(spec, QualitySpec):
            planned = self.plans.get(spec)
            if planned is None:
                raise ValueError(
                    "ShardedIndex cannot calibrate a new QualitySpec (planning "
                    "needs the single-host index) — call index.plan(quality) "
                    "BEFORE index.shard(mesh), or pass the resolved "
                    "PlannedSpec/QuerySpec explicitly"
                )
            spec = planned
        if isinstance(spec, PlannedSpec):
            cfg = spec.effective_config(cfg)
            spec = spec.to_query_spec()
        _check_probe_reach(cfg, spec)
        return sharded_index_query(
            self.index_sharded,
            queries,
            weights,
            cfg,
            self.mesh,
            spec=spec,
            merge_hierarchical=self.merge_hierarchical,
            delta_sharded=self.delta_sharded,
            tombstones_sharded=self.tombstones_sharded,
            update=self.update,
        )

    def _require_mutable(self, op: str) -> None:
        if not self.mutable:
            raise ValueError(
                f"ShardedIndex.{op}() requires a mutable index — build the "
                f"source Index with update=UpdateSpec(delta_capacity=...) "
                f"before .shard()"
            )

    def insert(self, rows: jax.Array) -> tuple["ShardedIndex", jax.Array]:
        """Insert rows across shards, routed round-robin by global id.

        Returns (new sharded index, (m,) assigned global ids; ``-1`` where
        the owning shard's delta is full). Ids match what a single-host
        mutable Index would assign for the same stream."""
        self._require_mutable("insert")
        from repro.core.distributed import sharded_delta_insert

        delta, ids = sharded_delta_insert(
            self.index_sharded, self.delta_sharded, rows, self.config, self.mesh
        )
        return dataclasses.replace(self, delta_sharded=delta), ids

    def delete(self, ids: jax.Array) -> "ShardedIndex":
        """Tombstone global ids on their owning shards (unknown ids ignored)."""
        self._require_mutable("delete")
        from repro.core.distributed import sharded_tombstone

        ts = sharded_tombstone(
            self.tombstones_sharded,
            jnp.asarray(ids, jnp.int32).reshape(-1),
            self.delta_sharded.fill,
            self.mesh,
            n_local=self.n // self.n_shards,
            cap=self._cap_local,
        )
        return dataclasses.replace(self, tombstones_sharded=ts)

    def compact(self) -> Index:
        """Host-coordinated compaction: gather surviving rows in global-id
        order, rebuild a fresh single-host sealed :class:`Index` (same
        ``build_key`` ⇒ same tables), ready to ``.shard()`` again. Returns
        the LOCAL index — re-shard explicitly, since the survivor count
        must still divide the mesh."""
        self._require_mutable("compact")
        if self.build_key is None:
            raise ValueError(
                "ShardedIndex.compact() needs build_key — this sharded index "
                "was constructed without one (build via Index.shard())"
            )
        S = self.n_shards
        n_local = self.n // S
        cap = self._cap_local
        tomb = np.asarray(self.tombstones_sharded).reshape(S, n_local + cap)
        fills = np.asarray(self.delta_sharded.fill)

        main_data = np.asarray(self.index_sharded.data)  # global-id order already
        main_keep = np.nonzero(~tomb[:, :n_local].reshape(-1))[0]
        rows = [main_data[main_keep]]
        if cap:
            delta_data = np.asarray(self.delta_sharded.data).reshape(S, cap, -1)
            e = np.arange(S * cap)  # delta gids in insertion order
            s, t = e % S, e // S
            live = (t < fills[s]) & ~tomb[s, n_local + t]
            rows.append(delta_data[s[live], t[live]])
        data = jnp.asarray(np.concatenate(rows, axis=0))
        return Index.build(self.build_key, data, self.config, update=self.update)
