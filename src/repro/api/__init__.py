"""repro.api — the unified Index facade over the paper's ALSH schemes.

Stable public surface for building, querying, persisting, and sharding
(d_w^l1)-ALSH indexes. One config-carrying :class:`Index`, one policy-driven
:meth:`Index.query`, self-describing :meth:`Index.save` / :meth:`Index.load`:

    from repro.api import Index, IndexConfig, QuerySpec

    index = Index.build(key, data, IndexConfig(d=16, M=32, K=10, L=16))
    res = index.query(q, w, QuerySpec(k=10))

Hash families are pluggable strategy objects (``ThetaFamily``, ``L2Family``)
registered in :mod:`repro.core.families`. The legacy free functions
(``repro.core.build_index`` / ``query_index`` / ``query_multiprobe``) remain
as thin shims over the same engine.
"""

from repro.api.index import Index, ShardedIndex
from repro.api.spec import QuerySpec
from repro.core.families import (
    FAMILIES,
    HashFamily,
    L2Family,
    ThetaFamily,
    get_family,
)
from repro.core.index import IndexConfig, QueryResult
from repro.core.transforms import BoundedSpace

__all__ = [
    "Index",
    "ShardedIndex",
    "QuerySpec",
    "IndexConfig",
    "QueryResult",
    "BoundedSpace",
    "HashFamily",
    "ThetaFamily",
    "L2Family",
    "FAMILIES",
    "get_family",
]
