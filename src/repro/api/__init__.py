"""repro.api — the unified Index facade over the paper's ALSH schemes.

Stable public surface for building, querying, UPDATING, persisting, and
sharding (d_w^l1)-ALSH indexes. One config-carrying :class:`Index`, one
policy-driven :meth:`Index.query`, a segmented mutable lifecycle
(:meth:`Index.insert` / :meth:`Index.delete` / :meth:`Index.compact`), and
self-describing :meth:`Index.save` / :meth:`Index.load`:

    from repro.api import Index, IndexConfig, QuerySpec, UpdateSpec

    index = Index.build(key, data, IndexConfig(d=16, M=32, K=10, L=16),
                        update=UpdateSpec(delta_capacity=4096))
    res = index.query(q, w, QuerySpec(k=10))
    index, ids = index.insert(new_rows)
    index = index.delete(ids[:16])
    if index.needs_compact:
        index = index.compact()

Or state the SCENARIO instead of the knobs — the declarative, quality-first
path (geometry and execution derived from the paper's theory plus a
one-shot on-data calibration, memoized and persisted):

    from repro.api import Index, QualitySpec

    quality = QualitySpec(k=10, recall_target=0.95)
    index = Index.build(key, data, quality)       # planner picks M/K/L/W/C
    res = index.query(q, w, quality)              # planner picks the execution
    report = index.explain(q, w, quality)         # per-query diagnostics

Hash families are pluggable strategy objects (``ThetaFamily``, ``L2Family``)
registered in :mod:`repro.core.families`. The legacy free functions
(``repro.core.build_index`` / ``query_index`` / ``query_multiprobe``) remain
as thin shims over the same engine (now emitting ``DeprecationWarning``).
"""

from repro.api.index import Index, ShardedIndex
from repro.api.planner import Planner, QueryReport
from repro.api.spec import PlannedSpec, QualitySpec, QuerySpec, UpdateSpec
from repro.core.index import DeltaSegment
from repro.core.families import (
    FAMILIES,
    HashFamily,
    L2Family,
    ThetaFamily,
    get_family,
)
from repro.core.index import IndexConfig, QueryResult
from repro.core.transforms import BoundedSpace

__all__ = [
    "Index",
    "ShardedIndex",
    "QuerySpec",
    "QualitySpec",
    "PlannedSpec",
    "Planner",
    "QueryReport",
    "UpdateSpec",
    "DeltaSegment",
    "IndexConfig",
    "QueryResult",
    "BoundedSpace",
    "HashFamily",
    "ThetaFamily",
    "L2Family",
    "FAMILIES",
    "get_family",
]
