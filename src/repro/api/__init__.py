"""repro.api — the unified Index facade over the paper's ALSH schemes.

Stable public surface for building, querying, UPDATING, persisting, and
sharding (d_w^l1)-ALSH indexes. One config-carrying :class:`Index`, one
policy-driven :meth:`Index.query`, a segmented mutable lifecycle
(:meth:`Index.insert` / :meth:`Index.delete` / :meth:`Index.compact`), and
self-describing :meth:`Index.save` / :meth:`Index.load`:

    from repro.api import Index, IndexConfig, QuerySpec, UpdateSpec

    index = Index.build(key, data, IndexConfig(d=16, M=32, K=10, L=16),
                        update=UpdateSpec(delta_capacity=4096))
    res = index.query(q, w, QuerySpec(k=10))
    index, ids = index.insert(new_rows)
    index = index.delete(ids[:16])
    if index.needs_compact:
        index = index.compact()

Hash families are pluggable strategy objects (``ThetaFamily``, ``L2Family``)
registered in :mod:`repro.core.families`. The legacy free functions
(``repro.core.build_index`` / ``query_index`` / ``query_multiprobe``) remain
as thin shims over the same engine.
"""

from repro.api.index import Index, ShardedIndex
from repro.api.spec import QuerySpec, UpdateSpec
from repro.core.index import DeltaSegment
from repro.core.families import (
    FAMILIES,
    HashFamily,
    L2Family,
    ThetaFamily,
    get_family,
)
from repro.core.index import IndexConfig, QueryResult
from repro.core.transforms import BoundedSpace

__all__ = [
    "Index",
    "ShardedIndex",
    "QuerySpec",
    "UpdateSpec",
    "DeltaSegment",
    "IndexConfig",
    "QueryResult",
    "BoundedSpace",
    "HashFamily",
    "ThetaFamily",
    "L2Family",
    "FAMILIES",
    "get_family",
]
