"""QuerySpec — the query-time policy object of the ``repro.api`` facade.

One ``Index.query(q, w, spec)`` call reaches every execution strategy; the
spec's *fields* select the behavior, so callers never pick a code path by
import:

  QuerySpec(k=10)                                   # single-probe ALSH (paper)
  QuerySpec(k=10, mode="multiprobe", n_probes=8)    # Lv et al. probing sequence
  QuerySpec(k=10, mode="exact")                     # streaming exact scan
  sharded.query(q, w, QuerySpec(k=10))              # hierarchical-merge service

The spec is a frozen (hashable) dataclass: it is a static argument to the
jit'd query dispatch, so two calls with equal specs share one compiled
program.
"""

from __future__ import annotations

import dataclasses

MODES = ("exact", "probe", "multiprobe")
IMPLS = ("auto", "gather", "onehot")


@dataclasses.dataclass(frozen=True)
class QuerySpec:
    """How to execute a query — policy, not mechanism.

    Attributes:
      k: neighbours to return.
      mode: "probe" (the paper's single-probe ALSH), "multiprobe"
        (query-directed bucket perturbation — same recall from fewer
        tables), or "exact" (streaming brute-force scan; the oracle the
        approximate modes are measured against).
      n_probes: multiprobe only — buckets probed per table (incl. the
        query's own bucket).
      max_flips: multiprobe only — max hash bits perturbed per probe key.
      impl: probe mode only — kernel dispatch override for the hash
        projections ("auto" | "gather" | "onehot"); leave "auto" outside
        benchmarks. Exact mode never hashes and multiprobe always uses the
        production dispatch, so a non-"auto" impl is rejected there rather
        than silently ignored.
    """

    k: int = 1
    mode: str = "probe"
    n_probes: int = 8
    max_flips: int = 3
    impl: str = "auto"

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(
                f"QuerySpec.mode must be one of {MODES}, got {self.mode!r}"
            )
        if not isinstance(self.k, int) or self.k <= 0:
            raise ValueError(f"QuerySpec.k must be a positive int, got {self.k!r}")
        if self.impl not in IMPLS:
            raise ValueError(
                f"QuerySpec.impl must be one of {IMPLS}, got {self.impl!r}"
            )
        if self.impl != "auto" and self.mode != "probe":
            raise ValueError(
                f"QuerySpec.impl={self.impl!r} only applies to mode='probe' "
                f"(got mode={self.mode!r}, which would silently ignore it)"
            )
        if self.mode == "multiprobe":
            if not isinstance(self.n_probes, int) or self.n_probes <= 0:
                raise ValueError(
                    f"QuerySpec.n_probes must be a positive int, got {self.n_probes!r}"
                )
            if not isinstance(self.max_flips, int) or self.max_flips < 0:
                raise ValueError(
                    f"QuerySpec.max_flips must be a non-negative int, "
                    f"got {self.max_flips!r}"
                )
