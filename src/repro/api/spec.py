"""QuerySpec / UpdateSpec — the policy objects of the ``repro.api`` facade.

One ``Index.query(q, w, spec)`` call reaches every execution strategy; the
spec's *fields* select the behavior, so callers never pick a code path by
import:

  QuerySpec(k=10)                                   # single-probe ALSH (paper)
  QuerySpec(k=10, mode="multiprobe", n_probes=8)    # Lv et al. probing sequence
  QuerySpec(k=10, mode="exact")                     # streaming exact scan
  sharded.query(q, w, QuerySpec(k=10))              # hierarchical-merge service

The spec is a frozen (hashable) dataclass: it is a static argument to the
jit'd query dispatch, so two calls with equal specs share one compiled
program.
"""

from __future__ import annotations

import dataclasses

MODES = ("exact", "probe", "multiprobe")
IMPLS = ("auto", "gather", "onehot")


@dataclasses.dataclass(frozen=True)
class QuerySpec:
    """How to execute a query — policy, not mechanism.

    Attributes:
      k: neighbours to return.
      mode: "probe" (the paper's single-probe ALSH), "multiprobe"
        (query-directed bucket perturbation — same recall from fewer
        tables), or "exact" (streaming brute-force scan; the oracle the
        approximate modes are measured against).
      n_probes: multiprobe only — buckets probed per table (incl. the
        query's own bucket).
      max_flips: multiprobe only — max hash bits perturbed per probe key.
      impl: probe mode only — kernel dispatch override for the hash
        projections ("auto" | "gather" | "onehot"); leave "auto" outside
        benchmarks. Exact mode never hashes and multiprobe always uses the
        production dispatch, so a non-"auto" impl is rejected there rather
        than silently ignored.
    """

    k: int = 1
    mode: str = "probe"
    n_probes: int = 8
    max_flips: int = 3
    impl: str = "auto"

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(
                f"QuerySpec.mode must be one of {MODES}, got {self.mode!r}"
            )
        if not isinstance(self.k, int) or self.k <= 0:
            raise ValueError(f"QuerySpec.k must be a positive int, got {self.k!r}")
        if self.impl not in IMPLS:
            raise ValueError(
                f"QuerySpec.impl must be one of {IMPLS}, got {self.impl!r}"
            )
        if self.impl != "auto" and self.mode != "probe":
            raise ValueError(
                f"QuerySpec.impl={self.impl!r} only applies to mode='probe' "
                f"(got mode={self.mode!r}, which would silently ignore it)"
            )
        if self.mode == "multiprobe":
            if not isinstance(self.n_probes, int) or self.n_probes <= 0:
                raise ValueError(
                    f"QuerySpec.n_probes must be a positive int, got {self.n_probes!r}"
                )
            if not isinstance(self.max_flips, int) or self.max_flips < 0:
                raise ValueError(
                    f"QuerySpec.max_flips must be a non-negative int, "
                    f"got {self.max_flips!r}"
                )


@dataclasses.dataclass(frozen=True)
class UpdateSpec:
    """Build-time mutability policy of an :class:`~repro.api.Index`.

    The lifecycle memory model is *segmented*: the sealed, sorted main
    segment built by ``Index.build`` never changes; a fixed-capacity delta
    segment absorbs inserts (hashed with the same tables, never sorted) and
    a tombstone bitmap absorbs deletes. ``delta_capacity`` is the STATIC
    size of the delta segment — it fixes every array shape, which is what
    lets insert/delete/query run under jit with no retrace as the index
    mutates. ``Index.compact()`` merges the delta and drops tombstoned rows
    into a fresh sealed segment when the delta fills up.

    Attributes:
      delta_capacity: delta-segment slots (rows insertable before a
        compact). 0 (default) = classic immutable index: insert/delete
        raise, query takes the sealed fast path with zero overhead.
      compact_threshold: advisory fill fraction at which
        ``Index.needs_compact`` flips true (streaming ingest loops poll it;
        nothing compacts automatically).
    """

    delta_capacity: int = 0
    compact_threshold: float = 0.75

    def __post_init__(self):
        if not isinstance(self.delta_capacity, int) or self.delta_capacity < 0:
            raise ValueError(
                f"UpdateSpec.delta_capacity must be a non-negative int, "
                f"got {self.delta_capacity!r}"
            )
        if not (0.0 < self.compact_threshold <= 1.0):
            raise ValueError(
                f"UpdateSpec.compact_threshold must be in (0, 1], "
                f"got {self.compact_threshold!r}"
            )

    @property
    def mutable(self) -> bool:
        return self.delta_capacity > 0
