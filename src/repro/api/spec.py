"""QuerySpec / QualitySpec / UpdateSpec — the policy objects of the
``repro.api`` facade.

One ``Index.query(q, w, spec)`` call reaches every execution strategy; the
spec's *fields* select the behavior, so callers never pick a code path by
import:

  QuerySpec(k=10)                                   # single-probe ALSH (paper)
  QuerySpec(k=10, mode="multiprobe", n_probes=8)    # Lv et al. probing sequence
  QuerySpec(k=10, mode="exact")                     # streaming exact scan
  sharded.query(q, w, QuerySpec(k=10))              # hierarchical-merge service

``QuerySpec`` states MECHANISM (which knobs); :class:`QualitySpec` states
the SCENARIO (what quality) and leaves the knobs to the planner:

  QualitySpec(k=10, recall_target=0.95)             # "give me 95% recall@10"
  index.query(q, w, QualitySpec(...))               # planned, memoized, cached

The planner resolves a QualitySpec into a :class:`PlannedSpec` — a frozen,
hashable record of the chosen execution parameters plus the calibrated
quality predictions. A PlannedSpec is itself a valid ``spec`` argument, and
``index.query(q, w, quality)`` is bit-identical to
``index.query(q, w, index.plan(quality))``.

Every spec is a frozen (hashable) dataclass: it is a static argument to the
jit'd query dispatch, so two calls with equal specs share one compiled
program.
"""

from __future__ import annotations

import dataclasses

MODES = ("exact", "probe", "multiprobe")
IMPLS = ("auto", "gather", "onehot")


@dataclasses.dataclass(frozen=True)
class QuerySpec:
    """How to execute a query — policy, not mechanism.

    Attributes:
      k: neighbours to return.
      mode: "probe" (the paper's single-probe ALSH), "multiprobe"
        (query-directed bucket perturbation — same recall from fewer
        tables), or "exact" (streaming brute-force scan; the oracle the
        approximate modes are measured against).
      n_probes: multiprobe only — buckets probed per table (incl. the
        query's own bucket).
      max_flips: multiprobe only — max hash bits perturbed per probe key.
      impl: probe mode only — kernel dispatch override for the hash
        projections ("auto" | "gather" | "onehot"); leave "auto" outside
        benchmarks. Exact mode never hashes and multiprobe always uses the
        production dispatch, so a non-"auto" impl is rejected there rather
        than silently ignored.
      screen_alpha: quantized-storage screening factor α. 0.0 (default)
        disables the proxy screen; α >= 1 keeps the top ``ceil(k·α)``
        proxy-ranked candidates for the exact f32 rerank. Only meaningful
        on an index built with ``storage != "f32"`` — the engine statically
        ignores it everywhere else (f32 storage and exact mode stay
        bit-identical to an unscreened query). Values in (0, 1) are
        rejected: they would screen away guaranteed top-k slots.
      early_exit: stream the (L, P) probe windows through the engine a
        group at a time and stop per query once the running top-k is
        provably (geometric bound) or confidently (Eq 25/27 estimate at
        the observed running radius vs ``exit_slack``) final. Off by
        default; when off — or whenever the engine folds it off (exact
        mode, an active quantized screen, or a lattice too small to split
        into 2+ groups) — the query is bit-identical to the monolithic
        tail.
      exit_group: early-exit only — probe windows evaluated per streamed
        group (trace-static; the loop runs ceil(L·P / exit_group) steps).
      exit_slack: early-exit only — per-query miss-probability budget δ
        for the confidence stop: a query stops once the Eq 25/27 estimate
        says an unseen collision with a better-than-running-kth neighbour
        has probability <= δ. 0.0 keeps only the provably-safe geometric
        stop, so results stay bit-identical to ``early_exit=False`` while
        still skipping work on degenerate (distance-0) hits.
    """

    k: int = 1
    mode: str = "probe"
    n_probes: int = 8
    max_flips: int = 3
    impl: str = "auto"
    screen_alpha: float = 0.0
    early_exit: bool = False
    exit_group: int = 8
    exit_slack: float = 0.0

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(
                f"QuerySpec.mode must be one of {MODES}, got {self.mode!r}"
            )
        if not isinstance(self.k, int) or self.k <= 0:
            raise ValueError(f"QuerySpec.k must be a positive int, got {self.k!r}")
        if self.screen_alpha != 0.0 and not self.screen_alpha >= 1.0:
            raise ValueError(
                f"QuerySpec.screen_alpha must be 0 (screen off) or >= 1.0 "
                f"(keep ceil(k·α) proxy survivors), got {self.screen_alpha!r}"
            )
        if self.impl not in IMPLS:
            raise ValueError(
                f"QuerySpec.impl must be one of {IMPLS}, got {self.impl!r}"
            )
        if self.impl != "auto" and self.mode != "probe":
            raise ValueError(
                f"QuerySpec.impl={self.impl!r} only applies to mode='probe' "
                f"(got mode={self.mode!r}, which would silently ignore it)"
            )
        if self.mode == "multiprobe":
            if not isinstance(self.n_probes, int) or self.n_probes <= 0:
                raise ValueError(
                    f"QuerySpec.n_probes must be a positive int, got {self.n_probes!r}"
                )
            if not isinstance(self.max_flips, int) or self.max_flips < 0:
                raise ValueError(
                    f"QuerySpec.max_flips must be a non-negative int, "
                    f"got {self.max_flips!r}"
                )
        if not isinstance(self.early_exit, bool):
            raise ValueError(
                f"QuerySpec.early_exit must be a bool, got {self.early_exit!r}"
            )
        if not isinstance(self.exit_group, int) or self.exit_group <= 0:
            raise ValueError(
                f"QuerySpec.exit_group must be a positive int, got {self.exit_group!r}"
            )
        if not (0.0 <= self.exit_slack < 1.0):
            raise ValueError(
                f"QuerySpec.exit_slack must be a miss-probability budget in "
                f"[0, 1), got {self.exit_slack!r}"
            )
        if self.early_exit and self.mode == "exact":
            raise ValueError(
                "QuerySpec.early_exit does not apply to mode='exact' (the "
                "streaming scan already visits every row exactly once)"
            )


@dataclasses.dataclass(frozen=True)
class QualitySpec:
    """What quality the caller needs — the planner derives the mechanism.

    The paper's Theorems 4/5 give closed-form collision probabilities for
    both ALSH families, which means the index can SOLVE for its own knobs:
    state the scenario here and ``Index.build`` / ``Index.query`` resolve it
    through :class:`repro.api.planner.Planner` (theory inversion plus a
    one-shot on-data calibration pass, memoized per index).

    Attributes:
      k: neighbours to return (recall is measured @ k).
      recall_target: minimum acceptable recall@k against the exact scan;
        the planner picks the CHEAPEST execution plan whose calibrated
        recall meets it (and warns if no plan can).
      approx_c: Thm 1 approximation factor c > 1 — the far radius is
        R2 = c * R1 where R1 is calibrated from the data.
      fail_prob: per-query failure bound delta for the Thm 1 table-count
        solve: build-time planning sizes L so an R1-near neighbour is
        missed with probability <= delta.
      latency_budget_ms: optional per-query latency ceiling. Deterministic
        planning cannot time wall clocks, so the budget is applied through
        a coarse linear cost model (candidates examined per ms; see
        ``Planner.candidates_per_ms``) — treat it as a knee-point selector,
        not an SLA.
      calibration_queries: sample size of the calibration pass. Larger =
        tighter recall estimates, slower planning.
      seed: calibration sample seed. Planning is DETERMINISTIC given
        (index, seed) — same index, same spec, same plan.
    """

    k: int = 10
    recall_target: float = 0.9
    approx_c: float = 2.0
    fail_prob: float = 0.1
    latency_budget_ms: float | None = None
    calibration_queries: int = 64
    seed: int = 0

    def __post_init__(self):
        if not isinstance(self.k, int) or self.k <= 0:
            raise ValueError(f"QualitySpec.k must be a positive int, got {self.k!r}")
        if not (0.0 < self.recall_target <= 1.0):
            raise ValueError(
                f"QualitySpec.recall_target must be in (0, 1], got {self.recall_target!r}"
            )
        if not self.approx_c > 1.0:
            raise ValueError(
                f"QualitySpec.approx_c must be > 1 (Thm 1 needs R2 > R1), "
                f"got {self.approx_c!r}"
            )
        if not (0.0 < self.fail_prob < 1.0):
            raise ValueError(
                f"QualitySpec.fail_prob must be in (0, 1), got {self.fail_prob!r}"
            )
        if self.latency_budget_ms is not None and not self.latency_budget_ms > 0:
            raise ValueError(
                f"QualitySpec.latency_budget_ms must be positive (or None), "
                f"got {self.latency_budget_ms!r}"
            )
        if not isinstance(self.calibration_queries, int) or self.calibration_queries <= 0:
            raise ValueError(
                f"QualitySpec.calibration_queries must be a positive int, "
                f"got {self.calibration_queries!r}"
            )


@dataclasses.dataclass(frozen=True)
class PlannedSpec:
    """A QualitySpec resolved to concrete execution parameters.

    Frozen, hashable, and jit-static: it rides in the Index pytree treedef
    (so plans survive jit/shard_map crossings), round-trips through the v3
    persistence manifest, and is a valid ``Index.query`` spec —
    ``query(q, w, quality)`` and ``query(q, w, the_resolved_plan)`` run the
    SAME compiled program, bit-identically.

    Attributes:
      k: neighbours returned.
      mode: chosen execution strategy ("probe" | "multiprobe").
      n_probes / max_flips: multiprobe knobs (1 / 0 in probe mode).
      max_candidates: effective per-table probe window — always <= the
        built ``IndexConfig.max_candidates`` (the window can shrink at
        query time but the build padding caps it).
      predicted_recall: calibrated recall@k of this plan on the planning
        sample (NaN when calibration was skipped).
      predicted_success: Thm 1 per-query success bound 1-(1-P1^K)^L at the
        calibrated operating radius.
      expected_candidates: mean unique candidates examined per query on the
        calibration sample — the sublinearity/latency proxy.
      screen_alpha: quantized-storage screening factor the plan executes
        with (0.0 on f32-stored indexes — the ladder never proposes a
        screen there, keeping planned f32 queries bit-identical to the
        unscreened engine).
      early_exit / exit_group / exit_slack: adaptive-probing knobs the
        plan executes with (see :class:`QuerySpec`). Early-exit rungs set
        ``exit_slack`` to the QualitySpec's ``fail_prob`` — the same
        per-query miss budget the Thm 1 table-count solve already accepts.
      expected_tables: mean probe windows actually visited per query on
        the calibration sample (== L·P when the plan never exits early) —
        the expected-tables-probed axis of the extended cost model.
      provenance: how the plan was resolved — "calibrated" (the full
        empirical ladder ran on this index) or "prior" (interpolated from
        an offline :mod:`repro.tuner` Pareto table and accepted after a
        single confirmation probe). Prior-based plans trade the 13–24 s
        calibration pass for a cheap confirmation; the stamp keeps that
        trade auditable per query (``Index.explain``) and per shipped
        artifact (the persistence manifest).
    """

    k: int
    mode: str
    n_probes: int = 1
    max_flips: int = 0
    max_candidates: int = 64
    predicted_recall: float = float("nan")
    predicted_success: float = float("nan")
    expected_candidates: float = float("nan")
    screen_alpha: float = 0.0
    early_exit: bool = False
    exit_group: int = 8
    exit_slack: float = 0.0
    expected_tables: float = float("nan")
    provenance: str = "calibrated"

    def __post_init__(self):
        if self.mode not in ("probe", "multiprobe"):
            raise ValueError(
                f"PlannedSpec.mode must be 'probe' or 'multiprobe', got {self.mode!r}"
            )
        if self.screen_alpha != 0.0 and not self.screen_alpha >= 1.0:
            raise ValueError(
                f"PlannedSpec.screen_alpha must be 0 (screen off) or >= 1.0, "
                f"got {self.screen_alpha!r}"
            )
        if self.provenance not in ("calibrated", "prior"):
            raise ValueError(
                f"PlannedSpec.provenance must be 'calibrated' or 'prior', "
                f"got {self.provenance!r}"
            )
        for field in ("k", "n_probes", "max_candidates"):
            v = getattr(self, field)
            if not isinstance(v, int) or v <= 0:
                raise ValueError(
                    f"PlannedSpec.{field} must be a positive int, got {v!r}"
                )
        if not isinstance(self.max_flips, int) or self.max_flips < 0:
            raise ValueError(
                f"PlannedSpec.max_flips must be a non-negative int, got {self.max_flips!r}"
            )
        if not isinstance(self.early_exit, bool):
            raise ValueError(
                f"PlannedSpec.early_exit must be a bool, got {self.early_exit!r}"
            )
        if not isinstance(self.exit_group, int) or self.exit_group <= 0:
            raise ValueError(
                f"PlannedSpec.exit_group must be a positive int, got {self.exit_group!r}"
            )
        if not (0.0 <= self.exit_slack < 1.0):
            raise ValueError(
                f"PlannedSpec.exit_slack must be in [0, 1), got {self.exit_slack!r}"
            )

    def to_query_spec(self) -> QuerySpec:
        """The mechanism-level spec this plan executes as."""
        if self.mode == "multiprobe":
            return QuerySpec(
                k=self.k, mode="multiprobe", n_probes=self.n_probes,
                max_flips=self.max_flips, screen_alpha=self.screen_alpha,
                early_exit=self.early_exit, exit_group=self.exit_group,
                exit_slack=self.exit_slack,
            )
        return QuerySpec(
            k=self.k, mode="probe", screen_alpha=self.screen_alpha,
            early_exit=self.early_exit, exit_group=self.exit_group,
            exit_slack=self.exit_slack,
        )

    def effective_config(self, cfg):
        """``cfg`` with this plan's probe window applied (never wider than
        the built window — the sort-time perm padding caps it)."""
        if self.max_candidates == cfg.max_candidates:
            return cfg
        if self.max_candidates > cfg.max_candidates:
            raise ValueError(
                f"PlannedSpec.max_candidates={self.max_candidates} exceeds the "
                f"built IndexConfig.max_candidates={cfg.max_candidates} — this "
                f"plan was made for a different index geometry"
            )
        return dataclasses.replace(cfg, max_candidates=self.max_candidates)


@dataclasses.dataclass(frozen=True)
class UpdateSpec:
    """Build-time mutability policy of an :class:`~repro.api.Index`.

    The lifecycle memory model is *segmented*: the sealed, sorted main
    segment built by ``Index.build`` never changes; a fixed-capacity delta
    segment absorbs inserts (hashed with the same tables, never sorted) and
    a tombstone bitmap absorbs deletes. ``delta_capacity`` is the STATIC
    size of the delta segment — it fixes every array shape, which is what
    lets insert/delete/query run under jit with no retrace as the index
    mutates. ``Index.compact()`` merges the delta and drops tombstoned rows
    into a fresh sealed segment when the delta fills up.

    Attributes:
      delta_capacity: delta-segment slots (rows insertable before a
        compact). 0 (default) = classic immutable index: insert/delete
        raise, query takes the sealed fast path with zero overhead.
      compact_threshold: advisory fill fraction at which
        ``Index.needs_compact`` flips true (streaming ingest loops poll it;
        nothing compacts automatically).
    """

    delta_capacity: int = 0
    compact_threshold: float = 0.75

    def __post_init__(self):
        if not isinstance(self.delta_capacity, int) or self.delta_capacity < 0:
            raise ValueError(
                f"UpdateSpec.delta_capacity must be a non-negative int, "
                f"got {self.delta_capacity!r}"
            )
        if not (0.0 < self.compact_threshold <= 1.0):
            raise ValueError(
                f"UpdateSpec.compact_threshold must be in (0, 1], "
                f"got {self.compact_threshold!r}"
            )

    @property
    def mutable(self) -> bool:
        return self.delta_capacity > 0
