"""The query planner — quality targets in, mechanism out.

The paper's Theorems 4/5 give closed-form collision probabilities for both
ALSH families, so the index can SOLVE for its own knobs instead of making
the user hand-pick ``(M, K, L, W, n_probes, max_candidates)``. The planner
has two halves:

**Build-time** (:meth:`Planner.plan_config`) — theory inversion on a data
sample: discretize the data, measure each sampled query's kth-NN distance
in lattice units, evaluate Eq 25/27 at the per-query radii ``r1_i`` /
``r2_i = c * r1_i`` (anchoring the l2 family's bucket width ``W`` at a
fixed collision prob on the 75th-percentile transformed near distance —
scale-robust where the rho-minimizing width is not), then run the
Theorem 1 solve: ``K = ceil(ln n / ln 1/P2)`` bounds far-point noise and
``L`` is the smallest table count whose PER-SAMPLE mean success
``mean_i[1-(1-p1_i^K)^L]`` reaches ``max(recall_target, 1-fail_prob)``,
with a hash budget that walks K down when K*L overshoots. With
``family="auto"`` both families are solved and the lower-rho one wins.

**Query-time** (:meth:`Planner.plan_query`) — a cheap EMPIRICAL calibration
pass against the built index: hash a deterministic sample of jittered data
rows as queries once, score a short ladder of execution plans (single-probe
at shrinking candidate windows; multiprobe at growing probe counts) against
the exact oracle, and pick the cheapest plan whose measured recall@k meets
``recall_target``. Calibration measures the EXACT programs the plan will
run (each ladder rung is executed through ``Index.query`` with a
:class:`~repro.api.spec.PlannedSpec`), so the resolved plan is
bit-reproducible: ``query(q, w, quality) == query(q, w, plan)``.

Planning is deterministic given (index, ``QualitySpec.seed``): the sample
is drawn from the index's own ``build_key`` folded with the spec seed, and
no wall clocks are read — the optional ``latency_budget_ms`` is applied
through the coarse linear cost model ``candidates_per_ms``.

``Index.plan`` memoizes resolved plans on the index (they ride the pytree
treedef, persist in the v3 manifest, and survive ``shard()``), so the
calibration pass runs once per (index, QualitySpec).

**Empirical prior** (``Planner(table=...)``) — a third source of truth
between theory and calibration: an offline :class:`repro.tuner.TuningTable`
(per-profile recall/cost/memory Pareto frontiers from a distributed
parameter scan). When the index's profile (family, n, d, weight skew) lands
inside a scanned bucket, BOTH planner halves consult it first:
``plan_config`` takes the cheapest frontier geometry meeting the recall
target instead of running theory inversion, and ``plan_query`` executes ONE
confirmation probe of the frontier's execution plan instead of the full
calibration ladder — the resolved plan is stamped
``provenance="prior"``. A failed confirmation, an out-of-bucket profile, or
no table at all falls back to the calibrated path (stamped
``provenance="calibrated"``) bit-identically to a table-less planner.
"""

from __future__ import annotations

import dataclasses
import math
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.spec import PlannedSpec, QualitySpec, QuerySpec
from repro.core import theory, transforms
from repro.core.families import get_family, n_flip_subsets
from repro.core.index import IndexConfig
from repro.core.transforms import BoundedSpace

__all__ = ["Planner", "QueryReport", "default_calibration_weights"]


def default_calibration_weights(key: jax.Array, shape: tuple[int, int]) -> jax.Array:
    """The planner's reference weight distribution: |N(0, 1)| + 0.1 per dim.

    Matches the weight profile the repo's benchmarks/examples query with;
    pass explicit ``weights`` to :class:`Planner` when the workload's
    weights look different (e.g. retrieval's precision weights).
    """
    return jnp.abs(jax.random.normal(key, shape)) + 0.1


def _prng(build_key, seed: int) -> jax.Array:
    """Deterministic planning key: the index's own build key (raw uint32
    data) folded with the QualitySpec seed."""
    key = jnp.asarray(build_key, jnp.uint32).reshape(-1)[:2]
    return jax.random.fold_in(key, seed)


def _n_windows(cfg, plan) -> int:
    """Size of the (table, probe-rank) window lattice ``plan`` visits —
    the ``expected_tables`` value of a plan that never exits early."""
    return cfg.L * (plan.n_probes if plan.mode == "multiprobe" else 1)


@dataclasses.dataclass
class QueryReport:
    """Per-query diagnostics from ``Index.explain`` — the resolved plan,
    the theory prediction, and what actually happened.

    Attributes:
      spec: the spec that EXECUTED (a QuerySpec, or the PlannedSpec a
        QualitySpec resolved to).
      quality: the QualitySpec the caller stated (None for mechanism specs).
      result: the :class:`~repro.core.index.QueryResult` (same arrays
        ``Index.query`` returns — explain never changes the answer).
      predicted_success: (b,) Thm 1 success bound 1-(1-p1^K)^L per query,
        with p1 = Eq 25/27 at the query's OWN weight vector and observed
        top-1 distance (0.0 where the query returned nothing). For
        multiprobe this is the single-probe lower bound — extra probes only
        add collisions.
      n_candidates: (b,) unique candidates examined (the sublinearity metric).
      truncated_tables: (b,) number of probed buckets whose window exceeded
        the effective ``max_candidates`` clamp — non-zero means candidates
        were dropped BEFORE re-rank (grow the window or raise K).
      n_invalid: (b,) sentinel result slots (ids == -1): fewer than k
        neighbours survived the probe.
      provenance: how the executed plan was resolved — "calibrated" |
        "prior" for planned specs, None for raw mechanism QuerySpecs. A
        query served off a PRIOR plan skipped the full calibration ladder;
        this stamp is what makes that degradation auditable per query.
      plan_build_s: wall seconds the plan resolution cost on THIS process
        (None for mechanism specs and for plans restored from a manifest —
        those were planned elsewhere).
      storage: the index's row codec ("f32" | "bf16" | "int8") — context
        for the byte accounting below.
      rows_screened: (b,) candidates ranked by the quantized proxy screen
        (0 everywhere when the screen was statically off: f32 storage,
        exact mode, or screen_alpha=0).
      rows_reranked: (b,) candidates the exact f32 rerank decoded — the
        screen survivors, or every unique candidate when unscreened.
      bytes_gathered: (b,) table payload bytes the fused tail gathered
        (screen + rerank passes, at the ENCODED row width) — the
        bandwidth the storage codec is saving.
      table_bytes: resident bytes of the row tables (main + delta payload
        + scales); compare across codecs for the memory ratio.
      tables_probed: (b,) probe windows the streamed early-exit tail
        visited per query (== tables when P = 1; None when the query ran
        the monolithic tail — early exit off or folded away).
      stop_reason: (b,) int32 early-exit stop code per query — 0 the
        stream exhausted every group, 1 geometric stop, 2 Eq 25/27
        confidence stop (None with the monolithic tail).
    """

    spec: object
    quality: QualitySpec | None
    result: object
    predicted_success: np.ndarray
    n_candidates: np.ndarray
    truncated_tables: np.ndarray
    n_invalid: np.ndarray
    provenance: str | None = None
    plan_build_s: float | None = None
    storage: str | None = None
    rows_screened: np.ndarray | None = None
    rows_reranked: np.ndarray | None = None
    bytes_gathered: np.ndarray | None = None
    table_bytes: int | None = None
    tables_probed: np.ndarray | None = None
    stop_reason: np.ndarray | None = None

    def to_dict(self) -> dict:
        """JSON-able summary (arrays reduced to batch means) for logging."""
        return {
            "spec": dataclasses.asdict(self.spec) if dataclasses.is_dataclass(self.spec) else str(self.spec),
            "quality": dataclasses.asdict(self.quality) if self.quality else None,
            "provenance": self.provenance,
            "plan_build_s": self.plan_build_s,
            "mean_predicted_success": float(np.mean(self.predicted_success)),
            "mean_n_candidates": float(np.mean(self.n_candidates)),
            "queries_with_truncation": int(np.sum(self.truncated_tables > 0)),
            "queries_with_invalid_slots": int(np.sum(self.n_invalid > 0)),
            "storage": self.storage,
            "mean_rows_screened": (
                float(np.mean(self.rows_screened))
                if self.rows_screened is not None else None
            ),
            "mean_rows_reranked": (
                float(np.mean(self.rows_reranked))
                if self.rows_reranked is not None else None
            ),
            "mean_bytes_gathered": (
                float(np.mean(self.bytes_gathered))
                if self.bytes_gathered is not None else None
            ),
            "table_bytes": self.table_bytes,
            "mean_tables_probed": (
                float(np.mean(self.tables_probed))
                if self.tables_probed is not None else None
            ),
            "stop_reasons": (
                {
                    "exhausted": int(np.sum(self.stop_reason == 0)),
                    "geometric": int(np.sum(self.stop_reason == 1)),
                    "confidence": int(np.sum(self.stop_reason == 2)),
                }
                if self.stop_reason is not None else None
            ),
        }


@dataclasses.dataclass
class Planner:
    """Resolves :class:`QualitySpec` targets to concrete parameters.

    Attributes:
      weights: optional (d,) or (m, d) calibration weight profile. Default
        draws :func:`default_calibration_weights` — override when the
        workload's weights are known (retrieval passes its precision
        weights).
      candidates_per_ms: the linear cost model behind
        ``QualitySpec.latency_budget_ms``: a budget of B ms admits plans
        examining at most ``B * candidates_per_ms`` candidates per query.
        Calibrate per deployment (``BENCH_kernels.json`` has the measured
        rerank throughput); the default is a conservative CPU figure.
      slot_cost: relative cost of one probed (table, probe, slot) versus one
        reranked candidate in the plan-ordering objective — charges the
        dedupe sort so a 32-probe plan doesn't look free just because its
        unique-candidate count matches an 8-probe plan.
      max_K / max_L: geometry caps for the build-time solve.
      max_hashes: build-time budget on K*L, the total hashes per point. The
        raw Thm 1 solve happily asks for K=30, L=600 at high-collision
        operating points — correct asymptotically, absurd as a memory/build
        bill. When the solve exceeds the budget, K is walked down (each step
        shrinks L exponentially since L ~ P1^-K) until K*L fits; the
        query-time calibration pass then recovers recall through wider
        windows/multiprobe if the slimmer geometry needs it.
      table: optional :class:`repro.tuner.TuningTable` empirical prior (see
        module docstring). None (default) plans exactly as before.
      profile_skew: the weight-skew coordinate this planner's workload
        occupies in the table's profile space — 1.0 is the reference
        ``default_calibration_weights`` distribution; planners constructed
        with explicit ``weights`` should state the matching skew.
      confirm_slack: recall slack the single confirmation probe tolerates
        before rejecting a prior plan (the probe measures on a finite
        sample; the 2 pt default matches the repo's adherence bar).
    """

    weights: jax.Array | None = None
    candidates_per_ms: float = 2000.0
    slot_cost: float = 0.02
    max_K: int = 32
    max_L: int = 256
    max_hashes: int = 512
    table: object | None = None
    profile_skew: float = 1.0
    confirm_slack: float = 0.02

    # -- shared sampling -----------------------------------------------------
    def _calibration_weights(self, key: jax.Array, m: int, d: int) -> jax.Array:
        if self.weights is None:
            return default_calibration_weights(key, (m, d))
        w = jnp.asarray(self.weights)
        return jnp.broadcast_to(w, (m, d))

    def _sample(self, key: jax.Array, data: jax.Array, m: int, jitter: float):
        """Deterministic (queries, weights) calibration sample: data rows
        JITTERED by one lattice cell. Raw rows would calibrate too
        optimistically — a data-row query's bucket key exists in every
        table by construction (its own row is there), while a held-out
        query can land in an empty bucket; the one-cell jitter decouples
        the hash keys while keeping the sample in-distribution."""
        n, d = data.shape
        m = min(m, n)
        k_rows, k_j, k_w = jax.random.split(key, 3)
        rows = jax.random.choice(k_rows, n, (m,), replace=False)
        qs = data[rows] + jax.random.uniform(
            k_j, (m, d), minval=-jitter, maxval=jitter
        )
        return qs, self._calibration_weights(k_w, m, d)

    # -- build-time: theory inversion ---------------------------------------
    def plan_config(
        self,
        data: jax.Array,
        quality: QualitySpec,
        family: str = "auto",
        M: int = 32,
        space: BoundedSpace | None = None,
    ) -> IndexConfig:
        """Derive a full :class:`IndexConfig` from a data sample + targets.

        ``family="auto"`` solves both families and keeps the lower rho.
        ``space`` defaults to the sample's bounding box at resolution
        ``M / (hi - lo)``. Deterministic given (data, quality.seed).

        With a tuning ``table``, a frontier geometry for the matching data
        profile short-circuits the theory inversion (the space still comes
        from the data's bounding box); out-of-bucket profiles run the full
        solve unchanged.
        """
        n, d = data.shape
        key = _prng(jnp.zeros((2,), jnp.uint32), quality.seed)
        if space is None:
            lo = float(jnp.min(data))
            hi = float(jnp.max(data))
            if hi <= lo:
                hi = lo + 1.0
            space = BoundedSpace(lo, hi, M / (hi - lo))
        M_eff = max(space.M, 1)
        prior_cfg = self._config_from_prior(n, d, quality, family, M_eff, space)
        if prior_cfg is not None:
            return prior_cfg
        qs, ws = self._sample(
            jax.random.fold_in(key, 0), data, quality.calibration_queries,
            jitter=1.0 / space.t,
        )

        # k-NN distance distribution IN LATTICE UNITS (hashing sees levels,
        # so Eq 24-27 radii must be measured on the discretized points)
        from repro.kernels import ops

        levels = transforms.discretize(data, space).astype(jnp.float32)
        qlevels = transforms.discretize(qs, space).astype(jnp.float32)
        # +1: each jittered query's source row sits at ~zero distance, so
        # the (k+1)-th column approximates the true kth-NN radius
        kk = min(quality.k + 1, n)
        nn_d, _ = ops.wl1_scan_topk(levels, qlevels, ws, kk)
        # per-query operating radii: each query must find ITS kth neighbour,
        # so the solve aggregates per-query collision probs pessimistically
        # instead of evaluating one mean-weight profile (which overpromises
        # badly for the scale-sensitive l2 family under spread-out weights)
        r1 = jnp.maximum(nn_d[:, kk - 1], 1e-6)  # (m,) lattice kth-NN dists
        r2 = quality.approx_c * r1

        candidates = ("theta", "l2") if family == "auto" else (family,)
        best = None
        for fam in candidates:
            sol = self._solve_family(fam, r1, r2, M_eff, d, ws, n, quality)
            if sol is not None and (best is None or sol["rho"] < best["rho"]):
                best = sol
        if best is None:
            raise ValueError(
                f"planner: no hash family yields usable collision probabilities "
                f"at the sampled operating radii (family={family!r}) — the "
                f"sample's neighbour distances may be degenerate; widen "
                f"approx_c or pass an explicit IndexConfig"
            )
        # per-table window: expected far-point collisions n*P2^K plus the k
        # requested neighbours, with 8x headroom, power-of-two, in [32, 1024]
        exp_far = n * best["P2"] ** best["K"]
        C = int(min(1024, max(32, 2 ** math.ceil(math.log2(8 * (exp_far + quality.k))))))
        return IndexConfig(
            d=d,
            M=M_eff,
            K=best["K"],
            L=best["L"],
            family=best["family"],
            W=best["W"],
            max_candidates=C,
            space=space,
        )

    # collision prob the near-radius solve anchors W to: p_l2(s, c_star * s)
    # == _P1_GOAL for any s (Eq 4 depends only on W/s)
    _P1_GOAL = 0.9

    def _solve_family(self, fam: str, r1, r2, M, d, ws, n, quality):
        """One family's Thm 1 solve over PER-QUERY operating radii.

        r1/r2: (m,) near/far lattice radii; ws: (m, d) sampled weights.
        Near-side collision probs aggregate at the 25th percentile (a plan
        that only works for the median query fails half the workload);
        far-side at the median (far collisions are a cost, not a guarantee).
        Returns None when the probabilities degenerate.
        """
        W = 4.0
        if fam == "l2":
            s1 = theory.l2_distance_from_wl1(r1, M, d, ws)  # (m,)
            s2 = theory.l2_distance_from_wl1(r2, M, d, ws)
            if not bool(jnp.all((s1 > 0) & (s2 > s1))):
                return None
            # anchor W so the near collision prob hits _P1_GOAL at the 75th
            # percentile of s1 — the scale-robust choice (rho-minimizing W
            # is optimal for ONE scale and collapses under weight spread)
            c_star = 1.0 / theory.invert_p_l2(self._P1_GOAL, 1.0)
            W = c_star * float(jnp.quantile(s1, 0.75))
            p1 = theory.p_l2(s1, W)
            p2 = theory.p_l2(s2, W)
        else:
            p1 = theory.collision_prob_theta(r1, M, d, ws)
            p2 = theory.collision_prob_theta(r2, M, d, ws)
        p1 = np.clip(np.asarray(p1, np.float64), 1e-9, 1 - 1e-9)
        P1 = float(np.quantile(p1, 0.25))
        P2 = float(jnp.median(p2))
        if not (0.0 < P2 < P1 < 1.0):
            return None
        max_K = self.max_K
        fam_cap = get_family(fam).max_K
        if fam_cap is not None:
            max_K = min(max_K, fam_cap)
        # K bounds the far-point candidate load (Thm 1); L is then solved
        # against the PER-SAMPLE success curve: mean_i 1-(1-p1_i^K)^L >=
        # max(recall_target, 1-fail_prob). Solving on the sampled p1_i
        # distribution (not one aggregate) is what provisions enough tables
        # for the heavy-tailed weight profiles the scalar solve overpromises
        # on. The hash budget walks K down when K*L overshoots (each step
        # shrinks L exponentially).
        goal = max(quality.recall_target, 1.0 - quality.fail_prob)
        K = theory.solve_K(P2, n, max_K)
        while True:
            L = self._solve_L(p1, K, goal)
            if K == 1 or K * L <= self.max_hashes:
                break
            K -= 1
        return {
            "family": fam,
            "W": W,
            "P1": P1,
            "P2": P2,
            "K": K,
            "L": L,
            "rho": math.log(P1) / math.log(P2),
        }

    def _solve_L(self, p1_samples: "np.ndarray", K: int, goal: float) -> int:
        """Smallest L <= max_L with mean_i[1 - (1 - p1_i^K)^L] >= goal
        (bisection on the monotone success curve; max_L when unreachable)."""
        miss = 1.0 - p1_samples**K  # (m,) per-sample per-table miss prob

        def mean_success(L: int) -> float:
            return float(np.mean(1.0 - miss**L))

        if mean_success(self.max_L) < goal:
            return self.max_L
        lo, hi = 1, self.max_L
        while lo < hi:
            mid = (lo + hi) // 2
            if mean_success(mid) >= goal:
                hi = mid
            else:
                lo = mid + 1
        return lo

    # screening factors the quantized-index ladder cross-products its rungs
    # with (in addition to every unscreened rung): keep 2k, keep 4k
    _SCREEN_ALPHAS = (2.0, 4.0)

    # streamed rungs must have at least this many exit groups to be worth
    # a separate compiled program (one group IS the monolithic tail and
    # normalize_static_args folds it away)
    _EXIT_GROUP = 8
    _MIN_EXIT_GROUPS = 2

    # -- query-time: empirical calibration ----------------------------------
    def _plan_ladder(
        self, cfg: IndexConfig, k: int, exit_slack: float = 0.0
    ) -> list[PlannedSpec]:
        """The candidate execution plans, cheapest-intent first.

        On an f32-stored index this list is EXACTLY the pre-quantization
        ladder (every rung screen_alpha=0 — planned f32 queries stay
        bit-identical). Quantized storage crosses each rung with the
        ``_SCREEN_ALPHAS`` screening factors, so calibration measures the
        proxy screen's recall cost on the real query path and α becomes a
        planner-chosen knob like the window or the probe count.

        With ``exit_slack`` > 0 every unscreened rung whose window lattice
        spans at least ``_MIN_EXIT_GROUPS`` exit groups additionally gets an
        early-exit twin (``exit_slack`` = the QualitySpec's fail_prob — the
        same per-query miss budget the Thm 1 table solve accepts), so
        calibration measures the streamed tail's real recall/tables-probed
        trade on this index instead of assuming it."""
        C = cfg.max_candidates
        windows = sorted({max(C >> s, min(C, max(2 * k, 16))) for s in (3, 2, 1, 0)})
        ladder = [
            PlannedSpec(k=k, mode="probe", max_candidates=c) for c in windows
        ]
        if get_family(cfg.family).supports_multiprobe:
            max_flips = min(3, cfg.K)
            cap = n_flip_subsets(cfg.K, max_flips)
            for p in (2, 4, 8, 16, 32):
                if p <= cap:
                    ladder.append(
                        PlannedSpec(
                            k=k, mode="multiprobe", n_probes=p,
                            max_flips=max_flips, max_candidates=C,
                        )
                    )
        if exit_slack > 0.0:
            ladder += [
                dataclasses.replace(
                    rung, early_exit=True, exit_group=self._EXIT_GROUP,
                    exit_slack=exit_slack,
                )
                for rung in list(ladder)
                if cfg.L * rung.n_probes
                >= self._MIN_EXIT_GROUPS * self._EXIT_GROUP
            ]
        if cfg.storage != "f32":
            ladder += [
                dataclasses.replace(rung, screen_alpha=alpha)
                for rung in list(ladder)
                if not rung.early_exit  # screening folds streaming off
                for alpha in self._SCREEN_ALPHAS
            ]
        return ladder

    def _plan_cost(self, cfg: IndexConfig, plan: PlannedSpec, mean_cand: float) -> float:
        """Deterministic cost model: reranked candidates + charged probe
        slots. A screened plan splits the rerank term into the proxy pass
        (every candidate at the compressed byte ratio — screening reads
        encoded rows, never decodes) plus the exact rerank of the
        ``ceil(k·α)`` survivors; that is what lets a screened rung undercut
        its unscreened twin once the candidate pool is large.

        An early-exit rung scales the probe-slot term by its CALIBRATED
        expected-tables-probed fraction (``plan.expected_tables`` over the
        full L·P lattice) — the streamed tail only pays for the windows the
        average query actually visits, which is what lets a worst-case-L
        plan price like an average-case one."""
        from repro.quant import bytes_per_value

        slots = cfg.L * plan.n_probes * plan.max_candidates
        if plan.early_exit and plan.expected_tables == plan.expected_tables:
            slots *= min(1.0, plan.expected_tables / _n_windows(cfg, plan))
        if plan.screen_alpha:
            keep = max(plan.k, math.ceil(plan.k * plan.screen_alpha))
            ratio = bytes_per_value(cfg.storage) / 4.0
            rerank = mean_cand * ratio + min(mean_cand, float(keep))
        else:
            rerank = mean_cand
        return rerank + self.slot_cost * slots

    def _calibration_sample(self, index, quality: QualitySpec):
        """The shared deterministic calibration setup: jittered-data-row
        queries + weights + the exact oracle's answer. Used by the full
        ladder calibration AND the single prior-confirmation probe (same
        sample, so a confirmed prior is measured on exactly the evidence a
        calibrated plan would have been)."""
        data = index.state.data
        if isinstance(data, jax.core.Tracer):
            raise ValueError(
                "Planner.plan_query cannot calibrate under jit (the index "
                "data is a tracer) — resolve the plan eagerly first via "
                "index.plan(quality), then query inside jit; the memoized "
                "plan crosses the jit boundary with the index"
            )
        if data.dtype != jnp.float32:
            # quantized storage: sample from the DECODED rows (oracle path —
            # one-shot at plan time, never resident). Jittered decoded rows
            # sit within one quantization step of the raw build rows, so the
            # calibration stays in-distribution
            from repro import quant

            data = quant.decode_table(data, index.state.scales)
        cfg = index.config
        key = _prng(index.build_key, quality.seed)
        qs, ws = self._sample(
            key, data, quality.calibration_queries, jitter=1.0 / cfg.space.t
        )
        exact = index.query(qs, ws, QuerySpec(k=quality.k, mode="exact"))
        return qs, ws, exact

    def _operating_success(self, cfg: IndexConfig, exact, ws) -> float:
        """Thm 1 success bound at the observed operating radius. Exact
        distances are in RAW data units; Eq 25/27 operate on lattice points,
        so scale by the discretization resolution t."""
        kth = exact.dists[:, -1]
        r_op = float(jnp.median(jnp.where(jnp.isfinite(kth), kth, 0.0)))
        r_op *= cfg.space.t
        w_ref = jnp.mean(jnp.abs(ws), axis=0)
        p1 = self._collision_prob(cfg, r_op, w_ref)
        return float(
            1.0 - (1.0 - min(max(p1, 1e-12), 1 - 1e-12) ** cfg.K) ** cfg.L
        )

    def _calibrate(self, index, quality: QualitySpec):
        """One calibration pass shared by ``plan_query`` and ``plan_ladder``:
        run EVERY ladder rung through the real query path against the exact
        oracle. Returns ``(scored, success)`` where ``scored`` is a list of
        ``(rung, recall, mean_cand, cost)`` tuples and ``success`` the Thm 1
        success bound at the calibrated operating radius."""
        from repro.distance import recall_at_k

        cfg = index.config
        qs, ws, exact = self._calibration_sample(index, quality)
        success = self._operating_success(cfg, exact, ws)

        scored = []
        for rung in self._plan_ladder(cfg, quality.k, exit_slack=quality.fail_prob):
            res = index.query(qs, ws, rung)
            recall = float(recall_at_k(res.ids, exact.ids, quality.k))
            mean_cand = float(jnp.mean(res.n_candidates))
            # stamp the expected-tables-probed BEFORE costing: measured on
            # streamed rungs, == the full window lattice otherwise. Never
            # leave the NaN field default in a memoized plan — NaN breaks
            # the save/load equality contract (nan != nan after the JSON
            # round-trip re-materializes the float).
            rung = dataclasses.replace(
                rung, expected_tables=(
                    float(jnp.mean(res.tables_probed))
                    if res.tables_probed is not None
                    else float(_n_windows(cfg, rung))
                )
            )
            scored.append((rung, recall, mean_cand, self._plan_cost(cfg, rung, mean_cand)))
        return scored, success

    def _select(self, scored, quality: QualitySpec):
        """Pick the winning rung from a calibrated ``scored`` list: cheapest
        meeting the recall target (then the latency budget), with the
        documented best-effort fallbacks + warnings. Returns the scored
        tuple ``(rung, recall, mean_cand, cost)``."""
        budget = None
        if quality.latency_budget_ms is not None:
            budget = quality.latency_budget_ms * self.candidates_per_ms
        meets_recall = [s for s in scored if s[1] >= quality.recall_target - 1e-9]
        feasible = [s for s in meets_recall if budget is None or s[2] <= budget]
        if feasible:
            return min(feasible, key=lambda s: s[3])
        if meets_recall:
            # recall is reachable but not inside the budget: keep the recall
            # guarantee, take the cheapest such plan, and say so — the budget
            # is a coarse model, the recall target is the contract
            plan, recall, mean_cand, cost = min(meets_recall, key=lambda s: s[3])
            warnings.warn(
                f"planner: no plan meets recall_target={quality.recall_target} "
                f"within latency_budget_ms={quality.latency_budget_ms} "
                f"(cheapest conforming plan examines ~{mean_cand:.0f} "
                f"candidates/query, budget admits {budget:.0f}); keeping the "
                f"recall target — relax one of the two",
                stacklevel=2,
            )
            return plan, recall, mean_cand, cost
        # best effort: highest calibrated recall, cheapest among ties
        plan, recall, mean_cand, cost = max(scored, key=lambda s: (s[1], -s[3]))
        warnings.warn(
            f"planner: no execution plan reaches recall_target="
            f"{quality.recall_target} on this index "
            f"(best calibrated recall {recall:.3f} via {plan.mode}); "
            f"rebuild with a QualitySpec (or more tables / a wider "
            f"max_candidates window) to close the gap",
            stacklevel=2,
        )
        return plan, recall, mean_cand, cost

    @staticmethod
    def _stamp(scored_entry, success: float) -> PlannedSpec:
        rung, recall, mean_cand, _ = scored_entry
        return dataclasses.replace(
            rung,
            predicted_recall=recall,
            predicted_success=success,
            expected_candidates=mean_cand,
            provenance="calibrated",
        )

    # -- empirical prior (offline tuning table) ------------------------------
    def _config_from_prior(
        self, n: int, d: int, quality: QualitySpec, family: str, M_eff: int,
        space: BoundedSpace,
    ) -> "IndexConfig | None":
        """Build geometry from the tuning table's nearest-profile frontier:
        the cheapest entry meeting the recall target. None (→ run the
        theory inversion) when there is no table, no in-tolerance bucket,
        or the scanned grid never reached the target on this profile."""
        if self.table is None:
            return None
        # family="auto" must consider every family's bucket: the nearest
        # bucket alone may be a family whose frontier never reached the
        # goal while another family's did.
        candidates = ("theta", "l2") if family == "auto" else (family,)
        goal = max(quality.recall_target, 1.0 - quality.fail_prob)
        entry = None
        for fam in candidates:
            bucket = self.table.nearest_bucket(fam, n, d, self.profile_skew)
            if bucket is None:
                continue
            e = self.table.best_entry(bucket, goal)
            if e is None:
                continue
            if entry is None or (e["cost"], e["trial_id"]) < (
                entry["cost"], entry["trial_id"]
            ):
                entry = e
        if entry is None:
            return None
        return IndexConfig(
            d=d, M=M_eff, K=entry["K"], L=entry["L"], family=entry["family"],
            W=float(entry["W"]), max_candidates=entry["window"], space=space,
        )

    def _entry_matches_config(self, entry: dict, cfg: IndexConfig) -> bool:
        """A frontier entry's execution plan only transfers to an index
        whose BUILT geometry matches the scanned trial's."""
        if entry["family"] != cfg.family or entry["K"] != cfg.K or entry["L"] != cfg.L:
            return False
        if cfg.family == "l2" and not math.isclose(
            float(entry["W"]), cfg.W, rel_tol=1e-6
        ):
            return False
        if entry["window"] > cfg.max_candidates:
            return False
        if entry["n_probes"] > 1 and entry["n_probes"] > n_flip_subsets(
            cfg.K, entry["max_flips"]
        ):
            return False
        return True

    def _plan_from_prior(self, index, quality: QualitySpec) -> "PlannedSpec | None":
        """Resolve the execution plan from the tuning table: nearest-profile
        frontier entry meeting the target, confirmed by ONE probe of the
        real query path on the calibration sample (instead of the full
        ladder). None → caller falls back to full calibration. The
        confirmation is what keeps the 2 pt adherence bar honest when the
        prior's profile only approximately matches this index."""
        if self.table is None:
            return None
        from repro.distance import recall_at_k

        cfg = index.config
        bucket = self.table.nearest_bucket(
            cfg.family, index.n, cfg.d, self.profile_skew
        )
        if bucket is None:
            return None
        candidates = [
            e for e in bucket["entries"]
            if e["recall"] >= quality.recall_target - 1e-9
            and self._entry_matches_config(e, cfg)
        ]
        if not candidates:
            return None
        entry = min(candidates, key=lambda e: (e["cost"], e["trial_id"]))
        rung = PlannedSpec(
            k=quality.k,
            mode="multiprobe" if entry["n_probes"] > 1 else "probe",
            n_probes=entry["n_probes"] if entry["n_probes"] > 1 else 1,
            max_flips=entry["max_flips"] if entry["n_probes"] > 1 else 0,
            max_candidates=entry["window"],
            # older tables predate the early-exit axes — default off
            early_exit=bool(entry.get("early_exit", False)),
            exit_group=int(entry.get("exit_group") or 8),
            exit_slack=float(entry.get("exit_slack") or 0.0),
        )
        qs, ws, exact = self._calibration_sample(index, quality)
        res = index.query(qs, ws, rung)
        recall = float(recall_at_k(res.ids, exact.ids, quality.k))
        if recall < quality.recall_target - self.confirm_slack:
            return None  # prior overpromised on THIS index — calibrate fully
        mean_cand = float(jnp.mean(res.n_candidates))
        if quality.latency_budget_ms is not None and mean_cand > (
            quality.latency_budget_ms * self.candidates_per_ms
        ):
            return None  # budget-infeasible prior: let _select arbitrate
        return dataclasses.replace(
            rung,
            predicted_recall=recall,
            predicted_success=self._operating_success(cfg, exact, ws),
            expected_candidates=mean_cand,
            expected_tables=(
                float(jnp.mean(res.tables_probed))
                if res.tables_probed is not None
                # never memoize the NaN field default: nan != nan would
                # break the save/load plan-equality contract
                else float(_n_windows(cfg, rung))
            ),
            provenance="prior",
        )

    def plan_query(self, index, quality: QualitySpec) -> PlannedSpec:
        """Resolve the execution plan for ``quality`` on ``index`` (a built
        ``repro.api.Index``). With a tuning-table prior whose profile covers
        this index, a single confirmation probe replaces the calibration
        ladder (plan stamped ``provenance="prior"``); otherwise calibrate
        every ladder rung and return the cheapest plan meeting
        ``quality.recall_target`` (best-effort + warning when none does;
        ``provenance="calibrated"``)."""
        planned = self._plan_from_prior(index, quality)
        if planned is not None:
            return planned
        scored, success = self._calibrate(index, quality)
        return self._stamp(self._select(scored, quality), success)

    def plan_ladder(self, index, quality: QualitySpec) -> tuple[PlannedSpec, ...]:
        """The DEGRADATION ladder of an index for ``quality``: rung 0 is
        exactly the plan ``plan_query`` would pick (the serving operating
        point); every later rung is strictly cheaper under the plan cost
        model — fewer probes, then single-probe, then shrinking candidate
        windows — down to the cheapest rung the geometry supports. Every
        rung is stamped with its CALIBRATED ``predicted_recall`` /
        ``predicted_success`` (Eq 25/27 at the calibrated operating radius),
        so a serving tier stepping down the ladder under load can label each
        degraded response with the recall it gave up instead of degrading
        silently. Deterministic given (index, ``quality.seed``) — one
        calibration pass scores every rung. Ladders always calibrate in
        full (every rung needs its own measured recall label), so the
        tuning-table prior never shortcuts this path."""
        scored, success = self._calibrate(index, quality)
        chosen = self._select(scored, quality)
        cheaper = sorted((s for s in scored if s[3] < chosen[3]), key=lambda s: -s[3])
        return tuple(
            self._stamp(s, success) for s in [chosen, *cheaper]
        )

    @staticmethod
    def _collision_prob(cfg: IndexConfig, r: float, w) -> float:
        """Eq 25/27 at distance r under weight profile w (family dispatch)."""
        if cfg.family == "l2":
            return float(
                theory.collision_prob_l2(jnp.asarray(r), cfg.M, cfg.d, w, cfg.W)
            )
        return float(theory.collision_prob_theta(jnp.asarray(r), cfg.M, cfg.d, w))
