"""Deterministic, resumable, shard-aware synthetic data pipeline.

Restart semantics by construction: the batch for step s is a pure function of
(seed, step, shard), so resuming from a checkpoint at step s reproduces the
exact remaining stream — no iterator state to persist beyond the step counter
(which lives in the train state). This is also the straggler/elastic story:
any host can compute any shard's batch for any step, so backup workers and
re-sharding after membership changes need no data re-coordination.

The synthetic LM stream is structured (Zipf-ish marginals + a Markov-like
local dependency) so a ~100M-param model visibly learns within a few hundred
steps in examples/train_small.py rather than flat-lining at log V.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 1234
    n_shards: int = 1  # data-parallel shards
    shard_id: int = 0


class SyntheticStream:
    """Synthetic token/frame stream; ``batch(step)`` is pure in (cfg, step)."""

    def __init__(self, dcfg: DataConfig, mcfg: ModelConfig):
        self.dcfg = dcfg
        self.mcfg = mcfg
        assert dcfg.global_batch % dcfg.n_shards == 0
        self.local_batch = dcfg.global_batch // dcfg.n_shards

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.dcfg.seed * 1_000_003 + step) * 4099 + self.dcfg.shard_id
        )

    def _lm_tokens(self, rng, batch: int, seq: int, vocab: int) -> np.ndarray:
        # Zipf-ish unigram + short-range repetition structure
        base = rng.zipf(1.3, size=(batch, seq)).astype(np.int64) % vocab
        rep = rng.random((batch, seq)) < 0.35
        shifted = np.roll(base, 3, axis=1)
        out = np.where(rep, shifted, base)
        return out.astype(np.int32)

    def batch(self, step: int) -> dict:
        d, m = self.dcfg, self.mcfg
        rng = self._rng(step)
        B, S = self.local_batch, d.seq_len
        if m.frontend == "audio":
            targets = self._lm_tokens(rng, B, S, m.vocab_size)
            # frames correlate with targets so masked prediction is learnable
            proj = rng.standard_normal((m.vocab_size, m.frontend_dim)).astype(np.float32)
            frames = proj[targets] + 0.1 * rng.standard_normal(
                (B, S, m.frontend_dim)
            ).astype(np.float32)
            mask = rng.random((B, S)) < 0.3
            return {"frames": frames, "targets": targets, "mask": mask}
        if m.frontend == "vision":
            nv = min(m.n_vision_tokens, S // 2)  # clamp for tiny test seqs
            tokens = self._lm_tokens(rng, B, S - nv, m.vocab_size)
            patches = rng.standard_normal((B, nv, m.frontend_dim)).astype(np.float32)
            t = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S))
            positions = np.stack([t, t, t])  # text-equivalent 3D grid stub
            return {"tokens": tokens, "patches": patches, "positions": positions}
        return {"tokens": self._lm_tokens(rng, B, S, m.vocab_size)}
