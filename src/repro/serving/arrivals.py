"""Arrival-time traces for the serving broker.

The broker is driven by an explicit trace of request arrival times (seconds,
ascending) instead of a live socket: the same ragged-arrival dynamics —
queueing, coalescing, overload — with full determinism (every trace is a
pure function of its seed), which is what lets the SLO/chaos tests assert
exact broker behavior and the benchmark report reproducible latency
distributions.

Two canonical shapes:

  * ``poisson_trace`` — memoryless arrivals at a constant rate; the
    steady-traffic baseline.
  * ``bursty_trace`` — a square-wave modulated Poisson process (ON windows
    at ``burst_hz``, OFF windows at ``base_hz``): the overload drill. Bursts
    above the engine's service rate are exactly what the degradation ladder
    and admission control exist for.
"""

from __future__ import annotations

import numpy as np


def poisson_trace(rate_hz: float, n: int, seed: int = 0, t0: float = 0.0) -> np.ndarray:
    """(n,) ascending arrival times of a Poisson process at ``rate_hz``."""
    if rate_hz <= 0:
        raise ValueError(f"poisson_trace rate_hz must be positive, got {rate_hz}")
    rng = np.random.default_rng(seed)
    return t0 + np.cumsum(rng.exponential(1.0 / rate_hz, size=n))


def bursty_trace(
    base_hz: float,
    burst_hz: float,
    n: int,
    seed: int = 0,
    period_s: float = 1.0,
    duty: float = 0.25,
    t0: float = 0.0,
) -> np.ndarray:
    """(n,) arrival times of a square-wave modulated Poisson process.

    Each ``period_s`` window opens with a burst phase (``duty`` fraction of
    the period at ``burst_hz``) and relaxes to ``base_hz`` for the rest —
    the classic flash-crowd shape. Sampled by thinning a ``burst_hz``
    homogeneous process, so the inter-arrival structure inside a burst is
    exactly Poisson.
    """
    if not (0.0 < duty <= 1.0):
        raise ValueError(f"bursty_trace duty must be in (0, 1], got {duty}")
    if burst_hz < base_hz or base_hz <= 0:
        raise ValueError(
            f"bursty_trace needs burst_hz >= base_hz > 0, got "
            f"base_hz={base_hz}, burst_hz={burst_hz}"
        )
    rng = np.random.default_rng(seed)
    out = np.empty(n)
    t = t0
    i = 0
    while i < n:
        t += rng.exponential(1.0 / burst_hz)
        phase = (t % period_s) / period_s
        # thinning: outside the burst window keep with prob base/burst
        if phase < duty or rng.random() < base_hz / burst_hz:
            out[i] = t
            i += 1
    return out


def make_trace(kind: str, rate_hz: float, n: int, seed: int = 0, **kw) -> np.ndarray:
    """CLI/bench dispatcher: ``kind`` is "poisson" or "bursty" (bursty
    bursts at 4x the stated rate with the default duty cycle)."""
    if kind == "poisson":
        return poisson_trace(rate_hz, n, seed=seed, **kw)
    if kind == "bursty":
        return bursty_trace(rate_hz, 4.0 * rate_hz, n, seed=seed, **kw)
    raise ValueError(f"unknown arrival trace kind {kind!r} (poisson | bursty)")
