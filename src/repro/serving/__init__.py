"""Fault-tolerant serving tier: broker, SLO degradation, shard chaos.

Quickstart::

    from repro.serving import (
        Broker, BrokerConfig, SLOConfig, ShardSet, ChaosPlan,
        poisson_trace, requests_from_trace,
    )

    index = Index.build(key, data, QualitySpec(k=10, recall_target=0.9))
    shards = ShardSet.build(index, n_shards=4, root="/tmp/shards")
    shards.chaos = ChaosPlan(kill_shard=1, kill_at_s=0.5)
    broker = Broker(index, quality, SLOConfig(p99_ms=50.0), shardset=shards)
    reqs = requests_from_trace(poisson_trace(200.0, 1000), Q, W)
    responses, stats = broker.run(reqs)

See the module docstrings (``broker``, ``slo``, ``chaos``, ``arrivals``)
and DESIGN.md §9 for the serving & failure contract.
"""

from repro.serving.arrivals import bursty_trace, make_trace, poisson_trace
from repro.serving.broker import (
    Broker,
    BrokerConfig,
    BrokerStats,
    Request,
    Response,
    requests_from_trace,
)
from repro.serving.chaos import ChaosPlan, ShardSet, ShardSetResult
from repro.serving.slo import DegradationController, LatencyTracker, SLOConfig

__all__ = [
    "Broker",
    "BrokerConfig",
    "BrokerStats",
    "ChaosPlan",
    "DegradationController",
    "LatencyTracker",
    "Request",
    "Response",
    "SLOConfig",
    "ShardSet",
    "ShardSetResult",
    "bursty_trace",
    "make_trace",
    "poisson_trace",
    "requests_from_trace",
]
