"""The serving broker: dynamic batching, SLO admission control, chaos.

One object in front of the PR 5 engine that turns ragged request arrivals
into the fixed-shape batched queries the jit cache wants, while keeping
the tail latency inside an SLO by *labeled* degradation instead of
unbounded queueing:

  * **Dynamic batching** — arrivals queue; each service round drains up to
    ``max_batch`` requests and pads them to the next power-of-two bucket,
    so every (bucket, rung) combination compiles exactly once at warmup.
    ``assert_no_retrace`` checks the jit cache did not grow after warmup —
    a retrace in steady state is a serving bug, not a slowdown to shrug at.
  * **Admission control** — a bounded queue (overflow ⇒ shed on arrival)
    and a per-request deadline (expired ⇒ shed at dequeue, not served
    uselessly late).
  * **Graceful degradation** — when the EWMA p99 breaches the SLO the
    controller steps down the index's calibrated plan ladder; every
    response is stamped with the rung served and that rung's calibrated
    ``predicted_recall``/``predicted_success``. Degraded answers are
    labeled, never silent.
  * **Chaos** — an optional :class:`~repro.serving.chaos.ShardSet` target
    with a scripted mid-stream shard kill: survivors keep answering (the
    response's ``coverage`` says how much of the database was consulted)
    while the broker's clock drives backoff-limited shard recovery.

Time model: a discrete-event loop over an explicit arrival trace (see
``arrivals``). The clock is *virtual* — it advances by each round's
service time, which is measured wall-clock by default (benchmarks, live
serving) or supplied by an injectable ``service_time_fn`` (deterministic
SLO tests, modeled overload). Queueing delay, deadlines, shedding, and
degradation dynamics are identical either way.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from time import perf_counter
from typing import Callable, Optional

import numpy as np

from repro.analysis.retrace_guard import RetraceGuard
from repro.api.index import Index
from repro.api.spec import PlannedSpec, QualitySpec
from repro.serving.chaos import ShardSet
from repro.serving.slo import DegradationController, LatencyTracker, SLOConfig


@dataclass(frozen=True)
class Request:
    """One query in flight: a single (q, w) row plus its arrival time."""

    rid: int
    arrival_s: float
    query: np.ndarray  # (d,)
    weight: np.ndarray  # (d,)


@dataclass(frozen=True)
class Response:
    rid: int
    status: str  # "ok" | "degraded" | "shed"
    ids: Optional[np.ndarray]  # (k,) global ids; None when shed
    dists: Optional[np.ndarray]  # (k,) distances; None when shed
    rung: int  # ladder rung served (0 = full quality)
    spec: Optional[PlannedSpec]  # the plan actually executed
    predicted_recall: float  # calibrated recall of that rung
    predicted_success: float  # Thm 1 success bound of that rung
    coverage: float  # fraction of shards consulted (1.0 single-host)
    latency_ms: float  # arrival -> answer in broker virtual time
    shed_reason: Optional[str] = None  # "queue_full" | "deadline"


@dataclass(frozen=True)
class BrokerConfig:
    max_batch: int = 64
    max_queue: int = 256
    warmup: bool = True


@dataclass
class BrokerStats:
    served: int = 0
    shed: int = 0
    degraded: int = 0
    p50_ms: float = 0.0
    p99_ms: float = 0.0
    throughput_rps: float = 0.0
    shed_rate: float = 0.0
    degraded_frac: float = 0.0
    mean_coverage: float = 1.0
    rung_counts: dict = field(default_factory=dict)
    degrades: int = 0
    recoveries: int = 0


def _bucket_ladder(max_batch: int) -> list:
    out, b = [], 1
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(max_batch)
    return out


class Broker:
    """Discrete-event serving broker over an Index or ShardSet target.

    The quality contract (``QualitySpec``) is resolved ONCE into the
    degradation ladder via ``index.plan_ladder`` — rung 0 is the planner's
    contract-meeting choice, later rungs strictly cheaper. The broker never
    invents query parameters; it only moves along calibrated rungs.
    """

    def __init__(
        self,
        index: Index,
        quality: QualitySpec,
        slo: SLOConfig,
        config: BrokerConfig = BrokerConfig(),
        shardset: Optional[ShardSet] = None,
        service_time_fn: Optional[Callable[[int, int, PlannedSpec], float]] = None,
    ):
        self.index = index
        self.quality = quality
        self.slo = slo
        self.config = config
        self.shardset = shardset
        self.service_time_fn = service_time_fn
        self.ladder = index.plan_ladder(quality)
        self.buckets = _bucket_ladder(config.max_batch)
        self.tracker = LatencyTracker(slo)
        self.controller = DegradationController(slo, len(self.ladder))
        self._retrace_guard = RetraceGuard()  # watches the shared engine jit
        if config.warmup:
            self.warmup()

    # -- compilation contract ------------------------------------------------
    def bucket_for(self, m: int) -> int:
        for b in self.buckets:
            if m <= b:
                return b
        return self.buckets[-1]

    def _targets(self):
        if self.shardset is not None:
            return [s for s in self.shardset.shards if s is not None]
        return [self.index]

    def warmup(self) -> None:
        """Compile every (bucket, rung) combination up front, then snapshot
        the engine's jit cache size — steady-state serving must never
        trace."""
        d = self.index.d
        for b in self.buckets:
            q = np.zeros((b, d), np.float32)
            w = np.ones((b, d), np.float32)
            for spec in self.ladder:
                for t in self._targets():
                    t.query(q, w, spec)
        self._retrace_guard.snapshot()

    def assert_no_retrace(self) -> None:
        """Raise if the engine jit cache grew since warmup (a shape or
        static-arg leak in the bucket/rung plumbing). Delegates to the
        shared :class:`repro.analysis.retrace_guard.RetraceGuard` — the
        error is a ``RetraceError`` (an ``AssertionError`` subclass)."""
        if not self._retrace_guard.snapshotted:
            raise RuntimeError("assert_no_retrace needs warmup() first")
        self._retrace_guard.assert_no_retrace(
            context="serving (a bucket or rung not covered by warmup)"
        )

    # -- the service loop ----------------------------------------------------
    def _execute(self, q: np.ndarray, w: np.ndarray, spec: PlannedSpec, now_s: float):
        """(dists, ids, coverage, measured_dt_s) for one padded bucket."""
        t0 = perf_counter()
        if self.shardset is not None:
            res = self.shardset.query(q, w, spec, now_s=now_s)
            dists, ids, cov = res.dists, res.ids, res.coverage
        else:
            res = self.index.query(q, w, spec)
            dists = np.asarray(res.dists)
            ids = np.asarray(res.ids)
            cov = 1.0
        return dists, ids, cov, perf_counter() - t0

    def run(self, requests: list) -> tuple[list, BrokerStats]:
        """Serve an arrival-ordered request list to completion.

        Returns (responses in completion order, aggregate stats). Every
        request gets exactly one Response — served (ok/degraded) or shed
        with a reason.
        """
        d = self.index.d
        deadline_s = self.slo.effective_deadline_ms / 1e3
        queue: deque = deque()
        responses: list = []
        clock = 0.0
        i, n = 0, len(requests)

        def shed(req: Request, reason: str, t: float) -> None:
            responses.append(
                Response(
                    rid=req.rid,
                    status="shed",
                    ids=None,
                    dists=None,
                    rung=self.controller.rung,
                    spec=None,
                    predicted_recall=0.0,
                    predicted_success=0.0,
                    coverage=0.0,
                    latency_ms=(t - req.arrival_s) * 1e3,
                    shed_reason=reason,
                )
            )

        while i < n or queue:
            if not queue and requests[i].arrival_s > clock:
                clock = requests[i].arrival_s  # idle: jump to next arrival
            while i < n and requests[i].arrival_s <= clock:
                if len(queue) >= self.config.max_queue:
                    shed(requests[i], "queue_full", requests[i].arrival_s)
                else:
                    queue.append(requests[i])
                i += 1
            if self.shardset is not None:
                self.shardset.tick(clock)
            batch: list = []
            while queue and len(batch) < self.config.max_batch:
                req = queue.popleft()
                if clock - req.arrival_s > deadline_s:
                    shed(req, "deadline", clock)
                else:
                    batch.append(req)
            if not batch:
                continue

            rung = self.controller.rung
            spec = self.ladder[rung]
            bucket = self.bucket_for(len(batch))
            q = np.zeros((bucket, d), np.float32)
            w = np.ones((bucket, d), np.float32)
            for j, req in enumerate(batch):
                q[j] = req.query
                w[j] = req.weight
            dists, ids, cov, measured_dt = self._execute(q, w, spec, clock)
            dt = (
                self.service_time_fn(bucket, rung, spec)
                if self.service_time_fn is not None
                else measured_dt
            )
            clock += dt

            degraded = rung > 0 or cov < 1.0
            for j, req in enumerate(batch):
                lat_ms = (clock - req.arrival_s) * 1e3
                self.tracker.observe(lat_ms)
                responses.append(
                    Response(
                        rid=req.rid,
                        status="degraded" if degraded else "ok",
                        ids=ids[j].copy(),
                        dists=dists[j].copy(),
                        rung=rung,
                        spec=spec,
                        predicted_recall=float(spec.predicted_recall),
                        predicted_success=float(spec.predicted_success),
                        coverage=cov,
                        latency_ms=lat_ms,
                    )
                )
            self.controller.on_batch(self.tracker.p99_ms, not queue)

        return responses, self._stats(responses, requests)

    def _stats(self, responses: list, requests: list) -> BrokerStats:
        served = [r for r in responses if r.status != "shed"]
        shed = [r for r in responses if r.status == "shed"]
        stats = BrokerStats(served=len(served), shed=len(shed))
        stats.degrades = self.controller.degrades
        stats.recoveries = self.controller.recoveries
        if responses:
            stats.shed_rate = len(shed) / len(responses)
        if served:
            lats = np.array([r.latency_ms for r in served])
            stats.p50_ms = float(np.percentile(lats, 50))
            stats.p99_ms = float(np.percentile(lats, 99))
            stats.degraded = sum(1 for r in served if r.status == "degraded")
            stats.degraded_frac = stats.degraded / len(served)
            stats.mean_coverage = float(
                np.mean([r.coverage for r in served])
            )
            for r in served:
                stats.rung_counts[r.rung] = stats.rung_counts.get(r.rung, 0) + 1
            t0 = min(r.arrival_s for r in requests)
            t1 = max(r.arrival_s for r in requests) + max(lats) / 1e3
            if t1 > t0:
                stats.throughput_rps = len(served) / (t1 - t0)
        return stats


def requests_from_trace(
    arrivals: np.ndarray, queries: np.ndarray, weights: np.ndarray
) -> list:
    """Zip an arrival trace with query/weight rows (cycled if shorter)
    into an arrival-ordered Request list."""
    nq = queries.shape[0]
    return [
        Request(
            rid=r,
            arrival_s=float(t),
            query=np.asarray(queries[r % nq], np.float32),
            weight=np.asarray(weights[r % nq], np.float32),
        )
        for r, t in enumerate(arrivals)
    ]
