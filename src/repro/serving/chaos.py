"""Shard fault injection and degraded-coverage answering for serving.

The serving tier's distribution unit is the :class:`ShardSet`: one
single-host :class:`~repro.api.index.Index` per contiguous row range
(``shard_row_ranges``), all built from the SAME ``build_key`` — so hash
tables are identical across shards and a query hashes once conceptually,
exactly the contract of ``core.distributed``. Unlike the mesh-collective
``ShardedIndex`` (one jit program over one device mesh), each ShardSet
member is an independently killable and recoverable process stand-in,
which is what a chaos drill needs:

  * ``arm_failure(s)`` makes shard ``s`` raise ``SimulatedFailure`` from
    its next query — the death happens MID-STREAM, inside a batch that
    other shards answer.
  * a dead shard contributes a full sentinel block (``ids == -1``,
    ``dists == +inf``) to the host merge; the response carries
    ``coverage = live/S`` so a survivors-only answer is labeled, never
    silent.
  * recovery rebuilds the lost shard from its persisted directory (the v3
    manifest written at build time) under a capped exponential backoff in
    the broker's virtual clock, with the first ``recovery_failures``
    attempts injected to fail — exercising the retry path, not just the
    happy one. Deterministic save/load + the stable host merge make
    post-recovery answers bit-identical to pre-failure ones.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import NamedTuple, Optional

import numpy as np

from repro import quant
from repro.api.index import Index
from repro.core.distributed import merge_topk_host, shard_row_ranges
from repro.runtime.fault import SimulatedFailure


@dataclass(frozen=True)
class ChaosPlan:
    """One scripted shard failure + its recovery policy.

    ``kill_at_s`` is in the broker's virtual clock; the kill is armed when
    the clock passes it, so the shard dies inside whatever batch is in
    flight. The first ``recovery_failures`` reload attempts are injected
    to fail, each pushing the next attempt out by
    ``min(backoff_base_s · 2^i, backoff_cap_s)``.
    """

    kill_shard: int = 0
    kill_at_s: float = 0.0
    recovery_failures: int = 2
    backoff_base_s: float = 0.05
    backoff_cap_s: float = 1.0


class ShardSetResult(NamedTuple):
    dists: np.ndarray  # (b, k) ascending; +inf sentinels
    ids: np.ndarray  # (b, k) GLOBAL row ids; -1 sentinels
    n_candidates: np.ndarray  # (b,) summed over live shards
    coverage: float  # live_shards / n_shards at answer time


@dataclass
class ShardSet:
    """Host-side set of per-range indexes with kill/recover lifecycle."""

    shards: list  # Optional[Index] per slot; None while dead
    offsets: list  # global row offset per shard
    dirs: list  # persisted directory per shard (the recovery source)
    n_rows: int
    chaos: Optional[ChaosPlan] = None
    recovery_log: list = field(default_factory=list)
    _armed: list = field(default_factory=list)
    _chaos_fired: bool = False
    _recover_attempts: dict = field(default_factory=dict)
    _next_attempt_s: dict = field(default_factory=dict)

    def __post_init__(self):
        if not self._armed:
            self._armed = [False] * len(self.shards)

    @classmethod
    def build(cls, index: Index, n_shards: int, root: str) -> "ShardSet":
        """Split ``index``'s rows into contiguous shards, build each with
        the parent's ``build_key`` (⇒ identical tables; a shard's local id
        plus its range offset IS the global id), and persist every shard
        under ``root/shard_<s>`` for later recovery."""
        ranges = shard_row_ranges(index.n, n_shards)
        # decode quantized payloads back to f32 rows: Index.build re-encodes
        # each shard with its own scales, so every shard is self-consistent
        data = quant.decode_table(index.state.data, index.state.scales)
        shards, offsets, dirs = [], [], []
        for s, (lo, hi) in enumerate(ranges):
            shard = Index.build(index.build_key, data[lo:hi], index.config)
            d = os.path.join(root, f"shard_{s}")
            shard.save(d)
            # serve the LOADED artifact, not the freshly-built object: a
            # recovered shard is then leaf-for-leaf identical (dtype, weak
            # type, device commitment) to the one it replaces, so recovery
            # can never grow the engine's jit cache
            shards.append(Index.load(d))
            offsets.append(lo)
            dirs.append(d)
        return cls(shards=shards, offsets=offsets, dirs=dirs, n_rows=index.n)

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def live(self) -> list:
        return [s is not None for s in self.shards]

    @property
    def coverage(self) -> float:
        return sum(self.live) / self.n_shards

    # -- failure injection ---------------------------------------------------
    def arm_failure(self, s: int) -> None:
        """Next query touching shard ``s`` raises SimulatedFailure (caught
        by ``query`` — the shard dies, the batch is answered by survivors)."""
        self._armed[s] = True

    def _on_death(self, s: int, now_s: float) -> None:
        self.shards[s] = None
        self._recover_attempts[s] = 0
        self._next_attempt_s[s] = now_s + (
            self.chaos.backoff_base_s if self.chaos else 0.05
        )
        self.recovery_log.append(
            {"t_s": now_s, "shard": s, "event": "killed"}
        )

    def tick(self, now_s: float) -> None:
        """Advance the chaos script to virtual time ``now_s``: fire the
        scripted kill once the clock passes ``kill_at_s``, and run due
        recovery attempts (with injected failures + capped exponential
        backoff) for every dead shard."""
        if (
            self.chaos is not None
            and not self._chaos_fired
            and now_s >= self.chaos.kill_at_s
        ):
            self._chaos_fired = True
            self.arm_failure(self.chaos.kill_shard)
        for s in range(self.n_shards):
            if self.shards[s] is None and now_s >= self._next_attempt_s.get(
                s, float("inf")
            ):
                self._attempt_recovery(s, now_s)

    def _attempt_recovery(self, s: int, now_s: float) -> None:
        plan = self.chaos or ChaosPlan(kill_shard=s)
        i = self._recover_attempts[s]
        self._recover_attempts[s] = i + 1
        backoff = min(plan.backoff_base_s * 2.0**i, plan.backoff_cap_s)
        try:
            if i < plan.recovery_failures:
                raise SimulatedFailure(
                    f"injected recovery failure {i + 1}/{plan.recovery_failures} "
                    f"for shard {s}"
                )
            self.shards[s] = Index.load(self.dirs[s])
        except SimulatedFailure as e:
            self._next_attempt_s[s] = now_s + backoff
            self.recovery_log.append(
                {
                    "t_s": now_s,
                    "shard": s,
                    "event": "recover_failed",
                    "attempt": i + 1,
                    "next_backoff_s": backoff,
                    "error": str(e),
                }
            )
        else:
            del self._next_attempt_s[s]
            self.recovery_log.append(
                {"t_s": now_s, "shard": s, "event": "recovered", "attempt": i + 1}
            )

    def recover_now(self, s: int) -> None:
        """Unconditional reload (tests / manual ops)."""
        self.shards[s] = Index.load(self.dirs[s])
        self._next_attempt_s.pop(s, None)

    # -- querying ------------------------------------------------------------
    def query(self, queries, weights, spec, now_s: float = 0.0) -> ShardSetResult:
        """Fan a batch over the live shards and host-merge to global top-k.

        Pass the resolved :class:`~repro.api.spec.PlannedSpec` (or a raw
        QuerySpec) — a QualitySpec would trigger a per-shard calibration.
        An armed failure raises from its shard's query and is caught HERE:
        the shard is marked dead mid-batch and the remaining shards still
        answer, with ``coverage`` reflecting the loss.
        """
        blocks_d, blocks_i, n_cand = [], [], None
        b = queries.shape[0]
        k = spec.k
        sent_d = np.full((b, k), np.inf)
        sent_i = np.full((b, k), -1, dtype=np.int64)
        for s in range(self.n_shards):
            if self.shards[s] is None:
                blocks_d.append(sent_d)
                blocks_i.append(sent_i)
                continue
            try:
                if self._armed[s]:
                    self._armed[s] = False
                    raise SimulatedFailure(f"shard {s} killed mid-stream")
                res = self.shards[s].query(queries, weights, spec)
            except SimulatedFailure:
                self._on_death(s, now_s)
                blocks_d.append(sent_d)
                blocks_i.append(sent_i)
                continue
            ids = np.asarray(res.ids, dtype=np.int64)
            blocks_d.append(np.asarray(res.dists, dtype=np.float64))
            blocks_i.append(np.where(ids >= 0, ids + self.offsets[s], -1))
            nc = np.asarray(res.n_candidates, dtype=np.int64)
            n_cand = nc if n_cand is None else n_cand + nc
        if n_cand is None:
            n_cand = np.zeros((b,), np.int64)
        dists, ids = merge_topk_host(np.stack(blocks_d), np.stack(blocks_i), k)
        return ShardSetResult(dists, ids, n_cand, self.coverage)
