"""SLO tracking and graceful degradation for the serving broker.

Admission control here is *quality-aware*: when the observed p99 breaches
the SLO the broker does not just shed load — it first walks down the
index's calibrated plan ladder (``Index.plan_ladder``), trading predicted
recall for candidate volume one rung at a time. Every degraded response is
stamped with the rung and the planner's calibrated ``predicted_recall`` /
``predicted_success`` for that rung, so a degraded answer is *labeled*,
never silent. Shedding (deadline expiry, queue overflow) is the last
resort, applied per-request before the batch is formed.

The latency estimate is the ``StragglerMonitor`` EWMA from runtime/fault.py
with ``k_sigma=inf``: unlike the training straggler rule (which must NOT
fold outliers into its baseline), an admission controller must fold its
own overload signal into the estimate or it would never react.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from repro.runtime.fault import StragglerMonitor


@dataclass(frozen=True)
class SLOConfig:
    """Service-level objective and controller tuning.

    ``p99_ms`` is the target tail latency. ``deadline_ms`` (default 4x the
    SLO) is the per-request hard deadline: a request still queued past it is
    shed rather than served uselessly late. The controller degrades one rung
    per breached batch and recovers one rung after ``patience`` consecutive
    healthy batches (p99 under ``recover_factor``·SLO *and* an empty queue) —
    the asymmetry damps flapping at the SLO boundary.
    """

    p99_ms: float
    deadline_ms: float | None = None
    recover_factor: float = 0.6
    patience: int = 8
    alpha: float = 0.2
    z_p99: float = 2.326

    @property
    def effective_deadline_ms(self) -> float:
        return self.deadline_ms if self.deadline_ms is not None else 4.0 * self.p99_ms


class LatencyTracker:
    """EWMA p99 estimate over observed per-request latencies (ms)."""

    def __init__(self, slo: SLOConfig):
        self._slo = slo
        self._mon = StragglerMonitor(alpha=slo.alpha, k_sigma=math.inf)

    def observe(self, latency_ms: float) -> None:
        self._mon.observe(self._mon.n, latency_ms)

    @property
    def p99_ms(self) -> float:
        return self._mon.ewma_quantile(self._slo.z_p99)

    @property
    def n(self) -> int:
        return self._mon.n


class DegradationController:
    """Walks the calibrated plan ladder in response to SLO breaches.

    Rung 0 is the plan the Planner would have chosen for the recall target;
    rungs 1..R-1 are strictly cheaper, cost-descending. ``on_batch`` is
    called once per served batch with the tracker's current p99 and whether
    the queue drained; it moves at most one rung per call.
    """

    def __init__(self, slo: SLOConfig, n_rungs: int):
        if n_rungs < 1:
            raise ValueError(f"need at least one ladder rung, got {n_rungs}")
        self.slo = slo
        self.n_rungs = n_rungs
        self.rung = 0
        self.degrades = 0
        self.recoveries = 0
        self._healthy_streak = 0

    def on_batch(self, p99_ms: float, queue_empty: bool) -> int:
        """Update the active rung from the latest p99 estimate; returns it."""
        if p99_ms > self.slo.p99_ms:
            self._healthy_streak = 0
            if self.rung < self.n_rungs - 1:
                self.rung += 1
                self.degrades += 1
        elif p99_ms < self.slo.recover_factor * self.slo.p99_ms and queue_empty:
            self._healthy_streak += 1
            if self._healthy_streak >= self.slo.patience and self.rung > 0:
                self.rung -= 1
                self.recoveries += 1
                self._healthy_streak = 0
        else:
            self._healthy_streak = 0
        return self.rung
