"""Fault-tolerant training driver: checkpoint/restart, failure injection,
straggler telemetry.

The contract exercised by tests/test_fault.py:

  * deterministic data (pure function of step) + committed checkpoints ⇒
    a run killed at any step and restarted from the last COMMIT reproduces
    the uninterrupted run exactly (bitwise on CPU).
  * failures are injected as ``SimulatedFailure`` at arbitrary steps;
    ``run_with_restarts`` plays the coordinator: catch, restart from disk,
    resume. On a real cluster the coordinator is the job scheduler watching
    heartbeats — the restart path is identical.

Straggler mitigation: per-step wall-time telemetry with an EWMA + k·sigma
outlier rule (``StragglerMonitor``). On detection the deterministic data
pipeline lets any healthy host recompute the slow shard's batch (backup
tasks) or the mesh be rebuilt without it (elastic): both need zero data
re-coordination because batch(step, shard) is stateless — see data/pipeline.
"""

from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax

from repro import ckpt
from repro.configs.base import ArchBundle
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.runtime.train_step import TrainState, init_train_state, make_train_step


class SimulatedFailure(RuntimeError):
    """Injected node failure (tests / chaos drills)."""


@dataclass
class StragglerMonitor:
    """EWMA mean/variance over per-step (or per-request) durations.

    Two consumers share it: the training loop's straggler rule (``observe``
    flags k·sigma outliers WITHOUT folding them into the estimate — a
    straggler must not drag the baseline up), and the serving tier's latency
    tracker (``ewma_quantile`` — there ``k_sigma=inf`` so overload latencies
    DO update the estimate; an admission controller that ignored its own
    overload signal would never degrade).
    """

    alpha: float = 0.2
    k_sigma: float = 3.0
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        if self.n >= 3:
            sd = max(self.var**0.5, 1e-6)
            if dt > self.mean + self.k_sigma * sd:
                self.flagged.append((step, dt))
                return True
        delta = dt - self.mean
        self.mean += self.alpha * delta
        self.var = (1 - self.alpha) * (self.var + self.alpha * delta * delta)
        self.n += 1
        return False

    def ewma_quantile(self, z: float = 2.326) -> float:
        """Normal-approximation EWMA quantile: ``mean + z·sd`` (z=2.326 is
        the 99th percentile). A smoothed tail estimate, not an exact order
        statistic — what an SLO admission controller wants: responsive to
        sustained shifts, calm about single spikes."""
        return self.mean + z * max(self.var, 0.0) ** 0.5


def train_loop(
    bundle: ArchBundle,
    dcfg: DataConfig,
    steps: int,
    ckpt_dir: str,
    ckpt_every: int = 5,
    fail_at: Optional[int] = None,
    seed: int = 0,
    async_ckpt: bool = False,
    on_metrics: Optional[Callable[[int, dict], None]] = None,
) -> TrainState:
    """Run (or resume) training to ``steps``. Raises SimulatedFailure at
    ``fail_at`` AFTER the step executes but BEFORE its checkpoint commits —
    the nastiest spot."""
    mcfg, tcfg = bundle.model, bundle.train
    stream = SyntheticStream(dcfg, mcfg)
    step_fn = jax.jit(make_train_step(mcfg, tcfg))

    template = init_train_state(jax.random.PRNGKey(seed), mcfg, tcfg)
    start = ckpt.latest_step(ckpt_dir)
    if start is not None:
        state = ckpt.restore_checkpoint(ckpt_dir, start, template)
        step = start
    else:
        state = template
        step = 0
        ckpt.save_checkpoint(ckpt_dir, 0, state)

    monitor = StragglerMonitor()
    # the async saver is a context manager so an in-flight save is flushed
    # even when an exception (e.g. an injected SimulatedFailure) unwinds the
    # loop — otherwise the restart's latest_step read races the writer
    # thread and can resume from an OLDER commit than the one in flight
    with contextlib.ExitStack() as stack:
        saver = None
        if async_ckpt:
            saver = stack.enter_context(ckpt.AsyncCheckpointer(ckpt_dir))
        while step < steps:
            batch = {k: jax.numpy.asarray(v) for k, v in stream.batch(step).items()}
            t0 = time.monotonic()
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            monitor.observe(step, time.monotonic() - t0)
            step += 1
            if on_metrics:
                on_metrics(step, {k: float(v) for k, v in metrics.items()})
            if fail_at is not None and step == fail_at:
                raise SimulatedFailure(f"injected failure at step {step}")
            if step % ckpt_every == 0 or step == steps:
                if saver is not None:
                    saver.save(step, state)
                else:
                    ckpt.save_checkpoint(ckpt_dir, step, state)
    return state


def run_with_restarts(
    bundle: ArchBundle,
    dcfg: DataConfig,
    steps: int,
    ckpt_dir: str,
    failures: tuple = (),
    **kw,
) -> TrainState:
    """Coordinator: restart from the last commit after each injected failure."""
    pending = list(failures)
    while True:
        fail_at = pending[0] if pending else None
        try:
            return train_loop(
                bundle, dcfg, steps, ckpt_dir, fail_at=fail_at, **kw
            )
        except SimulatedFailure:
            pending.pop(0)  # the "node" died; restart resumes from disk
