"""Pipeline parallelism (gpipe-style) over a named mesh axis.

The generic engine: ``n_stages`` devices along ``axis`` each hold one stage's
parameters (leading stage dim sharded to size 1 locally). Microbatches enter
stage 0; activations advance one stage per tick via ``ppermute``; after
``n_micro + n_stages - 1`` ticks every microbatch has exited the last stage.
Bubble fraction = (P-1)/(n_micro+P-1) — the standard gpipe trade.

Differentiable end-to-end: ppermute's transpose is the reverse permutation,
so ``jax.grad`` through ``pipeline_apply`` yields exact pipelined backward
(tested against the sequential reference in tests/test_pipeline.py).

In the production mesh the "pod" axis is configured as DP for the dry-run
cells (both lower identically); this engine is the PP alternative for
pod-crossing training where DCN bandwidth can't carry full gradient
reduce-scatters — activations-only traffic scales with microbatch size, not
model size.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(
    stage_fn: Callable,  # (stage_params, x) -> y   (same pytree/shape both sides)
    stage_params,  # pytree, leading dim = n_stages
    x_micro: jax.Array,  # (n_micro, mb, ...) inputs for stage 0
    mesh: Mesh,
    axis: str = "pod",
):
    """Returns (n_micro, mb, ...) outputs of the final stage (replicated)."""
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    n_ticks = n_micro + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def local(params_local, xs):
        params0 = jax.tree.map(lambda p: p[0], params_local)  # local stage params
        stage = jax.lax.axis_index(axis)
        buf0 = jnp.zeros_like(xs[0])

        def tick(buf, t):
            # stage 0 ingests microbatch t (clipped; bubbles feed zeros)
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            inj = jax.lax.dynamic_index_in_dim(xs, mb_idx, 0, keepdims=False)
            x_in = jnp.where(stage == 0, inj, buf)
            y = stage_fn(params0, x_in)
            nxt = jax.lax.ppermute(y, axis, perm)
            return nxt, y

        _, ys = jax.lax.scan(tick, buf0, jnp.arange(n_ticks))  # (ticks, mb, ...)
        # microbatch m exits the LAST stage at tick m + (P-1)
        outs = jax.lax.dynamic_slice_in_dim(ys, n_stages - 1, n_micro, axis=0)
        # replicate final-stage outputs to every pipeline rank
        all_outs = jax.lax.all_gather(outs, axis)  # (P, n_micro, mb, ...)
        return all_outs[-1]

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False,
    )
    return fn(stage_params, x_micro)


def pipeline_loss(
    stage_fn: Callable,
    loss_fn: Callable,  # (y_final, target_micro) -> scalar (mean per microbatch)
    stage_params,
    x_micro: jax.Array,
    targets_micro,
    mesh: Mesh,
    axis: str = "pod",
):
    y = pipeline_apply(stage_fn, stage_params, x_micro, mesh, axis)
    losses = jax.vmap(loss_fn)(y, targets_micro)
    return jnp.mean(losses)
