"""jit-able serving steps: prefill and decode (optionally ALSH-augmented).

Sharding: batch over ("pod","data"); decode KV caches shard their SEQUENCE
dim over "model" (uniform across archs — head counts like kv=1 MQA can't
shard 16-way, sequence always can). GSPMD turns the seq-sharded attention
into partial-softmax + cross-shard reduction (flash-decode-style); the
roofline pass quantifies the collective cost per cell.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import models
from repro.configs.base import ModelConfig, RetrievalConfig
from repro.models.sharding import BATCH, get_mesh, sharding
from repro.runtime import retrieval as rt


def make_prefill_step(mcfg: ModelConfig, cache_len: Optional[int] = None):
    def prefill_step(params, batch):
        return models.forward_prefill(params, batch, mcfg, cache_len=cache_len)

    return prefill_step


def make_decode_step(
    mcfg: ModelConfig,
    rcfg: Optional[RetrievalConfig] = None,
):
    """decode_step(params, batch, caches [, retrieval_state]) -> (logits, tok, caches)."""

    if rcfg is None:

        def decode_step(params, batch, caches):
            return models.forward_decode(params, batch, caches, mcfg)

        return decode_step

    def decode_step_retr(params, batch, caches, retr_state: rt.RetrievalState):
        logits, _, new_caches, hidden = models.forward_decode(
            params, batch, caches, mcfg, return_hidden=True
        )
        knn_logp = rt.retrieve_logits(
            hidden, retr_state, rcfg, mcfg.vocab_size, weights=batch.get("retr_weights")
        )
        mixed = rt.interpolate(logits, knn_logp, rcfg.interp_lambda)
        next_tok = jnp.argmax(mixed, axis=-1).astype(jnp.int32)
        return mixed, next_tok, new_caches

    return decode_step_retr


def jit_decode_step(mcfg: ModelConfig, rcfg: Optional[RetrievalConfig] = None):
    """jit with production shardings (params FSDP/TP, caches seq-over-model)."""
    mesh = get_mesh()
    step = make_decode_step(mcfg, rcfg)
    if mesh is None:
        return jax.jit(step)
    pspecs = models.param_specs(mcfg)
    cspecs = models.cache_specs(mcfg)
    to_sh = lambda t: jax.tree.map(
        lambda s: sharding(*s), t, is_leaf=lambda s: isinstance(s, P)
    )
    bspec = {"token": sharding(BATCH), "pos": sharding(BATCH)}
    in_sh = (to_sh(pspecs), bspec, to_sh(cspecs))
    out_sh = (None, sharding(BATCH), to_sh(cspecs))
    return jax.jit(step, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=(2,))
