from repro.runtime.train_step import TrainState, init_train_state, make_train_step, train_state_specs
from repro.runtime.serve_step import make_decode_step, make_prefill_step

__all__ = [
    "TrainState",
    "init_train_state",
    "make_train_step",
    "train_state_specs",
    "make_decode_step",
    "make_prefill_step",
]
