"""The jit-able training step: fwd+bwd (+microbatch accumulation, optional
gradient compression) + AdamW update, with full sharding annotations.

in/out shardings: parameters and optimizer moments are ZeRO-3-sharded by the
``param_specs`` rules (FSDP over "data", TP/EP over "model"); the batch is
sharded over ("pod", "data"). XLA/GSPMD inserts the per-layer all-gathers
inside the scanned unit body (overlapping with compute) and reduce-scatters
for the grads — verified against the dry-run HLO in EXPERIMENTS.md §Dry-run.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import models, optim
from repro.configs.base import ModelConfig, TrainConfig
from repro.models.sharding import BATCH, get_mesh, sharding


class TrainState(NamedTuple):
    params: dict
    opt: optim.AdamWState


def init_train_state(key, mcfg: ModelConfig, tcfg: TrainConfig) -> TrainState:
    params = models.init_params(key, mcfg)
    return TrainState(params=params, opt=optim.init_opt_state(params, tcfg))


def train_state_specs(mcfg: ModelConfig, tcfg: TrainConfig) -> TrainState:
    pspecs = models.param_specs(mcfg)
    return TrainState(params=pspecs, opt=optim.opt_state_specs(pspecs, tcfg))


def batch_pytree_specs(batch_shape_tree) -> dict:
    """Batch inputs shard over ("pod","data") on the leading batch dim.

    The M-RoPE ``positions`` leaf is (3, B, S) — batch on dim 1.
    """

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name == "positions":
            return P(None, BATCH, None)
        return P(BATCH, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(spec_for, batch_shape_tree)


def _loss_fn(params, batch, mcfg: ModelConfig):
    return models.forward_train(params, batch, mcfg)


def make_train_step(mcfg: ModelConfig, tcfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics), ready for jit."""

    def train_step(state: TrainState, batch: dict):
        params = state.params
        mode = tcfg.grad_compression
        if tcfg.microbatch > 1:
            k = tcfg.microbatch

            def slice_mb(i, x, bdim):
                mb = x.shape[bdim] // k
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=bdim)

            def mb_batch(i):
                return jax.tree_util.tree_map_with_path(
                    lambda path, x: slice_mb(
                        i,
                        x,
                        1 if (hasattr(path[-1], "key") and path[-1].key == "positions") else 0,
                    ),
                    batch,
                )

            ef = state.opt.ef
            acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def mb_body(carry, i):
                acc, ef_c, loss_acc = carry
                loss, grads = jax.value_and_grad(_loss_fn)(params, mb_batch(i), mcfg)
                comp, ef_c = optim.compress_grads(grads, mode, ef_c)
                acc = optim.decompress_accumulate(acc, comp, mode)
                return (acc, ef_c, loss_acc + loss), None

            (acc, ef, loss_sum), _ = jax.lax.scan(
                mb_body, (acc0, ef, jnp.zeros(())), jnp.arange(k)
            )
            grads = jax.tree.map(lambda g: g / k, acc)
            loss = loss_sum / k
            opt_state = state.opt._replace(ef=ef)
        else:
            loss, grads = jax.value_and_grad(_loss_fn)(params, batch, mcfg)
            if mode == "bf16":
                grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
            opt_state = state.opt

        new_params, new_opt, metrics = optim.adamw_update(params, grads, opt_state, tcfg)
        metrics = dict(metrics, loss=loss)
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step


def jit_train_step(mcfg: ModelConfig, tcfg: TrainConfig, batch_tree):
    """jit with explicit in/out shardings for the production mesh."""
    mesh = get_mesh()
    step = make_train_step(mcfg, tcfg)
    if mesh is None:
        return jax.jit(step)
    sspec = train_state_specs(mcfg, tcfg)
    bspec = batch_pytree_specs(batch_tree)
    to_sh = lambda spec_tree: jax.tree.map(
        lambda s: sharding(*s) if isinstance(s, P) else sharding(),
        spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )
    return jax.jit(
        step,
        in_shardings=(to_sh(sspec), to_sh(bspec)),
        out_shardings=(to_sh(sspec), None),
        donate_argnums=(0,),
    )
