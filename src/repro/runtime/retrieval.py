"""ALSH retrieval attachment for LM serving — the paper's technique as a
first-class decode feature (kNN-LM-style).

A datastore of (hidden-state key → next-token value) records is indexed with
(d_w^l1, theta)-ALSH over discretized reduced keys. At each decode step the
model's final hidden state queries the index under a per-query WEIGHT VECTOR
(exactly the paper's setting: w rides with the query; here it defaults to
per-dimension precision weights of the datastore but is caller-overridable),
and the retrieved neighbours' token distribution is interpolated with the LM
logits:  log p = logaddexp(log((1-λ) p_LM), log(λ p_kNN)).

All probe compute is jit-compatible and lives inside the same XLA program as
the decode step; the index shards over the "data" axis in the distributed
service (see core/distributed.py). The datastore index is a ``repro.api``
:class:`Index` — a config-carrying pytree, so the RetrievalState crosses the
jit boundary as one bundle and neighbour lookup is a single policy-driven
``index.query(q, w, QuerySpec(k=topk))`` through the shared ``repro.engine``
pipeline (candidate sources → dedupe → gather_rerank_topk): a decode step's
retrieval never materializes a (B, L·C, d_key) candidate tensor — the
datastore rows stream through the kernel's on-chip top-k (DESIGN.md §3/§8).
A growing datastore (``delta_capacity > 0``) adds the delta key-match
source to the same program; the chunked match keeps decode-step memory
independent of the configured capacity.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.api import Index, QualitySpec, QuerySpec, UpdateSpec
from repro.configs.base import RetrievalConfig
from repro.core import BoundedSpace, IndexConfig


class RetrievalState(NamedTuple):
    index: Index  # config-carrying ALSH index over the datastore keys
    values: jax.Array  # (n + delta_capacity,) int32 token ids of records
    proj: jax.Array  # (d_model, d_key) random key-reduction projection
    default_w: jax.Array  # (d_key,) default per-dimension weights


def query_spec(rcfg: RetrievalConfig):
    """The per-decode-step lookup spec this config asks for.

    With ``rcfg.recall_target`` set this is a :class:`QualitySpec` — it
    resolves through the plan memo ``build_datastore`` populated eagerly
    (the memo rides the Index pytree, so resolution inside a jit'd decode
    step is a Python dict hit at trace time, never a calibration run).
    """
    if rcfg.recall_target is not None:
        return QualitySpec(k=rcfg.topk, recall_target=rcfg.recall_target)
    return QuerySpec(k=rcfg.topk)


def index_config(rcfg: RetrievalConfig) -> IndexConfig:
    return IndexConfig(
        d=rcfg.d_key,
        M=rcfg.M,
        K=rcfg.K,
        L=rcfg.L,
        family=rcfg.family,
        max_candidates=rcfg.max_candidates,
        space=BoundedSpace(0.0, 1.0, float(rcfg.M)),
    )


def build_datastore(
    key, d_model: int, vocab: int, rcfg: RetrievalConfig
) -> RetrievalState:
    """Synthetic datastore (examples/tests); real deployments ingest hidden
    states from a corpus pass with the same machinery.

    With ``rcfg.delta_capacity > 0`` the index is built mutable and
    ``values`` is pre-sized for the delta slots, so the datastore can GROW
    during serving (``extend_datastore``) — the kNN-LM keeps learning from
    the streams it decodes without an index rebuild."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    n = rcfg.datastore_size
    cap = rcfg.delta_capacity
    keys = jax.random.uniform(k1, (n, rcfg.d_key))
    values = jax.random.randint(k2, (n,), 0, vocab, dtype=jnp.int32)
    values = jnp.concatenate([values, jnp.zeros((cap,), jnp.int32)])
    proj = jax.random.normal(k3, (d_model, rcfg.d_key)) / (d_model**0.5)
    # precision weights: inverse per-dim std of the datastore keys
    w = 1.0 / (jnp.std(keys, axis=0) + 1e-3)
    index = Index.build(
        k4, keys, index_config(rcfg), update=UpdateSpec(delta_capacity=cap)
    )
    if rcfg.recall_target is not None:
        # resolve the lookup plan NOW (host-side), calibrated against the
        # datastore's own precision-weight profile — decode steps then hit
        # the memo, even across the jit boundary
        from repro.api import Planner

        index.plan(query_spec(rcfg), planner=Planner(weights=w))
    return RetrievalState(index=index, values=values, proj=proj, default_w=w)


def extend_datastore(
    state: RetrievalState, hidden: jax.Array, values: jax.Array
) -> tuple[RetrievalState, jax.Array]:
    """Streaming ingest: append (hidden-state, next-token) records.

    Args:
      state: datastore built with ``rcfg.delta_capacity > 0``.
      hidden: (m, d_model) hidden states — reduced with the datastore's own
        projection, then inserted into the delta segment.
      values: (m,) int32 next-token ids observed after those states.

    Returns (new state, (m,) assigned record ids; -1 where the delta was
    full — compact offline and rebuild). jit-safe, no retrace across fills.
    """
    index, ids = state.index.insert(reduce_key(hidden, state))
    slot = jnp.where(ids >= 0, ids, state.values.shape[0])
    new_values = state.values.at[slot].set(values.astype(jnp.int32), mode="drop")
    return state._replace(index=index, values=new_values), ids


def retire_datastore(state: RetrievalState, ids: jax.Array) -> RetrievalState:
    """Tombstone datastore records (e.g. stale corpus spans) — retrieval
    stops returning them immediately; space is reclaimed by an offline
    compact/rebuild."""
    return state._replace(index=state.index.delete(ids))


def reduce_key(hidden: jax.Array, state: RetrievalState) -> jax.Array:
    """(B, d_model) hidden -> (B, d_key) in [0, 1] (sigmoid squash)."""
    return jax.nn.sigmoid(hidden.astype(jnp.float32) @ state.proj)


def retrieve_logits(
    hidden: jax.Array,
    state: RetrievalState,
    rcfg: RetrievalConfig,
    vocab: int,
    weights: jax.Array | None = None,
    temperature: float = 1.0,
) -> jax.Array:
    """kNN log-probs (B, V) from ALSH neighbours of the hidden state."""
    q = reduce_key(hidden, state)
    B = q.shape[0]
    w = weights if weights is not None else jnp.broadcast_to(state.default_w, q.shape)
    # config rides with the index; quality-first configs resolve via the
    # plan memo (populated by build_datastore, carried through jit)
    res = state.index.query(q, w, query_spec(rcfg))
    # softmax(-d/T) over retrieved records, scattered onto their token ids
    valid = res.ids >= 0
    scores = jnp.where(valid, -res.dists / temperature, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)  # (B, topk)
    tok = jnp.where(valid, state.values[jnp.maximum(res.ids, 0)], 0)
    pknn = jnp.zeros((B, vocab), jnp.float32)
    pknn = pknn.at[jnp.arange(B)[:, None], tok].add(jnp.where(valid, probs, 0.0))
    return jnp.log(pknn + 1e-20)


def interpolate(lm_logits: jax.Array, knn_logp: jax.Array, lam: float) -> jax.Array:
    """log((1-λ) p_LM + λ p_kNN) in a numerically stable form."""
    lm_logp = jax.nn.log_softmax(lm_logits, axis=-1)
    return jnp.logaddexp(
        lm_logp + jnp.log1p(-lam), knn_logp + jnp.log(lam)
    )
