"""AdamW from scratch: dtype-configurable moments (ZeRO-sharded alongside the
params), warmup+cosine schedule, global-norm clipping, and gradient
compression utilities (bf16 cast / int8 + error feedback).

Moments are stored in ``optimizer_dtype`` (bf16 halves optimizer HBM — how
llama4-maverick fits the single-pod mesh) but all update math runs in f32.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    step: jax.Array  # () int32
    m: dict  # first moments (params-shaped pytree)
    v: dict  # second moments
    ef: Optional[dict] = None  # int8 error-feedback residuals (params-shaped)


def init_opt_state(params, tcfg: TrainConfig) -> AdamWState:
    dt = jnp.dtype(tcfg.optimizer_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    ef = None
    if tcfg.grad_compression == "int8_ef":
        ef = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        ef=ef,
    )


def opt_state_specs(pspecs, tcfg: TrainConfig) -> AdamWState:
    """Moments shard exactly like their parameters (ZeRO)."""
    from jax.sharding import PartitionSpec as P

    ef = None
    if tcfg.grad_compression == "int8_ef":
        ef = pspecs
    return AdamWState(step=P(), m=pspecs, v=pspecs, ef=ef)


def lr_schedule(step, tcfg: TrainConfig):
    warm = jnp.minimum(step / jnp.maximum(tcfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - tcfg.warmup_steps)
        / jnp.maximum(tcfg.total_steps - tcfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return tcfg.learning_rate * warm * (0.1 + 0.9 * cos)


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def adamw_update(params, grads, state: AdamWState, tcfg: TrainConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(step, tcfg)
    b1, b2, eps, wd = tcfg.beta1, tcfg.beta2, tcfg.eps, tcfg.weight_decay
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m32 / bc1
        vhat = v32 / bc2
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p32)
        return p32.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = AdamWState(step=step, m=new_m, v=new_v, ef=state.ef)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# Gradient compression (distributed-optimization tricks)
# ---------------------------------------------------------------------------


def compress_grads(grads, mode: Optional[str], ef=None):
    """Compress per-microbatch grads BEFORE cross-replica reduction.

    "bf16": cast — under GSPMD the reduce-scatter then moves bf16 (half the
        collective bytes; verified in the dry-run HLO, see EXPERIMENTS §Perf).
    "int8_ef": symmetric per-tensor int8 quantization with error feedback —
        the residual is carried in the optimizer state and re-added next step,
        preserving convergence (1-bit-Adam-style analysis applies).
    Returns (compressed, new_ef).
    """
    if mode is None:
        return grads, ef
    if mode == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads), ef
    if mode == "int8_ef":
        def q(g, e):
            g32 = g.astype(jnp.float32) + e
            scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
            qg = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
            err = g32 - qg.astype(jnp.float32) * scale
            return (qg, scale), err

        pairs = jax.tree.map(q, grads, ef, is_leaf=lambda x: isinstance(x, jax.Array))
        comp = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple))
        new_ef = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple))
        return comp, new_ef
    raise ValueError(mode)


def decompress_accumulate(acc, compressed, mode: Optional[str]):
    """acc (f32 pytree) += decompress(compressed)."""
    if mode is None or mode == "bf16":
        return jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, compressed)
    if mode == "int8_ef":
        def d(a, qs):
            qg, scale = qs
            return a + qg.astype(jnp.float32) * scale

        return jax.tree.map(
            d, acc, compressed, is_leaf=lambda x: isinstance(x, jax.Array)
        )
    raise ValueError(mode)
