from repro.optim.adamw import (
    AdamWState,
    adamw_update,
    clip_by_global_norm,
    compress_grads,
    decompress_accumulate,
    init_opt_state,
    lr_schedule,
    opt_state_specs,
)

__all__ = [
    "AdamWState",
    "adamw_update",
    "clip_by_global_norm",
    "compress_grads",
    "decompress_accumulate",
    "init_opt_state",
    "lr_schedule",
    "opt_state_specs",
]
