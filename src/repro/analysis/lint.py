"""Layer 1 of the trace-contract analyzer: a custom AST lint pass.

Eight repo-specific rules (stable ``RPR0xx`` codes) enforcing the
trace-time invariants the jaxpr auditor (:mod:`repro.analysis.audit`)
cannot see from a single trace — the conventions that keep the engine's
one-compiled-program-per-lattice-point and ``ids == -1 ⇔ dists == +inf``
contracts true *as the code is edited*, not just on the paths the auditor
happens to enumerate:

  RPR001  tracer-branch        Python ``if``/``while``/ternary/``assert``
                               branching on a jnp/jax.lax expression inside
                               trace-reachable modules (engine, kernels,
                               core, quant) — under jit this is a
                               ConcretizationTypeError at best, a silent
                               trace-time constant at worst.
  RPR002  host-sync            ``.item()`` / ``.block_until_ready()`` /
                               ``jax.device_get`` / ``np.asarray`` /
                               ``np.array`` / ``float(...)`` over call
                               results on the engine/kernel hot path —
                               each one is a device→host round trip that
                               serializes the dispatch stream.
  RPR003  distance-fill        float literals ≥ 1e30 anywhere, or
                               ``jnp.full``-style fills ≥ 1e6 — distance
                               padding must be ``jnp.inf`` exactly or the
                               sentinel contract (and every downstream
                               ``isfinite`` check) silently breaks.
  RPR004  id-sentinel          negative integer literals other than ``-1``
                               used as fills or compared against — the id
                               sentinel is ``-1``, everywhere.
  RPR005  jit-static-unhashable  ``jax.jit(static_argnames=...)`` naming a
                               parameter whose default is a list/dict/set
                               display — hashing fails on first call with
                               the default.
  RPR006  import-time-jnp      module-scope jnp/jax.random/jax.lax calls —
                               array computation at import time allocates
                               on whatever backend initializes first and
                               runs before test/serving setup can configure
                               platforms.
  RPR007  pallas-outside-kernels  ``pl.pallas_call`` / pallas imports
                               outside ``repro/kernels`` — kernels live in
                               one audited package, everything else goes
                               through the ``ops`` dispatch wrappers.
  RPR008  private-jit-poke     ``._cache_size`` outside ``repro/analysis``
                               — use :mod:`repro.analysis.retrace_guard`.

Findings are suppressed line-by-line with an *explained* inline allowlist::

    if float(p_l2(mid, W)) > p:  # repro: allow[RPR001] host-side bisection, never traced

(the comment may also sit on the line above). An allow marker with no
reason is itself a finding (``RPR000``) — the gate's contract is zero
*unexplained* findings, not zero comments.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Iterable

# rule catalog: code -> (slug, one-line description). Stable — codes are
# referenced from allowlist comments and CI logs; never renumber.
RULES = {
    "RPR000": ("unexplained-allow", "allowlist marker without a reason"),
    "RPR001": ("tracer-branch", "Python control flow on a traced jnp expression"),
    "RPR002": ("host-sync", "device→host sync on the engine/kernel hot path"),
    "RPR003": ("distance-fill", "distance padding that is not jnp.inf"),
    "RPR004": ("id-sentinel", "id sentinel literal that is not -1"),
    "RPR005": ("jit-static-unhashable", "static_argnames param with unhashable default"),
    "RPR006": ("import-time-jnp", "module-import-time jnp computation"),
    "RPR007": ("pallas-outside-kernels", "pl.pallas_call outside repro/kernels"),
    "RPR008": ("private-jit-poke", "._cache_size poke outside repro.analysis"),
}

# module scopes (path fragments relative to the repo / src root)
_TRACED_SCOPES = ("repro/engine/", "repro/kernels/", "repro/core/", "repro/quant/")
_HOT_SCOPES = ("repro/engine/", "repro/kernels/")
_KERNEL_SCOPE = "repro/kernels/"
_ANALYSIS_SCOPE = "repro/analysis/"

# jnp/jax calls that return static metadata, not traced arrays
_STATIC_METADATA_FNS = {
    "jnp.dtype", "jnp.result_type", "jnp.promote_types", "jnp.issubdtype",
    "jnp.finfo", "jnp.iinfo", "jax.dtypes.issubdtype", "jax.eval_shape",
}

_ALLOW_RE = re.compile(r"#\s*repro:\s*allow\[(RPR\d{3})\]\s*(.*)")


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    code: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.code} [{RULES[self.code][0]}] {self.message}"


def _fn_name(node: ast.expr) -> str:
    """Dotted name of a call target ('jnp.full', 'pl.pallas_call', ...)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _is_traced_call(call: ast.Call) -> bool:
    name = _fn_name(call.func)
    if name in _STATIC_METADATA_FNS:
        return False
    return name.startswith(("jnp.", "jax.numpy.", "jax.lax."))


def _neg_int(node: ast.expr):
    """The value of a negative-int literal (-2, -999, ...), else None."""
    if (
        isinstance(node, ast.UnaryOp)
        and isinstance(node.op, ast.USub)
        and isinstance(node.operand, ast.Constant)
        and type(node.operand.value) is int
    ):
        return -node.operand.value
    if isinstance(node, ast.Constant) and type(node.value) is int and node.value < 0:
        return node.value
    return None


def _float_const(node: ast.expr):
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        node = node.operand
    if isinstance(node, ast.Constant) and type(node.value) is float:
        return node.value
    return None


class _Linter(ast.NodeVisitor):
    def __init__(self, relpath: str):
        self.relpath = relpath.replace("\\", "/")
        self.findings: list[Finding] = []
        self._depth = 0  # FunctionDef/ClassDef nesting (0 = module scope)

    def _in(self, scopes) -> bool:
        return any(s in self.relpath for s in scopes)

    def emit(self, node: ast.AST, code: str, message: str) -> None:
        self.findings.append(Finding(self.relpath, node.lineno, code, message))

    # -- scope tracking ------------------------------------------------------
    def visit_FunctionDef(self, node):
        self._check_jit_statics(node)
        self._depth += 1
        self.generic_visit(node)
        self._depth -= 1

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_ClassDef(self, node):
        self._depth += 1
        self.generic_visit(node)
        self._depth -= 1

    # -- RPR001: control flow on traced values -------------------------------
    def _check_branch_test(self, test: ast.expr, kind: str) -> None:
        if not self._in(_TRACED_SCOPES):
            return
        for sub in ast.walk(test):
            if isinstance(sub, ast.Call) and _is_traced_call(sub):
                self.emit(
                    test,
                    "RPR001",
                    f"{kind} test calls `{_fn_name(sub.func)}` — branching on a "
                    f"traced value fails (or constant-folds) under jit; use "
                    f"jnp.where / lax.cond, or hoist the decision to a static arg",
                )
                return

    def visit_If(self, node):
        self._check_branch_test(node.test, "if")
        self.generic_visit(node)

    def visit_While(self, node):
        self._check_branch_test(node.test, "while")
        self.generic_visit(node)

    def visit_IfExp(self, node):
        self._check_branch_test(node.test, "ternary")
        self.generic_visit(node)

    def visit_Assert(self, node):
        self._check_branch_test(node.test, "assert")
        self.generic_visit(node)

    # -- call-shaped rules ---------------------------------------------------
    def visit_Call(self, node):
        name = _fn_name(node.func)

        # RPR002: host syncs on the hot path
        if self._in(_HOT_SCOPES):
            if isinstance(node.func, ast.Attribute) and node.func.attr in (
                "item", "block_until_ready",
            ) and not node.args:
                self.emit(
                    node, "RPR002",
                    f"`.{node.func.attr}()` forces a device→host sync on the "
                    f"hot path — keep results on device through the tail",
                )
            elif name in ("np.asarray", "np.array", "np.frombuffer", "jax.device_get"):
                self.emit(
                    node, "RPR002",
                    f"`{name}` materializes device arrays on host inside the "
                    f"engine/kernel hot path",
                )
            elif name in ("float", "int", "bool") and node.args and isinstance(
                node.args[0], (ast.Call, ast.Subscript)
            ):
                self.emit(
                    node, "RPR002",
                    f"`{name}(...)` over an expression result is a host sync "
                    f"when the argument is a traced array",
                )

        # RPR003/RPR004: jnp.full-style fills
        if name in ("jnp.full", "jnp.full_like", "np.full", "np.full_like"):
            fill = node.args[1] if len(node.args) > 1 else None
            for kw in node.keywords:
                if kw.arg == "fill_value":
                    fill = kw.value
            if fill is not None:
                fv = _float_const(fill)
                if fv is not None and abs(fv) >= 1e6:
                    self.emit(
                        node, "RPR003",
                        f"distance padding `{name}(..., {fv!r})` — pad with "
                        f"jnp.inf so invalid slots satisfy dists == +inf",
                    )
                iv = _neg_int(fill)
                if iv is not None and iv != -1:
                    self.emit(
                        node, "RPR004",
                        f"id fill `{name}(..., {iv})` — the id sentinel is -1 "
                        f"(ids == -1 ⇔ dists == +inf)",
                    )

        # RPR007: pallas outside kernels/
        if name.endswith("pallas_call") and not self._in((_KERNEL_SCOPE,)):
            self.emit(
                node, "RPR007",
                "pl.pallas_call outside repro/kernels — kernels live in one "
                "audited package; dispatch through repro.kernels.ops",
            )

        # RPR006: import-time jnp computation
        if self._depth == 0 and _is_traced_call(node):
            self.emit(
                node, "RPR006",
                f"module-import-time `{name}` call — arrays allocated at "
                f"import bind the backend before JAX_PLATFORMS/test setup "
                f"runs; build them lazily inside a function",
            )

        self.generic_visit(node)

    # -- RPR003 (bare pseudo-inf literals) -----------------------------------
    def visit_Constant(self, node):
        if type(node.value) is float and abs(node.value) >= 1e30:  # repro: allow[RPR003] the rule's own detection threshold
            self.emit(
                node, "RPR003",
                f"pseudo-infinity literal {node.value!r} — use jnp.inf (the "
                f"sentinel contract checks +inf exactly)",
            )
        self.generic_visit(node)

    # -- RPR004 (sentinel comparisons) ---------------------------------------
    def visit_Compare(self, node):
        for comp in node.comparators:
            iv = _neg_int(comp)
            if iv is not None and iv != -1:
                self.emit(
                    node, "RPR004",
                    f"comparison against {iv} — the id sentinel is -1; a "
                    f"second magic negative id silently escapes every "
                    f"`ids == -1` mask",
                )
        self.generic_visit(node)

    # -- RPR008: private jit-cache pokes -------------------------------------
    def visit_Attribute(self, node):
        if node.attr == "_cache_size" and not self._in((_ANALYSIS_SCOPE,)):
            self.emit(
                node, "RPR008",
                "private `._cache_size` poke — use "
                "repro.analysis.retrace_guard (RetraceGuard / engine_cache_size)",
            )
        self.generic_visit(node)

    # -- pallas imports (RPR007) ---------------------------------------------
    def visit_Import(self, node):
        for a in node.names:
            if "experimental.pallas" in a.name and not self._in((_KERNEL_SCOPE,)):
                self.emit(node, "RPR007", f"pallas import `{a.name}` outside repro/kernels")
        self.generic_visit(node)

    def visit_ImportFrom(self, node):
        mod = node.module or ""
        if not self._in((_KERNEL_SCOPE,)):
            for a in node.names:
                full = f"{mod}.{a.name}"
                if "experimental.pallas" in full:
                    self.emit(
                        node, "RPR007",
                        f"pallas import `{full}` outside repro/kernels",
                    )
                    break
        self.generic_visit(node)

    # -- RPR005: unhashable static_argnames defaults -------------------------
    def _check_jit_statics(self, fn) -> None:
        for dec in fn.decorator_list:
            if not isinstance(dec, ast.Call):
                continue
            target = _fn_name(dec.func)
            is_jit = target in ("jax.jit", "jit")
            is_partial_jit = target in ("functools.partial", "partial") and dec.args and _fn_name(
                dec.args[0]
            ) in ("jax.jit", "jit")
            if not (is_jit or is_partial_jit):
                continue
            static_names: set[str] = set()
            static_nums: set[int] = set()
            for kw in dec.keywords:
                if kw.arg == "static_argnames":
                    for sub in ast.walk(kw.value):
                        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                            static_names.add(sub.value)
                if kw.arg == "static_argnums":
                    for sub in ast.walk(kw.value):
                        if isinstance(sub, ast.Constant) and isinstance(sub.value, int):
                            static_nums.add(sub.value)
            args = fn.args.args + fn.args.kwonlyargs
            defaults = dict(
                zip([a.arg for a in reversed(fn.args.args)], reversed(fn.args.defaults))
            )
            defaults.update(
                {
                    a.arg: d
                    for a, d in zip(fn.args.kwonlyargs, fn.args.kw_defaults)
                    if d is not None
                }
            )
            for i, a in enumerate(args):
                if a.arg in static_names or i in static_nums:
                    d = defaults.get(a.arg)
                    if isinstance(d, (ast.List, ast.Dict, ast.Set)):
                        self.emit(
                            fn, "RPR005",
                            f"static arg `{a.arg}` of jitted `{fn.name}` has an "
                            f"unhashable {type(d).__name__.lower()} default — "
                            f"the compile-key hash raises on first defaulted call",
                        )


def _collect_allows(src: str, relpath: str) -> tuple[dict, list[Finding]]:
    """Parse `# repro: allow[RPRxxx] reason` markers. Returns
    ({line: {code, ...}}, findings for reason-less markers)."""
    allows: dict[int, set[str]] = {}
    bad: list[Finding] = []
    for lineno, text in enumerate(src.splitlines(), start=1):
        m = _ALLOW_RE.search(text)
        if not m:
            continue
        code, reason = m.group(1), m.group(2).strip()
        if not reason:
            # a reasonless marker suppresses NOTHING — the finding it meant
            # to silence still fires, plus the RPR000 for the bare marker
            bad.append(
                Finding(
                    relpath, lineno, "RPR000",
                    f"allow[{code}] without a reason — the gate's contract is "
                    f"zero UNEXPLAINED findings; say why this line is exempt",
                )
            )
        else:
            allows.setdefault(lineno, set()).add(code)
    return allows, bad


def lint_source(src: str, relpath: str) -> list[Finding]:
    """Lint one module's source text; relpath scopes the per-package rules."""
    tree = ast.parse(src)
    linter = _Linter(relpath)
    linter.visit(tree)
    allows, findings = _collect_allows(src, relpath)

    def allowed(f: Finding) -> bool:
        return any(
            f.code in allows.get(ln, ()) for ln in (f.line, f.line - 1)
        )

    findings += [f for f in linter.findings if not allowed(f)]
    return sorted(findings, key=lambda f: (f.path, f.line, f.code))


def lint_paths(paths: Iterable[str | Path], root: str | Path | None = None) -> list[Finding]:
    """Lint every ``*.py`` under ``paths``; findings carry paths relative to
    ``root`` (default: each argument's parent)."""
    findings: list[Finding] = []
    for p in paths:
        p = Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        base = Path(root) if root is not None else p.parent
        for f in files:
            try:
                rel = f.relative_to(base)
            except ValueError:
                rel = f
            findings += lint_source(f.read_text(), str(rel))
    return sorted(findings, key=lambda f: (f.path, f.line, f.code))
