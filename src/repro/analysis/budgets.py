"""Declared trace-contract budgets for the jaxpr auditor.

The auditor (:mod:`repro.analysis.audit`) traces the public query
entry-point lattice at the AUDIT geometry below and checks three budgets:

  * **retrace budget** — the compile-key cardinality of the whole lattice
    after :func:`repro.engine.pipeline.normalize_static_args`. The audit
    enumerates RAW caller combinations (including the redundant axes the
    facades and ladder rungs might pass — probe-mode ``n_probes``,
    non-probe ``impl``, f32 ``screen_alpha``) and asserts the normalization
    folds them back to exactly ``RETRACE_BUDGET`` distinct compiled
    programs. A new static axis that the normalization does not fold is a
    budget breach at review time instead of compile stalls in production.
  * **memory envelope** — the peak live intermediate bytes of any single
    traced path (liveness-scanned over the jaxpr, sub-jaxprs included)
    must stay under ``MEMORY_ENVELOPE_BYTES``. The envelope is sized so
    every legitimate HEAD path fits with ~4x headroom while a
    ``(b, L·P·C, cap)``-class dense-delta-match materialization (the
    pre-PR5 regression this gate exists for: 8·4096·4096 f32 ≈ 512 MiB at
    audit geometry) breaches it by an order of magnitude.
  * **dtype contract** — no f64 avals anywhere (silent promotion doubles
    every table and intermediate), and int8 avals may only flow through
    movement/decode primitives (``INT8_ALLOWED_PRIMITIVES``) — int8
    arithmetic outside the gather-tail decode means a kernel is
    accumulating in the quantized domain.

Per-path measurements are additionally diffed against the checked-in
golden file (``golden_budget.json``, regenerate with
``python -m repro.analysis --write-golden``) with ``GOLDEN_REL_TOL``
slack, so a slow creep toward the envelope is visible in review long
before it breaches.
"""

from __future__ import annotations

from pathlib import Path

# The standard audit geometry: small enough that the four index builds the
# auditor needs take ~a second, big enough that the asymptotic shapes
# (candidate blocks, delta-match chunks, screen survivors) are the real
# ones. ``cap`` mirrors the 4096-row delta memory envelope from DESIGN §4.
AUDIT_GEOMETRY = {
    "n": 4096,
    "d": 16,
    "M": 32,
    "K": 4,
    "L": 8,
    "W": 4.0,
    "max_candidates": 64,
    "delta_capacity": 4096,
    "b": 8,  # query batch rows per trace
    "k": 10,
}

# Distinct compiled programs the full audited lattice may cost (exact —
# the lattice is deterministic, so any drift is a real new/removed
# program). Measured on HEAD: 146 raw caller combinations fold to 64 —
# the 14 keys beyond the pre-streaming 50 are the genuine early-exit
# programs (probe+stream per build × view, multiprobe+stream per theta
# storage × view); every other early-exit knob combination must fold.
RETRACE_BUDGET = 64

# Peak live intermediate bytes per traced path. Worst legitimate HEAD path
# is the segmented exact scan at ~18.3 MiB peak (the tombstoned
# two-segment ExhaustiveSource materializes the full id block); 32 MiB
# leaves it headroom while the (b, L·P·C, cap) dense-match regression
# (~512 MiB at audit geometry) breaches by 16x.
MEMORY_ENVELOPE_BYTES = 32 * 2**20

# Relative tolerance for the per-path golden diff (jax version skew moves
# fusion/liveness details a little; real regressions move them a lot).
GOLDEN_REL_TOL = 0.10

GOLDEN_PATH = Path(__file__).with_name("golden_budget.json")

# Primitives int8 avals may legitimately flow through: the quantized table
# is MOVED (gathered, sliced, reshaped, scanned through) and DECODED
# (convert_element_type) — never computed on. Anything else consuming an
# int8 operand is quantized-domain arithmetic outside the decode tail.
INT8_ALLOWED_PRIMITIVES = frozenset(
    {
        "convert_element_type",  # the decode itself (widen to f32)
        "gather",
        "dynamic_slice",
        "dynamic_update_slice",
        "slice",
        "squeeze",
        "reshape",
        "broadcast_in_dim",
        "concatenate",
        "transpose",
        "rev",
        "select_n",  # two-segment owner select moves encoded rows
        "pad",
        "copy",
        # structural plumbing that forwards operands untouched
        "pjit",
        "scan",
        "while",
        "cond",
        "custom_jvp_call",
        "custom_vjp_call",
        "stop_gradient",
    }
)
