"""CLI for the trace-contract analyzer.

    python -m repro.analysis                  # lint + audit, exit 0/1
    python -m repro.analysis --lint-only
    python -m repro.analysis --audit-only
    python -m repro.analysis --write-golden   # regenerate golden_budget.json
    python -m repro.analysis --seed-regression memory   # must exit 1
    python -m repro.analysis --seed-regression retrace  # must exit 1
    python -m repro.analysis --report out.json

The ``--seed-regression`` modes exist to test the gate itself: they
splice a known-bad pattern (the pre-PR5 dense delta-match materialization,
or an unfolded static axis) into the audit and MUST fail with the named
diagnostic; CI runs both and asserts the non-zero exit.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--lint-only", action="store_true")
    ap.add_argument("--audit-only", action="store_true")
    ap.add_argument("--write-golden", action="store_true",
                    help="regenerate golden_budget.json from this run")
    ap.add_argument("--seed-regression", choices=("memory", "retrace"),
                    help="inject a known-bad pattern; the audit must fail")
    ap.add_argument("--report", type=Path, default=Path("analysis_report.json"),
                    help="where to write the JSON report (audit runs only)")
    ap.add_argument("--paths", nargs="*", default=None,
                    help="lint these paths instead of src/repro")
    args = ap.parse_args(argv)

    root = Path(__file__).resolve().parents[2]  # .../src
    rc = 0

    if not args.audit_only:
        from repro.analysis.lint import lint_paths

        paths = args.paths or [str(root / "repro")]
        findings = lint_paths(paths, root=str(root))
        for f in findings:
            print(f)
        print(f"lint: {len(findings)} finding(s)")
        if findings:
            rc = 1

    if not args.lint_only:
        from repro.analysis import audit, budgets

        golden = None if (args.write_golden or args.seed_regression) else (
            audit.load_golden()
        )
        report = audit.run_audit(
            inject=args.seed_regression,
            golden=golden,
            live_probe=args.seed_regression is None,
        )
        args.report.write_text(json.dumps(report, indent=2) + "\n")
        ck = report["compile_keys"]
        mem = report["memory"]
        print(
            f"audit: {ck['raw_points']} raw lattice points -> "
            f"{ck['count']} compile keys (budget {ck['budget']}); "
            f"worst path {mem['worst_path']} peaks at "
            f"{mem['max_peak_live_bytes'] / 2**20:.1f} MiB "
            f"(envelope {mem['envelope_bytes'] / 2**20:.0f} MiB)"
        )
        for f in report["failures"]:
            print(
                f"{f['code']} {f['path']}: {f['message']} "
                f"(measured {f['measured']:g} vs budget {f['budget']:g})"
            )
        if args.write_golden:
            budgets.GOLDEN_PATH.write_text(
                json.dumps(audit.golden_from_report(report), indent=2,
                           sort_keys=True) + "\n"
            )
            print(f"golden written: {budgets.GOLDEN_PATH}")
        if not report["ok"]:
            rc = 1
        print(f"audit: {'ok' if report['ok'] else 'FAILED'} "
              f"({len(report['failures'])} failure(s))")

    return rc


if __name__ == "__main__":
    sys.exit(main())
