"""Static trace-contract analysis: lint gate + jaxpr budget auditor.

Two layers, one verdict (``python -m repro.analysis`` exits non-zero on
any finding):

  * :mod:`repro.analysis.lint` — an AST pass over ``src/repro`` with
    stable RPR0xx rule codes (tracer branching, host syncs on hot paths,
    sentinel fills, static-arg hygiene, import-time compute, pallas
    confinement, private-jit pokes). Violations are silenced only by an
    inline ``# repro: allow[RPRxxx] <reason>`` with a non-empty reason.
  * :mod:`repro.analysis.audit` — traces the public query entry-point
    lattice via ``jax.make_jaxpr`` (nothing executes) and checks the
    declared budgets of :mod:`repro.analysis.budgets`: compile-key
    cardinality (AUD002), peak live intermediate bytes (AUD001), dtype
    contracts (AUD003), and drift vs the checked-in golden (AUD004).

:mod:`repro.analysis.retrace_guard` is the shared LIVE counterpart of the
retrace contract — the serving broker, the auditor's live probe, and the
tests all watch the engine's jit cache through it instead of poking
``_query_jit._cache_size()`` directly.
"""

from __future__ import annotations

from repro.analysis.lint import Finding, lint_paths, lint_source
from repro.analysis.retrace_guard import (
    RetraceError,
    RetraceGuard,
    cache_size,
    engine_cache_size,
)

__all__ = [
    "Finding",
    "lint_paths",
    "lint_source",
    "RetraceError",
    "RetraceGuard",
    "cache_size",
    "engine_cache_size",
]
