"""The retrace guard: the ONE sanctioned way to watch a jit cache.

The engine's sublinear-time story assumes one compiled program per
(mode, family, storage, α, shape) point — a steady-state retrace means a
static argument or shape leaked past the normalization in
:func:`repro.engine.pipeline.query` and the serving path is silently
paying compile latency per request. Before this module, every consumer
that wanted to check that invariant reached into jax's private
``fn._cache_size()`` (the broker, the engine tests, ad-hoc debugging),
which is exactly the kind of scattered private poke the static-analysis
gate exists to retire: lint rule ``RPR008`` now flags ``_cache_size``
everywhere outside this package, and the broker, the jaxpr auditor, and
the tests all share these helpers instead.

Usage::

    guard = RetraceGuard()          # watches the shared engine entry point
    guard.snapshot()                # after warmup
    ...serve...
    guard.assert_no_retrace()       # raises RetraceError naming the growth

    with RetraceGuard(fn=my_jitted) as g:   # scoped form
        my_jitted(x)                        # first call may compile
        g.snapshot()
        my_jitted(x)                        # must not compile again

``RetraceError`` subclasses ``AssertionError`` so existing callers (and
pytest.raises clauses) written against the broker's old assertion keep
working unchanged.
"""

from __future__ import annotations

from typing import Callable, Optional


class RetraceError(AssertionError):
    """A watched jit cache grew after its snapshot — something retraced."""


def engine_cache_size() -> int:
    """Compiled-program count of the shared engine entry point
    (``repro.engine.pipeline._query_jit``) — every facade, legacy shim,
    planner rung, and shard body funnels through it, so this one number
    is the whole query surface's compile-key cardinality."""
    from repro.engine import pipeline as _pipeline

    return cache_size(_pipeline._query_jit)


def cache_size(fn) -> int:
    """Compiled-program count of any ``jax.jit``-wrapped callable."""
    return fn._cache_size()  # repro: allow[RPR008] the defining helper — every other module goes through here


class RetraceGuard:
    """Snapshot a jit cache and assert it never grows afterwards.

    Args:
      fn: the jitted callable to watch. ``None`` (default) watches the
        shared engine entry point via :func:`engine_cache_size`.
    """

    def __init__(self, fn: Optional[Callable] = None):
        self._size: Callable[[], int] = (
            engine_cache_size if fn is None else lambda: cache_size(fn)
        )
        self._snapshot: Optional[int] = None

    def cache_size(self) -> int:
        """Current compiled-program count of the watched cache."""
        return self._size()

    @property
    def snapshotted(self) -> bool:
        return self._snapshot is not None

    @property
    def baseline(self) -> Optional[int]:
        """The snapshotted size (None before :meth:`snapshot`)."""
        return self._snapshot

    def snapshot(self) -> int:
        """Record the current cache size as the no-retrace baseline."""
        self._snapshot = self._size()
        return self._snapshot

    def assert_no_retrace(self, context: str = "") -> None:
        """Raise :class:`RetraceError` if the cache grew since snapshot."""
        if self._snapshot is None:
            raise RuntimeError(
                "RetraceGuard.assert_no_retrace needs snapshot() first"
            )
        now = self._size()
        if now > self._snapshot:
            where = f" during {context}" if context else ""
            raise RetraceError(
                f"jit cache grew {self._snapshot} -> {now}{where}: a "
                f"shape or static-argument combination not covered by the "
                f"snapshot reached the compiled entry point"
            )

    def __enter__(self) -> "RetraceGuard":
        self.snapshot()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.assert_no_retrace()
