"""Layer 2 of the trace-contract analyzer: the jaxpr contract auditor.

Traces the full public query entry-point lattice — mode (probe /
multiprobe / exact) × view (sealed / segmented) × storage codec (f32 /
bf16 / int8) × screen-α × ladder rungs (probe windows, probe counts) ×
early-exit streaming knobs (early_exit / exit_group / exit_slack) —
through the REAL :func:`repro.engine.pipeline.dispatch`, via
``jax.make_jaxpr`` so nothing executes, then checks the declared budgets
(:mod:`repro.analysis.budgets`):

  * compile-key cardinality after the shared
    :func:`repro.engine.pipeline.normalize_static_args` vs
    ``RETRACE_BUDGET`` (AUD002) — the raw lattice deliberately includes
    the redundant static axes callers may pass (probe-mode ``n_probes``,
    non-probe ``impl``, f32 ``screen_alpha``) so a normalization gap
    shows up as extra keys;
  * peak live intermediate bytes per path (liveness scan over the jaxpr,
    sub-jaxprs included) vs ``MEMORY_ENVELOPE_BYTES`` (AUD001) — the
    ``(b, L·P·C, cap)``-class materializations that broke the 4096-row
    envelope before PR 5 are caught here at review time;
  * dtype contracts (AUD003): no f64 aval anywhere, int8 avals confined
    to ``INT8_ALLOWED_PRIMITIVES`` (movement + decode);
  * per-path drift vs the checked-in golden budget file (AUD004).

The auditor also runs a LIVE normalization probe on a tiny index: the
denormalized static variants are pushed through the real jitted entry
point under a :class:`~repro.analysis.retrace_guard.RetraceGuard` — the
static trace-level count and the live jit cache must agree that the
redundant axes compile nothing new.

``run_audit(inject=...)`` supports two seeded regressions for testing the
gate itself (``python -m repro.analysis --seed-regression ...``):
``"memory"`` splices a dense (b, L·P·C, cap) delta-match tensor into every
segmented path; ``"retrace"`` counts compile keys WITHOUT the
normalization, modeling a static axis the engine forgot to fold.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional

from repro.analysis import budgets
from repro.analysis.retrace_guard import RetraceGuard

# audit failure codes (stable, named in reports and CI logs)
AUDIT_CODES = {
    "AUD001": "memory-envelope breach",
    "AUD002": "retrace-budget breach",
    "AUD003": "dtype-contract violation",
    "AUD004": "golden-budget drift",
}


@dataclasses.dataclass(frozen=True)
class AuditPoint:
    """One RAW caller combination of the entry-point lattice."""

    family: str
    storage: str
    view: str  # "sealed" | "segmented"
    mode: str
    window: int  # effective max_candidates (ladder rung)
    n_probes: int
    max_flips: int
    impl: str
    screen_alpha: float
    early_exit: bool = False
    exit_group: int = 0
    exit_slack: float = 0.0

    @property
    def name(self) -> str:
        parts = [self.family, self.storage, self.view, self.mode]
        if self.mode != "exact":
            parts.append(f"w{self.window}")
        if self.mode == "multiprobe":
            parts.append(f"p{self.n_probes}")
        if self.screen_alpha:
            parts.append(f"a{int(self.screen_alpha)}")
        if self.early_exit:
            parts.append(f"e{self.exit_group}")
        return "/".join(parts)


@dataclasses.dataclass(frozen=True)
class AuditFailure:
    code: str
    path: str
    message: str
    measured: float
    budget: float

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        return (
            f"{self.code} [{AUDIT_CODES[self.code]}] {self.path}: "
            f"{self.message} (measured {self.measured:g} vs budget {self.budget:g})"
        )


def _audit_config(family: str, storage: str, window: Optional[int] = None):
    from repro.core.index import IndexConfig

    g = budgets.AUDIT_GEOMETRY
    return IndexConfig(
        d=g["d"],
        M=g["M"],
        K=g["K"],
        L=g["L"],
        family=family,
        W=g["W"],
        max_candidates=window or g["max_candidates"],
        storage=storage,
    )


# (family, storage) combos audited. theta carries the full codec axis;
# l2 pins the family-specific trace paths (float keys, W bucketing).
AUDIT_BUILDS = (("theta", "f32"), ("theta", "bf16"), ("theta", "int8"), ("l2", "f32"))


def build_audit_indexes() -> dict:
    """Build one tiny mutable index per audited (family, storage) — the
    only concrete computation the audit performs (~1 s total)."""
    import jax
    import jax.numpy as jnp

    from repro.api.index import Index
    from repro.api.spec import UpdateSpec

    g = budgets.AUDIT_GEOMETRY
    key = jax.random.PRNGKey(0)
    data = jax.random.uniform(key, (g["n"], g["d"]), jnp.float32)
    out = {}
    for family, storage in AUDIT_BUILDS:
        out[(family, storage)] = Index.build(
            key,
            data,
            _audit_config(family, storage),
            update=UpdateSpec(delta_capacity=g["delta_capacity"]),
        )
    return out


def enumerate_points() -> list:
    """The RAW lattice: every caller combination the facades, legacy
    shims, and planner ladder rungs can reach — including the static
    values the engine's normalization must fold away."""
    g = budgets.AUDIT_GEOMETRY
    full_w = g["max_candidates"]
    rung_w = full_w // 2
    points = []
    for family, storage in AUDIT_BUILDS:
        alphas = (0.0,) if storage == "f32" else (0.0, 2.0)
        for view in ("sealed", "segmented"):
            # probe: window rungs × redundant n_probes axis (must fold)
            for window in (full_w, rung_w):
                for n_probes in (1, 8):  # ignored by probe mode
                    for alpha in alphas:
                        points.append(
                            AuditPoint(family, storage, view, "probe", window,
                                       n_probes, 0, "auto", alpha)
                        )
            # multiprobe: probe-count rungs × redundant impl axis (must
            # fold). theta-only — l2 has no perturbation sequence.
            for n_probes in (8, 4) if family == "theta" else ():
                for impl in ("auto", "gather"):  # non-probe impl is folded
                    for alpha in alphas:
                        points.append(
                            AuditPoint(family, storage, view, "multiprobe", full_w,
                                       n_probes, 3, impl, alpha)
                        )
            # exact: window + α must both fold (cfg drops entirely)
            for window in (full_w, rung_w):
                points.append(
                    AuditPoint(family, storage, view, "exact", window, 8, 3,
                               "auto", alphas[-1])
                )
            # early exit — one GENUINE streamed program per mode (probe
            # G=4 over L=8 windows; theta multiprobe G=8 over 8·8), plus
            # the fold axes: knobs with early off must fold to the
            # baseline program, a group covering the whole lattice IS the
            # baseline program, early over an active screen folds to the
            # screened program, and early on exact folds entirely.
            points.append(
                AuditPoint(family, storage, view, "probe", full_w, 1, 0,
                           "auto", 0.0, True, 4, 0.1)
            )
            points.append(  # knobs ignored while early_exit=False
                AuditPoint(family, storage, view, "probe", full_w, 1, 0,
                           "auto", 0.0, False, 16, 0.5)
            )
            points.append(  # exit_group >= L·P — single group, must fold
                AuditPoint(family, storage, view, "probe", full_w, 1, 0,
                           "auto", 0.0, True, g["L"], 0.1)
            )
            if family == "theta":
                points.append(
                    AuditPoint(family, storage, view, "multiprobe", full_w,
                               8, 3, "auto", 0.0, True, 8, 0.1)
                )
            if alphas[-1] > 0.0:  # streaming under an active screen folds
                points.append(
                    AuditPoint(family, storage, view, "probe", full_w, 1, 0,
                               "auto", alphas[-1], True, 4, 0.1)
                )
            points.append(  # early on exact folds with everything else
                AuditPoint(family, storage, view, "exact", full_w, 8, 3,
                           "auto", alphas[-1], True, 4, 0.1)
            )
    return points


def _view_args(index, view: str):
    if view == "segmented":
        return index.state, index.delta, index.tombstones
    return index.state, None, None


def _shape_signature(args) -> tuple:
    """What jit's cache key sees of the dynamic args: flattened avals plus
    the treedef."""
    import jax

    leaves, treedef = jax.tree_util.tree_flatten(args)
    return (
        tuple((tuple(x.shape), str(x.dtype)) for x in leaves),
        str(treedef),
    )


def compile_key(point: AuditPoint, index, queries, weights, normalized: bool = True):
    """The compile key a call at this lattice point costs: the dynamic-arg
    shape signature plus the (normalized) static tuple — exactly the pair
    the engine's jit cache is keyed on."""
    from repro.engine import pipeline

    g = budgets.AUDIT_GEOMETRY
    cfg = _audit_config(point.family, point.storage, point.window)
    state, delta, tomb = _view_args(index, point.view)
    statics = (
        cfg, g["k"], point.mode, point.n_probes, point.max_flips, point.impl,
        point.screen_alpha, point.early_exit, point.exit_group,
        point.exit_slack,
    )
    if normalized:
        statics = tuple(
            pipeline.normalize_static_args(
                cfg, state.data.dtype, g["k"], point.mode, point.n_probes,
                point.max_flips, point.impl, point.screen_alpha,
                point.early_exit, point.exit_group, point.exit_slack,
            )
        )
    sig = _shape_signature((state, delta, tomb, queries, weights))
    return (sig, statics)


def trace_point(point: AuditPoint, index, queries, weights, inject: Optional[str] = None):
    """``jax.make_jaxpr`` of the real dispatch at this lattice point —
    nothing executes. ``inject="memory"`` splices the historical
    (b, L·P·C, cap) dense-delta-match materialization into segmented
    paths (the regression shape this auditor exists to catch)."""
    import jax
    import jax.numpy as jnp

    from repro.engine import pipeline

    g = budgets.AUDIT_GEOMETRY
    cfg = _audit_config(point.family, point.storage, point.window)
    state, delta, tomb = _view_args(index, point.view)

    def fn(state, delta, tomb, q, w):
        res = pipeline.dispatch(
            state, delta, tomb, q, w, cfg,
            k=g["k"], mode=point.mode, n_probes=point.n_probes,
            max_flips=point.max_flips, impl=point.impl,
            screen_alpha=point.screen_alpha, early_exit=point.early_exit,
            exit_group=point.exit_group, exit_slack=point.exit_slack,
        )
        if inject == "memory" and delta is not None:
            slots = cfg.L * point.n_probes * cfg.max_candidates
            cap = g["delta_capacity"]
            dense = jnp.zeros((q.shape[0], slots, cap), jnp.float32) + q[:, :1, None]
            res = res._replace(dists=res.dists + 0.0 * dense.sum())
        return res

    return jax.make_jaxpr(fn)(state, delta, tomb, queries, weights)


# -- jaxpr walking -----------------------------------------------------------


def _aval_bytes(aval) -> int:
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    size = 1
    for dim in shape:
        if not isinstance(dim, int):
            return 0  # dynamic dim — cannot cost it statically
        size *= dim
    return size * dtype.itemsize


def _sub_jaxprs(eqn):
    """Every Jaxpr/ClosedJaxpr hiding in an eqn's params (pjit, scan,
    while, cond branches, custom_jvp, ...)."""
    from jax._src import core as jcore

    found = []
    for v in eqn.params.values():
        items = v if isinstance(v, (tuple, list)) else (v,)
        for item in items:
            if isinstance(item, jcore.ClosedJaxpr):
                found.append(item.jaxpr)
            elif isinstance(item, jcore.Jaxpr):
                found.append(item)
    return found


def peak_live_bytes(jaxpr) -> int:
    """Deterministic liveness scan: walk eqns in trace order, allocate
    outputs, free each var after its last use; sub-jaxpr peaks count on
    top of the outer live set at their call site (minus their inputs,
    which alias outer buffers). An upper-bound *model* of XLA's actual
    allocator — its value is being stable and monotone in the shapes that
    matter, not being byte-exact."""
    from jax._src import core as jcore

    last_use: dict = {}
    for i, eqn in enumerate(jaxpr.eqns):
        for v in eqn.invars:
            if isinstance(v, jcore.Var):
                last_use[v] = i
    outset = {v for v in jaxpr.outvars if isinstance(v, jcore.Var)}

    live: dict = {}
    for v in (*jaxpr.invars, *jaxpr.constvars):
        live[v] = _aval_bytes(v.aval)
    cur = sum(live.values())
    peak = cur
    for i, eqn in enumerate(jaxpr.eqns):
        inner_extra = 0
        for sub in _sub_jaxprs(eqn):
            sub_inputs = sum(
                _aval_bytes(v.aval) for v in (*sub.invars, *sub.constvars)
            )
            inner_extra = max(inner_extra, peak_live_bytes(sub) - sub_inputs)
        for v in eqn.outvars:
            if isinstance(v, jcore.Var) and v not in live:
                live[v] = _aval_bytes(v.aval)
                cur += live[v]
        peak = max(peak, cur + max(inner_extra, 0))
        for v in eqn.invars:
            if (
                isinstance(v, jcore.Var)
                and last_use.get(v) == i
                and v not in outset
                and v in live
            ):
                cur -= live.pop(v)
    return peak


def dtype_violations(jaxpr, path: str) -> list:
    """AUD003 findings: f64 avals anywhere; int8 avals consumed by a
    primitive outside the movement/decode set."""
    import numpy as np
    from jax._src import core as jcore

    out = []

    def walk(jx):
        for eqn in jx.eqns:
            prim = eqn.primitive.name
            for v in (*eqn.invars, *eqn.outvars):
                aval = getattr(v, "aval", None)
                dt = getattr(aval, "dtype", None)
                if dt is None:
                    continue
                if dt == np.float64:
                    out.append(
                        AuditFailure(
                            "AUD003", path,
                            f"f64 aval at primitive `{prim}` — silent double "
                            f"promotion doubles every table and intermediate",
                            64, 32,
                        )
                    )
            for v in eqn.invars:
                aval = getattr(v, "aval", None)
                dt = getattr(aval, "dtype", None)
                if dt is not None and dt == np.int8 and (
                    prim not in budgets.INT8_ALLOWED_PRIMITIVES
                ):
                    out.append(
                        AuditFailure(
                            "AUD003", path,
                            f"int8 operand consumed by `{prim}` — quantized "
                            f"rows may only move (gather/slice/reshape) and "
                            f"decode (convert_element_type); arithmetic "
                            f"belongs after the decode",
                            1, 0,
                        )
                    )
            for sub in _sub_jaxprs(eqn):
                walk(sub)

    walk(jaxpr)
    # dedupe (the same breach shows once per aval otherwise)
    seen, uniq = set(), []
    for f in out:
        key = (f.code, f.path, f.message)
        if key not in seen:
            seen.add(key)
            uniq.append(f)
    return uniq


# -- live normalization probe -------------------------------------------------


def live_normalization_probe() -> list:
    """Push the denormalized static variants through the REAL jitted entry
    point on a tiny index under a RetraceGuard: after one warm call per
    distinct program, the redundant axes must compile nothing new. The
    dynamic counterpart of the static compile-key count — both watch the
    same contract, through :mod:`repro.analysis.retrace_guard`."""
    import jax
    import jax.numpy as jnp

    from repro.api.index import Index
    from repro.engine import pipeline

    key = jax.random.PRNGKey(0)
    data = jax.random.uniform(key, (64, 4), jnp.float32)
    cfg = _audit_config("theta", "f32")
    cfg = dataclasses.replace(cfg, d=4, K=3, L=2, max_candidates=8)
    index = Index.build(key, data, cfg)
    q = jnp.zeros((2, 4), jnp.float32)
    w = jnp.ones((2, 4), jnp.float32)

    def call(mode, n_probes, impl, alpha, early=False, group=0, slack=0.0):
        pipeline.query(
            index.state, None, None, q, w, cfg, k=3, mode=mode,
            n_probes=n_probes, max_flips=2, impl=impl, screen_alpha=alpha,
            early_exit=early, exit_group=group, exit_slack=slack,
        )

    # warm one program per genuinely-distinct point
    call("probe", 1, "auto", 0.0)
    call("multiprobe", 4, "auto", 0.0)
    call("exact", 1, "auto", 0.0)
    call("probe", 1, "auto", 0.0, early=True, group=1, slack=0.1)  # L=2: 2 groups
    guard = RetraceGuard()
    guard.snapshot()
    # redundant static variants — every one must hit the warm cache
    call("probe", 8, "auto", 0.0)      # probe ignores n_probes
    call("probe", 1, "auto", 2.0)      # f32 ignores screen_alpha
    call("multiprobe", 4, "gather", 0.0)  # non-probe ignores impl
    call("exact", 8, "gather", 2.0)    # exact ignores all of them
    call("probe", 1, "auto", 0.0, group=7, slack=0.5)  # knobs dead while off
    call("probe", 1, "auto", 0.0, early=True, group=2)  # one group == off
    call("probe", 8, "auto", 0.0, early=True, group=1, slack=0.1)  # n_probes folds
    call("exact", 1, "auto", 0.0, early=True, group=1, slack=0.1)  # exact folds
    try:
        guard.assert_no_retrace(context="the live normalization probe")
    except AssertionError as e:
        return [
            AuditFailure(
                "AUD002", "live-probe",
                f"denormalized static variants compiled new programs: {e}",
                guard.cache_size(), guard.baseline,
            )
        ]
    return []


# -- the audit ----------------------------------------------------------------


def run_audit(
    inject: Optional[str] = None,
    golden: Optional[dict] = None,
    live_probe: bool = True,
) -> dict:
    """Trace the lattice, check every budget, and return the report dict
    (``report["ok"]`` is the gate verdict; ``report["failures"]`` name
    each breach with its code, path, and measured-vs-budget numbers)."""
    import jax

    if inject not in (None, "memory", "retrace"):
        raise ValueError(
            f"inject must be None, 'memory', or 'retrace'; got {inject!r}"
        )
    import jax.numpy as jnp

    g = budgets.AUDIT_GEOMETRY
    indexes = build_audit_indexes()
    queries = jnp.zeros((g["b"], g["d"]), jnp.float32)
    weights = jnp.ones((g["b"], g["d"]), jnp.float32)
    points = enumerate_points()

    # --- compile-key cardinality over the raw lattice
    normalized = inject != "retrace"
    keys: dict = {}
    for p in points:
        k = compile_key(p, indexes[(p.family, p.storage)], queries, weights,
                        normalized=normalized)
        keys.setdefault(k, []).append(p)
    failures: list = []
    n_keys = len(keys)
    if n_keys > budgets.RETRACE_BUDGET:
        # name an axis that failed to fold: two raw points sharing a
        # normalized key but split across raw keys
        example = ""
        if not normalized:
            by_norm: dict = {}
            for p in points:
                nk = compile_key(p, indexes[(p.family, p.storage)], queries,
                                 weights, normalized=True)
                by_norm.setdefault(nk, set()).add(
                    compile_key(p, indexes[(p.family, p.storage)], queries,
                                weights, normalized=False)
                )
            split = next((v for v in by_norm.values() if len(v) > 1), None)
            if split:
                variants = sorted(str(s[1][2:]) for s in split)[:2]
                example = (
                    f"; e.g. one program now compiles per static variant "
                    f"{' vs '.join(variants)}"
                )
        failures.append(
            AuditFailure(
                "AUD002", "lattice",
                f"compile-key cardinality {n_keys} exceeds the declared "
                f"retrace budget {budgets.RETRACE_BUDGET} — a static axis "
                f"is not folded by normalize_static_args{example}",
                n_keys, budgets.RETRACE_BUDGET,
            )
        )
    elif n_keys < budgets.RETRACE_BUDGET and golden is not None:
        failures.append(
            AuditFailure(
                "AUD004", "lattice",
                f"compile-key cardinality {n_keys} under budget "
                f"{budgets.RETRACE_BUDGET} — a lattice path disappeared; "
                f"update budgets.RETRACE_BUDGET and the golden if intended",
                n_keys, budgets.RETRACE_BUDGET,
            )
        )

    # --- per-program traces (one representative per distinct key)
    paths = []
    worst = ("", 0)
    for key_, pts in sorted(keys.items(), key=lambda kv: kv[1][0].name):
        rep = pts[0]
        closed = trace_point(
            rep, indexes[(rep.family, rep.storage)], queries, weights,
            inject=inject if inject == "memory" else None,
        )
        peak = peak_live_bytes(closed.jaxpr)
        dvs = dtype_violations(closed.jaxpr, rep.name)
        failures += dvs
        paths.append(
            {
                "name": rep.name,
                "peak_live_bytes": int(peak),
                "eqns": len(closed.jaxpr.eqns),
                "dtype_ok": not dvs,
                "raw_variants": len(pts),
            }
        )
        if peak > worst[1]:
            worst = (rep.name, peak)
        if peak > budgets.MEMORY_ENVELOPE_BYTES:
            failures.append(
                AuditFailure(
                    "AUD001", rep.name,
                    f"peak live intermediates {peak / 2**20:.1f} MiB exceed "
                    f"the {budgets.MEMORY_ENVELOPE_BYTES / 2**20:.0f} MiB "
                    f"memory envelope — a (b, L·P·C, cap)-class "
                    f"materialization reached the traced path",
                    peak, budgets.MEMORY_ENVELOPE_BYTES,
                )
            )

    # --- golden diff (same-backend only; trace shapes differ across
    # backends because kernel dispatch branches on jax.default_backend())
    backend = jax.default_backend()
    if golden is not None and golden.get("backend") == backend:
        gpaths = golden.get("paths", {})
        for row in paths:
            want = gpaths.get(row["name"])
            if want is None:
                failures.append(
                    AuditFailure(
                        "AUD004", row["name"],
                        "path not in the golden budget — regenerate with "
                        "--write-golden if this lattice point is intended",
                        row["peak_live_bytes"], 0,
                    )
                )
                continue
            lo = want * (1 - budgets.GOLDEN_REL_TOL)
            hi = want * (1 + budgets.GOLDEN_REL_TOL)
            if not (lo <= row["peak_live_bytes"] <= hi):
                failures.append(
                    AuditFailure(
                        "AUD004", row["name"],
                        f"peak live bytes drifted beyond "
                        f"±{budgets.GOLDEN_REL_TOL:.0%} of the golden "
                        f"({want} bytes) — review, then --write-golden",
                        row["peak_live_bytes"], want,
                    )
                )
        for name in gpaths:
            if not any(r["name"] == name for r in paths):
                failures.append(
                    AuditFailure(
                        "AUD004", name,
                        "golden path no longer traced — a lattice point "
                        "disappeared; regenerate the golden if intended",
                        0, gpaths[name],
                    )
                )
        gkeys = golden.get("compile_keys")
        if gkeys is not None and gkeys != n_keys and n_keys <= budgets.RETRACE_BUDGET:
            failures.append(
                AuditFailure(
                    "AUD004", "lattice",
                    f"compile-key count changed vs golden ({gkeys})",
                    n_keys, gkeys,
                )
            )

    if live_probe and inject is None:
        failures += live_normalization_probe()

    return {
        "version": 1,
        "backend": backend,
        "geometry": dict(g),
        "inject": inject,
        "compile_keys": {
            "count": n_keys,
            "budget": budgets.RETRACE_BUDGET,
            "raw_points": len(points),
        },
        "memory": {
            "worst_path": worst[0],
            "max_peak_live_bytes": int(worst[1]),
            "envelope_bytes": budgets.MEMORY_ENVELOPE_BYTES,
        },
        "paths": paths,
        "failures": [f.to_dict() for f in failures],
        "ok": not failures,
    }


def golden_from_report(report: dict) -> dict:
    return {
        "backend": report["backend"],
        "compile_keys": report["compile_keys"]["count"],
        "paths": {
            row["name"]: row["peak_live_bytes"] for row in report["paths"]
        },
    }


def load_golden(path=None) -> Optional[dict]:
    path = path or budgets.GOLDEN_PATH
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        return None
