"""Reference generalized weighted distances (paper Eq. 2).

``d_w^l1(o, q) = sum_i w_i |o_i - q_i|``   (generalized weighted Manhattan)
``d_w^l2(o, q) = sum_i w_i (o_i - q_i)^2`` (generalized weighted square Euclidean)

Weights arrive *with the query* and may be negative — these are plain
reductions, used as the exactness oracle for every approximate path in the
framework (ALSH probes re-rank their candidates with ``wl1_distance``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wl1_distance(o: jax.Array, q: jax.Array, w: jax.Array) -> jax.Array:
    """Generalized weighted Manhattan distance.

    Args:
      o: data points, shape ``(..., d)``.
      q: query point(s), broadcastable to ``o`` — typically ``(d,)`` or ``(b, 1, d)``.
      w: weight vector(s), same broadcast rules as ``q``.

    Returns:
      distances with shape ``broadcast(o, q).shape[:-1]``.
    """
    return jnp.sum(w * jnp.abs(o - q), axis=-1)


def wl2_distance(o: jax.Array, q: jax.Array, w: jax.Array) -> jax.Array:
    """Generalized weighted square Euclidean distance (comparison baseline)."""
    diff = o - q
    return jnp.sum(w * diff * diff, axis=-1)


def pairwise_wl1(O: jax.Array, Q: jax.Array, W: jax.Array) -> jax.Array:
    """All-pairs weighted Manhattan: ``O (n, d)``, ``Q (b, d)``, ``W (b, d)`` -> ``(b, n)``."""
    return jnp.sum(W[:, None, :] * jnp.abs(O[None, :, :] - Q[:, None, :]), axis=-1)


def recall_at_k(ids, ref_ids, k: int | None = None) -> float:
    """Mean recall@k of retrieved ``ids`` against reference ``ref_ids``.

    Args:
      ids: (b, k') retrieved ids; entries < 0 are padding and never count.
      ref_ids: (b, k'') reference (exact) ids, same convention.
      k: denominator; defaults to ``ref_ids.shape[1]``.
    """
    import numpy as np

    ids = np.asarray(ids)
    ref = np.asarray(ref_ids)
    if k is None:
        k = ref.shape[1]
    hits = [
        len({x for x in ids[i].tolist() if x >= 0}
            & {x for x in ref[i].tolist() if x >= 0}) / k
        for i in range(ids.shape[0])
    ]
    return float(np.mean(hits))


def brute_force_nn(
    data: jax.Array,
    q: jax.Array,
    w: jax.Array,
    k: int = 1,
    distance: str = "wl1",
) -> tuple[jax.Array, jax.Array]:
    """Exact k-NN by linear scan — the O(nd) baseline the paper improves on.

    Args:
      data: ``(n, d)`` database.
      q: ``(d,)`` or ``(b, d)`` queries.
      w: weights, same shape as ``q``.
      k: neighbours to return.
      distance: ``"wl1"`` or ``"wl2"``.

    Returns:
      ``(dists, ids)`` each ``(k,)`` or ``(b, k)``, ascending by distance.

    The wl1 path runs through ``kernels.ops.wl1_scan_topk`` — the streaming
    top-k scan (Pallas on TPU, chunked jnp on CPU) that never materializes
    the (b, n) distance matrix; wl2 keeps the direct reduction.
    """
    squeeze = q.ndim == 1
    qb = jnp.atleast_2d(q)
    wb = jnp.atleast_2d(w)
    if distance == "wl1":
        from repro.kernels import ops

        dists, ids = ops.wl1_scan_topk(data, qb, wb, k)
    else:
        d = wl2_distance(data[None, :, :], qb[:, None, :], wb[:, None, :])  # (b, n)
        neg_top, ids = jax.lax.top_k(-d, k)
        dists = -neg_top
    if squeeze:
        return dists[0], ids[0]
    return dists, ids
