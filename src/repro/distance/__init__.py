from repro.distance.wl1 import (
    wl1_distance,
    wl2_distance,
    brute_force_nn,
    pairwise_wl1,
    recall_at_k,
)

__all__ = [
    "wl1_distance",
    "wl2_distance",
    "brute_force_nn",
    "pairwise_wl1",
    "recall_at_k",
]
