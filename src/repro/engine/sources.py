"""Candidate sources: where a query's candidate ids come from.

A :class:`CandidateSource` turns one query batch into a fixed-shape block
of candidate ids. The block contract (what lets arbitrary sources compose
through one tail):

  * ``emit(queries, weights)`` returns ``(b, P_src)`` int32 ids with a
    STATIC ``P_src`` (shapes never depend on how many candidates actually
    matched — jit/vmap/shard_map safe).
  * entries ``>= n_valid`` (the engine's total addressable row count) mark
    empty slots; any value past ``n_valid`` is a legal padding sentinel.
  * live candidate ids are GLOBAL row ids — main rows keep their build ids
    ``[0, n_main)``, delta slot ``s`` is ``n_main + s`` — so blocks from
    different sources concatenate without translation.

Three implementations cover the repo's whole query surface:

  * :class:`SortedTableSource` — the sealed main segment: searchsorted
    window probe of the L sorted key columns, one window per (table, probe
    key) pair. Handles single-probe and multiprobe identically (the key
    enumeration upstream decides P).
  * :class:`DeltaMatchSource` — the unsealed delta segment: chunked dense
    key match over the capacity (``core.index._delta_candidates``).
  * :class:`ExhaustiveSource` — every live row (the exact oracle as a
    source, so even the ground-truth scan runs the same tail).

The per-shard local source of the distributed service is not a fourth
class: inside ``shard_map`` each shard's view IS a (SortedTableSource,
DeltaMatchSource) composition over its slice — ``pipeline.dispatch`` runs
unchanged per shard and the hierarchical merge composes the shard results.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Protocol

import jax
import jax.numpy as jnp

from repro.core.index import (
    _delta_candidates,
    _mask_dead,
    _probe_one_table,
    delta_live_mask,
)

if TYPE_CHECKING:
    from repro.core.index import ALSHIndex, DeltaSegment, IndexConfig


class CandidateSource(Protocol):
    """Protocol every candidate source implements.

    ``pre_deduped`` declares the block already holds ascending unique ids
    with sentinels packed last — the tail then skips the dedupe sort and
    counts valid entries directly (the exact oracle uses this; probe
    sources must leave it False since windows overlap across tables).
    """

    pre_deduped: bool

    def emit(self, queries: jax.Array, weights: jax.Array) -> jax.Array:
        """(b, d) queries/weights -> (b, P_src) int32 candidate ids."""
        ...


class SortedTableSource:
    """Sealed-segment source: bounded sorted-window probe of every
    (table, probe key) pair.

    ``keys`` is the (b, L, P) probing sequence enumerated upstream —
    P == 1 reproduces the paper's single-probe lookup, P > 1 the
    query-directed multiprobe sequence. With ``tombstones`` given, window
    ids are masked to ``sentinel`` before they leave the source (window
    padding too), so deleted rows never reach the merge.
    """

    pre_deduped = False

    def __init__(
        self,
        state: "ALSHIndex",
        cfg: "IndexConfig",
        keys: jax.Array,
        tombstones: jax.Array | None = None,
        sentinel: int | None = None,
    ):
        self.state = state
        self.cfg = cfg
        self.keys = keys
        self.tombstones = tombstones
        self.sentinel = sentinel

    def emit(self, queries: jax.Array, weights: jax.Array) -> jax.Array:
        b = self.keys.shape[0]
        C = self.cfg.max_candidates
        # vmap over batch, then tables, then probes — one probe per
        # (query, table, key) triple, exactly the legacy enumeration order
        probe = jax.vmap(
            jax.vmap(
                jax.vmap(_probe_one_table, in_axes=(None, None, 0, None)),
                in_axes=(0, 0, 0, None),
            ),
            in_axes=(None, None, 0, None),
        )
        cand = probe(self.state.sorted_keys, self.state.perm, self.keys, C)
        cand = cand.reshape(b, -1)  # (b, L·P·C)
        if self.tombstones is not None:
            cand = _mask_dead(cand, self.tombstones, self.state.n, self.sentinel)
        return cand


class DeltaMatchSource:
    """Unsealed-segment source: chunked dense key match over the delta
    capacity. A slot is a candidate iff its stored key equals one of the
    query's probe keys IN THE SAME TABLE — the same predicate the sorted
    window applies to the sealed segment, so one key enumeration serves
    both sources."""

    pre_deduped = False

    def __init__(
        self,
        delta: "DeltaSegment",
        keys: jax.Array,
        live: jax.Array,
        n_main: int,
        sentinel: int,
    ):
        self.delta = delta
        self.keys = keys
        self.live = live
        self.n_main = n_main
        self.sentinel = sentinel

    def emit(self, queries: jax.Array, weights: jax.Array) -> jax.Array:
        return _delta_candidates(
            self.keys, self.delta, self.live, self.n_main, self.sentinel
        )


class ExhaustiveSource:
    """Every live row as a candidate — the exact oracle expressed as a
    source, so the ground truth runs the IDENTICAL tail it validates.
    Emits ascending live ids with sentinels packed last (``pre_deduped``:
    the tail skips its dedupe sort and the chunked kernel skips dead
    blocks)."""

    pre_deduped = True

    def __init__(
        self,
        state: "ALSHIndex",
        delta: "DeltaSegment | None",
        tombstones: jax.Array,
    ):
        n_main = state.n
        cap = delta.capacity if delta is not None else 0
        n_tot = n_main + cap
        live = ~tombstones[:n_main]
        if cap:
            live = jnp.concatenate([live, delta_live_mask(delta, tombstones, n_main)])
        self.ids_row = jnp.sort(
            jnp.where(live, jnp.arange(n_tot, dtype=jnp.int32), n_tot)
        )

    def emit(self, queries: jax.Array, weights: jax.Array) -> jax.Array:
        b = queries.shape[0]
        return jnp.broadcast_to(self.ids_row[None, :], (b, self.ids_row.shape[0]))
