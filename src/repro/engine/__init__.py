"""The candidate-stream execution engine — ONE query pipeline for every mode.

The paper's Theorem-1 query procedure is a single conceptual pipeline:
probe the L sorted tables, union the candidates, re-rank exactly under
d_w^l1. This package is that pipeline, factored so every query variant the
repo serves — single-probe, multiprobe, two-segment (mutable), exact
oracle, and the per-shard bodies of the distributed service — is a
*composition of candidate sources over one shared tail* instead of its own
copy of the probe/dedupe/mask/gather/rerank code:

  keys     = probe_keys(...)            # (b, L, P) — probe vs multiprobe is
                                        # just a different key enumeration
  sources  = sources_for(...)           # CandidateSource per segment
  blocks   = [s.emit(q, w) ...]         # fixed-shape (b, P_src) id blocks
  result   = merge → dedupe → fused gather/rerank/top-k   (execute())

``dispatch`` wires the stages for one index view (a single host, or one
shard inside ``shard_map`` — the sharded service is exactly this engine per
shard plus a hierarchical top-k merge on top); ``query`` is its jitted form
that the legacy ``repro.core`` entry points and the ``repro.api`` facade
both call, so every consumer shares one compiled-program cache and one set
of invariants (sentinels, tombstone semantics, dedupe counts).

See DESIGN.md §8 for the block-shape and merge-semantics contract.
"""

from repro.engine.pipeline import (
    dispatch,
    execute,
    execute_streamed,
    probe_keys,
    query,
    sources_for,
)
from repro.engine.sources import (
    CandidateSource,
    DeltaMatchSource,
    ExhaustiveSource,
    SortedTableSource,
)

__all__ = [
    "CandidateSource",
    "DeltaMatchSource",
    "ExhaustiveSource",
    "SortedTableSource",
    "dispatch",
    "execute",
    "execute_streamed",
    "probe_keys",
    "query",
    "sources_for",
]
