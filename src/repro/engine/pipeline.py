"""The shared execution pipeline: key enumeration → sources → one tail.

Stage contract (DESIGN.md §8):

  1. ``probe_keys`` — (b, L, P) int32 probing sequence. P = 1 is the
     paper's single-probe lookup; P > 1 is the Lv et al. query-directed
     sequence. This is the ONLY stage where probe and multiprobe differ.
  2. ``sources_for`` — the :mod:`repro.engine.sources` composition of the
     index view: sealed table windows, plus the delta key match when a
     delta segment is present. Tombstone masking happens inside the
     sources (before merge), so a deleted row can never reach a result.
  3. ``execute`` — merge the fixed-shape blocks, dedupe by sort (unique
     ids packed first; the unique count is the paper's sublinearity
     metric), and hand the ids to the fused gather/rerank/top-k kernel,
     which gathers straight from BOTH segment tables (scalar-prefetch DMA
     on TPU, chunked streaming on CPU) — neither a (b, P, d) candidate
     tensor nor an (n_main + cap, d) concatenated table is materialized.

``dispatch`` wires the stages for one index view; inside ``shard_map``
each shard runs ``dispatch`` over its slice (the per-shard local source)
and the distributed service merges the per-shard results hierarchically.
``query`` is the jitted entry every consumer shares — the legacy
``repro.core`` wrappers, the ``repro.api`` facade, and the planner's
calibration rungs all hit one compiled-program cache.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import transforms
from repro.core.index import (
    ALSHIndex,
    DeltaSegment,
    IndexConfig,
    QueryResult,
    _dedupe_candidates,
    _keys_for,
    delta_live_mask,
)
from repro.engine.sources import (
    CandidateSource,
    DeltaMatchSource,
    ExhaustiveSource,
    SortedTableSource,
)


def probe_keys(
    state: ALSHIndex,
    queries: jax.Array,
    weights: jax.Array,
    cfg: IndexConfig,
    mode: str = "probe",
    n_probes: int = 8,
    max_flips: int = 3,
    impl: str = "auto",
    with_ranks: bool = False,
) -> jax.Array:
    """Enumerate the (b, L, P) probing sequence of a query batch.

    mode="probe": each query's own bucket key per table (P = 1).
    mode="multiprobe": the query-directed perturbation sequence (P <=
    n_probes, clamped by the family's reachable-subset count).

    ``with_ranks=True`` returns ``(keys, ranks)`` with ranks the (b, L, P)
    int32 per-window probe-quality rank (P-axis position — the family emits
    keys most-likely first; rank 0 is always the query's own bucket). The
    streamed early-exit tail consumes this contract to visit windows
    quality-major instead of table-major.
    """
    if mode == "multiprobe":
        from repro.core.multiprobe import multiprobe_keys_for

        return multiprobe_keys_for(
            state, queries, weights, cfg, n_probes, max_flips, with_ranks=with_ranks
        )
    qlevels = transforms.discretize(queries, cfg.space)
    keys = _keys_for(qlevels, weights, state.tables, cfg, state.mixers, impl=impl)
    keys = keys[:, :, None]  # (b, L, 1)
    if not with_ranks:
        return keys
    return keys, jnp.zeros(keys.shape, jnp.int32)  # single probe = rank 0


def sources_for(
    state: ALSHIndex,
    delta: DeltaSegment | None,
    tombstones: jax.Array | None,
    cfg: IndexConfig,
    keys: jax.Array,
) -> list[CandidateSource]:
    """The candidate-source composition of one index view (a single host,
    or one shard's slice inside ``shard_map``): the sealed sorted-table
    window probe, plus the delta key match when a delta segment is
    present. One key enumeration feeds every source."""
    n_main = state.n
    cap = delta.capacity if delta is not None else 0
    n_tot = n_main + cap
    segmented = tombstones is not None or delta is not None
    if segmented and tombstones is None:
        tombstones = jnp.zeros((n_tot,), bool)
    srcs: list[CandidateSource] = [
        SortedTableSource(
            state,
            cfg,
            keys,
            tombstones=tombstones if segmented else None,
            sentinel=n_tot,
        )
    ]
    if cap:
        live = delta_live_mask(delta, tombstones, n_main)
        srcs.append(DeltaMatchSource(delta, keys, live, n_main, n_tot))
    return srcs


def execute(
    sources: list[CandidateSource],
    main_data: jax.Array,
    delta_data: jax.Array | None,
    queries: jax.Array,
    weights: jax.Array,
    k: int,
    n_valid: int,
    scales: jax.Array | None = None,
    screen_alpha: float = 0.0,
) -> QueryResult:
    """The shared tail: merge source blocks → dedupe → [quantized screen →]
    fused gather/rerank/top-k over the (optionally two-segment) row tables.

    ``n_valid`` is the total addressable row count (main + delta
    capacity); any id >= n_valid in a block is padding. A single
    ``pre_deduped`` source skips the dedupe sort (its block is already
    ascending-unique) and counts valid entries directly.

    With quantized storage (``main_data`` non-f32) and ``screen_alpha`` > 0
    a screening stage runs between dedupe and the exact rerank: the SAME
    fused kernel ranks every candidate by the compressed-domain proxy
    distance (``quant.proxy_query`` — no decode, the gather moves encoded
    bytes) and only the top ``ceil(k·α)`` survivors reach the exact f32
    rerank. ``screen_alpha`` must be trace-static (it sets the survivor
    shape). α = 0, f32 storage, or a survivor set covering every slot all
    statically disable the stage — the tail is then exactly the pre-screen
    program.
    """
    from repro import quant
    from repro.kernels import ops

    blocks = [s.emit(queries, weights) for s in sources]
    cand = blocks[0] if len(blocks) == 1 else jnp.concatenate(blocks, axis=1)
    if len(sources) == 1 and sources[0].pre_deduped:
        n_candidates = jnp.sum(cand < n_valid, axis=1).astype(jnp.int32)
    else:
        cand, n_candidates = _dedupe_candidates(cand, n_valid)
    keep = quant.screen_keep(k, screen_alpha, cand.shape[1])  # static int
    if keep:
        qp, wp = quant.proxy_query(queries, weights, main_data.dtype, scales)
        _, surv = ops.gather_rerank_topk(
            main_data, cand, qp, wp, keep, delta=delta_data
        )
        # survivors come back -1-padded; remap to the candidate sentinel the
        # rerank expects (so invalid slots stay invalid, never row 0)
        cand = jnp.where(surv >= 0, surv, n_valid).astype(jnp.int32)
    dists, ids = ops.gather_rerank_topk(
        main_data, cand, queries, weights, k, delta=delta_data, scales=scales
    )
    return QueryResult(dists=dists, ids=ids, n_candidates=n_candidates)


def execute_streamed(
    state: ALSHIndex,
    delta: DeltaSegment | None,
    tombstones: jax.Array | None,
    queries: jax.Array,
    weights: jax.Array,
    cfg: IndexConfig,
    keys: jax.Array,
    k: int,
    exit_group: int = 8,
    exit_slack: float = 0.0,
) -> QueryResult:
    """The adaptive-probing tail: stream the (b, L, P) window lattice in
    trace-static ``exit_group``-sized groups (quality-major order) through a
    ``lax.while_loop`` that carries the running top-k heap and a per-query
    live mask, stopping each query as soon as the geometric bound or the
    Eq 25/27 confidence estimate (at ``exit_slack`` miss budget) says the
    remaining windows cannot change its answer. Stopped queries ride
    all-sentinel blocks, so shapes — and the compiled program — are
    identical across batch compositions and delta fill levels. See
    :mod:`repro.engine.stream` for the algorithm and the bit-identity
    argument; results additionally report ``tables_probed``/``stop_reason``.
    """
    from repro.engine import stream

    return stream.stream_topk(
        state,
        delta,
        tombstones,
        queries,
        weights,
        cfg,
        keys,
        k,
        scales=state.scales,
        exit_group=exit_group,
        exit_slack=exit_slack,
    )


def dispatch(
    state: ALSHIndex,
    delta: DeltaSegment | None,
    tombstones: jax.Array | None,
    queries: jax.Array,
    weights: jax.Array,
    cfg: IndexConfig | None,
    k: int = 1,
    mode: str = "probe",
    n_probes: int = 8,
    max_flips: int = 3,
    impl: str = "auto",
    screen_alpha: float = 0.0,
    early_exit: bool = False,
    exit_group: int = 8,
    exit_slack: float = 0.0,
) -> QueryResult:
    """One query dispatch for every index view — the single-host facade,
    the legacy ``repro.core`` entry points, and each shard's body inside
    ``shard_map`` all run THIS function, so mode/segment/tombstone
    semantics cannot drift between deployments.

    ``delta``/``tombstones`` are None for an immutable (sealed-only) view;
    ``cfg`` may be None only for mode="exact" (no hashing happens).
    ``screen_alpha`` > 0 enables the quantized proxy screen of ``execute``
    (meaningful only for non-f32 storage; the jitted ``query`` wrapper
    normalizes it away everywhere else). ``early_exit=True`` routes the
    probe/multiprobe key lattice through :func:`execute_streamed` instead of
    the monolithic tail — the ``query`` wrapper folds it off whenever
    streaming cannot apply (exact mode, an active quantized screen, or a
    group covering the whole lattice). Trace-compatible: call under
    jit/shard_map freely, or use the jitted ``query`` wrapper from the
    host.
    """
    n_main = state.n
    cap = delta.capacity if delta is not None else 0
    segmented = tombstones is not None or delta is not None
    if mode == "exact":
        if not segmented:
            from repro import quant
            from repro.kernels import ops

            table = (
                state.data
                if state.data.dtype == jnp.float32
                else quant.decode_table(state.data, state.scales)
            )
            dists, ids = ops.wl1_scan_topk(table, queries, weights, k)
            n_candidates = jnp.full(queries.shape[0], n_main, jnp.int32)
            return QueryResult(dists=dists, ids=ids, n_candidates=n_candidates)
        if tombstones is None:
            tombstones = jnp.zeros((n_main + cap,), bool)
        src = ExhaustiveSource(state, delta, tombstones)
        return execute(
            [src],
            state.data,
            delta.data if cap else None,
            queries,
            weights,
            k,
            n_valid=n_main + cap,
            scales=state.scales,
        )
    keys = probe_keys(
        state, queries, weights, cfg,
        mode=mode, n_probes=n_probes, max_flips=max_flips, impl=impl,
    )
    if early_exit:
        return execute_streamed(
            state, delta, tombstones, queries, weights, cfg, keys, k,
            exit_group=exit_group, exit_slack=exit_slack,
        )
    srcs = sources_for(state, delta, tombstones, cfg, keys)
    return execute(
        srcs,
        state.data,
        delta.data if cap else None,
        queries,
        weights,
        k,
        n_valid=n_main + cap,
        scales=state.scales,
        screen_alpha=screen_alpha,
    )


def normalize_static_args(
    cfg: IndexConfig | None,
    storage_dtype,
    k: int,
    mode: str,
    n_probes: int,
    max_flips: int,
    impl: str,
    screen_alpha: float,
    early_exit: bool = False,
    exit_group: int = 8,
    exit_slack: float = 0.0,
) -> tuple:
    """Canonicalize the static arguments of a query BEFORE the jit
    compile-key lookup: every static a mode does not read is forced to its
    neutral value, so two calls that would trace the same program always
    share one executable. This is THE retrace contract of the engine —
    ``query`` applies it on every call and the :mod:`repro.analysis`
    auditor enumerates the public entry-point lattice through this same
    function to check the compile-key cardinality against the declared
    budget (a new static axis that this normalization does not fold shows
    up there as a retrace-budget breach at review time, not as compile
    stalls in production).

    Early-exit folds: streaming never applies to exact scans (the scan
    already visits every row once) or under an active quantized screen
    (the proxy screen is a global candidate-set stage — DESIGN.md §13), and
    a group covering the whole L·P window lattice IS the monolithic tail,
    so all three cases fold to ``early_exit=False``; whenever early exit is
    off, ``exit_group``/``exit_slack`` are forced to 0 so the knobs cannot
    mint compile keys for a program that never reads them.

    Returns the normalized ``(cfg, k, mode, n_probes, max_flips, impl,
    screen_alpha, early_exit, exit_group, exit_slack)`` tuple.
    """
    if mode != "multiprobe":
        n_probes, max_flips = 1, 0
    if mode != "probe":
        impl = "auto"
    if mode == "exact":
        cfg = None
    if mode == "exact" or jnp.dtype(storage_dtype) == jnp.dtype(jnp.float32):
        screen_alpha = 0.0
    if early_exit:
        if mode == "exact" or screen_alpha > 0.0:
            early_exit = False
        else:
            from repro.core.families import n_flip_subsets

            p_eff = (
                1
                if mode == "probe"
                else min(n_probes, n_flip_subsets(cfg.K, max_flips))
            )
            if exit_group >= cfg.L * p_eff:
                early_exit = False  # one group == the monolithic tail
    if not early_exit:
        exit_group, exit_slack = 0, 0.0
    return (
        cfg,
        k,
        mode,
        n_probes,
        max_flips,
        impl,
        float(screen_alpha),
        bool(early_exit),
        int(exit_group),
        float(exit_slack),
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "cfg", "k", "mode", "n_probes", "max_flips", "impl", "screen_alpha",
        "early_exit", "exit_group", "exit_slack",
    ),
)
def _query_jit(
    state: ALSHIndex,
    delta: DeltaSegment | None,
    tombstones: jax.Array | None,
    queries: jax.Array,
    weights: jax.Array,
    cfg: IndexConfig | None,
    k: int,
    mode: str,
    n_probes: int,
    max_flips: int,
    impl: str,
    screen_alpha: float,
    early_exit: bool,
    exit_group: int,
    exit_slack: float,
) -> QueryResult:
    return dispatch(
        state, delta, tombstones, queries, weights, cfg,
        k=k, mode=mode, n_probes=n_probes, max_flips=max_flips, impl=impl,
        screen_alpha=screen_alpha, early_exit=early_exit, exit_group=exit_group,
        exit_slack=exit_slack,
    )


def query(
    state: ALSHIndex,
    delta: DeltaSegment | None,
    tombstones: jax.Array | None,
    queries: jax.Array,
    weights: jax.Array,
    cfg: IndexConfig | None,
    k: int = 1,
    mode: str = "probe",
    n_probes: int = 8,
    max_flips: int = 3,
    impl: str = "auto",
    screen_alpha: float = 0.0,
    early_exit: bool = False,
    exit_group: int = 8,
    exit_slack: float = 0.0,
) -> QueryResult:
    """Jitted ``dispatch`` — the one compiled entry point every consumer
    shares. Static args a mode does not read are normalized by
    :func:`normalize_static_args` before the compile-key lookup (probe
    ignores n_probes/max_flips, multiprobe and exact ignore impl, exact
    ignores cfg entirely, ``screen_alpha`` is forced to 0 whenever
    screening cannot apply: f32-stored tables and exact scans, and the
    early-exit knobs fold off wherever streaming cannot apply), so two
    calls that trace the same program always reuse one executable —
    facade or legacy shim alike, whatever defaults their spec happened to
    carry."""
    (
        cfg, k, mode, n_probes, max_flips, impl, screen_alpha,
        early_exit, exit_group, exit_slack,
    ) = normalize_static_args(
        cfg, state.data.dtype, k, mode, n_probes, max_flips, impl, screen_alpha,
        early_exit, exit_group, exit_slack,
    )
    return _query_jit(
        state, delta, tombstones, queries, weights, cfg,
        k=k, mode=mode, n_probes=n_probes, max_flips=max_flips, impl=impl,
        screen_alpha=screen_alpha, early_exit=early_exit, exit_group=exit_group,
        exit_slack=exit_slack,
    )
