"""Streamed early-exit tail: confidence-bounded adaptive probing.

The monolithic :func:`repro.engine.pipeline.execute` pays the full
merge → dedupe → rerank cost of all L·P probe windows for EVERY query —
the worst-case budget the planner provisioned (Eq 24/26 solve L for the
hardest query), even though most queries meet their neighbour in the
first handful of tables. This module streams the same windows through the
same primitives a trace-static group at a time and stops per query as
soon as the running top-k is final:

  * **Window order** is quality-major, not table-major: visit-position
    ``j`` maps to probe rank ``j // L`` of table ``j % L``, so every
    query's own-bucket windows (multiprobe rank 0 — the paper's
    single-probe lookup) are streamed across all tables before any
    perturbed bucket. The theta multiprobe sequence emits keys in
    increasing flip-cost order (:meth:`ThetaFamily.multiprobe_keys`), so
    the P axis position IS the per-query quality rank — the contract
    :func:`repro.core.multiprobe.multiprobe_keys_for` exposes via
    ``with_ranks=True``.
  * **The loop** is a single ``jax.lax.while_loop`` carrying the running
    top-k heap ``(b, k)``, a per-query live mask, and the probe/stop
    accounting. Every iteration probes one group of ``exit_group``
    windows, masks the block of already-stopped queries to the sentinel
    (shapes never depend on data — the program cannot retrace across
    delta fill levels or batch compositions), re-dedupes the heap ids
    into the block, and re-ranks the merged ``(b, k + G·C)`` candidates
    with the group-sized fused gather kernel.
  * **The stop predicate** is evaluated per query after each group:
    geometric — the kth running distance is provably unbeatable by any
    unseen window (under generalized weights the only sound bucket bound
    is the zero bound: distances are >= 0 iff the query's weights are all
    non-negative, so the rule fires exactly at ``kth == 0``); confidence
    — the Eq 25/27 collision estimate at the observed running radius says
    an unseen better-than-kth neighbour collided in none of the rank-0
    windows probed so far with probability <= ``exit_slack`` (computed in
    log space so a deep table budget cannot underflow the miss bound to a
    spurious 0).

Bit-identity: every selection in the engine — ``jax.lax.top_k`` over
ascending-unique deduped ids, and the Pallas replace-max with strict
``dist < worst`` — picks exactly the k smallest candidates under the
(dist, id) lexicographic order. Merging the running heap into each
group's deduped block therefore maintains, by induction, "heap == k
smallest (dist, id) of everything seen", and a full streamed pass (no
query stops) returns the monolithic tail's answer bit for bit. With
``exit_slack = 0`` the confidence rule is statically disabled and the
geometric rule fires only at distance exactly 0, so streamed results
remain bit-identical to ``early_exit=False`` on any dataset without
duplicate rows at distance 0 from a query (ties at 0 may reorder ids
among equal-distance neighbours — DESIGN.md §13).

``n_candidates`` stays the EXACT unique-candidate count (the paper's
sublinearity metric): the loop carries a per-query (b, n_tot + 1) seen
bitmask — heap evictions that get re-probed in a later group cannot be
double-counted, so a full streamed pass reports the monolithic tail's
count bit for bit. ``tables_probed`` counts probe WINDOWS visited
(== tables when P = 1); ``stop_reason`` is one of the ``STOP_*`` codes
below.
"""

from __future__ import annotations

import math

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import theory
from repro.core.index import (
    ALSHIndex,
    DeltaSegment,
    IndexConfig,
    QueryResult,
    _dedupe_candidates,
    _delta_candidates,
    _mask_dead,
    _probe_one_table,
    delta_live_mask,
)

# stop_reason codes (stable, stamped through QueryReport / --stats)
STOP_EXHAUSTED = 0  # every group streamed, no early stop
STOP_GEOMETRIC = 1  # running kth distance provably unbeatable
STOP_CONFIDENCE = 2  # Eq 25/27 miss estimate under the slack budget

# Eq 25/27 clip — matches Index.explain's success stamping
_P1_EPS = 1e-12


def window_order(L: int, P: int, exit_group: int) -> tuple:
    """The static quality-major visit order, padded to whole groups.

    Returns ``(tables, ranks, n_windows, n_groups)`` where ``tables`` /
    ``ranks`` are int ndarrays of length ``n_groups * exit_group`` giving
    each visit position's (table, probe-rank) pair. Visit position ``j``
    maps to ``(j % L, j // L)`` — all rank-0 windows first. Padding
    repeats the LAST window: a padded slot re-probes an already-streamed
    window, whose candidates dedupe against the heap, so the union of
    probed windows (and therefore the result) is unchanged.
    """
    n_windows = L * P
    n_groups = -(-n_windows // exit_group)
    j = np.minimum(np.arange(n_groups * exit_group), n_windows - 1)
    return (j % L).astype(np.int32), (j // L).astype(np.int32), n_windows, n_groups


def _miss_log_prob(r_raw, weights, cfg: IndexConfig, tables_done):
    """log of the Eq 25/27 miss estimate: probability a point within
    running radius ``r_raw`` of its query collided with the query in NONE
    of the ``tables_done`` own-bucket windows probed so far. Radii reach
    theory in lattice units (raw distance × space.t), at each query's OWN
    weight vector — the same stamping Index.explain applies."""
    r = r_raw * cfg.space.t
    if cfg.family == "l2":
        p1 = theory.collision_prob_l2(r, cfg.M, cfg.d, weights, cfg.W)
    else:
        p1 = theory.collision_prob_theta(r, cfg.M, cfg.d, weights)
    p1 = jnp.clip(p1, _P1_EPS, 1.0 - _P1_EPS)
    return tables_done * jnp.log1p(-(p1**cfg.K))


def stream_topk(
    state: ALSHIndex,
    delta: DeltaSegment | None,
    tombstones: jax.Array | None,
    queries: jax.Array,
    weights: jax.Array,
    cfg: IndexConfig,
    keys: jax.Array,
    k: int,
    scales: jax.Array | None = None,
    exit_group: int = 8,
    exit_slack: float = 0.0,
) -> QueryResult:
    """The streamed adaptive-probing tail (see module docstring).

    ``keys`` is the full (b, L, P) probing sequence from
    :func:`repro.engine.pipeline.probe_keys` — P axis ordered by per-query
    probe quality. ``exit_group`` and ``exit_slack`` must be the
    NORMALIZED statics (``normalize_static_args`` guarantees >= 2 groups
    and no active quantized screen on this path).
    """
    from repro.kernels import ops

    b, L, P = keys.shape
    n_main = state.n
    cap = delta.capacity if delta is not None else 0
    n_tot = n_main + cap
    segmented = tombstones is not None or delta is not None
    if segmented and tombstones is None:
        tombstones = jnp.zeros((n_tot,), bool)
    C = cfg.max_candidates
    G = exit_group
    tbl, _ranks, n_windows, n_groups = window_order(L, P, G)
    tbl = jnp.asarray(tbl)
    # per-query keys in visit order (b, n_groups*G): rank-major gather of
    # the (b, L, P) lattice
    kw = keys[:, tbl, jnp.asarray(_ranks)]

    main_data = state.data
    delta_data = delta.data if cap else None

    # The delta segment seeds the heap OUTSIDE the loop: it is one
    # fixed-shape key-match source, not a window stream, and folding it
    # into the initial heap keeps every loop iteration's shapes identical.
    # (Final result = k smallest over delta ∪ all windows either way.)
    # seen[q, i] == candidate i already examined for query q; slot n_tot is
    # the sentinel sink, dropped from the final count. Exact bookkeeping —
    # heap evictions re-probed in a later group cannot double-count.
    seen0 = jnp.zeros((b, n_tot + 1), bool)
    mark = jax.vmap(lambda s, c: s.at[c].set(True))
    if cap:
        live_slots = delta_live_mask(delta, tombstones, n_main)
        dcand = _delta_candidates(keys, delta, live_slots, n_main, n_tot)
        cand0, _ = _dedupe_candidates(dcand, n_tot)
        heap_d, heap_i = ops.gather_rerank_topk_group(
            main_data, cand0, queries, weights, k, delta=delta_data, scales=scales
        )
        seen0 = mark(seen0, cand0)
    else:
        heap_d = jnp.full((b, k), jnp.inf, jnp.float32)
        heap_i = jnp.full((b, k), -1, jnp.int32)

    # geometric bound: with non-negative weights every wl1 distance is
    # >= 0, so a full heap at kth == 0 cannot be beaten (strict-< replace).
    # Any negative weight voids the bound — the rule never fires there.
    w_nonneg = jnp.all(weights >= 0.0, axis=1)

    probe = jax.vmap(
        jax.vmap(_probe_one_table, in_axes=(0, 0, 0, None)),  # group windows
        in_axes=(None, None, 0, None),  # query batch
    )

    def cond(carry):
        g, _hd, _hi, live, _probed, _reason, _seen = carry
        return (g < n_groups) & jnp.any(live)

    def body(carry):
        g, hd, hi, live, probed, reason, seen = carry
        lo = g * G
        tbl_g = jax.lax.dynamic_slice(tbl, (lo,), (G,))
        keys_g = jax.lax.dynamic_slice(kw, (jnp.int32(0), lo), (b, G))
        block = probe(
            state.sorted_keys[tbl_g], state.perm[tbl_g], keys_g, C
        ).reshape(b, G * C)
        if segmented:
            block = _mask_dead(block, tombstones, n_main, n_tot)
        # stopped queries ride an all-sentinel block — frozen result, same
        # shapes, no retrace
        block = jnp.where(live[:, None], block, n_tot)
        heap_ids = jnp.where(hi >= 0, hi, n_tot).astype(jnp.int32)
        cand, _ = _dedupe_candidates(
            jnp.concatenate([heap_ids, block], axis=1), n_tot
        )
        nd, ni = ops.gather_rerank_topk_group(
            main_data, cand, queries, weights, k, delta=delta_data, scales=scales
        )
        hd = jnp.where(live[:, None], nd, hd)
        hi = jnp.where(live[:, None], ni, hi)
        seen = mark(seen, block)
        probed = probed + jnp.where(
            live, jnp.minimum(G, n_windows - lo).astype(jnp.int32), 0
        )

        rk = hd[:, k - 1]
        heap_full = hi[:, k - 1] >= 0
        geo = heap_full & w_nonneg & (rk <= 0.0)
        if exit_slack > 0.0:
            rk_safe = jnp.where(jnp.isfinite(rk), rk, 0.0)
            tables_done = jnp.minimum(probed, L).astype(jnp.float32)
            log_miss = _miss_log_prob(rk_safe, weights, cfg, tables_done)
            conf = heap_full & (log_miss <= math.log(exit_slack))
        else:
            # slack 0 statically disables the confidence rule — an
            # underflowed miss estimate must never read as "certain"
            conf = jnp.zeros_like(geo)
        reason = jnp.where(live & geo, STOP_GEOMETRIC, reason)
        reason = jnp.where(live & conf & ~geo, STOP_CONFIDENCE, reason)
        live = live & ~(geo | conf)
        return g + 1, hd, hi, live, probed, reason, seen

    init = (
        jnp.int32(0),
        heap_d,
        heap_i,
        jnp.ones((b,), bool),
        jnp.zeros((b,), jnp.int32),
        jnp.full((b,), STOP_EXHAUSTED, jnp.int32),
        seen0,
    )
    _g, heap_d, heap_i, _live, probed, reason, seen = jax.lax.while_loop(
        cond, body, init
    )
    return QueryResult(
        dists=heap_d,
        ids=heap_i,
        n_candidates=jnp.sum(seen[:, :n_tot], axis=1).astype(jnp.int32),
        tables_probed=probed,
        stop_reason=reason,
    )
