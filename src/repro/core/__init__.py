"""The paper's primary contribution: ALSH for NNS over d_w^l1.

  transforms     — Obs 1 discretization, unary coding, P / Q_w maps (Eq 19-21)
  families       — hash families as pluggable strategy objects (theta, l2)
  hash_families  — L2-LSH + SimHash with the §4.2.3 O(d) projection trick
  theory         — Eq 4/6/25/27 collision probabilities, rho, (K, L) planning
  index          — Theorem-1 multi-table index (sorted-key CSR, static probes)
  multiprobe     — beyond-paper: probe perturbation sequences (fewer tables)

This package holds the DATA STRUCTURES and probe primitives; query
execution is the :mod:`repro.engine` candidate-stream pipeline and
``repro.api`` is the facade consumers should use. ``build_index`` /
``query_index`` / ``query_multiprobe`` remain as thin shims over the same
engine-backed code paths the facade calls — importable from here for
backward compatibility, but DEPRECATED: calling the package-level names
emits ``DeprecationWarning`` pointing at ``repro.api.Index``. (The
defining modules ``repro.core.index`` / ``repro.core.multiprobe`` stay
warning-free — the facade executes through the same wrappers.)
"""

import functools as _functools
import warnings as _warnings

from repro.core.families import (
    FAMILIES,
    HashFamily,
    L2Family,
    ThetaFamily,
    get_family,
)
from repro.core.transforms import (
    BoundedSpace,
    discretize,
    discretization_slack,
    transform_P,
    transform_Q,
    unary_code,
    wl1_via_mips,
)
from repro.core.hash_families import (
    LSHParams,
    PrefixTables,
    hash_data,
    hash_query,
    make_prefix_tables,
    project_data,
    project_query,
)
from repro.core.theory import (
    IndexPlan,
    collision_prob_l2,
    collision_prob_theta,
    plan_index,
    rho,
    success_probability,
)
from repro.core.index import (
    ALSHIndex,
    DeltaSegment,
    IndexConfig,
    QueryResult,
    delta_insert,
    query_index_segmented,
    tombstone_ids,
)
from repro.core.index import build_index as _build_index
from repro.core.index import query_index as _query_index
from repro.core.multiprobe import query_multiprobe as _query_multiprobe


def _deprecated_shim(fn, name: str, facade: str):
    @_functools.wraps(fn)
    def shim(*args, **kwargs):
        _warnings.warn(
            f"repro.core.{name} is a legacy shim — use {facade} instead "
            f"(one config-carrying Index; same engine, same results)",
            DeprecationWarning,
            stacklevel=2,
        )
        return fn(*args, **kwargs)

    return shim


build_index = _deprecated_shim(
    _build_index, "build_index", "repro.api.Index.build"
)
query_index = _deprecated_shim(
    _query_index, "query_index", "repro.api.Index.query"
)
query_multiprobe = _deprecated_shim(
    _query_multiprobe,
    "query_multiprobe",
    "repro.api.Index.query with QuerySpec(mode='multiprobe')",
)

__all__ = [
    "FAMILIES",
    "HashFamily",
    "L2Family",
    "ThetaFamily",
    "get_family",
    "BoundedSpace",
    "discretize",
    "discretization_slack",
    "transform_P",
    "transform_Q",
    "unary_code",
    "wl1_via_mips",
    "LSHParams",
    "PrefixTables",
    "hash_data",
    "hash_query",
    "make_prefix_tables",
    "project_data",
    "project_query",
    "IndexPlan",
    "collision_prob_l2",
    "collision_prob_theta",
    "plan_index",
    "rho",
    "success_probability",
    "ALSHIndex",
    "DeltaSegment",
    "IndexConfig",
    "QueryResult",
    "build_index",
    "delta_insert",
    "query_index",
    "query_index_segmented",
    "query_multiprobe",
    "tombstone_ids",
]
