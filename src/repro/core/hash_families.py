"""Paper §2.1 + §4.2: LSH families and the O(d) projection trick (§4.2.3).

The ALSH families are

  f(x) = h(P(x))       for data     (no weights available at index time)
  g(x) = h(Q_w(x))     for queries  (weights folded in at query time)

with h either the p-stable L2 hash (Eq 3) or the SimHash sign hash (Eq 5).
Both need the Gaussian projection  a^T P(o)  /  a^T Q_w(q)  over the 2Md-dim
transformed vectors. §4.2.3 shows the projection collapses to a table lookup:

  preprocess a (length 2Md, viewed as (2d, M) rows) into a' (2d, M+1):
     rows 0..d-1   : suffix sums   a'[i, j] = sum_{k>=j} a[i, k],  a'[i, M] = 0
     rows d..2d-1  : prefix sums   a'[i, 0] = 0, a'[i, j] = sum_{k<j} a[i, k]
  then    a^T P(o)   = sum_i ( a'[i, o_i] + a'[d+i, o_i] )
          a^T Q_w(q) = sum_i w_i ( a'[i, q_i] + a'[d+i, q_i] )

(0-indexed here; the paper's Eq 28 is 1-indexed.) Because data and query share
the lookup index, we FOLD the two halves into a single table

  b'[i, m] = a'[i, m] + a'[d+i, m]           # (d, M+1), "folded table"

so hashing is ONE gather + (weighted) sum per coordinate. On TPU the gather is
reformulated as a one-hot MXU contraction (see repro/kernels/alsh_project) —
bit-identical results, dense-matmul speed. This module holds the jnp reference
path; `repro.kernels.ops` provides the Pallas production path.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.families import get_family

__all__ = [
    "LSHParams",
    "PrefixTables",
    "make_prefix_tables",
    "naive_projection_vector",
    "project_data",
    "project_query",
    "l2_hash",
    "sign_hash",
    "hash_data",
    "hash_query",
]


@dataclasses.dataclass(frozen=True)
class LSHParams:
    """Static configuration of one ALSH family instance.

    Attributes:
      d: original dimensionality.
      M: lattice resolution (levels are in {0..M}).
      n_hashes: total hash functions H = K * L.
      family: "l2" (Eq 3, integer codes) or "theta" (Eq 5, sign bits).
      W: bucket width for the l2 family (paper's user constant ``w`` — renamed
         to avoid clashing with the weight vector).
    """

    d: int
    M: int
    n_hashes: int
    family: Literal["l2", "theta"] = "theta"
    W: float = 4.0


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PrefixTables:
    """The preprocessed projection state a' of §4.2.3 (folded form).

    folded: (H, d, M+1) — b'[h, i, m] = suffix_cos[h, i, m] + prefix_sin[h, i, m]
    offsets: (H,) — the uniform offset b ~ U[0, W] for the l2 family (zeros for theta).
    """

    folded: jax.Array
    offsets: jax.Array

    def tree_flatten(self):
        return (self.folded, self.offsets), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n_hashes(self) -> int:
        return self.folded.shape[0]

    @property
    def d(self) -> int:
        return self.folded.shape[1]

    @property
    def M(self) -> int:
        return self.folded.shape[2] - 1


def naive_projection_vector(a_rows: jax.Array) -> jax.Array:
    """Reassemble the flat 2Md Gaussian vector ``a`` from its (2d, M) row view.

    Test-only: used to check the O(d) trick against the naive O(Md) inner
    product with the explicit P/Q vectors. Layout must match transforms:
    P = (cos-block rows 0..d-1 ; sin-block rows d..2d-1), each row M entries.
    """
    return a_rows.reshape(-1)


def _prefix_tables_from_rows(a_rows: jax.Array) -> jax.Array:
    """Eq 28 (0-indexed) for one hash: (2d, M) -> folded (d, M+1)."""
    d2, M = a_rows.shape
    d = d2 // 2
    cos_rows, sin_rows = a_rows[:d], a_rows[d:]
    # suffix sums, with a trailing 0 column:  a'[i, j] = sum_{k >= j} a[i, k]
    zeros = jnp.zeros((d, 1), a_rows.dtype)
    suffix = jnp.concatenate(
        [jnp.cumsum(cos_rows[:, ::-1], axis=1)[:, ::-1], zeros], axis=1
    )
    # prefix sums, with a leading 0 column:   a'[d+i, j] = sum_{k < j} a[d+i, k]
    prefix = jnp.concatenate([zeros, jnp.cumsum(sin_rows, axis=1)], axis=1)
    return suffix + prefix  # folded b' (d, M+1)


def make_prefix_tables(key: jax.Array, params: LSHParams, dtype=jnp.float32) -> PrefixTables:
    """Draw H Gaussian projections and preprocess them per §4.2.3 + folding."""
    k_a, k_b = jax.random.split(key)
    a = jax.random.normal(k_a, (params.n_hashes, 2 * params.d, params.M), dtype=dtype)
    folded = jax.vmap(_prefix_tables_from_rows)(a)
    offsets = get_family(params.family).make_offsets(
        k_b, params.n_hashes, params.W, dtype
    )
    return PrefixTables(folded=folded, offsets=offsets)


def project_data(levels: jax.Array, tables: PrefixTables, impl: str = "auto") -> jax.Array:
    """a^T P(o) for a batch of data points — §4.2.3, 2d-1 additions per hash.

    Args:
      levels: (n, d) int32 lattice points in {0..M}.
      tables: PrefixTables with folded (H, d, M+1).
      impl: "gather" | "onehot" | "auto" (auto → kernels.ops dispatch).

    Returns:
      (n, H) float projections.
    """
    if impl == "auto":
        from repro.kernels import ops  # local import: kernels depend on core types

        return ops.alsh_project(levels, tables.folded, weights=None)
    if impl == "onehot":
        return _project_onehot(levels, tables.folded, None)
    return _project_gather(levels, tables.folded, None)


def project_query(
    levels: jax.Array, w: jax.Array, tables: PrefixTables, impl: str = "auto"
) -> jax.Array:
    """a^T Q_w(q): the asymmetric (weighted) projection — 2d-1 adds + d muls."""
    if impl == "auto":
        from repro.kernels import ops

        return ops.alsh_project(levels, tables.folded, weights=w)
    if impl == "onehot":
        return _project_onehot(levels, tables.folded, w)
    return _project_gather(levels, tables.folded, w)


def _project_gather(levels, folded, weights):
    """Reference: per-coordinate gather + reduce. levels (n, d); folded (H, d, M+1)."""
    # picked[n, h, i] = folded[h, i, levels[n, i]]
    picked = jnp.take_along_axis(
        folded[None],  # (1, H, d, M+1)
        levels[:, None, :, None].astype(jnp.int32),  # (n, 1, d, 1)
        axis=3,
    )[..., 0]  # (n, H, d)
    if weights is not None:
        picked = picked * weights[:, None, :]
    return jnp.sum(picked, axis=-1)  # (n, H)


def _project_onehot(levels, folded, weights):
    """TPU-native: one-hot contraction — same math on the MXU."""
    M1 = folded.shape[-1]
    onehot = jax.nn.one_hot(levels, M1, dtype=folded.dtype)  # (n, d, M+1)
    if weights is not None:
        onehot = onehot * weights[..., None]
    # (n, d*(M+1)) @ (d*(M+1), H)
    n = levels.shape[0]
    lhs = onehot.reshape(n, -1)
    rhs = jnp.transpose(folded, (1, 2, 0)).reshape(-1, folded.shape[0])
    return lhs @ rhs


def l2_hash(projections: jax.Array, tables: PrefixTables, W: float) -> jax.Array:
    """Eq 3: h(x) = floor((a^T x + b) / W) — integer bucket codes."""
    return get_family("l2").codes_from_projections(projections, tables.offsets, W)


def sign_hash(projections: jax.Array) -> jax.Array:
    """Eq 5: h(x) = 1[a^T x >= 0] — SimHash bits."""
    return get_family("theta").codes_from_projections(projections, None, 0.0)


def hash_data(
    levels: jax.Array, tables: PrefixTables, params: LSHParams, impl: str = "auto"
) -> jax.Array:
    """f(o) = h(P(o)) for a batch: (n, d) -> (n, H) int codes."""
    proj = project_data(levels, tables, impl=impl)
    return get_family(params.family).codes_from_projections(
        proj, tables.offsets, params.W
    )


def hash_query(
    levels: jax.Array,
    w: jax.Array,
    tables: PrefixTables,
    params: LSHParams,
    impl: str = "auto",
) -> jax.Array:
    """g(q) = h(Q_w(q)) for a batch: (b, d) + (b, d) weights -> (b, H) int codes."""
    proj = project_query(levels, w, tables, impl=impl)
    return get_family(params.family).codes_from_projections(
        proj, tables.offsets, params.W
    )
