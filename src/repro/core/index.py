"""The ALSH index: Theorem-1 construction as a TPU/XLA-native data structure.

Classical LSH indexes are pointer-chasing hash maps. On TPU we need static
shapes and sort-friendly primitives, so each of the L tables is stored as a
*sorted key column*:

  build:  codes (n, K) --combine--> keys (n,)  --argsort--> (sorted_keys, perm)
  query:  key --searchsorted--> [start, end)   --bounded gather--> candidate ids

Combining K codes into one int32 key:
  - theta family (bits): exact bit-packing for K <= 31 — zero spurious collisions.
  - l2 family (unbounded ints): random odd-multiplier mixing (universal-style);
    spurious collisions only ADD candidates — the exact d_w^l1 re-rank keeps
    correctness, the candidate budget keeps cost bounded.

This module owns the DATA STRUCTURES (build, insert, tombstone, compact
inputs) and the probe PRIMITIVES (sorted-window lookup, delta key match,
dedupe, tombstone mask). Query execution — composing those primitives into
the probe → merge → dedupe → mask → fused-rerank pipeline — lives in
:mod:`repro.engine`; the ``query_*`` names kept here are thin wrappers over
it (one pipeline serves probe, multiprobe, segmented, and sharded queries).

Memory model of a query batch (b queries, P = L·C probed slots):
  HBM traffic  = probe windows (b·P int32) + one gather of the unique
                 candidate rows + the (b, k) result;
  peak live    = O(b·P) ids + O(b·k) top-k — the (b, P, d) candidate tensor
                 of the old 3-step tail is never materialized anywhere.
All static-shape, jit/vmap/shard_map-compatible.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import hash_families as hf
from repro.core import transforms
from repro.core.families import HashFamily, get_family
from repro.core.theory import IndexPlan
from repro.quant import STORAGE_KINDS, get_codec


@dataclasses.dataclass(frozen=True)
class IndexConfig:
    """Static geometry of an ALSH index.

    ``family`` names a registered :mod:`repro.core.families` strategy (a
    ``HashFamily`` instance is also accepted and normalized to its name, so
    the config stays hashable/serializable). ``storage`` names a
    :mod:`repro.quant` row codec — how sealed/delta table rows are stored
    on device ("f32" default, "bf16", "int8"); hashing always sees the raw
    rows, so candidate generation is codec-invariant. Construction validates
    the geometry and raises ``ValueError`` naming the offending field — bad
    configs never reach trace time.
    """

    d: int
    M: int
    K: int  # hashes per table
    L: int  # tables
    family: str = "theta"  # "theta" | "l2"
    W: float = 4.0
    max_candidates: int = 64  # per-table probe budget C
    space: transforms.BoundedSpace = transforms.BoundedSpace(0.0, 1.0, 32.0)
    storage: str = "f32"  # repro.quant row codec for table segments

    def __post_init__(self):
        if isinstance(self.family, HashFamily):
            object.__setattr__(self, "family", self.family.name)
        for field in ("d", "M", "K", "L", "max_candidates"):
            v = getattr(self, field)
            if not isinstance(v, int) or v <= 0:
                raise ValueError(
                    f"IndexConfig.{field} must be a positive int, got {v!r}"
                )
        if self.storage not in STORAGE_KINDS:
            raise ValueError(
                f"IndexConfig.storage must be one of {STORAGE_KINDS}, got "
                f"{self.storage!r}"
            )
        if self.space.M > self.M:
            raise ValueError(
                f"IndexConfig.space discretizes to {self.space.M} levels but "
                f"IndexConfig.M={self.M} — lattice points would index past the "
                f"hash tables; use space=BoundedSpace(lo, hi, t) with "
                f"(hi-lo)*t <= M"
            )
        # family-specific constraints (raises on unknown family names too)
        get_family(self.family).validate(self)

    @property
    def family_obj(self) -> HashFamily:
        """The family strategy object this config names."""
        return get_family(self.family)

    @property
    def n_hashes(self) -> int:
        return self.K * self.L

    @property
    def lsh_params(self) -> hf.LSHParams:
        return hf.LSHParams(
            d=self.d, M=self.M, n_hashes=self.K * self.L, family=self.family, W=self.W
        )

    @classmethod
    def from_plan(cls, d: int, M: int, plan: IndexPlan, **kw) -> "IndexConfig":
        return cls(d=d, M=M, K=plan.K, L=plan.L, **kw)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ALSHIndex:
    """Built index state (a pytree — crosses jit/shard_map boundaries)."""

    tables: hf.PrefixTables  # folded projection tables (H, d, M+1)
    mixers: jax.Array  # (L, K) int32 key combiners
    sorted_keys: jax.Array  # (L, n) int32 — per-table sorted bucket keys
    perm: jax.Array  # (L, n + C) int32 — point ids by key order, padded with n
    data: jax.Array  # (n, d) ENCODED rows, cfg.storage dtype (f32 default)
    levels: jax.Array  # (n, d) int32 — lattice points (hash oracle/debug)
    scales: jax.Array | None = None  # (d,) f32 decode scales (int8 storage only)

    def tree_flatten(self):
        return (
            (
                self.tables,
                self.mixers,
                self.sorted_keys,
                self.perm,
                self.data,
                self.levels,
                self.scales,
            ),
            None,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n(self) -> int:
        return self.data.shape[0]


class QueryResult(NamedTuple):
    """Batched k-NN result.

    Invalid-slot contract (all query paths, all backends): a slot is invalid
    iff ``ids == -1`` iff ``dists == +inf``. ``-1`` is the ONLY user-facing
    invalid sentinel — the internal candidate sentinels (``n``, ``n + C``)
    used by the probe/dedupe stages never escape a QueryResult.

    ``tables_probed``/``stop_reason`` are populated only by the streamed
    early-exit tail (None on the monolithic paths, keeping their pytree
    structure unchanged). Stop-reason codes: 0 = exhausted every group,
    1 = geometric stop (running kth distance provably unbeatable),
    2 = confidence stop (Eq 25/27 miss estimate under the slack budget).
    """

    dists: jax.Array  # (b, k) ascending d_w^l1 (+inf where fewer than k found)
    ids: jax.Array  # (b, k) point ids (-1 where invalid)
    n_candidates: jax.Array  # (b,) unique candidates examined — sublinearity metric
    tables_probed: jax.Array | None = None  # (b,) probe windows visited (streamed tail)
    stop_reason: jax.Array | None = None  # (b,) int32 stop code (streamed tail)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeltaSegment:
    """Fixed-capacity unsealed segment: rows inserted after the main build.

    Rows are hashed at insert time with the SAME tables/mixers as the main
    segment (re-derived from the persisted build key on load/shard), so a
    query's per-table keys are valid against both segments. Unlike the main
    segment the delta is never sorted — it is probed by a dense key match
    over at most ``capacity`` slots, which keeps ``insert`` an O(H·d·m)
    hash + scatter with NO re-sort, and keeps every shape static so
    insert/delete/query jit without retracing as the fill level moves.

    Slots are append-only: deletes tombstone, they never free a slot — only
    ``compact()`` reclaims space (and is the only place a sort happens).

    ``fill`` is a device scalar (shape ``()``, or ``(1,)`` for the per-shard
    view inside ``shard_map``) so the fill level is data, not Python state.
    """

    data: jax.Array  # (cap, d) inserted rows (zeros past fill)
    levels: jax.Array  # (cap, d) int32 lattice points of inserted rows
    keys: jax.Array  # (L, cap) int32 per-table bucket keys of inserted rows
    fill: jax.Array  # () int32 — slots used (append-only)

    def tree_flatten(self):
        return (self.data, self.levels, self.keys, self.fill), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def capacity(self) -> int:
        return self.data.shape[0]

    @classmethod
    def empty(cls, cfg: "IndexConfig", capacity: int, dtype=jnp.float32) -> "DeltaSegment":
        return cls(
            data=jnp.zeros((capacity, cfg.d), dtype),
            levels=jnp.zeros((capacity, cfg.d), jnp.int32),
            keys=jnp.zeros((cfg.L, capacity), jnp.int32),
            fill=jnp.zeros((), jnp.int32),
        )


def hash_rows(
    index: ALSHIndex, rows: jax.Array, cfg: IndexConfig, impl: str = "auto"
) -> tuple[jax.Array, jax.Array]:
    """Hash new data rows with the index's own tables: (m, d) ->
    ((L, m) int32 keys, (m, d) int32 levels). This is what makes delta rows
    query-compatible with the sealed main segment."""
    levels = transforms.discretize(rows, cfg.space)
    keys = _keys_for(levels, None, index.tables, cfg, index.mixers, impl=impl).T
    return keys, levels


@partial(jax.jit, static_argnames=("cfg", "impl"))
def delta_insert(
    index: ALSHIndex,
    delta: DeltaSegment,
    rows: jax.Array,
    cfg: IndexConfig,
    impl: str = "auto",
) -> tuple[DeltaSegment, jax.Array]:
    """Append rows to the delta segment (functional).

    rows: (m, d). Returns (new delta, (m,) assigned ids) where ids are
    ``n_main + slot`` and ``-1`` for rows that did not fit (delta full —
    compact() and retry). Static-shape: jit-stable across fill levels.
    """
    m = rows.shape[0]
    cap = delta.capacity
    keys, levels = hash_rows(index, rows, cfg, impl=impl)  # (L, m) (raw rows!)
    # storage-encode AFTER hashing, under the SEALED segment's scales, so a
    # delta row decodes identically to a main row (one scale stream covers
    # both segments in the fused gather)
    enc = get_codec(cfg.storage).encode_rows(rows, index.scales)
    slots = delta.fill + jnp.arange(m, dtype=jnp.int32)  # (m,)
    ok = slots < cap
    tgt = jnp.where(ok, slots, cap)  # out-of-capacity -> dropped by scatter
    new = DeltaSegment(
        data=delta.data.at[tgt].set(enc.astype(delta.data.dtype), mode="drop"),
        levels=delta.levels.at[tgt].set(levels, mode="drop"),
        keys=delta.keys.at[:, tgt].set(keys, mode="drop"),
        fill=jnp.minimum(jnp.asarray(cap, jnp.int32), delta.fill + m),
    )
    ids = jnp.where(ok, index.n + slots, -1).astype(jnp.int32)
    return new, ids


@partial(jax.jit, static_argnames=("n_main",))
def tombstone_ids(
    tombstones: jax.Array, ids: jax.Array, n_main: int, fill: jax.Array
) -> jax.Array:
    """Set tombstone bits for ``ids`` (functional).

    Ids that name no row — negative, past the delta capacity, or in the
    UNFILLED delta range ``[n_main + fill, n_main + cap)`` — are ignored:
    tombstoning an unassigned slot would silently kill the row a future
    insert places there."""
    n_tot = tombstones.shape[0]
    ids = jnp.asarray(ids, jnp.int32).reshape(-1)
    assigned = (ids >= 0) & (ids < n_main + fill) & (ids < n_tot)
    idx = jnp.where(assigned, ids, n_tot)
    return tombstones.at[idx].set(True, mode="drop")


# Delta-slot block size of the chunked key match: the per-step working set
# is (b, L, P, block) bools, whatever the configured delta capacity — large
# capacities (16k+) query under the same memory envelope as small ones.
DELTA_MATCH_BLOCK = 1024


def _delta_candidates(
    probe_keys: jax.Array,
    delta: DeltaSegment,
    live: jax.Array,
    n_main: int,
    sentinel: int,
    block: int = DELTA_MATCH_BLOCK,
) -> jax.Array:
    """Delta probe: which delta slots collide with the query's keys.

    probe_keys: (b, L) single-probe keys or (b, L, P) multiprobe keys.
    live: (cap,) bool — slot filled and not tombstoned.
    Returns (b, cap) candidate ids (``n_main + slot``), ``sentinel`` where
    the slot doesn't collide or isn't live. A slot is a candidate iff its
    key matches one of the probe keys IN THE SAME TABLE — exactly the
    predicate the sorted-window probe applies to the main segment.

    The match runs as a ``fori_loop`` over ``block``-slot chunks of the
    capacity, so the (b, L, P, cap) comparison tensor of the naive
    formulation is never materialized — only (b, L, P, block) per step.
    Bit-identical to the dense match (same compares, same slot order).
    """
    cap = delta.capacity
    b = probe_keys.shape[0]
    if cap == 0:
        return jnp.zeros((b, 0), jnp.int32)
    pk = probe_keys if probe_keys.ndim == 3 else probe_keys[:, :, None]  # (b, L, P)
    L = delta.keys.shape[0]
    block = min(block, cap)
    n_blocks = -(-cap // block)
    pad = n_blocks * block - cap
    keys_p = jnp.pad(delta.keys, ((0, 0), (0, pad)))
    live_p = jnp.pad(live, (0, pad))  # padding slots are never live

    def body(c, out):
        kblk = jax.lax.dynamic_slice(keys_p, (0, c * block), (L, block))  # (L, block)
        lblk = jax.lax.dynamic_slice(live_p, (c * block,), (block,))
        match = jnp.any(
            pk[:, :, :, None] == kblk[None, :, None, :], axis=(1, 2)
        )  # (b, block)
        ids_blk = n_main + c * block + jnp.arange(block, dtype=jnp.int32)
        cand = jnp.where(match & lblk[None, :], ids_blk[None, :], sentinel).astype(
            jnp.int32
        )
        return jax.lax.dynamic_update_slice(out, cand, (0, c * block))

    out = jnp.full((b, n_blocks * block), sentinel, jnp.int32)
    return jax.lax.fori_loop(0, n_blocks, body, out)[:, :cap]


def _mask_dead(cand: jax.Array, tombstones: jax.Array, n_main: int, sentinel: int) -> jax.Array:
    """Zap probe-window padding (ids >= n_main) and tombstoned main ids to
    ``sentinel`` BEFORE re-rank, so deleted rows can never reach a result."""
    n_tot = tombstones.shape[0]
    dead = tombstones[jnp.minimum(cand, n_tot - 1)]
    return jnp.where((cand < n_main) & ~dead, cand, sentinel)


def delta_live_mask(delta: DeltaSegment, tombstones: jax.Array, n_main: int) -> jax.Array:
    """(cap,) bool: slot filled and not tombstoned."""
    cap = delta.capacity
    return (jnp.arange(cap, dtype=jnp.int32) < delta.fill) & ~tombstones[n_main:]


def _combine_codes(codes_lk: jax.Array, mixers: jax.Array, family: str, K: int) -> jax.Array:
    """(..., L, K) int codes -> (..., L) int32 keys (family strategy dispatch)."""
    return get_family(family).combine_codes(codes_lk, mixers, K)


def _keys_for(
    levels: jax.Array,
    weights: jax.Array | None,
    index_tables: hf.PrefixTables,
    cfg: IndexConfig,
    mixers: jax.Array,
    impl: str = "auto",
) -> jax.Array:
    """Hash points/queries to per-table keys: (b, d)[, (b, d)w] -> (b, L)."""
    params = cfg.lsh_params
    if weights is None:
        codes = hf.hash_data(levels, index_tables, params, impl=impl)  # (b, H)
    else:
        codes = hf.hash_query(levels, weights, index_tables, params, impl=impl)
    codes = codes.reshape(*codes.shape[:-1], cfg.L, cfg.K)
    return _combine_codes(codes, mixers, cfg.family, cfg.K)


def build_index(
    key: jax.Array,
    data: jax.Array,
    cfg: IndexConfig,
    impl: str = "auto",
) -> ALSHIndex:
    """Preprocess the database: hash every point, sort each table by key.

    O(H d n) hashing (the §4.2.3 trick) + L sorts of n keys. Hashing and
    discretization always see the RAW rows; the table payload is
    storage-encoded (``cfg.storage`` codec) as the LAST step, so candidate
    generation is identical across codecs and only the rerank tail observes
    the compression. ``f32`` encoding is the identity (same array object).
    """
    k_tab, k_mix = jax.random.split(key)
    tables = hf.make_prefix_tables(k_tab, cfg.lsh_params, dtype=data.dtype)
    mixers = (
        jax.random.randint(k_mix, (cfg.L, cfg.K), 1, jnp.iinfo(jnp.int32).max, dtype=jnp.int32)
        | 1
    )  # odd multipliers
    levels = transforms.discretize(data, cfg.space)
    keys_ln = _keys_for(levels, None, tables, cfg, mixers, impl=impl).T  # (L, n)
    perm = jnp.argsort(keys_ln, axis=1).astype(jnp.int32)  # (L, n)
    sorted_keys = jnp.take_along_axis(keys_ln, perm, axis=1)
    n = data.shape[0]
    pad = jnp.full((cfg.L, cfg.max_candidates), n, dtype=jnp.int32)
    perm = jnp.concatenate([perm, pad], axis=1)  # (L, n + C) — safe window gather
    payload, scales = get_codec(cfg.storage).encode(data)
    return ALSHIndex(
        tables=tables,
        mixers=mixers,
        sorted_keys=sorted_keys,
        perm=perm,
        data=payload,
        levels=levels,
        scales=scales,
    )


def table_window_sizes(sorted_keys: jax.Array, keys: jax.Array) -> jax.Array:
    """How many rows share each probed bucket — the probe window BEFORE the
    ``max_candidates`` clamp.

    sorted_keys: (L, n) per-table sorted bucket keys.
    keys: (b, L) single-probe keys or (b, L, P) multiprobe keys.
    Returns window sizes of the same (b, L[, P]) shape. Windows larger than
    the configured ``max_candidates`` are TRUNCATED by the probe — this is
    the signal ``Index.explain`` surfaces so a recall miss can be told apart
    from an unlucky hash draw."""
    k3 = keys if keys.ndim == 3 else keys[..., None]  # (b, L, P)

    def one_table(sk_row, key_row):  # (n,), (b, P) -> (b, P)
        s = jnp.searchsorted(sk_row, key_row, side="left")
        e = jnp.searchsorted(sk_row, key_row, side="right")
        return (e - s).astype(jnp.int32)

    out = jax.vmap(one_table, in_axes=(0, 1), out_axes=1)(sorted_keys, k3)
    return out if keys.ndim == 3 else out[..., 0]


def query_keys_for(
    index: ALSHIndex, queries: jax.Array, weights: jax.Array, cfg: IndexConfig
) -> jax.Array:
    """(b, L) single-probe bucket keys of a query batch (diagnostic entry
    point for the planner and ``Index.explain``; the query path computes
    the same keys inside ``repro.engine.probe_keys``)."""
    qlevels = transforms.discretize(queries, cfg.space)
    return _keys_for(qlevels, weights, index.tables, cfg, index.mixers)


def _probe_one_table(sorted_keys_row, perm_row, qkey, C: int):
    """One table probe: sorted lookup + bounded candidate window."""
    start = jnp.searchsorted(sorted_keys_row, qkey, side="left")
    end = jnp.searchsorted(sorted_keys_row, qkey, side="right")
    pos = start + jnp.arange(C, dtype=jnp.int32)
    ids = perm_row[pos]  # perm_row padded with n → in-bounds
    valid = pos < end
    return jnp.where(valid, ids, perm_row.shape[0])  # invalid → large sentinel


def _dedupe_candidates(cand: jax.Array, n: int) -> tuple[jax.Array, jax.Array]:
    """Sort candidate ids, zap duplicates/invalids to the sentinel ``n``, and
    compact the unique ids to the front of each row.

    cand: (b, P) int32 ids, entries >= n are invalid (window padding).
    Returns ((b, P) ascending unique ids, sentinels ``n`` packed last,
    (b,) unique-candidate counts). The compaction is what lets the fused
    tail's chunk loop skip all-sentinel chunks — tail cost scales with the
    number of UNIQUE candidates, not the L·C probe-slot budget.
    """
    cand = jnp.sort(jnp.minimum(cand, n), axis=1)
    first = jnp.concatenate(
        [jnp.ones((cand.shape[0], 1), bool), cand[:, 1:] != cand[:, :-1]], axis=1
    )
    valid = (cand < n) & first
    return jnp.sort(jnp.where(valid, cand, n), axis=1), jnp.sum(valid, axis=1)


# ---------------------------------------------------------------------------
# Query entry points — thin wrappers over the shared execution engine
# (repro.engine: one probe → merge → dedupe → mask → fused-rerank pipeline
# for every mode/segment/shard combination). Imported lazily: the engine
# composes the primitives defined above, so it depends on this module.
# ---------------------------------------------------------------------------


def query_index(
    index: ALSHIndex,
    queries: jax.Array,
    weights: jax.Array,
    cfg: IndexConfig,
    k: int = 1,
    impl: str = "auto",
) -> QueryResult:
    """Batched ALSH query: probe L tables → dedupe → fused rerank/top-k.

    Args:
      queries: (b, d) float query points.
      weights: (b, d) float per-query weight vectors (the paper's w — may be negative).
      k: neighbours to return.
    """
    from repro.engine import query

    return query(index, None, None, queries, weights, cfg, k=k, impl=impl)


def query_index_segmented(
    index: ALSHIndex,
    delta: DeltaSegment,
    tombstones: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    cfg: IndexConfig,
    k: int = 1,
    impl: str = "auto",
) -> QueryResult:
    """Two-segment ALSH query: sorted-window probe of the sealed main tables
    + key-match probe of the delta segment, tombstoned ids masked to the
    internal sentinel BEFORE dedupe/re-rank (a deleted row can never appear
    in a result), then one fused rerank/top-k tail gathering from both
    segment tables. Returned ids are global: main rows keep their build ids
    ``[0, n_main)``; delta slot ``s`` is ``n_main + s``.

    Static-shape in everything but the fill level and tombstone bits, so
    repeated insert→query→delete cycles at fixed capacity reuse one
    compiled program.
    """
    from repro.engine import query

    return query(index, delta, tombstones, queries, weights, cfg, k=k, impl=impl)


def query_exact_segmented(
    index: ALSHIndex,
    delta: DeltaSegment,
    tombstones: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    k: int = 1,
) -> QueryResult:
    """Exact oracle over the LIVE rows of both segments: every filled,
    non-tombstoned row is a candidate of the fused rerank tail. Reports the
    live-row count as ``n_candidates`` (what the scan actually examined)."""
    from repro.engine import query

    return query(index, delta, tombstones, queries, weights, None, k=k, mode="exact")
