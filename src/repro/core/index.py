"""The ALSH index: Theorem-1 construction as a TPU/XLA-native data structure.

Classical LSH indexes are pointer-chasing hash maps. On TPU we need static
shapes and sort-friendly primitives, so each of the L tables is stored as a
*sorted key column*:

  build:  codes (n, K) --combine--> keys (n,)  --argsort--> (sorted_keys, perm)
  query:  key --searchsorted--> [start, end)   --bounded gather--> candidate ids

Combining K codes into one int32 key:
  - theta family (bits): exact bit-packing for K <= 31 — zero spurious collisions.
  - l2 family (unbounded ints): random odd-multiplier mixing (universal-style);
    spurious collisions only ADD candidates — the exact d_w^l1 re-rank keeps
    correctness, the candidate budget keeps cost bounded.

The probe path retrieves at most ``max_candidates`` per table (static C),
dedupes across tables by sort, then hands the candidate *ids* to the fused
``gather_rerank_topk`` kernel, which gathers each needed row straight from
the (n, d) table (scalar-prefetch DMA on TPU, chunked streaming on CPU),
re-ranks exactly with d_w^l1, and maintains the running top-k on-chip.

Memory model of a query batch (b queries, P = L·C probed slots):
  HBM traffic  = probe windows (b·P int32) + one gather of the unique
                 candidate rows + the (b, k) result;
  peak live    = O(b·P) ids + O(b·k) top-k — the (b, P, d) candidate tensor
                 of the old 3-step tail is never materialized anywhere.
All static-shape, jit/vmap/shard_map-compatible.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import hash_families as hf
from repro.core import transforms
from repro.core.families import HashFamily, get_family
from repro.core.theory import IndexPlan


@dataclasses.dataclass(frozen=True)
class IndexConfig:
    """Static geometry of an ALSH index.

    ``family`` names a registered :mod:`repro.core.families` strategy (a
    ``HashFamily`` instance is also accepted and normalized to its name, so
    the config stays hashable/serializable). Construction validates the
    geometry and raises ``ValueError`` naming the offending field — bad
    configs never reach trace time.
    """

    d: int
    M: int
    K: int  # hashes per table
    L: int  # tables
    family: str = "theta"  # "theta" | "l2"
    W: float = 4.0
    max_candidates: int = 64  # per-table probe budget C
    space: transforms.BoundedSpace = transforms.BoundedSpace(0.0, 1.0, 32.0)

    def __post_init__(self):
        if isinstance(self.family, HashFamily):
            object.__setattr__(self, "family", self.family.name)
        for field in ("d", "M", "K", "L", "max_candidates"):
            v = getattr(self, field)
            if not isinstance(v, int) or v <= 0:
                raise ValueError(
                    f"IndexConfig.{field} must be a positive int, got {v!r}"
                )
        if self.space.M > self.M:
            raise ValueError(
                f"IndexConfig.space discretizes to {self.space.M} levels but "
                f"IndexConfig.M={self.M} — lattice points would index past the "
                f"hash tables; use space=BoundedSpace(lo, hi, t) with "
                f"(hi-lo)*t <= M"
            )
        # family-specific constraints (raises on unknown family names too)
        get_family(self.family).validate(self)

    @property
    def family_obj(self) -> HashFamily:
        """The family strategy object this config names."""
        return get_family(self.family)

    @property
    def n_hashes(self) -> int:
        return self.K * self.L

    @property
    def lsh_params(self) -> hf.LSHParams:
        return hf.LSHParams(
            d=self.d, M=self.M, n_hashes=self.K * self.L, family=self.family, W=self.W
        )

    @classmethod
    def from_plan(cls, d: int, M: int, plan: IndexPlan, **kw) -> "IndexConfig":
        return cls(d=d, M=M, K=plan.K, L=plan.L, **kw)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ALSHIndex:
    """Built index state (a pytree — crosses jit/shard_map boundaries)."""

    tables: hf.PrefixTables  # folded projection tables (H, d, M+1)
    mixers: jax.Array  # (L, K) int32 key combiners
    sorted_keys: jax.Array  # (L, n) int32 — per-table sorted bucket keys
    perm: jax.Array  # (L, n + C) int32 — point ids by key order, padded with n
    data: jax.Array  # (n, d) float — original points (exact re-rank)
    levels: jax.Array  # (n, d) int32 — lattice points (hash oracle/debug)

    def tree_flatten(self):
        return (
            (self.tables, self.mixers, self.sorted_keys, self.perm, self.data, self.levels),
            None,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n(self) -> int:
        return self.data.shape[0]


class QueryResult(NamedTuple):
    dists: jax.Array  # (b, k) ascending d_w^l1 (inf where fewer than k found)
    ids: jax.Array  # (b, k) point ids (-1 where invalid)
    n_candidates: jax.Array  # (b,) unique candidates examined — sublinearity metric


def _combine_codes(codes_lk: jax.Array, mixers: jax.Array, family: str, K: int) -> jax.Array:
    """(..., L, K) int codes -> (..., L) int32 keys (family strategy dispatch)."""
    return get_family(family).combine_codes(codes_lk, mixers, K)


def _keys_for(
    levels: jax.Array,
    weights: jax.Array | None,
    index_tables: hf.PrefixTables,
    cfg: IndexConfig,
    mixers: jax.Array,
    impl: str = "auto",
) -> jax.Array:
    """Hash points/queries to per-table keys: (b, d)[, (b, d)w] -> (b, L)."""
    params = cfg.lsh_params
    if weights is None:
        codes = hf.hash_data(levels, index_tables, params, impl=impl)  # (b, H)
    else:
        codes = hf.hash_query(levels, weights, index_tables, params, impl=impl)
    codes = codes.reshape(*codes.shape[:-1], cfg.L, cfg.K)
    return _combine_codes(codes, mixers, cfg.family, cfg.K)


def build_index(
    key: jax.Array,
    data: jax.Array,
    cfg: IndexConfig,
    impl: str = "auto",
) -> ALSHIndex:
    """Preprocess the database: hash every point, sort each table by key.

    O(H d n) hashing (the §4.2.3 trick) + L sorts of n keys.
    """
    k_tab, k_mix = jax.random.split(key)
    tables = hf.make_prefix_tables(k_tab, cfg.lsh_params, dtype=data.dtype)
    mixers = (
        jax.random.randint(k_mix, (cfg.L, cfg.K), 1, jnp.iinfo(jnp.int32).max, dtype=jnp.int32)
        | 1
    )  # odd multipliers
    levels = transforms.discretize(data, cfg.space)
    keys_ln = _keys_for(levels, None, tables, cfg, mixers, impl=impl).T  # (L, n)
    perm = jnp.argsort(keys_ln, axis=1).astype(jnp.int32)  # (L, n)
    sorted_keys = jnp.take_along_axis(keys_ln, perm, axis=1)
    n = data.shape[0]
    pad = jnp.full((cfg.L, cfg.max_candidates), n, dtype=jnp.int32)
    perm = jnp.concatenate([perm, pad], axis=1)  # (L, n + C) — safe window gather
    return ALSHIndex(
        tables=tables,
        mixers=mixers,
        sorted_keys=sorted_keys,
        perm=perm,
        data=data,
        levels=levels,
    )


def _probe_one_table(sorted_keys_row, perm_row, qkey, C: int):
    """One table probe: sorted lookup + bounded candidate window."""
    start = jnp.searchsorted(sorted_keys_row, qkey, side="left")
    end = jnp.searchsorted(sorted_keys_row, qkey, side="right")
    pos = start + jnp.arange(C, dtype=jnp.int32)
    ids = perm_row[pos]  # perm_row padded with n → in-bounds
    valid = pos < end
    return jnp.where(valid, ids, perm_row.shape[0])  # invalid → large sentinel


def _dedupe_candidates(cand: jax.Array, n: int) -> tuple[jax.Array, jax.Array]:
    """Sort candidate ids, zap duplicates/invalids to the sentinel ``n``, and
    compact the unique ids to the front of each row.

    cand: (b, P) int32 ids, entries >= n are invalid (window padding).
    Returns ((b, P) ascending unique ids, sentinels ``n`` packed last,
    (b,) unique-candidate counts). The compaction is what lets the fused
    tail's chunk loop skip all-sentinel chunks — tail cost scales with the
    number of UNIQUE candidates, not the L·C probe-slot budget.
    """
    cand = jnp.sort(jnp.minimum(cand, n), axis=1)
    first = jnp.concatenate(
        [jnp.ones((cand.shape[0], 1), bool), cand[:, 1:] != cand[:, :-1]], axis=1
    )
    valid = (cand < n) & first
    return jnp.sort(jnp.where(valid, cand, n), axis=1), jnp.sum(valid, axis=1)


def fused_rerank_topk(
    index: ALSHIndex,
    cand: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    k: int,
) -> QueryResult:
    """Shared probe tail: dedupe → fused gather/re-rank/top-k (no (b, P, d)
    candidate tensor). ``cand`` is (b, P) raw probe ids (>= n ⇒ padding)."""
    from repro.kernels import ops

    cand, n_candidates = _dedupe_candidates(cand, index.n)
    dists, ids = ops.gather_rerank_topk(index.data, cand, queries, weights, k)
    return QueryResult(dists=dists, ids=ids, n_candidates=n_candidates)


@partial(jax.jit, static_argnames=("cfg", "k", "impl"))
def query_index(
    index: ALSHIndex,
    queries: jax.Array,
    weights: jax.Array,
    cfg: IndexConfig,
    k: int = 1,
    impl: str = "auto",
) -> QueryResult:
    """Batched ALSH query: probe L tables → dedupe → fused rerank/top-k.

    Args:
      queries: (b, d) float query points.
      weights: (b, d) float per-query weight vectors (the paper's w — may be negative).
      k: neighbours to return.
    """
    b, d = queries.shape
    C = cfg.max_candidates
    qlevels = transforms.discretize(queries, cfg.space)
    qkeys = _keys_for(qlevels, weights, index.tables, cfg, index.mixers, impl=impl)  # (b, L)

    # probe all (table, query) pairs — vmap over tables, then queries
    probe = jax.vmap(
        jax.vmap(_probe_one_table, in_axes=(0, 0, 0, None)), in_axes=(None, None, 0, None)
    )
    cand = probe(index.sorted_keys, index.perm, qkeys, C)  # (b, L, C), sentinel = n+C pad id
    return fused_rerank_topk(index, cand.reshape(b, cfg.L * C), queries, weights, k)
