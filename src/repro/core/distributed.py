"""Distributed ALSH service: row-sharded index, replicated queries,
hierarchical top-k merge — the paper's workload at cluster scale.

Sharding contract (mesh axes ("pod","data","model")):

  * database rows: disjointly partitioned over ALL devices — each device
    builds a complete local index over its n_local rows (hash tables are
    valid per-shard because the (R1,R2)-NNS guarantee is closed under
    disjoint union: the global NN lives in exactly one shard).
  * queries: replicated (or batch-sharded for throughput serving).
  * merge: local exact top-k per shard, then a hierarchical merge — sorted
    concat + re-top-k along "model", then "data", then "pod". Two-hop
    merging moves k·devices_per_hop entries per link instead of k·devices,
    cutting cross-pod DCN bytes by the pod fan-in (see EXPERIMENTS §Perf).

Implemented with shard_map over the mesh; every collective is explicit
(jax.lax.all_gather over one named axis at a time).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import hash_families as hf
from repro.core import transforms
from repro.core.index import ALSHIndex, IndexConfig, build_index, query_index


class ShardedQueryResult(NamedTuple):
    dists: jax.Array  # (b, k) global ascending
    ids: jax.Array  # (b, k) global ids (shard_offset + local id)
    n_candidates: jax.Array  # (b,) summed over shards


def build_local_indexes(key, data_global: jax.Array, cfg: IndexConfig, mesh: Mesh):
    """data_global (n, d) row-sharded over all mesh axes -> per-shard ALSHIndex.

    All shards share the SAME hash tables (key is broadcast) so query hashing
    is computed once and is valid against every shard's tables.
    """
    n = data_global.shape[0]
    axes = tuple(mesh.axis_names)
    data_sharded = jax.device_put(data_global, NamedSharding(mesh, P(axes, None)))

    def local_build(data_local):
        return build_index(key, data_local, cfg)

    fn = shard_map(
        local_build,
        mesh=mesh,
        in_specs=P(axes, None),
        out_specs=P(axes, None),  # leading axis of every index leaf is stacked per shard
        check_rep=False,
    )
    # NOTE: build_index's leaves have mixed leading dims; to keep specs simple
    # the sharded service stores the index leaves with a per-shard leading
    # batch dim via vmap-style stacking. We instead build one index per shard
    # lazily inside the query shard_map (tables are deterministic given key).
    return data_sharded


def sharded_query(
    key,
    data_sharded: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    cfg: IndexConfig,
    mesh: Mesh,
    k: int = 10,
    merge_hierarchical: bool = True,
):
    """One-shot build+query under shard_map (used by tests/benchmarks on small
    CPU meshes; the serve launcher caches the built index between calls)."""
    axes = tuple(mesh.axis_names)
    n_local = data_sharded.shape[0] // mesh.devices.size

    def local(data_local, q, w):
        idx = build_index(key, data_local, cfg)
        res = query_index(idx, q, w, cfg, k=k)
        # globalize ids: offset by shard rank
        rank = jnp.zeros((), jnp.int32)
        mul = 1
        for ax in reversed(axes):
            rank = rank + jax.lax.axis_index(ax) * mul
            mul *= mesh.shape[ax]  # static size (lax.axis_size needs jax>=0.4.38)
        gids = jnp.where(res.ids >= 0, res.ids + rank * n_local, -1)
        d, i, nc = res.dists, gids, res.n_candidates

        def merge_axis(d, i, nc, ax):
            dg = jax.lax.all_gather(d, ax, axis=0)  # (g, b, k)
            ig = jax.lax.all_gather(i, ax, axis=0)
            g, b, kk = dg.shape
            dg = jnp.moveaxis(dg, 0, 1).reshape(b, g * kk)
            ig = jnp.moveaxis(ig, 0, 1).reshape(b, g * kk)
            neg, sel = jax.lax.top_k(-dg, k)
            return -neg, jnp.take_along_axis(ig, sel, axis=1), jax.lax.psum(nc, ax)

        if merge_hierarchical:
            for ax in reversed(axes):  # model -> data -> pod
                d, i, nc = merge_axis(d, i, nc, ax)
        else:  # flat merge across the whole mesh at once (baseline)
            d, i, nc = merge_axis(d, i, nc, axes)
        return d, i, nc

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axes, None), P(), P()),
        out_specs=(P(), P(), P()),
        check_rep=False,
    )
    d, i, nc = fn(data_sharded, queries, weights)
    return ShardedQueryResult(dists=d, ids=i, n_candidates=nc)
