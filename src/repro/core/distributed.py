"""Distributed ALSH service: row-sharded index, replicated queries,
hierarchical top-k merge — the paper's workload at cluster scale.

Sharding contract (mesh axes ("pod","data","model")):

  * database rows: disjointly partitioned over ALL devices — each device
    builds a complete local index over its n_local rows (hash tables are
    valid per-shard because the (R1,R2)-NNS guarantee is closed under
    disjoint union: the global NN lives in exactly one shard).
  * hash tables/mixers: REPLICATED — every shard derives them from the same
    broadcast build key, so query hashing is computed once and is valid
    against every shard.
  * queries: replicated (or batch-sharded for throughput serving).
  * merge: local exact top-k per shard, then a hierarchical merge — sorted
    concat + re-top-k along "model", then "data", then "pod". Two-hop
    merging moves k·devices_per_hop entries per link instead of k·devices,
    cutting cross-pod DCN bytes by the pod fan-in (see EXPERIMENTS §Perf).

Two entry points, both under shard_map with explicit collectives:

  * ``build_local_indexes`` + ``sharded_index_query`` — build the per-shard
    indexes ONCE, query many times (what ``repro.api.Index.shard`` uses).
  * ``sharded_query`` — one-shot build+query (tests/benchmarks on small CPU
    meshes, where rebuild cost is irrelevant).

Each shard's query body is :func:`repro.engine.dispatch` over the shard's
slice — the same candidate-source composition and fused rerank tail the
single-host facade runs (the shard's sorted tables + its private delta
slice ARE its local candidate sources) — so sharded results can only
differ from single-host results by the merge, which is exact.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro import engine
from repro.core.hash_families import PrefixTables
from repro.core.index import (
    ALSHIndex,
    DeltaSegment,
    IndexConfig,
    build_index,
    hash_rows,
)


def _local_query(idx_local, delta_local, ts_local, q, w, cfg, spec):
    """One shard's query body: the SAME engine dispatch the single-host
    facade runs, over this shard's slice (its sorted tables + its private
    delta/tombstone slice form the shard-local candidate sources)."""
    return engine.dispatch(
        idx_local, delta_local, ts_local, q, w, cfg,
        k=spec.k, mode=spec.mode, n_probes=spec.n_probes,
        max_flips=spec.max_flips, impl=spec.impl,
    )


class ShardedQueryResult(NamedTuple):
    dists: jax.Array  # (b, k) global ascending
    ids: jax.Array  # (b, k) global ids (shard_offset + local id)
    n_candidates: jax.Array  # (b,) summed over shards


def shard_row_ranges(n: int, n_shards: int) -> list[tuple[int, int]]:
    """Contiguous equal row partition [start, stop) per shard — the id
    scheme of ``_globalize_and_merge`` (shard s owns [s·n_local, (s+1)·
    n_local)) and of the serving tier's host-side shard set. Requires
    ``n % n_shards == 0`` so every shard compiles one program shape."""
    if n_shards <= 0 or n % n_shards:
        raise ValueError(
            f"n={n} database rows cannot be split into {n_shards} equal "
            f"shards — the contiguous-partition id scheme (and the one-"
            f"compiled-program-per-bucket serving contract) needs n % "
            f"n_shards == 0"
        )
    n_local = n // n_shards
    return [(s * n_local, (s + 1) * n_local) for s in range(n_shards)]


def merge_topk_host(dists: np.ndarray, ids: np.ndarray, k: int):
    """Host-side top-k merge of per-shard results — the serving-tier mirror
    of ``_globalize_and_merge``'s on-device merge (there the shards live on
    one mesh and merge with collectives; here each shard is its own host
    process and the broker merges replies).

    Args:
      dists: (S, b, k') per-shard ascending distances. Sentinel slots
        (``+inf``, incl. ENTIRE dead-shard blocks — a killed shard
        contributes only sentinels) sink to the tail, exactly like the §8
        engine merge.
      ids: (S, b, k') matching global ids (``-1`` on sentinel slots).
      k: result width.

    Returns:
      (dists (b, k), ids (b, k)) numpy arrays, ascending per row; ids are
      ``-1`` wherever fewer than k finite candidates exist across the
      surviving shards. Deterministic (stable sort), so a recovered shard
      set answers bit-identically to the pre-failure one.
    """
    dists = np.asarray(dists)
    ids = np.asarray(ids)
    S, b, kk = dists.shape
    flat_d = np.moveaxis(dists, 0, 1).reshape(b, S * kk)
    flat_i = np.moveaxis(ids, 0, 1).reshape(b, S * kk)
    # sentinel ids must not win ties against real rows at equal distance
    order = np.argsort(
        np.where(flat_i < 0, np.inf, flat_d), axis=1, kind="stable"
    )[:, :k]
    out_d = np.take_along_axis(flat_d, order, axis=1)
    out_i = np.take_along_axis(flat_i, order, axis=1)
    out_d = np.where(out_i < 0, np.inf, out_d)
    return out_d, out_i


def local_index_specs(mesh: Mesh) -> ALSHIndex:
    """Per-leaf PartitionSpecs of a row-sharded ALSHIndex pytree.

    Tables/mixers are replicated (derived from the broadcast key); the
    point-indexed leaves shard their n-sized axis over all mesh axes.
    """
    axes = tuple(mesh.axis_names)
    return ALSHIndex(
        tables=PrefixTables(folded=P(), offsets=P()),
        mixers=P(),
        sorted_keys=P(None, axes),  # (L, n_local)
        perm=P(None, axes),  # (L, n_local + C)
        data=P(axes, None),  # (n_local, d)
        levels=P(axes, None),  # (n_local, d)
        scales=None,  # f32 storage only (Index.shard gates quantized indexes)
    )


def local_delta_specs(mesh: Mesh) -> DeltaSegment:
    """Per-leaf PartitionSpecs of a shard-private DeltaSegment bundle: each
    device owns ``cap`` delta slots; ``fill`` is one counter per shard."""
    axes = tuple(mesh.axis_names)
    return DeltaSegment(
        data=P(axes, None),  # (S·cap, d) -> local (cap, d)
        levels=P(axes, None),
        keys=P(None, axes),  # (L, S·cap) -> local (L, cap)
        fill=P(axes),  # (S,) -> local (1,)
    )


def make_sharded_delta(
    cfg: IndexConfig, mesh: Mesh, capacity: int, dtype, n_local: int
) -> tuple[DeltaSegment, jax.Array]:
    """Allocate empty per-shard delta segments + the shard-major tombstone
    bitmap ((S·(n_local+cap),): shard s owns slice [s·(n_local+cap), ...))."""
    S = mesh.devices.size
    axes = tuple(mesh.axis_names)

    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    delta = DeltaSegment(
        data=put(jnp.zeros((S * capacity, cfg.d), dtype), P(axes, None)),
        levels=put(jnp.zeros((S * capacity, cfg.d), jnp.int32), P(axes, None)),
        keys=put(jnp.zeros((cfg.L, S * capacity), jnp.int32), P(None, axes)),
        fill=put(jnp.zeros((S,), jnp.int32), P(axes)),
    )
    tombstones = put(jnp.zeros((S * (n_local + capacity),), bool), P(axes))
    return delta, tombstones


def _shard_rank(axes, mesh) -> jax.Array:
    """Linearized shard rank inside a shard_map body (row-major over axes)."""
    rank = jnp.zeros((), jnp.int32)
    mul = 1
    for ax in reversed(axes):
        rank = rank + jax.lax.axis_index(ax) * mul
        mul *= mesh.shape[ax]  # static size (lax.axis_size needs jax>=0.4.38)
    return rank


def build_local_indexes(
    key, data_global: jax.Array, cfg: IndexConfig, mesh: Mesh
) -> ALSHIndex:
    """Build one complete local index per shard, ONCE: (n, d) row-sharded
    data -> a sharded ALSHIndex pytree (leaf layout per ``local_index_specs``).

    All shards share the SAME hash tables (key is broadcast), so a query's
    hash keys are valid against every shard's sorted tables.
    """
    axes = tuple(mesh.axis_names)
    data_sharded = jax.device_put(data_global, NamedSharding(mesh, P(axes, None)))
    fn = shard_map(
        lambda data_local: build_index(key, data_local, cfg),
        mesh=mesh,
        in_specs=P(axes, None),
        out_specs=local_index_specs(mesh),
        check_rep=False,
    )
    return fn(data_sharded)


def _globalize_and_merge(res, axes, mesh, k, n_local, merge_hierarchical):
    """Inside a query shard_map body: local QueryResult -> merged globals.

    Maps local ids to global ids — main row i on shard s is ``s·n_local + i``
    (rows are contiguously partitioned); delta slot t on shard s is
    ``S·n_local + t·S + s`` (inserts route round-robin, so the t-th slot of
    shard s held the (t·S + s)-th insert) — then top-k-merges along each
    mesh axis innermost-first (hierarchical) or across the whole mesh at
    once.
    """
    rank = _shard_rank(axes, mesh)
    S = mesh.devices.size
    main_g = res.ids + rank * n_local
    delta_g = S * n_local + (res.ids - n_local) * S + rank
    gids = jnp.where(res.ids < 0, -1, jnp.where(res.ids < n_local, main_g, delta_g))
    d, i, nc = res.dists, gids, res.n_candidates

    def merge_axis(d, i, nc, ax):
        dg = jax.lax.all_gather(d, ax, axis=0)  # (g, b, k)
        ig = jax.lax.all_gather(i, ax, axis=0)
        g, b, kk = dg.shape
        dg = jnp.moveaxis(dg, 0, 1).reshape(b, g * kk)
        ig = jnp.moveaxis(ig, 0, 1).reshape(b, g * kk)
        neg, sel = jax.lax.top_k(-dg, k)
        return -neg, jnp.take_along_axis(ig, sel, axis=1), jax.lax.psum(nc, ax)

    if merge_hierarchical:
        for ax in reversed(axes):  # model -> data -> pod
            d, i, nc = merge_axis(d, i, nc, ax)
    else:  # flat merge across the whole mesh at once (baseline)
        d, i, nc = merge_axis(d, i, nc, axes)
    return d, i, nc


def sharded_index_query(
    index_sharded: ALSHIndex,
    queries: jax.Array,
    weights: jax.Array,
    cfg: IndexConfig,
    mesh: Mesh,
    spec=None,
    k: int = 10,
    merge_hierarchical: bool = True,
    delta_sharded: DeltaSegment | None = None,
    tombstones_sharded: jax.Array | None = None,
    update=None,
):
    """Query prebuilt shard-local indexes (from ``build_local_indexes``).

    ``spec`` (a :class:`repro.api.QuerySpec`) selects the shard-local
    execution strategy — probe, multiprobe, or exact — so the sharded
    service exposes the same policy surface as a single-host ``Index``.
    Each shard's body is :func:`repro.engine.dispatch` over its slice —
    the identical pipeline (sources, dedupe, tombstone mask, fused rerank)
    the single-host facade runs — with the hierarchical top-k merge
    composing the per-shard results.

    With ``delta_sharded``/``tombstones_sharded`` (a mutable
    ``ShardedIndex``), each shard adds the delta key-match source over its
    private delta and tombstone slice; merged ids use the global id scheme
    of ``_globalize_and_merge``. ``update`` is accepted for backward
    compatibility and unused (the engine needs only the arrays).
    """
    del update  # kept for call-site compatibility
    from repro.api import QuerySpec  # lazy: api builds on core

    if spec is None:
        spec = QuerySpec(k=k)
    axes = tuple(mesh.axis_names)
    S = mesh.devices.size
    n_local = index_sharded.data.shape[0] // S

    if delta_sharded is None:

        def local(idx_local, q, w):
            res = _local_query(idx_local, None, None, q, w, cfg, spec)
            return _globalize_and_merge(
                res, axes, mesh, spec.k, n_local, merge_hierarchical
            )

        fn = shard_map(
            local,
            mesh=mesh,
            in_specs=(local_index_specs(mesh), P(), P()),
            out_specs=(P(), P(), P()),
            check_rep=False,
        )
        d, i, nc = fn(index_sharded, queries, weights)
        return ShardedQueryResult(dists=d, ids=i, n_candidates=nc)

    def local_mut(idx_local, delta_local, ts_local, q, w):
        delta = DeltaSegment(
            data=delta_local.data,
            levels=delta_local.levels,
            keys=delta_local.keys,
            fill=delta_local.fill.reshape(()),
        )
        res = _local_query(idx_local, delta, ts_local, q, w, cfg, spec)
        return _globalize_and_merge(
            res, axes, mesh, spec.k, n_local, merge_hierarchical
        )

    fn = shard_map(
        local_mut,
        mesh=mesh,
        in_specs=(local_index_specs(mesh), local_delta_specs(mesh), P(axes), P(), P()),
        out_specs=(P(), P(), P()),
        check_rep=False,
    )
    d, i, nc = fn(index_sharded, delta_sharded, tombstones_sharded, queries, weights)
    return ShardedQueryResult(dists=d, ids=i, n_candidates=nc)


def sharded_delta_insert(
    index_sharded: ALSHIndex,
    delta_sharded: DeltaSegment,
    rows: jax.Array,
    cfg: IndexConfig,
    mesh: Mesh,
    impl: str = "auto",
) -> tuple[DeltaSegment, jax.Array]:
    """Insert rows into per-shard delta segments, routed by global id.

    The j-th row of the stream gets global id ``n_main_global + e`` (e =
    running insert count); its owner is shard ``e % S`` and its slot is
    ``e // S`` — round-robin striping, so every shard's delta fills evenly
    and the single-host id scheme is preserved. Each shard hashes its own
    rows with the replicated tables (O(H·d·m/S) per shard, no resort).

    Returns (new delta_sharded, (m,) global ids; -1 where the owning
    shard's delta was full).
    """
    S = mesh.devices.size
    axes = tuple(mesh.axis_names)
    n_local = index_sharded.data.shape[0] // S
    cap = delta_sharded.data.shape[0] // S
    n_main_global = n_local * S
    m = rows.shape[0]
    B = -(-m // S)  # rows per shard this call

    # next insert position: all shards filled round-robin from e=0, so the
    # resume phase is the total fill (drops only happen when EVERY later
    # shard is full too, keeping fills within one stripe of each other)
    phase = (jnp.sum(delta_sharded.fill) % S).astype(jnp.int32)
    rows_p = jnp.pad(rows.astype(delta_sharded.data.dtype), ((0, B * S - m), (0, 0)))
    valid = jnp.arange(B * S, dtype=jnp.int32) < m
    # J[s, t] = stream position routed to shard s, slot offset t
    s_idx = jnp.arange(S, dtype=jnp.int32)[:, None]
    t_idx = jnp.arange(B, dtype=jnp.int32)[None, :]
    J = ((s_idx - phase) % S) + t_idx * S  # (S, B)
    rows_routed = jnp.take(rows_p, J.reshape(-1), axis=0)  # (S·B, d)
    valid_routed = jnp.take(valid, J.reshape(-1))  # (S·B,)

    def local(idx_local, delta_local, rows_s, valid_s):
        rank = _shard_rank(axes, mesh)
        rows_s = rows_s.reshape(B, -1)
        valid_s = valid_s.reshape(B)
        keys, levels = hash_rows(idx_local, rows_s, cfg, impl=impl)  # (L, B), (B, d)
        fill = delta_local.fill.reshape(())
        n_valid = jnp.sum(valid_s.astype(jnp.int32))  # valid rows are a prefix
        t = jnp.arange(B, dtype=jnp.int32)
        slot = fill + t
        write = (t < n_valid) & (slot < cap)
        tgt = jnp.where(write, slot, cap)  # out-of-capacity -> dropped
        new_delta = DeltaSegment(
            data=delta_local.data.at[tgt].set(rows_s, mode="drop"),
            levels=delta_local.levels.at[tgt].set(levels, mode="drop"),
            keys=delta_local.keys.at[:, tgt].set(keys, mode="drop"),
            fill=jnp.minimum(jnp.asarray(cap, jnp.int32), fill + n_valid).reshape(1),
        )
        ids = jnp.where(write, n_main_global + slot * S + rank, -1)
        return new_delta, ids.reshape(1, B)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(local_index_specs(mesh), local_delta_specs(mesh), P(axes), P(axes)),
        out_specs=(local_delta_specs(mesh), P(axes, None)),
        check_rep=False,
    )
    new_delta, ids_mat = fn(index_sharded, delta_sharded, rows_routed, valid_routed)
    j = jnp.arange(m, dtype=jnp.int32)
    ids = ids_mat[(phase + j) % S, j // S]  # back to stream order
    return new_delta, ids


def sharded_tombstone(
    tombstones_sharded: jax.Array,
    gids: jax.Array,
    delta_fill: jax.Array,
    mesh: Mesh,
    n_local: int,
    cap: int,
) -> jax.Array:
    """Tombstone global ids on their owning shards (others drop them).

    Owner/local-slot mapping inverts ``_globalize_and_merge``: main gid g
    lives on shard ``g // n_local`` at slot ``g % n_local``; delta gid
    ``n_main_global + e`` lives on shard ``e % S`` at slot
    ``n_local + e // S``. Unknown gids — negative, out of range, or naming
    a delta slot no insert has assigned yet (slot >= the owner's fill) —
    are ignored, matching single-host ``tombstone_ids``.
    """
    S = mesh.devices.size
    axes = tuple(mesh.axis_names)
    n_main_global = n_local * S

    def local(ts_local, g, fill_local):
        rank = _shard_rank(axes, mesh)
        fill = fill_local.reshape(())
        safe = jnp.maximum(g, 0)
        is_main = (g >= 0) & (g < n_main_global)
        in_delta = (g >= n_main_global) & (g < n_main_global + cap * S)
        e = safe - n_main_global
        in_delta = in_delta & (e // S < fill)  # unassigned slots: ignored
        owner = jnp.where(is_main, safe // n_local, e % S)
        local_slot = jnp.where(is_main, safe % n_local, n_local + e // S)
        mine = (is_main | in_delta) & (owner == rank)
        idx = jnp.where(mine, local_slot, n_local + cap)  # miss -> dropped
        return ts_local.at[idx].set(True, mode="drop")

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axes), P(), P(axes)),
        out_specs=P(axes),
        check_rep=False,
    )
    return fn(tombstones_sharded, gids, delta_fill)


def sharded_query(
    key,
    data_sharded: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    cfg: IndexConfig,
    mesh: Mesh,
    k: int = 10,
    merge_hierarchical: bool = True,
    spec=None,
):
    """One-shot build+query under shard_map (tests/benchmarks on small CPU
    meshes; serving paths prebuild via ``build_local_indexes`` instead).

    ``k`` is kept for backward compatibility and ignored when ``spec`` is
    given.
    """
    from repro.api import QuerySpec  # lazy: api builds on core

    if spec is None:
        spec = QuerySpec(k=k)
    axes = tuple(mesh.axis_names)
    n_local = data_sharded.shape[0] // mesh.devices.size

    def local(data_local, q, w):
        idx = build_index(key, data_local, cfg)
        res = _local_query(idx, None, None, q, w, cfg, spec)
        return _globalize_and_merge(res, axes, mesh, spec.k, n_local, merge_hierarchical)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axes, None), P(), P()),
        out_specs=(P(), P(), P()),
        check_rep=False,
    )
    d, i, nc = fn(data_sharded, queries, weights)
    return ShardedQueryResult(dists=d, ids=i, n_candidates=nc)
