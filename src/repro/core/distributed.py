"""Distributed ALSH service: row-sharded index, replicated queries,
hierarchical top-k merge — the paper's workload at cluster scale.

Sharding contract (mesh axes ("pod","data","model")):

  * database rows: disjointly partitioned over ALL devices — each device
    builds a complete local index over its n_local rows (hash tables are
    valid per-shard because the (R1,R2)-NNS guarantee is closed under
    disjoint union: the global NN lives in exactly one shard).
  * hash tables/mixers: REPLICATED — every shard derives them from the same
    broadcast build key, so query hashing is computed once and is valid
    against every shard.
  * queries: replicated (or batch-sharded for throughput serving).
  * merge: local exact top-k per shard, then a hierarchical merge — sorted
    concat + re-top-k along "model", then "data", then "pod". Two-hop
    merging moves k·devices_per_hop entries per link instead of k·devices,
    cutting cross-pod DCN bytes by the pod fan-in (see EXPERIMENTS §Perf).

Two entry points, both under shard_map with explicit collectives:

  * ``build_local_indexes`` + ``sharded_index_query`` — build the per-shard
    indexes ONCE, query many times (what ``repro.api.Index.shard`` uses).
  * ``sharded_query`` — one-shot build+query (tests/benchmarks on small CPU
    meshes, where rebuild cost is irrelevant).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.hash_families import PrefixTables
from repro.core.index import ALSHIndex, IndexConfig, build_index


class ShardedQueryResult(NamedTuple):
    dists: jax.Array  # (b, k) global ascending
    ids: jax.Array  # (b, k) global ids (shard_offset + local id)
    n_candidates: jax.Array  # (b,) summed over shards


def local_index_specs(mesh: Mesh) -> ALSHIndex:
    """Per-leaf PartitionSpecs of a row-sharded ALSHIndex pytree.

    Tables/mixers are replicated (derived from the broadcast key); the
    point-indexed leaves shard their n-sized axis over all mesh axes.
    """
    axes = tuple(mesh.axis_names)
    return ALSHIndex(
        tables=PrefixTables(folded=P(), offsets=P()),
        mixers=P(),
        sorted_keys=P(None, axes),  # (L, n_local)
        perm=P(None, axes),  # (L, n_local + C)
        data=P(axes, None),  # (n_local, d)
        levels=P(axes, None),  # (n_local, d)
    )


def build_local_indexes(
    key, data_global: jax.Array, cfg: IndexConfig, mesh: Mesh
) -> ALSHIndex:
    """Build one complete local index per shard, ONCE: (n, d) row-sharded
    data -> a sharded ALSHIndex pytree (leaf layout per ``local_index_specs``).

    All shards share the SAME hash tables (key is broadcast), so a query's
    hash keys are valid against every shard's sorted tables.
    """
    axes = tuple(mesh.axis_names)
    data_sharded = jax.device_put(data_global, NamedSharding(mesh, P(axes, None)))
    fn = shard_map(
        lambda data_local: build_index(key, data_local, cfg),
        mesh=mesh,
        in_specs=P(axes, None),
        out_specs=local_index_specs(mesh),
        check_rep=False,
    )
    return fn(data_sharded)


def _globalize_and_merge(res, axes, mesh, k, n_local, merge_hierarchical):
    """Inside a query shard_map body: local QueryResult -> merged globals.

    Offsets local ids by the shard's rank, then top-k-merges along each mesh
    axis innermost-first (hierarchical) or across the whole mesh at once.
    """
    rank = jnp.zeros((), jnp.int32)
    mul = 1
    for ax in reversed(axes):
        rank = rank + jax.lax.axis_index(ax) * mul
        mul *= mesh.shape[ax]  # static size (lax.axis_size needs jax>=0.4.38)
    gids = jnp.where(res.ids >= 0, res.ids + rank * n_local, -1)
    d, i, nc = res.dists, gids, res.n_candidates

    def merge_axis(d, i, nc, ax):
        dg = jax.lax.all_gather(d, ax, axis=0)  # (g, b, k)
        ig = jax.lax.all_gather(i, ax, axis=0)
        g, b, kk = dg.shape
        dg = jnp.moveaxis(dg, 0, 1).reshape(b, g * kk)
        ig = jnp.moveaxis(ig, 0, 1).reshape(b, g * kk)
        neg, sel = jax.lax.top_k(-dg, k)
        return -neg, jnp.take_along_axis(ig, sel, axis=1), jax.lax.psum(nc, ax)

    if merge_hierarchical:
        for ax in reversed(axes):  # model -> data -> pod
            d, i, nc = merge_axis(d, i, nc, ax)
    else:  # flat merge across the whole mesh at once (baseline)
        d, i, nc = merge_axis(d, i, nc, axes)
    return d, i, nc


def sharded_index_query(
    index_sharded: ALSHIndex,
    queries: jax.Array,
    weights: jax.Array,
    cfg: IndexConfig,
    mesh: Mesh,
    spec=None,
    k: int = 10,
    merge_hierarchical: bool = True,
):
    """Query prebuilt shard-local indexes (from ``build_local_indexes``).

    ``spec`` (a :class:`repro.api.QuerySpec`) selects the shard-local
    execution strategy — probe, multiprobe, or exact — so the sharded
    service exposes the same policy surface as a single-host ``Index``.
    """
    from repro.api import Index, QuerySpec  # facade (lazy: api builds on core)

    if spec is None:
        spec = QuerySpec(k=k)
    axes = tuple(mesh.axis_names)
    n_local = index_sharded.data.shape[0] // mesh.devices.size

    def local(idx_local, q, w):
        # build_key is irrelevant for querying — any placeholder works
        facade = Index(state=idx_local, build_key=jnp.zeros((2,), jnp.uint32), config=cfg)
        res = facade.query(q, w, spec)
        return _globalize_and_merge(res, axes, mesh, spec.k, n_local, merge_hierarchical)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(local_index_specs(mesh), P(), P()),
        out_specs=(P(), P(), P()),
        check_rep=False,
    )
    d, i, nc = fn(index_sharded, queries, weights)
    return ShardedQueryResult(dists=d, ids=i, n_candidates=nc)


def sharded_query(
    key,
    data_sharded: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    cfg: IndexConfig,
    mesh: Mesh,
    k: int = 10,
    merge_hierarchical: bool = True,
    spec=None,
):
    """One-shot build+query under shard_map (tests/benchmarks on small CPU
    meshes; serving paths prebuild via ``build_local_indexes`` instead).

    ``k`` is kept for backward compatibility and ignored when ``spec`` is
    given.
    """
    from repro.api import Index, QuerySpec  # facade (lazy: api builds on core)

    if spec is None:
        spec = QuerySpec(k=k)
    axes = tuple(mesh.axis_names)
    n_local = data_sharded.shape[0] // mesh.devices.size

    def local(data_local, q, w):
        idx = Index.build(key, data_local, cfg)
        res = idx.query(q, w, spec)
        return _globalize_and_merge(res, axes, mesh, spec.k, n_local, merge_hierarchical)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axes, None), P(), P()),
        out_specs=(P(), P(), P()),
        check_rep=False,
    )
    d, i, nc = fn(data_sharded, queries, weights)
    return ShardedQueryResult(dists=d, ids=i, n_candidates=nc)
