"""Paper theory: collision probabilities (Eq 4/6/25/27), rho (Thm 4/5), (K, L) selection.

Everything here is closed-form and differentiable; benchmarks/collision.py
Monte-Carlo-validates these curves against the actual hash implementations,
and benchmarks/rho_tables.py reproduces the paper's complexity claims
(rho < 1 => sublinear query time, Theorem 1).

Besides the forward curves this module carries their INVERSES:

  wl1_from_l2_distance / wl1_from_angular_distance   — Eq 24/26 inverted
  invert_p_l2                                        — Eq 4 inverted (bisection)
  solve_K / solve_tables(P1, P2, n, fail_prob)       — Thm 1 (K, L) for a
                                                       requested failure bound
  solve_bucket_width                                 — W minimizing rho for the
                                                       l2 family at (s1, s2)
  operating_radii                                    — (R1, R2) from a sample
                                                       of observed NN distances

These are the SCALAR Thm 1 solvers — one aggregate (P1, P2) operating
point in, one (K, L) out — directly unit-tested in tests/test_theory.py.
The declarative planner (``repro.api.planner``) shares ``solve_K`` and
``invert_p_l2`` but deliberately replaces the scalar L / W / radius solves
with PER-SAMPLE variants (L from the sampled success curve, W anchored at a
collision-prob quantile): a single aggregate operating point overpromises
badly for spread-out weight distributions — see DESIGN.md §5. Fixes to the
scalar solvers here do NOT change planner behavior; they remain the
closed-form reference (and the right tool when you have a known worst-case
weight profile rather than a data sample).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.scipy.stats import norm


def p_l2(r: jax.Array, W: float) -> jax.Array:
    """Eq 4 — collision probability of the p-stable L2 hash at l2 distance r."""
    r = jnp.asarray(r, jnp.float64 if jax.config.x64_enabled else jnp.float32)
    c = W / r
    return 1.0 - 2.0 * norm.cdf(-c) - 2.0 / (jnp.sqrt(2.0 * jnp.pi) * c) * (
        1.0 - jnp.exp(-(c**2) / 2.0)
    )


def p_theta(r: jax.Array) -> jax.Array:
    """Eq 6 — collision probability of SimHash at angular distance r."""
    return 1.0 - r / jnp.pi


def l2_distance_from_wl1(r: jax.Array, M: int, d: int, w: jax.Array) -> jax.Array:
    """Eq 24: ||P(o) - Q_w(q)||_2 as a function of r = d_w^l1(o, q).

    = sqrt( M (d + sum w_i^2) - 2 (M sum w_i - r) ).
    """
    sw = jnp.sum(w, axis=-1)
    sw2 = jnp.sum(w * w, axis=-1)
    return jnp.sqrt(M * (d + sw2) - 2.0 * (M * sw - r))


def angular_distance_from_wl1(r: jax.Array, M: int, d: int, w: jax.Array) -> jax.Array:
    """Eq 26: angle between P(o) and Q_w(q) as a function of r = d_w^l1(o, q)."""
    sw = jnp.sum(w, axis=-1)
    sw2 = jnp.sum(w * w, axis=-1)
    cosang = (M * sw - r) / (M * jnp.sqrt(d * sw2))
    return jnp.arccos(jnp.clip(cosang, -1.0, 1.0))


def collision_prob_l2(r: jax.Array, M: int, d: int, w: jax.Array, W: float) -> jax.Array:
    """Eq 25 — collision probability of (d_w^l1, l2)-ALSH at weighted-L1 distance r."""
    return p_l2(l2_distance_from_wl1(r, M, d, w), W)


def collision_prob_theta(r: jax.Array, M: int, d: int, w: jax.Array) -> jax.Array:
    """Eq 27 — collision probability of (d_w^l1, theta)-ALSH at weighted-L1 distance r."""
    return p_theta(angular_distance_from_wl1(r, M, d, w))


def rho(
    R1: jax.Array,
    R2: jax.Array,
    M: int,
    d: int,
    w: jax.Array,
    family: str = "theta",
    W: float = 4.0,
) -> jax.Array:
    """Thm 4/5: rho = log P(R1) / log P(R2) — the sublinearity exponent (< 1)."""
    if family == "l2":
        p1 = collision_prob_l2(R1, M, d, w, W)
        p2 = collision_prob_l2(R2, M, d, w, W)
    else:
        p1 = collision_prob_theta(R1, M, d, w)
        p2 = collision_prob_theta(R2, M, d, w)
    return jnp.log(p1) / jnp.log(p2)


class IndexPlan(NamedTuple):
    """Derived index geometry from LSH theory (Theorem 1 construction)."""

    K: int  # concatenated hashes per table: collision prob p^K
    L: int  # number of tables: L ~ n^rho for >= 1 - 1/e success
    rho: float
    P1: float
    P2: float


def plan_index(
    n: int,
    R1: float,
    R2: float,
    M: int,
    d: int,
    w_scale: float = 1.0,
    family: str = "theta",
    W: float = 4.0,
    max_K: int = 32,
    max_L: int = 256,
) -> IndexPlan:
    """Pick (K, L) per Theorem 1 for a worst-case weight magnitude profile.

    The weights are query-time data, so the plan is made for a *reference*
    weight profile (all-|w_scale| vector); theory.py exposes the exact rho for
    any concrete ``w`` so callers can re-plan per workload. Success probability
    per query is >= 1 - (1 - P1^K)^L (≈ 1 - 1/e at L = ceil(P1^-K)).
    ``max_K`` is additionally clamped to the family's per-table cap (the
    theta family bit-packs K codes into an int32 key, so K <= 31) — plans
    always satisfy ``IndexConfig`` validation.
    """
    from repro.core.families import get_family  # lazy: families ↛ theory

    fam_cap = get_family(family).max_K
    if fam_cap is not None:
        max_K = min(max_K, fam_cap)
    w = jnp.full((d,), float(w_scale))
    if family == "l2":
        P1 = float(collision_prob_l2(jnp.asarray(R1), M, d, w, W))
        P2 = float(collision_prob_l2(jnp.asarray(R2), M, d, w, W))
    else:
        P1 = float(collision_prob_theta(jnp.asarray(R1), M, d, w))
        P2 = float(collision_prob_theta(jnp.asarray(R2), M, d, w))
    if not (0.0 < P2 < P1 < 1.0):
        raise ValueError(f"degenerate collision probs P1={P1} P2={P2}; widen (R1, R2)")
    r = math.log(P1) / math.log(P2)
    K = max(1, min(max_K, math.ceil(math.log(n) / math.log(1.0 / P2))))
    L = max(1, min(max_L, math.ceil(P1 ** (-K))))
    return IndexPlan(K=K, L=L, rho=r, P1=P1, P2=P2)


def success_probability(plan: IndexPlan) -> float:
    """P[some table collides with an R1-near neighbour] = 1 - (1 - P1^K)^L."""
    return 1.0 - (1.0 - plan.P1**plan.K) ** plan.L


# ---------------------------------------------------------------------------
# Inverse solvers — quality targets in, mechanism out (the planner's substrate)
# ---------------------------------------------------------------------------


def wl1_from_l2_distance(s: jax.Array, M: int, d: int, w: jax.Array) -> jax.Array:
    """Eq 24 inverted: the d_w^l1 distance r whose transformed l2 distance is s.

    From s^2 = M (d + sum w_i^2) - 2 (M sum w_i - r):
    r = M sum w_i - (M (d + sum w_i^2) - s^2) / 2.
    """
    sw = jnp.sum(w, axis=-1)
    sw2 = jnp.sum(w * w, axis=-1)
    return M * sw - (M * (d + sw2) - jnp.square(s)) / 2.0


def wl1_from_angular_distance(ang: jax.Array, M: int, d: int, w: jax.Array) -> jax.Array:
    """Eq 26 inverted: the d_w^l1 distance r whose transformed angle is ang."""
    sw = jnp.sum(w, axis=-1)
    sw2 = jnp.sum(w * w, axis=-1)
    return M * sw - jnp.cos(ang) * M * jnp.sqrt(d * sw2)


def invert_p_l2(p: float, W: float, r_hi: float = 1e9) -> float:
    """Eq 4 inverted: the l2 distance r at which p_l2(r, W) == p.

    ``p_l2`` is strictly decreasing in r with range (0, 1), so the root is
    unique; solved by bisection on r in (0, r_hi]. Host-side (not
    differentiable/jittable) — the planner calls it a handful of times.
    """
    if not (0.0 < p < 1.0):
        raise ValueError(f"invert_p_l2: p must be in (0, 1), got {p}")
    lo, hi = 1e-12, float(r_hi)
    # p unreachably small even at r_hi
    if float(p_l2(jnp.asarray(hi), W)) > p:  # repro: allow[RPR001] host-side bisection solver, docstring forbids jit
        return hi
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if float(p_l2(jnp.asarray(mid), W)) > p:  # repro: allow[RPR001] host-side bisection solver, docstring forbids jit
            lo = mid
        else:
            hi = mid
        if hi - lo <= 1e-9 * max(1.0, hi):
            break
    return 0.5 * (lo + hi)


def solve_K(P2: float, n: int, max_K: int = 32) -> int:
    """Thm 1 hash count: K = ceil(ln n / ln(1/P2)) caps the expected
    far-point collisions per table at O(1); clamped to [1, max_K]."""
    if not (0.0 < P2 < 1.0):
        raise ValueError(f"solve_K: P2 must be in (0, 1), got {P2}")
    return max(1, min(max_K, math.ceil(math.log(n) / math.log(1.0 / P2))))


def solve_tables(
    P1: float,
    P2: float,
    n: int,
    fail_prob: float = math.exp(-1.0),
    max_K: int = 32,
    max_L: int = 1024,
) -> tuple[int, int]:
    """Thm 1 construction solved for a REQUESTED failure bound.

    K = ceil(ln n / ln(1/P2)) bounds the far-point candidate load at O(1)
    per table; L = ceil(ln(1/delta) / P1^K) makes the miss probability of an
    R1-near neighbour (1 - P1^K)^L <= delta = ``fail_prob``. The classic
    L = P1^-K choice is the special case delta = 1/e.

    Returns (K, L) clamped to [1, max_K] x [1, max_L]; the clamp can raise
    the achieved failure probability above ``fail_prob`` — callers that need
    the truth recompute 1-(1-P1^K)^L from the returned values (the planner
    records it in ``PlannedSpec.predicted_success``).
    """
    if not (0.0 < P2 < P1 < 1.0):
        raise ValueError(f"solve_tables: need 0 < P2 < P1 < 1, got P1={P1} P2={P2}")
    if not (0.0 < fail_prob < 1.0):
        raise ValueError(f"solve_tables: fail_prob must be in (0, 1), got {fail_prob}")
    K = solve_K(P2, n, max_K)
    # miss prob (1 - P1^K)^L <= delta  =>  L >= ln(delta) / ln(1 - P1^K)
    p_hit = P1**K
    if p_hit >= 1.0:
        L = 1
    else:
        L = math.ceil(math.log(fail_prob) / math.log1p(-p_hit))
    return K, max(1, min(max_L, L))


def solve_bucket_width(
    s1: float,
    s2: float,
    lo_factor: float = 0.05,
    hi_factor: float = 8.0,
    steps: int = 256,
) -> float:
    """Pick the l2 family's bucket width W minimizing rho at (s1, s2).

    s1/s2 are the TRANSFORMED l2 distances of the near/far radii (Eq 24).
    rho(W) = log p_l2(s1, W) / log p_l2(s2, W) is smooth and single-dipped
    in W; a deterministic log-spaced grid search over [lo_factor*s2,
    hi_factor*s2] is accurate to ~1% and has no convergence knobs.
    """
    if not (0.0 < s1 < s2):
        raise ValueError(f"solve_bucket_width: need 0 < s1 < s2, got {s1}, {s2}")
    ws = jnp.exp(
        jnp.linspace(math.log(lo_factor * s2), math.log(hi_factor * s2), steps)
    )
    p1 = p_l2(jnp.asarray(s1), ws)
    p2 = p_l2(jnp.asarray(s2), ws)
    # guard the open ends where p -> 0 or 1 and the ratio degenerates
    eps = 1e-12
    rhos = jnp.log(jnp.clip(p1, eps, 1 - eps)) / jnp.log(jnp.clip(p2, eps, 1 - eps))
    ok = (p1 > eps) & (p2 > eps) & (p1 < 1 - eps) & (p2 < 1 - eps)
    rhos = jnp.where(ok, rhos, jnp.inf)
    return float(ws[int(jnp.argmin(rhos))])


def operating_radii(
    nn_dists, approx_c: float, quantile: float = 0.5, r_max: float | None = None
) -> tuple[float, float]:
    """(R1, R2) from a calibration sample of observed NN distances.

    R1 is the ``quantile`` of the sample (the radius a typical query's true
    neighbour sits at); R2 = approx_c * R1 is the Thm 1 far radius. Both are
    clamped to (0, r_max) when ``r_max`` (the geometric diameter
    M * sum w_i) is given — degenerate samples (all-zero distances) fall
    back to r_max / (2 * approx_c).
    """
    import numpy as np

    if approx_c <= 1.0:
        raise ValueError(f"operating_radii: approx_c must be > 1, got {approx_c}")
    arr = np.asarray(nn_dists, dtype=np.float64).reshape(-1)
    arr = arr[np.isfinite(arr)]
    R1 = float(np.quantile(arr, quantile)) if arr.size else 0.0
    if r_max is not None and (R1 <= 0.0 or approx_c * R1 >= r_max):
        R1 = min(R1, r_max / (2.0 * approx_c)) or r_max / (2.0 * approx_c)
    if R1 <= 0.0:
        raise ValueError(
            "operating_radii: calibration sample gave a non-positive near "
            "radius and no r_max fallback was provided"
        )
    return R1, approx_c * R1
