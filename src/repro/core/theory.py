"""Paper theory: collision probabilities (Eq 4/6/25/27), rho (Thm 4/5), (K, L) selection.

Everything here is closed-form and differentiable; benchmarks/collision.py
Monte-Carlo-validates these curves against the actual hash implementations,
and benchmarks/rho_tables.py reproduces the paper's complexity claims
(rho < 1 => sublinear query time, Theorem 1).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.scipy.stats import norm


def p_l2(r: jax.Array, W: float) -> jax.Array:
    """Eq 4 — collision probability of the p-stable L2 hash at l2 distance r."""
    r = jnp.asarray(r, jnp.float64 if jax.config.x64_enabled else jnp.float32)
    c = W / r
    return 1.0 - 2.0 * norm.cdf(-c) - 2.0 / (jnp.sqrt(2.0 * jnp.pi) * c) * (
        1.0 - jnp.exp(-(c**2) / 2.0)
    )


def p_theta(r: jax.Array) -> jax.Array:
    """Eq 6 — collision probability of SimHash at angular distance r."""
    return 1.0 - r / jnp.pi


def l2_distance_from_wl1(r: jax.Array, M: int, d: int, w: jax.Array) -> jax.Array:
    """Eq 24: ||P(o) - Q_w(q)||_2 as a function of r = d_w^l1(o, q).

    = sqrt( M (d + sum w_i^2) - 2 (M sum w_i - r) ).
    """
    sw = jnp.sum(w, axis=-1)
    sw2 = jnp.sum(w * w, axis=-1)
    return jnp.sqrt(M * (d + sw2) - 2.0 * (M * sw - r))


def angular_distance_from_wl1(r: jax.Array, M: int, d: int, w: jax.Array) -> jax.Array:
    """Eq 26: angle between P(o) and Q_w(q) as a function of r = d_w^l1(o, q)."""
    sw = jnp.sum(w, axis=-1)
    sw2 = jnp.sum(w * w, axis=-1)
    cosang = (M * sw - r) / (M * jnp.sqrt(d * sw2))
    return jnp.arccos(jnp.clip(cosang, -1.0, 1.0))


def collision_prob_l2(r: jax.Array, M: int, d: int, w: jax.Array, W: float) -> jax.Array:
    """Eq 25 — collision probability of (d_w^l1, l2)-ALSH at weighted-L1 distance r."""
    return p_l2(l2_distance_from_wl1(r, M, d, w), W)


def collision_prob_theta(r: jax.Array, M: int, d: int, w: jax.Array) -> jax.Array:
    """Eq 27 — collision probability of (d_w^l1, theta)-ALSH at weighted-L1 distance r."""
    return p_theta(angular_distance_from_wl1(r, M, d, w))


def rho(
    R1: jax.Array,
    R2: jax.Array,
    M: int,
    d: int,
    w: jax.Array,
    family: str = "theta",
    W: float = 4.0,
) -> jax.Array:
    """Thm 4/5: rho = log P(R1) / log P(R2) — the sublinearity exponent (< 1)."""
    if family == "l2":
        p1 = collision_prob_l2(R1, M, d, w, W)
        p2 = collision_prob_l2(R2, M, d, w, W)
    else:
        p1 = collision_prob_theta(R1, M, d, w)
        p2 = collision_prob_theta(R2, M, d, w)
    return jnp.log(p1) / jnp.log(p2)


class IndexPlan(NamedTuple):
    """Derived index geometry from LSH theory (Theorem 1 construction)."""

    K: int  # concatenated hashes per table: collision prob p^K
    L: int  # number of tables: L ~ n^rho for >= 1 - 1/e success
    rho: float
    P1: float
    P2: float


def plan_index(
    n: int,
    R1: float,
    R2: float,
    M: int,
    d: int,
    w_scale: float = 1.0,
    family: str = "theta",
    W: float = 4.0,
    max_K: int = 32,
    max_L: int = 256,
) -> IndexPlan:
    """Pick (K, L) per Theorem 1 for a worst-case weight magnitude profile.

    The weights are query-time data, so the plan is made for a *reference*
    weight profile (all-|w_scale| vector); theory.py exposes the exact rho for
    any concrete ``w`` so callers can re-plan per workload. Success probability
    per query is >= 1 - (1 - P1^K)^L (≈ 1 - 1/e at L = ceil(P1^-K)).
    ``max_K`` is additionally clamped to the family's per-table cap (the
    theta family bit-packs K codes into an int32 key, so K <= 31) — plans
    always satisfy ``IndexConfig`` validation.
    """
    from repro.core.families import get_family  # lazy: families ↛ theory

    fam_cap = get_family(family).max_K
    if fam_cap is not None:
        max_K = min(max_K, fam_cap)
    w = jnp.full((d,), float(w_scale))
    if family == "l2":
        P1 = float(collision_prob_l2(jnp.asarray(R1), M, d, w, W))
        P2 = float(collision_prob_l2(jnp.asarray(R2), M, d, w, W))
    else:
        P1 = float(collision_prob_theta(jnp.asarray(R1), M, d, w))
        P2 = float(collision_prob_theta(jnp.asarray(R2), M, d, w))
    if not (0.0 < P2 < P1 < 1.0):
        raise ValueError(f"degenerate collision probs P1={P1} P2={P2}; widen (R1, R2)")
    r = math.log(P1) / math.log(P2)
    K = max(1, min(max_K, math.ceil(math.log(n) / math.log(1.0 / P2))))
    L = max(1, min(max_L, math.ceil(P1 ** (-K))))
    return IndexPlan(K=K, L=L, rho=r, P1=P1, P2=P2)


def success_probability(plan: IndexPlan) -> float:
    """P[some table collides with an R1-near neighbour] = 1 - (1 - P1^K)^L."""
    return 1.0 - (1.0 - plan.P1**plan.K) ** plan.L
