"""Beyond-paper optimization: MULTIPROBE querying for (d_w^l1, theta)-ALSH.

The paper's Theorem-1 construction needs L ~ n^rho independent tables — the
dominant memory cost. Multiprobe LSH (Lv et al., VLDB'07) recovers the same
success probability from far fewer tables by ALSO probing buckets whose keys
differ from the query's in the hash bits most likely to have flipped.

For the theta family each of the K bits is sign(a_j^T Q_w(q)); the flip
likelihood of bit j is monotone in -|a_j^T Q_w(q)| (small margin = likely
flip). We probe the T buckets given by flipping subsets of the lowest-margin
bits, in increasing total-margin order — the standard query-directed probing
sequence, computed entirely with static shapes (top-T over precomputed
subset scores).

Effect measured in benchmarks/multiprobe_bench.py: matching recall with
4-8x fewer tables (=> 4-8x less index memory and build hashing).

Execution-wise, multiprobe is ONLY a different key enumeration: this module
contributes ``multiprobe_keys_for`` — the (b, L, P) probing sequence — and
the :mod:`repro.engine` pipeline runs the identical sorted-window sources
and fused merge/dedupe/gather/rerank tail as the single-probe path (which
enumerates P = 1). The ``query_multiprobe*`` names below are thin wrappers
over that engine.
"""

from __future__ import annotations

import jax

from repro.core import transforms
from repro.core.families import flip_subsets, get_family
from repro.core.index import (
    ALSHIndex,
    DeltaSegment,
    IndexConfig,
    QueryResult,
)
from repro.kernels import ops

# re-exported for backward compatibility (the enumeration now lives with the
# family strategies in core.families)
_flip_subsets = flip_subsets


def multiprobe_keys_for(
    index: ALSHIndex,
    queries: jax.Array,
    weights: jax.Array,
    cfg: IndexConfig,
    n_probes: int,
    max_flips: int,
    with_ranks: bool = False,
) -> jax.Array:
    """The (b, L, P) query-directed probing sequence for a query batch —
    the query's own bucket key first, then perturbed keys in increasing
    flip-cost order. P may be clamped below ``n_probes`` by the family's
    reachable-subset count. Shared by the engine's key-enumeration stage,
    the planner's calibration pass, and ``Index.explain`` window
    diagnostics.

    With ``with_ranks=True`` returns ``(keys, ranks)`` where ``ranks`` is
    the (b, L, P) int32 per-window probe-quality rank. The family contract
    (``HashFamily.multiprobe_keys``: "most-likely first") makes the P-axis
    position the rank — rank 0 is the query's own bucket, rank p the
    (p+1)-th most likely perturbation — so ranks is the broadcast position
    index. The streamed early-exit tail (repro.engine.stream) relies on
    exactly this contract to visit windows in query-directed quality order
    (all rank-0 windows across tables before any rank-1 window) instead of
    table order; exposing it here keeps that assumption a tested API
    property rather than engine folklore. ``with_ranks=False`` is the
    original single-array return — bit-identical, nothing recomputed."""
    family = get_family(cfg.family)
    if not family.supports_multiprobe:
        raise ValueError(
            f"family {cfg.family!r} does not support multiprobe querying; "
            "build the index with family='theta' or query with "
            "QuerySpec(mode='probe')"
        )
    b = queries.shape[0]
    qlevels = transforms.discretize(queries, cfg.space)
    proj = ops.alsh_project(qlevels, index.tables.folded, weights)  # (b, H)
    keys = family.multiprobe_keys(proj.reshape(b, cfg.L, cfg.K), n_probes, max_flips)
    if not with_ranks:
        return keys
    import jax.numpy as jnp

    ranks = jnp.broadcast_to(
        jnp.arange(keys.shape[2], dtype=jnp.int32)[None, None, :], keys.shape
    )
    return keys, ranks


def query_multiprobe(
    index: ALSHIndex,
    queries: jax.Array,
    weights: jax.Array,
    cfg: IndexConfig,
    k: int = 1,
    n_probes: int = 8,
    max_flips: int = 3,
) -> QueryResult:
    """Multiprobe query: per table, probe the n_probes most likely buckets
    (query bucket + low-margin perturbations, ordered by the family's
    ``multiprobe_keys`` strategy)."""
    from repro.engine import query

    return query(
        index, None, None, queries, weights, cfg,
        k=k, mode="multiprobe", n_probes=n_probes, max_flips=max_flips,
    )


def query_multiprobe_segmented(
    index: ALSHIndex,
    delta: DeltaSegment,
    tombstones: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    cfg: IndexConfig,
    k: int = 1,
    n_probes: int = 8,
    max_flips: int = 3,
) -> QueryResult:
    """Two-segment multiprobe: the delta match uses the FULL (b, L, P)
    probing sequence — a delta row is a candidate iff one of the perturbed
    keys hits it in its own table, exactly the predicate the sorted-window
    probe applies to the sealed segment. See ``query_index_segmented`` for
    the id/tombstone contract."""
    from repro.engine import query

    return query(
        index, delta, tombstones, queries, weights, cfg,
        k=k, mode="multiprobe", n_probes=n_probes, max_flips=max_flips,
    )
