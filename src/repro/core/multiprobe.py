"""Beyond-paper optimization: MULTIPROBE querying for (d_w^l1, theta)-ALSH.

The paper's Theorem-1 construction needs L ~ n^rho independent tables — the
dominant memory cost. Multiprobe LSH (Lv et al., VLDB'07) recovers the same
success probability from far fewer tables by ALSO probing buckets whose keys
differ from the query's in the hash bits most likely to have flipped.

For the theta family each of the K bits is sign(a_j^T Q_w(q)); the flip
likelihood of bit j is monotone in -|a_j^T Q_w(q)| (small margin = likely
flip). We probe the T buckets given by flipping subsets of the lowest-margin
bits, in increasing total-margin order — the standard query-directed probing
sequence, computed entirely with static shapes (top-T over precomputed
subset scores).

Effect measured in benchmarks/multiprobe_bench.py: matching recall with
4-8x fewer tables (=> 4-8x less index memory and build hashing).

The probe tail is the same fused pipeline as ``query_index``
(``core.index.fused_rerank_topk``): the (b, L·P·C) probe ids are deduped by
sort and handed to the ``gather_rerank_topk`` kernel, which gathers candidate
rows directly from the (n, d) table and keeps the running top-k on-chip —
multiprobe's larger probe fan-out (P buckets per table) never materializes a
(b, L·P·C, d) candidate tensor.
"""

from __future__ import annotations

import itertools
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import hash_families as hf
from repro.core import transforms
from repro.core.index import (
    ALSHIndex,
    IndexConfig,
    QueryResult,
    _probe_one_table,
    fused_rerank_topk,
)
from repro.kernels import ops


def _flip_subsets(K: int, max_flips: int):
    """Static enumeration of bit-flip subsets (as masks), ordered by size."""
    subsets = [()]
    for r in range(1, max_flips + 1):
        subsets.extend(itertools.combinations(range(K), r))
    masks = jnp.zeros((len(subsets), K), jnp.bool_)
    for i, s in enumerate(subsets):
        for j in s:
            masks = masks.at[i, j].set(True)
    return masks  # (n_subsets, K)


@partial(jax.jit, static_argnames=("cfg", "k", "n_probes", "max_flips"))
def query_multiprobe(
    index: ALSHIndex,
    queries: jax.Array,
    weights: jax.Array,
    cfg: IndexConfig,
    k: int = 1,
    n_probes: int = 8,
    max_flips: int = 3,
) -> QueryResult:
    """theta-family multiprobe query: per table, probe the n_probes most
    likely buckets (query bucket + low-margin bit flips)."""
    assert cfg.family == "theta" and cfg.K <= 31
    b, d = queries.shape
    C = cfg.max_candidates
    K, L = cfg.K, cfg.L

    qlevels = transforms.discretize(queries, cfg.space)
    proj = ops.alsh_project(qlevels, index.tables.folded, weights)  # (b, H)
    proj = proj.reshape(b, L, K)
    bits = (proj >= 0).astype(jnp.int32)  # (b, L, K)
    margins = jnp.abs(proj)  # flip cost per bit

    masks = _flip_subsets(K, max_flips)  # (S, K)
    # score of a subset = total margin flipped (lower = more likely)
    scores = jnp.einsum("blk,sk->bls", margins, masks.astype(proj.dtype))
    n_probes = min(n_probes, masks.shape[0])
    _, probe_idx = jax.lax.top_k(-scores, n_probes)  # (b, L, P) best subsets

    shifts = (1 << jnp.arange(K, dtype=jnp.int32))[None, None, :]
    base_key = jnp.sum(bits * shifts, axis=-1)  # (b, L)
    flip_keys = jnp.sum(
        masks[probe_idx].astype(jnp.int32) * shifts[:, :, None, :], axis=-1
    )  # (b, L, P) xor masks as ints
    probe_keys = jnp.bitwise_xor(base_key[:, :, None], flip_keys)  # (b, L, P)

    # probe every (table, probe) pair
    probe = jax.vmap(  # over batch
        jax.vmap(  # over tables
            jax.vmap(_probe_one_table, in_axes=(None, None, 0, None)),  # over probes
            in_axes=(0, 0, 0, None),
        ),
        in_axes=(None, None, 0, None),
    )
    cand = probe(index.sorted_keys, index.perm, probe_keys, C)  # (b, L, P, C)
    return fused_rerank_topk(
        index, cand.reshape(b, L * n_probes * C), queries, weights, k
    )
