"""Beyond-paper optimization: MULTIPROBE querying for (d_w^l1, theta)-ALSH.

The paper's Theorem-1 construction needs L ~ n^rho independent tables — the
dominant memory cost. Multiprobe LSH (Lv et al., VLDB'07) recovers the same
success probability from far fewer tables by ALSO probing buckets whose keys
differ from the query's in the hash bits most likely to have flipped.

For the theta family each of the K bits is sign(a_j^T Q_w(q)); the flip
likelihood of bit j is monotone in -|a_j^T Q_w(q)| (small margin = likely
flip). We probe the T buckets given by flipping subsets of the lowest-margin
bits, in increasing total-margin order — the standard query-directed probing
sequence, computed entirely with static shapes (top-T over precomputed
subset scores).

Effect measured in benchmarks/multiprobe_bench.py: matching recall with
4-8x fewer tables (=> 4-8x less index memory and build hashing).

The probe tail is the same fused pipeline as ``query_index``
(``core.index.fused_rerank_topk``): the (b, L·P·C) probe ids are deduped by
sort and handed to the ``gather_rerank_topk`` kernel, which gathers candidate
rows directly from the (n, d) table and keeps the running top-k on-chip —
multiprobe's larger probe fan-out (P buckets per table) never materializes a
(b, L·P·C, d) candidate tensor.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import transforms
from repro.core.families import flip_subsets, get_family
from repro.core.index import (
    ALSHIndex,
    DeltaSegment,
    IndexConfig,
    QueryResult,
    _delta_candidates,
    _mask_dead,
    _probe_one_table,
    delta_live_mask,
    fused_rerank_topk,
    rerank_topk,
    segment_table,
)
from repro.kernels import ops

# re-exported for backward compatibility (the enumeration now lives with the
# family strategies in core.families)
_flip_subsets = flip_subsets


def multiprobe_keys_for(
    index: ALSHIndex,
    queries: jax.Array,
    weights: jax.Array,
    cfg: IndexConfig,
    n_probes: int,
    max_flips: int,
) -> jax.Array:
    """The (b, L, P) query-directed probing sequence for a query batch —
    the query's own bucket key first, then perturbed keys in increasing
    flip-cost order. P may be clamped below ``n_probes`` by the family's
    reachable-subset count. Shared by the query path, the planner's
    calibration pass, and ``Index.explain`` window diagnostics."""
    family = get_family(cfg.family)
    if not family.supports_multiprobe:
        raise ValueError(
            f"family {cfg.family!r} does not support multiprobe querying; "
            "build the index with family='theta' or query with "
            "QuerySpec(mode='probe')"
        )
    b = queries.shape[0]
    qlevels = transforms.discretize(queries, cfg.space)
    proj = ops.alsh_project(qlevels, index.tables.folded, weights)  # (b, H)
    return family.multiprobe_keys(proj.reshape(b, cfg.L, cfg.K), n_probes, max_flips)


def _multiprobe_candidates(
    index: ALSHIndex,
    queries: jax.Array,
    weights: jax.Array,
    cfg: IndexConfig,
    n_probes: int,
    max_flips: int,
) -> tuple[jax.Array, jax.Array]:
    """Multiprobe front half: probing sequence + window-probe of every
    (table, probe) pair. Returns ((b, L·P·C) raw candidate ids, (b, L, P)
    probe keys — reused by the delta-segment probe)."""
    b, d = queries.shape
    C = cfg.max_candidates
    K, L = cfg.K, cfg.L

    probe_keys = multiprobe_keys_for(index, queries, weights, cfg, n_probes, max_flips)
    n_probes = probe_keys.shape[-1]  # family may clamp to the subset count

    # probe every (table, probe) pair
    probe = jax.vmap(  # over batch
        jax.vmap(  # over tables
            jax.vmap(_probe_one_table, in_axes=(None, None, 0, None)),  # over probes
            in_axes=(0, 0, 0, None),
        ),
        in_axes=(None, None, 0, None),
    )
    cand = probe(index.sorted_keys, index.perm, probe_keys, C)  # (b, L, P, C)
    return cand.reshape(b, L * n_probes * C), probe_keys


@partial(jax.jit, static_argnames=("cfg", "k", "n_probes", "max_flips"))
def query_multiprobe(
    index: ALSHIndex,
    queries: jax.Array,
    weights: jax.Array,
    cfg: IndexConfig,
    k: int = 1,
    n_probes: int = 8,
    max_flips: int = 3,
) -> QueryResult:
    """Multiprobe query: per table, probe the n_probes most likely buckets
    (query bucket + low-margin perturbations, ordered by the family's
    ``multiprobe_keys`` strategy)."""
    cand, _ = _multiprobe_candidates(index, queries, weights, cfg, n_probes, max_flips)
    return fused_rerank_topk(index, cand, queries, weights, k)


@partial(jax.jit, static_argnames=("cfg", "k", "n_probes", "max_flips"))
def query_multiprobe_segmented(
    index: ALSHIndex,
    delta: DeltaSegment,
    tombstones: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    cfg: IndexConfig,
    k: int = 1,
    n_probes: int = 8,
    max_flips: int = 3,
) -> QueryResult:
    """Two-segment multiprobe: the delta match uses the FULL (b, L, P)
    probing sequence — a delta row is a candidate iff one of the perturbed
    keys hits it in its own table, exactly the predicate the sorted-window
    probe applies to the sealed segment. See ``query_index_segmented`` for
    the id/tombstone contract."""
    n_main = index.n
    cap = delta.capacity
    n_tot = n_main + cap
    cand, probe_keys = _multiprobe_candidates(
        index, queries, weights, cfg, n_probes, max_flips
    )
    cand = _mask_dead(cand, tombstones, n_main, n_tot)
    if cap:
        live = delta_live_mask(delta, tombstones, n_main)
        cand = jnp.concatenate(
            [cand, _delta_candidates(probe_keys, delta, live, n_main, n_tot)], axis=1
        )
    return rerank_topk(segment_table(index, delta), cand, queries, weights, k, n_tot)
