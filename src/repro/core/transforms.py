"""Paper §3–§4.1: discretization, unary coding, and the asymmetric transforms.

Pipeline (paper Observation 1 + Steps 1, 2):

  real space [Ml, Mu]^d --shift--> [0, Mu-Ml]^d --u_t--> lattice {0..M}^d
      --unary v(.)--> {0,1}^{Md}  --cos/sin (Obs 2)--> MIPS instance

with the closed forms (all verified by tests/test_transforms.py):

  P(o)   = ( 1 - v(o) ; v(o) )                 in {0,1}^{2Md}      (Eq 19)
  Q_w(q) = ( I(w) * (1 - v(q)) ; I(w) * v(q) ) in R^{2Md}          (Eq 20)
  d_w^l1(o, q) = M * sum_i(w_i) - <P(o), Q_w(q)>                   (Eq 21)
  ||P(o)||_2^2   = M * d                                           (Eq 22)
  ||Q_w(q)||_2^2 = M * sum_i(w_i^2)                                (Eq 23)

Note ``cos(pi/2 * bit) = 1 - bit`` and ``sin(pi/2 * bit) = bit`` for bits, so
the trigonometric construction collapses to complement/identity of the unary
code — the explicit materialization below exists for testing and for the naive
O(Md) baseline; production hashing NEVER materializes these vectors (see
hash_families.py for the paper's §4.2.3 O(d) trick).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class BoundedSpace(NamedTuple):
    """The bounded box [lo, hi]^d the data/queries live in (paper §3)."""

    lo: float
    hi: float
    t: float  # discretization resolution; M = floor((hi - lo) * t)

    @property
    def M(self) -> int:
        return int((self.hi - self.lo) * self.t)  # floor for positive operands


def discretize(x: jax.Array, space: BoundedSpace) -> jax.Array:
    """Observation 1: u_t(x) = floor((x - lo) * t), clipped to {0..M}.

    The clip guards against floating-point round-up at the upper boundary
    (e.g. hi * t = M + ulp); interior points are untouched.
    """
    levels = jnp.floor((x - space.lo) * space.t).astype(jnp.int32)
    return jnp.clip(levels, 0, space.M)


def discretization_slack(w: jax.Array, space: BoundedSpace) -> jax.Array:
    """Observation 1 threshold slack: |R' - R/t| <= sum_i |w_i| / t.

    An (R1, R2)-guarantee on the lattice transfers to
    (R1' , R2') = ((R1 - slack*t)/t, (R2 + slack*t)/t) on the box.
    """
    return jnp.sum(jnp.abs(w), axis=-1) / space.t


def unary_code(levels: jax.Array, M: int) -> jax.Array:
    """Step 1: v(x) — per-coordinate unary code. (..., d) int -> (..., d, M) {0,1}.

    Unary(x_i) = x_i ones followed by (M - x_i) zeros.
    """
    iota = jnp.arange(M, dtype=levels.dtype)
    return (iota[None, :] < levels[..., :, None]).astype(jnp.float32)


def transform_P(levels: jax.Array, M: int) -> jax.Array:
    """Eq 19: P(o) = (cos~(pi/2 v(o)) ; sin~(pi/2 v(o))) = (1 - v(o) ; v(o)).

    (..., d) int levels -> (..., 2*M*d) float. Reference implementation —
    O(Md) memory, used by tests and the naive baseline only.
    """
    v = unary_code(levels, M)  # (..., d, M)
    flat = v.reshape(*v.shape[:-2], -1)  # (..., d*M) — concat over coords
    return jnp.concatenate([1.0 - flat, flat], axis=-1)


def transform_Q(levels: jax.Array, w: jax.Array, M: int) -> jax.Array:
    """Eq 20: Q_w(q) = (I(w) ⊙ (1 - v(q)) ; I(w) ⊙ v(q)).

    I(w) repeats each w_i M times (matching the unary blocks).
    """
    v = unary_code(levels, M)  # (..., d, M)
    wv = w[..., :, None] * v  # weighted unary blocks
    wc = w[..., :, None] * (1.0 - v)
    flat_wv = wv.reshape(*wv.shape[:-2], -1)
    flat_wc = wc.reshape(*wc.shape[:-2], -1)
    return jnp.concatenate([flat_wc, flat_wv], axis=-1)


def wl1_via_mips(levels_o: jax.Array, levels_q: jax.Array, w: jax.Array, M: int) -> jax.Array:
    """Eq 21 evaluated literally: M*sum(w) - <P(o), Q_w(q)>. Test oracle."""
    P = transform_P(levels_o, M)
    Q = transform_Q(levels_q, w, M)
    return M * jnp.sum(w, axis=-1) - jnp.sum(P * Q, axis=-1)
