"""Hash families as strategy objects — one protocol, two paper schemes.

The paper defines two ALSH families over the transformed MIPS instance:

  * (d_w^l1, l2)-ALSH   — Eq 3, p-stable L2 hash, integer bucket codes
  * (d_w^l1, theta)-ALSH — Eq 5, SimHash sign bits

Every family-specific decision the engine has to make (how raw projections
become codes, how K codes combine into one int32 table key, whether
query-directed multiprobe applies, what is valid to configure) lives behind
the :class:`HashFamily` protocol below. The rest of the codebase —
``hash_families.py``, ``index.py``, ``multiprobe.py``, the ``repro.api``
facade — dispatches through ``get_family(name)`` instead of matching on
``"theta" | "l2"`` strings, so adding a third scheme (e.g. another weighted
distance from Hu & Li's companion work, arXiv:2011.11907) means implementing
one class, not editing four call paths.

Instances are stateless frozen singletons: safe to hash, compare, and close
over in jit'd code.
"""

from __future__ import annotations

import itertools
from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp

if TYPE_CHECKING:  # import only for annotations — avoids a core.index cycle
    from repro.core.index import IndexConfig

__all__ = [
    "HashFamily",
    "ThetaFamily",
    "L2Family",
    "THETA",
    "L2",
    "FAMILIES",
    "get_family",
    "flip_subsets",
    "n_flip_subsets",
]


class HashFamily:
    """Protocol (with shared behavior) for one ALSH hash family.

    Subclasses override the class attributes and the four hooks:
    ``validate``, ``make_offsets``, ``codes_from_projections``,
    ``combine_codes`` — plus ``multiprobe_keys`` when
    ``supports_multiprobe``. Instances carry no state (singletons below),
    so identity hashing/equality is correct under jit.
    """

    name: str = "abstract"
    supports_multiprobe: bool = False
    max_K: int | None = None  # per-table hash cap (None = unbounded)

    # -- configuration ------------------------------------------------------
    def validate(self, cfg: "IndexConfig") -> None:
        """Raise ValueError (naming the offending field) on bad geometry."""

    def make_offsets(self, key: jax.Array, n_hashes: int, W: float, dtype) -> jax.Array:
        """Per-hash offsets drawn at table-build time ((H,) array)."""
        raise NotImplementedError

    # -- hashing ------------------------------------------------------------
    def codes_from_projections(
        self, proj: jax.Array, offsets: jax.Array, W: float
    ) -> jax.Array:
        """(..., H) float projections -> (..., H) int32 hash codes."""
        raise NotImplementedError

    def combine_codes(self, codes_lk: jax.Array, mixers: jax.Array, K: int) -> jax.Array:
        """(..., L, K) int codes -> (..., L) int32 table keys."""
        raise NotImplementedError

    # -- multiprobe ---------------------------------------------------------
    def multiprobe_keys(
        self,
        proj_lk: jax.Array,
        n_probes: int,
        max_flips: int,
    ) -> jax.Array:
        """(b, L, K) raw projections -> (b, L, P) probe keys, most-likely first."""
        raise NotImplementedError(
            f"family {self.name!r} does not support multiprobe querying; "
            "use the 'theta' family or QuerySpec(mode='probe')"
        )


class ThetaFamily(HashFamily):
    """(d_w^l1, theta)-ALSH — Eq 5 SimHash sign bits, exact bit-packed keys."""

    name = "theta"
    supports_multiprobe = True
    max_K = 31  # int32 bit-packing limit

    def validate(self, cfg: "IndexConfig") -> None:
        if cfg.K > 31:
            raise ValueError(
                "IndexConfig.K: the theta family packs K sign bits into one "
                f"int32 table key, which requires K <= 31 (got K={cfg.K}); "
                "use more tables (L) or the 'l2' family instead"
            )

    def make_offsets(self, key, n_hashes, W, dtype):
        return jnp.zeros((n_hashes,), dtype)  # sign hash has no offset

    def codes_from_projections(self, proj, offsets, W):
        return (proj >= 0).astype(jnp.int32)  # Eq 5

    def combine_codes(self, codes_lk, mixers, K):
        # exact bit-packing — zero spurious collisions (K <= 31 by validate)
        shifts = (1 << jnp.arange(K, dtype=jnp.int32))[None, :]
        return jnp.sum(codes_lk.astype(jnp.int32) * shifts, axis=-1)

    def multiprobe_keys(self, proj_lk, n_probes, max_flips):
        """Query-directed probing (Lv et al., VLDB'07): probe the buckets
        whose keys flip the lowest-|margin| bits of the query's code."""
        b, L, K = proj_lk.shape
        bits = (proj_lk >= 0).astype(jnp.int32)  # (b, L, K)
        margins = jnp.abs(proj_lk)  # flip cost per bit

        masks = flip_subsets(K, max_flips)  # (S, K)
        # score of a subset = total margin flipped (lower = more likely)
        scores = jnp.einsum("blk,sk->bls", margins, masks.astype(proj_lk.dtype))
        n_probes = min(n_probes, masks.shape[0])
        _, probe_idx = jax.lax.top_k(-scores, n_probes)  # (b, L, P) best subsets

        shifts = (1 << jnp.arange(K, dtype=jnp.int32))[None, None, :]
        base_key = jnp.sum(bits * shifts, axis=-1)  # (b, L)
        flip_keys = jnp.sum(
            masks[probe_idx].astype(jnp.int32) * shifts[:, :, None, :], axis=-1
        )  # (b, L, P) xor masks as ints
        return jnp.bitwise_xor(base_key[:, :, None], flip_keys)  # (b, L, P)


class L2Family(HashFamily):
    """(d_w^l1, l2)-ALSH — Eq 3 p-stable hash, mixed integer-code keys."""

    name = "l2"
    supports_multiprobe = False

    def validate(self, cfg: "IndexConfig") -> None:
        if cfg.W <= 0:
            raise ValueError(
                f"IndexConfig.W: the l2 family's bucket width must be > 0, got {cfg.W}"
            )

    def make_offsets(self, key, n_hashes, W, dtype):
        return jax.random.uniform(key, (n_hashes,), dtype=dtype, minval=0.0, maxval=W)

    def codes_from_projections(self, proj, offsets, W):
        return jnp.floor((proj + offsets[None, :]) / W).astype(jnp.int32)  # Eq 3

    def combine_codes(self, codes_lk, mixers, K):
        # unbounded int codes: random odd-multiplier mixing (universal-style);
        # spurious collisions only ADD candidates — the exact re-rank keeps
        # correctness, the candidate budget keeps cost bounded.
        mixed = codes_lk.astype(jnp.int32) * mixers  # wrapping int32 mul
        return jnp.sum(mixed, axis=-1)


def n_flip_subsets(K: int, max_flips: int) -> int:
    """How many distinct probe keys ``flip_subsets`` can reach: the number
    of bit-flip subsets of size <= max_flips, INCLUDING the empty subset
    (the query's own bucket). ``n_probes`` beyond this count can only probe
    duplicate buckets — the facade rejects such specs up front."""
    import math

    return sum(math.comb(K, r) for r in range(0, min(max_flips, K) + 1))


def flip_subsets(K: int, max_flips: int) -> jax.Array:
    """Static enumeration of bit-flip subsets (as masks), ordered by size."""
    subsets = [()]
    for r in range(1, max_flips + 1):
        subsets.extend(itertools.combinations(range(K), r))
    masks = jnp.zeros((len(subsets), K), jnp.bool_)
    for i, s in enumerate(subsets):
        for j in s:
            masks = masks.at[i, j].set(True)
    return masks  # (n_subsets, K)


THETA = ThetaFamily()
L2 = L2Family()
FAMILIES: dict[str, HashFamily] = {f.name: f for f in (THETA, L2)}


def get_family(name: str) -> HashFamily:
    """Resolve a family by name (or pass a strategy object through)."""
    if isinstance(name, HashFamily):
        return name
    fam = FAMILIES.get(name)
    if fam is None:
        raise ValueError(
            f"unknown hash family {name!r}; known families: {sorted(FAMILIES)}"
        )
    return fam
