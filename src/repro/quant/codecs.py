"""Row codecs: how a table segment's rows are stored on device.

A codec maps an ``(n, d)`` f32 row block to its ENCODED payload (plus an
optional ``(d,)`` f32 scale vector) and back. The contract every codec
obeys:

  * ``encode`` is called ONCE per sealed segment, at build/compact time,
    AFTER hashing — the lattice levels and bucket keys are always computed
    from the raw rows, so the probe stage is codec-invariant.
  * ``encode_rows`` encodes post-build inserts WITH THE SEALED SEGMENT'S
    scales (a delta row never gets its own scale vector — both segments
    must decode under one transform so the fused two-segment gather can
    apply a single scale stream).
  * ``decode`` is exact for ``f32`` (identity — same array object) and
    ``bf16`` (widening cast), and ``payload * scales`` for ``int8``.
  * the decoded row NEVER materializes as a resident table: the fused
    kernels decode per gathered row (Pallas) or per candidate chunk
    (chunked CPU). ``decode_table`` exists for the oracle paths (exact
    scan, planner calibration, host-side re-sharding) only.

Symmetric int8: ``scale_j = max_i |x_ij| / 127`` per dimension,
``enc = clip(round(x / scale), -127, 127)``. Symmetric (no zero point)
keeps the weighted-l1 proxy exact up to the scale factor:
``sum_j w_j s_j |enc_x - enc_q|_j`` needs no offset correction.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

STORAGE_KINDS = ("f32", "bf16", "int8")

# int8 symmetric range: full [-127, 127] (−128 unused keeps |enc| symmetric)
_INT8_MAX = 127.0


@dataclasses.dataclass(frozen=True)
class RowCodec:
    """One storage format for table-segment rows.

    Attributes:
      name: registry key — the ``IndexConfig.storage`` value.
      dtype: payload dtype the segment arrays hold.
      bytes_per_value: payload bytes per coordinate (the memory-ratio and
        bytes-gathered accounting unit).
      scaled: whether this codec stores a per-dimension scale vector.
    """

    name: str
    dtype: jnp.dtype
    bytes_per_value: int
    scaled: bool

    def encode(self, data: jax.Array) -> tuple[jax.Array, jax.Array | None]:
        """(n, d) f32 rows -> (payload, scales-or-None). Build/compact only."""
        if self.name == "f32":
            return data, None
        if self.name == "bf16":
            return data.astype(jnp.bfloat16), None
        scales = self.fit_scales(data)
        return self.encode_rows(data, scales), scales

    def fit_scales(self, data: jax.Array) -> jax.Array:
        """(d,) f32 symmetric per-dimension scales of a row block.

        All-zero dimensions get scale 1.0 (they encode to 0 either way;
        a zero scale would poison the decode with 0/0)."""
        amax = jnp.max(jnp.abs(data.astype(jnp.float32)), axis=0)  # (d,)
        return jnp.where(amax > 0, amax / _INT8_MAX, 1.0)

    def encode_rows(self, rows: jax.Array, scales: jax.Array | None) -> jax.Array:
        """Encode rows under EXISTING scales (delta inserts into a sealed
        segment). Out-of-range values saturate — they were outside the
        sealed segment's observed range, so the proxy distance for them is
        clamped, never garbage; the exact rerank still sees the decoded
        (saturated) row."""
        if self.name == "f32":
            return rows.astype(jnp.float32)
        if self.name == "bf16":
            return rows.astype(jnp.bfloat16)
        q = jnp.round(rows.astype(jnp.float32) / scales)
        return jnp.clip(q, -_INT8_MAX, _INT8_MAX).astype(jnp.int8)

    def decode(self, payload: jax.Array, scales: jax.Array | None) -> jax.Array:
        """Encoded rows -> f32 rows (f32 payloads pass through untouched)."""
        if payload.dtype == jnp.float32:
            return payload
        out = payload.astype(jnp.float32)
        if scales is not None:
            out = out * scales
        return out


_CODECS = {
    "f32": RowCodec(name="f32", dtype=jnp.dtype(jnp.float32), bytes_per_value=4, scaled=False),
    "bf16": RowCodec(name="bf16", dtype=jnp.dtype(jnp.bfloat16), bytes_per_value=2, scaled=False),
    "int8": RowCodec(name="int8", dtype=jnp.dtype(jnp.int8), bytes_per_value=1, scaled=True),
}


def get_codec(name: str) -> RowCodec:
    codec = _CODECS.get(name)
    if codec is None:
        raise ValueError(
            f"unknown storage codec {name!r}; registered codecs: {STORAGE_KINDS}"
        )
    return codec


def storage_dtype(name: str) -> jnp.dtype:
    """Payload dtype of a named codec."""
    return get_codec(name).dtype


def bytes_per_value(name: str) -> int:
    return get_codec(name).bytes_per_value


def codec_for_dtype(dtype) -> RowCodec:
    """The codec whose payload dtype matches a stored segment array (used to
    cross-check a persistence manifest against its payload)."""
    dtype = jnp.dtype(dtype)
    for codec in _CODECS.values():
        if codec.dtype == dtype:
            return codec
    raise ValueError(
        f"no registered storage codec stores dtype {dtype} — the payload was "
        f"written by an incompatible build"
    )


def decode_table(payload: jax.Array, scales: jax.Array | None) -> jax.Array:
    """Whole-table decode for the ORACLE paths only (exact scan, planner
    calibration sampling, host-side re-shard). The query tail never calls
    this — it decodes per gathered row inside the fused kernels."""
    return codec_for_dtype(payload.dtype).decode(payload, scales)
