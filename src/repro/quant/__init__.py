"""Quantized table tier: compressed row storage + proxy screening.

The fused gather tail moves one full-precision data row per candidate, so
HBM capacity/bandwidth — not FLOPs — bound rows-per-host. This package is
the storage-tier answer: pluggable row codecs encode each table segment at
build/compact time (the ENCODED payload is what lives in ``ALSHIndex.data``
— there is no resident f32 copy), and the engine screens candidates against
the compressed rows with a cheap proxy distance before running the exact
f32 rerank on the ``k·α`` survivors. Hash keys are computed from the raw
rows BEFORE encoding, so candidate generation is bit-identical across
codecs — only the rerank tail sees the compression.

Codecs:
  * ``f32``  — passthrough (the default; every path bit-identical to an
    unquantized index).
  * ``bf16`` — truncated-mantissa rows, 2x smaller; decode is a widening
    cast (exact).
  * ``int8`` — symmetric per-dimension quantization with stored (d,) f32
    scales, 4x smaller; decode is ``row * scale``.

See DESIGN.md §11 "Memory tiers" for the screening math and the α
calibration contract.
"""

from repro.quant.codecs import (
    STORAGE_KINDS,
    RowCodec,
    bytes_per_value,
    decode_table,
    get_codec,
    storage_dtype,
)
from repro.quant.screen import proxy_query, screen_keep

__all__ = [
    "STORAGE_KINDS",
    "RowCodec",
    "bytes_per_value",
    "decode_table",
    "get_codec",
    "proxy_query",
    "screen_keep",
    "storage_dtype",
]
