"""The quantized-proxy screen: transform a query so the EXISTING fused
gather/top-k machinery computes the compressed-domain proxy distance.

For the symmetric int8 codec the weighted-l1 distance between DEQUANTIZED
rows factors through the stored integer levels:

    d_w(x̂, q̂) = Σ_j w_j · |enc_x[j]·s_j − enc_q[j]·s_j|
              = Σ_j (w_j·s_j) · |enc_x[j] − enc_q[j]|

so screening needs NO decode at all: quantize the query once per batch
(``enc_q = clip(round(q/s), ±127)``), fold the scales into the weights
(``w' = w·s``), and run the stock gather/rerank/top-m kernels over the raw
int8 rows — the gather stays byte-bound, which is the whole point. For
``bf16`` the proxy is the weighted-l1 between the bf16-rounded query and
the bf16 rows (widened in-register; no scale fold needed). ``f32`` never
screens — the engine statically disables the pass, keeping the default
storage bit-identical to the unscreened engine.

The proxy is LOSSY (quantization error can reorder near-ties), which is why
it only SELECTS the top ``keep = ceil(k·α)`` survivors; the exact f32 rerank
over decoded rows always has the final word. α is a ``QuerySpec`` /
``PlannedSpec`` knob the planner calibrates against the recall target.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.quant.codecs import _INT8_MAX


def proxy_query(
    queries: jax.Array, weights: jax.Array, storage_dtype, scales: jax.Array | None
) -> tuple[jax.Array, jax.Array]:
    """(queries, weights) -> (q', w') such that the stock wl1 kernels over
    the RAW encoded rows compute the screening proxy distance.

    int8 (``scales`` present): q' is the quantized query in integer levels
    (f32-valued), w' = w·s — exactly the dequantized weighted-l1 between
    codes. bf16: q' is the bf16-rounded query (widened back to f32 so the
    kernel accumulators stay f32), w' unchanged. f32: identity (callers
    never screen f32, but the transform is total)."""
    q = queries.astype(jnp.float32)
    w = weights.astype(jnp.float32)
    if scales is not None:
        enc_q = jnp.clip(jnp.round(q / scales), -_INT8_MAX, _INT8_MAX)
        return enc_q, w * scales
    if jnp.dtype(storage_dtype) == jnp.dtype(jnp.bfloat16):
        return q.astype(jnp.bfloat16).astype(jnp.float32), w
    return q, w


def screen_keep(k: int, screen_alpha: float, n_slots: int) -> int:
    """Static survivor count of a screen pass: ``ceil(k·α)`` clamped to
    ``[k, n_slots]``. Returns 0 — screening statically disabled — when α is
    0 (off) or the survivor set would cover every candidate slot anyway
    (screening would gather every row twice for nothing)."""
    if not screen_alpha or screen_alpha <= 0.0:
        return 0
    keep = max(int(k), int(math.ceil(k * screen_alpha)))
    if keep >= n_slots:
        return 0
    return keep
