"""repro — sublinear-time NNS over generalized weighted Manhattan distance.

A production-grade JAX framework reproducing and extending:

    Hu & Li, "Sublinear Time Nearest Neighbor Search over Generalized
    Weighted Manhattan Distance", 2021.

Public API surface (stable):
    repro.api         — THE facade: config-carrying Index, QuerySpec policies,
                        self-describing save/load, mesh sharding
    repro.engine      — candidate-stream execution engine: one probe→merge→
                        dedupe→rerank pipeline behind every query mode
    repro.core        — data structures + primitives: ALSH transforms, hash
                        family strategies, theory, Theorem-1 index (legacy
                        shims live here)
    repro.distance    — d_w^l1 / d_w^l2 reference distances + brute force NN
    repro.kernels     — Pallas TPU kernels (ops wrappers fall back to jnp on CPU)
    repro.models      — assigned LM architectures
    repro.configs     — per-architecture configs (``--arch <id>``)
    repro.launch      — mesh / dryrun / train / serve entry points
"""

__version__ = "1.0.0"
