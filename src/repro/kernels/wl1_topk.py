"""Streaming top-k wl1 scan: exact k-NN without the (b, n) distance matrix.

``wl1_scan`` (wl1_distance.py) materializes every query-point distance and
leaves the top-k to XLA — O(b n) HBM writes + a second O(b n) read. For the
exact baseline and the distributed re-rank that traffic dominates, so this
kernel keeps a per-query running top-k (dists + ids) resident in VMEM across
the data-row grid axis and only ever writes the (b, k) result:

  grid (query-block i, data-block j, d-chunk kd) — kd innermost:
    * a VMEM scratch (BQ, BNV) accumulates partial weighted |diff| sums
      over d-chunks exactly like the scan kernel;
    * on the last d-chunk the finished block distances are merged into the
      running top-k output block (revisited across j — Pallas keeps it in
      VMEM) by a k-step selection: each step extracts the global argmin of
      [running top-k ‖ block] and appends it in ascending order.

Ties resolve toward earlier candidates ([prev top-k ‖ ascending block ids]),
matching ``lax.top_k`` order on exact equality. Rows padded past n enter with
+inf and id -1; queries short of k valid rows return (+inf, -1) tails —
identical semantics to the materializing oracle.

``wl1_scan_topk_chunked`` is the same algorithm in pure jnp (a fori_loop over
row chunks with a top_k merge) — the CPU production path: the working set
stays cache-sized instead of a (b, n) spill.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BQ = 8  # queries per block
BNV = 128  # data rows per block
BDV = 256  # coordinates per reduction step
LANE = 128  # top-k buffer lane alignment


def _merge_topk(top_d, top_i, blk_d, blk_i, k: int):
    """Selection-merge: ascending k smallest of [top ‖ blk] (pure jnp, kernel-safe).

    top_d/top_i: (BQ, KP) running top-k (ascending, +inf/-1 padded).
    blk_d/blk_i: (BQ, BN) new block distances / ids.
    Returns new (top_d, top_i) with the first k slots filled ascending.
    """
    cand_d = jnp.concatenate([top_d, blk_d], axis=1)
    cand_i = jnp.concatenate([top_i, blk_i], axis=1)
    kp = top_d.shape[1]
    out_iota = jax.lax.broadcasted_iota(jnp.int32, top_d.shape, 1)
    cand_iota = jax.lax.broadcasted_iota(jnp.int32, cand_d.shape, 1)
    init = (
        cand_d,
        cand_i,
        jnp.full(top_d.shape, jnp.inf, top_d.dtype),
        jnp.full(top_i.shape, -1, top_i.dtype),
    )

    def step(t, carry):
        cd, ci, nd, ni = carry
        pos = jnp.argmin(cd, axis=1)  # (BQ,) first-occurrence ⇒ stable ties
        sel = cand_iota == pos[:, None]
        mval = jnp.min(cd, axis=1)
        mid = jnp.sum(jnp.where(sel, ci, 0), axis=1)  # gather-free pick
        put = out_iota == t
        nd = jnp.where(put, mval[:, None], nd)
        ni = jnp.where(put, mid[:, None], ni)
        cd = jnp.where(sel, jnp.inf, cd)
        return cd, ci, nd, ni

    _, _, new_d, new_i = jax.lax.fori_loop(0, min(k, kp), step, init)
    return new_d, new_i


def _scan_topk_kernel(data_ref, q_ref, w_ref, outd_ref, outi_ref, acc_ref, *, k: int, n: int):
    j = pl.program_id(1)
    kd = pl.program_id(2)
    nd = pl.num_programs(2)

    @pl.when((j == 0) & (kd == 0))
    def _init_topk():
        outd_ref[...] = jnp.full_like(outd_ref, jnp.inf)
        outi_ref[...] = jnp.full_like(outi_ref, -1)

    diff = jnp.abs(data_ref[...][None, :, :] - q_ref[...][:, None, :])  # (BQ, BNV, BDV)
    partial = jnp.sum(w_ref[...][:, None, :] * diff, axis=-1)  # (BQ, BNV)

    @pl.when(kd == 0)
    def _acc_init():
        acc_ref[...] = partial

    @pl.when(kd != 0)
    def _acc():
        acc_ref[...] += partial

    @pl.when(kd == nd - 1)
    def _merge():
        row0 = j * BNV
        ids = row0 + jax.lax.broadcasted_iota(jnp.int32, acc_ref.shape, 1)  # (BQ, BNV)
        in_bounds = ids < n
        blk_d = jnp.where(in_bounds, acc_ref[...], jnp.inf)
        blk_i = jnp.where(in_bounds, ids, -1)
        new_d, new_i = _merge_topk(outd_ref[...], outi_ref[...], blk_d, blk_i, k)
        outd_ref[...] = new_d
        outi_ref[...] = new_i


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def wl1_scan_topk_pallas(
    data: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    k: int,
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """data (n, d), queries (b, d), weights (b, d) -> ((b, k) dists, (b, k) ids)."""
    n, d = data.shape
    b, _ = queries.shape
    kp = -k % LANE + k  # top-k buffer lane-aligned
    pn = -n % BNV
    pb = -b % BQ
    pd = -d % BDV
    data_p = jnp.pad(data.astype(jnp.float32), ((0, pn), (0, pd)))
    q_p = jnp.pad(queries.astype(jnp.float32), ((0, pb), (0, pd)))
    w_p = jnp.pad(weights.astype(jnp.float32), ((0, pb), (0, pd)))
    bp, dp = q_p.shape
    np_ = data_p.shape[0]
    grid = (bp // BQ, np_ // BNV, dp // BDV)
    out_d, out_i = pl.pallas_call(
        functools.partial(_scan_topk_kernel, k=k, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BNV, BDV), lambda i, j, kd: (j, kd)),
            pl.BlockSpec((BQ, BDV), lambda i, j, kd: (i, kd)),
            pl.BlockSpec((BQ, BDV), lambda i, j, kd: (i, kd)),
        ],
        out_specs=(
            pl.BlockSpec((BQ, kp), lambda i, j, kd: (i, 0)),
            pl.BlockSpec((BQ, kp), lambda i, j, kd: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((bp, kp), jnp.float32),
            jax.ShapeDtypeStruct((bp, kp), jnp.int32),
        ),
        scratch_shapes=[pltpu.VMEM((BQ, BNV), jnp.float32)],
        interpret=interpret,
    )(data_p, q_p, w_p)
    out_d, out_i = out_d[:b, :k], out_i[:b, :k]
    # invalid-slot contract (QueryResult): ids == -1 ⇔ dists == +inf — a row
    # whose distance overflowed to +inf reports "not found", matching the
    # _topk_ascending paths bit-for-bit
    return out_d, jnp.where(jnp.isfinite(out_d), out_i, -1)


@functools.partial(jax.jit, static_argnames=("k", "chunk"))
def wl1_scan_topk_chunked(
    data: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    k: int,
    chunk: int = 2048,
) -> tuple[jax.Array, jax.Array]:
    """Pure-jnp streaming top-k scan (CPU production path).

    Processes data rows in ``chunk``-sized windows, merging each window's
    distances into a running (b, k) top-k — peak live memory is
    O(b·chunk + b·k) instead of O(b·n).
    """
    n, d = data.shape
    b, _ = queries.shape
    pn = -n % chunk
    data_p = jnp.pad(data.astype(jnp.float32), ((0, pn), (0, 0)))
    q = queries.astype(jnp.float32)
    w = weights.astype(jnp.float32)
    n_chunks = data_p.shape[0] // chunk

    def body(c, carry):
        top_d, top_i = carry
        rows = jax.lax.dynamic_slice_in_dim(data_p, c * chunk, chunk, axis=0)
        dists = jnp.sum(w[:, None, :] * jnp.abs(rows[None, :, :] - q[:, None, :]), axis=-1)
        ids = c * chunk + jnp.arange(chunk, dtype=jnp.int32)
        ids = jnp.broadcast_to(ids[None, :], dists.shape)
        dists = jnp.where(ids < n, dists, jnp.inf)
        cand_d = jnp.concatenate([top_d, dists], axis=1)
        cand_i = jnp.concatenate([top_i, jnp.where(ids < n, ids, -1)], axis=1)
        neg, sel = jax.lax.top_k(-cand_d, k)
        return -neg, jnp.take_along_axis(cand_i, sel, axis=1)

    top_d = jnp.full((b, k), jnp.inf, jnp.float32)
    top_i = jnp.full((b, k), -1, jnp.int32)
    top_d, top_i = jax.lax.fori_loop(0, n_chunks, body, (top_d, top_i))
    # invalid-slot contract (QueryResult): ids == -1 ⇔ dists == +inf
    return top_d, jnp.where(jnp.isfinite(top_d), top_i, -1)
