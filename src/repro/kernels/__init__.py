"""Pallas TPU kernels for the paper's compute hot-spots.

  alsh_project  — §4.2.3 O(d) hash projection as a one-hot MXU contraction
  wl1_distance  — exact d_w^l1 scan / re-rank (VPU, materializing)
  wl1_topk      — streaming top-k scan: exact k-NN without the (b, n) matrix
  gather_rerank — fused probe tail: scalar-prefetch gather + re-rank + top-k
                  (never materializes the (b, L·C, d) candidate tensor)

``ops`` holds the jit'd dispatch wrappers (TPU → Pallas, CPU → jnp fast
path); ``ref`` holds the pure-jnp oracles every kernel is validated against.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
