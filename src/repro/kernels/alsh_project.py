"""Pallas TPU kernel for the §4.2.3 ALSH projection.

The paper's O(d) trick makes hashing a table lookup:

    proj[n, h] = sum_i  w[n, i] * folded[h, i, levels[n, i]]

GPU/CPU implementations do per-element gathers. TPU adaptation (DESIGN.md §2):
the lookup over the last axis of a VMEM-resident table is reformulated as a
**one-hot contraction on the MXU** — for each d-chunk we build the one-hot of
the levels on the fly (broadcasted-iota compare, never touching HBM), fold the
query weights into the one-hot, and issue a dense

    (bn, dc*(M+1)) @ (dc*(M+1), bh)

matmul, accumulating over d-chunks via the innermost grid dimension. Tables
tile VMEM as (bh, dc, M+1); MXU dims (bn, bh) are 128-aligned by the wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes (MXU-aligned). d-chunk keeps the one-hot tile ~ bn*dc*(M+1)*4 B
# in VMEM: with bn=128, dc=64, M+1=65 that's ~2.1 MB; folded tile bh*dc*(M+1)*4
# = 2.1 MB; comfortably inside the ~16 MB VMEM budget with double buffering.
BN = 128  # points per block
BH = 128  # hash functions per block
BD = 64  # coordinates per reduction step


def _project_kernel(levels_ref, weights_ref, folded_ref, out_ref, *, weighted: bool):
    """One (bn, bh) output tile; accumulates over the d-chunk grid axis."""
    kd = pl.program_id(2)

    levels = levels_ref[...]  # (BN, BD) int32
    m1 = folded_ref.shape[-1]
    # one-hot on the fly: (BN, BD, M+1)
    iota = jax.lax.broadcasted_iota(jnp.int32, (levels.shape[0], levels.shape[1], m1), 2)
    onehot = (iota == levels[:, :, None]).astype(folded_ref.dtype)
    if weighted:
        onehot = onehot * weights_ref[...][:, :, None].astype(folded_ref.dtype)

    lhs = onehot.reshape(levels.shape[0], -1)  # (BN, BD*(M+1))
    folded = folded_ref[...]  # (BH, BD, M+1)
    rhs = folded.reshape(folded.shape[0], -1)  # (BH, BD*(M+1))
    partial = jax.lax.dot_general(
        lhs,
        rhs,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (BN, BH)

    @pl.when(kd == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(kd != 0)
    def _accum():
        out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("interpret",))
def alsh_project_pallas(
    levels: jax.Array,
    folded: jax.Array,
    weights: jax.Array | None = None,
    *,
    interpret: bool = False,
) -> jax.Array:
    """Pallas entry point. levels (n, d) int32, folded (H, d, M+1) -> (n, H) f32.

    The wrapper pads every dim to block multiples (padded d-coords use level 0
    with zero table columns / zero weights, so they contribute exactly 0) and
    slices the result back.
    """
    n, d = levels.shape
    H, d2, m1 = folded.shape
    assert d == d2, (d, d2)
    weighted = weights is not None
    if not weighted:
        weights = jnp.ones((1, 1), jnp.float32)  # placeholder operand

    pn = -n % BN
    ph = -H % BH
    pd = -d % BD
    levels_p = jnp.pad(levels, ((0, pn), (0, pd)))
    folded_p = jnp.pad(folded, ((0, ph), (0, pd), (0, 0)))
    if weighted:
        weights_p = jnp.pad(weights.astype(jnp.float32), ((0, pn), (0, pd)))
    else:
        # broadcast placeholder to the padded point grid (never read as values
        # beyond masking; padded coords hit zero table columns anyway)
        weights_p = jnp.zeros((n + pn, d + pd), jnp.float32)

    np_, dp_ = levels_p.shape
    hp_ = folded_p.shape[0]
    grid = (np_ // BN, hp_ // BH, dp_ // BD)

    out = pl.pallas_call(
        functools.partial(_project_kernel, weighted=weighted),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BN, BD), lambda i, j, k: (i, k)),
            pl.BlockSpec((BN, BD), lambda i, j, k: (i, k)),
            pl.BlockSpec((BH, BD, m1), lambda i, j, k: (j, k, 0)),
        ],
        out_specs=pl.BlockSpec((BN, BH), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, hp_), jnp.float32),
        interpret=interpret,
    )(levels_p, weights_p, folded_p)
    return out[:n, :H]
