"""Fused ALSH probe tail: scalar-prefetch gather + exact re-rank + top-k.

The unfused tail (`index.data[ids]` → wl1_rerank → lax.top_k) materializes a
(b, L·C, d) candidate tensor in HBM and reads it straight back — for the
standard b=64, L·C=4096, d=128 probe that is two full passes over 128 MB the
query never needed. This kernel removes it: candidate ids are handed to
Pallas as **scalar-prefetch** arguments (`pltpu.PrefetchScalarGridSpec`), so
the BlockSpec index map — evaluated ahead of the grid step — points the
pipeline's DMA engine directly at the needed `(1, d-chunk)` row of the
(n, d) table in HBM. Each candidate's weighted |diff| partial sums accumulate
in a scalar scratch across d-chunks; the finished distance is folded into a
per-query VMEM top-k buffer by replace-max insertion:

  grid (query i, candidate j, d-chunk kd):
    data block  (1, BDR)  @ row  min(ids[i, j], n-1)   — the gather
    out blocks  (1, KP)   @ i                          — running top-k

Invalid candidates (padding, duplicates zapped by dedupe) carry the sentinel
id n: the index map clamps them to a readable row and the merge step drops
them. The buffer holds the KP (=128-aligned) smallest distances unsorted; the
wrapper sorts the (b, KP) result and slices (b, k) — exactly the oracle's
`ref.gather_rerank_topk` semantics ((+inf, -1) tails when fewer than k valid).

The CPU production path (`gather_rerank_topk_auto`) fuses in pure jnp and
picks its schedule by static footprint: a monolithic single-pass (one XLA
fusion region, no inter-stage materialization) while the (b, P, d) working
set is cache-resident, switching to `gather_rerank_topk_chunked` — a
fori_loop over candidate chunks (gather chunk → re-rank → top-k merge) that
keeps the live set at O(b·chunk·d) and skips all-sentinel chunks — once the
monolith would spill.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BDR = 128  # coordinates per d-chunk (gather DMA granularity)
KP_LANE = 128  # top-k buffer lane alignment


def _gather_rerank_kernel(ids_ref, row_ref, q_ref, w_ref, outd_ref, outi_ref, acc_ref, *, n: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    kd = pl.program_id(2)
    nd = pl.num_programs(2)

    @pl.when((j == 0) & (kd == 0))
    def _init_topk():
        outd_ref[...] = jnp.full_like(outd_ref, jnp.inf)
        outi_ref[...] = jnp.full_like(outi_ref, -1)

    partial = jnp.sum(w_ref[...] * jnp.abs(row_ref[...] - q_ref[...]))  # scalar

    @pl.when(kd == 0)
    def _acc_init():
        acc_ref[0, 0] = partial

    @pl.when(kd != 0)
    def _acc():
        acc_ref[0, 0] += partial

    @pl.when(kd == nd - 1)
    def _merge():
        cid = ids_ref[i, j]
        dist = acc_ref[0, 0]
        cur_d = outd_ref[...]  # (1, KP)
        cur_i = outi_ref[...]
        worst = jnp.max(cur_d)
        slot = jnp.argmax(cur_d)  # first-occurrence ⇒ fills +inf slots in order

        @pl.when((cid < n) & (dist < worst))
        def _insert():
            lane = jax.lax.broadcasted_iota(jnp.int32, cur_d.shape, 1)
            put = lane == slot
            outd_ref[...] = jnp.where(put, dist, cur_d)
            outi_ref[...] = jnp.where(put, cid, cur_i)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def gather_rerank_topk_pallas(
    data: jax.Array,
    ids: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    k: int,
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """data (n, d), ids (b, P) int32 (>= n ⇒ invalid), queries/weights (b, d)
    -> ((b, k) ascending dists, (b, k) ids)."""
    n, d = data.shape
    b, P = ids.shape
    kp = -min(k, P) % KP_LANE + min(k, P)
    pd = -d % BDR
    data_p = jnp.pad(data.astype(jnp.float32), ((0, 0), (0, pd)))
    q_p = jnp.pad(queries.astype(jnp.float32), ((0, 0), (0, pd)))
    w_p = jnp.pad(weights.astype(jnp.float32), ((0, 0), (0, pd)))
    dp = d + pd
    grid = (b, P, dp // BDR)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BDR), lambda i, j, kd, ids_ref: (jnp.minimum(ids_ref[i, j], n - 1), kd)),
            pl.BlockSpec((1, BDR), lambda i, j, kd, ids_ref: (i, kd)),
            pl.BlockSpec((1, BDR), lambda i, j, kd, ids_ref: (i, kd)),
        ],
        out_specs=(
            pl.BlockSpec((1, kp), lambda i, j, kd, ids_ref: (i, 0)),
            pl.BlockSpec((1, kp), lambda i, j, kd, ids_ref: (i, 0)),
        ),
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.float32)],
    )
    out_d, out_i = pl.pallas_call(
        functools.partial(_gather_rerank_kernel, n=n),
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((b, kp), jnp.float32),
            jax.ShapeDtypeStruct((b, kp), jnp.int32),
        ),
        interpret=interpret,
    )(ids.astype(jnp.int32), data_p, q_p, w_p)
    # buffer is the kp smallest, unsorted — order + trim to k outside the kernel
    from repro.kernels.ref import _topk_ascending

    return _topk_ascending(out_d, out_i, k)


# Above this candidate-tensor footprint (b·P·d·4 bytes) the one-shot XLA
# fusion starts spilling LLC on CPU and the chunked streaming schedule wins
# (measured crossover between 16 MB and 32 MB on x86; see BENCH_kernels.json).
MONOLITH_BYTES = 24 * 1024 * 1024


@functools.partial(jax.jit, static_argnames=("k",))
def _gather_rerank_topk_monolith(
    data: jax.Array,
    ids: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """One-shot fused tail: same math as the oracle but inside a single jit
    region, so XLA folds gather → re-rank → top-k into one pass with no
    inter-stage materialization. Best schedule while the candidate tensor
    stays cache-resident."""
    from repro.kernels import ref

    return ref.gather_rerank_topk(data, ids, queries, weights, k)


def gather_rerank_topk_auto(
    data: jax.Array,
    ids: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """CPU production dispatch: pick the fused schedule by static footprint —
    monolithic single-pass when the (b, P, d) working set fits on-chip,
    chunked streaming (skip-capable) when it would spill."""
    b, P = ids.shape
    d = data.shape[1]
    if b * P * d * 4 <= MONOLITH_BYTES:
        return _gather_rerank_topk_monolith(data, ids, queries, weights, k)
    return gather_rerank_topk_chunked(data, ids, queries, weights, k)


@functools.partial(jax.jit, static_argnames=("k", "chunk"))
def gather_rerank_topk_chunked(
    data: jax.Array,
    ids: jax.Array,
    queries: jax.Array,
    weights: jax.Array,
    k: int,
    chunk: int = 256,
) -> tuple[jax.Array, jax.Array]:
    """Pure-jnp fused tail (CPU production path): chunked gather → re-rank →
    streaming top-k merge. Never materializes the (b, P, d) tensor.

    Chunks whose every id is the invalid sentinel are skipped entirely
    (a cheap predicate guards the gather + reduction) — with the dedupe
    stage packing unique ids first, the loop does O(#unique) work however
    large the L·C probe budget is."""
    n, d = data.shape
    b, P = ids.shape
    pc = -P % chunk
    ids_p = jnp.pad(ids.astype(jnp.int32), ((0, 0), (0, pc)), constant_values=n)
    n_chunks = ids_p.shape[1] // chunk
    q = queries.astype(jnp.float32)
    w = weights.astype(jnp.float32)
    data_f = data.astype(jnp.float32)

    def body(c, carry):
        cid = jax.lax.dynamic_slice_in_dim(ids_p, c * chunk, chunk, axis=1)  # (b, chunk)
        valid = cid < n

        def compute(carry):
            top_d, top_i = carry
            pts = data_f[jnp.minimum(cid, n - 1)]  # (b, chunk, d)
            dists = jnp.sum(w[:, None, :] * jnp.abs(pts - q[:, None, :]), axis=-1)
            dists = jnp.where(valid, dists, jnp.inf)
            cand_d = jnp.concatenate([top_d, dists], axis=1)
            cand_i = jnp.concatenate([top_i, jnp.where(valid, cid, -1)], axis=1)
            neg, sel = jax.lax.top_k(-cand_d, top_d.shape[1])
            return -neg, jnp.take_along_axis(cand_i, sel, axis=1)

        return jax.lax.cond(jnp.any(valid), compute, lambda cr: cr, carry)

    kk = max(1, min(k, P))
    top_d = jnp.full((b, kk), jnp.inf, jnp.float32)
    top_i = jnp.full((b, kk), -1, jnp.int32)
    top_d, top_i = jax.lax.fori_loop(0, n_chunks, body, (top_d, top_i))
    if top_d.shape[1] < k:
        top_d = jnp.pad(top_d, ((0, 0), (0, k - top_d.shape[1])), constant_values=jnp.inf)
        top_i = jnp.pad(top_i, ((0, 0), (0, k - top_i.shape[1])), constant_values=-1)
    return top_d[:, :k], jnp.where(jnp.isfinite(top_d[:, :k]), top_i[:, :k], -1)
